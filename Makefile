PY ?= python

.PHONY: test test-fast bench dev

dev:
	$(PY) -m pip install -r requirements-dev.txt

# tier-1 verification command (ROADMAP.md)
test:
	PYTHONPATH=src $(PY) -m pytest -x -q

test-fast:
	PYTHONPATH=src $(PY) -m pytest -q tests/test_retrieval.py \
		tests/test_seismic_core.py tests/test_sparse_ops.py

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run
