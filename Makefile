PY ?= python

.PHONY: test test-fast bench bench-serving bench-replica bench-graph \
	bench-tune bench-kernels bench-obs bench-audit bench-mutation \
	bench-compare dev

dev:
	$(PY) -m pip install -r requirements-dev.txt

# tier-1 verification command (ROADMAP.md)
test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# interpret-mode kernel/router parity + core invariants (the CI fast job)
test-fast:
	PYTHONPATH=src $(PY) -m pytest -q tests/test_retrieval.py \
		tests/test_superblocks.py tests/test_seismic_core.py \
		tests/test_sparse_ops.py tests/test_kernels.py \
		tests/test_serve_async.py tests/test_graph_refine.py \
		tests/test_tune_properties.py

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

# serving-load smoke: tiny collection, async vs sync QPS (~3s)
bench-serving:
	PYTHONPATH=src $(PY) -m benchmarks.serving_load --smoke

# replica smoke: 1->4 replica QPS scaling + slow-replica p99 gates
bench-replica:
	PYTHONPATH=src $(PY) -m benchmarks.serving_load --smoke --replica

# graph-refinement smoke: recall lift + degree-0 bit-exactness gates
bench-graph:
	PYTHONPATH=src $(PY) -m benchmarks.graph_refine --smoke

# autotune smoke: tuned point beats hand configs + pre-tune back-compat
bench-tune:
	PYTHONPATH=src $(PY) -m benchmarks.autotune --smoke

# kernel microbench smoke: tiling sweep + fused-path parity gates +
# candidate-compaction tile-skip gate
bench-kernels:
	PYTHONPATH=src $(PY) -m benchmarks.kernel_microbench --smoke

# observability overhead smoke: component-gated <5% p50 / <3% QPS
# (instrumented arm includes the shadow auditor at default cadence)
bench-obs:
	PYTHONPATH=src $(PY) -m benchmarks.obs_overhead --smoke

# quality-plane smoke: live-recall Wilson gate, funnel completeness,
# mistuned-policy SLO breach
bench-audit:
	PYTHONPATH=src $(PY) -m benchmarks.serving_load --smoke --audit

# streaming-mutation smoke: insert/compaction latency + recall-vs-fresh
# ratio gates + delete-absence gate
bench-mutation:
	PYTHONPATH=src $(PY) -m benchmarks.mutation --smoke

# regression sentinel: fresh artifacts vs committed baselines
bench-compare:
	PYTHONPATH=src $(PY) -m benchmarks.run \
		--only serving_load,obs_overhead,mutation --smoke \
		--artifacts bench-artifacts
	$(PY) -m benchmarks.compare --baseline benchmarks/baselines \
		--fresh bench-artifacts
