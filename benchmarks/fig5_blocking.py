"""Fig. 5: fixed vs geometric blocking.

Same collection, same query knobs; one index built with shallow-K-Means
geometric blocks, one with impact-ordered fixed-size chunks. Geometric
blocking should dominate the accuracy-per-docs-evaluated frontier.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import (INDEX, built_index, collection, mean_recall,
                               row)
from repro.core import SearchParams, search_batch


def run() -> list[str]:
    docs, queries, docs_np, queries_np, eids = collection()
    geo_idx, _ = built_index()
    fixed_cfg = dataclasses.replace(
        INDEX, blocking="fixed",
        block_cap=max(INDEX.lam // INDEX.beta, 8))  # match geo block size
    fixed_idx, _ = built_index(fixed_cfg)
    out = []
    for tag, idx in (("geometric", geo_idx), ("fixed", fixed_idx)):
        for b in (4, 8, 16, 32):
            p = SearchParams(k=10, cut=10, block_budget=b, policy="budget")
            _, ids, ev = search_batch(idx, queries, p)
            out.append(row(f"fig5_{tag}_b{b}", 0.0,
                           recall=round(mean_recall(ids, eids), 4),
                           docs=int(np.asarray(ev).mean())))
    return out
