"""Shared benchmark fixtures: one synthetic SPLADE-like collection per
scale, exact ground truth, timing helpers.

Latency numbers are single-thread CPU wall time of the jitted JAX
implementation — NOT comparable to the paper's Rust microseconds on an
i9-9900K; the hardware-independent reproduction metrics are recall and
docs-evaluated (see EXPERIMENTS.md §Repro).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import SeismicConfig, build_index
from repro.core.baselines import exact_search
from repro.data import SyntheticSparseConfig, make_collection
from repro.obs.quality import recall_at_k
from repro.sparse.ops import PaddedSparse

SMALL = SyntheticSparseConfig(dim=2048, n_docs=16384, n_queries=64,
                              doc_nnz=96, query_nnz=32, n_topics=64,
                              topic_coords=256, seed=11)

INDEX = SeismicConfig(lam=192, beta=12, alpha=0.4, block_cap=32,
                      summary_nnz=48)

_cache: dict = {}


def collection(cfg: SyntheticSparseConfig = SMALL):
    key = ("col", cfg)
    if key not in _cache:
        docs_np, queries_np, meta = make_collection(cfg)
        docs = PaddedSparse(jnp.asarray(docs_np.coords),
                            jnp.asarray(docs_np.vals), docs_np.dim)
        queries = PaddedSparse(jnp.asarray(queries_np.coords),
                               jnp.asarray(queries_np.vals), queries_np.dim)
        es, eids = exact_search(docs, queries, 10)
        _cache[key] = (docs, queries, docs_np, queries_np,
                       np.asarray(eids))
    return _cache[key]


def built_index(icfg: SeismicConfig = INDEX,
                cfg: SyntheticSparseConfig = SMALL):
    key = ("idx", icfg, cfg)
    if key not in _cache:
        docs, *_ = collection(cfg)
        t0 = time.time()
        idx = build_index(docs, icfg, list_chunk=32)
        jax.block_until_ready(idx.sum_q)
        _cache[key] = (idx, time.time() - t0)
    return _cache[key]


def mean_recall(ids, exact_ids) -> float:
    return float(np.mean([recall_at_k(np.asarray(ids[q]), exact_ids[q])
                          for q in range(ids.shape[0])]))


def timeit_us(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Mean wall-time per call in microseconds (post-warmup, jitted)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def row(name: str, us: float, **derived) -> str:
    d = ";".join(f"{k}={v}" for k, v in derived.items())
    return f"{name},{us:.1f},{d}"
