"""kNN-graph refinement benchmark + acceptance gate (repro.graph).

Measures what the refinement tier buys back: the pipeline is run at a
HALVED ``block_budget`` (half the exact scoring work of the reference
operating point), unrefined vs refined with ``graph_degree=8,
refine_rounds=1``. Reported per run:

  graph_build      offline graph construction (the corpus driven
                   through the batched ``search_pipeline`` in fixed
                   chunks) — wall time, edges, artifact bytes
  refine_unref     recall@10 / docs-evaluated at the halved budget
  refine_on        same + the recall lift and per-stage refine latency
  refine_compact   the same refined point on a ``compact_forward``
                   (u8 forward plane) graph index
  refine_rounds_k  recall as ``refine_rounds`` grows (monotone
                   non-decreasing; the dedicated test enforces it)

Exit gates (CI runs ``--smoke``; the full run gates identically):

  * refined recall@10 >= unrefined + 0.05 at the halved budget
    (``lift_ok``), and
  * ``graph_degree=0`` on the graph-carrying index is bit-exact with
    the five-stage pipeline on the plain index (``bitexact_ok``).

    PYTHONPATH=src python -m benchmarks.graph_refine [--smoke]
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (INDEX, built_index, collection, mean_recall,
                               row, timeit_us)
from repro.core import SeismicConfig, build_index
from repro.core.baselines import exact_search
from repro.data import SyntheticSparseConfig, make_collection
from repro.graph import build_doc_graph
from repro.retrieval import SearchParams, search_pipeline, stage_fns
from repro.sparse.ops import PaddedSparse

DEGREE = 8
ROUNDS = 1
HALVED_BUDGET = 4        # half the block_budget=8 reference point
MIN_LIFT = 0.05          # acceptance: >= 5 recall points recovered

SMOKE = SyntheticSparseConfig(dim=512, n_docs=2048, n_queries=24,
                              doc_nnz=32, query_nnz=12, n_topics=16,
                              topic_coords=96, seed=3)
SMOKE_INDEX = SeismicConfig(lam=96, beta=8, alpha=0.4, block_cap=24,
                            summary_nnz=24)


def _fixture(smoke: bool):
    if smoke:
        docs_np, queries_np, _ = make_collection(SMOKE)
        docs = PaddedSparse(jnp.asarray(docs_np.coords),
                            jnp.asarray(docs_np.vals), docs_np.dim)
        queries = PaddedSparse(jnp.asarray(queries_np.coords),
                               jnp.asarray(queries_np.vals),
                               queries_np.dim)
        idx = build_index(docs, SMOKE_INDEX, list_chunk=16)
        _, eids = exact_search(docs, queries, 10)
        return idx, queries, np.asarray(eids)
    _, queries, _, _, eids = collection()
    idx, _ = built_index()
    return idx, queries, eids


def _recall(idx, queries, eids, p):
    _, ids, ev = search_pipeline(idx, queries, p)
    return mean_recall(np.asarray(ids), eids), int(np.asarray(ev).mean())


def run(smoke: bool = False):
    idx, queries, eids = _fixture(smoke)
    build_p = SearchParams(k=DEGREE + 1, cut=8,
                           block_budget=16 if smoke else 64,
                           policy="budget")

    t0 = time.time()
    gidx = build_doc_graph(idx, degree=DEGREE, build_params=build_p,
                           batch=256)
    jax.block_until_ready(gidx.knn_ids)
    build_s = time.time() - t0
    n = gidx.n_docs
    yield row("graph_build", build_s * 1e6, degree=DEGREE,
              docs=n, launches=-(-n // 256),
              graph_bytes=gidx.nbytes()["graph"])

    p0 = SearchParams(k=10, cut=8, block_budget=HALVED_BUDGET,
                      policy="budget")
    p1 = dataclasses.replace(p0, graph_degree=DEGREE,
                             refine_rounds=ROUNDS)

    r0, ev0 = _recall(idx, queries, eids, p0)
    yield row("refine_unref", 0.0, recall10=f"{r0:.3f}", docs_eval=ev0,
              block_budget=HALVED_BUDGET)

    r1, ev1 = _recall(gidx, queries, eids, p1)
    lift = r1 - r0
    lift_ok = lift >= MIN_LIFT
    # per-stage latency of the refine stage (standalone-jitted hook)
    fns = stage_fns(gidx, p1)
    q_dense, lists, _ = jax.block_until_ready(
        fns["prep"](queries.coords, queries.vals))
    batch = jax.block_until_ready(fns["router"](q_dense, lists))
    sel = jax.block_until_ready(fns["selector"](batch))
    cand, scores = jax.block_until_ready(fns["scorer"](batch, sel))
    merged = jax.block_until_ready(fns["merge"](cand, scores))
    us_refine = timeit_us(fns["refine"], q_dense, *merged)
    yield row("refine_on", us_refine, recall10=f"{r1:.3f}",
              docs_eval=ev1, lift=f"{lift:+.3f}",
              graph_degree=DEGREE, refine_rounds=ROUNDS,
              lift_ok=lift_ok)

    # the same refined point over a compact (u8) forward plane: both
    # scorer and refine rescore through the fused-dequant gather_dot
    cgidx = build_doc_graph(idx, degree=DEGREE, build_params=build_p,
                            batch=256, compact_forward=True)
    rc, evc = _recall(cgidx, queries, eids, p1)
    yield row("refine_compact", 0.0, recall10=f"{rc:.3f}", docs_eval=evc,
              fwd_dtype="u8")

    # recall vs refine_rounds (monotone; tests enforce, we report)
    for rounds in (2, 3):
        pr = dataclasses.replace(p1, refine_rounds=rounds)
        rr, evr = _recall(gidx, queries, eids, pr)
        yield row(f"refine_rounds_{rounds}", 0.0, recall10=f"{rr:.3f}",
                  docs_eval=evr)

    # graph_degree=0 on the graph index must be bit-exact with the
    # five-stage pipeline on the plain index
    s_plain, i_plain, e_plain = search_pipeline(idx, queries, p0)
    s_graph, i_graph, e_graph = search_pipeline(gidx, queries, p0)
    bitexact_ok = (
        np.array_equal(np.asarray(s_plain), np.asarray(s_graph))
        and np.array_equal(np.asarray(i_plain), np.asarray(i_graph))
        and np.array_equal(np.asarray(e_plain), np.asarray(e_graph)))
    yield row("refine_degree0", 0.0, bitexact_ok=bitexact_ok)


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny collection (CI smoke); same exit gates")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    bad = []
    for line in run(smoke=args.smoke):
        print(line)
        if "lift_ok=False" in line or "bitexact_ok=False" in line:
            bad.append(line)
    if bad:
        raise SystemExit(
            "graph-refinement acceptance failed (need >= "
            f"{MIN_LIFT * 100:.0f} recall points recovered at halved "
            "block_budget AND degree-0 bit-exactness):\n"
            + "\n".join(bad))


if __name__ == "__main__":
    main()
