"""Table 2: index size and build time (Seismic vs SparseIvf-style; the
exact/impact baselines reuse Seismic's inverted arrays so their size is
the 'inverted' component)."""
from __future__ import annotations

import time

import numpy as np
import jax

from benchmarks.common import INDEX, built_index, collection, row
from repro.core.baselines import build_ivf


def run() -> list[str]:
    docs, *_ = collection()
    idx, build_s = built_index()
    sizes = idx.nbytes()
    out = [row("table2_seismic_build", build_s * 1e6,
               seconds=round(build_s, 2)),
           row("table2_seismic_size", 0.0,
               total_mib=round(sizes["total"] / 2 ** 20, 1),
               fwd_mib=round(sizes["forward"] / 2 ** 20, 1),
               inv_mib=round(sizes["inverted"] / 2 ** 20, 1),
               summaries_mib=round(sizes["summaries"] / 2 ** 20, 1))]

    t0 = time.time()
    ivf = build_ivf(docs, n_clusters=int(4 * np.sqrt(docs.n)), cap=256)
    jax.block_until_ready(ivf.centroids)
    ivf_s = time.time() - t0
    ivf_bytes = (ivf.centroids.nbytes + ivf.member_docs.nbytes
                 + ivf.member_len.nbytes + ivf.fwd.coords.nbytes
                 + ivf.fwd.vals.nbytes)
    out.append(row("table2_sparseivf_build", ivf_s * 1e6,
                   seconds=round(ivf_s, 2)))
    out.append(row("table2_sparseivf_size", 0.0,
                   total_mib=round(ivf_bytes / 2 ** 20, 1)))
    # quantization saves 4x on summary values (paper §7.3)
    q_mib = idx.sum_q.nbytes / 2 ** 20
    out.append(row("table2_summary_quant_saving", 0.0,
                   u8_mib=round(q_mib, 1),
                   f32_equiv_mib=round(q_mib * 4, 1)))
    return out
