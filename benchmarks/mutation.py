"""Streaming-mutation benchmark: grow an index from empty through the
LSM tail (``repro.core.mutate``), timing insert throughput and
compaction latency, and gating search quality against a fresh
``build_index`` of the identical corpus.

Protocol — one corpus, two arms:

  * **fresh arm** — ``build_index`` over the full collection, the
    quality ceiling the mutation path must track;
  * **mutable arm** — ``MutableSeismicIndex.empty`` sized to the
    corpus, grown chunk-by-chunk (chunk = ``tail_max``) with an
    explicit timed ``compact()`` between chunks. Recall is measured
    twice: *during* mutation (last chunk still live in the unblocked
    tail — the state a server actually serves between compactions) and
    *after* the final compaction (everything re-blocked).

Gates (CI runs ``--smoke``):

  * ``gate_recall_during`` / ``gate_recall_after`` — recall@10 of each
    mutable-arm state must be >= ``RECALL_RATIO_GATE`` of the fresh
    arm under the same adaptive budget. Tail docs are scored exactly,
    so *during* usually matches or beats fresh; *after* exercises the
    minor/major compaction summaries.
  * ``gate_deleted_absent`` — after tombstoning a random 5% of docs,
    no deleted id appears in any result, both before (mask-only) and
    after (physical purge) the following compaction.

Latency rows (insert docs/sec, compaction ms, full-rebuild ms for
scale) are informational — single-thread CPU wall time, environment-
sensitive, so the regression sentinel only warns on them.

    PYTHONPATH=src python -m benchmarks.mutation [--smoke]
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import mean_recall, row
from repro.core import SeismicConfig, build_index
from repro.core.baselines import exact_search
from repro.core.mutate import MutableSeismicIndex
from repro.data import SyntheticSparseConfig, make_collection
from repro.retrieval import SearchParams, search_pipeline
from repro.sparse.ops import PaddedSparse

RECALL_RATIO_GATE = 0.98

FULL = SyntheticSparseConfig(dim=512, n_docs=3072, n_queries=64,
                             doc_nnz=48, query_nnz=24, n_topics=32,
                             topic_coords=128, seed=17)
SMOKE = SyntheticSparseConfig(dim=256, n_docs=768, n_queries=32,
                              doc_nnz=32, query_nnz=16, n_topics=16,
                              topic_coords=64, seed=17)
INDEX_FULL = SeismicConfig(lam=96, beta=8, alpha=0.4, block_cap=16,
                           summary_nnz=32)
INDEX_SMOKE = SeismicConfig(lam=64, beta=8, alpha=0.4, block_cap=16,
                            summary_nnz=32)


def _search_us(idx, queries, p):
    """(ids, us-per-query) for one jitted batch search (post-warmup)."""
    fn = jax.jit(lambda c, v: search_pipeline(
        idx, PaddedSparse(c, v, idx.dim), p))
    out = jax.block_until_ready(fn(queries.coords, queries.vals))
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(queries.coords, queries.vals))
    us = (time.perf_counter() - t0) / queries.coords.shape[0] * 1e6
    return np.asarray(out[1]), us


def run(smoke: bool = False):
    dcfg = SMOKE if smoke else FULL
    icfg = INDEX_SMOKE if smoke else INDEX_FULL
    chunk = dcfg.n_docs // 4 if smoke else dcfg.n_docs // 8
    docs_np, queries_np, _ = make_collection(dcfg)
    docs = PaddedSparse(jnp.asarray(docs_np.coords),
                        jnp.asarray(docs_np.vals), docs_np.dim)
    queries = PaddedSparse(jnp.asarray(queries_np.coords),
                           jnp.asarray(queries_np.vals), queries_np.dim)
    _, exact_ids = exact_search(docs, queries, 10)
    exact_ids = np.asarray(exact_ids)
    # budget chosen to keep fresh recall off the 1.0 ceiling so the
    # ratio gates compare real pruning quality, not saturation
    p = SearchParams(k=10, cut=6, block_budget=8, policy="adaptive")

    # ---- fresh arm: the one-shot build the mutable arm must track
    t0 = time.perf_counter()
    fresh = build_index(docs, icfg, list_chunk=16)
    jax.block_until_ready(fresh.sum_q)
    rebuild_ms = (time.perf_counter() - t0) * 1e3
    ids, _ = _search_us(fresh, queries, p)
    r_fresh = mean_recall(ids, exact_ids)

    # ---- mutable arm: empty -> full corpus, chunk inserts + timed
    # compactions; the last chunk stays in the tail for the "during"
    # measurement before the final compaction closes the loop
    mut = MutableSeismicIndex.empty(
        dcfg.dim, docs_np.coords.shape[1], icfg,
        capacity=dcfg.n_docs, tail_cap=chunk, tail_max=chunk)
    coords = np.asarray(docs_np.coords)
    vals = np.asarray(docs_np.vals)
    insert_s = 0.0
    compact_s: list[float] = []
    for s in range(0, dcfg.n_docs, chunk):
        if mut.tail_occupancy:                 # all but the first chunk
            t0 = time.perf_counter()
            mut.compact()
            compact_s.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        mut.insert_docs(coords[s:s + chunk], vals[s:s + chunk])
        insert_s += time.perf_counter() - t0

    ids, us_during = _search_us(mut.index, queries, p)
    r_during = mean_recall(ids, exact_ids)
    t0 = time.perf_counter()
    mut.compact()
    compact_s.append(time.perf_counter() - t0)
    ids, us_after = _search_us(mut.index, queries, p)
    r_after = mean_recall(ids, exact_ids)

    ins_us_doc = insert_s / dcfg.n_docs * 1e6
    yield row("mutation_insert", ins_us_doc,
              docs_per_s=f"{dcfg.n_docs / insert_s:.3g}",
              n_docs=dcfg.n_docs, chunk=chunk,
              rebuild_ms=f"{rebuild_ms:.0f}")
    yield row("mutation_compact", float(np.median(compact_s)) * 1e6,
              compactions=len(compact_s),
              median_ms=f"{np.median(compact_s) * 1e3:.0f}",
              max_ms=f"{max(compact_s) * 1e3:.0f}")
    yield row("mutation_recall", us_after,
              recall_fresh=f"{r_fresh:.3f}",
              recall_during=f"{r_during:.3f}",
              recall_after=f"{r_after:.3f}",
              us_during=f"{us_during:.0f}",
              gate_recall_during=r_during >= RECALL_RATIO_GATE * r_fresh,
              gate_recall_after=r_after >= RECALL_RATIO_GATE * r_fresh)

    # ---- delete sweep: tombstone 5%, gate absence before (mask) and
    # after (purge) compaction
    rng = np.random.default_rng(3)
    doomed = rng.choice(dcfg.n_docs, size=max(1, dcfg.n_docs // 20),
                        replace=False)
    mut.delete_docs(doomed)
    doomed_set = set(int(i) for i in doomed)
    ids_mask, _ = _search_us(mut.index, queries, p)
    absent_mask = not (doomed_set & set(ids_mask.ravel().tolist()))
    mut.compact()
    ids_purge, us_del = _search_us(mut.index, queries, p)
    absent_purge = not (doomed_set & set(ids_purge.ravel().tolist()))
    yield row("mutation_delete", us_del,
              deleted=len(doomed), n_live=mut.n_live,
              gate_deleted_absent=absent_mask and absent_purge)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="quarter-size corpus (CI smoke)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = False
    for line in run(smoke=args.smoke):
        print(line)
        if "gate_" in line and "=False" in line:
            failed = True
    if failed:
        raise SystemExit("mutation gate FAILED")
