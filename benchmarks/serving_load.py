"""Serving load generator: async micro-batcher vs the synchronous
per-request facade, under online (one-query-at-a-time) traffic.

Two load shapes per selector policy, same ``SearchParams`` (so recall
is equal by construction — both run the identical jitted pipeline):

  closed-loop sync    each arriving query is served immediately by
                      ``SeismicServer.search`` — one fixed
                      ``[max_batch, nnz]`` launch per query, occupancy
                      1/max_batch (the padding waste this subsystem
                      exists to remove)
  open-loop async     Poisson arrivals at an offered rate above the
                      sync capacity, submitted to
                      ``AsyncSeismicServer``; the micro-batcher
                      coalesces the backlog into high-occupancy
                      launches

Reported per policy: QPS, recall@10, and for the async server p50 /
p95 / p99 request latency plus mean batch occupancy (from telemetry).

A third section exercises ``ReplicaSeismicServer`` (mirror topology)
with an injected per-replica device delay so batch cost is known and
identical everywhere:

  replica scaling     closed batch of requests, makespan QPS at 1 vs
                      4 replicas; ``gate_replica_scaling`` requires
                      >= 2.5x (near-linear minus dispatch overhead)
  slow replica        4 replicas, one 5x slower; the stage-timing
                      balancer steers load away, and
                      ``gate_replica_degradation`` requires p99 with
                      the slow replica <= 3x the all-healthy p99

A fourth section (``run_audit``) gates the quality-observability
plane: a ``ShadowAuditor`` at cadence 1 audits every served request,
and three gates check that its windowed live recall agrees with
offline-measured recall within the Wilson interval
(``gate_audit_wilson``), that the loss funnel attributes 100% of
oracle misses to exactly one stage (``gate_funnel_complete``), and
that a deliberately mistuned policy (block budget forced below the
tuned point) drives the SLO state machine to breach
(``gate_slo_breach``). The auditor snapshots land in
``obs_quality.json`` when an artifacts dir is given.

    PYTHONPATH=src python -m benchmarks.serving_load [--smoke]
                                                     [--replica]
                                                     [--audit]

``--smoke`` (also used by CI and ``make bench-serving``) shrinks the
collection and runs one policy so the whole module finishes in a few
seconds; ``--replica`` runs only the replica section (see
``make bench-replica``); ``--audit`` only the quality-plane section
(``make bench-audit``).
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import built_index, collection, mean_recall, row
from repro.core import SeismicConfig, build_index
from repro.core.baselines import exact_search
from repro.data import SyntheticSparseConfig, make_collection
from repro.retrieval import SearchParams
from repro.serve import (AsyncSeismicServer, ReplicaSeismicServer,
                         SeismicServer)
from repro.sparse.ops import PaddedSparse

POLICIES = ("budget", "adaptive", "global_threshold")

SMOKE = SyntheticSparseConfig(dim=512, n_docs=2048, n_queries=24,
                              doc_nnz=32, query_nnz=12, n_topics=16,
                              topic_coords=96, seed=3)
SMOKE_INDEX = SeismicConfig(lam=96, beta=8, alpha=0.4, block_cap=24,
                            summary_nnz=24)


def _smoke_fixture():
    docs_np, queries_np, _ = make_collection(SMOKE)
    docs = PaddedSparse(jnp.asarray(docs_np.coords),
                        jnp.asarray(docs_np.vals), docs_np.dim)
    queries = PaddedSparse(jnp.asarray(queries_np.coords),
                           jnp.asarray(queries_np.vals), queries_np.dim)
    idx = build_index(docs, SMOKE_INDEX, list_chunk=16)
    _, eids = exact_search(docs, queries, 10)
    return idx, queries, np.asarray(eids)


def _sync_per_request(idx, queries, eids, p, max_batch, n_req):
    """Closed-loop: one padded fixed-batch launch per arriving query."""
    server = SeismicServer(idx, p, max_batch=max_batch)
    qn = queries.n
    one = queries[0:1]
    server.search(one)                       # compile the launch shape
    ids = np.empty((n_req, p.k), np.int32)
    t0 = time.perf_counter()
    for i in range(n_req):
        ids[i] = server.search(queries[i % qn:i % qn + 1]).ids[0]
    dt = time.perf_counter() - t0
    recall = mean_recall(ids, eids[np.arange(n_req) % qn])
    return n_req / dt, recall


def _async_open_loop(idx, queries, eids, p, max_batch, n_req, rate,
                     deadline_s):
    """Open-loop: Poisson arrivals at ``rate`` qps, micro-batched."""
    server = AsyncSeismicServer(idx, p, max_batch=max_batch,
                                query_nnz=queries.nnz_max,
                                deadline_s=deadline_s,
                                queue_bound=max(n_req, 64),
                                admission="reject")
    qn = queries.n
    coords = np.asarray(queries.coords)
    vals = np.asarray(queries.vals)
    arrivals = np.cumsum(
        np.random.default_rng(0).exponential(1.0 / rate, n_req))
    with server:
        futs = []
        t0 = time.perf_counter()
        for i in range(n_req):
            lag = arrivals[i] - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)
            futs.append(server.submit(coords[i % qn], vals[i % qn]))
        for f in futs:
            f.wait()
        dt = time.perf_counter() - t0
    ids = np.stack([f.result().ids for f in futs])
    recall = mean_recall(ids, eids[np.arange(n_req) % qn])
    tel = server.telemetry_export()
    lat = tel["latency_s"]["request_e2e"]
    return n_req / dt, recall, lat, tel["batch"]["mean_occupancy"]


def _replica_server(idx, queries, p, max_batch, n_req, *, n_replicas,
                    delays, deadline_s):
    """Mirror-topology replica server with deterministic injected
    per-replica device cost; caching/coalescing off so every request
    is real work."""
    return ReplicaSeismicServer(
        idx, p, n_replicas=n_replicas, mode="mirror",
        replica_delay_s=delays, max_batch=max_batch,
        query_nnz=queries.nnz_max, deadline_s=deadline_s,
        queue_bound=max(2 * n_req, 64), cache_size=0, coalesce=False,
        admission="reject")


def _replica_closed_batch(idx, queries, eids, p, max_batch, n_req,
                          n_replicas, delay):
    """Makespan of a closed batch of ``n_req`` requests, all queued
    up-front: with the per-batch delay dominating, QPS scales with the
    number of replicas draining the queue."""
    server = _replica_server(idx, queries, p, max_batch, n_req,
                             n_replicas=n_replicas,
                             delays=delay, deadline_s=0.002)
    qn = queries.n
    coords, vals = np.asarray(queries.coords), np.asarray(queries.vals)
    with server:
        t0 = time.perf_counter()
        futs = [server.submit(coords[i % qn], vals[i % qn])
                for i in range(n_req)]
        for f in futs:
            f.wait()
        dt = time.perf_counter() - t0
    ids = np.stack([f.result().ids for f in futs])
    recall = mean_recall(ids, eids[np.arange(n_req) % qn])
    return n_req / dt, recall


def _replica_paced_p99(idx, queries, p, max_batch, n_req, delays):
    """p99 request latency under paced arrivals on 4 replicas. A prime
    burst first: balancer cost records only land when launches finish,
    so the EWMA must be warm before the measured window."""
    n_rep = len(delays)
    server = _replica_server(idx, queries, p, max_batch, n_req,
                             n_replicas=n_rep, delays=delays,
                             deadline_s=0.015)
    qn = queries.n
    coords, vals = np.asarray(queries.coords), np.asarray(queries.vals)
    with server:
        prime = [server.submit(coords[i % qn], vals[i % qn])
                 for i in range(4 * max_batch)]
        for f in prime:
            f.wait()
        futs = []
        for i in range(n_req):
            time.sleep(0.002)
            futs.append(server.submit(coords[i % qn], vals[i % qn]))
        for f in futs:
            f.wait()
    lat = np.sort([f.result().latency_s for f in futs])
    return float(lat[int(round(0.99 * (len(lat) - 1)))])


def run_replica(smoke: bool = False):
    """Replica-scaling + slow-replica-degradation rows (both gated).
    Always on the smoke fixture: these rows measure serving topology,
    not corpus-dependent pipeline cost, and the injected delay keeps
    per-batch work identical across replica counts."""
    idx, queries, eids = _smoke_fixture()
    p = SearchParams(policy="adaptive", k=10, cut=8, block_budget=8)
    max_batch, n_req, delay = 8, 96 if smoke else 192, 0.008

    qps1, _ = _replica_closed_batch(idx, queries, eids, p, max_batch,
                                    n_req, 1, delay)
    qps4, rec = _replica_closed_batch(idx, queries, eids, p, max_batch,
                                      n_req, 4, delay)
    speedup = qps4 / qps1
    yield row("serve_replica_scaling", 1e6 / qps4,
              qps_1=f"{qps1:.3g}", qps_4=f"{qps4:.3g}",
              recall10=f"{rec:.3f}", speedup=f"{speedup:.2f}x",
              gate_replica_scaling=bool(speedup >= 2.5))

    base = 0.006
    p99_ok = _replica_paced_p99(idx, queries, p, max_batch, n_req,
                                [base] * 4)
    p99_slow = _replica_paced_p99(idx, queries, p, max_batch, n_req,
                                  [5 * base] + [base] * 3)
    ratio = p99_slow / p99_ok
    yield row("serve_replica_degradation", p99_slow * 1e6,
              p99_healthy_ms=f"{p99_ok*1e3:.2f}",
              p99_slow_ms=f"{p99_slow*1e3:.2f}",
              ratio=f"{ratio:.2f}x",
              gate_replica_degradation=bool(ratio <= 3.0))


def _serve_audited(idx, queries, params, n_req, *, target, reference):
    """Serve ``n_req`` requests through an AsyncSeismicServer with a
    started ShadowAuditor at cadence 1 (every request audited, every
    launch captured), drain, and return (ids, snapshot, seconds)."""
    from repro.obs import Observability, ShadowAuditor
    obs = Observability.create(stage_sample_every=0)
    auditor = ShadowAuditor(idx, params, obs.registry,
                            audit_sample_every=1,
                            queue_bound=4 * n_req,
                            window=max(2 * n_req, 256),
                            target=target, reference=reference)
    obs.auditor = auditor
    server = AsyncSeismicServer(
        idx, params, max_batch=8, query_nnz=queries.nnz_max,
        deadline_s=1e-3, queue_bound=max(2 * n_req, 64),
        cache_size=0, coalesce=False, obs=obs)
    qn = queries.n
    coords, vals = np.asarray(queries.coords), np.asarray(queries.vals)
    with auditor, server:
        t0 = time.perf_counter()
        futs = [server.submit(coords[i % qn], vals[i % qn])
                for i in range(n_req)]
        ids = np.stack([f.result(60.0).ids for f in futs])
        auditor.drain()
        dt = time.perf_counter() - t0
    return ids, auditor.snapshot(), dt


def run_audit(smoke: bool = False, artifacts_dir=None):
    """Quality-plane acceptance gates on the seeded smoke corpus:

    gate_audit_wilson    the auditor's windowed live recall@10 agrees
                         with offline-measured recall within its
                         Wilson interval
    gate_funnel_complete the loss funnel attributes 100% of oracle
                         misses to exactly one stage
    gate_slo_breach      a deliberately mistuned policy (block budget
                         forced below the tuned point) drives the SLO
                         state machine to ``breach``
    """
    import dataclasses
    import json
    import os

    from repro.obs import sample_stats
    from repro.tune import tune_and_attach

    idx, queries, eids = _smoke_fixture()
    qn = queries.n
    n_req = 2 * qn if smoke else 4 * qn
    grid = [SearchParams(k=10, cut=8, block_budget=b, policy="budget")
            for b in (2, 4, 8, 16)]
    # feasible target: just under what the strongest grid point measures
    strong = SeismicServer(idx, grid[-1], max_batch=qn)
    rec_strong = mean_recall(strong.search(queries).ids, eids)
    target = max(0.5, round(rec_strong - 0.02, 3))
    idx = tune_and_attach(idx, queries, eids, targets=[target], grid=grid)
    pol = idx.tuned[0]
    params = SearchParams.from_tuned(idx, target=target)
    reference = sample_stats(np.asarray(queries.coords),
                             np.asarray(queries.vals), queries.dim)

    # tuned point, audited at cadence 1: live recall + funnel gates.
    # target=None resolves from the attached TunedPolicy (the serving
    # default); the explicit target below tests the mistuned override.
    ids, snap, dt = _serve_audited(idx, queries, params, n_req,
                                   target=None, reference=reference)
    offline = mean_recall(ids, eids[np.arange(n_req) % qn])
    w = snap["window"]
    gate_wilson = bool(w["trials"] > 0
                       and w["wilson_lo"] <= offline <= w["wilson_hi"])
    yield row("serve_audit_live_recall", dt / n_req * 1e6,
              live=f"{w['live_recall']:.4f}",
              offline=f"{offline:.4f}",
              wilson_lo=f"{w['wilson_lo']:.4f}",
              wilson_hi=f"{w['wilson_hi']:.4f}",
              audits=snap["audits"], dropped=snap["dropped"],
              slo_state=snap["slo_state"],
              gate_audit_wilson=gate_wilson)

    loss = snap["loss"]
    attributed = sum(loss.values())
    misses = w["trials"] - w["hits"]
    gate_funnel = bool(attributed == snap["misses"] == misses)
    yield row("serve_audit_funnel", dt / n_req * 1e6,
              router=loss["router"], selector=loss["selector"],
              scorer=loss["scorer"], refine=loss["refine"],
              attributed=attributed, misses=misses,
              gate_funnel_complete=gate_funnel)

    # mistuned point: budget forced below the tuned operating point
    # must drive the SLO machine to breach (explicit target: degraded
    # knobs no longer match the attached TunedPolicy)
    bad_budget = max(1, pol.block_budget // 4)
    bad_params = dataclasses.replace(params, block_budget=bad_budget)
    _, bad_snap, _ = _serve_audited(idx, queries, bad_params, n_req,
                                    target=target, reference=reference)
    bw = bad_snap["window"]
    gate_breach = bool(bad_snap["slo_state"] == "breach")
    yield row("serve_audit_breach", dt / n_req * 1e6,
              tuned_budget=pol.block_budget, forced_budget=bad_budget,
              target=f"{target:.3f}",
              live=f"{bw['live_recall']:.4f}",
              wilson_hi=f"{bw['wilson_hi']:.4f}",
              slo_state=bad_snap["slo_state"],
              gate_slo_breach=gate_breach)

    if artifacts_dir:
        os.makedirs(artifacts_dir, exist_ok=True)
        path = os.path.join(artifacts_dir, "obs_quality.json")
        with open(path, "w", encoding="utf-8") as f:
            json.dump({"tuned": snap, "mistuned": bad_snap,
                       "offline_recall": offline,
                       "target": target}, f, indent=2)


def run(smoke: bool = False, artifacts_dir=None):
    if smoke:
        idx, queries, eids = _smoke_fixture()
        policies, max_batch, n_req = ("adaptive",), 8, 48
        sp = dict(k=10, cut=8, block_budget=8)
    else:
        _, queries, _, _, eids = collection()
        idx, _ = built_index()
        policies, max_batch, n_req = POLICIES, 32, 128
        sp = dict(k=10, cut=8, block_budget=32)

    for policy in policies:
        p = SearchParams(policy=policy, **sp)
        sync_qps, sync_rec = _sync_per_request(
            idx, queries, eids, p, max_batch, n_req)
        yield row(f"serve_sync_{policy}", 1e6 / sync_qps,
                  qps=f"{sync_qps:.3g}", recall10=f"{sync_rec:.3f}",
                  occupancy="1")

        # offer 3x the sync capacity: the backlog is what the
        # micro-batcher coalesces into high-occupancy launches
        rate = 3.0 * sync_qps
        deadline_s = min(0.05, max(0.002, 4.0 / sync_qps))
        qps, rec, lat, occ = _async_open_loop(
            idx, queries, eids, p, max_batch, n_req, rate, deadline_s)
        yield row(f"serve_async_{policy}", 1e6 / qps,
                  qps=f"{qps:.3g}", recall10=f"{rec:.3f}",
                  occupancy=f"{occ:.1f}",
                  p50_ms=f"{lat['p50']*1e3:.2f}",
                  p95_ms=f"{lat['p95']*1e3:.2f}",
                  p99_ms=f"{lat['p99']*1e3:.2f}",
                  speedup=f"{qps / sync_qps:.2f}x")

    yield from run_replica(smoke=smoke)
    yield from run_audit(smoke=smoke, artifacts_dir=artifacts_dir)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny collection, one policy (CI smoke)")
    ap.add_argument("--replica", action="store_true",
                    help="only the replica scaling/degradation rows")
    ap.add_argument("--audit", action="store_true",
                    help="only the quality-plane audit rows (gated)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.replica:
        gen = run_replica(smoke=args.smoke)
    elif args.audit:
        gen = run_audit(smoke=args.smoke)
    else:
        gen = run(smoke=args.smoke)
    failed = []
    for line in gen:
        print(line)
        if "gate_" in line and "=False" in line:
            failed.append(line.split(",", 1)[0])
    if failed:
        raise SystemExit(f"gate failure in rows: {failed}")
