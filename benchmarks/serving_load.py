"""Serving load generator: async micro-batcher vs the synchronous
per-request facade, under online (one-query-at-a-time) traffic.

Two load shapes per selector policy, same ``SearchParams`` (so recall
is equal by construction — both run the identical jitted pipeline):

  closed-loop sync    each arriving query is served immediately by
                      ``SeismicServer.search`` — one fixed
                      ``[max_batch, nnz]`` launch per query, occupancy
                      1/max_batch (the padding waste this subsystem
                      exists to remove)
  open-loop async     Poisson arrivals at an offered rate above the
                      sync capacity, submitted to
                      ``AsyncSeismicServer``; the micro-batcher
                      coalesces the backlog into high-occupancy
                      launches

Reported per policy: QPS, recall@10, and for the async server p50 /
p95 / p99 request latency plus mean batch occupancy (from telemetry).

    PYTHONPATH=src python -m benchmarks.serving_load [--smoke]

``--smoke`` (also used by CI and ``make bench-serving``) shrinks the
collection and runs one policy so the whole module finishes in a few
seconds.
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import built_index, collection, mean_recall, row
from repro.core import SeismicConfig, build_index
from repro.core.baselines import exact_search
from repro.data import SyntheticSparseConfig, make_collection
from repro.retrieval import SearchParams
from repro.serve import AsyncSeismicServer, SeismicServer
from repro.sparse.ops import PaddedSparse

POLICIES = ("budget", "adaptive", "global_threshold")

SMOKE = SyntheticSparseConfig(dim=512, n_docs=2048, n_queries=24,
                              doc_nnz=32, query_nnz=12, n_topics=16,
                              topic_coords=96, seed=3)
SMOKE_INDEX = SeismicConfig(lam=96, beta=8, alpha=0.4, block_cap=24,
                            summary_nnz=24)


def _smoke_fixture():
    docs_np, queries_np, _ = make_collection(SMOKE)
    docs = PaddedSparse(jnp.asarray(docs_np.coords),
                        jnp.asarray(docs_np.vals), docs_np.dim)
    queries = PaddedSparse(jnp.asarray(queries_np.coords),
                           jnp.asarray(queries_np.vals), queries_np.dim)
    idx = build_index(docs, SMOKE_INDEX, list_chunk=16)
    _, eids = exact_search(docs, queries, 10)
    return idx, queries, np.asarray(eids)


def _sync_per_request(idx, queries, eids, p, max_batch, n_req):
    """Closed-loop: one padded fixed-batch launch per arriving query."""
    server = SeismicServer(idx, p, max_batch=max_batch)
    qn = queries.n
    one = queries[0:1]
    server.search(one)                       # compile the launch shape
    ids = np.empty((n_req, p.k), np.int32)
    t0 = time.perf_counter()
    for i in range(n_req):
        ids[i] = server.search(queries[i % qn:i % qn + 1]).ids[0]
    dt = time.perf_counter() - t0
    recall = mean_recall(ids, eids[np.arange(n_req) % qn])
    return n_req / dt, recall


def _async_open_loop(idx, queries, eids, p, max_batch, n_req, rate,
                     deadline_s):
    """Open-loop: Poisson arrivals at ``rate`` qps, micro-batched."""
    server = AsyncSeismicServer(idx, p, max_batch=max_batch,
                                query_nnz=queries.nnz_max,
                                deadline_s=deadline_s,
                                queue_bound=max(n_req, 64),
                                admission="reject")
    qn = queries.n
    coords = np.asarray(queries.coords)
    vals = np.asarray(queries.vals)
    arrivals = np.cumsum(
        np.random.default_rng(0).exponential(1.0 / rate, n_req))
    with server:
        futs = []
        t0 = time.perf_counter()
        for i in range(n_req):
            lag = arrivals[i] - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)
            futs.append(server.submit(coords[i % qn], vals[i % qn]))
        for f in futs:
            f.wait()
        dt = time.perf_counter() - t0
    ids = np.stack([f.result().ids for f in futs])
    recall = mean_recall(ids, eids[np.arange(n_req) % qn])
    tel = server.telemetry_export()
    lat = tel["latency_s"]["request_e2e"]
    return n_req / dt, recall, lat, tel["batch"]["mean_occupancy"]


def run(smoke: bool = False):
    if smoke:
        idx, queries, eids = _smoke_fixture()
        policies, max_batch, n_req = ("adaptive",), 8, 48
        sp = dict(k=10, cut=8, block_budget=8)
    else:
        _, queries, _, _, eids = collection()
        idx, _ = built_index()
        policies, max_batch, n_req = POLICIES, 32, 128
        sp = dict(k=10, cut=8, block_budget=32)

    for policy in policies:
        p = SearchParams(policy=policy, **sp)
        sync_qps, sync_rec = _sync_per_request(
            idx, queries, eids, p, max_batch, n_req)
        yield row(f"serve_sync_{policy}", 1e6 / sync_qps,
                  qps=f"{sync_qps:.3g}", recall10=f"{sync_rec:.3f}",
                  occupancy="1")

        # offer 3x the sync capacity: the backlog is what the
        # micro-batcher coalesces into high-occupancy launches
        rate = 3.0 * sync_qps
        deadline_s = min(0.05, max(0.002, 4.0 / sync_qps))
        qps, rec, lat, occ = _async_open_loop(
            idx, queries, eids, p, max_batch, n_req, rate, deadline_s)
        yield row(f"serve_async_{policy}", 1e6 / qps,
                  qps=f"{qps:.3g}", recall10=f"{rec:.3f}",
                  occupancy=f"{occ:.1f}",
                  p50_ms=f"{lat['p50']*1e3:.2f}",
                  p95_ms=f"{lat['p95']*1e3:.2f}",
                  p99_ms=f"{lat['p99']*1e3:.2f}",
                  speedup=f"{qps / sync_qps:.2f}x")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny collection, one policy (CI smoke)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in run(smoke=args.smoke):
        print(line)
