"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,table1,...]
                                            [--smoke] [--artifacts DIR]

``--artifacts DIR`` persists one ``BENCH_<module>.json`` per module —
the machine-readable benchmark trail (name, git revision, runtime
config, every row, and the verdict of any ``gate_*`` derived value) —
which CI uploads as a build artifact so a regression can be traced to
the exact run that introduced it. ``--smoke`` is forwarded to modules
whose ``run()`` accepts it (the CI-sized path).
"""
from __future__ import annotations

import argparse
import inspect
import json
import platform
import subprocess
import sys
import time
from pathlib import Path

MODULES = ["fig1_concentration", "table1_tradeoff", "table2_space_build",
           "fig5_blocking", "fig6_summaries", "pipeline_throughput",
           "serving_load", "graph_refine", "autotune",
           "kernel_microbench", "obs_overhead", "mutation"]


def parse_row(line: str) -> dict:
    """One ``name,us_per_call,k=v;k=v`` row -> plain dict."""
    name, us, derived = line.split(",", 2)
    d = {}
    for kv in derived.split(";"):
        if "=" in kv:
            k, v = kv.split("=", 1)
            d[k] = v
    return {"name": name, "us_per_call": float(us), "derived": d}


def gate_verdicts(rows: list[dict]) -> dict:
    """Every ``gate_*`` derived value across the module's rows.
    Stringly ``True``/``False`` (the row format) -> real booleans."""
    out = {}
    for r in rows:
        for k, v in r["derived"].items():
            if k.startswith("gate_"):
                out[f"{r['name']}.{k}"] = v == "True"
    return out


def git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True,
            text=True, check=True, timeout=10).stdout.strip()
    except Exception:   # noqa: BLE001 — artifacts must not need git
        return "unknown"


def write_artifact(art_dir: Path, mod_name: str, rows: list[dict],
                   *, smoke: bool, elapsed_s: float,
                   error: str | None = None) -> None:
    import jax
    gates = gate_verdicts(rows)
    art = {
        "name": mod_name,
        "git_rev": git_rev(),
        "unix_time": time.time(),
        "config": {
            "smoke": smoke,
            "python": platform.python_version(),
            "platform": platform.platform(),
            "jax": jax.__version__,
            "jax_backend": jax.default_backend(),
        },
        "elapsed_s": elapsed_s,
        "rows": rows,
        "gates": gates,
        "verdict": ("error" if error is not None
                    else "fail" if gates and not all(gates.values())
                    else "pass"),
        "error": error,
    }
    art_dir.mkdir(parents=True, exist_ok=True)
    path = art_dir / f"BENCH_{mod_name}.json"
    path.write_text(json.dumps(art, indent=1) + "\n")
    print(f"# artifact {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated prefixes (fig1,table1,...)")
    ap.add_argument("--smoke", action="store_true",
                    help="forward smoke=True to modules that take it")
    ap.add_argument("--artifacts", default=None, metavar="DIR",
                    help="persist BENCH_<module>.json artifacts here")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None
    art_dir = Path(args.artifacts) if args.artifacts else None

    print("name,us_per_call,derived")
    failures = 0
    for mod_name in MODULES:
        if only and not any(mod_name.startswith(o) for o in only):
            continue
        t0 = time.time()
        rows: list[dict] = []
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            kwargs = {}
            sig = inspect.signature(mod.run).parameters
            if args.smoke and "smoke" in sig:
                kwargs["smoke"] = True
            if art_dir is not None and "artifacts_dir" in sig:
                kwargs["artifacts_dir"] = art_dir  # side artifacts
                art_dir.mkdir(parents=True, exist_ok=True)
            for line in mod.run(**kwargs):
                print(line)
                rows.append(parse_row(line))
            print(f"# {mod_name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
            if art_dir is not None:
                write_artifact(art_dir, mod_name, rows, smoke=args.smoke,
                               elapsed_s=time.time() - t0)
            if not all(gate_verdicts(rows).values()):
                failures += 1
                print(f"# {mod_name} GATE FAILED", file=sys.stderr)
        except Exception as e:  # keep the harness going
            failures += 1
            print(f"# {mod_name} FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
            if art_dir is not None:
                write_artifact(art_dir, mod_name, rows, smoke=args.smoke,
                               elapsed_s=time.time() - t0,
                               error=f"{type(e).__name__}: {e}")
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
