"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,table1,...]
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = ["fig1_concentration", "table1_tradeoff", "table2_space_build",
           "fig5_blocking", "fig6_summaries", "pipeline_throughput",
           "serving_load", "graph_refine", "autotune",
           "kernel_microbench"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated prefixes (fig1,table1,...)")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for mod_name in MODULES:
        if only and not any(mod_name.startswith(o) for o in only):
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            for line in mod.run():
                print(line)
            print(f"# {mod_name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # keep the harness going
            failures += 1
            print(f"# {mod_name} FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
