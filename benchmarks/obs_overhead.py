"""Observability overhead gate: full instrumentation — tracing, device
accounting, AND shadow-oracle quality auditing at its default cadence —
must stay within <5% p50 request latency and <3% QPS of the
uninstrumented server.

Two measurements, one gate:

**Component measurement (the gate).** Times the instrumentation code
paths themselves, on the real index and launch shapes:

  * per-request span work — ``start_trace`` + ``queue_wait``/``launch``
    span assembly + ``end_trace``, exactly the calls the batcher makes
    per request (all on the request's critical path);
  * the staged-launch delta — ``run_pipeline_staged`` (with span
    collection and ``DeviceAccounting.observe``, the full sampled
    path) minus the fused ``search_pipeline``, amortized by the
    default ``stage_sample_every`` since only every Nth launch pays it;
  * the audit hot-path cost — ``ShadowAuditor.plan`` runs on every
    launch; ``feed`` (row copies + a bounded, non-blocking enqueue)
    plus the forced staged launch only every ``audit_sample_every``-th
    request. The oracle recompute itself runs on the background worker
    thread, off the request path, so it is deliberately not gated.

  p50 overhead  = (span_work + audit_plan) / baseline_p50
  QPS overhead  = (span_work + audit_plan
                   + staged_delta / sample_every
                   + (audit_feed + staged_delta) / audit_every)
                  / baseline_mean

**Interleaved A/B (informational rows).** Closed-loop traffic against
a bare and an instrumented server in alternating segments. On a shared
CI box, per-run thread placement alone moves wall-clock QPS by ±5% —
more than the true overhead — so the A/B rows document the end-to-end
picture while the deterministic component measurement carries the
gate; sub-noise gating on wall clock would only measure the host.

    PYTHONPATH=src python -m benchmarks.obs_overhead [--smoke]

Exits nonzero when a gate fails (CI runs ``--smoke``; ``make
bench-obs`` runs it too).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.core import SeismicConfig, build_index
from repro.data import SyntheticSparseConfig, make_collection
from repro.obs import Observability, ShadowAuditor, Tracer
from repro.obs.device import DeviceAccounting
from repro.obs.registry import MetricsRegistry
from repro.retrieval import SearchParams, search_pipeline
from repro.retrieval.pipeline import run_pipeline_staged, stage_fns
from repro.serve import AsyncSeismicServer
from repro.serve.batcher import attach_stage_spans
from repro.sparse.ops import PaddedSparse

P50_GATE_PCT = 5.0    # p50 request latency overhead must stay below
QPS_GATE_PCT = 3.0    # QPS loss must stay below

# Sized so one request is ms-scale pipeline work — the scale the
# serving path is for. On sub-ms toy requests every comparison
# measures thread-scheduling jitter, not instrumentation.
FIXTURE = SyntheticSparseConfig(dim=1024, n_docs=8192, n_queries=32,
                                doc_nnz=64, query_nnz=24, n_topics=32,
                                topic_coords=128, seed=5)
FIXTURE_INDEX = SeismicConfig(lam=128, beta=8, alpha=0.4, block_cap=32,
                              summary_nnz=32)


def _fixture():
    docs_np, queries_np, _ = make_collection(FIXTURE)
    docs = PaddedSparse(jnp.asarray(docs_np.coords),
                        jnp.asarray(docs_np.vals), docs_np.dim)
    queries = PaddedSparse(jnp.asarray(queries_np.coords),
                           jnp.asarray(queries_np.vals), queries_np.dim)
    return build_index(docs, FIXTURE_INDEX, list_chunk=16), queries


def _span_work_us(iters: int = 2000) -> float:
    """Per-request tracer cost: the exact span calls the batcher makes
    for one served request (submit mint + queue/launch spans + close)."""
    tracer = Tracer(capacity=256)
    t0 = time.perf_counter()
    for _ in range(iters):
        tr = tracer.start_trace("request", 0.0)
        tracer.add_span(tr, "queue_wait", 0.0, 1.0)
        sp = tracer.add_span(tr, "launch", 1.0, 2.0, width=8,
                             occupancy=1, batch_seq=0, staged=False)
        tracer.end_trace(tr, 2.0, status="done", docs_evaluated=0)
        del sp
    return (time.perf_counter() - t0) / iters * 1e6


def _audit_cost_us(idx, p, nnz: int, k: int,
                   iters: int = 2000) -> tuple[float, float, int]:
    """Hot-path cost of the shadow auditor: per-launch ``plan`` and
    per-sampled-request ``feed`` (row copies + ``put_nowait``). Uses an
    unstarted auditor with a queue sized past ``iters`` so the oracle
    worker never runs — only the request-path code is on the clock."""
    aud = ShadowAuditor(idx, p, MetricsRegistry(),
                        queue_bound=iters + 8)
    t0 = time.perf_counter()
    for _ in range(iters):
        aud.plan(8)
    plan_us = (time.perf_counter() - t0) / iters * 1e6
    coords = np.zeros(nnz, np.int32)
    vals = np.zeros(nnz, np.float32)
    ids = np.zeros(k, np.int32)
    t0 = time.perf_counter()
    for _ in range(iters):
        aud.feed(coords, vals, ids, captures=None)
    feed_us = (time.perf_counter() - t0) / iters * 1e6
    return plan_us, feed_us, aud.audit_sample_every


def _launch_us(fn, iters: int = 12) -> float:
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def _staged_delta_us(idx, p, width: int, nnz: int) -> float:
    """Extra wall time of one fully-instrumented staged launch (span
    collection + stage-span assembly + device accounting) over the
    fused launch it replaces."""
    coords = jnp.zeros((width, nnz), jnp.int32)
    vals = jnp.zeros((width, nnz), jnp.float32)
    q = PaddedSparse(coords, vals, idx.dim)
    fns = stage_fns(idx, p)
    device = DeviceAccounting(idx, p, MetricsRegistry())
    tracer = Tracer()

    def staged():
        triples, probed = [], {}
        out = run_pipeline_staged(
            idx, coords, vals, p, fns=fns,
            span_cb=lambda name, a, b: triples.append((name, a, b)),
            split_refine=True, probe=probed.__setitem__)
        tr = tracer.start_trace("launch", 0.0)
        attach_stage_spans(tracer, tr, tr.root, triples)
        tracer.end_trace(tr, 1.0)
        device.observe({n: b - a for n, a, b in triples}, width,
                       cand=probed.get("cand"))
        return out

    def fused():
        return search_pipeline(idx, q, p)

    jax.block_until_ready(staged())
    jax.block_until_ready(fused())
    return max(0.0, _launch_us(staged) - _launch_us(fused))


def _segment(server, coords, vals, n_req: int,
             lat: list, t_total: list) -> None:
    """One closed-loop segment: append per-request latencies and the
    segment's wall time to the arm's running pools."""
    qn = coords.shape[0]
    t0 = time.perf_counter()
    for i in range(n_req):
        t = time.perf_counter()
        server.submit(coords[i % qn], vals[i % qn]).result(timeout=60)
        lat.append(time.perf_counter() - t)
    t_total.append(time.perf_counter() - t0)


def _ab_wallclock(idx, queries, p, n_req: int, segments: int,
                  obs) -> dict:
    """Interleaved closed-loop A/B (informational; see module doc)."""
    coords = np.asarray(queries.coords)
    vals = np.asarray(queries.vals)

    def make(o):
        return AsyncSeismicServer(
            idx, p, max_batch=8, query_nnz=int(coords.shape[1]),
            deadline_s=1e-4, cache_size=0, coalesce=False, obs=o)

    lat = {"off": [], "on": []}
    t_total = {"off": [], "on": []}
    with make(None) as off, make(obs) as on:
        _segment(off, coords, vals, n_req, [], [])     # warm both arms
        _segment(on, coords, vals, n_req, [], [])
        for s in range(segments):
            order = (("off", off), ("on", on)) if s % 2 == 0 \
                else (("on", on), ("off", off))
            for arm, server in order:
                _segment(server, coords, vals, n_req,
                         lat[arm], t_total[arm])
    return {arm: {"qps": segments * n_req / sum(t_total[arm]),
                  "p50": float(np.percentile(lat[arm], 50)),
                  "mean": float(np.mean(lat[arm]))}
            for arm in ("off", "on")}


def _write_trail(obs, artifacts_dir) -> None:
    """Persist the instrumented arm's metric snapshot and Chrome trace
    export next to the BENCH_*.json artifacts — the inputs
    ``python -m repro.obs.report`` renders."""
    import json
    import pathlib

    from repro.obs import write_jsonl_snapshot
    d = pathlib.Path(artifacts_dir)
    write_jsonl_snapshot(obs.registry, str(d / "obs_snapshots.jsonl"),
                         extra={"bench": "obs_overhead"})
    (d / "obs_traces.json").write_text(
        json.dumps(obs.tracer.export_chrome()))


def run(smoke: bool = False, artifacts_dir=None):
    idx, queries = _fixture()
    p = SearchParams(k=10, cut=8, block_budget=16, policy="adaptive")
    n_req, segments = (16, 4) if smoke else (16, 12)

    obs = Observability.create()
    # The instrumented arm carries the full quality plane too: a
    # started shadow auditor at its default cadence rides the A/B.
    obs.auditor = ShadowAuditor(idx, p, obs.registry)
    with obs.auditor:
        ab = _ab_wallclock(idx, queries, p, n_req, segments, obs)
        obs.auditor.drain()
    if artifacts_dir is not None:
        # the instrumented arm's obs trail, for `repro.obs.report`
        _write_trail(obs, artifacts_dir)
    span_us = _span_work_us()
    sample_every = obs.stage_sample_every
    staged_us = _staged_delta_us(idx, p, width=8,
                                 nnz=int(queries.coords.shape[1]))
    plan_us, feed_us, audit_every = _audit_cost_us(
        idx, p, nnz=int(queries.coords.shape[1]), k=p.k)
    base_p50_us = ab["off"]["p50"] * 1e6
    base_mean_us = ab["off"]["mean"] * 1e6
    p50_pct = (span_us + plan_us) / base_p50_us * 100
    qps_pct = (span_us + plan_us + staged_us / sample_every
               + (feed_us + staged_us) / audit_every) \
        / base_mean_us * 100

    for arm in ("off", "on"):
        yield row(f"obs_overhead_{arm}", 1e6 / ab[arm]["qps"],
                  qps=f"{ab[arm]['qps']:.3g}",
                  p50_ms=f"{ab[arm]['p50'] * 1e3:.2f}")
    yield row("obs_overhead_gate", 0.0,
              span_work_us=f"{span_us:.1f}",
              staged_delta_us=f"{staged_us:.0f}",
              sample_every=sample_every,
              audit_plan_us=f"{plan_us:.2f}",
              audit_feed_us=f"{feed_us:.2f}",
              audit_every=audit_every,
              p50_overhead_pct=f"{p50_pct:.2f}",
              qps_loss_pct=f"{qps_pct:.2f}",
              gate_p50=p50_pct < P50_GATE_PCT,
              gate_qps=qps_pct < QPS_GATE_PCT)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer requests / segments (CI smoke)")
    ap.add_argument("--artifacts", default=None, metavar="DIR",
                    help="also write the obs snapshot/trace trail here")
    args = ap.parse_args()
    if args.artifacts:
        import pathlib
        pathlib.Path(args.artifacts).mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    failed = False
    for line in run(smoke=args.smoke, artifacts_dir=args.artifacts):
        print(line)
        if "gate_" in line and "=False" in line:
            failed = True
    if failed:
        raise SystemExit("obs overhead gate FAILED")
