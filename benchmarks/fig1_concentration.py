"""Fig. 1 + Fig. 2: concentration of importance.

Fig. 1 — fraction of L1 mass captured by the top-n entries of query and
document vectors. The paper reports ~0.75 for the top-10 query / top-50
doc entries on SPLADE MS MARCO; the synthetic collection is tuned to
land in that regime.

Fig. 2 — fraction of the full inner product preserved when trimming
queries/documents to their top entries (paper: ~85% with the top 10%).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import collection, row
from repro.sparse.ops import l1_mass_fraction


def run() -> list[str]:
    docs, queries, docs_np, queries_np, eids = collection()
    out = []
    # Fig. 1
    for tag, vals, tops in (("query", queries_np.vals, (2, 5, 10, 16)),
                            ("doc", docs_np.vals, (10, 25, 50, 96))):
        for t in tops:
            frac = float(l1_mass_fraction(np.asarray(vals), t).mean())
            out.append(row(f"fig1_l1mass_{tag}_top{t}", 0.0, frac=round(frac, 4)))
    # Fig. 2: preserved inner product between trimmed q (top-10) and
    # trimmed docs (top fractions) for the true top-10 pairs
    q_dense = np.zeros((queries_np.coords.shape[0], docs.dim))
    rows_ = np.arange(queries_np.coords.shape[0])[:, None]
    np.add.at(q_dense, (rows_, queries_np.coords), queries_np.vals)

    def trim(coords, vals, keep):
        order = np.argsort(-vals, axis=-1)[:, :keep]
        c = np.take_along_axis(coords, order, axis=1)
        v = np.take_along_axis(vals, order, axis=1)
        return c, v

    for qk, dk in ((5, 10), (10, 25), (16, 48)):
        qc, qv = trim(np.asarray(queries_np.coords),
                      np.asarray(queries_np.vals), qk)
        fracs = []
        for qi in range(queries.n):
            qd = np.zeros(docs.dim)
            np.add.at(qd, qc[qi], qv[qi])
            for doc in eids[qi][:5]:
                dc, dv = docs_np.coords[doc], docs_np.vals[doc]
                order = np.argsort(-dv)[:dk]
                full = (q_dense[qi][dc] * dv).sum()
                part = (qd[dc[order]] * dv[order]).sum()
                if full > 0:
                    fracs.append(part / full)
        out.append(row(f"fig2_ip_preserved_q{qk}_d{dk}", 0.0,
                       frac=round(float(np.mean(fracs)), 4)))
    return out
