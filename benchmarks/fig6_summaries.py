"""Fig. 6 + §7.3 ablations: summary construction.

  * importance-based (alpha-mass) vs fixed-top-k summaries: alpha=1.0
    with the same padded size IS the fixed variant (keeps top-S entries
    regardless of mass), so the comparison isolates the alpha cut.
  * alpha sweep: size vs recall (paper: alpha .3/.4/.5 -> 1801/2303/
    2885 MiB trend).
  * quantization: routing-score error of u8 summaries vs float (paper:
    no effectiveness loss, 4x smaller).
  * §6 generalized sketch: centroid summaries vs Eq. 2 max bound.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import (INDEX, built_index, collection, mean_recall,
                               row)
from repro.core import SearchParams, search_batch
from repro.sparse.quant import dequantize_u8


def _frontier(idx, queries, eids, tag, out):
    for b in (4, 8, 16, 32):
        p = SearchParams(k=10, cut=10, block_budget=b, policy="budget")
        _, ids, ev = search_batch(idx, queries, p)
        out.append(row(f"{tag}_b{b}", 0.0,
                       recall=round(mean_recall(ids, eids), 4),
                       docs=int(np.asarray(ev).mean())))


def run() -> list[str]:
    docs, queries, docs_np, queries_np, eids = collection()
    out: list[str] = []

    # importance-based (alpha-mass) vs fixed-length summaries
    alpha_idx, _ = built_index()
    fixed_idx, _ = built_index(dataclasses.replace(INDEX, alpha=1.0))
    _frontier(alpha_idx, queries, eids, "fig6_alpha0.4", out)
    _frontier(fixed_idx, queries, eids, "fig6_fixedtop", out)

    # alpha sweep: summary occupancy (stored entries) vs recall
    for a in (0.3, 0.4, 0.5):
        idx, _ = built_index(dataclasses.replace(INDEX, alpha=a))
        occupancy = int((np.asarray(idx.sum_q) > 0).sum())
        p = SearchParams(k=10, cut=10, block_budget=16, policy="budget")
        _, ids, _ = search_batch(idx, queries, p)
        out.append(row(f"fig6_alpha{a}", 0.0,
                       recall=round(mean_recall(ids, eids), 4),
                       summary_entries=occupancy))

    # quantization ablation: u8 vs exact float routing scores
    idx = alpha_idx
    sv = np.asarray(dequantize_u8(idx.sum_q, idx.sum_scale, idx.sum_zero))
    # reconstruct float summaries from the forward index (oracle)
    rng = np.random.default_rng(0)
    lists = rng.choice(idx.n_lists, 64, replace=False)
    errs = []
    for i in lists:
        q = rng.lognormal(0, 1, idx.dim)
        for j in range(idx.config.n_blocks):
            if idx.block_len[i, j] == 0:
                continue
            coords = np.asarray(idx.sum_coords[i, j])
            # float routing score vs quantized routing score
            float_s = (q[coords] * sv[i, j]).sum()
            errs.append(float_s)
    out.append(row("fig6_quant_u8", 0.0,
                   note="see test_summary_dot(<2pct_ip_err);4x_smaller"))

    # §6 centroid sketch vs Eq.2 max
    cent_idx, _ = built_index(dataclasses.replace(INDEX,
                                                  summary_kind="centroid"))
    _frontier(cent_idx, queries, eids, "fig6_centroid", out)
    return out
