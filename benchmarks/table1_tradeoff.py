"""Table 1: accuracy-latency trade-off, Seismic vs baselines.

Sweeps each method's efficiency knob and reports (recall@10, mean wall
time per query batch, docs evaluated). The paper's hardware-independent
signal — Seismic reaching a given accuracy while evaluating orders of
magnitude fewer documents than exhaustive/impact-ordered methods, and
fewer than cluster-probing IVF — is what this table reproduces; wall
time is CPU-JAX and only meaningful relatively.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (built_index, collection, mean_recall, row,
                               timeit_us)
from repro.core import SearchParams, search_batch
from repro.core.baselines import build_ivf, exact_search, impact_search, ivf_search


def run() -> list[str]:
    docs, queries, docs_np, queries_np, eids = collection()
    idx, _ = built_index()
    out = []
    nq = queries.n

    # exact (PISA's rank-safe role)
    us = timeit_us(lambda: exact_search(docs, queries, 10))
    out.append(row("table1_exact", us / nq, recall=1.0, docs=docs.n))

    # Seismic: budget sweep (one-go routing) + adaptive (heap_factor)
    for policy, budgets in (("budget", (4, 8, 16, 32, 64)),
                            ("adaptive", (16, 32, 64))):
        for b in budgets:
            p = SearchParams(k=10, cut=10, block_budget=b,
                             heap_factor=0.9, policy=policy)
            s, ids, ev = search_batch(idx, queries, p)
            r = mean_recall(ids, eids)
            us = timeit_us(lambda p=p: search_batch(idx, queries, p)[0])
            out.append(row(f"table1_seismic_{policy}_b{b}", us / nq,
                           recall=round(r, 4),
                           docs=int(np.asarray(ev).mean())))

    # SparseIvf-style
    ivf = build_ivf(docs, n_clusters=int(4 * np.sqrt(docs.n)), cap=256)
    for nprobe in (2, 4, 8, 16, 32):
        s, ids, ev = ivf_search(ivf, queries, 10, nprobe=nprobe)
        r = mean_recall(ids, eids)
        us = timeit_us(lambda n=nprobe: ivf_search(ivf, queries, 10, n)[0])
        out.append(row(f"table1_sparseivf_np{nprobe}", us / nq,
                       recall=round(r, 4), docs=int(np.asarray(ev).mean())))

    # IP-NSW graph walk (GrassRMA / PyANN role) — numpy host oracle,
    # compared on the docs-evaluated axis (the paper's own §7.2.1 proxy)
    from repro.core.graph_baseline import IPNSWIndex
    from repro.core.oracle import recall_at_k as _r
    gidx = IPNSWIndex(np.asarray(docs_np.coords), np.asarray(docs_np.vals),
                      docs.dim, m=16)
    for ef in (10, 16, 32, 64):
        recs, evs = [], []
        for qi in range(min(nq, 32)):
            _, ids, ev = gidx.search(queries_np.coords[qi],
                                     queries_np.vals[qi], 10, ef)
            recs.append(_r(ids, eids[qi]))
            evs.append(ev)
        out.append(row(f"table1_ipnsw_ef{ef}", 0.0,
                       recall=round(float(np.mean(recs)), 4),
                       docs=int(np.mean(evs))))

    # IOQP-style impact-ordered
    for b in (16, 48, 96, 192):
        s, ids = impact_search(idx.list_docs, idx.list_vals, idx.list_len,
                               docs.n, queries, 10, postings_per_list=b)
        r = mean_recall(ids, eids)
        us = timeit_us(lambda b=b: impact_search(
            idx.list_docs, idx.list_vals, idx.list_len, docs.n, queries,
            10, b)[0])
        out.append(row(f"table1_impact_b{b}", us / nq, recall=round(r, 4),
                       postings_per_list=b))
    return out
