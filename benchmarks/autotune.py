"""Recall-target autotuner benchmark + acceptance gate (repro.tune).

Sweeps the coupled knob grid (block budget x selector factors x
superblock budget x refine rounds) over a held-out query sample,
reports the recall/cost Pareto frontier, tunes operating points for
recall targets {0.90, 0.95}, and compares the tuned point against the
repo's HAND-WRITTEN operating points (the ``SearchParams`` defaults,
the msmarco ``SHAPES`` cell, and the hierarchical hand point — the
knob sets ``CONFIG_HIER``/``REDUCED_HIER`` pair with by hand). Rows:

  tune_sweep        grid size + sweep wall time
  tune_frontier_*   the Pareto frontier (recall, docs, router dots)
  tune_hand_*       each hand-written operating point, same cost model
  tune_point        the tuned point: knobs, measured recall/cost,
                    per-stage seconds (run_pipeline_staged), and gates
  tune_backcompat   pre-tune checkpoint loads + searches bit-exact

Exit gates (CI runs ``--smoke``; the full run gates identically):

  * ``meets_target``: tuned recall@10 >= 0.90 on the held-out sample;
  * ``cheaper_ok``: strictly fewer docs_evaluated than EVERY
    hand-written operating point that reaches equal-or-better recall
    than the target (the tuner must dominate hand tuning, not tie it);
  * ``backcompat_ok``: an index saved WITHOUT a TunedPolicy loads and
    searches bit-exact, and the tuned index's persisted policy
    round-trips to bit-identical params and results.

    PYTHONPATH=src python -m benchmarks.autotune [--smoke]
"""
from __future__ import annotations

import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import built_index, collection, row
from repro.ckpt import load_index, save_index
from repro.core import SeismicConfig, build_index, live_blocks, suggest_fanout
from repro.core.baselines import exact_search
from repro.data import SyntheticSparseConfig, make_collection
from repro.graph import build_doc_graph
from repro.retrieval import SearchParams, search_pipeline
from repro.tune import (attach_tuned, default_grid, measure_point,
                        pareto_frontier, sweep, tune)

TARGET = 0.90
TARGETS = (0.90, 0.95)
DEGREE = 8

SMOKE = SyntheticSparseConfig(dim=512, n_docs=2048, n_queries=24,
                              doc_nnz=32, query_nnz=12, n_topics=16,
                              topic_coords=96, seed=3)
SMOKE_INDEX = SeismicConfig(lam=96, beta=8, alpha=0.4, block_cap=24,
                            summary_nnz=24)


def _fixture(smoke: bool):
    """A built index carrying a kNN graph + superblock tier (the tuner
    co-tunes across all of them), held-out queries, exact top-10."""
    if smoke:
        docs_np, queries_np, _ = make_collection(SMOKE)
        from repro.sparse.ops import PaddedSparse
        docs = PaddedSparse(jnp.asarray(docs_np.coords),
                            jnp.asarray(docs_np.vals), docs_np.dim)
        queries = PaddedSparse(jnp.asarray(queries_np.coords),
                               jnp.asarray(queries_np.vals),
                               queries_np.dim)
        base_cfg = SMOKE_INDEX
        idx = build_index(docs, base_cfg, list_chunk=16)
        _, eids = exact_search(docs, queries, 10)
        eids = np.asarray(eids)
    else:
        docs, queries, _, _, eids = collection()   # exact ids cached
        idx, _ = built_index()
        base_cfg = idx.config
    # rebuild with the adaptive superblock tier so hierarchical grid
    # points are explorable (fanout 0 when lists are too short)
    fanout = suggest_fanout(live_blocks(idx))
    if fanout:
        import dataclasses
        idx = build_index(docs, dataclasses.replace(
            base_cfg, superblock_fanout=fanout), list_chunk=16)
    idx = build_doc_graph(idx, degree=DEGREE, batch=256,
                          build_params=SearchParams(
                              k=DEGREE + 1, cut=8,
                              block_budget=16 if smoke else 64,
                              policy="budget"))
    return idx, queries, eids


def _hand_points(idx):
    """The repo's hand-written operating points (what ``CONFIG_HIER`` /
    ``REDUCED_HIER`` pair with before tuning)."""
    hands = {
        # SearchParams defaults — the untuned "just search" point
        "default": SearchParams(k=10, cut=8, block_budget=32,
                                policy="adaptive"),
        # configs/seismic_msmarco SHAPES query cells
        "shapes": SearchParams(k=10, cut=10, block_budget=64,
                               policy="budget"),
    }
    if idx.sup_coords is not None:
        f = idx.config.superblock_fanout
        hands["hier"] = SearchParams(k=10, cut=8, block_budget=32,
                                     policy="budget",
                                     superblock_fanout=f,
                                     superblock_budget=16)
    return hands


def run(smoke: bool = False):
    idx, queries, eids = _fixture(smoke)
    grid = default_grid(idx, k=10, cut=8)

    t0 = time.time()
    # timings=True: every point rides its per-stage advisory seconds
    # (run_pipeline_staged); selection still orders on the
    # deterministic cost_key only
    points = sweep(idx, queries, eids, k=10, grid=grid, timings=True)
    sweep_s = time.time() - t0
    yield row("tune_sweep", sweep_s * 1e6 / max(len(points), 1),
              grid_points=len(points), queries=queries.n,
              wall_s=f"{sweep_s:.1f}")

    for i, pt in enumerate(pareto_frontier(points)):
        p = pt.params
        adv = pt.advisory_seconds
        yield row(f"tune_frontier_{i}", 0.0, recall10=f"{pt.recall:.3f}",
                  docs_eval=f"{pt.docs_evaluated:.0f}",
                  router_dots=pt.router_cost, policy=p.policy,
                  block_budget=p.block_budget,
                  superblock_budget=(p.superblock_budget
                                     if p.superblock_fanout else 0),
                  refine_rounds=p.refine_rounds,
                  advisory_ms=("" if adv is None else f"{adv*1e3:.1f}"))

    hands = {name: measure_point(idx, queries, eids, p)
             for name, p in _hand_points(idx).items()}
    for name, pt in hands.items():
        yield row(f"tune_hand_{name}", 0.0, recall10=f"{pt.recall:.3f}",
                  docs_eval=f"{pt.docs_evaluated:.0f}",
                  router_dots=pt.router_cost,
                  block_budget=pt.params.block_budget,
                  policy=pt.params.policy)

    pols = [tune(idx, queries, eids, t, points=points) for t in TARGETS]
    tuned = pols[0]
    # re-measure the chosen point through the staged pipeline so the
    # advisory per-stage seconds ride the report
    staged = measure_point(idx, queries, eids, tuned.to_params(),
                           timings=True)
    meets_target = tuned.measured_recall >= TARGET
    # hand points below the target are dominated outright (the tuned
    # point reaches strictly better recall); the strict docs_evaluated
    # comparison applies to the rivals that reach it. With zero rivals
    # the gate is vacuously true — hand_rivals in the row makes that
    # case visible rather than a false CI failure.
    rivals = {n: pt for n, pt in hands.items() if pt.recall >= TARGET}
    cheaper_ok = all(tuned.measured_cost < pt.docs_evaluated
                     for pt in rivals.values())
    stage_s = ";".join(f"{n}={s*1e3:.1f}ms" for n, s in staged.stage_seconds)
    yield row("tune_point", 0.0, target=TARGET,
              recall10=f"{tuned.measured_recall:.3f}",
              docs_eval=f"{tuned.measured_cost:.0f}",
              router_dots=tuned.router_cost, policy=tuned.policy,
              block_budget=tuned.block_budget,
              refine_rounds=tuned.refine_rounds,
              fingerprint=tuned.sample_fingerprint,
              stages=stage_s, meets_target=meets_target,
              hand_rivals=len(rivals), cheaper_ok=cheaper_ok)

    # ---- back-compat: untuned ckpt bit-exact; tuned ckpt round-trips
    p_ref = SearchParams(k=10, cut=8, block_budget=16, policy="budget")
    s0, i0, e0 = search_pipeline(idx, queries, p_ref)
    tidx = attach_tuned(idx, pols)
    with tempfile.TemporaryDirectory() as d:
        save_index(d, idx)                      # no TunedPolicy attached
        plain = load_index(d)
        ok_plain = plain.tuned == ()
        s1, i1, e1 = search_pipeline(plain, queries, p_ref)
        ok_plain &= (np.array_equal(np.asarray(s0), np.asarray(s1))
                     and np.array_equal(np.asarray(i0), np.asarray(i1))
                     and np.array_equal(np.asarray(e0), np.asarray(e1)))
    with tempfile.TemporaryDirectory() as d:
        save_index(d, tidx)
        loaded = load_index(d)
        pt0 = SearchParams.from_tuned(tidx, TARGET)
        pt1 = SearchParams.from_tuned(loaded, TARGET)
        ok_tuned = (loaded.tuned == tidx.tuned) and (pt0 == pt1)
        st0, it0, _ = search_pipeline(tidx, queries, pt0)
        st1, it1, _ = search_pipeline(loaded, queries, pt1)
        ok_tuned &= (np.array_equal(np.asarray(st0), np.asarray(st1))
                     and np.array_equal(np.asarray(it0), np.asarray(it1)))
    yield row("tune_backcompat", 0.0,
              backcompat_ok=bool(ok_plain and ok_tuned),
              untuned_bitexact=bool(ok_plain),
              tuned_roundtrip=bool(ok_tuned))


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny collection (CI smoke); same exit gates")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    bad = []
    for line in run(smoke=args.smoke):
        print(line)
        if ("meets_target=False" in line or "cheaper_ok=False" in line
                or "backcompat_ok=False" in line):
            bad.append(line)
    if bad:
        raise SystemExit(
            "autotune acceptance failed (tuned point must meet recall "
            f"target {TARGET} with strictly fewer docs_evaluated than "
            "every hand config at equal-or-better recall, and pre-tune "
            "checkpoints must stay bit-exact):\n" + "\n".join(bad))


if __name__ == "__main__":
    main()
