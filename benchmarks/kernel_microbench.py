"""Kernel microbenchmark: tiling sweep + fusion parity/efficiency gates.

Three sections, all CSV rows like every other benchmark module:

  kb_tile_*       (tile_q, tile_n) sweep of the gather_dot and
                  summary_dot launches around the VMEM chooser's pick:
                  wall us/call next to the MODELED HBM bytes-moved
                  (repro.kernels.tiling.bytes_moved) — the bandwidth
                  story wall time can't tell on the CPU interpret path.
                  Every tiling must score bit-identically (tile-
                  invariance is part of the parity gate).
  kb_fuse_*       fused router (flat + hierarchical) and fused refine
                  vs their unfused fuse_level=0 stages on a built
                  index, plus an end-to-end fuse_level 0/1/2 pipeline
                  sweep: bit-exact or the gate trips. The work-model
                  rows report the per-query bytes each fusion deletes
                  (repro.retrieval.workmodel).
  kb_compact_*    the candidate-compaction fast path on a HIGH-DEDUPE
                  fixture: after ``compact_candidates`` the candidate-
                  driven kernel must skip enough all-sentinel tiles
                  that the scored-slot reduction matches the dead-slot
                  rate up to one tile_n of rounding —
                  ``reduction + tile_n/C >= dead_rate`` (the host-side
                  ``cand_tiles_processed`` mirror of the kernel's
                  pl.when predicate is the accounting).

Exit gates (CI runs ``--smoke``; the full run gates identically): any
``*_ok=False`` row fails the process — fused paths losing parity or
compaction failing to shrink the scored candidate axis is a build
breaker, not a soft regression.

    PYTHONPATH=src python -m benchmarks.kernel_microbench [--smoke]
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import mean_recall, row, timeit_us
from repro.core import SeismicConfig, build_index
from repro.core.baselines import exact_search
from repro.data import SyntheticSparseConfig, make_collection
from repro.graph import build_doc_graph
from repro.kernels.gather_dot.ops import (cand_tiles_processed,
                                          gather_dot_batch,
                                          gather_dot_cand_batch)
from repro.kernels.gather_dot.ref import gather_dot_batch_ref
from repro.kernels.summary_dot.ops import summary_dot_batch
from repro.kernels.tiling import (bytes_moved, choose_tiles,
                                  gather_row_bytes, summary_row_bytes)
from repro.retrieval import SearchParams, search_pipeline
from repro.retrieval.scorer import (compact_candidates, dedupe_batch,
                                    score_candidates)
from repro.retrieval.workmodel import refine_bytes, router_bytes, scorer_bytes
from repro.sparse.ops import PaddedSparse

FULL = SyntheticSparseConfig(dim=1024, n_docs=4096, n_queries=32,
                             doc_nnz=48, query_nnz=16, n_topics=32,
                             topic_coords=128, seed=13)
SMOKE = SyntheticSparseConfig(dim=512, n_docs=1024, n_queries=16,
                              doc_nnz=32, query_nnz=12, n_topics=16,
                              topic_coords=96, seed=13)
DEGREE = 4


def _fixture(smoke: bool):
    cfg = SMOKE if smoke else FULL
    docs_np, queries_np, _ = make_collection(cfg)
    docs = PaddedSparse(jnp.asarray(docs_np.coords),
                        jnp.asarray(docs_np.vals), docs_np.dim)
    queries = PaddedSparse(jnp.asarray(queries_np.coords),
                           jnp.asarray(queries_np.vals), queries_np.dim)
    icfg = SeismicConfig(lam=96, beta=8, alpha=0.4, block_cap=24,
                         summary_nnz=24, superblock_fanout=4)
    idx = build_doc_graph(build_index(docs, icfg, list_chunk=16),
                          degree=DEGREE)
    _, eids = exact_search(docs, queries, 10)
    return idx, queries, np.asarray(eids)


# ------------------------------------------------------- tiling sweep


def _tile_sweep_rows(idx, queries, smoke):
    """gather_dot over a [Q, C, nnz] candidate fixture and summary_dot
    over probed summaries, at several tilings around the chooser pick.
    All tilings must agree bit-for-bit with the reference oracle."""
    rng = np.random.default_rng(0)
    qn = int(queries.coords.shape[0])
    d = idx.dim
    n = 256 if smoke else 512
    nnz = int(idx.fwd.coords.shape[1])
    q_dense = jnp.zeros((qn, d), jnp.float32).at[
        jnp.arange(qn)[:, None], queries.coords].add(queries.vals)
    cand = jnp.asarray(rng.integers(0, idx.n_docs, (qn, n)), jnp.int32)
    coords = jnp.take(idx.fwd.coords, cand, axis=0).astype(jnp.int32)
    vals = jnp.take(idx.fwd.vals, cand, axis=0).astype(jnp.float32)

    pick = choose_tiles(qn, n, row_bytes=gather_row_bytes(nnz, quant=False),
                        q_row_bytes=4 * d)
    tilings = sorted({(8, 128), (8, min(pick.tile_n, 256)),
                      (pick.tile_q, pick.tile_n)})
    # oracle agreement is allclose (XLA may reassociate the nnz sum
    # differently outside the kernel); TILE-invariance is bitwise — the
    # per-element sum never depends on the grid carve-up
    ref = np.asarray(gather_dot_batch_ref(q_dense, coords, vals))
    first = None
    ok = True
    for tq, tn in tilings:
        us = timeit_us(lambda tq=tq, tn=tn: gather_dot_batch(
            q_dense, coords, vals, tile_q=tq, tile_n=tn))
        got = np.asarray(gather_dot_batch(q_dense, coords, vals,
                                          tile_q=tq, tile_n=tn))
        first = got if first is None else first
        same = (np.allclose(got, ref, rtol=1e-5, atol=1e-6)
                and np.array_equal(got, first))
        ok &= same
        tag = "pick" if (tq, tn) == (pick.tile_q, pick.tile_n) else "alt"
        yield row(f"kb_tile_gather_{tq}x{tn}", us,
                  kind=tag, parity=same,
                  model_bytes=bytes_moved(
                      qn, n, tq, tn,
                      row_bytes=gather_row_bytes(nnz, quant=False),
                      q_row_bytes=4 * d))

    # summary_dot over the flat probed-summary axis
    cut = 4
    lists = jnp.asarray(rng.integers(0, idx.sum_coords.shape[0],
                                     (qn, cut)), jnp.int32)
    nb, s = idx.sum_coords.shape[1], idx.sum_coords.shape[2]
    sc = idx.sum_coords[lists].reshape(qn, cut * nb, s)
    sq = idx.sum_q[lists].reshape(qn, cut * nb, s)
    scl = idx.sum_scale[lists].reshape(qn, cut * nb)
    zro = idx.sum_zero[lists].reshape(qn, cut * nb)
    l_ax = cut * nb
    ref_s = np.asarray(summary_dot_batch(q_dense, sc, sq, scl, zro,
                                         tile_q=8, tile_l=128))
    pick_s = choose_tiles(qn, l_ax, row_bytes=summary_row_bytes(s),
                          q_row_bytes=4 * d)
    for tq, tl in sorted({(8, 128), (pick_s.tile_q, pick_s.tile_n)}):
        us = timeit_us(lambda tq=tq, tl=tl: summary_dot_batch(
            q_dense, sc, sq, scl, zro, tile_q=tq, tile_l=tl))
        got = np.asarray(summary_dot_batch(q_dense, sc, sq, scl, zro,
                                           tile_q=tq, tile_l=tl))
        same = np.array_equal(got, ref_s)   # bitwise across tilings
        ok &= same
        yield row(f"kb_tile_summary_{tq}x{tl}", us, parity=same,
                  model_bytes=bytes_moved(
                      qn, l_ax, tq, tl,
                      row_bytes=summary_row_bytes(s), q_row_bytes=4 * d))
    yield row("kb_tile_parity", 0.0, tile_invariant_ok=bool(ok))


# ----------------------------------------------- fusion parity + model


def _fuse_rows(idx, queries, eids):
    cfg = idx.config
    base = dict(k=10, cut=4, block_budget=12, policy="budget",
                graph_degree=DEGREE, refine_rounds=2)
    variants = {
        "flat": SearchParams(**base),
        "hier": SearchParams(**base, superblock_fanout=cfg.superblock_fanout,
                             superblock_budget=6),
    }
    all_ok = True
    for tag, p0 in variants.items():
        outs, times = {}, {}
        for fl in (0, 1, 2):
            p = dataclasses.replace(p0, fuse_level=fl)
            s, i, e = jax.block_until_ready(search_pipeline(idx, queries, p))
            outs[fl] = (np.asarray(s), np.asarray(i), np.asarray(e))
            times[fl] = timeit_us(lambda p=p: search_pipeline(
                idx, queries, p))
        ok = all(
            np.array_equal(outs[0][j], outs[fl][j], equal_nan=True)
            for fl in (1, 2) for j in range(3))
        all_ok &= ok
        rec = mean_recall(outs[2][1], eids)
        # per-query work-model bytes the fusions delete
        rb = {fl: router_bytes(
            cut=p0.cut, n_blocks=cfg.n_blocks, summary_nnz=cfg.summary_nnz,
            dim=idx.dim, fuse_level=fl,
            n_superblocks=cfg.n_superblocks if tag == "hier" else 0,
            fanout=cfg.superblock_fanout if tag == "hier" else 0,
            superblock_budget=6, superblock_nnz=cfg.superblock_nnz)
            for fl in (0, 2)}
        fb = {fl: refine_bytes(
            k=p0.k, degree=DEGREE, rounds=p0.refine_rounds,
            nnz=int(idx.fwd.coords.shape[1]),
            quant=idx.fwd_scale is not None, dim=idx.dim, fuse_level=fl)
            for fl in (0, 2)}
        yield row(f"kb_fuse_{tag}", times[2],
                  us_level0=f"{times[0]:.0f}", us_level1=f"{times[1]:.0f}",
                  bit_exact_012=ok, recall10=f"{rec:.3f}",
                  router_bytes_l0=rb[0], router_bytes_l2=rb[2],
                  router_bytes_x=f"{rb[0] / rb[2]:.2f}",
                  refine_bytes_l0=fb[0], refine_bytes_l2=fb[2],
                  refine_bytes_x=f"{fb[0] / fb[2]:.2f}")
        all_ok &= rb[2] < rb[0] and fb[2] < fb[0]
    yield row("kb_fuse_parity", 0.0, fused_parity_ok=bool(all_ok))


# -------------------------------------------------- compaction gate


def _compact_rows(idx, queries, smoke):
    """High-dedupe fixture: a candidate axis drawn from a tiny id pool
    so most slots dedupe to the sentinel. After compaction the
    candidate-driven kernel must skip the sentinel tail."""
    rng = np.random.default_rng(1)
    qn = int(queries.coords.shape[0])
    c_ax = 1024 if smoke else 2048
    pool = 60                                   # ~60 live ids per query
    raw = jnp.asarray(rng.integers(0, pool, (qn, c_ax)), jnp.int32)
    cand = compact_candidates(dedupe_batch(raw, idx.n_docs))
    q_dense = jnp.zeros((qn, idx.dim), jnp.float32).at[
        jnp.arange(qn)[:, None], queries.coords].add(queries.vals)

    nnz = int(idx.fwd.coords.shape[1])
    quant = idx.fwd_scale is not None
    # tiles pinned small: the gate probes the SKIP mechanism, and a
    # chooser-sized tile can legally cover the whole (tiny) fixture axis
    tq, tn = 8, 128
    processed = cand_tiles_processed(cand, idx.n_docs, tq, tn)
    total_tiles = processed.size
    scored_slots = int(processed.sum()) * tq * tn
    total_slots = total_tiles * tq * tn
    live = np.asarray((cand < idx.n_docs).sum(axis=1))
    dead_rate = 1.0 - live.max() / c_ax
    reduction = 1.0 - scored_slots / total_slots
    # equality up to one tile_n of rounding per row-tile
    ok = reduction + tn / c_ax + 1e-9 >= dead_rate
    ok &= reduction > 0.5           # and the skip must actually bite

    # parity: compacted fast path == level-0 host scoring. Compaction
    # only permutes each row, so the sorted score rows must agree
    # (allclose: the host path's nnz-sum may reassociate under XLA)
    s0 = np.asarray(score_candidates(idx, q_dense,
                                     dedupe_batch(raw, idx.n_docs), False))
    s1 = np.asarray(gather_dot_cand_batch(
        q_dense, cand, idx.fwd.coords, idx.fwd.vals, idx.fwd_scale,
        idx.fwd_zero, n_docs=idx.n_docs, tile_q=tq, tile_n=tn))
    f0, f1 = np.sort(s0, axis=1), np.sort(s1, axis=1)
    sent = ~np.isfinite(f0)
    same = (np.array_equal(sent, ~np.isfinite(f1))
            and np.allclose(f0[~sent], f1[~sent], rtol=1e-5, atol=1e-6))
    us = timeit_us(lambda: gather_dot_cand_batch(
        q_dense, cand, idx.fwd.coords, idx.fwd.vals, idx.fwd_scale,
        idx.fwd_zero, n_docs=idx.n_docs, tile_q=tq, tile_n=tn))
    sb = {fl: scorer_bytes(n_slots=c_ax,
                           scored_slots=scored_slots // qn if fl else c_ax,
                           nnz=nnz, quant=quant, dim=idx.dim, fuse_level=fl)
          for fl in (0, 1)}
    yield row("kb_compact", us, tile_q=tq, tile_n=tn,
              cand_slots=c_ax, live_max=int(live.max()),
              scored_slots=scored_slots // qn,
              dead_rate=f"{dead_rate:.3f}", reduction=f"{reduction:.3f}",
              scorer_bytes_l0=sb[0], scorer_bytes_l1=sb[1],
              scorer_bytes_x=f"{sb[0] / sb[1]:.2f}",
              score_parity=bool(same), compaction_ok=bool(ok and same))


def run(smoke: bool = False):
    idx, queries, eids = _fixture(smoke)
    yield from _tile_sweep_rows(idx, queries, smoke)
    yield from _fuse_rows(idx, queries, eids)
    yield from _compact_rows(idx, queries, smoke)


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fixture (CI smoke); same exit gates")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    bad = []
    for line in run(smoke=args.smoke):
        print(line)
        if any(f"{g}=False" in line
               for g in ("tile_invariant_ok", "fused_parity_ok",
                         "compaction_ok")):
            bad.append(line)
    if bad:
        raise SystemExit(
            "kernel microbench gates failed (fused paths must stay "
            "bit-exact and compaction must shrink the scored candidate "
            "axis):\n" + "\n".join(bad))


if __name__ == "__main__":
    main()
