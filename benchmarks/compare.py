"""Benchmark regression sentinel: diff a fresh ``BENCH_*.json``
artifact directory (``benchmarks.run --artifacts``) against a committed
baseline directory and fail CI on *quality* regressions.

    python -m benchmarks.compare --baseline benchmarks/baselines \
        --fresh bench-artifacts [--pct 10]

Two classes of regression ERROR (nonzero exit):

  * a ``gate_*`` verdict that was True in the baseline and is False in
    the fresh run (a hard acceptance gate flipped);
  * a recall-like metric (any derived key containing ``recall``)
    that dropped by more than ``--pct`` percent relative.

Everything else — latency, QPS, span costs — is environment-sensitive
on shared CI boxes, so timing drifts only WARN (with a direction
heuristic: ``qps``/``recall``/``speedup``/``hit``/``occupancy`` are
higher-better; ``us``/``_ms``/``_pct`` suffixed keys lower-better).
Rows or modules present on only one side are reported but never fail
the run, so adding a benchmark doesn't require regenerating baselines
atomically. Stdlib-only: runs before (and without) the repro package.

Regenerate baselines with::

    PYTHONPATH=src python -m benchmarks.run \
        --only serving_load,obs_overhead --smoke \
        --artifacts benchmarks/baselines
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

HIGHER_BETTER = ("qps", "recall", "speedup", "hit", "occupancy")
LOWER_BETTER_SUFFIX = ("us", "_ms", "_pct")


def load_artifacts(d: Path) -> dict[str, dict]:
    """``BENCH_<module>.json`` files in ``d`` -> {module: artifact}."""
    out = {}
    for p in sorted(d.glob("BENCH_*.json")):
        art = json.loads(p.read_text())
        out[art.get("name", p.stem[len("BENCH_"):])] = art
    return out


def _rows_by_name(art: dict) -> dict[str, dict]:
    """Derived dicts keyed by row name; duplicate names keep the last
    occurrence (rows are append-ordered, last is freshest)."""
    return {r["name"]: r.get("derived", {}) for r in art.get("rows", [])}


def _as_float(v) -> float | None:
    try:
        return float(v)
    except (TypeError, ValueError):
        return None


def direction(key: str) -> int:
    """+1 if higher is better, -1 if lower is better, 0 if unknown."""
    k = key.lower()
    if any(tok in k for tok in HIGHER_BETTER):
        return 1
    if any(k.endswith(suf) for suf in LOWER_BETTER_SUFFIX):
        return -1
    return 0


def compare(baseline: dict[str, dict], fresh: dict[str, dict],
            pct: float) -> tuple[list[str], list[str]]:
    """Diff two artifact maps -> (errors, warnings)."""
    errors: list[str] = []
    warnings: list[str] = []
    for mod in sorted(set(baseline) | set(fresh)):
        if mod not in fresh:
            warnings.append(f"{mod}: missing from fresh run")
            continue
        if mod not in baseline:
            warnings.append(f"{mod}: new module (no baseline)")
            continue
        base, new = baseline[mod], fresh[mod]
        if new.get("verdict") == "error":
            errors.append(f"{mod}: fresh run errored: {new.get('error')}")
            continue
        base_gates = base.get("gates", {})
        for gate, held in sorted(new.get("gates", {}).items()):
            if base_gates.get(gate) is True and held is False:
                errors.append(f"{mod}: gate flipped True->False: {gate}")
        base_rows = _rows_by_name(base)
        for name, derived in sorted(_rows_by_name(new).items()):
            if name not in base_rows:
                warnings.append(f"{mod}/{name}: new row (no baseline)")
                continue
            for key, raw in sorted(derived.items()):
                v_new = _as_float(raw)
                v_old = _as_float(base_rows[name].get(key))
                if v_new is None or v_old is None or key.startswith("gate_"):
                    continue
                # classify on row name + key, so e.g. the `live` column
                # of serve_audit_live_recall counts as recall-like
                ctx = f"{name}.{key}"
                d = direction(ctx)
                if d == 0 or v_old == 0:
                    continue
                # signed relative change in the *better* direction
                change_pct = d * (v_new - v_old) / abs(v_old) * 100
                if change_pct >= -pct:
                    continue
                msg = (f"{mod}/{name}: {key} regressed "
                       f"{v_old:.6g} -> {v_new:.6g} "
                       f"({change_pct:+.1f}% vs gate -{pct:g}%)")
                if "recall" in ctx.lower():
                    errors.append(msg)
                else:
                    warnings.append(f"(timing) {msg}")
    return errors, warnings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.compare",
        description="Diff fresh BENCH_*.json artifacts vs a baseline "
                    "directory; exit nonzero on quality regressions.")
    ap.add_argument("--baseline", required=True, metavar="DIR",
                    help="committed baseline artifact directory")
    ap.add_argument("--fresh", required=True, metavar="DIR",
                    help="artifact directory from this run")
    ap.add_argument("--pct", type=float, default=10.0,
                    help="max relative drop for recall-like metrics "
                         "(default 10%%)")
    args = ap.parse_args(argv)
    base_dir, fresh_dir = Path(args.baseline), Path(args.fresh)
    if not base_dir.is_dir():
        print(f"baseline dir {base_dir} missing — nothing to compare "
              f"(regenerate per module docstring)", file=sys.stderr)
        return 0
    if not fresh_dir.is_dir():
        print(f"fresh dir {fresh_dir} missing", file=sys.stderr)
        return 2
    baseline = load_artifacts(base_dir)
    fresh = load_artifacts(fresh_dir)
    if not baseline:
        print(f"no BENCH_*.json in {base_dir} — nothing to compare",
              file=sys.stderr)
        return 0
    errors, warnings = compare(baseline, fresh, args.pct)
    for w in warnings:
        print(f"WARN  {w}")
    for e in errors:
        print(f"ERROR {e}")
    n_mod = len(set(baseline) & set(fresh))
    print(f"compared {n_mod} modules: {len(errors)} errors, "
          f"{len(warnings)} warnings")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
