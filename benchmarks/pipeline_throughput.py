"""Per-stage throughput of the staged retrieval pipeline
(repro.retrieval): prep -> router -> selector -> scorer -> merge.

Each stage is jitted standalone on materialized inputs of the previous
stage, so the numbers isolate where a query batch spends its time.
Derived metrics:

  router   routed_blocks_s  — summary inner products / second
                              (Q * router_work(cfg, p) per batch)
           summary_dots     — router-stage work per query: flat scores
                              cut * n_blocks summaries, hierarchical
                              scores cut * n_superblocks coarse
                              summaries + superblock_budget * fanout
                              child summaries (the BMP-style two-tier
                              route)
  scorer   scored_docs_s    — exact forward-index scorings / second
                              (deduped candidates, sentinels excluded)
  e2e      qps + recall@10  — whole-pipeline sanity per policy

Runs all three registry policies (budget / adaptive / global_threshold)
twice: flat routing, then hierarchical routing on a superblock-built
index (SUPERBLOCK_FANOUT / SUPERBLOCK_BUDGET), and prints the per-query
router-work reduction. The hierarchical rows must hold selector recall
while evaluating >= 2x fewer summary dots (work_vs_flat >= 2).

A ``pipe_fuse_*`` row per policy compares ``fuse_level`` 0 vs 2 on the
hierarchical index: recall must be equal (the fusions are bit-exact)
while the modeled per-query router/scorer/refine HBM bytes
(repro.retrieval.workmodel) drop — the memory-traffic story the fused
kernels are for (interpret-mode wall time cannot show it; the kernel
microbench gates the model's honesty against the tile-skip counter).

    PYTHONPATH=src python -m benchmarks.pipeline_throughput
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import (INDEX, built_index, collection, mean_recall,
                               row, timeit_us)
from repro.core import build_index
from repro.retrieval import (SearchParams, router_work, search_pipeline,
                             stage_fns)
from repro.retrieval.workmodel import (refine_bytes, router_bytes,
                                       scorer_bytes)

POLICIES = ("budget", "adaptive", "global_threshold")

# coarse-tier operating point: 18 blocks -> 3 superblocks per list;
# keeping 8 of the 24 probed superblocks halves router work (144 -> 72
# summary dots per query) at equal selector recall on the synthetic
# collection (see ISSUE 3 acceptance)
SUPERBLOCK_FANOUT = 6
SUPERBLOCK_BUDGET = 8

_hier_cache: dict = {}


def hier_index():
    if "idx" not in _hier_cache:
        docs, *_ = collection()
        icfg = dataclasses.replace(INDEX,
                                   superblock_fanout=SUPERBLOCK_FANOUT)
        idx = build_index(docs, icfg, list_chunk=32)
        jax.block_until_ready(idx.sup_q)
        _hier_cache["idx"] = idx
    return _hier_cache["idx"]


def _policy_rows(tag, idx, p, queries, eids):
    """Stage + e2e rows for one (index, params) pair. Returns
    (rows, recall@10, summary_dots_per_query) so the caller can emit
    the flat-vs-hier reduction row without re-running the pipeline."""
    rows = []
    qn = queries.n
    fns = stage_fns(idx, p)   # the retrieval-layer timing hooks
    prep, route, select, score, merge = (
        fns["prep"], fns["router"], fns["selector"], fns["scorer"],
        fns["merge"])

    # materialize stage inputs once
    q_dense, lists, _ = jax.block_until_ready(
        prep(queries.coords, queries.vals))
    batch = jax.block_until_ready(route(q_dense, lists))
    sel = jax.block_until_ready(select(batch))
    cand, scores = jax.block_until_ready(score(batch, sel))
    _ = jax.block_until_ready(merge(cand, scores))

    us_prep = timeit_us(prep, queries.coords, queries.vals)
    us_route = timeit_us(route, q_dense, lists)
    us_select = timeit_us(select, batch)
    us_score = timeit_us(score, batch, sel)
    us_merge = timeit_us(merge, cand, scores)

    work = router_work(idx.config, p)            # summary dots / query
    routed = qn * work
    _, ids, ev = search_pipeline(idx, queries, p)
    scored = int(np.asarray(ev).sum())
    rows.append(row(f"pipe_prep_{tag}", us_prep, q=qn))
    rows.append(row(f"pipe_router_{tag}", us_route,
                    routed_blocks_s=f"{routed / (us_route * 1e-6):.3g}",
                    summary_dots=work))
    rows.append(row(f"pipe_selector_{tag}", us_select,
                    blocks=p.block_budget))
    rows.append(row(f"pipe_scorer_{tag}", us_score,
                    scored_docs_s=f"{scored / (us_score * 1e-6):.3g}"))
    rows.append(row(f"pipe_merge_{tag}", us_merge, k=p.k))

    us_e2e = timeit_us(lambda: search_pipeline(idx, queries, p))
    rec = mean_recall(np.asarray(ids), eids)
    rows.append(row(f"pipe_e2e_{tag}", us_e2e,
                    qps=f"{qn / (us_e2e * 1e-6):.3g}",
                    recall10=f"{rec:.3f}",
                    docs_eval=int(np.asarray(ev).mean())))
    return rows, rec, work


def _fuse_row(policy, idx, ph, queries, eids):
    """fuse_level 0 vs 2 on the hierarchical index: equal recall,
    reduced modeled router/scorer (and refine, when enabled) bytes."""
    from repro.kernels.gather_dot.ops import (cand_tile_choice,
                                              cand_tiles_processed)
    cfg = idx.config
    recs, times = {}, {}
    for fl in (0, 2):
        p = dataclasses.replace(ph, fuse_level=fl)
        _, ids, _ = jax.block_until_ready(search_pipeline(idx, queries, p))
        recs[fl] = mean_recall(np.asarray(ids), eids)
        times[fl] = timeit_us(lambda p=p: search_pipeline(idx, queries, p))
    # measured scored slots: the compacted scorer candidates through
    # the same tile-skip accounting the kernel applies
    p2 = dataclasses.replace(ph, fuse_level=2)
    fns = stage_fns(idx, p2)
    q_dense, lists, _ = fns["prep"](queries.coords, queries.vals)
    batch = fns["router"](q_dense, lists)
    cand, _ = fns["scorer"](batch, fns["selector"](batch))
    qn, c_ax = cand.shape
    nnz = int(idx.fwd.coords.shape[1])
    quant = idx.fwd_scale is not None
    ch = cand_tile_choice(qn, c_ax, nnz, quant=quant, dim=idx.dim)
    proc = cand_tiles_processed(np.asarray(cand), idx.n_docs,
                                ch.tile_q, ch.tile_n)
    scored = int(proc.sum()) * ch.tile_q * ch.tile_n // qn
    rb = {fl: router_bytes(
        cut=ph.cut, n_blocks=cfg.n_blocks, summary_nnz=cfg.summary_nnz,
        dim=idx.dim, fuse_level=fl, n_superblocks=cfg.n_superblocks,
        fanout=cfg.superblock_fanout,
        superblock_budget=ph.superblock_budget,
        superblock_nnz=cfg.superblock_nnz) for fl in (0, 2)}
    sb = {fl: scorer_bytes(n_slots=c_ax,
                           scored_slots=scored if fl else c_ax, nnz=nnz,
                           quant=quant, dim=idx.dim, fuse_level=fl)
          for fl in (0, 2)}
    fb = {fl: refine_bytes(k=ph.k, degree=ph.graph_degree,
                           rounds=ph.refine_rounds, nnz=nnz, quant=quant,
                           dim=idx.dim, fuse_level=fl) for fl in (0, 2)}
    ok = (recs[2] == recs[0] and rb[2] < rb[0] and sb[2] < sb[0]
          and (ph.refine_rounds <= 0 or fb[2] < fb[0]))
    return row(f"pipe_fuse_{policy}", times[2],
               us_level0=f"{times[0]:.0f}",
               recall_l0=f"{recs[0]:.3f}", recall_l2=f"{recs[2]:.3f}",
               router_bytes_x=f"{rb[0] / rb[2]:.2f}",
               scorer_bytes_x=f"{sb[0] / sb[2]:.2f}",
               refine_bytes_x=(f"{fb[0] / fb[2]:.2f}"
                               if ph.refine_rounds > 0 else "n/a"),
               scored_slots=scored, cand_slots=c_ax,
               fuse_reduces_bytes_at_equal_recall=ok)


def run():
    _, queries, _, _, eids = collection()
    idx_flat, _ = built_index()
    idx_hier = hier_index()

    for policy in POLICIES:
        pf = SearchParams(k=10, cut=8, block_budget=32, policy=policy)
        ph = dataclasses.replace(pf, superblock_fanout=SUPERBLOCK_FANOUT,
                                 superblock_budget=SUPERBLOCK_BUDGET)
        rows_f, rf, wf = _policy_rows(policy, idx_flat, pf, queries, eids)
        rows_h, rh, wh = _policy_rows(f"hier_{policy}", idx_hier, ph,
                                      queries, eids)
        yield from rows_f
        yield from rows_h

        reduction = wf / wh
        ok = reduction >= 2.0 and rh >= rf - 0.01
        yield row(f"pipe_router_reduction_{policy}", 0.0,
                  summary_dots_flat=wf, summary_dots_hier=wh,
                  work_vs_flat=f"{reduction:.2f}x",
                  recall_flat=f"{rf:.3f}", recall_hier=f"{rh:.3f}",
                  meets_2x_at_equal_recall=ok)
        yield _fuse_row(policy, idx_hier, ph, queries, eids)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    bad = []
    for line in run():
        print(line)
        if ("meets_2x_at_equal_recall=False" in line
                or "fuse_reduces_bytes_at_equal_recall=False" in line):
            bad.append(line)
    if bad:
        raise SystemExit(
            "pipeline acceptance failed (need >= 2x summary-dot "
            "reduction at equal recall, and fused levels must reduce "
            "modeled bytes at equal recall):\n" + "\n".join(bad))
