"""Per-stage throughput of the staged retrieval pipeline
(repro.retrieval): prep -> router -> selector -> scorer -> merge.

Each stage is jitted standalone on materialized inputs of the previous
stage, so the numbers isolate where a query batch spends its time.
Derived metrics:

  router   routed_blocks_s  — summary inner products / second
                              (Q * cut * n_blocks per batch)
  scorer   scored_docs_s    — exact forward-index scorings / second
                              (deduped candidates, sentinels excluded)
  e2e      qps + recall@10  — whole-pipeline sanity per policy

Run all three registry policies (budget / adaptive / global_threshold);
the adaptive selector's time includes its stage-1 scoring bootstrap.

    PYTHONPATH=src python -m benchmarks.pipeline_throughput
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (built_index, collection, mean_recall, row,
                               timeit_us)
from repro.retrieval import SearchParams, search_pipeline, stage_fns

POLICIES = ("budget", "adaptive", "global_threshold")


def run():
    _, queries, _, _, eids = collection()
    idx, _ = built_index()
    qn = queries.n
    nb = idx.config.n_blocks

    for policy in POLICIES:
        p = SearchParams(k=10, cut=8, block_budget=32, policy=policy)
        fns = stage_fns(idx, p)   # the retrieval-layer timing hooks
        prep, route, select, score, merge = (
            fns["prep"], fns["router"], fns["selector"], fns["scorer"],
            fns["merge"])

        # materialize stage inputs once
        q_dense, lists, _ = jax.block_until_ready(
            prep(queries.coords, queries.vals))
        batch = jax.block_until_ready(route(q_dense, lists))
        sel = jax.block_until_ready(select(batch))
        cand, scores = jax.block_until_ready(score(batch, sel))
        _, ids, ev = jax.block_until_ready(merge(cand, scores))

        us_prep = timeit_us(prep, queries.coords, queries.vals)
        us_route = timeit_us(route, q_dense, lists)
        us_select = timeit_us(select, batch)
        us_score = timeit_us(score, batch, sel)
        us_merge = timeit_us(merge, cand, scores)

        routed = qn * p.cut * nb
        scored = int(np.asarray(ev).sum())
        yield row(f"pipe_prep_{policy}", us_prep, q=qn)
        yield row(f"pipe_router_{policy}", us_route,
                  routed_blocks_s=f"{routed / (us_route * 1e-6):.3g}")
        yield row(f"pipe_selector_{policy}", us_select,
                  blocks=p.block_budget)
        yield row(f"pipe_scorer_{policy}", us_score,
                  scored_docs_s=f"{scored / (us_score * 1e-6):.3g}")
        yield row(f"pipe_merge_{policy}", us_merge, k=p.k)

        us_e2e = timeit_us(lambda: search_pipeline(idx, queries, p))
        _, ids, ev = search_pipeline(idx, queries, p)
        yield row(f"pipe_e2e_{policy}", us_e2e,
                  qps=f"{qn / (us_e2e * 1e-6):.3g}",
                  recall10=f"{mean_recall(np.asarray(ids), eids):.3f}",
                  docs_eval=int(np.asarray(ev).mean()))


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in run():
        print(line)
