"""Per-stage throughput of the staged retrieval pipeline
(repro.retrieval): prep -> router -> selector -> scorer -> merge.

Each stage is jitted standalone on materialized inputs of the previous
stage, so the numbers isolate where a query batch spends its time.
Derived metrics:

  router   routed_blocks_s  — summary inner products / second
                              (Q * router_work(cfg, p) per batch)
           summary_dots     — router-stage work per query: flat scores
                              cut * n_blocks summaries, hierarchical
                              scores cut * n_superblocks coarse
                              summaries + superblock_budget * fanout
                              child summaries (the BMP-style two-tier
                              route)
  scorer   scored_docs_s    — exact forward-index scorings / second
                              (deduped candidates, sentinels excluded)
  e2e      qps + recall@10  — whole-pipeline sanity per policy

Runs all three registry policies (budget / adaptive / global_threshold)
twice: flat routing, then hierarchical routing on a superblock-built
index (SUPERBLOCK_FANOUT / SUPERBLOCK_BUDGET), and prints the per-query
router-work reduction. The hierarchical rows must hold selector recall
while evaluating >= 2x fewer summary dots (work_vs_flat >= 2).

    PYTHONPATH=src python -m benchmarks.pipeline_throughput
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import (INDEX, built_index, collection, mean_recall,
                               row, timeit_us)
from repro.core import build_index
from repro.retrieval import (SearchParams, router_work, search_pipeline,
                             stage_fns)

POLICIES = ("budget", "adaptive", "global_threshold")

# coarse-tier operating point: 18 blocks -> 3 superblocks per list;
# keeping 8 of the 24 probed superblocks halves router work (144 -> 72
# summary dots per query) at equal selector recall on the synthetic
# collection (see ISSUE 3 acceptance)
SUPERBLOCK_FANOUT = 6
SUPERBLOCK_BUDGET = 8

_hier_cache: dict = {}


def hier_index():
    if "idx" not in _hier_cache:
        docs, *_ = collection()
        icfg = dataclasses.replace(INDEX,
                                   superblock_fanout=SUPERBLOCK_FANOUT)
        idx = build_index(docs, icfg, list_chunk=32)
        jax.block_until_ready(idx.sup_q)
        _hier_cache["idx"] = idx
    return _hier_cache["idx"]


def _policy_rows(tag, idx, p, queries, eids):
    """Stage + e2e rows for one (index, params) pair. Returns
    (rows, recall@10, summary_dots_per_query) so the caller can emit
    the flat-vs-hier reduction row without re-running the pipeline."""
    rows = []
    qn = queries.n
    fns = stage_fns(idx, p)   # the retrieval-layer timing hooks
    prep, route, select, score, merge = (
        fns["prep"], fns["router"], fns["selector"], fns["scorer"],
        fns["merge"])

    # materialize stage inputs once
    q_dense, lists, _ = jax.block_until_ready(
        prep(queries.coords, queries.vals))
    batch = jax.block_until_ready(route(q_dense, lists))
    sel = jax.block_until_ready(select(batch))
    cand, scores = jax.block_until_ready(score(batch, sel))
    _ = jax.block_until_ready(merge(cand, scores))

    us_prep = timeit_us(prep, queries.coords, queries.vals)
    us_route = timeit_us(route, q_dense, lists)
    us_select = timeit_us(select, batch)
    us_score = timeit_us(score, batch, sel)
    us_merge = timeit_us(merge, cand, scores)

    work = router_work(idx.config, p)            # summary dots / query
    routed = qn * work
    _, ids, ev = search_pipeline(idx, queries, p)
    scored = int(np.asarray(ev).sum())
    rows.append(row(f"pipe_prep_{tag}", us_prep, q=qn))
    rows.append(row(f"pipe_router_{tag}", us_route,
                    routed_blocks_s=f"{routed / (us_route * 1e-6):.3g}",
                    summary_dots=work))
    rows.append(row(f"pipe_selector_{tag}", us_select,
                    blocks=p.block_budget))
    rows.append(row(f"pipe_scorer_{tag}", us_score,
                    scored_docs_s=f"{scored / (us_score * 1e-6):.3g}"))
    rows.append(row(f"pipe_merge_{tag}", us_merge, k=p.k))

    us_e2e = timeit_us(lambda: search_pipeline(idx, queries, p))
    rec = mean_recall(np.asarray(ids), eids)
    rows.append(row(f"pipe_e2e_{tag}", us_e2e,
                    qps=f"{qn / (us_e2e * 1e-6):.3g}",
                    recall10=f"{rec:.3f}",
                    docs_eval=int(np.asarray(ev).mean())))
    return rows, rec, work


def run():
    _, queries, _, _, eids = collection()
    idx_flat, _ = built_index()
    idx_hier = hier_index()

    for policy in POLICIES:
        pf = SearchParams(k=10, cut=8, block_budget=32, policy=policy)
        ph = dataclasses.replace(pf, superblock_fanout=SUPERBLOCK_FANOUT,
                                 superblock_budget=SUPERBLOCK_BUDGET)
        rows_f, rf, wf = _policy_rows(policy, idx_flat, pf, queries, eids)
        rows_h, rh, wh = _policy_rows(f"hier_{policy}", idx_hier, ph,
                                      queries, eids)
        yield from rows_f
        yield from rows_h

        reduction = wf / wh
        ok = reduction >= 2.0 and rh >= rf - 0.01
        yield row(f"pipe_router_reduction_{policy}", 0.0,
                  summary_dots_flat=wf, summary_dots_hier=wh,
                  work_vs_flat=f"{reduction:.2f}x",
                  recall_flat=f"{rf:.3f}", recall_hier=f"{rh:.3f}",
                  meets_2x_at_equal_recall=ok)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    bad = []
    for line in run():
        print(line)
        if "meets_2x_at_equal_recall=False" in line:
            bad.append(line)
    if bad:
        raise SystemExit(
            "router-work acceptance failed (need >= 2x summary-dot "
            "reduction at equal recall):\n" + "\n".join(bad))
