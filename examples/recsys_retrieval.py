"""Beyond-paper application: Seismic for recsys candidate retrieval.

SASRec's retrieval cell is a MIPS over the item-embedding table
(DESIGN.md §5). Dense item embeddings are sparsified (top-t entries of
a nonneg-transformed embedding) and indexed with Seismic; the user
state queries the index instead of brute-forcing all items.

    PYTHONPATH=src python examples/recsys_retrieval.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import SeismicConfig, SearchParams, build_index, search_batch
from repro.models.api import get_bundle
from repro.models.recsys import sasrec
from repro.sparse.ops import PaddedSparse, sparsify


def main():
    bundle = get_bundle("sasrec")
    import dataclasses
    cfg = dataclasses.replace(bundle.reduced, n_items=4096, embed_dim=32)
    params = bundle.init(jax.random.PRNGKey(0), cfg, {})
    rng = np.random.default_rng(0)

    print("== dense item table -> nonneg sparse embeddings ==")
    table = np.asarray(params["item_emb"])[:cfg.n_items + 1]
    # nonnegative decomposition: [relu(x); relu(-x)] keeps inner products
    nonneg = np.concatenate([np.maximum(table, 0), np.maximum(-table, 0)],
                            axis=1)                       # [N, 2D]
    items = sparsify(jnp.asarray(nonneg), nnz_max=16)
    index = build_index(items, SeismicConfig(lam=128, beta=8, alpha=0.5,
                                             block_cap=32, summary_nnz=32),
                        list_chunk=16)

    print("== user states -> queries ==")
    n_users = 32
    seqs = rng.integers(1, cfg.n_items, (n_users, cfg.seq_len)).astype(np.int32)
    states = np.asarray(sasrec.forward(params, jnp.asarray(seqs), cfg))[:, -1]
    q_nonneg = np.concatenate([np.maximum(states, 0),
                               np.maximum(-states, 0)], axis=1)
    queries = sparsify(jnp.asarray(q_nonneg), nnz_max=16)

    print("== Seismic retrieval vs dense brute force ==")
    dense_scores = states @ table.T                      # [U, N]
    dense_top = np.argsort(-dense_scores, axis=1)[:, :10]
    p = SearchParams(k=10, cut=8, block_budget=32, policy="budget")
    _, ids, ev = search_batch(index, queries, p)
    overlap = np.mean([len(set(np.asarray(ids[u]).tolist())
                           & set(dense_top[u].tolist())) / 10
                       for u in range(n_users)])
    print(f"   top-10 overlap with dense brute force: {overlap:.2f} "
          f"(sparsified embeddings, {int(np.asarray(ev).mean())} of "
          f"{cfg.n_items} items evaluated)")
    print("   NOTE: overlap is bounded by the top-16-entry sparsification;"
          " the contract demonstrated is index <-> any sparse encoder.")


if __name__ == "__main__":
    main()
