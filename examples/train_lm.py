"""End-to-end LM training driver: data pipeline -> train loop -> async
checkpointing -> resume. CPU-sized by default; --arch/--steps/--batch
scale it up (the same code path the production launcher uses).

    PYTHONPATH=src python examples/train_lm.py --steps 100
    PYTHONPATH=src python examples/train_lm.py --resume   # picks up ckpt
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.ckpt import CheckpointManager
from repro.data.pipeline import PrefetchLoader, lm_token_stream
from repro.models.api import get_bundle
from repro.train import AdamWConfig, init_opt_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full arch config (needs accelerators)")
    args = ap.parse_args()

    bundle = get_bundle(args.arch)
    cfg = bundle.config if args.full_config else bundle.reduced
    dims = dict(global_batch=args.batch, seq_len=args.seq)
    print(f"arch={cfg.name} layers={cfg.n_layers} d_model={cfg.d_model}")

    params = bundle.init(jax.random.PRNGKey(0), cfg, dims)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.1f}M")
    opt = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(bundle.step(cfg, dims, "train"),
                                      opt_cfg))

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start = 0
    if args.resume:
        try:
            restored, start = mgr.restore_latest(dict(params=params, opt=opt))
            params, opt = restored["params"], restored["opt"]
            print(f"resumed from step {start}")
        except FileNotFoundError:
            print("no checkpoint found; starting fresh")

    loader = PrefetchLoader(
        lm_token_stream(cfg.vocab, args.batch, args.seq, seed=start),
        prefetch=4)
    t0 = time.time()
    for i, batch in enumerate(loader):
        step = start + i
        if i >= args.steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if step % 10 == 0:
            print(f"step {step:5d}  loss={float(metrics['loss']):.4f}  "
                  f"lr={float(metrics['lr']):.2e}  "
                  f"gnorm={float(metrics['grad_norm']):.2f}  "
                  f"{(time.time()-t0)/(i+1)*1000:.0f} ms/step")
        if step > 0 and step % args.ckpt_every == 0:
            mgr.save_async(step, dict(params=params, opt=opt))
    loader.close()
    mgr.save_async(start + args.steps, dict(params=params, opt=opt))
    mgr.wait()
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
