"""LSR end-to-end: an LM encoder producing learned sparse embeddings,
indexed and served by Seismic — the bridge between the assigned LM
architectures and the paper's technique (DESIGN.md §5).

Pipeline: tiny decoder LM (llama3-8b reduced) -> SPLADE-style pooling
(log(1+relu(logits)) max-pooled over positions) -> sparse embeddings ->
Seismic index -> retrieval. With an untrained encoder the embeddings
are not semantically meaningful; the demonstration is the *system
contract*: any vocab-dim sparse encoder drops into the index, and
approximate search matches exact search over those embeddings.

    PYTHONPATH=src python examples/lsr_end_to_end.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import SeismicConfig, SearchParams, build_index, search_batch
from repro.core.baselines import exact_search
from repro.core.oracle import recall_at_k
from repro.models.api import get_bundle
from repro.models.transformer import lm
from repro.sparse.ops import PaddedSparse, sparsify


def splade_pool(logits: jax.Array, mask: jax.Array) -> jax.Array:
    """SPLADE pooling: max over positions of log(1 + relu(logit))."""
    act = jnp.log1p(jax.nn.relu(logits.astype(jnp.float32)))
    act = jnp.where(mask[..., None], act, 0.0)
    return act.max(axis=1)                       # [B, V]


def main():
    bundle = get_bundle("llama3-8b")
    cfg = bundle.reduced                          # vocab 256 toy encoder
    params = bundle.init(jax.random.PRNGKey(0), cfg, {})
    rng = np.random.default_rng(0)

    print("== encoding 'documents' and 'queries' with the LM ==")
    n_docs, n_queries, seq = 2048, 32, 24
    doc_tokens = rng.integers(0, cfg.vocab, (n_docs, seq)).astype(np.int32)
    # queries are prefixes of some docs -> they have true near neighbors
    q_docs = rng.choice(n_docs, n_queries, replace=False)
    q_tokens = doc_tokens[q_docs][:, :12]
    q_tokens = np.pad(q_tokens, ((0, 0), (0, seq - 12)))

    @jax.jit
    def encode(tokens):
        logits, _ = lm.forward(params, tokens, cfg)
        mask = jnp.asarray(tokens) != 0
        return splade_pool(logits, mask)

    doc_emb = np.concatenate([np.asarray(encode(jnp.asarray(
        doc_tokens[i:i + 256]))) for i in range(0, n_docs, 256)])
    q_emb = np.asarray(encode(jnp.asarray(q_tokens)))
    nnz = (doc_emb > 0).sum(-1).mean()
    print(f"   embeddings: dim={cfg.vocab}, doc nnz(mean)={nnz:.0f}")

    print("== sparsify + index with Seismic ==")
    docs = sparsify(jnp.asarray(doc_emb), nnz_max=64)
    queries = sparsify(jnp.asarray(q_emb), nnz_max=32)
    index = build_index(docs, SeismicConfig(lam=128, beta=8, alpha=0.4,
                                            block_cap=32, summary_nnz=32),
                        list_chunk=16)

    _, exact_ids = exact_search(docs, queries, 10)
    for budget in (24, 64, 128):
        p = SearchParams(k=10, cut=12, block_budget=budget, policy="adaptive")
        _, ids, ev = search_batch(index, queries, p)
        rec = np.mean([recall_at_k(np.asarray(ids[q]),
                                   np.asarray(exact_ids[q]))
                       for q in range(n_queries)])
        hit = np.mean([q_docs[q] in np.asarray(ids[q])
                       for q in range(n_queries)])
        print(f"   budget={budget:3d} recall@10 vs exact = {rec:.3f}  "
              f"(docs evaluated {int(np.asarray(ev).mean())}/{n_docs})  "
              f"source-doc hit rate: {hit:.2f}")
    print("   NOTE: an untrained encoder emits near-dense embeddings with"
          " weak concentration of importance; recall climbs slowly with"
          " budget — the paper's efficiency PRESUMES the concentration"
          " property (§4), which trained SPLADE models exhibit and the"
          " synthetic benchmarks reproduce.")


if __name__ == "__main__":
    main()
