"""Serving demo: the SeismicServer batched retrieval front-end plus a
small LMDecoder generation loop (the two serving engines).

    PYTHONPATH=src python examples/serve_retrieval.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import SeismicConfig, SearchParams, build_index
from repro.core.baselines import exact_search
from repro.core.oracle import recall_at_k
from repro.data import SyntheticSparseConfig, make_collection
from repro.models.api import get_bundle
from repro.serve.engine import LMDecoder, SeismicServer
from repro.sparse.ops import PaddedSparse


def retrieval_demo():
    print("== SeismicServer: batched approximate retrieval ==")
    cfg = SyntheticSparseConfig(dim=2048, n_docs=8192, n_queries=300,
                                doc_nnz=96, query_nnz=32)
    docs_np, queries_np, _ = make_collection(cfg)
    docs = PaddedSparse(jnp.asarray(docs_np.coords),
                        jnp.asarray(docs_np.vals), docs_np.dim)
    queries = PaddedSparse(jnp.asarray(queries_np.coords),
                           jnp.asarray(queries_np.vals), queries_np.dim)
    index = build_index(docs, SeismicConfig(lam=192, beta=12, alpha=0.4,
                                            block_cap=32, summary_nnz=48),
                        list_chunk=32)
    server = SeismicServer(index, SearchParams(k=10, cut=10,
                                               block_budget=16,
                                               policy="adaptive"),
                           max_batch=128)
    t0 = time.time()
    result = server.search(queries)   # 300 queries -> 3 padded batches
    dt = time.time() - t0
    _, exact_ids = exact_search(docs, queries, 10)
    rec = np.mean([recall_at_k(result.ids[q], np.asarray(exact_ids[q]))
                   for q in range(queries.n)])
    print(f"   300 queries in {dt*1000:.0f} ms "
          f"({dt/300*1e6:.0f} us/query CPU-JAX)  recall@10={rec:.3f}  "
          f"mean docs evaluated={result.docs_evaluated.mean():.0f}")


def decode_demo():
    print("== LMDecoder: KV-cache batched generation ==")
    bundle = get_bundle("gemma3-27b")          # reduced: dual-cache path
    cfg = bundle.reduced
    params = bundle.init(jax.random.PRNGKey(0), {}, cfg) \
        if False else bundle.init(jax.random.PRNGKey(0), cfg, {})
    dec = LMDecoder(params, cfg, batch=4, max_seq=64)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (4, 8))
    t0 = time.time()
    out = dec.generate(prompts.astype(np.int32), n_steps=24, greedy=True)
    print(f"   generated {out.shape} tokens in {time.time()-t0:.1f}s")
    print("   sample:", out[0].tolist())


if __name__ == "__main__":
    retrieval_demo()
    decode_demo()
