"""Serving demo: the synchronous SeismicServer facade, the async
deadline micro-batching server, and a small LMDecoder generation loop.

    PYTHONPATH=src python examples/serve_retrieval.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import SeismicConfig, SearchParams, build_index
from repro.core.baselines import exact_search
from repro.core.oracle import recall_at_k
from repro.data import SyntheticSparseConfig, make_collection
from repro.models.api import get_bundle
from repro.serve import AsyncSeismicServer, LMDecoder, SeismicServer
from repro.sparse.ops import PaddedSparse


def build_demo_index():
    cfg = SyntheticSparseConfig(dim=2048, n_docs=8192, n_queries=300,
                                doc_nnz=96, query_nnz=32)
    docs_np, queries_np, _ = make_collection(cfg)
    docs = PaddedSparse(jnp.asarray(docs_np.coords),
                        jnp.asarray(docs_np.vals), docs_np.dim)
    queries = PaddedSparse(jnp.asarray(queries_np.coords),
                           jnp.asarray(queries_np.vals), queries_np.dim)
    index = build_index(docs, SeismicConfig(lam=192, beta=12, alpha=0.4,
                                            block_cap=32, summary_nnz=48),
                        list_chunk=32)
    return docs, queries, index


def retrieval_demo(docs, queries, index):
    print("== SeismicServer: batched approximate retrieval ==")
    server = SeismicServer(index, SearchParams(k=10, cut=10,
                                               block_budget=16,
                                               policy="adaptive"),
                           max_batch=128)
    t0 = time.time()
    result = server.search(queries)   # 300 queries -> 3 padded batches
    dt = time.time() - t0
    _, exact_ids = exact_search(docs, queries, 10)
    rec = np.mean([recall_at_k(result.ids[q], np.asarray(exact_ids[q]))
                   for q in range(queries.n)])
    print(f"   300 queries in {dt*1000:.0f} ms "
          f"({dt/300*1e6:.0f} us/query CPU-JAX)  recall@10={rec:.3f}  "
          f"mean docs evaluated={result.docs_evaluated.mean():.0f}")


def async_demo(queries, index):
    """Submit per-request traffic with dispatch deadlines; print the
    occupancy / latency / cache telemetry the server exports."""
    print("== AsyncSeismicServer: deadline micro-batching ==")
    server = AsyncSeismicServer(
        index, SearchParams(k=10, cut=10, block_budget=16,
                            policy="adaptive"),
        max_batch=32, query_nnz=queries.nnz_max, deadline_s=0.01,
        queue_bound=512, admission="reject", cache_size=512)
    coords = np.asarray(queries.coords)
    vals = np.asarray(queries.vals)
    rng = np.random.default_rng(0)
    n_req = 2 * queries.n                 # every query twice: cache hits
    with server:
        futs = []
        t0 = time.time()
        for i in range(n_req):
            q = i % queries.n
            futs.append(server.submit(coords[q], vals[q],
                                      deadline_s=0.01))
            time.sleep(float(rng.exponential(2e-4)))   # ~5k qps offered
        for f in futs:
            f.wait()
        dt = time.time() - t0
    tel = server.telemetry_export()
    lat = tel["latency_s"]["request_e2e"]
    done = sum(f.status == "done" for f in futs)
    print(f"   {done}/{n_req} requests in {dt*1000:.0f} ms "
          f"({done/dt:.0f} qps)")
    print(f"   launches={tel['batch']['launches']}  "
          f"mean occupancy={tel['batch']['mean_occupancy']:.1f}/32  "
          f"max queue depth={tel['queue']['depth_max']}")
    print(f"   latency p50={lat['p50']*1e3:.1f}ms "
          f"p95={lat['p95']*1e3:.1f}ms p99={lat['p99']*1e3:.1f}ms")
    print(f"   cache hit-rate={tel['cache']['hit_rate']:.2f} "
          f"({tel['cache']['hits']} hits)")


def decode_demo():
    print("== LMDecoder: KV-cache batched generation ==")
    bundle = get_bundle("gemma3-27b")          # reduced: dual-cache path
    cfg = bundle.reduced
    params = bundle.init(jax.random.PRNGKey(0), cfg, {})
    dec = LMDecoder(params, cfg, batch=4, max_seq=64)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (4, 8))
    t0 = time.time()
    out = dec.generate(prompts.astype(np.int32), n_steps=24, greedy=True)
    print(f"   generated {out.shape} tokens in {time.time()-t0:.1f}s")
    print("   sample:", out[0].tolist())


if __name__ == "__main__":
    docs, queries, index = build_demo_index()
    retrieval_demo(docs, queries, index)
    async_demo(queries, index)
    decode_demo()
