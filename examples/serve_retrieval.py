"""Serving demo: the synchronous SeismicServer facade, the async
deadline micro-batching server, end-to-end observability (request
tracing + a live Prometheus/trace HTTP endpoint), serving a TUNED
operating point resolved from the index, shadow-oracle quality
auditing of live traffic (the /quality.json recall/funnel plane), and
a small LMDecoder generation loop.

Every retrieval launch runs the six-stage pipeline
(prep -> router -> selector -> scorer -> merge -> refine; see
src/repro/retrieval/README.md) — the refine stage traces as the
identity until an index carries a kNN graph and the params enable it.

    PYTHONPATH=src python examples/serve_retrieval.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import SeismicConfig, SearchParams, build_index
from repro.core.baselines import exact_search
from repro.core.oracle import recall_at_k
from repro.data import SyntheticSparseConfig, make_collection
from repro.graph import build_doc_graph
from repro.models.api import get_bundle
from repro.serve import AsyncSeismicServer, LMDecoder, SeismicServer
from repro.sparse.ops import PaddedSparse
from repro.tune import tune_and_attach


def build_demo_index():
    cfg = SyntheticSparseConfig(dim=2048, n_docs=8192, n_queries=300,
                                doc_nnz=96, query_nnz=32)
    docs_np, queries_np, _ = make_collection(cfg)
    docs = PaddedSparse(jnp.asarray(docs_np.coords),
                        jnp.asarray(docs_np.vals), docs_np.dim)
    queries = PaddedSparse(jnp.asarray(queries_np.coords),
                           jnp.asarray(queries_np.vals), queries_np.dim)
    index = build_index(docs, SeismicConfig(lam=192, beta=12, alpha=0.4,
                                            block_cap=32, summary_nnz=48),
                        list_chunk=32)
    return docs, queries, index


def retrieval_demo(docs, queries, index):
    print("== SeismicServer: batched approximate retrieval ==")
    server = SeismicServer(index, SearchParams(k=10, cut=10,
                                               block_budget=16,
                                               policy="adaptive"),
                           max_batch=128)
    t0 = time.time()
    result = server.search(queries)   # 300 queries -> 3 padded batches
    dt = time.time() - t0
    _, exact_ids = exact_search(docs, queries, 10)
    rec = np.mean([recall_at_k(result.ids[q], np.asarray(exact_ids[q]))
                   for q in range(queries.n)])
    print(f"   300 queries in {dt*1000:.0f} ms "
          f"({dt/300*1e6:.0f} us/query CPU-JAX)  recall@10={rec:.3f}  "
          f"mean docs evaluated={result.docs_evaluated.mean():.0f}")


def async_demo(queries, index):
    """Submit per-request traffic with dispatch deadlines; print the
    occupancy / latency / cache telemetry the server exports."""
    print("== AsyncSeismicServer: deadline micro-batching ==")
    server = AsyncSeismicServer(
        index, SearchParams(k=10, cut=10, block_budget=16,
                            policy="adaptive"),
        max_batch=32, query_nnz=queries.nnz_max, deadline_s=0.01,
        queue_bound=512, admission="reject", cache_size=512)
    coords = np.asarray(queries.coords)
    vals = np.asarray(queries.vals)
    rng = np.random.default_rng(0)
    n_req = 2 * queries.n                 # every query twice: cache hits
    with server:
        futs = []
        t0 = time.time()
        for i in range(n_req):
            q = i % queries.n
            futs.append(server.submit(coords[q], vals[q],
                                      deadline_s=0.01))
            time.sleep(float(rng.exponential(2e-4)))   # ~5k qps offered
        for f in futs:
            f.wait()
        dt = time.time() - t0
    tel = server.telemetry_export()
    lat = tel["latency_s"]["request_e2e"]
    done = sum(f.status == "done" for f in futs)
    print(f"   {done}/{n_req} requests in {dt*1000:.0f} ms "
          f"({done/dt:.0f} qps)")
    print(f"   launches={tel['batch']['launches']}  "
          f"mean occupancy={tel['batch']['mean_occupancy']:.1f}/32  "
          f"max queue depth={tel['queue']['depth_max']}")
    print(f"   latency p50={lat['p50']*1e3:.1f}ms "
          f"p95={lat['p95']*1e3:.1f}ms p99={lat['p99']*1e3:.1f}ms")
    print(f"   cache hit-rate={tel['cache']['hit_rate']:.2f} "
          f"({tel['cache']['hits']} hits)")


def replica_demo(docs, queries, index):
    """Replica-parallel serving behind the one request queue: a
    mirrored fleet with one deliberately slow replica (the stage-timing
    balancer steers load away from it), then the same corpus split over
    doc shards with per-shard top-k merged under the pad-row mask."""
    from repro.core.distributed import build_sharded_index
    from repro.serve import ReplicaSeismicServer

    print("== ReplicaSeismicServer: replica-parallel serving ==")
    p = SearchParams(k=10, cut=10, block_budget=16, policy="adaptive")
    coords = np.asarray(queries.coords)
    vals = np.asarray(queries.vals)
    server = ReplicaSeismicServer(
        index, p, n_replicas=3, mode="mirror",
        replica_delay_s=[0.012, 0.003, 0.003],   # replica 0 is 4x slower
        max_batch=16, query_nnz=queries.nnz_max, deadline_s=0.004,
        queue_bound=1024, cache_size=0, coalesce=False)
    with server:
        futs = []
        for i in range(240):
            futs.append(server.submit(coords[i % queries.n],
                                      vals[i % queries.n]))
            time.sleep(0.001)
        for f in futs:
            f.wait()
    snap = server.balancer.snapshot()
    print("   mirror x3, replica 0 slowed 4x:")
    print("   dispatch share = "
          + str([round(s, 2) for s in snap["dispatch_share"]])
          + "  cost EWMA ms = "
          + str([round(c * 1e3, 1) for c in snap["cost_ewma_s"]]))

    stacked = build_sharded_index(docs, index.config, n_shards=4,
                                  list_chunk=32)
    sharded = ReplicaSeismicServer(
        stacked, p, mode="shard", max_batch=16,
        query_nnz=queries.nnz_max, deadline_s=0.004, cache_size=0)
    sub = queries[:64]
    with sharded:
        futs = [sharded.submit(coords[i], vals[i]) for i in range(64)]
        ids = np.stack([f.result(30.0).ids for f in futs])
    _, exact_ids = exact_search(docs, sub, 10)
    rec = np.mean([recall_at_k(ids[q], np.asarray(exact_ids[q]))
                   for q in range(64)])
    print(f"   shard x4: 64 queries served over 4 doc shards, "
          f"merged recall@10={rec:.3f}")


def observability_demo(queries, index):
    """Serve traced traffic with a live metrics endpoint: scrape the
    Prometheus exposition over HTTP, print a snapshot table and the
    slowest request span trees, and save one Chrome trace."""
    import json
    import urllib.request

    from repro.obs import Observability, start_exporter
    from repro.obs.report import slowest_traces_table, snapshot_table

    print("== Observability: tracing + metrics endpoint ==")
    obs = Observability.create(stage_sample_every=4)   # demo: lots of detail
    server = AsyncSeismicServer(
        index, SearchParams(k=10, cut=10, block_budget=16,
                            policy="adaptive"),
        max_batch=32, query_nnz=queries.nnz_max, deadline_s=0.005,
        cache_size=128, obs=obs)
    coords = np.asarray(queries.coords)
    vals = np.asarray(queries.vals)
    with server, start_exporter(obs.registry, obs.tracer) as exporter:
        futs = [server.submit(coords[i % queries.n], vals[i % queries.n])
                for i in range(128)]
        for f in futs:
            f.wait()
        with urllib.request.urlopen(exporter.url + "/metrics") as r:
            metrics = r.read().decode()
        with urllib.request.urlopen(exporter.url + "/traces") as r:
            chrome = json.load(r)
    print(f"   scraped {exporter.url}/metrics "
          f"({len(metrics.splitlines())} lines); excerpt:")
    for line in metrics.splitlines():
        if line.startswith(("seismic_cache_hit_rate",
                            "seismic_docs_evaluated_mean",
                            "seismic_stage_modeled_bytes_per_query")):
            print("     " + line)
    print("   -- metric snapshot (excerpt) --")
    snap = {k: v for k, v in obs.registry.snapshot().items()
            if k in ("seismic_latency_seconds", "seismic_events_total")}
    for line in snapshot_table(snap, max_rows=12).splitlines():
        print("     " + line)
    print("   -- slowest traced requests --")
    for line in slowest_traces_table(chrome, n=3).splitlines():
        print("     " + line)
    path = "/tmp/seismic_trace.json"
    with open(path, "w", encoding="utf-8") as f:
        json.dump(chrome, f)
    print(f"   Chrome trace ({len(chrome['traceEvents'])} events) -> "
          f"{path} (load in Perfetto / chrome://tracing)")


def tuned_demo(docs, queries, index):
    """Tune an operating point for a recall target on a held-out query
    sample, persist it ON the index, and serve with params resolved
    from the artifact instead of hand-picked knobs."""
    print("== TunedPolicy: autotuned operating point ==")
    index = build_doc_graph(index, degree=8, batch=256)   # refine tier
    held_out, rest = queries[:64], queries[64:]
    _, eids = exact_search(docs, held_out, 10)
    # small coupled grid: block budget down vs refine rounds up
    grid = [SearchParams(k=10, cut=10, block_budget=b, policy="budget",
                         graph_degree=d, refine_rounds=r)
            for b in (4, 8, 16) for d, r in ((0, 0), (8, 1))]
    index = tune_and_attach(index, held_out, np.asarray(eids),
                            targets=[0.9], grid=grid)
    pol = index.tuned[0]
    print(f"   tuned@{pol.target}: block_budget={pol.block_budget} "
          f"refine_rounds={pol.refine_rounds} "
          f"(measured recall={pol.measured_recall:.3f}, "
          f"{pol.measured_cost:.0f} docs/query)")
    params = SearchParams.from_tuned(index, target=0.9)
    server = SeismicServer(index, params, max_batch=128)  # validates
    result = server.search(rest)
    _, exact_ids = exact_search(docs, rest, 10)
    rec = np.mean([recall_at_k(result.ids[q], np.asarray(exact_ids[q]))
                   for q in range(rest.n)])
    print(f"   served {rest.n} fresh queries at recall@10={rec:.3f}, "
          f"mean docs evaluated={result.docs_evaluated.mean():.0f}")


def quality_demo(docs, queries, index):
    """The quality plane: serve a tuned operating point with a shadow
    auditor sampling live traffic, print the live-recall / loss-funnel
    report, and poke the /quality.json + /healthz endpoints."""
    import json
    import urllib.request

    from repro.obs import (Observability, ShadowAuditor, sample_stats,
                           start_exporter)
    from repro.obs.report import funnel_table

    print("== Quality plane: shadow-oracle recall auditing ==")
    held_out = queries[:64]
    _, eids = exact_search(docs, held_out, 10)
    grid = [SearchParams(k=10, cut=10, block_budget=b, policy="budget")
            for b in (4, 8, 16)]
    index = tune_and_attach(index, held_out, np.asarray(eids),
                            targets=[0.9], grid=grid)
    params = SearchParams.from_tuned(index, target=0.9)
    coords = np.asarray(queries.coords)
    vals = np.asarray(queries.vals)
    obs = Observability.create(stage_sample_every=0)
    # target auto-resolves from the TunedPolicy matching `params`;
    # the reference enables the query-drift gauges
    obs.auditor = ShadowAuditor(
        index, params, obs.registry, audit_sample_every=4,
        queue_bound=256,
        reference=sample_stats(np.asarray(held_out.coords),
                               np.asarray(held_out.vals), index.dim))
    server = AsyncSeismicServer(
        index, params, max_batch=32, query_nnz=queries.nnz_max,
        deadline_s=0.005, cache_size=0, obs=obs)
    with server, obs.auditor:
        futs = [server.submit(coords[i % queries.n],
                              vals[i % queries.n]) for i in range(256)]
        for f in futs:
            f.wait()
        obs.auditor.drain()          # let the worker catch up
        with start_exporter(obs.registry, obs.tracer,
                            quality=obs.auditor.snapshot) as exp:
            with urllib.request.urlopen(exp.url + "/healthz") as r:
                health = json.load(r)
            with urllib.request.urlopen(exp.url + "/quality.json") as r:
                snap = json.load(r)
    print(f"   GET /healthz -> {health}")
    print(f"   GET /quality.json (every 4th of {snap['served']} "
          f"served requests audited):")
    for line in funnel_table(snap).splitlines():
        print("     " + line)


def decode_demo():
    print("== LMDecoder: KV-cache batched generation ==")
    bundle = get_bundle("gemma3-27b")          # reduced: dual-cache path
    cfg = bundle.reduced
    params = bundle.init(jax.random.PRNGKey(0), cfg, {})
    dec = LMDecoder(params, cfg, batch=4, max_seq=64)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (4, 8))
    t0 = time.time()
    out = dec.generate(prompts.astype(np.int32), n_steps=24, greedy=True)
    print(f"   generated {out.shape} tokens in {time.time()-t0:.1f}s")
    print("   sample:", out[0].tolist())


if __name__ == "__main__":
    docs, queries, index = build_demo_index()
    retrieval_demo(docs, queries, index)
    async_demo(queries, index)
    replica_demo(docs, queries, index)
    observability_demo(queries, index)
    tuned_demo(docs, queries, index)
    quality_demo(docs, queries, index)
    decode_demo()
