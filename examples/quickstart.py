"""Quickstart: build a Seismic index over a synthetic SPLADE-like
collection and run approximate retrieval.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import SeismicConfig, SearchParams, build_index, search_batch
from repro.core.baselines import exact_search
from repro.core.oracle import recall_at_k
from repro.data import SyntheticSparseConfig, make_collection
from repro.sparse.ops import PaddedSparse


def main():
    print("== generating synthetic learned-sparse collection ==")
    cfg = SyntheticSparseConfig(dim=2048, n_docs=8192, n_queries=32,
                                doc_nnz=96, query_nnz=32)
    docs_np, queries_np, _ = make_collection(cfg)
    docs = PaddedSparse(jnp.asarray(docs_np.coords),
                        jnp.asarray(docs_np.vals), docs_np.dim)
    queries = PaddedSparse(jnp.asarray(queries_np.coords),
                           jnp.asarray(queries_np.vals), queries_np.dim)

    print("== building Seismic index (Algorithm 1) ==")
    icfg = SeismicConfig(lam=192, beta=12, alpha=0.4, block_cap=32,
                         summary_nnz=48)
    t0 = time.time()
    index = build_index(docs, icfg, list_chunk=32)
    jax.block_until_ready(index.sum_q)
    print(f"   built in {time.time() - t0:.1f}s; "
          f"size = {index.nbytes()['total'] / 2**20:.1f} MiB")

    print("== exact ground truth ==")
    _, exact_ids = exact_search(docs, queries, 10)

    print("== Seismic search (Algorithm 2, batched two-phase) ==")
    for budget in (8, 16, 32):
        p = SearchParams(k=10, cut=10, block_budget=budget,
                         heap_factor=0.9, policy="adaptive")
        scores, ids, evaluated = search_batch(index, queries, p)
        rec = np.mean([recall_at_k(np.asarray(ids[q]),
                                   np.asarray(exact_ids[q]))
                       for q in range(queries.n)])
        print(f"   budget={budget:3d}  recall@10={rec:.3f}  "
              f"docs evaluated={int(np.asarray(evaluated).mean())} "
              f"of {docs.n} ({100*np.asarray(evaluated).mean()/docs.n:.2f}%)")


if __name__ == "__main__":
    main()
