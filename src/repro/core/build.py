"""Seismic index construction (Algorithm 1), jit-compiled.

Pipeline per coordinate i (one inverted list):
  1. static pruning  — keep the lam docs with the largest x_i (§5.1)
  2. geometric blocking — shallow K-Means: sample beta member docs as
     representatives, assign every member to its max-inner-product
     representative (§5.2, [Chierichetti et al. 07])
  3. physical blocks — contiguous runs after the cluster permutation,
     split at ``block_cap`` boundaries
  4. summaries — coordinate-wise max per block (Eq. 2), alpha-mass
     pruned (Def. 3.1), 8-bit quantized (§5.3)
  5. superblocks (cfg.superblock_fanout > 0) — BMP-style coarse tier:
     every ``fanout`` consecutive physical blocks get one summary that
     coordinate-wise dominates its children (round-up requantized), so
     the router can prune whole superblocks before touching per-block
     summaries

TPU adaptation: assignment inner products are computed either by
gathers against densified representatives (``cluster_mode="gather"``,
cheap on CPU) or by scatter-to-dense + one MXU matmul per list
(``cluster_mode="matmul"``, the TPU-native path). Lists are processed
in ``lax.map`` chunks so peak memory stays at
``chunk * beta * dim`` floats.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.types import SeismicConfig, SeismicIndex
from repro.sparse.ops import PaddedSparse, alpha_mass_subvector
from repro.sparse.quant import dequantize_u8, quantize_u8, quantize_u8_ceil


def _sorted_postings(docs: PaddedSparse):
    """Flatten (coord, val, doc) triples and sort by (coord asc, val desc)."""
    n, nnz = docs.coords.shape
    flat_c = docs.coords.reshape(-1)
    flat_v = docs.vals.reshape(-1).astype(jnp.float32)
    flat_d = jnp.repeat(jnp.arange(n, dtype=jnp.int32), nnz)
    # invalid (padding) entries sort to the very end
    flat_c = jnp.where(flat_v > 0, flat_c, docs.dim)
    order = jnp.lexsort((-flat_v, flat_c))
    return flat_c[order], flat_v[order], flat_d[order]


def _prune_list(i, sorted_c, sorted_v, sorted_d, starts, counts, lam, n_docs):
    """Top-lam postings of coordinate i out of the global sorted triples."""
    start = starts[i]
    cnt = jnp.minimum(counts[i], lam)
    idx = start + jnp.arange(lam)
    valid = jnp.arange(lam) < cnt
    docs = jnp.where(valid, jnp.take(sorted_d, idx, mode="clip"), n_docs)
    vals = jnp.where(valid, jnp.take(sorted_v, idx, mode="clip"), 0.0)
    return docs.astype(jnp.int32), vals, cnt.astype(jnp.int32)


def _assign_clusters(key, docs, vals, cnt, fwd, cfg: SeismicConfig):
    """Shallow K-Means over one pruned list.

    Representatives are ``beta`` uniformly sampled members; each member
    goes to the representative maximizing <x, mu> (§5.2).
    """
    lam, beta, d = cfg.lam, cfg.beta, fwd.dim
    pos = jax.random.randint(key, (beta,), 0, jnp.maximum(cnt, 1))
    rep_ids = jnp.take(docs, pos, mode="clip")                     # [beta]
    rep_c = jnp.take(fwd.coords, rep_ids, axis=0, mode="clip")     # [beta, nnz]
    rep_v = jnp.take(fwd.vals, rep_ids, axis=0,
                     mode="clip").astype(jnp.float32)
    # densify representatives: [beta, d]
    rep_dense = jnp.zeros((beta, d), jnp.float32)
    rep_dense = rep_dense.at[jnp.arange(beta)[:, None], rep_c].add(rep_v)

    doc_c = jnp.take(fwd.coords, docs, axis=0, mode="clip")        # [lam, nnz]
    doc_v = jnp.take(fwd.vals, docs, axis=0,
                     mode="clip").astype(jnp.float32)
    if cfg.cluster_mode == "matmul":
        # TPU-native: densify members tile-by-tile and use the MXU.
        doc_dense = jnp.zeros((lam, d), jnp.float32)
        doc_dense = doc_dense.at[jnp.arange(lam)[:, None], doc_c].add(doc_v)
        ips = doc_dense @ rep_dense.T                              # [lam, beta]
    else:
        # gather path: <x, mu> = sum_j mu[x.coords_j] * x.vals_j
        gathered = rep_dense[:, doc_c]                             # [beta, lam, nnz]
        ips = jnp.einsum("bln,ln->lb", gathered, doc_v)
    assign = jnp.argmax(ips, axis=-1).astype(jnp.int32)            # [lam]
    # padding entries sort last
    assign = jnp.where(jnp.arange(lam) < cnt, assign, beta)
    return assign


def _physical_blocks(assign, cnt, cfg: SeismicConfig):
    """Stable-sort by cluster, then split runs at block_cap boundaries."""
    lam, nb = cfg.lam, cfg.n_blocks
    perm = jnp.argsort(assign, stable=True)
    sorted_assign = assign[perm]
    pos = jnp.arange(lam)
    # start-of-cluster flags
    prev = jnp.concatenate([jnp.array([-1], sorted_assign.dtype),
                            sorted_assign[:-1]])
    new_cluster = sorted_assign != prev
    # position within cluster
    cluster_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(new_cluster, pos, 0))
    within = pos - cluster_start
    new_block = new_cluster | (within % cfg.block_cap == 0)
    # only positions holding real entries form blocks
    new_block = new_block & (pos < cnt)
    block_id = jnp.cumsum(new_block.astype(jnp.int32)) - 1          # [-1 .. nb)
    block_id = jnp.where(pos < cnt, block_id, nb)                   # pad -> sentinel
    blk_len = jnp.bincount(jnp.clip(block_id, 0, nb), length=nb + 1)[:nb]
    blk_off = jnp.concatenate([jnp.zeros(1, jnp.int32),
                               jnp.cumsum(blk_len)[:-1].astype(jnp.int32)])
    return perm, block_id.astype(jnp.int32), blk_off.astype(jnp.int32), \
        blk_len.astype(jnp.int32)


def _summaries(docs_perm, block_id, fwd, cfg: SeismicConfig):
    """Per-block summary (Eq. 2 coordinate-wise max, or centroid under
    the §6 generalized sketch) -> alpha-mass -> u8 quant."""
    nb, d, s = cfg.n_blocks, fwd.dim, cfg.summary_nnz
    doc_c = jnp.take(fwd.coords, docs_perm, axis=0, mode="clip")    # [lam, nnz]
    doc_v = jnp.take(fwd.vals, docs_perm, axis=0,
                     mode="clip").astype(jnp.float32)
    doc_v = jnp.where(docs_perm[:, None] < fwd.n, doc_v, 0.0)
    dense = jnp.zeros((nb + 1, d), jnp.float32)
    bid = jnp.clip(block_id, 0, nb)
    if cfg.summary_kind == "centroid":
        dense = dense.at[bid[:, None], doc_c].add(doc_v)
        cnt = jnp.zeros((nb + 1,), jnp.float32).at[bid].add(
            (docs_perm < fwd.n).astype(jnp.float32))
        dense = dense / jnp.maximum(cnt, 1.0)[:, None]
    else:  # "max": the conservative Eq. 2 bound
        dense = dense.at[bid[:, None], doc_c].max(doc_v)
    dense = dense[:nb]
    sc, sv = jax.vmap(
        lambda row: alpha_mass_subvector(jnp.arange(d, dtype=jnp.int32),
                                         row, cfg.alpha, s))(dense)
    q, scale, zero = quantize_u8(sv)
    return sc, q, scale, zero


def _superblock_summaries(sc, q, scale, zero, dim: int, cfg: SeismicConfig):
    """Coarse tier over one list's quantized block summaries.

    Groups blocks [0..nb) into ``n_superblocks`` fixed-fanout groups
    (block j -> superblock j // fanout) and takes the coordinate-wise
    max of the DEQUANTIZED child summaries, so the superblock score
    upper-bounds every child score for any nonnegative query — the BMP
    block-max property one level up. Size ``fanout * summary_nnz``
    never truncates the union of child supports, and the round-up
    requantization (:func:`quantize_u8_ceil`) keeps the bound through
    the second quantization (up to float rounding).
    """
    nb, s = q.shape
    f, ns = cfg.superblock_fanout, cfg.n_superblocks
    s2 = min(cfg.superblock_nnz, dim)   # top_k width can't exceed dim
    v = dequantize_u8(q, scale, zero)                       # [nb, S]
    sup_id = jnp.arange(nb, dtype=jnp.int32) // f           # [nb]
    dense = jnp.zeros((ns, dim), jnp.float32)
    dense = dense.at[sup_id[:, None], sc].max(v)            # scatter-max
    vals, coords = jax.lax.top_k(dense, s2)                 # [ns, S2]
    coords = jnp.where(vals > 0, coords, 0)
    q2, scale2, zero2 = quantize_u8_ceil(vals)
    return coords.astype(jnp.int32), q2, scale2, zero2


def list_block_arrays(key_i, docs, vals, cnt, fwd, cfg: SeismicConfig):
    """Cluster + block + summarize ONE pruned list: the per-list half of
    Algorithm 1 after static pruning.

    ``docs``/``vals`` are the pruned postings ([lam], value-descending,
    value ties broken by ascending doc id, sentinel ``fwd.n`` padding)
    and ``key_i`` the per-list PRNG key
    (``fold_in(PRNGKey(cfg.seed), coord)``). This is the seam
    :mod:`repro.core.mutate` reuses for major (per-list) compaction:
    feeding it the merged base+tail members of a list reproduces the
    fresh-build arrays bit-exactly, because ``build_index`` routes
    through the identical call.
    """
    if cfg.blocking == "fixed":
        # Fig. 5 baseline: impact-ordered fixed-size chunks (single
        # cluster; the physical block splitter cuts it at block_cap)
        assign = jnp.where(jnp.arange(cfg.lam) < cnt, 0, cfg.beta)
        assign = assign.astype(jnp.int32)
    else:
        assign = _assign_clusters(key_i, docs, vals, cnt, fwd, cfg)
    perm, block_id, blk_off, blk_len = _physical_blocks(assign, cnt, cfg)
    docs_perm = docs[perm]
    vals_perm = vals[perm]
    sc, q, scale, zero = _summaries(docs_perm, block_id, fwd, cfg)
    out = (docs_perm, vals_perm, cnt, blk_off, blk_len, sc, q, scale, zero)
    if cfg.superblock_fanout > 0:
        out = out + _superblock_summaries(sc, q, scale, zero, fwd.dim, cfg)
    return out


def _build_one_list(i, key, sorted_c, sorted_v, sorted_d, starts, counts,
                    fwd, cfg: SeismicConfig):
    docs, vals, cnt = _prune_list(i, sorted_c, sorted_v, sorted_d,
                                  starts, counts, cfg.lam, fwd.n)
    return list_block_arrays(jax.random.fold_in(key, i), docs, vals, cnt,
                             fwd, cfg)


def block_summaries(docs_perm, block_id, fwd, cfg: SeismicConfig):
    """Public seam over the per-block summary construction (Eq. 2 max ->
    alpha-mass -> u8): compaction computes summaries for freshly
    appended tail blocks through the SAME code path as the builder, so
    an appended block's summary is bit-identical to what a fresh build
    would give the same member set."""
    return _summaries(docs_perm, block_id, fwd, cfg)


def merge_superblock_summary(sup_coords, sup_q, sup_scale, sup_zero,
                             child_sc, child_q, child_scale, child_zero,
                             dim: int, cfg: SeismicConfig):
    """Monotone update of ONE superblock summary with new child blocks.

    Takes the coordinate-wise max of the DEQUANTIZED old superblock
    summary ([S2] + scalars) and the new child block summaries
    ([m, S] + [m]), then round-up requantizes (quantize_u8_ceil). The
    result upper-bounds every child of the group: old children through
    the old superblock (itself an upper bound), new children directly —
    so summaries only ever loosen monotonically under mutation and the
    hierarchical router's pruning stays safe without rebuilding the
    tier.
    """
    s2 = sup_q.shape[-1]
    dense = jnp.zeros((dim,), jnp.float32)
    dense = dense.at[sup_coords].max(
        dequantize_u8(sup_q[None], sup_scale[None], sup_zero[None])[0])
    cv = dequantize_u8(child_q, child_scale, child_zero)       # [m, S]
    dense = dense.at[child_sc.reshape(-1)].max(cv.reshape(-1))
    vals, coords = jax.lax.top_k(dense, s2)
    coords = jnp.where(vals > 0, coords, 0)
    q2, scale2, zero2 = quantize_u8_ceil(vals)
    return coords.astype(jnp.int32), q2, scale2, zero2


def suggest_fanout(n_blocks_stats, *, max_fanout: int = 8) -> int:
    """Adaptive superblock fanout from per-list live-block counts.

    ``n_blocks_stats`` is an array of live (non-empty) physical blocks
    per inverted list — ``(index.block_len > 0).sum(-1)`` for a built
    index, or a modeled estimate at config time. Two-tier routing over
    a list with ``nb`` live blocks costs ``~nb/f`` coarse dots plus
    ``~f`` child dots per kept superblock, so the minimizing fanout
    scales like ``sqrt(nb)``. Lists with <= 2 live blocks pay pure
    superblock overhead (the coarse tier scores as many summaries as
    the flat route would), so collections dominated by them get 0
    (keep flat routing).
    """
    stats = np.asarray(n_blocks_stats, np.float64).reshape(-1)
    live = stats[stats > 0]
    if live.size == 0:
        return 0
    mean = float(live.mean())
    if mean <= 2.0:
        return 0
    return int(np.clip(round(math.sqrt(mean)), 2, max_fanout))


def live_blocks(index: SeismicIndex) -> np.ndarray:
    """Per-list live-block counts of a built index (the
    :func:`suggest_fanout` statistic)."""
    return np.asarray((index.block_len > 0).sum(axis=-1))


class DocBlockMap(NamedTuple):
    """CSR doc -> (list, block) membership over a built index.

    ``lists[indptr[d]:indptr[d+1]]`` / ``blocks[...]`` enumerate every
    (inverted list, physical block) pair holding doc ``d`` after static
    pruning — the structural ground truth the quality plane's loss
    funnel needs to decide whether a missed doc was ever reachable
    through the routed blocks (``repro.obs.quality``).
    """
    indptr: np.ndarray    # i64 [n_docs + 1]
    lists: np.ndarray     # i32 [n_memberships]
    blocks: np.ndarray    # i32 [n_memberships]


def doc_block_map(index: SeismicIndex) -> DocBlockMap:
    """Invert ``list_docs`` into per-doc block memberships (host-side).

    Physical blocks are contiguous position runs per list
    (``block_off`` is the cumsum of ``block_len``), so position ``p``'s
    block is the first block whose end offset exceeds ``p``.
    """
    docs = np.asarray(index.list_docs)                  # [L, lam]
    lens = np.asarray(index.list_len)                   # [L]
    ends = np.asarray(index.block_off) + np.asarray(index.block_len)
    n_docs = index.n_docs
    pos = np.arange(docs.shape[1])
    live = pos[None, :] < lens[:, None]
    live &= docs < n_docs                               # drop pad sentinels
    list_ids, positions = np.nonzero(live)
    block_ids = np.empty(list_ids.size, np.int32)
    for i, (ell, p) in enumerate(zip(list_ids, positions)):
        block_ids[i] = np.searchsorted(ends[ell], p, side="right")
    member_docs = docs[list_ids, positions]
    order = np.argsort(member_docs, kind="stable")
    counts = np.bincount(member_docs, minlength=n_docs)
    indptr = np.zeros(n_docs + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return DocBlockMap(indptr, list_ids[order].astype(np.int32),
                       block_ids[order])


@partial(jax.jit, static_argnames=("cfg", "list_chunk"))
def build_index(docs: PaddedSparse, cfg: SeismicConfig = SeismicConfig(),
                *, list_chunk: int = 64) -> SeismicIndex:
    """Algorithm 1 over the whole collection. ``list_chunk`` bounds peak
    memory of the per-list map (chunk * n_blocks * dim floats)."""
    d = docs.dim
    sorted_c, sorted_v, sorted_d = _sorted_postings(docs)
    starts = jnp.searchsorted(sorted_c, jnp.arange(d + 1))
    counts = (starts[1:] - starts[:-1]).astype(jnp.int32)
    starts = starts[:-1].astype(jnp.int32)
    key = jax.random.PRNGKey(cfg.seed)
    fwd32 = docs.astype(jnp.float32)

    def body(i):
        return _build_one_list(i, key, sorted_c, sorted_v, sorted_d,
                               starts, counts, fwd32, cfg)

    outs = jax.lax.map(body, jnp.arange(d), batch_size=min(list_chunk, d))
    (list_docs, list_vals, list_len, blk_off, blk_len,
     sum_coords, sum_q, sum_scale, sum_zero) = outs[:9]
    sup_coords = sup_q = sup_scale = sup_zero = None
    if cfg.superblock_fanout > 0:
        sup_coords, sup_q, sup_scale, sup_zero = outs[9:]

    fwd_scale = fwd_zero = None
    if cfg.fwd_quant:
        # compact forward index: u8 values (per-doc affine) + u16 coords
        q, fwd_scale, fwd_zero = quantize_u8(docs.vals.astype(jnp.float32))
        cdt = jnp.uint16 if docs.dim < 65536 else jnp.int32
        fwd = PaddedSparse(docs.coords.astype(cdt), q, docs.dim)
    else:
        fwd = docs.astype(jnp.dtype(cfg.fwd_dtype))
    return SeismicIndex(
        fwd=fwd, list_docs=list_docs, list_vals=list_vals,
        list_len=list_len, block_off=blk_off, block_len=blk_len,
        sum_coords=sum_coords, sum_q=sum_q, sum_scale=sum_scale,
        sum_zero=sum_zero, fwd_scale=fwd_scale, fwd_zero=fwd_zero,
        sup_coords=sup_coords, sup_q=sup_q, sup_scale=sup_scale,
        sup_zero=sup_zero, config=cfg)
