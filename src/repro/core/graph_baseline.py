"""IP-NSW graph baseline (the GrassRMA / PyANN role, §7.1).

The BigANN-winning baselines are greedy best-first graph walks
(IP-HNSW, Morozov & Babenko '18). Their per-hop data dependence is
hostile to batched TPU execution (DESIGN.md §2), so — like the heap
oracle — the baseline lives on the host in numpy and is compared on the
hardware-independent axis the paper itself uses: documents evaluated at
a given recall (§7.2.1: PyANN visits ~40,000 docs where Seismic
evaluates 2,198 at 97% on E-SPLADE).

Construction: exact top-M inner-product neighbors per node (feasible at
benchmark scale; real systems approximate this) + the standard reverse-
edge augmentation. Search: best-first beam of width ``ef`` from a
high-norm entry point.
"""
from __future__ import annotations

import heapq

import numpy as np


class IPNSWIndex:
    def __init__(self, doc_coords: np.ndarray, doc_vals: np.ndarray,
                 dim: int, m: int = 16, *, chunk: int = 1024):
        self.coords = doc_coords
        self.vals = doc_vals.astype(np.float32)
        self.dim = dim
        n = doc_coords.shape[0]
        dense = np.zeros((n, dim), np.float32)
        rows = np.arange(n)[:, None]
        np.add.at(dense, (rows, doc_coords), doc_vals)
        self._dense = dense
        # exact top-M IP neighbors, blocked
        nbrs = np.zeros((n, m), np.int64)
        for s in range(0, n, chunk):
            sc = dense[s:s + chunk] @ dense.T            # [c, N]
            for i in range(sc.shape[0]):
                sc[i, s + i] = -np.inf                   # no self edge
            nbrs[s:s + chunk] = np.argpartition(
                -sc, m, axis=1)[:, :m]
        # reverse-edge augmentation (cap 2M total per node) + small-world
        # long-range links (the "SW" in NSW: without them, exact-IP
        # neighborhoods fragment into topic clusters and the walk traps)
        rng = np.random.default_rng(0)
        adj: list[list[int]] = [list(row) for row in nbrs]
        for u in range(n):
            for v in nbrs[u]:
                if len(adj[v]) < 2 * m:
                    adj[v].append(u)
            adj[u].extend(rng.integers(0, n, 4).tolist())
        self.adj = [np.unique(a) for a in adj]
        order = np.argsort(-np.linalg.norm(dense, axis=1))
        self.entries = [int(order[0])] + rng.choice(
            n, 3, replace=False).tolist()

    def search(self, q_coords: np.ndarray, q_vals: np.ndarray, k: int,
               ef: int):
        """Greedy best-first beam. Returns (scores, ids, docs_evaluated)."""
        q = np.zeros(self.dim, np.float32)
        np.add.at(q, q_coords, q_vals.astype(np.float32))

        def score(v: int) -> float:
            return float(self._dense[v] @ q)

        visited = set(self.entries)
        cand: list[tuple[float, int]] = []                    # max-heap
        best: list[tuple[float, int]] = []                    # min-heap
        for e in self.entries:
            se = score(e)
            heapq.heappush(cand, (-se, e))
            heapq.heappush(best, (se, e))
        evaluated = len(self.entries)
        while cand:
            neg, u = heapq.heappop(cand)
            if len(best) >= ef and -neg < best[0][0]:
                break
            for v in self.adj[u]:
                v = int(v)
                if v in visited:
                    continue
                visited.add(v)
                sv = score(v)
                evaluated += 1
                if len(best) < ef or sv > best[0][0]:
                    heapq.heappush(cand, (-sv, v))
                    heapq.heappush(best, (sv, v))
                    if len(best) > ef:
                        heapq.heappop(best)
        top = sorted(best, reverse=True)[:k]
        return (np.array([s for s, _ in top]),
                np.array([v for _, v in top], np.int64), evaluated)
