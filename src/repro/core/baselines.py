"""Baseline retrieval systems the paper compares against (§7.1).

* ``exact_search``        — brute-force MIPS over the forward index
                            (PISA's role: the exact, rank-safe
                            reference; also the recall ground truth).
* ``IvfIndex``            — SparseIvf [Bruch et al. '23]: documents
                            clustered once globally; the query probes
                            the ``nprobe`` closest centroids and
                            exactly scores every doc in them.
* ``impact_search``       — IOQP-style impact-ordered evaluation: each
                            probed coordinate contributes its top
                            ``rho``-fraction of postings; partial
                            scores accumulate (score-at-a-time) and the
                            top-k of the accumulator is returned.

Graph baselines (GrassRMA / PyANN) are greedy best-first graph walks
whose per-hop data dependence does not map to a batched TPU execution
model; ``graph_baseline.IPNSWIndex`` implements them as a host-side
numpy oracle compared on the paper's own docs-evaluated axis (§7.2.1).
See DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.sparse.ops import PaddedSparse, densify, densify_one

NEG = -jnp.inf


# --------------------------------------------------------------------------
# Exact search (PISA reference point)
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k",))
def exact_search(docs: PaddedSparse, queries: PaddedSparse, k: int):
    """Brute-force MIPS, batched: for each query scores every doc via the
    padded gather-dot. Returns (scores [Q,k], ids [Q,k])."""

    def one(qc, qv):
        q = densify_one(qc, qv.astype(jnp.float32), docs.dim)
        s = (q[docs.coords] * docs.vals.astype(jnp.float32)).sum(-1)
        return jax.lax.top_k(s, k)

    return jax.vmap(one)(queries.coords, queries.vals)


# --------------------------------------------------------------------------
# SparseIvf-style IVF
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class IvfIndex:
    fwd: PaddedSparse
    centroids: jax.Array      # [C, d] dense f32
    member_docs: jax.Array    # int32 [C, cap] (N = pad)
    member_len: jax.Array     # int32 [C]
    cap: int = dataclasses.field(metadata=dict(static=True), default=0)


@partial(jax.jit, static_argnames=("n_clusters", "cap", "iters"))
def build_ivf(docs: PaddedSparse, n_clusters: int, cap: int,
              iters: int = 3, seed: int = 0) -> IvfIndex:
    """K-means (Lloyd, dense centroids) with max-IP assignment, matching
    the spherical-ish clustering SparseIvf uses; capacity-padded members."""
    n = docs.n
    dense = densify(docs)                                   # [N, d]
    key = jax.random.PRNGKey(seed)
    init = jax.random.choice(key, n, (n_clusters,), replace=False)
    cent = dense[init]

    def step(cent, _):
        ips = dense @ cent.T                                # [N, C]
        assign = jnp.argmax(ips, axis=-1)
        one_hot = jax.nn.one_hot(assign, n_clusters, dtype=jnp.float32)
        sums = one_hot.T @ dense
        cnt = one_hot.sum(0)[:, None]
        new = jnp.where(cnt > 0, sums / jnp.maximum(cnt, 1), cent)
        return new, assign

    cent, assigns = jax.lax.scan(step, cent, None, length=iters)
    assign = assigns[-1]
    # membership lists, capacity-capped
    order = jnp.argsort(assign, stable=True)
    sorted_assign = assign[order]
    start = jnp.searchsorted(sorted_assign, jnp.arange(n_clusters))
    ln = jnp.searchsorted(sorted_assign, jnp.arange(n_clusters) + 1) - start
    idx = start[:, None] + jnp.arange(cap)[None, :]
    member = jnp.where(jnp.arange(cap)[None, :] < jnp.minimum(ln, cap)[:, None],
                       jnp.take(order, jnp.clip(idx, 0, n - 1)), n)
    return IvfIndex(fwd=docs, centroids=cent, member_docs=member.astype(jnp.int32),
                    member_len=ln.astype(jnp.int32), cap=cap)


@partial(jax.jit, static_argnames=("k", "nprobe"))
def ivf_search(index: IvfIndex, queries: PaddedSparse, k: int, nprobe: int):
    """Probe the nprobe max-IP centroids, exactly score their members."""
    fwd = index.fwd

    def one(qc, qv):
        q = densify_one(qc, qv.astype(jnp.float32), fwd.dim)
        cs = index.centroids @ q                            # [C]
        _, probe = jax.lax.top_k(cs, nprobe)
        cand = index.member_docs[probe].reshape(-1)         # [nprobe*cap]
        c = jnp.take(fwd.coords, cand, axis=0, mode="clip")
        v = jnp.take(fwd.vals, cand, axis=0, mode="clip").astype(jnp.float32)
        s = (q[c] * v).sum(-1)
        s = jnp.where(cand < fwd.n, s, NEG)
        top_s, pos = jax.lax.top_k(s, k)
        ids = jnp.where(jnp.isfinite(top_s), cand[pos], -1)
        return top_s, ids.astype(jnp.int32), (cand < fwd.n).sum()

    return jax.vmap(one)(queries.coords, queries.vals)


# --------------------------------------------------------------------------
# IOQP-style impact-ordered, budgeted score-at-a-time
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "postings_per_list", "n_docs"))
def impact_search(list_docs: jax.Array, list_vals: jax.Array,
                  list_len: jax.Array, n_docs: int,
                  queries: PaddedSparse, k: int, postings_per_list: int):
    """Score-at-a-time over impact-ordered lists with a per-list budget
    (IOQP's `fraction` knob ~ postings_per_list / lam). Partial scores
    q_i * x_i accumulate in a dense [N] accumulator per query.

    Takes the *unblocked* impact-ordered lists from the Seismic index
    (list_docs/list_vals are already value-sorted per coordinate before
    permutation — we re-sort here to be explicit)."""
    lam = list_docs.shape[1]
    b = min(postings_per_list, lam)

    def one(qc, qv):
        acc = jnp.zeros((n_docs + 1,), jnp.float32)
        docs = list_docs[qc]                                # [nnz_q, lam]
        vals = list_vals[qc].astype(jnp.float32)
        # impact order within each list
        order = jnp.argsort(-vals, axis=-1)[:, :b]
        docs_b = jnp.take_along_axis(docs, order, axis=1)
        vals_b = jnp.take_along_axis(vals, order, axis=1)
        contrib = vals_b * qv[:, None].astype(jnp.float32)
        contrib = jnp.where(qv[:, None] > 0, contrib, 0.0)
        acc = acc.at[jnp.clip(docs_b, 0, n_docs)].add(contrib)
        acc = acc[:n_docs]
        return jax.lax.top_k(acc, k)

    return jax.vmap(one)(queries.coords, queries.vals)
