from repro.core.types import SeismicConfig, SeismicIndex
from repro.core.build import build_index
from repro.core.query import SearchParams, search_batch

__all__ = ["SeismicConfig", "SeismicIndex", "build_index", "SearchParams",
           "search_batch"]
