from repro.core.types import SeismicConfig, SeismicIndex
from repro.core.build import build_index, live_blocks, suggest_fanout
from repro.core.mutate import MutableSeismicIndex, make_mutable
from repro.core.query import SearchParams, search_batch

__all__ = ["SeismicConfig", "SeismicIndex", "build_index", "live_blocks",
           "suggest_fanout", "SearchParams", "search_batch",
           "MutableSeismicIndex", "make_mutable"]
