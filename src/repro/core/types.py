"""Seismic index data model (fixed-shape, shardable pytrees).

Layout decisions vs the paper (§5, Fig. 3):

  * Inverted lists are a dense ``[n_coords, lam]`` doc-id matrix,
    *block-permuted*: after geometric clustering each list's entries
    are reordered so blocks occupy contiguous ranges. A block is then
    just ``(offset, length)`` into its list row.
  * Geometric clusters larger than ``block_cap`` are split into
    multiple *physical* blocks (each gets its own summary — strictly
    tighter than one summary for the whole cluster). This bounds the
    query-time gather window to ``block_cap`` and keeps shapes static.
    The physical block axis has size
    ``n_blocks = beta + ceil(lam / block_cap)``.
  * Summaries are alpha-mass subvectors of the coordinate-wise max
    (Eq. 2), stored padded to ``summary_nnz`` entries and 8-bit
    quantized with per-block (scale, zero).
  * The forward index is the PaddedSparse collection itself (paper
    stores fp16; we default to bf16-compatible fp32-on-CPU and cast
    per config).
  * With ``superblock_fanout > 0`` a second, coarser summary tier is
    built (BMP-style superblocks): every ``fanout`` consecutive
    physical blocks share one u8 summary that upper-bounds each child
    block summary for any nonnegative query, letting the router prune
    whole groups before touching tier-1 summaries. See
    ``src/repro/core/README.md`` ("Index layout") for the full array
    map and the routing contract.
"""
from __future__ import annotations

import dataclasses
import math

import jax

from repro.sparse.ops import PaddedSparse


@dataclasses.dataclass(frozen=True)
class SeismicConfig:
    """Indexing hyper-parameters (paper's lambda, beta, alpha)."""

    lam: int = 256            # max inverted-list length (static pruning)
    beta: int = 16            # max geometric clusters per list
    alpha: float = 0.4        # summary alpha-mass fraction
    block_cap: int = 64       # physical block capacity (gather window)
    summary_nnz: int = 64     # padded summary size
    fwd_dtype: str = "float32"   # forward index value dtype
    fwd_quant: bool = False      # compact forward index: u8 values with
    #                              per-doc affine scale + u16 coords when
    #                              dim < 65536 (beyond-paper, §Perf —
    #                              halves scoring-phase HBM traffic)
    cluster_mode: str = "gather"  # "gather" | "matmul" (MXU densified)
    # §6 generalized architecture knobs:
    blocking: str = "geometric"   # "geometric" (shallow K-Means) |
    #                               "fixed" (impact-order chunks, Fig. 5)
    summary_kind: str = "max"     # "max" (Eq. 2 upper bound) |
    #                               "centroid" (mean sketch, §6)
    superblock_fanout: int = 0    # BMP-style coarse summary tier: group
    #                               every `fanout` physical blocks of a
    #                               list into one superblock whose u8
    #                               summary upper-bounds its children
    #                               (0 = no superblock tier built)
    seed: int = 0

    @property
    def n_blocks(self) -> int:
        return self.beta + math.ceil(self.lam / self.block_cap)

    @property
    def n_superblocks(self) -> int:
        """Superblocks per list (0 when the coarse tier is off)."""
        if self.superblock_fanout <= 0:
            return 0
        return math.ceil(self.n_blocks / self.superblock_fanout)

    @property
    def superblock_nnz(self) -> int:
        """Padded superblock summary size: the union of `fanout` child
        supports never exceeds fanout * summary_nnz, so this size is
        lossless (no coordinate of any child is ever dropped — the
        upper-bound guarantee needs that)."""
        return self.superblock_fanout * self.summary_nnz


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SeismicIndex:
    """The built index. All arrays are fixed-shape; ``n_docs`` is the
    sentinel doc id (one past the last real doc)."""

    fwd: PaddedSparse                # forward index  [N, nnz_d]
    list_docs: jax.Array             # int32 [L, lam]  block-permuted doc ids (N = pad)
    list_vals: jax.Array             # fwd value of the list coordinate  [L, lam]
    list_len: jax.Array              # int32 [L]
    block_off: jax.Array             # int32 [L, n_blocks]
    block_len: jax.Array             # int32 [L, n_blocks] (0 = unused)
    sum_coords: jax.Array            # int32 [L, n_blocks, S]
    sum_q: jax.Array                 # uint8 [L, n_blocks, S]
    sum_scale: jax.Array             # f32   [L, n_blocks]
    sum_zero: jax.Array              # f32   [L, n_blocks]
    # compact forward index (fwd_quant=True): per-doc dequant constants
    fwd_scale: jax.Array | None = None   # f32 [N]
    fwd_zero: jax.Array | None = None    # f32 [N]
    # coarse summary tier (superblock_fanout > 0): one u8 summary per
    # group of `fanout` blocks, upper-bounding every child summary
    sup_coords: jax.Array | None = None  # int32 [L, n_super, S2]
    sup_q: jax.Array | None = None       # uint8 [L, n_super, S2]
    sup_scale: jax.Array | None = None   # f32   [L, n_super]
    sup_zero: jax.Array | None = None    # f32   [L, n_super]
    # document kNN graph (repro.graph): per-doc approximate nearest
    # neighbors, score-descending, sentinel n_docs pads missing edges.
    # The refine stage rescores expanded neighbors through the SAME
    # forward plane as the scorer stage (fwd + fwd_scale/fwd_zero), so
    # merged scores stay consistent across stages.
    knn_ids: jax.Array | None = None        # int32 [N, degree]
    # streaming mutation plane (repro.core.mutate): an unblocked tail
    # segment absorbing inserts (scored exactly, no summary pruning;
    # sentinel n_docs marks empty slots) and per-doc delete tombstones
    # masked at candidate level. "frozen blocks + exact tail +
    # tombstones == one logical corpus" is the invariant every stage
    # preserves; both fields are None on an immutable (build-once)
    # index so its pytree structure — and compiled programs — are
    # unchanged.
    tail_ids: jax.Array | None = None       # int32 [tail_cap]
    tombstone: jax.Array | None = None      # bool  [N]
    # tuned operating points (repro.tune): recall-target -> coupled knob
    # set, measured on a held-out sample and persisted with the index.
    # Static metadata like `config` (frozen TunedPolicy dataclasses are
    # hashable), so a re-tune recompiles nothing the arrays share.
    tuned: tuple = dataclasses.field(metadata=dict(static=True),
                                     default=())
    config: SeismicConfig = dataclasses.field(metadata=dict(static=True),
                                              default_factory=SeismicConfig)

    @property
    def dim(self) -> int:
        return self.fwd.dim

    @property
    def n_docs(self) -> int:
        return self.fwd.n

    @property
    def n_lists(self) -> int:
        return self.list_docs.shape[0]

    @property
    def graph_degree(self) -> int:
        """Built kNN-graph degree (0 when no graph is attached)."""
        return 0 if self.knn_ids is None else self.knn_ids.shape[1]

    @property
    def tail_cap(self) -> int:
        """Tail-segment capacity (0 when the index is immutable)."""
        return 0 if self.tail_ids is None else self.tail_ids.shape[0]

    def nbytes(self) -> dict:
        """Index size accounting (Table 2 analog)."""
        fwd = self.fwd.coords.nbytes + self.fwd.vals.nbytes
        inv = (self.list_docs.nbytes + self.list_vals.nbytes
               + self.list_len.nbytes + self.block_off.nbytes
               + self.block_len.nbytes)
        summaries = (self.sum_coords.nbytes + self.sum_q.nbytes
                     + self.sum_scale.nbytes + self.sum_zero.nbytes)
        superblocks = 0
        if self.sup_coords is not None:
            superblocks = (self.sup_coords.nbytes + self.sup_q.nbytes
                           + self.sup_scale.nbytes + self.sup_zero.nbytes)
        graph = 0 if self.knn_ids is None else self.knn_ids.nbytes
        mutation = 0
        if self.tail_ids is not None:
            mutation += self.tail_ids.nbytes
        if self.tombstone is not None:
            mutation += self.tombstone.nbytes
        return dict(forward=fwd, inverted=inv, summaries=summaries,
                    superblocks=superblocks, graph=graph,
                    mutation=mutation,
                    total=(fwd + inv + summaries + superblocks + graph
                           + mutation))
