"""Faithful CPU oracles.

``exact_topk``      — brute-force MIPS (the paper's ground truth for
                      its "accuracy" metric).
``algorithm2``      — a line-by-line numpy/heapq implementation of the
                      paper's Algorithm 2 (coordinate-at-a-time, Min-Heap,
                      heap_factor block skipping), run against the SAME
                      index arrays the JAX build produced. Used to
                      cross-validate the batched TPU query path.

Both are deliberately independent of jax on the query path.
"""
from __future__ import annotations

import heapq

import numpy as np


def exact_topk(doc_coords: np.ndarray, doc_vals: np.ndarray, dim: int,
               q_coords: np.ndarray, q_vals: np.ndarray, k: int):
    """Brute force over the padded-sparse collection. Returns (scores, ids)."""
    q = np.zeros(dim, np.float64)
    np.add.at(q, q_coords, q_vals.astype(np.float64))
    scores = (q[doc_coords] * doc_vals).sum(axis=-1)
    ids = np.argsort(-scores, kind="stable")[:k]
    return scores[ids], ids


class NumpyIndexView:
    """Numpy view over a (device) SeismicIndex."""

    def __init__(self, index):
        self.fwd_coords = np.asarray(index.fwd.coords)
        self.fwd_vals = np.asarray(index.fwd.vals, dtype=np.float64)
        self.list_docs = np.asarray(index.list_docs)
        self.list_len = np.asarray(index.list_len)
        self.block_off = np.asarray(index.block_off)
        self.block_len = np.asarray(index.block_len)
        self.sum_coords = np.asarray(index.sum_coords)
        self.sum_q = np.asarray(index.sum_q)
        self.sum_scale = np.asarray(index.sum_scale)
        self.sum_zero = np.asarray(index.sum_zero)
        self.dim = index.dim
        self.n_docs = index.n_docs

    def summary(self, i: int, j: int) -> tuple[np.ndarray, np.ndarray]:
        q = self.sum_q[i, j].astype(np.float64)
        v = np.where(q > 0,
                     (q - 1.0) * self.sum_scale[i, j] + self.sum_zero[i, j],
                     0.0)
        return self.sum_coords[i, j], v


def algorithm2(view: NumpyIndexView, q_coords: np.ndarray, q_vals: np.ndarray,
               k: int, cut: int, heap_factor: float):
    """Paper Algorithm 2, verbatim control flow.

    Returns (scores desc [k], ids [k], stats dict). Duplicated docs
    across lists are skipped on heap insert (set membership), matching
    the effect of the paper's heap (a doc's score is identical each
    time it is fully evaluated).
    """
    q_dense = np.zeros(view.dim, np.float64)
    np.add.at(q_dense, q_coords, q_vals.astype(np.float64))
    order = np.argsort(-q_vals, kind="stable")[:cut]
    probe = [int(q_coords[o]) for o in order if q_vals[o] > 0]

    heap: list[tuple[float, int]] = []   # min-heap of (score, doc)
    in_heap: set[int] = set()
    docs_evaluated = 0
    blocks_scored = 0
    blocks_skipped = 0

    for i in probe:                                   # line 3
        nb = view.block_off.shape[1]
        for j in range(nb):                           # line 4
            ln = int(view.block_len[i, j])
            if ln == 0:
                continue
            sc, sv = view.summary(i, j)
            r = float((q_dense[sc] * sv).sum())       # line 5
            blocks_scored += 1
            if len(heap) == k and r < heap[0][0] / heap_factor:   # line 6
                blocks_skipped += 1
                continue                              # line 7
            off = int(view.block_off[i, j])
            for d in view.list_docs[i, off:off + ln]:  # line 8
                d = int(d)
                if d >= view.n_docs:
                    continue
                docs_evaluated += 1
                p = float((q_dense[view.fwd_coords[d]]
                           * view.fwd_vals[d]).sum())  # line 9
                if d in in_heap:
                    continue
                if len(heap) < k or p > heap[0][0]:    # line 10
                    heapq.heappush(heap, (p, d))       # line 11
                    in_heap.add(d)
                    if len(heap) == k + 1:             # line 12
                        _, popped = heapq.heappop(heap)  # line 13
                        in_heap.discard(popped)

    out = sorted(heap, reverse=True)
    scores = np.array([s for s, _ in out], np.float64)
    ids = np.array([d for _, d in out], np.int64)
    stats = dict(docs_evaluated=docs_evaluated, blocks_scored=blocks_scored,
                 blocks_skipped=blocks_skipped)
    return scores, ids, stats


def recall_at_k(approx_ids: np.ndarray, exact_ids: np.ndarray) -> float:
    """The paper's 'accuracy': |approx ∩ exact| / k.

    Delegates to the shared :func:`repro.obs.quality.recall_at_k`
    (kept here as the historical import path; lazy import so the core
    oracle stays importable without the obs package loaded first).
    """
    from repro.obs.quality import recall_at_k as impl
    return impl(approx_ids, exact_ids)
