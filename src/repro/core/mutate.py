"""Streaming index mutation: LSM-style tail segments + tombstones.

``build_index`` freezes the corpus; this module makes the frozen index
a *base segment* in a two-level LSM tree so a live corpus can absorb
inserts and deletes without a full rebuild (ROADMAP direction 3, first
half):

  * **Tail segment** — ``insert_docs`` appends new doc ids to an
    unblocked per-index tail (``SeismicIndex.tail_ids``) and writes
    their rows into the forward index. Tail docs are scored EXACTLY by
    the scorer stage (no summary pruning — tails are small by
    construction, bounded by ``tail_max``), so a freshly inserted doc
    is searchable on the very next query.
  * **Tombstones** — ``delete_docs`` flips per-doc bits
    (``SeismicIndex.tombstone``); every retrieval stage masks
    tombstoned candidates to the sentinel id before merge, so deleted
    docs are never returned (and never counted as evaluated).
  * **Compaction** — when the tail exceeds ``tail_max``, ``compact``
    re-blocks it LSM-style: deleted ids are purged from the inverted
    lists, and each affected list either *appends* delta blocks (minor
    compaction — block summaries built through the builder's own
    :func:`repro.core.build.block_summaries`, superblock summaries
    updated monotonically via
    :func:`repro.core.build.merge_superblock_summary`, whose round-up
    requantization keeps them true upper bounds) or is *rebuilt* from
    its merged member set through
    :func:`repro.core.build.list_block_arrays` when the delta no
    longer fits (major compaction — bit-identical to a fresh build of
    that list). ``knn_ids`` is patched lazily: deleted ids become
    sentinels immediately, former-tail docs get out-edges by querying
    the compacted index (reverse edges toward new docs stay missing
    until the next full graph build — refine quality degrades
    gracefully, never correctness).

The invariant threaded through every layer is:

    frozen blocks  +  exact tail  +  tombstones  ==  one logical corpus

Every mutation bumps ``epoch`` — the token the serving layer mixes
into cache keys (``repro.serve``) so no stale result survives a swap.

Bit-exactness contract (the property the mutation tests pin): at FULL
block budget, with ``fwd_quant=False`` and a ``lam`` that never
truncates a list, searching a grown+compacted index bit-matches
``build_index`` over the equivalent final corpus (same capacity,
deleted/unassigned rows all-zero). Major compaction routes through the
identical per-list builder with the identical per-list PRNG key, and
at full budget routing/summaries cannot change the candidate set;
minor (append) compaction changes only block *permutation*, which the
doc-ascending dedupe order makes invisible to the merge.

Host-side orchestration is single-writer: one ``MutableSeismicIndex``
must only be mutated from one thread (servers swap in published
snapshots; see ``serve/README.md``).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.build import (block_summaries, build_index,
                              list_block_arrays, merge_superblock_summary)
from repro.core.types import SeismicConfig, SeismicIndex
from repro.sparse.ops import PaddedSparse
from repro.sparse.quant import dequantize_u8, quantize_u8


def make_mutable(index: SeismicIndex, **kwargs) -> "MutableSeismicIndex":
    """Wrap a built (or loaded) index for streaming mutation.

    Keyword arguments are forwarded to :class:`MutableSeismicIndex`;
    pass ``capacity`` to reserve insert headroom beyond the built
    corpus. Tuned policies survive (``validate_policy`` checks knob
    sanity, not index content)."""
    return MutableSeismicIndex(index, **kwargs)


class MutableSeismicIndex:
    """Single-writer mutation wrapper around immutable index snapshots.

    ``.index`` is always a complete, internally consistent
    :class:`SeismicIndex` safe to hand to the pipeline or a server
    (mutations never modify a published snapshot in place — they
    functionally update and republish). ``epoch`` increments on every
    visible mutation and is what cache keys and the
    ``seismic_index_epoch`` gauge observe.

    Parameters
    ----------
    capacity:
        Total doc-id space (existing + insert headroom). Defaults to
        the built corpus size, i.e. no insert room. Ids are assigned
        monotonically and NEVER reused — a deleted id stays dead.
    tail_cap:
        Physical tail-segment slots (the ``tail_ids`` array length).
    tail_max:
        Occupancy that triggers auto-compaction on the next insert
        needing room (<= tail_cap; default tail_cap).
    n_docs:
        Ids already assigned (default: every row of the built index).
        ``empty()`` passes 0 so a capacity-sized all-zero build starts
        with no live docs.
    registry:
        Optional :class:`repro.obs.MetricsRegistry` receiving
        ``seismic_index_epoch``, ``seismic_tail_occupancy``,
        ``seismic_tail_fill_ratio`` gauges, insert/delete counters and
        the ``seismic_compaction_seconds`` histogram.
    """

    def __init__(self, index: SeismicIndex, *, capacity: int | None = None,
                 tail_cap: int = 64, tail_max: int | None = None,
                 n_docs: int | None = None, registry=None):
        cfg = index.config
        n_old = index.n_docs
        cap = n_old if capacity is None else int(capacity)
        if cap < n_old:
            raise ValueError(f"capacity {cap} < built corpus {n_old}")
        tail_cap = int(tail_cap)
        if tail_cap <= 0:
            raise ValueError("tail_cap must be positive")
        self.tail_max = tail_cap if tail_max is None else int(tail_max)
        if not (1 <= self.tail_max <= tail_cap):
            raise ValueError(
                f"tail_max {self.tail_max} not in [1, {tail_cap}]")
        self.capacity = cap
        self.tail_cap = tail_cap
        self.config: SeismicConfig = cfg
        self._next_id = n_old if n_docs is None else int(n_docs)
        if not (0 <= self._next_id <= cap):
            raise ValueError(f"n_docs {self._next_id} not in [0, {cap}]")
        self._epoch = 0

        # ---- lift the immutable snapshot to capacity: pad the forward
        # plane with all-zero rows and remap the old pad sentinel
        # (n_old) to the new one (cap) wherever doc ids appear.
        coords = np.asarray(index.fwd.coords)
        vals = np.asarray(index.fwd.vals)
        list_docs = np.asarray(index.list_docs)
        knn = None if index.knn_ids is None else np.asarray(index.knn_ids)
        fwd_scale = (None if index.fwd_scale is None
                     else np.asarray(index.fwd_scale))
        fwd_zero = (None if index.fwd_zero is None
                    else np.asarray(index.fwd_zero))
        if cap > n_old:
            grow = cap - n_old
            coords = np.concatenate(
                [coords, np.zeros((grow, coords.shape[1]), coords.dtype)])
            vals = np.concatenate(
                [vals, np.zeros((grow, vals.shape[1]), vals.dtype)])
            list_docs = np.where(list_docs == n_old, cap, list_docs)
            if knn is not None:
                knn = np.where(knn == n_old, cap, knn)
                knn = np.concatenate(
                    [knn, np.full((grow, knn.shape[1]), cap, knn.dtype)])
            if fwd_scale is not None:
                fwd_scale = np.concatenate(
                    [fwd_scale, np.zeros(grow, fwd_scale.dtype)])
                fwd_zero = np.concatenate(
                    [fwd_zero, np.zeros(grow, fwd_zero.dtype)])

        # tail: resume a persisted one (checkpoint round-trip), else
        # start empty. Entries are doc ids; `cap` marks empty slots.
        tail = np.full(tail_cap, cap, np.int32)
        if index.tail_ids is not None:
            old_tail = np.asarray(index.tail_ids)
            live = old_tail[old_tail < n_old]
            if live.size > tail_cap:
                raise ValueError(
                    f"persisted tail ({live.size}) exceeds tail_cap "
                    f"{tail_cap}")
            tail[:live.size] = live
        self._tail_occ = int((tail < cap).sum())

        tomb = np.zeros(cap, bool)
        if index.tombstone is not None:
            old_tomb = np.asarray(index.tombstone)
            tomb[:old_tomb.size] = old_tomb
        # conservative resume: anything tombstoned might still sit in
        # the lists of a loaded snapshot — schedule it for purge (the
        # purge is idempotent on already-sentinel entries).
        self._pending_deletes: set[int] = {
            int(i) for i in np.nonzero(tomb)[0]}

        self._index = dataclasses.replace(
            index,
            fwd=PaddedSparse(jnp.asarray(coords), jnp.asarray(vals),
                             index.dim),
            list_docs=jnp.asarray(list_docs.astype(np.int32)),
            fwd_scale=None if fwd_scale is None else jnp.asarray(fwd_scale),
            fwd_zero=None if fwd_zero is None else jnp.asarray(fwd_zero),
            knn_ids=None if knn is None else jnp.asarray(
                knn.astype(np.int32)),
            tail_ids=jnp.asarray(tail),
            tombstone=jnp.asarray(tomb),
        )
        self._register_metrics(registry)

    # ------------------------------------------------------ lifecycle

    @classmethod
    def empty(cls, dim: int, doc_nnz: int,
              cfg: SeismicConfig = SeismicConfig(), *, capacity: int,
              tail_cap: int = 64, tail_max: int | None = None,
              registry=None) -> "MutableSeismicIndex":
        """An index with NO live docs and room for ``capacity`` of them
        (the grow-from-empty entry point). Builds over an all-zero
        collection so every array has its final shape up front."""
        docs = PaddedSparse(jnp.zeros((capacity, doc_nnz), jnp.int32),
                            jnp.zeros((capacity, doc_nnz), jnp.float32),
                            dim)
        return cls(build_index(docs, cfg), capacity=capacity,
                   tail_cap=tail_cap, tail_max=tail_max, n_docs=0,
                   registry=registry)

    @property
    def index(self) -> SeismicIndex:
        """The current published snapshot (hand this to servers)."""
        return self._index

    @property
    def epoch(self) -> int:
        return self._epoch

    @property
    def n_docs(self) -> int:
        """Ids assigned so far (monotone; includes deleted)."""
        return self._next_id

    @property
    def n_live(self) -> int:
        return self._next_id - int(np.asarray(self._index.tombstone).sum())

    @property
    def tail_occupancy(self) -> int:
        return self._tail_occ

    # ------------------------------------------------------ mutations

    def insert_docs(self, coords, vals) -> np.ndarray:
        """Insert a batch of docs; returns their assigned ids.

        ``coords``/``vals`` are ``[B, nnz]`` (or 1-D for a single doc)
        with ``vals <= 0`` marking padding, ``nnz <= fwd.nnz_max``.
        Auto-compacts whenever the tail lacks room for the next chunk.
        """
        coords = np.atleast_2d(np.asarray(coords))
        vals = np.atleast_2d(np.asarray(vals, np.float32))
        if coords.shape != vals.shape:
            raise ValueError(f"coords {coords.shape} != vals {vals.shape}")
        b, nnz = coords.shape
        nnz_max = self._index.fwd.nnz_max
        if nnz > nnz_max:
            raise ValueError(f"doc nnz {nnz} > index nnz_max {nnz_max}")
        if self._next_id + b > self.capacity:
            raise ValueError(
                f"capacity exhausted: {self._next_id} assigned + {b} new "
                f"> {self.capacity}; rebuild with more headroom")
        first = self._next_id
        s = 0
        while s < b:
            room = self.tail_max - self._tail_occ
            if room <= 0:
                self.compact()
                continue
            take = min(room, b - s)
            self._append_tail(coords[s:s + take], vals[s:s + take])
            s += take
        if self._m_inserted is not None:
            self._m_inserted.inc(b)
        return np.arange(first, self._next_id, dtype=np.int64)

    def _append_tail(self, coords: np.ndarray, vals: np.ndarray) -> None:
        idx = self._index
        take, nnz = coords.shape
        nnz_max = idx.fwd.nnz_max
        # canonical padded rows: nonpositive values are padding (coord 0,
        # value 0 — exactly the all-zero-row convention the equivalence
        # corpus uses, so bit-match tests need no row normalization)
        c = np.zeros((take, nnz_max), np.int64)
        v = np.zeros((take, nnz_max), np.float32)
        c[:, :nnz] = coords
        v[:, :nnz] = vals
        c = np.where(v > 0, c, 0)
        v = np.where(v > 0, v, 0.0)
        if np.any(c < 0) or np.any(c >= idx.dim):
            raise ValueError("doc coords out of range")
        ids = jnp.arange(self._next_id, self._next_id + take,
                         dtype=jnp.int32)
        cj = jnp.asarray(c).astype(idx.fwd.coords.dtype)
        vj = jnp.asarray(v)
        if idx.fwd_scale is not None:
            # compact forward plane: per-doc affine u8, same per-row
            # quantizer as build_index's whole-matrix pass
            q, scale, zero = quantize_u8(vj)
            fwd = PaddedSparse(idx.fwd.coords.at[ids].set(cj),
                               idx.fwd.vals.at[ids].set(q), idx.dim)
            fwd_scale = idx.fwd_scale.at[ids].set(scale)
            fwd_zero = idx.fwd_zero.at[ids].set(zero)
        else:
            fwd = PaddedSparse(
                idx.fwd.coords.at[ids].set(cj),
                idx.fwd.vals.at[ids].set(vj.astype(idx.fwd.vals.dtype)),
                idx.dim)
            fwd_scale, fwd_zero = None, None
        tail = idx.tail_ids.at[self._tail_occ + jnp.arange(take)].set(ids)
        self._index = dataclasses.replace(
            idx, fwd=fwd, fwd_scale=fwd_scale, fwd_zero=fwd_zero,
            tail_ids=tail)
        self._next_id += take
        self._tail_occ += take
        self._epoch += 1

    def delete_docs(self, ids) -> None:
        """Tombstone docs (idempotent). Masked from results immediately;
        physically purged from lists at the next compaction."""
        ids = np.unique(np.asarray(ids, np.int64).reshape(-1))
        if ids.size == 0:
            return
        if ids[0] < 0 or ids[-1] >= self._next_id:
            raise ValueError(
                f"delete ids must be in [0, {self._next_id}), got "
                f"[{ids[0]}, {ids[-1]}]")
        idx = self._index
        self._index = dataclasses.replace(
            idx, tombstone=idx.tombstone.at[jnp.asarray(ids)].set(True))
        self._pending_deletes.update(int(i) for i in ids)
        self._epoch += 1
        if self._m_deleted is not None:
            self._m_deleted.inc(int(ids.size))

    # ----------------------------------------------------- compaction

    def compact(self) -> None:
        """Fold the tail into the blocked index and purge tombstones.

        Per affected list: *minor* (append) compaction when the delta
        fits the list's spare row/block slots — new blocks chunked at
        ``block_cap`` in value-descending order, summaries via the
        builder's own path, superblock summaries merged monotonically
        (round-up requantize keeps the upper bound); otherwise a
        *major* per-list rebuild through :func:`list_block_arrays`,
        bit-identical to a fresh build of the merged member set.
        No-op when tail and pending deletes are both empty.
        """
        t0 = time.monotonic()
        idx = self._index
        cfg = idx.config
        cap = self.capacity
        tail = np.asarray(idx.tail_ids)
        tomb = np.asarray(idx.tombstone)
        pending = np.array(sorted(self._pending_deletes), np.int64)
        live_tail = tail[tail < cap]
        live_tail = live_tail[~tomb[live_tail]].astype(np.int64)
        if live_tail.size == 0 and pending.size == 0:
            self._pending_deletes.clear()
            return

        list_docs = np.asarray(idx.list_docs).copy()
        list_vals = np.asarray(idx.list_vals).copy()
        list_len = np.asarray(idx.list_len).copy()
        block_off = np.asarray(idx.block_off).copy()
        block_len = np.asarray(idx.block_len).copy()
        sum_coords = np.asarray(idx.sum_coords).copy()
        sum_q = np.asarray(idx.sum_q).copy()
        sum_scale = np.asarray(idx.sum_scale).copy()
        sum_zero = np.asarray(idx.sum_zero).copy()
        has_sup = idx.sup_coords is not None
        if has_sup:
            sup_coords = np.asarray(idx.sup_coords).copy()
            sup_q = np.asarray(idx.sup_q).copy()
            sup_scale = np.asarray(idx.sup_scale).copy()
            sup_zero = np.asarray(idx.sup_zero).copy()
        fwd_coords = np.asarray(idx.fwd.coords).copy()
        fwd_vals = np.asarray(idx.fwd.vals).copy()
        fwd_scale = (None if idx.fwd_scale is None
                     else np.asarray(idx.fwd_scale).copy())
        fwd_zero = (None if idx.fwd_zero is None
                    else np.asarray(idx.fwd_zero).copy())

        # ---- 1. purge tombstones. List positions keep their block
        # (summaries become loose-but-valid upper bounds); forward rows
        # go all-zero so the logical corpus equals "final live docs".
        if pending.size:
            dead = np.isin(list_docs, pending)
            list_docs[dead] = cap
            list_vals[dead] = 0.0
            fwd_coords[pending] = 0
            fwd_vals[pending] = 0
            if fwd_scale is not None:
                fwd_scale[pending] = 0.0
                fwd_zero[pending] = 0.0

        # float32 forward view for the builder seams (identical to the
        # fresh build's `docs.astype(float32)` for an unquantized plane)
        if fwd_scale is not None:
            v32 = np.asarray(dequantize_u8(
                jnp.asarray(fwd_vals), jnp.asarray(fwd_scale),
                jnp.asarray(fwd_zero)))
            c32 = fwd_coords.astype(np.int32)
        else:
            v32 = fwd_vals.astype(np.float32)
            c32 = fwd_coords
        fwd32 = PaddedSparse(jnp.asarray(c32), jnp.asarray(v32), idx.dim)

        # ---- 2. per-coordinate delta membership from live tail docs
        delta: dict[int, list[tuple[int, float]]] = {}
        for d in live_tail:
            for cc, vv in zip(fwd_coords[d], v32[d]):
                if vv > 0:
                    delta.setdefault(int(cc), []).append((int(d), float(vv)))

        lam, nb, bcap = cfg.lam, cfg.n_blocks, cfg.block_cap
        fanout = cfg.superblock_fanout
        key = jax.random.PRNGKey(cfg.seed)
        n_minor = n_major = 0
        for ell, members in delta.items():
            # value-descending, ties doc-ascending — the builder's own
            # posting order (lexsort primary -val, secondary doc)
            members.sort(key=lambda t: (-t[1], t[0]))
            d = len(members)
            base_len = int(list_len[ell])
            nb_used = int((block_len[ell] > 0).sum())   # blocks are a
            n_new = -(-d // bcap)                        # slot prefix
            if base_len + d <= lam and nb_used + n_new <= nb:
                # ---------------- minor: append delta blocks
                n_minor += 1
                docs_new = np.fromiter((m[0] for m in members), np.int32,
                                       d)
                vals_new = np.fromiter((m[1] for m in members), np.float32,
                                       d)
                list_docs[ell, base_len:base_len + d] = docs_new
                list_vals[ell, base_len:base_len + d] = vals_new
                list_len[ell] = base_len + d
                # summaries for the new blocks only, through the
                # builder's _summaries (artificial [lam] layout: delta
                # docs in a prefix, block j = position // block_cap)
                docs_perm = np.full(lam, cap, np.int32)
                docs_perm[:d] = docs_new
                block_id = np.full(lam, nb, np.int32)
                block_id[:d] = np.arange(d) // bcap
                sc, q, scale, zero = block_summaries(
                    jnp.asarray(docs_perm), jnp.asarray(block_id), fwd32,
                    cfg)
                sc = np.asarray(sc)[:n_new]
                q = np.asarray(q)[:n_new]
                scale = np.asarray(scale)[:n_new]
                zero = np.asarray(zero)[:n_new]
                for j in range(n_new):
                    slot = nb_used + j
                    block_off[ell, slot] = base_len + j * bcap
                    block_len[ell, slot] = min(bcap, d - j * bcap)
                    sum_coords[ell, slot] = sc[j]
                    sum_q[ell, slot] = q[j]
                    sum_scale[ell, slot] = scale[j]
                    sum_zero[ell, slot] = zero[j]
                if has_sup:
                    for g in sorted({(nb_used + j) // fanout
                                     for j in range(n_new)}):
                        kids = [j for j in range(n_new)
                                if (nb_used + j) // fanout == g]
                        merged = merge_superblock_summary(
                            jnp.asarray(sup_coords[ell, g]),
                            jnp.asarray(sup_q[ell, g]),
                            jnp.asarray(sup_scale[ell, g]),
                            jnp.asarray(sup_zero[ell, g]),
                            jnp.asarray(sc[kids]), jnp.asarray(q[kids]),
                            jnp.asarray(scale[kids]),
                            jnp.asarray(zero[kids]), idx.dim, cfg)
                        (sup_coords[ell, g], sup_q[ell, g],
                         sup_scale[ell, g], sup_zero[ell, g]) = (
                            np.asarray(a) for a in merged)
            else:
                # ---------------- major: rebuild the list from its
                # merged member set — the fresh-build code path with
                # the fresh-build PRNG key, so bit-identical arrays
                n_major += 1
                base = list_docs[ell, :base_len]
                keep = base < cap
                mdocs = np.concatenate(
                    [base[keep].astype(np.int64),
                     np.fromiter((m[0] for m in members), np.int64, d)])
                mvals = np.concatenate(
                    [list_vals[ell, :base_len][keep].astype(np.float32),
                     np.fromiter((m[1] for m in members), np.float32, d)])
                order = np.lexsort((mdocs, -mvals))
                cnt = min(order.size, lam)
                docs_p = np.full(lam, cap, np.int32)
                vals_p = np.zeros(lam, np.float32)
                docs_p[:cnt] = mdocs[order[:cnt]]
                vals_p[:cnt] = mvals[order[:cnt]]
                out = list_block_arrays(
                    jax.random.fold_in(key, ell), jnp.asarray(docs_p),
                    jnp.asarray(vals_p), jnp.int32(cnt), fwd32, cfg)
                (list_docs[ell], list_vals[ell], _, block_off[ell],
                 block_len[ell], sum_coords[ell], sum_q[ell],
                 sum_scale[ell], sum_zero[ell]) = (
                    np.asarray(a) for a in out[:9])
                list_len[ell] = cnt
                if has_sup:
                    (sup_coords[ell], sup_q[ell], sup_scale[ell],
                     sup_zero[ell]) = (np.asarray(a) for a in out[9:])

        # ---- 3. publish the compacted snapshot (tail now empty)
        new_fwd_dtype = idx.fwd.vals.dtype
        compacted = dataclasses.replace(
            idx,
            fwd=PaddedSparse(jnp.asarray(fwd_coords),
                             jnp.asarray(fwd_vals.astype(new_fwd_dtype)),
                             idx.dim),
            list_docs=jnp.asarray(list_docs),
            list_vals=jnp.asarray(list_vals),
            list_len=jnp.asarray(list_len),
            block_off=jnp.asarray(block_off),
            block_len=jnp.asarray(block_len),
            sum_coords=jnp.asarray(sum_coords),
            sum_q=jnp.asarray(sum_q),
            sum_scale=jnp.asarray(sum_scale),
            sum_zero=jnp.asarray(sum_zero),
            fwd_scale=None if fwd_scale is None else jnp.asarray(fwd_scale),
            fwd_zero=None if fwd_zero is None else jnp.asarray(fwd_zero),
            sup_coords=jnp.asarray(sup_coords) if has_sup else None,
            sup_q=jnp.asarray(sup_q) if has_sup else None,
            sup_scale=jnp.asarray(sup_scale) if has_sup else None,
            sup_zero=jnp.asarray(sup_zero) if has_sup else None,
            tail_ids=jnp.full((self.tail_cap,), cap, jnp.int32),
        )

        # ---- 4. lazy graph patch: dead edges -> sentinel, former-tail
        # docs get fresh out-edges by querying the compacted index
        if idx.knn_ids is not None:
            knn = np.asarray(idx.knn_ids).copy()
            if pending.size:
                knn[np.isin(knn, pending)] = cap
                knn[pending] = cap
            if live_tail.size:
                knn[live_tail] = cap
                res = self._fresh_edges(compacted, live_tail, c32, v32,
                                        tomb, knn.shape[1])
                for i, doc in enumerate(live_tail):
                    row = res[i]
                    knn[doc, :row.size] = row
            compacted = dataclasses.replace(compacted,
                                            knn_ids=jnp.asarray(knn))

        self._index = compacted
        self._tail_occ = 0
        self._pending_deletes.clear()
        self._epoch += 1
        dt = time.monotonic() - t0
        if self._m_compactions is not None:
            self._m_compactions.inc()
            self._m_compact_s.record(dt)
            self._m_compact_minor.inc(n_minor)
            self._m_compact_major.inc(n_major)

    def _fresh_edges(self, compacted: SeismicIndex, new_ids: np.ndarray,
                     c32: np.ndarray, v32: np.ndarray, tomb: np.ndarray,
                     degree: int) -> list[np.ndarray]:
        """Out-edges for compacted-in docs: drive their forward rows as
        queries through the pipeline (the graph builder's own recipe,
        ``repro.graph.build``), drop self/tombstoned/pad hits."""
        from repro.retrieval.params import SearchParams
        from repro.retrieval.pipeline import search_pipeline

        cfg = compacted.config
        p = SearchParams(k=degree + 1, cut=8,
                         block_budget=min(64, 8 * cfg.n_blocks),
                         policy="budget")
        q = PaddedSparse(jnp.asarray(c32[new_ids].astype(np.int32)),
                         jnp.asarray(v32[new_ids]), compacted.dim)
        _, ids_out, _ = search_pipeline(compacted, q, p)
        ids_out = np.asarray(ids_out)
        rows = []
        for i, doc in enumerate(new_ids):
            row = ids_out[i]
            row = row[(row >= 0) & (row != doc)]
            row = row[~tomb[row]][:degree].astype(np.int32)
            rows.append(row)
        return rows

    # -------------------------------------------------------- metrics

    def _register_metrics(self, registry) -> None:
        self._m_inserted = self._m_deleted = None
        self._m_compactions = self._m_compact_s = None
        self._m_compact_minor = self._m_compact_major = None
        if registry is None:
            return
        registry.gauge(
            "seismic_index_epoch",
            "Mutation epoch of the index (bumped on every visible "
            "mutation)").labels().set_fn(lambda: self._epoch)
        registry.gauge(
            "seismic_tail_occupancy",
            "Live docs in the unblocked tail segment").labels().set_fn(
            lambda: self._tail_occ)
        registry.gauge(
            "seismic_tail_fill_ratio",
            "Tail occupancy / tail_max (1.0 = next insert "
            "compacts)").labels().set_fn(
            lambda: self._tail_occ / self.tail_max)
        self._m_inserted = registry.counter(
            "seismic_docs_inserted_total", "Docs inserted").labels()
        self._m_deleted = registry.counter(
            "seismic_docs_deleted_total", "Docs tombstoned").labels()
        self._m_compactions = registry.counter(
            "seismic_compactions_total", "Compaction runs").labels()
        self._m_compact_minor = registry.counter(
            "seismic_compaction_lists_minor_total",
            "Lists compacted by block append").labels()
        self._m_compact_major = registry.counter(
            "seismic_compaction_lists_major_total",
            "Lists compacted by full per-list rebuild").labels()
        self._m_compact_s = registry.histogram(
            "seismic_compaction_seconds", "Wall time per compaction",
            lo=1e-5, hi=1e3).labels()
