"""Seismic query processing — compatibility shim.

The execution path lives in :mod:`repro.retrieval`: an explicit staged
batch-first pipeline (prep -> router -> selector -> scorer -> merge)
where every stage operates on whole ``[Q, ...]`` batches and the hot
phases R and S are single batched Pallas kernel launches. Local,
served (``repro.serve.engine.SeismicServer``), and distributed
(``repro.core.distributed``) search all route through that one
pipeline; this module re-exports the historical entry points so
existing imports (``from repro.core.query import SearchParams,
search_batch``) keep working.
"""
from __future__ import annotations

from repro.retrieval.params import SearchParams
from repro.retrieval.pipeline import run_pipeline, search_pipeline
from repro.retrieval.router import NEG
from repro.sparse.ops import PaddedSparse


def search_batch(index, queries: PaddedSparse, p: SearchParams):
    """Batched Seismic search (the shared retrieval pipeline).

    Returns (scores [Q,k], ids [Q,k] with -1 padding, docs_evaluated [Q]).
    """
    return search_pipeline(index, queries, p)


__all__ = ["SearchParams", "search_batch", "search_pipeline",
           "run_pipeline", "NEG"]
