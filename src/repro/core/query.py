"""Seismic query processing (Algorithm 2), batched for TPU.

The paper's coordinate-at-a-time heap traversal is re-scheduled as a
two-phase batched computation (the §6 "Routing ... in one go" design):

  phase R (routing)  score ALL summaries of the ``cut`` probed lists
                     with one quantized gather-dot contraction
                     -> [cut, n_blocks] block scores
  phase S (scoring)  select blocks, gather their docs, dedupe, compute
                     exact inner products against the forward index,
                     one final top-k

Two block-selection policies:

  * ``budget``   — top ``block_budget`` blocks by summary score
                   (pure IVF-style routing, one pass)
  * ``adaptive`` — two-stage emulation of Alg. 2's heap_factor: stage 1
                   fully scores the top ``probe_budget`` blocks to
                   bootstrap a k-th-best estimate theta, stage 2 keeps
                   only blocks with summary >= theta / heap_factor
                   (capped at block_budget). This recovers the paper's
                   dynamic pruning without a serial heap.

Everything is vmapped over the query batch.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.types import SeismicIndex
from repro.sparse.ops import PaddedSparse, densify_one, top_cut
from repro.sparse.quant import dequantize_u8

NEG = -jnp.inf


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Query-time hyper-parameters (paper's cut, heap_factor)."""

    k: int = 10
    cut: int = 8                  # probed query coordinates
    block_budget: int = 32        # max fully-evaluated blocks
    heap_factor: float = 0.9      # summary over-estimate correction
    policy: str = "adaptive"      # "budget" | "adaptive"
    probe_budget: int = 8         # stage-1 blocks for the adaptive policy
    use_kernel: bool = False      # Pallas gather_dot/summary_dot path


def _score_fwd(index: SeismicIndex, q_dense: jax.Array, cand: jax.Array,
               use_kernel: bool) -> jax.Array:
    """<q, doc> for candidate ids (sentinel-masked to -inf). With a
    compact (fwd_quant) index the per-doc u8 dequant fuses into the
    gather-dot; scores stay 'exact' up to ~0.4% value quantization."""
    c = jnp.take(index.fwd.coords, cand, axis=0, mode="clip").astype(jnp.int32)
    v = jnp.take(index.fwd.vals, cand, axis=0, mode="clip")
    if index.fwd_scale is not None:
        from repro.sparse.quant import dequantize_u8
        scale = jnp.take(index.fwd_scale, cand, mode="clip")
        zero = jnp.take(index.fwd_zero, cand, mode="clip")
        v = dequantize_u8(v, scale, zero)
    else:
        v = v.astype(jnp.float32)
    if use_kernel:
        from repro.kernels.gather_dot.ops import gather_dot
        scores = gather_dot(q_dense, c, v)
    else:
        scores = (q_dense[c] * v).sum(axis=-1)
    return jnp.where(cand < index.n_docs, scores, NEG)


def _route(index: SeismicIndex, q_dense: jax.Array, lists: jax.Array,
           use_kernel: bool) -> jax.Array:
    """Summary inner products for all blocks of the probed lists
    -> [cut, n_blocks]; unused blocks are -inf."""
    sc = index.sum_coords[lists]            # [cut, nb, S]
    sq = index.sum_q[lists]                 # [cut, nb, S] u8
    scale = index.sum_scale[lists]
    zero = index.sum_zero[lists]
    if use_kernel:
        from repro.kernels.summary_dot.ops import summary_dot
        r = summary_dot(q_dense, sc, sq, scale, zero)
    else:
        sv = dequantize_u8(sq, scale, zero)
        r = (q_dense[sc] * sv).sum(axis=-1)
    alive = index.block_len[lists] > 0
    return jnp.where(alive, r, NEG)


def _gather_block_docs(index: SeismicIndex, lists: jax.Array,
                       flat_blocks: jax.Array) -> jax.Array:
    """Doc ids of selected (list, block) pairs -> [n_sel, block_cap]."""
    nb = index.config.n_blocks
    li = flat_blocks // nb                  # index into `lists`
    bi = flat_blocks % nb
    coord = lists[li]
    off = index.block_off[coord, bi]        # [n_sel]
    ln = index.block_len[coord, bi]
    ar = jnp.arange(index.config.block_cap)
    pos = off[:, None] + ar[None, :]
    docs = jnp.take_along_axis(index.list_docs[coord],
                               jnp.clip(pos, 0, index.config.lam - 1), axis=1)
    return jnp.where(ar[None, :] < ln[:, None], docs, index.n_docs)


def _dedupe(cand: jax.Array, n_docs: int) -> jax.Array:
    """Sort candidate ids and mask duplicates to the sentinel."""
    s = jnp.sort(cand)
    dup = jnp.concatenate([jnp.zeros(1, bool), s[1:] == s[:-1]])
    return jnp.where(dup, n_docs, s)


def _search_one(index: SeismicIndex, q_coords: jax.Array, q_vals: jax.Array,
                p: SearchParams):
    q_dense = densify_one(q_coords, q_vals.astype(jnp.float32), index.dim)
    qc, qv = top_cut(q_coords, q_vals.astype(jnp.float32), p.cut)
    # probing coord 0 repeatedly for padded queries is harmless: its
    # routing scores are finite but the same blocks dedupe later.
    r = _route(index, q_dense, qc, p.use_kernel)          # [cut, nb]
    r_flat = r.reshape(-1)

    if p.policy == "adaptive":
        # ---- stage 1: bootstrap theta from the top probe_budget blocks
        r1, b1 = jax.lax.top_k(r_flat, p.probe_budget)
        cand1 = _gather_block_docs(index, qc, b1).reshape(-1)
        cand1 = _dedupe(cand1, index.n_docs)
        s1 = _score_fwd(index, q_dense, cand1, p.use_kernel)
        theta = jax.lax.top_k(s1, p.k)[0][-1]
        theta = jnp.where(jnp.isfinite(theta), theta, NEG)
        # ---- stage 2: Alg.2 line 6 -> keep blocks w/ r >= theta/heap_factor
        r_flat2 = r_flat.at[b1].set(NEG)  # already evaluated
        passing = r_flat2 >= theta / p.heap_factor
        r_flat2 = jnp.where(passing, r_flat2, NEG)
        n2 = p.block_budget - p.probe_budget
        r2, b2 = jax.lax.top_k(r_flat2, n2)
        cand2 = _gather_block_docs(index, qc, b2)
        cand2 = jnp.where(jnp.isfinite(r2)[:, None], cand2,
                          index.n_docs).reshape(-1)
        cand = jnp.concatenate([cand1, _dedupe(cand2, index.n_docs)])
        cand = _dedupe(cand, index.n_docs)
        scores = _score_fwd(index, q_dense, cand, p.use_kernel)
    else:
        _, bsel = jax.lax.top_k(r_flat, p.block_budget)
        cand = _gather_block_docs(index, qc, bsel).reshape(-1)
        cand = _dedupe(cand, index.n_docs)
        scores = _score_fwd(index, q_dense, cand, p.use_kernel)

    top_s, pos = jax.lax.top_k(scores, p.k)
    top_ids = cand[pos]
    top_ids = jnp.where(jnp.isfinite(top_s), top_ids, -1)
    docs_evaluated = (cand < index.n_docs).sum()
    return top_s, top_ids.astype(jnp.int32), docs_evaluated


@partial(jax.jit, static_argnames=("p",))
def search_batch(index: SeismicIndex, queries: PaddedSparse, p: SearchParams):
    """Batched Seismic search.

    Returns (scores [Q,k], ids [Q,k] with -1 padding, docs_evaluated [Q]).
    """
    return jax.vmap(lambda c, v: _search_one(index, c, v, p))(
        queries.coords, queries.vals)
