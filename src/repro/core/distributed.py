"""Distributed Seismic: doc-sharded indexes, query fan-out, top-k merge.

Scale-out design (DESIGN.md §4): the corpus is sharded over the mesh's
``model`` (and optionally ``pod``) axes; every shard owns a complete
local Seismic index over its documents. Queries are sharded over
``data``. A query executes its local search on every doc shard, then an
``all_gather`` of the per-shard (score, global_id) top-k over the doc
axes and a vectorized merge produce the global top-k. Per-query
collective volume is O(k * n_doc_shards) — independent of corpus size.

The stacked index (leading axis = doc shard) is a regular pytree, so
``jax.jit`` + ``shard_map`` drive the whole thing; the same function is
what the multi-pod dry-run lowers for the retrieval cells. Each
shard's local search is the shared batch-first staged pipeline
(``repro.retrieval``) — the exact code path of local and served
search.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.build import build_index
from repro.core.types import SeismicConfig
from repro.retrieval import SearchParams, run_pipeline
from repro.sparse.ops import PaddedSparse


def shard_collection(docs: PaddedSparse, n_shards: int) -> PaddedSparse:
    """Pad N to a multiple of n_shards and add a leading shard axis:
    [S, N/S, nnz].

    Pad rows are all-zero docs appended at the tail of the LAST shard;
    every merge seam over per-shard results must mask them out (see
    ``mask_shard_topk``) — an all-zero doc that surfaces as a candidate
    scores exactly 0.0 with an out-of-range global id."""
    n = docs.n
    per = -(-n // n_shards)
    pad = per * n_shards - n
    coords = jnp.pad(docs.coords, ((0, pad), (0, 0)))
    vals = jnp.pad(docs.vals, ((0, pad), (0, 0)))
    return PaddedSparse(coords.reshape(n_shards, per, -1),
                        vals.reshape(n_shards, per, -1), docs.dim)


def mask_shard_topk(scores: jax.Array, ids: jax.Array, fwd: PaddedSparse,
                    shard_offset, n_docs: int | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Globalize one shard's local top-k and mask pad hits to
    ``(-inf, -1)`` — the invariant every cross-shard merge relies on.

    ``shard_collection`` zero-pads the corpus to a multiple of
    ``n_shards``; a pad row that surfaces as a candidate (k exceeding
    the shard's live hits, index surgery, future mutable-index paths)
    scores exactly 0.0 and would enter the merged global top-k with an
    out-of-range global id. Pad rows are exactly the all-zero forward
    rows, so they are masked from ``fwd`` content (dtype-agnostic:
    holds for the f32 and the u8-quantized plane alike); an explicit
    live-doc bound ``n_docs`` additionally masks any globalized id at
    or past it.

    scores/ids: [Q, kk] local top-k; fwd: the shard's forward plane
    [per_shard, nnz]; returns (scores, global ids) with dead slots at
    (-inf, -1).
    """
    per_shard = fwd.coords.shape[0]
    live_row = (fwd.vals != 0).any(axis=-1)             # [per_shard]
    pad_hit = ~jnp.take(live_row, jnp.clip(ids, 0, per_shard - 1),
                        axis=0)
    gids = ids + shard_offset
    dead = (ids < 0) | pad_hit
    if n_docs is not None:
        dead = dead | (gids >= n_docs)
    scores = jnp.where(dead, -jnp.inf, scores)
    gids = jnp.where(dead, -1, gids)
    return scores, gids


def build_sharded_index(docs: PaddedSparse, cfg: SeismicConfig,
                        n_shards: int, *, list_chunk: int = 32):
    """Build one local index per doc shard; returns a stacked pytree
    whose every array leaf has a leading [n_shards] axis."""
    sharded = shard_collection(docs, n_shards)
    indexes = []
    for s in range(n_shards):
        shard_docs = PaddedSparse(sharded.coords[s], sharded.vals[s], docs.dim)
        indexes.append(build_index(shard_docs, cfg, list_chunk=list_chunk))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *indexes)


def make_distributed_search(mesh, p: SearchParams,
                            doc_axes=("model",), data_axis="data",
                            *, n_docs: int | None = None):
    """Returns ``search(stacked_index, q_coords, q_vals) -> (scores, ids)``
    running under shard_map on ``mesh``.

    stacked_index leaves: [n_doc_shards, ...] sharded over ``doc_axes``.
    q_coords/q_vals: [Q, nnz] sharded over ``data_axis``.
    output: (scores [Q,k], global ids [Q,k]) sharded over ``data_axis``.
    ``n_docs``: the LIVE corpus size (pre-padding ``docs.n``); when
    given, any globalized id at or past it is masked before the merge
    in addition to the content-based pad masking.
    """
    index_spec = P(doc_axes)
    q_spec = P(data_axis)

    def local_search(index_shard, q_coords, q_vals):
        # every leaf arrives as [1, ...] on its doc-shard device
        local = jax.tree.map(lambda x: x[0], index_shard)
        per_shard = local.fwd.coords.shape[0]

        # the shared batch-first pipeline runs on the whole local
        # query batch at once (same code as local + served search)
        scores, ids, _ = run_pipeline(local, q_coords, q_vals, p)  # [Ql, k]

        # globalize ids with the shard offset (row-major over doc axes)
        shard_id = jax.lax.axis_index(doc_axes[0])
        for ax in doc_axes[1:]:
            shard_id = shard_id * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
        # mask pad-doc hits to (-inf, -1) BEFORE the all-gather: the
        # global merge must never see a zero-padded row's 0.0 score
        scores, gids = mask_shard_topk(scores, ids, local.fwd,
                                       shard_id * per_shard,
                                       n_docs=n_docs)

        # fan-in: gather every shard's top-k, merge
        all_s, all_g = scores, gids
        for ax in doc_axes:
            all_s = jax.lax.all_gather(all_s, ax)              # [Pax, Q, kk]
            all_g = jax.lax.all_gather(all_g, ax)
            all_s = jnp.moveaxis(all_s, 0, 1).reshape(scores.shape[0], -1)
            all_g = jnp.moveaxis(all_g, 0, 1).reshape(scores.shape[0], -1)
        top_s, pos = jax.lax.top_k(all_s, p.k)
        top_g = jnp.take_along_axis(all_g, pos, axis=-1)
        return top_s, top_g

    def search(stacked_index, q_coords, q_vals):
        specs = jax.tree.map(lambda _: index_spec, stacked_index)
        fn = jax.shard_map(
            local_search, mesh=mesh,
            in_specs=(specs, q_spec, q_spec),
            out_specs=(q_spec, q_spec),
            check_vma=False)  # outputs replicated over doc axes post-gather
        return fn(stacked_index, q_coords, q_vals)

    return search
