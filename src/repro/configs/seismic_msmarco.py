"""The paper's own system as a selectable arch: Seismic over a
SPLADE-statistics MS MARCO-scale collection (8.8M docs, vocab 30522,
lambda=6000, beta=400, alpha=0.4 — the paper's best MS MARCO settings,
§7.1). The dry-run lowers the distributed query step; CPU experiments
use the reduced config. ``CONFIG_HIER`` / ``REDUCED_HIER`` derive the
superblock tier with the adaptive ``core.build.suggest_fanout`` helper
instead of a hand-picked fanout."""
import dataclasses
import math

import numpy as np

from repro.configs.base import ShapeCell
from repro.core.build import suggest_fanout
from repro.core.types import SeismicConfig


@dataclasses.dataclass(frozen=True)
class SeismicArchConfig:
    name: str
    index: SeismicConfig
    n_docs: int
    dim: int
    doc_nnz: int
    query_nnz: int

    @property
    def family(self) -> str:
        return "retrieval"


CONFIG = SeismicArchConfig(
    name="seismic-msmarco",
    index=SeismicConfig(lam=6000, beta=400, alpha=0.4, block_cap=64,
                        summary_nnz=96, fwd_dtype="bfloat16"),
    n_docs=8_841_823, dim=30522, doc_nnz=128, query_nnz=48)

SHAPES = [
    ShapeCell("query_batch", "retrieval", dict(batch=4096, k=10, cut=10,
                                               block_budget=64)),
    ShapeCell("query_online", "retrieval", dict(batch=256, k=10, cut=10,
                                                block_budget=64)),
]

REDUCED = SeismicArchConfig(
    name="seismic-reduced",
    index=SeismicConfig(lam=128, beta=8, alpha=0.4, block_cap=32,
                        summary_nnz=32),
    n_docs=2048, dim=1024, doc_nnz=48, query_nnz=16)


def estimated_live_blocks(arch: SeismicArchConfig) -> np.ndarray:
    """Modeled per-list live-block counts for a collection that has not
    been built yet (the :func:`suggest_fanout` statistic at config
    time): expected postings per coordinate under a uniform token
    model, truncated by ``lam``, split at ``block_cap``. Replace with
    ``core.build.live_blocks(index)`` once an index exists — real
    Zipf-skewed lists only sharpen the estimate."""
    per_list = min(arch.n_docs * arch.doc_nnz / arch.dim, arch.index.lam)
    return np.full(arch.dim,
                   math.ceil(per_list / arch.index.block_cap), np.int32)


def with_suggested_fanout(arch: SeismicArchConfig,
                          stats: np.ndarray | None = None
                          ) -> SeismicArchConfig:
    """Derive the hierarchical (superblock) variant of an arch config,
    with the fanout picked by ``suggest_fanout`` from live-block stats
    (modeled when ``stats`` is None). Single-/few-block collections
    come back unchanged (fanout 0 = flat routing, no overhead)."""
    if stats is None:
        stats = estimated_live_blocks(arch)
    f = suggest_fanout(stats)
    if f == arch.index.superblock_fanout:
        return arch
    return dataclasses.replace(
        arch, name=f"{arch.name}-hier",
        index=dataclasses.replace(arch.index, superblock_fanout=f))


# adaptive-fanout variants: MS MARCO lists saturate lam (~94 live
# blocks/list -> fanout 8, capped); the reduced CPU config lands ~3
CONFIG_HIER = with_suggested_fanout(CONFIG)
REDUCED_HIER = with_suggested_fanout(REDUCED)
