"""The paper's own system as a selectable arch: Seismic over a
SPLADE-statistics MS MARCO-scale collection (8.8M docs, vocab 30522,
lambda=6000, beta=400, alpha=0.4 — the paper's best MS MARCO settings,
§7.1). The dry-run lowers the distributed query step; CPU experiments
use the reduced config. ``CONFIG_HIER`` / ``REDUCED_HIER`` derive the
superblock tier with the adaptive ``core.build.suggest_fanout`` helper
instead of a hand-picked fanout."""
import dataclasses
import math

import numpy as np

from repro.configs.base import ShapeCell
from repro.core.build import suggest_fanout
from repro.core.types import SeismicConfig


@dataclasses.dataclass(frozen=True)
class SeismicArchConfig:
    name: str
    index: SeismicConfig
    n_docs: int
    dim: int
    doc_nnz: int
    query_nnz: int
    # modeled TunedPolicy tuple (repro.tune): config-time operating
    # points picked by the SAME frontier/selection code the measured
    # tuner uses, over a modeled cost/recall surface. Marked
    # `modeled=True`; a real `tune_and_attach` run on the built index
    # supersedes them.
    tuned: tuple = ()

    @property
    def family(self) -> str:
        return "retrieval"


CONFIG = SeismicArchConfig(
    name="seismic-msmarco",
    index=SeismicConfig(lam=6000, beta=400, alpha=0.4, block_cap=64,
                        summary_nnz=96, fwd_dtype="bfloat16"),
    n_docs=8_841_823, dim=30522, doc_nnz=128, query_nnz=48)

SHAPES = [
    ShapeCell("query_batch", "retrieval", dict(batch=4096, k=10, cut=10,
                                               block_budget=64)),
    ShapeCell("query_online", "retrieval", dict(batch=256, k=10, cut=10,
                                                block_budget=64)),
]

REDUCED = SeismicArchConfig(
    name="seismic-reduced",
    index=SeismicConfig(lam=128, beta=8, alpha=0.4, block_cap=32,
                        summary_nnz=32),
    n_docs=2048, dim=1024, doc_nnz=48, query_nnz=16)


def estimated_live_blocks(arch: SeismicArchConfig) -> np.ndarray:
    """Modeled per-list live-block counts for a collection that has not
    been built yet (the :func:`suggest_fanout` statistic at config
    time): expected postings per coordinate under a uniform token
    model, truncated by ``lam``, split at ``block_cap``. Replace with
    ``core.build.live_blocks(index)`` once an index exists — real
    Zipf-skewed lists only sharpen the estimate."""
    per_list = min(arch.n_docs * arch.doc_nnz / arch.dim, arch.index.lam)
    return np.full(arch.dim,
                   math.ceil(per_list / arch.index.block_cap), np.int32)


def with_suggested_fanout(arch: SeismicArchConfig,
                          stats: np.ndarray | None = None
                          ) -> SeismicArchConfig:
    """Derive the hierarchical (superblock) variant of an arch config,
    with the fanout picked by ``suggest_fanout`` from live-block stats
    (modeled when ``stats`` is None). Single-/few-block collections
    come back unchanged (fanout 0 = flat routing, no overhead)."""
    if stats is None:
        stats = estimated_live_blocks(arch)
    f = suggest_fanout(stats)
    if f == arch.index.superblock_fanout:
        return arch
    return dataclasses.replace(
        arch, name=f"{arch.name}-hier",
        index=dataclasses.replace(arch.index, superblock_fanout=f))


# adaptive-fanout variants: MS MARCO lists saturate lam (~94 live
# blocks/list -> fanout 8, capped); the reduced CPU config lands ~3
CONFIG_HIER = with_suggested_fanout(CONFIG)
REDUCED_HIER = with_suggested_fanout(REDUCED)


# ------------------------------------------------ tuned operating points

def _modeled_points(arch: SeismicArchConfig, k: int = 10, cut: int = 8,
                    graph_degree: int = 8):
    """Modeled recall/cost surface over the coupled knob grid.

    The config-time analog of ``repro.tune.sweep``: the cost side is
    the real work model (expected exactly-scored docs + refine rescore
    work, ``router_work`` for the routing side); the recall side is a
    saturating coverage model (early blocks carry most of the top-k
    mass, each refine round recovers a fixed fraction of what the
    truncated budget dropped). It exists only to pick defensible
    DEFAULTS before a collection is built — ``tune_and_attach`` on the
    built index replaces these with measured points (``modeled=False``).
    """
    from repro.retrieval.params import SearchParams
    from repro.retrieval.router import router_work
    from repro.tune.sweep import MeasuredPoint
    icfg = arch.index
    per_list = min(arch.n_docs * arch.doc_nnz / arch.dim, icfg.lam)
    pool = max(cut * per_list, 1.0)
    # impact concentration (paper Fig. 1): the summary-routed best
    # blocks carry the top-k mass, so coverage is measured against the
    # concentrated quarter of the probed postings, saturating concavely
    eff_pool = max(pool * 0.25, 1.0)
    gain_per_round = 0.8 * graph_degree / (graph_degree + k)
    f = icfg.superblock_fanout
    points = []
    for budget in (2, 4, 8, 16, 32, 64, 128):
        if budget > cut * icfg.n_blocks:
            continue
        for rounds in (0, 1, 2):
            cov = min(1.0, budget * icfg.block_cap / eff_pool)
            base = cov ** 0.3
            gain = 1.0 - (1.0 - gain_per_round) ** rounds
            recall = base + (1.0 - base) * gain
            docs = min(budget * icfg.block_cap, pool) \
                + rounds * k * graph_degree
            p = SearchParams(
                k=k, cut=cut, block_budget=budget, policy="budget",
                superblock_fanout=f,
                superblock_budget=max(2, budget // max(f // 2, 1)),
                graph_degree=graph_degree if rounds else 0,
                refine_rounds=rounds)
            points.append(MeasuredPoint(
                params=p, recall=round(recall, 6),
                docs_evaluated=float(round(docs, 3)),
                router_cost=router_work(icfg, p)))
    return points


def with_modeled_tuning(arch: SeismicArchConfig,
                        targets=(0.9, 0.95)) -> SeismicArchConfig:
    """Derive the ``*-tuned`` variant: one modeled ``TunedPolicy`` per
    recall target, selected by the measured tuner's own frontier code
    over the modeled surface. ``SearchParams.from_tuned(arch, target)``
    resolves them (duck-typed on ``.tuned``), same as on a tuned
    index."""
    from repro.tune.frontier import policy_from_point, select_operating_point
    points = _modeled_points(arch)
    pols = tuple(
        policy_from_point(select_operating_point(points, t), t,
                          fingerprint="modeled", modeled=True)
        for t in targets)
    return dataclasses.replace(arch, name=f"{arch.name}-tuned",
                               tuned=pols)


# modeled tuned variants of the hierarchical archs. On the reduced CPU
# arch the model trades block budget down against a refine round
# (budget 4 + 1 round at target 0.9); the MS MARCO-scale surface needs
# its top budget rung plus a refine round to clear 0.9. The measured
# tuner on a BUILT index (benchmarks/autotune.py) is the ground truth
# for the budget-down/refine-up trade — these are config-time defaults.
CONFIG_TUNED = with_modeled_tuning(CONFIG_HIER)
REDUCED_TUNED = with_modeled_tuning(REDUCED_HIER)
