"""The paper's own system as a selectable arch: Seismic over a
SPLADE-statistics MS MARCO-scale collection (8.8M docs, vocab 30522,
lambda=6000, beta=400, alpha=0.4 — the paper's best MS MARCO settings,
§7.1). The dry-run lowers the distributed query step; CPU experiments
use the reduced config."""
import dataclasses

from repro.configs.base import ShapeCell
from repro.core.types import SeismicConfig


@dataclasses.dataclass(frozen=True)
class SeismicArchConfig:
    name: str
    index: SeismicConfig
    n_docs: int
    dim: int
    doc_nnz: int
    query_nnz: int

    @property
    def family(self) -> str:
        return "retrieval"


CONFIG = SeismicArchConfig(
    name="seismic-msmarco",
    index=SeismicConfig(lam=6000, beta=400, alpha=0.4, block_cap=64,
                        summary_nnz=96, fwd_dtype="bfloat16"),
    n_docs=8_841_823, dim=30522, doc_nnz=128, query_nnz=48)

SHAPES = [
    ShapeCell("query_batch", "retrieval", dict(batch=4096, k=10, cut=10,
                                               block_budget=64)),
    ShapeCell("query_online", "retrieval", dict(batch=256, k=10, cut=10,
                                                block_budget=64)),
]

REDUCED = SeismicArchConfig(
    name="seismic-reduced",
    index=SeismicConfig(lam=128, beta=8, alpha=0.4, block_cap=32,
                        summary_nnz=32),
    n_docs=2048, dim=1024, doc_nnz=48, query_nnz=16)
