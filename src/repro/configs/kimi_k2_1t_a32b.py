"""kimi-k2-1t-a32b [arXiv:2501.kimi2]: 61L d_model=7168 64H (GQA kv=8)
expert d_ff=2048 vocab=163840, MoE 384 experts top-8 — trillion-param
MoE (paper-table config). One leading dense layer (d_ff=18432) and one
shared expert, matching the released K2 stack."""
from repro.configs.base import TransformerConfig, lm_shapes

CONFIG = TransformerConfig(
    name="kimi-k2-1t-a32b", n_layers=61, d_model=7168, n_heads=64,
    n_kv_heads=8, d_head=112, d_ff=18432, vocab=163840,
    moe=True, n_experts=384, n_shared_experts=1, moe_top_k=8,
    moe_d_ff=2048, n_dense_layers=1, rope_theta=50000.0)

SHAPES = lm_shapes(long_ok=False)

REDUCED = TransformerConfig(
    name="kimi-k2-reduced", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_head=16, d_ff=160, vocab=256,
    moe=True, n_experts=8, n_shared_experts=1, moe_top_k=2,
    moe_d_ff=64, n_dense_layers=1, dtype="float32")
