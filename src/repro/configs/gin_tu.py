"""gin-tu [arXiv:1810.00826]: 5 layers, d_hidden=64, sum aggregator,
learnable eps (GIN-eps)."""
from repro.configs.base import GNNConfig, GNN_SHAPES

CONFIG = GNNConfig(name="gin-tu", n_layers=5, d_hidden=64,
                   aggregator="sum", learn_eps=True)

SHAPES = GNN_SHAPES

REDUCED = GNNConfig(name="gin-tu-reduced", n_layers=3, d_hidden=16,
                    aggregator="sum", learn_eps=True, n_classes=4)
