"""fm [Rendle, ICDM'10]: n_sparse=39 embed_dim=10, pairwise
<v_i, v_j> x_i x_j via the O(nk) sum-square trick. Criteo-like field
vocabulary mix (~10.6M total rows)."""
from repro.configs.base import RecsysConfig, RECSYS_SHAPES

_TABLE_ROWS = tuple([1_000_000] * 8 + [100_000] * 15 + [10_000] * 16)

CONFIG = RecsysConfig(
    name="fm", interaction="fm-2way", embed_dim=10, n_sparse=39,
    table_rows=_TABLE_ROWS, n_dense_feat=13)

SHAPES = RECSYS_SHAPES

REDUCED = RecsysConfig(
    name="fm-reduced", interaction="fm-2way", embed_dim=8, n_sparse=6,
    table_rows=(100, 100, 50, 50, 20, 20), n_dense_feat=4)
