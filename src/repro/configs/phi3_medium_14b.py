"""phi3-medium-14b [arXiv:2404.14219]: 40L d_model=5120 40H (GQA kv=10)
d_ff=17920 vocab=100352 — RoPE SwiGLU GQA."""
from repro.configs.base import TransformerConfig, lm_shapes

CONFIG = TransformerConfig(
    name="phi3-medium-14b", n_layers=40, d_model=5120, n_heads=40,
    n_kv_heads=10, d_head=128, d_ff=17920, vocab=100352)

SHAPES = lm_shapes(long_ok=False)

REDUCED = TransformerConfig(
    name="phi3-medium-14b-reduced", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=1, d_head=16, d_ff=128, vocab=256, dtype="float32")
