"""deepseek-v2-lite-16b [arXiv:2405.04434]: 27L d_model=2048 16H
MLA kv_lora_rank=512, MoE 64 routed experts top-6 + 2 shared, expert
d_ff=1408, vocab=102400. First layer dense (d_ff=10944), per the
released V2-Lite. qk dims: nope 128, rope 64; v_head 128.

Note: the assignment line lists "GQA kv=16" alongside "MLA kv_lora=512";
MLA replaces GQA (latent KV), so n_kv_heads is recorded but unused on
the MLA path (DESIGN.md §5)."""
from repro.configs.base import TransformerConfig, lm_shapes

CONFIG = TransformerConfig(
    name="deepseek-v2-lite-16b", n_layers=27, d_model=2048, n_heads=16,
    n_kv_heads=16, d_head=128, d_ff=10944, vocab=102400,
    moe=True, n_experts=64, n_shared_experts=2, moe_top_k=6,
    moe_d_ff=1408, n_dense_layers=1,
    mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128)

SHAPES = lm_shapes(long_ok=False)

REDUCED = TransformerConfig(
    name="deepseek-v2-lite-reduced", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_head=16, d_ff=160, vocab=256,
    moe=True, n_experts=8, n_shared_experts=2, moe_top_k=2,
    moe_d_ff=48, n_dense_layers=1,
    mla=True, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
    v_head_dim=16, dtype="float32")
