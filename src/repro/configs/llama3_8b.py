"""llama3-8b [arXiv:2407.21783]: 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=128256 — GQA, 128k vocab."""
from repro.configs.base import TransformerConfig, lm_shapes

CONFIG = TransformerConfig(
    name="llama3-8b", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, d_head=128, d_ff=14336, vocab=128256,
    rope_theta=500000.0)

SHAPES = lm_shapes(long_ok=False)

REDUCED = TransformerConfig(
    name="llama3-8b-reduced", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_head=16, d_ff=128, vocab=256, dtype="float32")
