"""bst [arXiv:1905.06874] (Behavior Sequence Transformer, Alibaba):
embed_dim=32 seq_len=20 n_blocks=1 n_heads=8 mlp=1024-512-256,
transformer over the behavior sequence + target item, MLP CTR head."""
from repro.configs.base import RecsysConfig, RECSYS_SHAPES

CONFIG = RecsysConfig(
    name="bst", interaction="transformer-seq", embed_dim=32,
    seq_len=20, n_items=1_000_000, n_blocks=1, n_heads=8,
    mlp_dims=(1024, 512, 256))

SHAPES = RECSYS_SHAPES

REDUCED = RecsysConfig(
    name="bst-reduced", interaction="transformer-seq", embed_dim=16,
    seq_len=8, n_items=1000, n_blocks=1, n_heads=4,
    mlp_dims=(64, 32))
