"""Architecture config system.

Every assigned architecture registers one frozen dataclass under its
public id (``--arch <id>`` in the launchers). Each config also knows:

  * its input-shape set (the assigned (arch x shape) cells),
  * a ``reduced()`` config of the same family for CPU smoke tests,
  * which shapes are skipped and why (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Optional


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (arch x input-shape) cell."""

    name: str
    kind: str                  # "train" | "prefill" | "decode" | "serve" | ...
    dims: dict
    skip: Optional[str] = None  # reason, if the cell is skipped


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # MoE
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    n_dense_layers: int = 0          # leading dense layers in MoE stacks
    capacity_factor: float = 1.25
    # MLA (DeepSeek-V2)
    mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # local:global attention (Gemma-3)
    local_window: int = 0            # 0 = all layers global
    local_per_global: int = 0        # e.g. 5 -> pattern LLLLLG
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: str = "dots"              # "none" | "dots" | "full"
    unroll_layers: bool = False      # python loop instead of scan (used
    #                                  by the dry-run probe lowerings so
    #                                  cost_analysis sees every layer)
    attn_q_chunk: int = 512          # q-tile for the chunked XLA sdpa
    #                                  (probes set >= seq_len: no loop)
    seq_parallel: bool = False       # sequence-parallel residual stream
    #                                  (hillclimb lever, EXPERIMENTS §Perf)
    sharding_mode: str = "tp"        # "tp" (Megatron) | "fsdp" (params
    #                                  sharded over ALL axes, comm scales
    #                                  with params not tokens — §Perf;
    #                                  dense archs only)

    @property
    def family(self) -> str:
        return "lm"

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, h, kv, dh = self.d_model, self.n_heads, self.n_kv_heads, self.d_head
        emb = self.vocab * d * 2  # in + out (untied)
        if self.mla:
            attn = d * (h * (self.qk_nope_dim + self.qk_rope_dim))  # W_q
            attn += d * self.kv_lora_rank + d * self.qk_rope_dim    # W_dkv, W_kr
            attn += self.kv_lora_rank * h * (self.qk_nope_dim + self.v_head_dim)
            attn += h * self.v_head_dim * d                          # W_o
        else:
            attn = d * h * dh + 2 * d * kv * dh + h * dh * d
        dense_ffn = 3 * d * self.d_ff
        n_moe = self.n_layers - self.n_dense_layers if self.moe else 0
        n_dense = self.n_layers - n_moe
        per_moe = 0
        if self.moe:
            per_moe = (self.n_experts + self.n_shared_experts) * 3 * d * self.moe_d_ff
            per_moe += d * self.n_experts  # router
        return (emb + self.n_layers * attn + n_dense * dense_ffn
                + n_moe * per_moe + self.n_layers * 2 * d + d)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        n_moe = self.n_layers - self.n_dense_layers
        all_experts = n_moe * self.n_experts * 3 * d * self.moe_d_ff
        active = n_moe * (self.moe_top_k + self.n_shared_experts) * 3 * d * self.moe_d_ff
        return full - all_experts + active


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int
    d_hidden: int
    aggregator: str = "sum"
    learn_eps: bool = True
    n_classes: int = 16
    mlp_layers: int = 2
    dtype: str = "float32"
    aggregate_mode: str = "psum"     # "psum" (vertex-cut baseline) |
    #                                  "shard" (node-sharded MLP +
    #                                  reduce-scatter/all-gather, §Perf)

    @property
    def family(self) -> str:
        return "gnn"


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    interaction: str                 # "fm-2way" | "concat" | "self-attn-seq" | "transformer-seq"
    embed_dim: int
    n_sparse: int = 0                # categorical fields (fm / wide-deep)
    table_rows: tuple = ()           # per-field vocab sizes
    n_dense_feat: int = 0
    mlp_dims: tuple = ()
    # sequence models (sasrec / bst)
    seq_len: int = 0
    n_items: int = 0
    n_blocks: int = 0
    n_heads: int = 0
    dtype: str = "float32"

    @property
    def family(self) -> str:
        return "recsys"

    def total_rows(self) -> int:
        return sum(self.table_rows) + self.n_items


ArchConfig = TransformerConfig | GNNConfig | RecsysConfig

# id -> (module, attr); modules define CONFIG, SHAPES, REDUCED
ARCH_REGISTRY: dict[str, str] = {
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "llama3-8b": "repro.configs.llama3_8b",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "gin-tu": "repro.configs.gin_tu",
    "sasrec": "repro.configs.sasrec",
    "bst": "repro.configs.bst",
    "fm": "repro.configs.fm",
    "wide-deep": "repro.configs.wide_deep",
    # the paper's own system as a selectable arch
    "seismic-msmarco": "repro.configs.seismic_msmarco",
}


def get_arch(arch_id: str):
    """Returns the config module for an arch id (CONFIG, SHAPES, REDUCED)."""
    if arch_id not in ARCH_REGISTRY:
        raise KeyError(f"unknown arch '{arch_id}'; known: {list_archs()}")
    return importlib.import_module(ARCH_REGISTRY[arch_id])


def list_archs() -> list[str]:
    return sorted(ARCH_REGISTRY)


def lm_shapes(long_ok: bool, why_not: str = "") -> list[ShapeCell]:
    """The assigned LM-family shape set."""
    cells = [
        ShapeCell("train_4k", "train", dict(seq_len=4096, global_batch=256)),
        ShapeCell("prefill_32k", "prefill", dict(seq_len=32768, global_batch=32)),
        ShapeCell("decode_32k", "decode", dict(seq_len=32768, global_batch=128)),
    ]
    skip = None if long_ok else (why_not or
                                 "pure full-attention arch; long_500k needs "
                                 "sub-quadratic attention (DESIGN.md §5)")
    cells.append(ShapeCell("long_500k", "decode",
                           dict(seq_len=524288, global_batch=1), skip=skip))
    return cells


GNN_SHAPES = [
    ShapeCell("full_graph_sm", "train",
              dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7)),
    ShapeCell("minibatch_lg", "train",
              dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
                   fanout=(15, 10), d_feat=602, n_classes=41)),
    ShapeCell("ogb_products", "train",
              dict(n_nodes=2449029, n_edges=61859140, d_feat=100,
                   n_classes=47)),
    ShapeCell("molecule", "train",
              dict(n_nodes=30, n_edges=64, batch=128, d_feat=16,
                   n_classes=2)),
]

RECSYS_SHAPES = [
    ShapeCell("train_batch", "train", dict(batch=65536)),
    ShapeCell("serve_p99", "serve", dict(batch=512)),
    ShapeCell("serve_bulk", "serve", dict(batch=262144)),
    ShapeCell("retrieval_cand", "retrieval", dict(batch=1, n_candidates=1000000)),
]
