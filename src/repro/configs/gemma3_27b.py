"""gemma3-27b [hf:google/gemma-3-*]: 62L d_model=5376 32H (GQA kv=16)
d_ff=21504 vocab=262144 — 5:1 local:global sliding-window attention,
window 1024, 128k context.

long_500k RUNS for this arch: 5/6 of the layers attend within a
1024-token window, so decode cost/caches are bounded for them; only
every 6th (global) layer touches the full 500k cache (DESIGN.md §5).
"""
from repro.configs.base import TransformerConfig, lm_shapes

CONFIG = TransformerConfig(
    name="gemma3-27b", n_layers=62, d_model=5376, n_heads=32,
    n_kv_heads=16, d_head=128, d_ff=21504, vocab=262144,
    local_window=1024, local_per_global=5, rope_theta=1000000.0)

SHAPES = lm_shapes(long_ok=True)

REDUCED = TransformerConfig(
    name="gemma3-27b-reduced", n_layers=6, d_model=64, n_heads=4,
    n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
    local_window=16, local_per_global=5, dtype="float32")
