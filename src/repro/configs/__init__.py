from repro.configs.base import (ARCH_REGISTRY, ArchConfig, GNNConfig,
                                RecsysConfig, TransformerConfig, get_arch,
                                list_archs)

__all__ = ["ARCH_REGISTRY", "ArchConfig", "GNNConfig", "RecsysConfig",
           "TransformerConfig", "get_arch", "list_archs"]
