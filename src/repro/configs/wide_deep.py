"""wide-deep [arXiv:1606.07792]: n_sparse=40 embed_dim=32
mlp=1024-512-256, wide linear + deep MLP over concatenated embeddings."""
from repro.configs.base import RecsysConfig, RECSYS_SHAPES

_TABLE_ROWS = tuple([1_000_000] * 8 + [100_000] * 16 + [10_000] * 16)

CONFIG = RecsysConfig(
    name="wide-deep", interaction="concat", embed_dim=32, n_sparse=40,
    table_rows=_TABLE_ROWS, n_dense_feat=13, mlp_dims=(1024, 512, 256))

SHAPES = RECSYS_SHAPES

REDUCED = RecsysConfig(
    name="wide-deep-reduced", interaction="concat", embed_dim=8,
    n_sparse=6, table_rows=(100, 100, 50, 50, 20, 20), n_dense_feat=4,
    mlp_dims=(32, 16))
