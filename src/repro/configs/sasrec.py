"""sasrec [arXiv:1808.09781]: embed_dim=50 n_blocks=2 n_heads=1
seq_len=50, self-attention sequential recommender. Item vocabulary is
production-scale 1M (the retrieval_cand cell scores 1M candidates)."""
from repro.configs.base import RecsysConfig, RECSYS_SHAPES

CONFIG = RecsysConfig(
    name="sasrec", interaction="self-attn-seq", embed_dim=50,
    seq_len=50, n_items=1_000_000, n_blocks=2, n_heads=1)

SHAPES = RECSYS_SHAPES

REDUCED = RecsysConfig(
    name="sasrec-reduced", interaction="self-attn-seq", embed_dim=16,
    seq_len=12, n_items=1000, n_blocks=2, n_heads=1)
