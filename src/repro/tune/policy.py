"""``TunedPolicy`` — a persisted recall-target operating point.

The quality/cost trade-off of the staged pipeline is governed by a
COUPLED knob set (``block_budget`` x ``policy`` factors x superblock
budget x ``refine_rounds``): halving ``block_budget`` loses recall that
one refine round often buys back at a fraction of the scoring work, so
the knobs only make sense tuned together, per collection, against a
recall target (paper §5 tunes them by hand; Mallia et al. 2024 and
Bruch et al. 2023 show the selection-policy + budget pair is the
decisive lever). ``repro.tune`` turns the hand-tuned constants into a
first-class index artifact:

  * ``TunedPolicy`` is the frozen, JSON-round-trippable record of one
    tuned operating point: the recall target it was tuned for, every
    quality knob of ``SearchParams``, the measured recall / cost on the
    held-out sample, and an order-invariant fingerprint of that sample.
  * A ``SeismicIndex`` carries a tuple of them (static metadata, like
    ``config``); ``ckpt.save_index`` persists them in the manifest with
    pre-tune back-compat (old checkpoints load with ``tuned == ()``).
  * ``SearchParams.from_tuned(index, target)`` resolves the cheapest
    persisted policy meeting a target back into pipeline params,
    bit-exactly (every knob is stored, nothing is re-derived).
  * Serving validates the persisted policies against the index at
    construction (:func:`validate_tuned_index`), so a stale policy
    (graph dropped, superblock tier rebuilt with another fanout) fails
    fast instead of at trace time.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.retrieval.params import SearchParams

# SearchParams quality knobs a tuned policy pins (everything except the
# execution details ``use_kernel`` / ``fuse_level``, which the caller
# picks per backend — they never change results)
KNOB_FIELDS = ("k", "cut", "block_budget", "heap_factor", "policy",
               "probe_budget", "threshold_factor", "superblock_fanout",
               "superblock_budget", "graph_degree", "refine_rounds")

# recall comparisons tolerate one float ulp-ish of slack so a policy
# measured exactly AT the target is feasible after a JSON round-trip
RECALL_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class TunedPolicy:
    """One tuned operating point (frozen + hashable: it rides the index
    pytree as static metadata, like ``SeismicConfig``)."""

    target: float                  # recall@k target it was tuned for
    # ---- the coupled knob set (mirrors SearchParams' quality knobs)
    k: int = 10
    cut: int = 8
    block_budget: int = 32
    heap_factor: float = 0.9
    policy: str = "budget"
    probe_budget: int = 8
    threshold_factor: float = 0.75
    superblock_fanout: int = 0
    superblock_budget: int = 16
    graph_degree: int = 0
    refine_rounds: int = 0
    # ---- what the tuner measured on the held-out sample
    measured_recall: float = 0.0   # mean recall@k
    measured_cost: float = 0.0     # mean docs exactly scored per query
    router_cost: int = 0           # summary dots per query (router_work)
    sample_fingerprint: str = ""   # order-invariant sample digest
    modeled: bool = False          # True: config-time model, not measured

    def to_params(self, *, use_kernel: bool = False,
                  fuse_level: int = 0) -> SearchParams:
        """The pipeline params this policy pins — bit-exact: every knob
        is stored on the policy, nothing is re-derived. ``use_kernel``
        and ``fuse_level`` are execution details (results identical at
        every level), so the caller picks them per backend."""
        return SearchParams(use_kernel=use_kernel, fuse_level=fuse_level,
                            **{f: getattr(self, f) for f in KNOB_FIELDS})

    def satisfies(self, target: float) -> bool:
        return self.measured_recall >= target - RECALL_EPS


def knobs_from_params(p: SearchParams) -> dict:
    """The persistable quality-knob subset of ``SearchParams``."""
    return {f: getattr(p, f) for f in KNOB_FIELDS}


def sample_fingerprint(coords, vals) -> str:
    """Order-invariant digest of a held-out query sample.

    Per-query row digests are sorted before the final hash, so a
    permuted sample fingerprints identically — the tuner's selection is
    order-invariant (means over queries), and the fingerprint must be
    too, or re-tuning on a shuffled sample would look like a different
    sample.
    """
    c = np.ascontiguousarray(np.asarray(coords))
    v = np.ascontiguousarray(np.asarray(vals, np.float32))
    rows = sorted(
        hashlib.sha256(c[i].tobytes() + v[i].tobytes()).digest()
        for i in range(c.shape[0]))
    return hashlib.sha256(b"".join(rows)).hexdigest()[:16]


def row_digest(coords_row, vals_row) -> bytes:
    """Canonical digest of ONE padded-sparse query row.

    Unlike :func:`sample_fingerprint`'s raw-bytes row hash (kept
    byte-stable for persisted policies), this canonicalizes first —
    dtypes pinned to i32/f32, padding coordinates zeroed, entries
    sorted by (coord, val) — so a query digests identically however
    its nnz entries are ordered or padded. The quality plane's drift
    sketch uses these to test served queries for literal membership in
    the tuning sample.
    """
    c = np.asarray(coords_row, np.int32).reshape(-1)
    v = np.asarray(vals_row, np.float32).reshape(-1)
    c = np.where(v > 0, c, 0)
    v = np.where(v > 0, v, 0.0).astype(np.float32)
    order = np.lexsort((v, c))
    c = np.ascontiguousarray(c[order])
    v = np.ascontiguousarray(v[order])
    return hashlib.sha256(c.tobytes() + v.tobytes()).digest()


def row_digests(coords, vals) -> list[bytes]:
    """Per-row :func:`row_digest` over a [Q, nnz] padded sample."""
    c = np.asarray(coords)
    v = np.asarray(vals)
    return [row_digest(c[i], v[i]) for i in range(c.shape[0])]


def attach_tuned(index, policies) -> "SeismicIndex":  # noqa: F821
    """Return the index carrying ``policies`` (sorted by target then
    cost, so the persisted tuple is deterministic regardless of tuning
    order). Replaces any previously attached policies."""
    pols = tuple(sorted(policies,
                        key=lambda t: (t.target, t.measured_cost,
                                       t.measured_recall)))
    for t in pols:
        validate_policy(index, t)
    return dataclasses.replace(index, tuned=pols)


def validate_policy(index, policy: TunedPolicy) -> None:
    """Fail fast when a (possibly persisted) policy no longer matches
    the index it rides on — the serve-construction check."""
    from repro.graph.refine import validate_refine_params
    from repro.retrieval.selector import selector_names
    if not (0.0 < policy.target <= 1.0):
        raise ValueError(f"TunedPolicy.target must be in (0, 1], got "
                         f"{policy.target}")
    if policy.k < 1 or policy.cut < 1 or policy.block_budget < 1:
        raise ValueError(
            f"TunedPolicy has degenerate knobs: k={policy.k}, "
            f"cut={policy.cut}, block_budget={policy.block_budget}")
    if policy.policy not in selector_names():
        raise ValueError(
            f"TunedPolicy.policy {policy.policy!r} is not a registered "
            f"selector (have {sorted(selector_names())})")
    if policy.superblock_fanout > 0:
        if index.sup_coords is None:
            raise ValueError(
                "TunedPolicy routes hierarchically (superblock_fanout="
                f"{policy.superblock_fanout}) but the index has no "
                "superblock tier")
        if policy.superblock_fanout != index.config.superblock_fanout:
            raise ValueError(
                f"TunedPolicy superblock_fanout={policy.superblock_fanout}"
                f" mismatches the index tier "
                f"({index.config.superblock_fanout})")
    validate_refine_params(index, policy.to_params())


def validate_tuned_index(index) -> None:
    """Validate every policy attached to an index (serve construction:
    a stale persisted policy must fail before the first launch)."""
    for t in getattr(index, "tuned", ()) or ():
        validate_policy(index, t)
