"""Operating-point sweep: drive a knob grid through the batched
pipeline and measure recall + deterministic cost per point.

Every grid point runs the EXISTING ``search_pipeline`` (or, with
``timings=True``, ``run_pipeline_staged`` so per-stage wall seconds
ride along) over the whole held-out query batch. The cost model is the
hardware-independent pair the pipeline already reports:

  * ``docs_evaluated`` — documents exactly scored per query (scorer
    stage + every refine round's genuinely-new frontier; the merge and
    refine stages count distinct documents), and
  * ``router_work``    — summary inner products per query (the
    closed-form phase-R work model).

Wall-clock stage timings are recorded as ADVISORY data only: selection
must be bit-reproducible and invariant to machine load and to the
order of the query sample, so the frontier orders points purely by the
deterministic (docs_evaluated, router_cost) pair.

Order invariance is engineered, not assumed: per-query recalls are
sorted before the mean is taken (float addition is not associative —
a permuted sample would otherwise perturb the mean by an ulp and could
flip the argmin between cost-tied points), and ``docs_evaluated`` sums
exact integers.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Sequence

import numpy as np

from repro.retrieval.params import SearchParams
from repro.retrieval.router import router_work

if TYPE_CHECKING:  # annotation-only: keeps repro.tune import-cycle-free
    from repro.core.types import SeismicIndex
    from repro.sparse.ops import PaddedSparse


@dataclasses.dataclass(frozen=True)
class MeasuredPoint:
    """One swept operating point with its measurements."""

    params: SearchParams
    recall: float                # mean recall@k on the held-out sample
    docs_evaluated: float        # mean docs exactly scored per query
    router_cost: int             # summary dots per query (closed form)
    stage_seconds: tuple = ()    # advisory: (("prep", s), ...) wall time

    @property
    def advisory_seconds(self) -> float | None:
        """Total staged wall seconds for the sample — the ADVISORY cost
        column (None when the point was measured without timings).
        Reported next to the deterministic costs, never selected on."""
        if not self.stage_seconds:
            return None
        return sum(s for _, s in self.stage_seconds)

    @property
    def cost_key(self) -> tuple:
        """Deterministic total order for frontier/selection: scoring
        work first, routing work second, then the knob tuple so exact
        cost ties break reproducibly (never by sweep order or wall
        time)."""
        return (self.docs_evaluated, self.router_cost,
                dataclasses.astuple(self.params))


def default_grid(index: SeismicIndex, *, k: int = 10, cut: int = 8
                 ) -> list[SearchParams]:
    """The coupled knob grid for one collection.

    Budgets ladder geometrically; each budget is paired against refine
    rounds when the index carries a kNN graph (co-tuning: ``refine``
    evaluates ~``k * degree`` docs per round, often cheaper than the
    blocks a halved budget drops) and against the superblock tier when
    one is built. Policy factors ride at the two LARGEST budgets,
    where the selector has candidates left to prune away.
    """
    cfg = index.config
    max_budget = cut * cfg.n_blocks          # selector top_k axis bound
    ladder = [b for b in (2, 4, 8, 16, 32, 64) if b <= max_budget]
    if not ladder:
        ladder = [max_budget]
    degree = min(index.graph_degree, 8)
    refine = [(0, 0)]
    if degree > 0:
        refine += [(degree, 1), (degree, 2)]
    grid: list[SearchParams] = []
    for budget in ladder:
        for deg, rounds in refine:
            grid.append(SearchParams(
                k=k, cut=cut, block_budget=budget, policy="budget",
                graph_degree=deg, refine_rounds=rounds))
    # policy factors at the two largest budgets (pruning headroom)
    for budget in ladder[-2:]:
        for hf in (0.8, 0.9):
            grid.append(SearchParams(k=k, cut=cut, block_budget=budget,
                                     policy="adaptive", heap_factor=hf,
                                     probe_budget=min(8, budget)))
        for tf in (0.6, 0.75):
            grid.append(SearchParams(k=k, cut=cut, block_budget=budget,
                                     policy="global_threshold",
                                     threshold_factor=tf))
    # hierarchical variants: route through the built superblock tier
    if index.sup_coords is not None:
        f = cfg.superblock_fanout
        for budget in ladder:
            for deg, rounds in refine:
                grid.append(SearchParams(
                    k=k, cut=cut, block_budget=budget, policy="budget",
                    superblock_fanout=f,
                    superblock_budget=max(2, budget // max(f // 2, 1)),
                    graph_degree=deg, refine_rounds=rounds))
    return grid


def _per_query_recall(ids: np.ndarray, exact_ids: np.ndarray) -> np.ndarray:
    from repro.obs.quality import per_query_recall
    return per_query_recall(ids, exact_ids)


def measure_point(index: SeismicIndex, queries: PaddedSparse,
                  exact_ids: np.ndarray, p: SearchParams, *,
                  timings: bool = False) -> MeasuredPoint:
    """Run one operating point over the whole held-out batch."""
    stage_s: dict[str, float] = {}
    if timings:
        from repro.retrieval.pipeline import run_pipeline_staged

        def record(name, secs):
            stage_s[name] = stage_s.get(name, 0.0) + secs

        _, ids, ev = run_pipeline_staged(index, queries.coords,
                                         queries.vals, p, record=record)
    else:
        from repro.retrieval.pipeline import search_pipeline
        _, ids, ev = search_pipeline(index, queries, p)
    ids = np.asarray(ids)
    ev = np.asarray(ev, np.int64)
    # sorted before the mean: bit-identical under sample permutation
    rec = np.sort(_per_query_recall(ids, exact_ids))
    recall = float(rec.sum() / rec.size)
    docs = float(int(ev.sum()) / ev.size)
    return MeasuredPoint(
        params=p, recall=recall, docs_evaluated=docs,
        router_cost=router_work(index.config, p),
        stage_seconds=tuple(sorted(stage_s.items())))


def sweep(index: SeismicIndex, queries: PaddedSparse,
          exact_ids: np.ndarray, *, k: int = 10, cut: int = 8,
          grid: Sequence[SearchParams] | None = None,
          timings: bool = False) -> list[MeasuredPoint]:
    """Measure every grid point (default: :func:`default_grid`).

    The returned list preserves grid order; dedupe happens here so a
    hand-assembled grid with repeats doesn't measure twice.
    """
    if grid is None:
        grid = default_grid(index, k=k, cut=cut)
    seen: set[SearchParams] = set()
    points = []
    for p in grid:
        if p in seen:
            continue
        seen.add(p)
        points.append(measure_point(index, queries, exact_ids, p,
                                    timings=timings))
    return points
