"""Recall/cost Pareto frontier + recall-target operating-point
selection (the tuner's decision layer).

All ordering is by ``MeasuredPoint.cost_key`` — the deterministic
(docs_evaluated, router_cost, knob-tuple) triple — never by wall time
or sweep order, so the selected point is bit-reproducible and invariant
to a permutation of the held-out query sample (see ``sweep.py``).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Sequence

from repro.tune.policy import (RECALL_EPS, TunedPolicy, attach_tuned,
                               knobs_from_params, sample_fingerprint)
from repro.tune.sweep import MeasuredPoint, sweep

if TYPE_CHECKING:  # annotation-only: keeps repro.tune import-cycle-free
    import numpy as np
    from repro.core.types import SeismicIndex
    from repro.sparse.ops import PaddedSparse


def pareto_frontier(points: Sequence[MeasuredPoint]
                    ) -> list[MeasuredPoint]:
    """The non-dominated subset, cost-ascending / recall-ascending.

    A point is kept iff no other point reaches >= its recall at < its
    true cost — the (docs_evaluated, router_cost) pair — nor the same
    cost at higher recall. The sort therefore orders equal-cost points
    recall-DESCENDING before the scan (the knob tuple breaks only
    exact (cost, recall) ties, for determinism); ordering by the full
    ``cost_key`` here would let a lower-recall point with a smaller
    knob tuple shadow its equal-cost better sibling. By construction
    the result is strictly monotone: walking toward higher recall is
    walking toward higher cost.
    """
    frontier: list[MeasuredPoint] = []
    best = float("-inf")
    for pt in sorted(points,
                     key=lambda t: (t.docs_evaluated, t.router_cost,
                                    -t.recall,
                                    dataclasses.astuple(t.params))):
        if pt.recall > best + RECALL_EPS:
            frontier.append(pt)
            best = pt.recall
    return frontier


def select_operating_point(points: Sequence[MeasuredPoint],
                           target: float) -> MeasuredPoint:
    """The cheapest measured point whose recall meets ``target``.

    Raises ``ValueError`` naming the best achievable recall when the
    target is infeasible on this sweep (the caller widens the grid or
    lowers the target — silently under-delivering recall is not an
    option for a persisted artifact).
    """
    feasible = [pt for pt in points if pt.recall >= target - RECALL_EPS]
    if not feasible:
        best = max((pt.recall for pt in points), default=0.0)
        raise ValueError(
            f"recall target {target:.4f} is infeasible on this sweep "
            f"(best achievable {best:.4f} over {len(points)} points); "
            "widen the grid (larger block_budget / refine_rounds) or "
            "lower the target")
    return min(feasible, key=lambda pt: pt.cost_key)


def policy_from_point(point: MeasuredPoint, target: float,
                      fingerprint: str = "", *,
                      modeled: bool = False) -> TunedPolicy:
    """Freeze a selected point into the persistable artifact."""
    return TunedPolicy(target=target,
                       measured_recall=point.recall,
                       measured_cost=point.docs_evaluated,
                       router_cost=point.router_cost,
                       sample_fingerprint=fingerprint, modeled=modeled,
                       **knobs_from_params(point.params))


def tune(index: SeismicIndex, queries: PaddedSparse,
         exact_ids: "np.ndarray", target: float, *, k: int = 10,
         cut: int = 8, grid=None, timings: bool = False,
         points: Sequence[MeasuredPoint] | None = None) -> TunedPolicy:
    """Sweep (unless ``points`` is a pre-measured sweep), select the
    cheapest operating point meeting ``target``, and freeze it.

    Deterministic end to end: same index + same query sample (in any
    order) + same grid -> the identical ``TunedPolicy``, bit for bit.
    """
    if points is None:
        points = sweep(index, queries, exact_ids, k=k, cut=cut,
                       grid=grid, timings=timings)
    chosen = select_operating_point(points, target)
    return policy_from_point(chosen, target,
                             sample_fingerprint(queries.coords,
                                                queries.vals))


def tune_and_attach(index: SeismicIndex, queries: PaddedSparse,
                    exact_ids: "np.ndarray",
                    targets: Sequence[float], *, k: int = 10,
                    cut: int = 8, grid=None,
                    timings: bool = False) -> SeismicIndex:
    """Tune one policy per target over a single shared sweep and attach
    them to the index (``ckpt.save_index`` then persists them)."""
    points = sweep(index, queries, exact_ids, k=k, cut=cut, grid=grid,
                   timings=timings)
    pols = [tune(index, queries, exact_ids, t, points=points)
            for t in targets]
    return attach_tuned(index, pols)
