"""Recall-target operating-point autotuner (``repro.tune``).

Sweeps the coupled quality-knob space (``block_budget`` x selector
policy factors x superblock budget x ``refine_rounds``) against a
held-out query sample through the existing batched pipeline, builds
the recall/cost Pareto frontier on the deterministic
(docs_evaluated, router_work) cost model, and freezes the cheapest
point meeting a caller-given recall target into a persisted
``TunedPolicy`` index artifact. See ``src/repro/tune/README.md``.

    from repro.tune import tune_and_attach
    idx = tune_and_attach(idx, held_out, exact_ids, targets=[0.9, 0.95])
    save_index(path, idx)                         # policy rides the ckpt
    ...
    p = SearchParams.from_tuned(load_index(path), target=0.9)
"""
from repro.tune.frontier import (pareto_frontier, policy_from_point,
                                 select_operating_point, tune,
                                 tune_and_attach)
from repro.tune.policy import (KNOB_FIELDS, TunedPolicy, attach_tuned,
                               knobs_from_params, sample_fingerprint,
                               validate_policy, validate_tuned_index)
from repro.tune.sweep import MeasuredPoint, default_grid, measure_point, sweep

__all__ = [
    "TunedPolicy", "MeasuredPoint", "KNOB_FIELDS",
    "default_grid", "measure_point", "sweep",
    "pareto_frontier", "select_operating_point", "policy_from_point",
    "tune", "tune_and_attach",
    "attach_tuned", "knobs_from_params", "sample_fingerprint",
    "validate_policy", "validate_tuned_index",
]
