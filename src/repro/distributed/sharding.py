"""Logical-axis sharding helpers.

Model code annotates tensors with *logical* axes ("dp", "tp", None);
these resolve against the ambient mesh (set via ``jax.set_mesh``):

  "dp" -> every data-parallel axis present:   ("pod", "data")
  "tp" -> the tensor/model-parallel axis:     "model"

With no ambient mesh (single-device smoke tests) every constraint is a
no-op, so the same model code runs unsharded on CPU and sharded on the
production meshes without changes.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _ambient_axes() -> tuple[str, ...]:
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return ()
    return tuple(mesh.axis_names)


def dp_axes() -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in _ambient_axes())


def tp_axis() -> str | None:
    return "model" if "model" in _ambient_axes() else None


def mesh_axis_size(name: str) -> int:
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def _resolve(n):
    if n == "dp":
        ax = dp_axes()
        return ax if ax else None
    if n == "tp":
        return tp_axis()
    if n is None:
        return None
    return n if n in _ambient_axes() else None


def logical(*names) -> P:
    """Resolve logical axis names to a PartitionSpec on the ambient
    mesh. A tuple entry (e.g. ("dp", "tp")) combines the resolved axes
    of its members onto one positional dimension (FSDP batch)."""
    out = []
    for n in names:
        if isinstance(n, tuple):
            axes: list = []
            for m in n:
                r = _resolve(m)
                if r is None:
                    continue
                axes.extend(r if isinstance(r, tuple) else (r,))
            out.append(tuple(axes) if axes else None)
        else:
            out.append(_resolve(n))
    return P(*out)


def shard(x: jax.Array, *names) -> jax.Array:
    """with_sharding_constraint against logical axes; no-op without a mesh."""
    if not _ambient_axes():
        return x
    return jax.lax.with_sharding_constraint(x, logical(*names))
