"""Rule-based parameter/batch/cache PartitionSpecs.

One place maps every param leaf to its mesh axes (Megatron-style TP on
"model"; DP axes = ("pod", "data") when present). ZeRO-1 sharding of
the optimizer state over the DP axes is a transform on these specs.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

TP = "model"


def _names(path) -> list[str]:
    out = []
    for p in path:
        if isinstance(p, DictKey):
            out.append(str(p.key))
        elif isinstance(p, SequenceKey):
            out.append(f"[{p.idx}]")
    return out


def _lm_rule(names: list[str], ndim: int) -> P:
    name = names[-1]
    stacked = "layers" in names
    base_nd = ndim - (1 if stacked else 0)
    if name in ("embed", "out_embed"):
        return P(TP, None)
    if name in ("wq", "wk", "wv", "w_uk", "w_uv"):
        spec = (None, TP)
    elif name == "wo":
        spec = (TP, None)
    elif name in ("w1", "w3"):
        # dense ffn [d, ff] -> col shard; moe experts [E, d, ff] -> E shard
        spec = (TP, None, None) if base_nd == 3 else (None, TP)
    elif name == "w2":
        spec = (TP, None, None) if base_nd == 3 else (TP, None)
    elif name in ("w_dkv", "w_kr", "router"):
        spec = (None,) * base_nd
    else:  # norms, biases, scalars
        spec = (None,) * base_nd
    if stacked:
        spec = (None,) + tuple(spec)
    return P(*spec)


def _lm_rule_fsdp(names: list[str], ndim: int, shape) -> P:
    """FSDP: every weight matrix row-sharded over (data, model); per-
    layer all-gathers replace the per-token TP all-reduces. Vocab
    matrices keep the Megatron vocab shard on model (2D: fsdp body +
    vocab-parallel head)."""
    name = names[-1]
    stacked = "layers" in names
    base_nd = ndim - (1 if stacked else 0)
    base_shape = shape[1:] if stacked else shape
    if name in ("embed", "out_embed"):
        return P(("data", TP), None)
    two_plus = base_nd >= 2
    if two_plus and name not in ("router",):
        # shard the first dim divisible by the full world
        spec = [None] * base_nd
        for i, dim in enumerate(base_shape):
            if dim % (16 * 16) == 0:
                spec[i] = ("data", TP)
                break
        else:
            for i, dim in enumerate(base_shape):
                if dim % 16 == 0:
                    spec[i] = TP
                    break
    else:
        spec = [None] * base_nd
    if stacked:
        spec = [None] + spec
    return P(*spec)


def lm_param_specs(params, mode: str = "tp") -> dict:
    """PartitionSpec tree for LM params (works on arrays or SDS)."""
    if mode == "fsdp":
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: _lm_rule_fsdp(_names(path), leaf.ndim,
                                             leaf.shape), params)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _lm_rule(_names(path), np.ndim(leaf) if not
                                    hasattr(leaf, "ndim") else leaf.ndim),
        params)


def recsys_param_specs(params) -> dict:
    """Embedding tables row-sharded over TP; everything else replicated."""
    def rule(path, leaf):
        names = _names(path)
        name = names[-1]
        if name in ("item_emb", "emb", "v", "w_lin", "wide") \
                and leaf.ndim == 2 and leaf.shape[0] % 16 == 0:
            return P(TP, None)
        return P(*(None,) * leaf.ndim)
    return jax.tree_util.tree_map_with_path(rule, params)


def gnn_param_specs(params) -> dict:
    return jax.tree.map(lambda l: P(*(None,) * l.ndim), params)


def cache_specs(cache, dp, dp_size: int = 0, tp_size: int = 0) -> dict:
    """Decode caches: batch over DP, cache length over TP (updates use
    the one-hot formulation so the sharded dim partitions cleanly).
    Small batches (e.g. long_500k's batch=1) fall back to sharding the
    cache length over DP+TP together."""
    def rule(path, leaf):
        names = _names(path)
        name = names[-1]
        if name in ("k", "v", "ckv", "kr", "k_local", "v_local",
                    "k_global", "v_global"):       # [L, B, T, ...]
            b, t = leaf.shape[1], leaf.shape[2]
            if dp_size and b % dp_size != 0:
                axes = (tuple(dp) if isinstance(dp, (tuple, list))
                        else (dp,)) + (TP,)
                size = dp_size * max(tp_size, 1)
                if t % size == 0:
                    return P(None, None, axes, *(None,) * (leaf.ndim - 3))
                return P(None, None, TP, *(None,) * (leaf.ndim - 3))
            return P(None, dp, TP, *(None,) * (leaf.ndim - 3))
        if name in ("k0", "v0", "ckv0", "kr0"):    # [B, T, ...]
            b = leaf.shape[0]
            if dp_size and b % dp_size != 0:
                return P(None, TP, *(None,) * (leaf.ndim - 2))
            return P(dp, TP, *(None,) * (leaf.ndim - 2))
        return P(*(None,) * leaf.ndim)
    return jax.tree_util.tree_map_with_path(rule, cache)


def zero_shard_spec(spec: P, shape: tuple, dp, dp_size: int) -> P:
    """ZeRO-1: additionally shard the first dim that is unsharded and
    divisible by the DP world size. No-op for params already sharded
    over a DP axis (FSDP mode)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    dp_axes = set(dp) if isinstance(dp, (tuple, list)) else {dp}
    for ax in parts:
        axes = set(ax) if isinstance(ax, (tuple, list)) else {ax}
        if axes & dp_axes:
            return P(*parts)          # already DP-sharded
    for i, (ax, dim) in enumerate(zip(parts, shape)):
        if ax is None and dim % dp_size == 0 and dim >= dp_size:
            parts[i] = dp
            return P(*parts)
    return P(*parts)


def opt_state_specs(param_specs, params, *, zero: bool = False,
                    dp=("pod", "data"), dp_size: int = 1) -> dict:
    """Optimizer-state specs mirror the params; ZeRO adds DP sharding."""
    if not zero:
        mv = param_specs
    else:
        mv = jax.tree.map(
            lambda s, p: zero_shard_spec(s, p.shape, dp, dp_size),
            param_specs, params)
    return dict(m=mv, v=mv, step=P())
