"""Roofline math for TPU v5e (the TARGET hardware; this container is
CPU-only so terms are derived from the compiled artifact, not walltime).

Hardware constants (per chip):
  peak bf16 compute : 197 TFLOP/s
  HBM bandwidth     : 819 GB/s
  ICI link bandwidth: ~50 GB/s per link (3D-torus links per chip
                      counted as ``n_links``; the conservative default
                      1 attributes all collective bytes to one link)

Terms (seconds, per device, per step):
  T_compute    = flops / PEAK_FLOPS
  T_memory     = hbm_bytes / HBM_BW
  T_collective = collective_bytes / (n_links * ICI_BW)
"""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


@dataclasses.dataclass(frozen=True)
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    n_links: int = 1

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.n_links * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = dict(compute=self.t_compute, memory=self.t_memory,
                     collective=self.t_collective)
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Lower-bound step time = max term (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def compute_fraction(self) -> float:
        """How compute-bound the cell is: t_compute / t_bound. 1.0 means
        the chip's MXUs are the limiter (the roofline optimum for
        flops-dominated kernels)."""
        t = self.t_bound
        return self.t_compute / t if t > 0 else 0.0

    def as_dict(self) -> dict:
        return dict(flops=self.flops, hbm_bytes=self.hbm_bytes,
                    coll_bytes=self.coll_bytes,
                    t_compute=self.t_compute, t_memory=self.t_memory,
                    t_collective=self.t_collective,
                    bottleneck=self.bottleneck,
                    compute_fraction=self.compute_fraction())


def model_flops_train(n_params_active: int, n_tokens: int) -> float:
    """6 * N * D for one training step (fwd+bwd)."""
    return 6.0 * n_params_active * n_tokens


def model_flops_infer(n_params_active: int, n_tokens: int) -> float:
    """2 * N * D for forward-only."""
    return 2.0 * n_params_active * n_tokens
