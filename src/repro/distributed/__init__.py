from repro.distributed.sharding import (dp_axes, logical, mesh_axis_size,
                                        shard, tp_axis)

__all__ = ["shard", "logical", "dp_axes", "tp_axis", "mesh_axis_size"]
