"""Collective-bytes extraction from compiled HLO text.

``cost_analysis()`` has no collective accounting, so we parse the
(post-SPMD, per-device) module: every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute contributes the byte
size of its RESULT shape(s) (async ``-start`` forms counted once,
``-done`` skipped). This is the per-device wire volume under the
convention that one collective moves ~result-size bytes per device;
all-reduce's 2x (reduce-scatter + all-gather) factor is folded into
the roofline's link-efficiency margin rather than double-counted here.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|f16)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9_]+\[[^\]]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Returns {op_kind: bytes, ..., 'total': bytes, 'total_wire': bytes}
    per device. 'total' sums result shapes (the table convention);
    'total_wire' weights all-reduce 2x (its ring realization is a
    reduce-scatter + all-gather), the more faithful wire volume used by
    the §Perf iterations."""
    out: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        result_type, kind, _ = m.groups()
        out[kind] += _shape_bytes(result_type)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["total_wire"] = out["total"] + out.get("all-reduce", 0)
    return dict(out)


def count_ops(hlo_text: str, names=("fusion", "dot", "custom-call")) -> dict:
    out = {}
    for n in names:
        out[n] = len(re.findall(rf"\b{re.escape(n)}\b", hlo_text))
    return out


# ---------------------------------------------------------------------
# Dot-flop accounting. XLA:CPU's cost_analysis() misses flops inside
# fusion/while called computations, so we count matmul flops directly
# from the HLO text: flops(dot) = 2 * prod(result_shape)
#                               * prod(lhs contracting dim sizes).
# Valid when no while loops remain (the dry-run probe lowers models
# UNROLLED); `n_while` in the result flags any leftover loops whose
# bodies would be counted once.
# ---------------------------------------------------------------------

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\))|(?:\w+\[[^\]]*\]\S*))\s+(\w[\w\-]*)\(")
_DOT_OPERANDS_RE = re.compile(r"dot\(\s*(?:\w+\[[^\]]*\]\S*\s+)?%?([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_FIRST_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|f16)\[([\d,]*)\]")


def _dims(type_text: str) -> list[int]:
    m = _FIRST_SHAPE_RE.search(type_text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def hlo_dot_flops(hlo_text: str) -> dict:
    """Sum matmul flops over every computation in the module."""
    total = 0.0
    n_dots = 0
    sym: dict[str, str] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{"):      # new computation -> new scope
            sym = {}
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rtype, op = m.groups()
        sym[name] = rtype
        if op != "dot":
            continue
        om = _DOT_OPERANDS_RE.search(line)
        cm = _LHS_CONTRACT_RE.search(line)
        if not om or not cm:
            continue
        lhs_name = om.group(1)
        lhs_type = sym.get(lhs_name)
        if lhs_type is None:
            continue
        lhs_dims = _dims(lhs_type)
        contract = [int(d) for d in cm.group(1).split(",") if d]
        k = 1
        for c in contract:
            if c < len(lhs_dims):
                k *= lhs_dims[c]
        out_elems = 1
        for d in _dims(rtype):
            out_elems *= d
        total += 2.0 * out_elems * k
        n_dots += 1
    n_while = len(re.findall(r"\bwhile\(", hlo_text))
    return dict(dot_flops=total, n_dots=n_dots, n_while=n_while)
