"""Sharded checkpointing with manifest, atomic commit, async save, and
elastic re-mesh restore.

Layout of one checkpoint:

    <dir>/step_<n>.tmp/          (written)
        manifest.json            tree structure, shapes, dtypes, step
        shard_<i>.npz            leaf arrays (flat index -> array)
    <dir>/step_<n>/              (atomic rename on commit)

Fault-tolerance contract:
  * a crash mid-save leaves only ``.tmp`` dirs — never a corrupt
    committed checkpoint; restore picks the latest committed step.
  * restore is mesh-agnostic ("elastic re-mesh"): arrays are saved
    unsharded-logical (gathered), and the loader re-shards onto
    whatever mesh/sharding the new job passes — a 512-chip checkpoint
    restores onto 256 chips or 1 CPU.
  * ``CheckpointManager`` keeps the last k checkpoints and saves in a
    background thread (training never blocks on I/O).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import numpy as np
import jax


def _flatten_with_names(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, step: int, tree, *, shards: int = 1) -> str:
    """Write one checkpoint atomically; returns the committed dir."""
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    # start from a clean tmp: an orphaned .tmp from a crashed save at
    # the same step must not contribute stale shard files to the commit
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten_with_names(tree)
    arrays = [np.asarray(l) for l in leaves]
    manifest = dict(
        step=step,
        treedef=str(treedef),
        n_leaves=len(arrays),
        shards=shards,
        shapes=[list(a.shape) for a in arrays],
        dtypes=[str(a.dtype) for a in arrays],
    )
    # round-robin leaves over shard files (parallel-friendly on real fs)
    per_shard: list[dict] = [dict() for _ in range(shards)]
    for i, a in enumerate(arrays):
        per_shard[i % shards][f"leaf_{i}"] = a
    for s, d in enumerate(per_shard):
        np.savez(os.path.join(tmp, f"shard_{s}.npz"), **d)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomic commit
    return final


def _parse_step(name: str, prefix: str = "step_") -> int | None:
    """Step number of one committed checkpoint entry, or ``None`` for
    anything else: ``.tmp``/``.old`` leftovers, foreign files a user
    dropped into the directory (``step_final``, ``step_7.bak``), or
    the prefix alone. The scan helpers below must never raise on such
    entries — a single stray name used to turn ``latest_step`` into a
    ``ValueError`` and brick restore for the whole directory."""
    if not name.startswith(prefix):
        return None
    suffix = name[len(prefix):]
    return int(suffix) if suffix.isdigit() else None


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [s for d in os.listdir(path)
             if (s := _parse_step(d)) is not None]
    return max(steps) if steps else None


def load_checkpoint(path: str, like_tree, *, step: int | None = None,
                    shardings=None):
    """Restore into the structure of ``like_tree``. ``shardings`` (same
    pytree of jax.sharding.Sharding, optional) re-shards each leaf onto
    the new mesh (elastic restore)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    arrays: dict[int, np.ndarray] = {}
    for s in range(manifest["shards"]):
        with np.load(os.path.join(d, f"shard_{s}.npz")) as z:
            for k in z.files:
                arrays[int(k.split("_")[1])] = z[k]
    leaves, treedef = jax.tree_util.tree_flatten(like_tree)
    assert len(leaves) == manifest["n_leaves"], \
        f"leaf count mismatch: {len(leaves)} vs {manifest['n_leaves']}"
    out = []
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        a = arrays[i]
        assert tuple(a.shape) == tuple(ref.shape), \
            f"leaf {i}: {a.shape} vs {ref.shape}"
        if shd is not None:
            out.append(jax.device_put(a, shd))
        else:
            out.append(jax.numpy.asarray(a, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), step


_INDEX_MANIFEST = "seismic_index.json"


def save_index(path: str, index, *, step: int = 0) -> str:
    """Persist a ``SeismicIndex`` atomically (named-field npz + config
    JSON). Optional tiers (compact forward index, superblock summaries,
    kNN graph) are stored only when present, so old loaders skip
    unknown fields and new loaders default absent fields to ``None``.
    Tuned operating points (``repro.tune.TunedPolicy``) are static
    metadata, not arrays: they ride the JSON manifest (absent on an
    untuned index, so pre-tune checkpoints are byte-identical)."""
    import dataclasses
    final = os.path.join(path, f"index_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arrays = dict(fwd_coords=np.asarray(index.fwd.coords),
                  fwd_vals=np.asarray(index.fwd.vals))
    for f in dataclasses.fields(type(index)):
        if f.name in ("fwd", "config", "tuned"):
            continue
        v = getattr(index, f.name)
        if v is not None:
            arrays[f.name] = np.asarray(v)
    np.savez(os.path.join(tmp, "index.npz"), **arrays)
    manifest = dict(step=step, dim=index.fwd.dim,
                    config=dataclasses.asdict(index.config))
    if getattr(index, "tuned", ()):
        manifest["tuned"] = [dataclasses.asdict(t) for t in index.tuned]
    with open(os.path.join(tmp, _INDEX_MANIFEST), "w") as f:
        json.dump(manifest, f)
    # overwrite without a commit gap: move the old dir aside first, so
    # a crash at any point leaves either the old or the new committed
    # (.old/.tmp dirs are skipped by the loader's step scan)
    old = final + ".old"
    if os.path.exists(old):
        shutil.rmtree(old)
    if os.path.exists(final):
        os.rename(final, old)
    os.rename(tmp, final)           # atomic commit
    shutil.rmtree(old, ignore_errors=True)
    return final


def load_index(path: str, *, step: int | None = None):
    """Restore a ``SeismicIndex`` saved by :func:`save_index`.

    Back-compat: checkpoints written before the superblock tier, the
    compact forward index, the kNN graph, or the tuned operating
    points simply lack those npz/manifest keys; the loader leaves them
    ``None`` (``()`` for ``tuned``) and rebuilds the config through
    ``SeismicConfig(**...)`` defaults, so a pre-superblock (or
    pre-graph, pre-tune) checkpoint loads as a flat-routing,
    refinement-free, untuned index unchanged — bit-exact search."""
    import dataclasses
    from repro.core.types import SeismicConfig, SeismicIndex
    from repro.tune.policy import TunedPolicy
    if step is None:
        steps = [int(d.split("_")[1]) for d in os.listdir(path)
                 if d.startswith("index_") and d.split("_")[1].isdigit()]
        if not steps:
            raise FileNotFoundError(f"no committed index under {path}")
        step = max(steps)
    d = os.path.join(path, f"index_{step:08d}")
    with open(os.path.join(d, _INDEX_MANIFEST)) as f:
        manifest = json.load(f)
    known = {f.name for f in dataclasses.fields(SeismicConfig)}
    cfg = SeismicConfig(**{k: v for k, v in manifest["config"].items()
                           if k in known})
    from repro.sparse.ops import PaddedSparse
    with np.load(os.path.join(d, "index.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    fwd = PaddedSparse(jax.numpy.asarray(arrays.pop("fwd_coords")),
                       jax.numpy.asarray(arrays.pop("fwd_vals")),
                       manifest["dim"])
    fields = {f.name for f in dataclasses.fields(SeismicIndex)}
    kwargs = {k: jax.numpy.asarray(v) for k, v in arrays.items()
              if k in fields}
    known_t = {f.name for f in dataclasses.fields(TunedPolicy)}
    tuned = tuple(
        TunedPolicy(**{k: v for k, v in d.items() if k in known_t})
        for d in manifest.get("tuned", []))
    return SeismicIndex(fwd=fwd, config=cfg, tuned=tuned, **kwargs)


class CheckpointManager:
    """Async save + keep-last-k retention."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(path, exist_ok=True)
        self._clean_orphans()

    def _clean_orphans(self) -> None:
        """Drop half-written ``step_*.tmp`` dirs left by a crash
        mid-save. The atomic-rename commit guarantees a ``.tmp`` is
        never a valid checkpoint, but before this cleanup they
        accumulated forever (and a later save to the same step would
        silently merge stale shard files via ``makedirs(exist_ok)``).
        Runs once at manager start, before any new save can race it."""
        for d in os.listdir(self.path):
            if d.startswith("step_") and d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.path, d),
                              ignore_errors=True)

    def save_async(self, step: int, tree):
        # snapshot to host before handing to the writer thread
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()

        def work():
            save_checkpoint(self.path, step, host_tree)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(s for d in os.listdir(self.path)
                       if (s := _parse_step(d)) is not None)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, like_tree, shardings=None):
        return load_checkpoint(self.path, like_tree, shardings=shardings)
