from repro.ckpt.checkpoint import (CheckpointManager, load_checkpoint,
                                   load_index, save_checkpoint, save_index)

__all__ = ["CheckpointManager", "save_checkpoint", "load_checkpoint",
           "save_index", "load_index"]
