"""GIN (Graph Isomorphism Network, arXiv:1810.00826) in pure JAX.

Message passing is gather + segment_sum over an edge index (JAX has no
CSR SpMM; the scatter formulation IS the system here — taxonomy §GNN):

    h_i' = MLP((1 + eps) * h_i + sum_{j in N(i)} h_j)

Distribution (full-graph cells): edges are sharded over every mesh
axis; node features are replicated. Each shard scatter-adds its edge
messages into a local [N, d] partial aggregate, then a psum over the
edge axes completes the sum — the vertex-cut pattern. The psum volume
(N * d * 4 bytes per layer) is what the roofline flags; the hillclimb
alternative is 1D node partitioning with sorted edges.

Batched small graphs (``molecule``) reuse the same code with a block-
diagonal edge index; graph readout is a segment_sum over graph ids.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import GNNConfig
from repro.distributed.sharding import dp_axes, tp_axis
from repro.models.common import mlp_apply, mlp_init, cross_entropy


def init_params(key, cfg: GNNConfig, d_feat: int, n_classes: int) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 2)
    layers = []
    d_in = d_feat
    for i in range(cfg.n_layers):
        layers.append(dict(
            mlp=mlp_init(keys[i], (d_in, cfg.d_hidden, cfg.d_hidden), dtype),
            eps=jnp.zeros((), jnp.float32),
        ))
        d_in = cfg.d_hidden
    params = dict(
        layers=layers,  # heterogeneous first layer -> plain list, unrolled
        head=mlp_init(keys[-1], (cfg.d_hidden, n_classes), dtype),
    )
    return params


def _aggregate(h: jax.Array, edges: jax.Array, n_nodes: int) -> jax.Array:
    """sum_{j in N(i)} h_j via gather + segment scatter-add.

    h [N, d], edges [E, 2] (src, dst) -> [N, d]. Under a mesh, edges
    are sharded and the partial aggregate is psum-ed (shard_map).
    """
    axes = dp_axes() + (("model",) if tp_axis() else ())
    if not axes:
        msgs = jnp.take(h, edges[:, 0], axis=0)
        return jnp.zeros((n_nodes, h.shape[1]), h.dtype).at[edges[:, 1]].add(msgs)

    def body(h_rep, edges_loc):
        msgs = jnp.take(h_rep, edges_loc[:, 0], axis=0)
        partial = jnp.zeros((n_nodes, h_rep.shape[1]), h_rep.dtype)
        partial = partial.at[edges_loc[:, 1]].add(msgs)
        return jax.lax.psum(partial, axes)

    return jax.shard_map(body, in_specs=(P(), P(axes)), out_specs=P(),
                         check_vma=False)(h, edges)


def _layer_sharded(layer: dict, h: jax.Array, edges: jax.Array,
                   n_nodes: int, axes) -> jax.Array:
    """§Perf 'shard' mode: one GIN layer with node-sharded combine.

    Per device: local-edge scatter-add partial -> reduce_scatter over
    all axes (each device owns N/P rows) -> (1+eps)h + agg and the MLP
    run on the OWNED rows only (the psum baseline computes them
    replicated, P-fold redundantly) -> all_gather replicates h for the
    next layer's gathers. Wire volume ~= one all-gather instead of one
    all-reduce (half), and MLP flops/HBM drop by the world size.
    """
    def body(h_rep, edges_loc, lp):
        world = 1
        for ax in axes:
            world *= jax.lax.axis_size(ax)
        per = n_nodes // world
        msgs = jnp.take(h_rep, edges_loc[:, 0], axis=0)
        partial = jnp.zeros((n_nodes, h_rep.shape[1]), h_rep.dtype)
        partial = partial.at[edges_loc[:, 1]].add(msgs)
        agg_own = jax.lax.psum_scatter(partial, axes, scatter_dimension=0,
                                       tiled=True)          # [N/P, d]
        lin = jax.lax.axis_index(axes[0])
        for ax in axes[1:]:
            lin = lin * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
        own = jax.lax.dynamic_slice_in_dim(h_rep, lin * per, per, axis=0)
        hn = (1.0 + lp["eps"]).astype(own.dtype) * own + agg_own
        hn = jax.nn.relu(mlp_apply(lp["mlp"], hn, 2)).astype(own.dtype)
        return jax.lax.all_gather(hn, axes, axis=0, tiled=True)

    lp_specs = jax.tree.map(lambda _: P(), layer)
    return jax.shard_map(body, in_specs=(P(), P(axes), lp_specs),
                         out_specs=P(), check_vma=False)(h, edges, layer)


def forward(params: dict, feats: jax.Array, edges: jax.Array,
            cfg: GNNConfig) -> jax.Array:
    """Node embeddings [N, d_hidden]. Padding edges must point at a
    dedicated sink node (callers append one)."""
    n = feats.shape[0]
    h = feats.astype(jnp.dtype(cfg.dtype))   # bf16 halves psum/AG volume
    axes = dp_axes() + (("model",) if tp_axis() else ())
    world = 1
    from repro.distributed.sharding import mesh_axis_size
    for ax in axes:
        world *= mesh_axis_size(ax)
    sharded_ok = (cfg.aggregate_mode == "shard" and axes
                  and n % world == 0)
    for layer in params["layers"]:
        if sharded_ok:
            h = _layer_sharded(layer, h, edges, n, axes)
        else:
            agg = _aggregate(h, edges, n)
            h = (1.0 + layer["eps"]).astype(h.dtype) * h + agg
            h = mlp_apply(layer["mlp"], h, 2)
            h = jax.nn.relu(h).astype(agg.dtype)
    return h


def node_loss(params: dict, batch: dict, cfg: GNNConfig) -> jax.Array:
    """Node classification: batch = {feats [N,F], edges [E,2],
    labels [N] (-1 = unlabeled/pad)}."""
    h = forward(params, batch["feats"], batch["edges"], cfg)
    logits = mlp_apply(params["head"], h, 1)
    return cross_entropy(logits, batch["labels"])


def graph_loss(params: dict, batch: dict, cfg: GNNConfig) -> jax.Array:
    """Graph classification (molecule cell): batch adds graph_ids [N]
    and graph labels [G]; readout = per-graph sum pooling."""
    h = forward(params, batch["feats"], batch["edges"], cfg)
    n_graphs = batch["graph_labels"].shape[0]
    pooled = jax.ops.segment_sum(h, batch["graph_ids"],
                                 num_segments=n_graphs)
    logits = mlp_apply(params["head"], pooled, 1)
    return cross_entropy(logits, batch["graph_labels"])
