"""Fanout neighbor sampler (GraphSAGE-style) — host-side, numpy.

Produces fixed-shape padded subgraph batches for the ``minibatch_lg``
cell: seeds [B], then per hop a uniform sample of ``fanout[h]``
neighbors per frontier node. Output arrays are padded to the static
worst case so the jitted train step never recompiles:

  nodes:  B * (1 + f0 + f0*f1 + ...)    (with a trailing sink node)
  edges:  B * f0 + B * f0 * f1 + ...

Padding edges point src=dst=sink; padded labels are -1.
"""
from __future__ import annotations

import numpy as np


class CSRGraph:
    """Compressed neighbor lists for host-side sampling."""

    def __init__(self, n_nodes: int, edges: np.ndarray):
        dst_order = np.argsort(edges[:, 1], kind="stable")
        self.nbr = edges[dst_order, 0].astype(np.int64)
        counts = np.bincount(edges[:, 1], minlength=n_nodes)
        self.offsets = np.concatenate([[0], np.cumsum(counts)])
        self.n_nodes = n_nodes

    def neighbors(self, v: int) -> np.ndarray:
        return self.nbr[self.offsets[v]:self.offsets[v + 1]]


def subgraph_shapes(batch_nodes: int, fanout: tuple[int, ...]):
    n_nodes = batch_nodes
    n_edges = 0
    frontier = batch_nodes
    for f in fanout:
        n_edges += frontier * f
        frontier *= f
        n_nodes += frontier
    return n_nodes + 1, n_edges          # +1 sink node


def sample_subgraph(rng: np.random.Generator, graph: CSRGraph,
                    seeds: np.ndarray, fanout: tuple[int, ...],
                    feats: np.ndarray, labels: np.ndarray):
    """Returns a fixed-shape batch dict (feats, edges, labels)."""
    max_nodes, max_edges = subgraph_shapes(len(seeds), fanout)
    sink = max_nodes - 1
    node_ids = list(seeds.tolist())
    local = {int(v): i for i, v in enumerate(seeds)}
    edges = []
    frontier = list(seeds.tolist())
    for f in fanout:
        nxt = []
        for v in frontier:
            nbrs = graph.neighbors(int(v))
            if len(nbrs) == 0:
                continue
            pick = rng.choice(nbrs, size=min(f, len(nbrs)), replace=False)
            for u in pick:
                u = int(u)
                if u not in local:
                    local[u] = len(node_ids)
                    node_ids.append(u)
                edges.append((local[u], local[int(v)]))   # src -> dst
                nxt.append(u)
        frontier = nxt
    node_ids = np.asarray(node_ids[:max_nodes - 1], np.int64)

    out_feats = np.zeros((max_nodes, feats.shape[1]), feats.dtype)
    out_feats[:len(node_ids)] = feats[node_ids]
    out_labels = np.full((max_nodes,), -1, np.int32)
    out_labels[:len(seeds)] = labels[seeds]               # loss on seeds only
    out_edges = np.full((max_edges, 2), sink, np.int32)
    if edges:
        e = np.asarray(edges[:max_edges], np.int32)
        out_edges[:len(e)] = e
    return dict(feats=out_feats, edges=out_edges, labels=out_labels)
