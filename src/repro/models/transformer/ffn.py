"""FFN layers: SwiGLU dense and top-k MoE with sort-based dispatch.

MoE design (DESIGN.md §4):
  * router: softmax over expert logits, top-k selection, probs
    renormalized over the selected experts; load-balance aux loss
    (Switch-style) returned alongside.
  * dispatch: sort-based (no [T, E, C] one-hot): flatten (token, k)
    assignments, stable-sort by expert, rank-within-expert via the
    sorted layout, drop tokens past the per-expert capacity
    C = ceil(T * k / E * capacity_factor).
  * compute: gathered [E, C, d] buffers hit the experts as one batched
    einsum (MXU grouped-GEMM analog).
  * expert parallelism: under an active mesh the layer runs in
    shard_map — tokens sharded over (pod, data, model), experts over
    model; dispatch/return are ragged all_to_alls over the model axis.
    Without a mesh the same local path runs unsharded (smoke tests).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import TransformerConfig
from repro.distributed.sharding import dp_axes, mesh_axis_size, tp_axis


# ------------------------------------------------------------- SwiGLU

def init_swiglu(key, d: int, ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    s = d ** -0.5
    return dict(
        w1=(jax.random.normal(ks[0], (d, ff), jnp.float32) * s).astype(dtype),
        w3=(jax.random.normal(ks[1], (d, ff), jnp.float32) * s).astype(dtype),
        w2=(jax.random.normal(ks[2], (ff, d), jnp.float32) * ff ** -0.5).astype(dtype),
    )


def swiglu(p: dict, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]


# ---------------------------------------------------------------- MoE

def init_moe(key, cfg: TransformerConfig, dtype) -> dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    s = d ** -0.5
    p = dict(
        router=(jax.random.normal(ks[0], (d, e), jnp.float32) * s).astype(jnp.float32),
        w1=(jax.random.normal(ks[1], (e, d, ff), jnp.float32) * s).astype(dtype),
        w3=(jax.random.normal(ks[2], (e, d, ff), jnp.float32) * s).astype(dtype),
        w2=(jax.random.normal(ks[3], (e, ff, d), jnp.float32) * ff ** -0.5).astype(dtype),
    )
    if cfg.n_shared_experts:
        p["shared"] = init_swiglu(ks[4], d,
                                  cfg.moe_d_ff * cfg.n_shared_experts, dtype)
    return p


def _route(router_w: jax.Array, x: jax.Array, top_k: int):
    """x [T, d] -> (expert_idx [T,k], weights [T,k], aux_loss)."""
    logits = x.astype(jnp.float32) @ router_w           # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    e = logits.shape[-1]
    me = probs.mean(0)                                   # mean prob per expert
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(ce.sum(), 1.0)
    aux = e * jnp.sum(me * ce)
    return idx, w, aux


def _dispatch_compute(x, idx, w, w1, w3, w2, capacity: int):
    """Sort-based dispatch + batched expert einsum + combine.

    x [T, d]; idx/w [T, k]; w1/w3 [El, d, ff], w2 [El, ff, d] where El
    is the LOCAL expert count and idx is already local-expert-indexed
    (callers offset & mask foreign experts to El => dropped).
    """
    t, k = idx.shape
    el = w1.shape[0]
    flat_e = idx.reshape(-1)                             # [T*k]
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_w = w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)             # group by expert
    se, st_, sw = flat_e[order], flat_t[order], flat_w[order]
    # rank within expert via segment-relative position
    start = jnp.searchsorted(se, jnp.arange(el + 1))
    rank = jnp.arange(t * k) - jnp.take(start, se, mode="clip")
    keep = (rank < capacity) & (se < el)
    slot_e = jnp.where(keep, se, el)                     # drop -> sentinel
    slot_c = jnp.where(keep, rank, 0)
    # gather tokens into [El+1, C, d] (sentinel row absorbs drops)
    buf = jnp.zeros((el + 1, capacity, x.shape[1]), x.dtype)
    buf = buf.at[slot_e, slot_c].set(jnp.take(x, st_, axis=0))
    hidden = buf[:el]
    h = jnp.einsum("ecd,edf->ecf", hidden, w1)
    g = jnp.einsum("ecd,edf->ecf", hidden, w3)
    out_e = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * g, w2)
    # combine back to tokens
    out_pad = jnp.concatenate(
        [out_e, jnp.zeros((1, capacity, x.shape[1]), out_e.dtype)], axis=0)
    contrib = out_pad[slot_e, slot_c] * sw[:, None].astype(out_e.dtype)
    contrib = jnp.where(keep[:, None], contrib, 0)
    out = jnp.zeros_like(x).at[st_].add(contrib)
    return out


def moe_local(p: dict, x: jax.Array, cfg: TransformerConfig):
    """Single-device MoE (also the per-shard body of the EP path)."""
    t = x.shape[0]
    cap = max(1, math.ceil(t * cfg.moe_top_k / cfg.n_experts
                           * cfg.capacity_factor))
    idx, w, aux = _route(p["router"], x, cfg.moe_top_k)
    out = _dispatch_compute(x, idx, w, p["w1"], p["w3"], p["w2"], cap)
    if "shared" in p:
        out = out + swiglu(p["shared"], x)
    return out, aux


def moe_ep(p: dict, x: jax.Array, cfg: TransformerConfig):
    """Expert-parallel MoE under shard_map on the ambient mesh.

    x is [B, S, d] (train/prefill — B shards over dp, S over model: no
    cross-shard reshape at the shard_map boundary, which is what caused
    SPMD's 'involuntary full rematerialization' all-gathers in the flat
    [T, d] formulation) or [T, d] (decode). Experts shard over model;
    dispatch/return are all_to_alls. Token-poor decode batches fall
    back to redundant routing + local expert slice + psum (all_to_all
    volume would exceed the redundant-compute cost there).
    """
    tp = tp_axis()
    ep = mesh_axis_size("model") if tp else 1
    if ep <= 1 or cfg.n_experts % ep != 0:
        if x.ndim == 3:
            b, s, d = x.shape
            out, aux = moe_local(p, x.reshape(b * s, d), cfg)
            return out.reshape(b, s, d), aux
        return moe_local(p, x, cfg)

    dp_size = 1
    for a in dp_axes():
        dp_size *= mesh_axis_size(a)
    e = cfg.n_experts

    three_d = (x.ndim == 3 and x.shape[0] % max(dp_size, 1) == 0
               and x.shape[1] % ep == 0)
    if not three_d:
        xf = x.reshape(-1, x.shape[-1])
        if dp_size > 1 and xf.shape[0] % dp_size == 0:
            out, aux = _moe_ep_token_poor(p, xf, cfg, dp_axes(), ep)
        else:
            out, aux = _moe_ep_token_poor(p, xf, cfg, (), ep)
        return out.reshape(x.shape), aux

    token_axes = dp_axes() + ("model",)

    def body(p_sh, x_loc3):
        bl, sl, d = x_loc3.shape
        x_loc = x_loc3.reshape(bl * sl, d)     # local reshape: no comm
        t_loc = x_loc.shape[0]
        cap = max(1, math.ceil(t_loc * cfg.moe_top_k / e
                               * cfg.capacity_factor))
        idx, w, aux = _route(p_sh["router"], x_loc, cfg.moe_top_k)
        # build the global [E, C, d] send buffer
        t, k = idx.shape
        flat_e = idx.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(t), k)
        flat_w = w.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se, st_, sw = flat_e[order], flat_t[order], flat_w[order]
        start = jnp.searchsorted(se, jnp.arange(e + 1))
        rank = jnp.arange(t * k) - jnp.take(start, se, mode="clip")
        keep = rank < cap
        slot_e = jnp.where(keep, se, e)
        slot_c = jnp.where(keep, rank, 0)
        buf = jnp.zeros((e + 1, cap, d), x_loc.dtype)
        buf = buf.at[slot_e, slot_c].set(jnp.take(x_loc, st_, axis=0))
        buf = buf[:e]                                     # [E, C, d]
        # dispatch: E split over model -> [E/P, C*P, d]
        recv = jax.lax.all_to_all(buf, "model", split_axis=0,
                                  concat_axis=1, tiled=True)
        h = jnp.einsum("ecd,edf->ecf", recv, p_sh["w1"])
        g = jnp.einsum("ecd,edf->ecf", recv, p_sh["w3"])
        out_e = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * g, p_sh["w2"])
        # return trip: [E/P, C*P, d] -> [E, C, d]
        back = jax.lax.all_to_all(out_e, "model", split_axis=1,
                                  concat_axis=0, tiled=True)
        back_pad = jnp.concatenate(
            [back, jnp.zeros((1, cap, d), back.dtype)], axis=0)
        contrib = back_pad[slot_e, slot_c] * sw[:, None].astype(back.dtype)
        contrib = jnp.where(keep[:, None], contrib, 0)
        out = jnp.zeros_like(x_loc).at[st_].add(contrib)
        if "shared" in p_sh:
            out = out + swiglu(p_sh["shared"], x_loc)
        aux = jax.lax.pmean(aux, token_axes)   # replicate the aux loss
        return out.reshape(bl, sl, d), aux

    expert_specs = dict(router=P(), w1=P("model"), w3=P("model"),
                        w2=P("model"))
    if "shared" in p:
        expert_specs["shared"] = dict(w1=P(), w2=P(), w3=P())
    dp = dp_axes()
    x_spec = P(dp if dp else None, "model", None)
    fn = jax.shard_map(
        body,
        in_specs=(expert_specs, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False)
    out, aux = fn(p, x)
    return out, aux


def _moe_ep_token_poor(p: dict, x: jax.Array, cfg: TransformerConfig,
                       token_axes: tuple, ep: int):
    """Decode-batch EP: redundant routing per model rank, local expert
    slice, psum(model) combine."""
    e = cfg.n_experts
    el = e // ep

    def body(p_sh, x_loc):
        t_loc = x_loc.shape[0]
        cap = max(1, math.ceil(t_loc * cfg.moe_top_k / e
                               * cfg.capacity_factor))
        idx, w, aux = _route(p_sh["router"], x_loc, cfg.moe_top_k)
        my = jax.lax.axis_index("model")
        # re-index experts to the local chunk; foreign -> sentinel el
        local_idx = idx - my * el
        local_idx = jnp.where((local_idx >= 0) & (local_idx < el),
                              local_idx, el)
        out = _dispatch_compute(x_loc, local_idx, w, p_sh["w1"],
                                p_sh["w3"], p_sh["w2"], cap)
        out = jax.lax.psum(out, ("model",))
        if "shared" in p_sh:
            out = out + swiglu(p_sh["shared"], x_loc)
        if token_axes:
            aux = jax.lax.pmean(aux, token_axes)
        return out, aux

    expert_specs = dict(router=P(), w1=P("model"), w3=P("model"),
                        w2=P("model"))
    if "shared" in p:
        expert_specs["shared"] = dict(w1=P(), w2=P(), w3=P())
    x_spec = P(token_axes) if token_axes else P()
    fn = jax.shard_map(
        body,
        in_specs=(expert_specs, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False)
    return fn(p, x)


def moe_forward(p: dict, x: jax.Array, cfg: TransformerConfig):
    """x [T, d] -> ([T, d], aux). Chooses EP vs local off the mesh."""
    return moe_ep(p, x, cfg)
