"""Attention layers: GQA (optionally sliding-window) and MLA.

Two execution paths per layer:
  * train/prefill — full-sequence attention. The XLA path is q-chunked
    (lax.scan over query tiles, exact softmax per tile row) so the
    [S, S] score matrix never materializes; the Pallas flash kernel is
    the TPU fast path (``use_pallas``).
  * decode       — one token against a KV cache. Cache updates use the
    one-hot formulation (elementwise select instead of a dynamic-update
    -slice) so a sequence-sharded cache partitions cleanly under SPMD.
    MLA decode uses matrix absorption (q/out projected into the latent
    space) so per-step cost is O(S * kv_lora_rank), the production
    trick from the DeepSeek-V2 paper.

GQA einsums keep kv heads un-expanded: q is grouped [B, S, KV, G, Dh]
and scores contract against k [B, T, KV, Dh] directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import TransformerConfig
from repro.distributed.sharding import shard
from repro.models.common import rms_norm
from repro.models.transformer.rope import apply_rope

NEG = -1e30


# ----------------------------------------------------------------- init

def init_gqa(key, cfg: TransformerConfig, dtype) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return dict(
        wq=(jax.random.normal(ks[0], (d, h * dh), jnp.float32) * s).astype(dtype),
        wk=(jax.random.normal(ks[1], (d, kv * dh), jnp.float32) * s).astype(dtype),
        wv=(jax.random.normal(ks[2], (d, kv * dh), jnp.float32) * s).astype(dtype),
        wo=(jax.random.normal(ks[3], (h * dh, d), jnp.float32) * s).astype(dtype),
    )


def init_mla(key, cfg: TransformerConfig, dtype) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    r, nd, rd, vd = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return dict(
        wq=(jax.random.normal(ks[0], (d, h * (nd + rd)), jnp.float32) * s).astype(dtype),
        w_dkv=(jax.random.normal(ks[1], (d, r), jnp.float32) * s).astype(dtype),
        w_kr=(jax.random.normal(ks[2], (d, rd), jnp.float32) * s).astype(dtype),
        w_uk=(jax.random.normal(ks[3], (r, h * nd), jnp.float32) * r ** -0.5).astype(dtype),
        w_uv=(jax.random.normal(ks[4], (r, h * vd), jnp.float32) * r ** -0.5).astype(dtype),
        wo=(jax.random.normal(ks[5], (h * vd, d), jnp.float32) * s).astype(dtype),
        kv_norm=jnp.zeros((r,), jnp.float32),
    )


# ----------------------------------------------------- chunked XLA sdpa

def _sdpa_chunked(q, k, v, *, causal: bool, window: int, q_chunk: int = 512):
    """q [B, S, KV, G, Dh], k/v [B, T, KV, Dh] -> [B, S, KV, G, Dh].

    Exact softmax computed one query tile at a time; window > 0 applies
    Gemma-style sliding-window masking on top of causality.
    """
    b, s, kvh, g, dh = q.shape
    t = k.shape[1]
    scale = dh ** -0.5
    if s % q_chunk != 0:
        q_chunk = s
    nq = s // q_chunk
    qs = q.reshape(b, nq, q_chunk, kvh, g, dh)
    k32 = k.astype(jnp.float32)
    v32 = v.astype(jnp.float32)

    def tile(i):
        qc = qs[:, i].astype(jnp.float32)                  # [B,C,KV,G,Dh]
        sc = jnp.einsum("bckgd,btkd->bkgct", qc, k32) * scale
        q_pos = i * q_chunk + jnp.arange(q_chunk)
        k_pos = jnp.arange(t)
        mask = jnp.ones((q_chunk, t), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        sc = jnp.where(mask, sc, NEG)
        p = jax.nn.softmax(sc, axis=-1)
        return jnp.einsum("bkgct,btkd->bckgd", p, v32)

    if nq == 1:
        # single tile: stay in the entry computation (keeps the program
        # analyzable by cost_analysis and avoids a trip-1 while loop)
        return tile(0).reshape(b, s, kvh, g, dh).astype(q.dtype)
    out = jax.lax.map(tile, jnp.arange(nq))                # [nq,B,C,KV,G,Dh]
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, kvh, g, dh)
    return out.astype(q.dtype)


# ------------------------------------------------------------ GQA layer

def gqa_forward(p: dict, x: jax.Array, positions: jax.Array,
                cfg: TransformerConfig, *, window: int = 0,
                use_pallas: bool = False) -> jax.Array:
    """Full-sequence GQA. x [B, S, d] -> [B, S, d]."""
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    g = h // kv
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k = (x @ p["wk"]).reshape(b, s, kv, dh)
    v = (x @ p["wv"]).reshape(b, s, kv, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # TP coherence: kv heads < tp in several assigned archs, so the
    # grouped [B,S,KV,G,Dh] layout cannot shard on the model axis.
    # Expand K/V to H heads AFTER the (replicated) projections; all of
    # q/k/v then shard on H and attention is fully head-parallel with
    # zero resharding. Per-device expanded K/V is H/tp heads — the same
    # footprint as q.
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    tp = "tp" if cfg.sharding_mode == "tp" else None
    bx = ("dp", "tp") if cfg.sharding_mode == "fsdp" else "dp"
    q = shard(q, bx, None, tp, None)
    k = shard(k, bx, None, tp, None)
    v = shard(v, bx, None, tp, None)
    if use_pallas:
        from repro.kernels.flash_attention.ops import flash_attention
        o = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=True,
                            window=window if window > 0 else None)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    else:
        qg = q.reshape(b, s, h, 1, dh)
        o = _sdpa_chunked(qg, k, v, causal=True, window=window,
                          q_chunk=cfg.attn_q_chunk)
        o = o.reshape(b, s, h * dh)
    return o @ p["wo"]


def gqa_decode(p: dict, x: jax.Array, pos: jax.Array, cache_k: jax.Array,
               cache_v: jax.Array, cfg: TransformerConfig, *,
               window: int = 0):
    """One-token GQA against a cache.

    x [B, 1, d]; pos [] scalar step index; cache_k/v [B, T, KV, Dh]
    (T = max seq or ring-buffer window). Returns (out [B,1,d], new caches).

    Ring-buffer semantics when T < pos+1: slot = pos % T, and all T
    slots are within the window once warm (window == T).
    """
    b, _, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    g = h // kv
    t = cache_k.shape[1]
    q = (x @ p["wq"]).reshape(b, 1, h, dh)
    k_new = (x @ p["wk"]).reshape(b, 1, kv, dh)
    v_new = (x @ p["wv"]).reshape(b, 1, kv, dh)
    pos_b = jnp.broadcast_to(pos, (b, 1))
    q = apply_rope(q, pos_b, cfg.rope_theta)
    k_new = apply_rope(k_new, pos_b, cfg.rope_theta)

    slot = pos % t
    onehot = (jnp.arange(t) == slot).astype(cache_k.dtype)  # [T]
    cache_k = cache_k * (1 - onehot)[None, :, None, None] \
        + k_new * onehot[None, :, None, None]
    cache_v = cache_v * (1 - onehot)[None, :, None, None] \
        + v_new * onehot[None, :, None, None]

    qg = q.reshape(b, kv, g, dh)
    sc = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32),
                    cache_k.astype(jnp.float32)) * dh ** -0.5
    # validity: slots written so far; ring buffers are fully valid once warm
    slot_pos = jnp.arange(t)
    if window > 0 and t <= window:
        valid = (slot_pos <= pos) | (pos >= t)   # ring buffer
    else:
        valid = slot_pos <= pos
        if window > 0:
            valid &= slot_pos > pos - window     # windowed full-length cache
    sc = jnp.where(valid[None, None, None, :], sc, NEG)
    pr = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", pr, cache_v.astype(jnp.float32))
    o = o.reshape(b, 1, h * dh).astype(x.dtype)
    return o @ p["wo"], cache_k, cache_v


# ------------------------------------------------------------ MLA layer

def mla_forward(p: dict, x: jax.Array, positions: jax.Array,
                cfg: TransformerConfig) -> jax.Array:
    """Full-sequence MLA (DeepSeek-V2). x [B, S, d] -> [B, S, d]."""
    b, s, d = x.shape
    h = cfg.n_heads
    nd, rd, vd, r = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                     cfg.kv_lora_rank)
    q = (x @ p["wq"]).reshape(b, s, h, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)  # [B,S,r]
    k_rope = apply_rope((x @ p["w_kr"])[:, :, None, :], positions,
                        cfg.rope_theta)                          # [B,S,1,rd]
    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, nd)
    v = (c_kv @ p["w_uv"]).reshape(b, s, h, vd)

    scale = (nd + rd) ** -0.5
    sc = (jnp.einsum("bshd,bthd->bhst", q_nope.astype(jnp.float32),
                     k_nope.astype(jnp.float32))
          + jnp.einsum("bshd,btxd->bhst", q_rope.astype(jnp.float32),
                       k_rope.astype(jnp.float32))) * scale
    mask = jnp.arange(s)[None, :] <= jnp.arange(s)[:, None]
    sc = jnp.where(mask, sc, NEG)
    pr = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", pr, v.astype(jnp.float32))
    o = o.reshape(b, s, h * vd).astype(x.dtype)
    return o @ p["wo"]


def mla_decode(p: dict, x: jax.Array, pos: jax.Array, cache_ckv: jax.Array,
               cache_kr: jax.Array, cfg: TransformerConfig):
    """Absorbed MLA decode: O(S * r) per step, caching only
    (c_kv [B, T, r], k_rope [B, T, rd])."""
    b, _, d = x.shape
    h = cfg.n_heads
    nd, rd, vd, r = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                     cfg.kv_lora_rank)
    t = cache_ckv.shape[1]
    q = (x @ p["wq"]).reshape(b, 1, h, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    pos_b = jnp.broadcast_to(pos, (b, 1))
    q_rope = apply_rope(q_rope, pos_b, cfg.rope_theta)[:, 0]     # [B,h,rd]

    c_new = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)  # [B,1,r]
    kr_new = apply_rope((x @ p["w_kr"])[:, :, None, :], pos_b,
                        cfg.rope_theta)[:, :, 0, :]               # [B,1,rd]
    onehot = (jnp.arange(t) == pos).astype(cache_ckv.dtype)
    cache_ckv = cache_ckv * (1 - onehot)[None, :, None] \
        + c_new * onehot[None, :, None]
    cache_kr = cache_kr * (1 - onehot)[None, :, None] \
        + kr_new * onehot[None, :, None]

    # absorb W_uk into q: q_lat [B, h, r]
    w_uk = p["w_uk"].reshape(r, h, nd)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = (nd + rd) ** -0.5
    sc = (jnp.einsum("bhr,btr->bht", q_lat,
                     cache_ckv.astype(jnp.float32))
          + jnp.einsum("bhd,btd->bht", q_rope.astype(jnp.float32),
                       cache_kr.astype(jnp.float32))) * scale
    valid = jnp.arange(t) <= pos
    sc = jnp.where(valid[None, None, :], sc, NEG)
    pr = jax.nn.softmax(sc, axis=-1)
    o_lat = jnp.einsum("bht,btr->bhr", pr,
                       cache_ckv.astype(jnp.float32))             # [B,h,r]
    w_uv = p["w_uv"].reshape(r, h, vd)
    o = jnp.einsum("bhr,rhd->bhd", o_lat, w_uv.astype(jnp.float32))
    o = o.reshape(b, 1, h * vd).astype(x.dtype)
    return o @ p["wo"], cache_ckv, cache_kr
