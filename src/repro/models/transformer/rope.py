"""Rotary position embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [B, S, ..., D] with positions [B, S]; any number of axes (e.g.
    heads) between S and the even last axis D.

    Layout: split halves (x1 = x[..., :D/2], x2 = x[..., D/2:]), the
    llama convention."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    # broadcast ang over any axes between S and D (e.g. heads)
    while ang.ndim < x.ndim:
        ang = ang[..., None, :]
    cos = jnp.cos(ang).astype(x.dtype)
    sin = jnp.sin(ang).astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1)
