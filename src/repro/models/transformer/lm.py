"""Decoder-only LM supporting the five assigned architectures.

One code base covers:
  * GQA (phi3 / llama3 / kimi) and MLA (deepseek-v2) attention,
  * dense SwiGLU and MoE (sort-dispatch, EP under a mesh) FFNs with an
    optional leading dense layer (kimi / deepseek stacks),
  * Gemma-3's 5:1 local:global pattern — per-layer window values in the
    scanned stack for train/prefill, and a dual-cache decode (ring
    buffers for local layers, full-length caches for global layers),
  * scan-over-layers with configurable remat policy (HLO stays flat at
    61+ layers).

Train entry: ``loss_fn(params, batch, cfg)``;
decode entry: ``decode_step(params, cache, tokens, pos, cfg)``.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import TransformerConfig
from repro.distributed.sharding import shard
from repro.models.common import cross_entropy, rms_norm
from repro.models.transformer.attention import (gqa_decode, gqa_forward,
                                                init_gqa, init_mla,
                                                mla_decode, mla_forward)
from repro.models.transformer.ffn import (init_moe, init_swiglu, moe_forward,
                                          swiglu)

AUX_COEF = 0.01


# ----------------------------------------------------------------- init

def _init_layer(key, cfg: TransformerConfig, moe_layer: bool, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    attn = init_mla(k1, cfg, dtype) if cfg.mla else init_gqa(k1, cfg, dtype)
    if moe_layer:
        ffn = init_moe(k2, cfg, dtype)
    else:
        ffn = init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype)
    return dict(attn=attn, attn_norm=jnp.zeros((cfg.d_model,), jnp.float32),
                ffn=ffn, ffn_norm=jnp.zeros((cfg.d_model,), jnp.float32))


def init_params(key, cfg: TransformerConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    k_emb, k_out, k_dense, k_layers = jax.random.split(key, 4)
    d, v = cfg.d_model, cfg.vocab
    params = dict(
        embed=(jax.random.normal(k_emb, (v, d), jnp.float32)
               * d ** -0.5).astype(dtype),
        out_embed=(jax.random.normal(k_out, (v, d), jnp.float32)
                   * d ** -0.5).astype(dtype),
        final_norm=jnp.zeros((d,), jnp.float32),
    )
    n_scan = cfg.n_layers - (cfg.n_dense_layers if cfg.moe else 0)
    layer_keys = jax.random.split(k_layers, n_scan)
    params["layers"] = jax.vmap(
        lambda k: _init_layer(k, cfg, cfg.moe, dtype))(layer_keys)
    if cfg.moe and cfg.n_dense_layers:
        params["dense0"] = _init_layer(k_dense, cfg, False, dtype)
    return params


def layer_windows(cfg: TransformerConfig) -> np.ndarray:
    """Per-layer sliding window (0 = global). Gemma pattern: every
    (local_per_global+1)-th layer is global."""
    n_scan = cfg.n_layers - (cfg.n_dense_layers if cfg.moe else 0)
    if cfg.local_per_global <= 0:
        return np.zeros(n_scan, np.int32)
    idx = np.arange(n_scan)
    is_global = (idx + 1) % (cfg.local_per_global + 1) == 0
    return np.where(is_global, 0, cfg.local_window).astype(np.int32)


# -------------------------------------------------------------- forward

def _batch_axes(cfg: TransformerConfig):
    """FSDP shards the batch over EVERY mesh axis (the model axis holds
    no tensor parallelism there); Megatron TP keeps batch on dp only."""
    return ("dp", "tp") if cfg.sharding_mode == "fsdp" else "dp"


def _block(layer, x, positions, window, cfg: TransformerConfig,
           use_pallas: bool):
    if cfg.moe and not cfg.seq_parallel:
        # the MoE shard_map emits (dp, model)-sharded (B, S); re-replicate
        # S ONCE here (one [B,S,d] all-gather) so the attention head
        # constraints don't trigger SPMD's replicate-then-repartition on
        # every projected tensor (the 'involuntary full remat' path)
        x = shard(x, _batch_axes(cfg), None, None)
    h = rms_norm(x, layer["attn_norm"], cfg.norm_eps)
    if cfg.mla:
        a = mla_forward(layer["attn"], h, positions, cfg)
    else:
        a = gqa_forward(layer["attn"], h, positions, cfg,
                        window=int(window) if isinstance(window, int) else 0,
                        use_pallas=use_pallas)
    x = x + a
    h = rms_norm(x, layer["ffn_norm"], cfg.norm_eps)
    h = shard(h, _batch_axes(cfg), None, None)
    if cfg.moe and "router" in layer["ffn"]:
        out, aux = moe_forward(layer["ffn"], h, cfg)   # 3D in, 3D out
    else:
        out, aux = swiglu(layer["ffn"], h), jnp.zeros((), jnp.float32)
    y = x + out
    if cfg.seq_parallel:
        # sequence-parallel residual stream: the saved boundary
        # activation shards over (dp, tp); SPMD turns the per-layer
        # all-reduces into reduce-scatter + all-gather pairs
        y = shard(y, "dp", "tp", None)
    return y, aux


def _block_windowed(layer, x, positions, window, cfg, use_pallas):
    """Variant taking a traced per-layer window (Gemma scan): the window
    is applied inside the mask, one code path for local+global."""
    h = rms_norm(x, layer["attn_norm"], cfg.norm_eps)
    a = _gqa_forward_dyn_window(layer["attn"], h, positions, cfg, window)
    x = x + a
    h = rms_norm(x, layer["ffn_norm"], cfg.norm_eps)
    out = swiglu(layer["ffn"], h)
    return x + out, jnp.zeros((), jnp.float32)


def _gqa_forward_dyn_window(p, x, positions, cfg, window):
    """GQA with a traced window scalar (0 = unbounded)."""
    from repro.models.transformer.attention import _sdpa_chunked
    from repro.models.transformer.rope import apply_rope
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    g = h // kv
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k = (x @ p["wk"]).reshape(b, s, kv, dh)
    v = (x @ p["wv"]).reshape(b, s, kv, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if g > 1:  # expand for TP head-sharding (see attention.gqa_forward)
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    tp = "tp" if cfg.sharding_mode == "tp" else None
    bx = _batch_axes(cfg)
    q = shard(q, bx, None, tp, None)
    k = shard(k, bx, None, tp, None)
    v = shard(v, bx, None, tp, None)
    # dynamic window mask folded into the chunked sdpa via a huge window
    win = jnp.where(window > 0, window, s + 1)
    qg = q.reshape(b, s, h, 1, dh)
    out = _sdpa_dyn(qg, k, v, win, q_chunk=cfg.attn_q_chunk)
    return out.reshape(b, s, h * dh) @ p["wo"]


def _sdpa_dyn(q, k, v, win, q_chunk: int = 512):
    b, s, kvh, g, dh = q.shape
    t = k.shape[1]
    scale = dh ** -0.5
    if s % q_chunk != 0:
        q_chunk = s
    nq = s // q_chunk
    qs = q.reshape(b, nq, q_chunk, kvh, g, dh)
    k32, v32 = k.astype(jnp.float32), v.astype(jnp.float32)

    def tile(i):
        qc = qs[:, i].astype(jnp.float32)
        sc = jnp.einsum("bckgd,btkd->bkgct", qc, k32) * scale
        q_pos = i * q_chunk + jnp.arange(q_chunk)
        k_pos = jnp.arange(t)
        mask = (k_pos[None, :] <= q_pos[:, None]) \
            & (k_pos[None, :] > q_pos[:, None] - win)
        sc = jnp.where(mask, sc, -1e30)
        p = jax.nn.softmax(sc, axis=-1)
        return jnp.einsum("bkgct,btkd->bckgd", p, v32)

    if nq == 1:
        return tile(0).reshape(b, s, kvh, g, dh).astype(q.dtype)
    out = jax.lax.map(tile, jnp.arange(nq))
    return jnp.moveaxis(out, 0, 1).reshape(b, s, kvh, g, dh).astype(q.dtype)


def _remat(fn, cfg: TransformerConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)


def forward(params: dict, tokens: jax.Array, cfg: TransformerConfig, *,
            use_pallas: bool = False) -> tuple[jax.Array, jax.Array]:
    """tokens [B, S] -> (logits [B, S, V], moe aux loss)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, _batch_axes(cfg), None, None)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.moe and cfg.n_dense_layers:
        blk = _remat(functools.partial(_block, cfg=cfg, window=0,
                                       use_pallas=use_pallas), cfg)
        x, _ = blk(params["dense0"], x, positions)

    windows_np = layer_windows(cfg)
    n_scan = len(windows_np)
    if cfg.unroll_layers:
        # probe mode: every layer in the entry computation; static
        # per-layer windows (exact local/global masks for Gemma)
        for i in range(n_scan):
            lyr = jax.tree.map(lambda p: p[i], params["layers"])
            x, a = _block(lyr, x, positions, int(windows_np[i]), cfg,
                          use_pallas)
            aux_total = aux_total + a
    elif cfg.local_per_global > 0:
        windows = jnp.asarray(windows_np)
        body = _remat(lambda lyr, xx, w: _block_windowed(
            lyr, xx, positions, w, cfg, use_pallas), cfg)

        def step(carry, inp):
            lyr, w = inp
            xx, aux = carry
            xx, a = body(lyr, xx, w)
            return (xx, aux + a), None
        (x, aux_total), _ = jax.lax.scan(
            step, (x, aux_total), (params["layers"], windows))
    else:
        body = _remat(lambda lyr, xx: _block(
            lyr, xx, positions, 0, cfg, use_pallas), cfg)

        def step(carry, lyr):
            xx, aux = carry
            xx, a = body(lyr, xx)
            return (xx, aux + a), None
        (x, aux_total), _ = jax.lax.scan(step, (x, aux_total),
                                         params["layers"])

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["out_embed"])
    logits = shard(logits, "dp", None, "tp")  # vocab-parallel head in BOTH modes
    return logits, aux_total


def loss_fn(params: dict, batch: dict, cfg: TransformerConfig, *,
            use_pallas: bool = False) -> jax.Array:
    """batch = {"tokens": [B, S], "labels": [B, S]} (labels -1 = pad)."""
    logits, aux = forward(params, batch["tokens"], cfg,
                          use_pallas=use_pallas)
    return cross_entropy(logits, batch["labels"]) + AUX_COEF * aux


# --------------------------------------------------------------- decode

def init_cache(cfg: TransformerConfig, batch: int, max_seq: int) -> dict:
    """Decode cache pytree. Gemma gets ring buffers for local layers."""
    dtype = jnp.dtype(cfg.dtype)
    n_scan = cfg.n_layers - (cfg.n_dense_layers if cfg.moe else 0)
    kv, dh = cfg.n_kv_heads, cfg.d_head
    cache: dict = {}
    if cfg.mla:
        r, rd = cfg.kv_lora_rank, cfg.qk_rope_dim
        cache["ckv"] = jnp.zeros((n_scan, batch, max_seq, r), dtype)
        cache["kr"] = jnp.zeros((n_scan, batch, max_seq, rd), dtype)
    elif cfg.local_per_global > 0:
        wins = layer_windows(cfg)
        n_local = int((wins > 0).sum())
        n_global = int((wins == 0).sum())
        w = cfg.local_window
        cache["k_local"] = jnp.zeros((n_local, batch, w, kv, dh), dtype)
        cache["v_local"] = jnp.zeros((n_local, batch, w, kv, dh), dtype)
        cache["k_global"] = jnp.zeros((n_global, batch, max_seq, kv, dh), dtype)
        cache["v_global"] = jnp.zeros((n_global, batch, max_seq, kv, dh), dtype)
    else:
        cache["k"] = jnp.zeros((n_scan, batch, max_seq, kv, dh), dtype)
        cache["v"] = jnp.zeros((n_scan, batch, max_seq, kv, dh), dtype)
    if cfg.moe and cfg.n_dense_layers:
        if cfg.mla:
            cache["ckv0"] = jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype)
            cache["kr0"] = jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dtype)
        else:
            cache["k0"] = jnp.zeros((batch, max_seq, kv, dh), dtype)
            cache["v0"] = jnp.zeros((batch, max_seq, kv, dh), dtype)
    return cache


def _ffn_decode(layer, x, cfg):
    h = rms_norm(x, layer["ffn_norm"], cfg.norm_eps)
    if cfg.moe and "router" in layer["ffn"]:
        b = h.shape[0]
        out, _ = moe_forward(layer["ffn"], h.reshape(b, -1), cfg)
        return x + out.reshape(h.shape)
    return x + swiglu(layer["ffn"], h)


def decode_step(params: dict, cache: dict, tokens: jax.Array,
                pos: jax.Array, cfg: TransformerConfig):
    """One decode step. tokens [B, 1] int32, pos scalar int32 (same for
    all sequences; per-sequence offsets belong to the serving engine).
    Returns (logits [B, V], new_cache)."""
    b = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)       # [B, 1, d]
    x = shard(x, "dp", None, None)

    if cfg.moe and cfg.n_dense_layers:
        lyr = params["dense0"]
        h = rms_norm(x, lyr["attn_norm"], cfg.norm_eps)
        if cfg.mla:
            a, cache["ckv0"], cache["kr0"] = mla_decode(
                lyr["attn"], h, pos, cache["ckv0"], cache["kr0"], cfg)
        else:
            a, cache["k0"], cache["v0"] = gqa_decode(
                lyr["attn"], h, pos, cache["k0"], cache["v0"], cfg)
        x = _ffn_decode(lyr, x + a, cfg)

    if cfg.unroll_layers:
        x, cache = _decode_unrolled(params, cache, x, pos, cfg)
    elif cfg.mla:
        def step(carry, inp):
            xx = carry
            lyr, ckv, kr = inp
            h = rms_norm(xx, lyr["attn_norm"], cfg.norm_eps)
            a, ckv, kr = mla_decode(lyr["attn"], h, pos, ckv, kr, cfg)
            xx = _ffn_decode(lyr, xx + a, cfg)
            return xx, (ckv, kr)
        x, (cache["ckv"], cache["kr"]) = jax.lax.scan(
            step, x, (params["layers"], cache["ckv"], cache["kr"]))
    elif cfg.local_per_global > 0:
        x, cache = _decode_gemma(params, cache, x, pos, cfg)
    else:
        win = 0

        def step(carry, inp):
            xx = carry
            lyr, ck, cv = inp
            h = rms_norm(xx, lyr["attn_norm"], cfg.norm_eps)
            a, ck, cv = gqa_decode(lyr["attn"], h, pos, ck, cv, cfg,
                                   window=win)
            xx = _ffn_decode(lyr, xx + a, cfg)
            return xx, (ck, cv)
        x, (cache["k"], cache["v"]) = jax.lax.scan(
            step, x, (params["layers"], cache["k"], cache["v"]))

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["out_embed"])[:, 0]
    return shard(logits, "dp", "tp"), cache


def _decode_unrolled(params, cache, x, pos, cfg):
    """Probe-mode decode: python loop, static per-layer windows."""
    wins = layer_windows(cfg)
    n_scan = len(wins)
    if cfg.local_per_global > 0:
        is_local = wins > 0
        slots = np.where(is_local, np.cumsum(is_local) - 1,
                         np.cumsum(~is_local) - 1)
    new_slices: dict = {k: [] for k in ("k", "v", "ckv", "kr")}
    kl, vl = cache.get("k_local"), cache.get("v_local")
    kg, vg = cache.get("k_global"), cache.get("v_global")
    for i in range(n_scan):
        lyr = jax.tree.map(lambda p: p[i], params["layers"])
        h = rms_norm(x, lyr["attn_norm"], cfg.norm_eps)
        if cfg.mla:
            a, ckv, kr = mla_decode(lyr["attn"], h, pos, cache["ckv"][i],
                                    cache["kr"][i], cfg)
            new_slices["ckv"].append(ckv)
            new_slices["kr"].append(kr)
        elif cfg.local_per_global > 0:
            sl = int(slots[i])
            if wins[i] > 0:
                a, ck, cv = gqa_decode(lyr["attn"], h, pos, kl[sl], vl[sl],
                                       cfg, window=cfg.local_window)
                kl, vl = kl.at[sl].set(ck), vl.at[sl].set(cv)
            else:
                a, ck, cv = gqa_decode(lyr["attn"], h, pos, kg[sl], vg[sl],
                                       cfg, window=0)
                kg, vg = kg.at[sl].set(ck), vg.at[sl].set(cv)
        else:
            a, ck, cv = gqa_decode(lyr["attn"], h, pos, cache["k"][i],
                                   cache["v"][i], cfg, window=0)
            new_slices["k"].append(ck)
            new_slices["v"].append(cv)
        x = _ffn_decode(lyr, x + a, cfg)
    cache = dict(cache)
    for name, sl in new_slices.items():
        if sl:
            cache[name] = jnp.stack(sl)
    if cfg.local_per_global > 0:
        cache.update(k_local=kl, v_local=vl, k_global=kg, v_global=vg)
    return x, cache


def _decode_gemma(params, cache, x, pos, cfg):
    """Dual-cache decode: ring buffers (window W) for local layers,
    full-length caches for global layers; one scan over all layers with
    a cond on the layer kind."""
    wins = layer_windows(cfg)
    is_local = wins > 0
    slot_idx = np.where(is_local, np.cumsum(is_local) - 1,
                        np.cumsum(~is_local) - 1).astype(np.int32)
    kl, vl = cache["k_local"], cache["v_local"]
    kg, vg = cache["k_global"], cache["v_global"]

    def step(carry, inp):
        xx, kl, vl, kg, vg = carry
        lyr, loc, sl = inp
        h = rms_norm(xx, lyr["attn_norm"], cfg.norm_eps)

        def local_branch(op):
            h, kl, vl, kg, vg = op
            a, ck, cv = gqa_decode(lyr["attn"], h, pos, kl[sl], vl[sl],
                                   cfg, window=cfg.local_window)
            return a, kl.at[sl].set(ck), vl.at[sl].set(cv), kg, vg

        def global_branch(op):
            h, kl, vl, kg, vg = op
            a, ck, cv = gqa_decode(lyr["attn"], h, pos, kg[sl], vg[sl],
                                   cfg, window=0)
            return a, kl, vl, kg.at[sl].set(ck), vg.at[sl].set(cv)

        a, kl, vl, kg, vg = jax.lax.cond(loc, local_branch, global_branch,
                                         (h, kl, vl, kg, vg))
        xx = _ffn_decode(lyr, xx + a, cfg)
        return (xx, kl, vl, kg, vg), None

    (x, kl, vl, kg, vg), _ = jax.lax.scan(
        step, (x, kl, vl, kg, vg),
        (params["layers"], jnp.asarray(is_local), jnp.asarray(slot_idx)))
    cache = dict(cache, k_local=kl, v_local=vl, k_global=kg, v_global=vg)
    return x, cache
