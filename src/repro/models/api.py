"""Unified model API: one entry point per (arch x shape-cell) that the
smoke tests, launchers, and the multi-pod dry-run all share.

  bundle = get_bundle("llama3-8b")
  params = bundle.init(key, cfg, dims)
  fn, inputs = bundle.step(cfg, dims, kind)      # callable + SDS specs
  batch = bundle.make_batch(rng, cfg, dims, kind)  # real (small) arrays

``dims`` comes from the ShapeCell (full scale for the dry-run, tiny for
smoke tests) so every cell is driven by the same code path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import (GNNConfig, RecsysConfig, TransformerConfig,
                                get_arch)

I32 = jnp.int32
F32 = jnp.float32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ============================================================ LM family

def _lm_specs(cfg: TransformerConfig, dims: dict, kind: str) -> dict:
    if kind == "train":
        b, s = dims["global_batch"], dims["seq_len"]
        return dict(tokens=_sds((b, s), I32), labels=_sds((b, s), I32))
    if kind == "prefill":
        b, s = dims["global_batch"], dims["seq_len"]
        return dict(tokens=_sds((b, s), I32))
    if kind == "decode":
        b = dims["global_batch"]
        return dict(tokens=_sds((b, 1), I32), pos=_sds((), I32))
    raise ValueError(kind)


def _lm_batch(rng, cfg: TransformerConfig, dims: dict, kind: str) -> dict:
    specs = _lm_specs(cfg, dims, kind)
    out = {}
    for k, s in specs.items():
        if k == "pos":
            out[k] = jnp.asarray(dims.get("pos", 3), I32)
        else:
            out[k] = jnp.asarray(
                rng.integers(0, cfg.vocab, s.shape), I32)
    return out


def _lm_step(cfg: TransformerConfig, kind: str) -> Callable:
    from repro.models.transformer import lm
    if kind == "train":
        return lambda params, batch: lm.loss_fn(params, batch, cfg)
    if kind == "prefill":
        return lambda params, batch: lm.forward(params, batch["tokens"], cfg)[0]
    if kind == "decode":
        return lambda params, cache, batch: lm.decode_step(
            params, cache, batch["tokens"], batch["pos"], cfg)
    raise ValueError(kind)


# =========================================================== GNN family

def _gnn_dims(cell_dims: dict) -> dict:
    d = dict(cell_dims)
    if "fanout" in d:  # minibatch_lg: padded subgraph shapes
        from repro.models.gnn.sampler import subgraph_shapes
        n, e = subgraph_shapes(d["batch_nodes"], tuple(d["fanout"]))
        d["sub_nodes"], d["sub_edges"] = n, e
    return d


def _pad_edges(e: int) -> int:
    """Edge counts pad to 512-multiples so the edge axis shards on any
    production mesh (padding edges are sink self-loops)."""
    return e if e < 512 else -(-e // 512) * 512


def _pad_nodes(n: int) -> int:
    """Node counts (incl. sink) pad likewise for node-sharded layers."""
    return n if n < 512 else -(-n // 512) * 512


def _gnn_specs(cfg: GNNConfig, dims: dict, kind: str) -> dict:
    d = _gnn_dims(dims)
    if "batch" in d:      # molecule: batched small graphs
        n = _pad_nodes(d["batch"] * d["n_nodes"] + 1)
        e = _pad_edges(d["batch"] * d["n_edges"])
        return dict(feats=_sds((n, d["d_feat"]), F32),
                    edges=_sds((e, 2), I32),
                    graph_ids=_sds((n,), I32),
                    graph_labels=_sds((d["batch"],), I32))
    if "sub_nodes" in d:  # sampled minibatch
        return dict(feats=_sds((_pad_nodes(d["sub_nodes"]), d["d_feat"]), F32),
                    edges=_sds((_pad_edges(d["sub_edges"]), 2), I32),
                    labels=_sds((_pad_nodes(d["sub_nodes"]),), I32))
    n = _pad_nodes(d["n_nodes"] + 1)  # full graph + sink (+ pad)
    return dict(feats=_sds((n, d["d_feat"]), F32),
                edges=_sds((_pad_edges(d["n_edges"]), 2), I32),
                labels=_sds((n,), I32))


def _gnn_batch(rng, cfg: GNNConfig, dims: dict, kind: str) -> dict:
    specs = _gnn_specs(cfg, dims, kind)
    n = specs["feats"].shape[0]
    out = dict(
        feats=jnp.asarray(rng.standard_normal(specs["feats"].shape), F32),
        edges=jnp.asarray(rng.integers(0, n - 1, specs["edges"].shape), I32),
    )
    ncls = dims.get("n_classes", cfg.n_classes)
    if "graph_labels" in specs:
        g = specs["graph_labels"].shape[0]
        out["graph_ids"] = jnp.asarray(
            np.minimum(np.arange(n) // dims["n_nodes"], g - 1), I32)
        out["graph_labels"] = jnp.asarray(rng.integers(0, ncls, (g,)), I32)
    else:
        labels = rng.integers(0, ncls, (n,))
        real = dims.get("n_nodes", n - 1)
        labels[min(real, n - 1):] = -1  # sink + node padding
        out["labels"] = jnp.asarray(labels, I32)
    return out


def _gnn_step(cfg: GNNConfig, kind: str, dims: dict) -> Callable:
    from repro.models.gnn import gin
    if "batch" in dims:
        return lambda params, batch: gin.graph_loss(params, batch, cfg)
    return lambda params, batch: gin.node_loss(params, batch, cfg)


def _gnn_init(key, cfg: GNNConfig, dims: dict):
    from repro.models.gnn import gin
    return gin.init_params(key, cfg, dims["d_feat"],
                           dims.get("n_classes", cfg.n_classes))


# ======================================================== RecSys family

def _pad_cand(n: int) -> int:
    """Candidate counts pad up to a 512-multiple so the candidate axis
    shards on any production mesh (1,000,000 -> 1,000,448; padding
    candidates score and are dropped after top-k)."""
    return n if n < 512 else -(-n // 512) * 512


def _recsys_specs(cfg: RecsysConfig, dims: dict, kind: str) -> dict:
    b = dims.get("batch", 1)
    if cfg.interaction in ("fm-2way", "concat"):
        if kind == "retrieval":
            return dict(ids=_sds((1, cfg.n_sparse - 1), I32),
                        dense=_sds((1, cfg.n_dense_feat), F32),
                        cand=_sds((_pad_cand(dims["n_candidates"]),), I32))
        specs = dict(ids=_sds((b, cfg.n_sparse), I32),
                     dense=_sds((b, cfg.n_dense_feat), F32))
        if kind == "train":
            specs["labels"] = _sds((b,), F32)
        return specs
    if cfg.interaction == "self-attn-seq":       # sasrec
        if kind == "train":
            return dict(seq=_sds((b, cfg.seq_len), I32),
                        pos=_sds((b, cfg.seq_len), I32),
                        neg=_sds((b, cfg.seq_len), I32))
        if kind == "retrieval":
            return dict(seq=_sds((1, cfg.seq_len), I32),
                        cand=_sds((_pad_cand(dims["n_candidates"]),), I32))
        return dict(seq=_sds((b, cfg.seq_len), I32),
                    cand=_sds((b, 100), I32))
    # bst
    if kind == "train":
        return dict(seq=_sds((b, cfg.seq_len), I32),
                    target=_sds((b,), I32), labels=_sds((b,), F32))
    if kind == "retrieval":
        return dict(seq=_sds((1, cfg.seq_len), I32),
                    cand=_sds((_pad_cand(dims["n_candidates"]),), I32))
    return dict(seq=_sds((b, cfg.seq_len), I32), target=_sds((b,), I32))


def _recsys_batch(rng, cfg: RecsysConfig, dims: dict, kind: str) -> dict:
    specs = _recsys_specs(cfg, dims, kind)
    out = {}
    for k, s in specs.items():
        if k == "ids":
            cols = np.stack([rng.integers(0, cfg.table_rows[i], s.shape[0])
                             for i in range(s.shape[1])], axis=1)
            out[k] = jnp.asarray(cols, I32)
        elif k in ("seq", "pos", "neg", "target", "cand"):
            hi = max(cfg.n_items, 2)
            out[k] = jnp.asarray(rng.integers(1, hi, s.shape), I32)
        elif k == "dense":
            out[k] = jnp.asarray(rng.standard_normal(s.shape), F32)
        elif k == "labels":
            out[k] = jnp.asarray(rng.integers(0, 2, s.shape), F32)
    return out


def _recsys_module(cfg: RecsysConfig):
    from repro.models.recsys import bst, fm, sasrec, wide_deep
    return {"fm-2way": fm, "concat": wide_deep, "self-attn-seq": sasrec,
            "transformer-seq": bst}[cfg.interaction]


def _recsys_step(cfg: RecsysConfig, kind: str) -> Callable:
    mod = _recsys_module(cfg)
    if kind == "train":
        return lambda params, batch: mod.loss_fn(params, batch, cfg)
    if kind == "retrieval":
        return lambda params, batch: mod.retrieval_step(params, batch, cfg)
    if hasattr(mod, "serve_step"):
        return lambda params, batch: mod.serve_step(params, batch, cfg)
    return lambda params, batch: mod.forward(params, batch["ids"],
                                             batch["dense"], cfg)


# ============================================================== bundles

@dataclasses.dataclass(frozen=True)
class ModelBundle:
    arch_id: str
    config: object
    reduced: object
    shapes: list
    family: str

    def init(self, key, cfg, dims: dict):
        if self.family == "lm":
            from repro.models.transformer import lm
            return lm.init_params(key, cfg)
        if self.family == "gnn":
            return _gnn_init(key, cfg, dims)
        return _recsys_module(cfg).init_params(key, cfg)

    def init_cache(self, cfg, dims: dict):
        assert self.family == "lm"
        from repro.models.transformer import lm
        return lm.init_cache(cfg, dims["global_batch"], dims["seq_len"])

    def step(self, cfg, dims: dict, kind: str) -> Callable:
        if self.family == "lm":
            return _lm_step(cfg, kind)
        if self.family == "gnn":
            return _gnn_step(cfg, kind, _gnn_dims(dims))
        return _recsys_step(cfg, kind)

    def batch_specs(self, cfg, dims: dict, kind: str) -> dict:
        if self.family == "lm":
            return _lm_specs(cfg, dims, kind)
        if self.family == "gnn":
            return _gnn_specs(cfg, dims, kind)
        return _recsys_specs(cfg, dims, kind)

    def make_batch(self, rng, cfg, dims: dict, kind: str) -> dict:
        if self.family == "lm":
            return _lm_batch(rng, cfg, dims, kind)
        if self.family == "gnn":
            return _gnn_batch(rng, cfg, dims, kind)
        return _recsys_batch(rng, cfg, dims, kind)

    def param_specs(self, params):
        from repro.distributed.param_sharding import (gnn_param_specs,
                                                      lm_param_specs,
                                                      recsys_param_specs)
        if self.family == "lm":
            return lm_param_specs(
                params, mode=getattr(self.config, "sharding_mode", "tp"))
        if self.family == "gnn":
            return gnn_param_specs(params)
        return recsys_param_specs(params)


def get_bundle(arch_id: str) -> ModelBundle:
    mod = get_arch(arch_id)
    cfg = mod.CONFIG
    return ModelBundle(arch_id=arch_id, config=cfg, reduced=mod.REDUCED,
                       shapes=mod.SHAPES, family=cfg.family)
