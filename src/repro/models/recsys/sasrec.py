"""SASRec [arXiv:1808.09781]: self-attentive sequential recommendation.

2 causal transformer blocks (1 head, d=50) over the item history;
training uses the paper's BCE with one positive (next item) and one
sampled negative per position. Serving scores the last-position user
state against candidate item embeddings — a pure MIPS, which is where
the Seismic bridge applies (examples/recsys_retrieval.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.models.common import layer_norm
from repro.models.recsys.embedding import init_table, lookup, padded_rows


def init_params(key, cfg: RecsysConfig) -> dict:
    d = cfg.embed_dim
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 2 + cfg.n_blocks)
    blocks = []
    for i in range(cfg.n_blocks):
        bk = jax.random.split(ks[2 + i], 6)
        s = d ** -0.5
        blocks.append(dict(
            wq=(jax.random.normal(bk[0], (d, d)) * s).astype(dtype),
            wk=(jax.random.normal(bk[1], (d, d)) * s).astype(dtype),
            wv=(jax.random.normal(bk[2], (d, d)) * s).astype(dtype),
            wo=(jax.random.normal(bk[3], (d, d)) * s).astype(dtype),
            w1=(jax.random.normal(bk[4], (d, d)) * s).astype(dtype),
            w2=(jax.random.normal(bk[5], (d, d)) * s).astype(dtype),
            ln1_s=jnp.ones((d,), jnp.float32),
            ln1_b=jnp.zeros((d,), jnp.float32),
            ln2_s=jnp.ones((d,), jnp.float32),
            ln2_b=jnp.zeros((d,), jnp.float32),
        ))
    return dict(
        item_emb=init_table(ks[0], padded_rows(cfg.n_items + 1), d, dtype),  # 0 = pad
        pos_emb=(jax.random.normal(ks[1], (cfg.seq_len, d)) * 0.01).astype(dtype),
        blocks=blocks,
    )


def _attn(b, h, cfg):
    bs, s, d = h.shape
    nh = cfg.n_heads
    dh = d // nh
    q = (h @ b["wq"]).reshape(bs, s, nh, dh)
    k = (h @ b["wk"]).reshape(bs, s, nh, dh)
    v = (h @ b["wv"]).reshape(bs, s, nh, dh)
    sc = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * dh ** -0.5
    mask = jnp.arange(s)[None, :] <= jnp.arange(s)[:, None]
    sc = jnp.where(mask, sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32))
    return o.reshape(bs, s, d).astype(h.dtype) @ b["wo"]


def forward(params: dict, seq: jax.Array, cfg: RecsysConfig) -> jax.Array:
    """seq [B, S] item ids (0 = pad) -> states [B, S, D]."""
    h = lookup(params["item_emb"], seq) + params["pos_emb"][None]
    pad = (seq == 0)[..., None]
    h = jnp.where(pad, 0, h)
    for b in params["blocks"]:
        a = _attn(b, layer_norm(h, b["ln1_s"], b["ln1_b"]), cfg)
        h = h + a
        f = layer_norm(h, b["ln2_s"], b["ln2_b"])
        h = h + jax.nn.relu(f @ b["w1"]) @ b["w2"]
        h = jnp.where(pad, 0, h)
    return h


def loss_fn(params: dict, batch: dict, cfg: RecsysConfig) -> jax.Array:
    """batch = {seq [B,S], pos [B,S], neg [B,S]}; pos/neg 0 = pad."""
    h = forward(params, batch["seq"], cfg)
    pe = lookup(params["item_emb"], batch["pos"])
    ne = lookup(params["item_emb"], batch["neg"])
    ps = (h * pe).sum(-1).astype(jnp.float32)
    ns = (h * ne).sum(-1).astype(jnp.float32)
    mask = (batch["pos"] != 0).astype(jnp.float32)
    loss = -(jax.nn.log_sigmoid(ps) + jax.nn.log_sigmoid(-ns)) * mask
    return loss.sum() / jnp.maximum(mask.sum(), 1.0)


def serve_step(params: dict, batch: dict, cfg: RecsysConfig) -> jax.Array:
    """Score per-request candidates: batch = {seq [B,S], cand [B,C]}."""
    h = forward(params, batch["seq"], cfg)[:, -1]           # [B, D]
    ce = lookup(params["item_emb"], batch["cand"])          # [B, C, D]
    return jnp.einsum("bd,bcd->bc", h.astype(jnp.float32),
                      ce.astype(jnp.float32))


def retrieval_step(params: dict, batch: dict, cfg: RecsysConfig) -> jax.Array:
    """One user vs C item candidates: batch = {seq [1,S], cand [C]} —
    a single [C, D] @ [D] MIPS (the Seismic-applicable cell)."""
    h = forward(params, batch["seq"], cfg)[0, -1]
    ce = lookup(params["item_emb"], batch["cand"])
    return ce.astype(jnp.float32) @ h.astype(jnp.float32)
