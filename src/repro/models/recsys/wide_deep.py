"""Wide & Deep [arXiv:1606.07792]: wide linear over categorical fields
+ deep MLP over concatenated field embeddings and dense features."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.models.common import bce_with_logits, mlp_apply, mlp_init
from repro.models.recsys.embedding import (field_offsets, fielded_lookup,
                                           init_table, lookup, padded_rows)


def init_params(key, cfg: RecsysConfig) -> dict:
    rows = padded_rows(sum(cfg.table_rows))
    ks = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.dtype)
    d_in = cfg.n_sparse * cfg.embed_dim + cfg.n_dense_feat
    return dict(
        wide=init_table(ks[0], rows, 1, dtype),
        wide_dense=jnp.zeros((cfg.n_dense_feat,), jnp.float32),
        emb=init_table(ks[1], rows, cfg.embed_dim, dtype),
        deep=mlp_init(ks[2], (d_in,) + cfg.mlp_dims + (1,), dtype),
        b=jnp.zeros((), jnp.float32),
    )


def forward(params: dict, ids: jax.Array, dense: jax.Array,
            cfg: RecsysConfig) -> jax.Array:
    offs = jnp.asarray(field_offsets(cfg.table_rows))
    wide = fielded_lookup(params["wide"], ids, offs)[..., 0].sum(-1)
    emb = fielded_lookup(params["emb"], ids, offs)            # [B, F, D]
    x = jnp.concatenate([emb.reshape(emb.shape[0], -1),
                         dense.astype(emb.dtype)], axis=-1)
    deep = mlp_apply(params["deep"], x, len(cfg.mlp_dims) + 1)[..., 0]
    return (params["b"] + wide + dense @ params["wide_dense"]
            + deep).astype(jnp.float32)


def loss_fn(params: dict, batch: dict, cfg: RecsysConfig) -> jax.Array:
    logits = forward(params, batch["ids"], batch["dense"], cfg)
    return bce_with_logits(logits, batch["labels"])


def retrieval_step(params: dict, batch: dict, cfg: RecsysConfig) -> jax.Array:
    """Score C candidates in field 0 for one user context: the deep MLP
    runs batched over candidates (bulk scorer — no factorization trick
    exists for an MLP)."""
    ids, dense, cand = batch["ids"], batch["dense"], batch["cand"]
    c = cand.shape[0]
    full_ids = jnp.concatenate(
        [jnp.zeros((ids.shape[0], 1), ids.dtype), ids], axis=1)  # slot 0
    full_ids = jnp.broadcast_to(full_ids, (c, full_ids.shape[1]))
    full_ids = full_ids.at[:, 0].set(cand)
    dense_b = jnp.broadcast_to(dense, (c, dense.shape[1]))
    return forward(params, full_ids, dense_b, cfg)
