"""Sharded embedding tables + EmbeddingBag.

JAX has no native nn.EmbeddingBag and no CSR sparse; the lookup here is
built from ``jnp.take`` + masked reductions / ``segment_sum`` — this IS
part of the system (taxonomy §RecSys).

Layout: all categorical fields live in ONE fused table [R_total, D]
with per-field row offsets (the production packing). Under a mesh the
table rows are sharded over the model axis and lookups run in
shard_map: each shard resolves the ids that fall in its row range and
a psum over the model axis completes the gather — the classic
model-parallel embedding with O(B * F * D) collective volume.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import dp_axes, mesh_axis_size, tp_axis


def field_offsets(table_rows: tuple[int, ...]) -> np.ndarray:
    return np.concatenate([[0], np.cumsum(table_rows)[:-1]]).astype(np.int64)


def padded_rows(n: int, mult: int = 512) -> int:
    """Round table rows up so row-sharding divides any mesh axis."""
    return -(-n // mult) * mult


def init_table(key, n_rows: int, dim: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (n_rows, dim), jnp.float32)
            * 0.01).astype(dtype)


def lookup(table: jax.Array, gids: jax.Array) -> jax.Array:
    """Row lookup [..., ] -> [..., D]; model-sharded table under a mesh."""
    tp = tp_axis()
    rows = table.shape[0]
    if tp is None or rows % mesh_axis_size("model") != 0:
        return jnp.take(table, gids, axis=0)

    token_axes = dp_axes()
    if token_axes:
        dp_size = 1
        for a in token_axes:
            dp_size *= mesh_axis_size(a)
        if gids.shape[0] % dp_size != 0:
            token_axes = ()      # small request batches stay replicated

    def body(tbl, ids):
        per = tbl.shape[0]
        shard_id = jax.lax.axis_index("model")
        lo = shard_id * per
        local = ids - lo
        in_range = (local >= 0) & (local < per)
        got = jnp.take(tbl, jnp.clip(local, 0, per - 1), axis=0)
        got = jnp.where(in_range[..., None], got, 0)
        return jax.lax.psum(got, "model")

    ids_spec = P(token_axes) if token_axes else P()
    return jax.shard_map(
        body,
        in_specs=(P("model", None), ids_spec),
        out_specs=ids_spec,
        check_vma=False)(table, gids)


def embedding_bag(table: jax.Array, ids: jax.Array, mask: jax.Array,
                  mode: str = "sum") -> jax.Array:
    """EmbeddingBag: ids [B, L] with validity mask [B, L] -> [B, D].
    take + masked reduce (sum/mean) — the jnp EmbeddingBag."""
    emb = lookup(table, ids)                       # [B, L, D]
    emb = emb * mask[..., None].astype(emb.dtype)
    out = emb.sum(axis=1)
    if mode == "mean":
        out = out / jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)
    return out


def fielded_lookup(table: jax.Array, ids: jax.Array,
                   offsets: jax.Array) -> jax.Array:
    """ids [B, F] per-field local ids -> [B, F, D] via the fused table."""
    gids = ids.astype(jnp.int64) + offsets[None, :]
    return lookup(table, gids)
