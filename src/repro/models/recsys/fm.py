"""Factorization Machine [Rendle, ICDM'10].

score = w0 + sum_i w_i x_i + sum_{i<j} <v_i, v_j> x_i x_j, with the
pairwise term computed by the O(nk) identity
0.5 * ((sum_i v_i x_i)^2 - sum_i (v_i x_i)^2).

Categorical fields have x_i = 1 (one-hot); dense features enter with
their value. The retrieval cell exploits the same identity: with a
fixed user context U and candidate item embedding v_c,
score(c) = const(U) + w_c + <sum(U), v_c>, one [C, D] @ [D] matmul for
a million candidates — no per-candidate loop.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.models.common import bce_with_logits
from repro.models.recsys.embedding import (field_offsets, fielded_lookup,
                                           init_table, lookup, padded_rows)


def init_params(key, cfg: RecsysConfig) -> dict:
    rows = padded_rows(sum(cfg.table_rows))
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    return dict(
        w0=jnp.zeros((), jnp.float32),
        w_lin=init_table(ks[0], rows, 1, dtype),
        v=init_table(ks[1], rows, cfg.embed_dim, dtype),
        w_dense=jnp.zeros((cfg.n_dense_feat,), jnp.float32),
        v_dense=(jax.random.normal(ks[2], (cfg.n_dense_feat, cfg.embed_dim),
                                   jnp.float32) * 0.01).astype(dtype),
    )


def forward(params: dict, ids: jax.Array, dense: jax.Array,
            cfg: RecsysConfig) -> jax.Array:
    """ids [B, F] (per-field local ids), dense [B, Nd] -> logits [B]."""
    offs = jnp.asarray(field_offsets(cfg.table_rows))
    lin = fielded_lookup(params["w_lin"], ids, offs)[..., 0].sum(-1)
    v_cat = fielded_lookup(params["v"], ids, offs)          # [B, F, D]
    v_den = params["v_dense"][None] * dense[..., None]      # [B, Nd, D]
    vx = jnp.concatenate([v_cat, v_den], axis=1)
    s = vx.sum(axis=1)
    pair = 0.5 * ((s * s).sum(-1) - (vx * vx).sum(axis=-1).sum(-1))
    return (params["w0"] + lin + dense @ params["w_dense"]
            + pair).astype(jnp.float32)


def loss_fn(params: dict, batch: dict, cfg: RecsysConfig) -> jax.Array:
    logits = forward(params, batch["ids"], batch["dense"], cfg)
    return bce_with_logits(logits, batch["labels"])


def retrieval_step(params: dict, batch: dict, cfg: RecsysConfig) -> jax.Array:
    """One user context vs C candidates in field 0.
    batch = {ids [1, F-1] (fields 1..F-1), dense [1, Nd], cand [C]}."""
    offs = np.asarray(field_offsets(cfg.table_rows))
    ctx_offs = jnp.asarray(offs[1:])
    ids, dense, cand = batch["ids"], batch["dense"], batch["cand"]
    v_ctx = fielded_lookup(params["v"], ids, ctx_offs)[0]   # [F-1, D]
    v_den = params["v_dense"] * dense[0][:, None]
    u = jnp.concatenate([v_ctx, v_den], 0)                  # [Fc, D]
    u_sum = u.sum(0)
    const = (params["w0"] + dense[0] @ params["w_dense"]
             + fielded_lookup(params["w_lin"], ids, ctx_offs)[0, :, 0].sum()
             + 0.5 * ((u_sum * u_sum).sum() - (u * u).sum()))
    cand_g = cand.astype(jnp.int64) + offs[0]
    v_c = lookup(params["v"], cand_g)                       # [C, D]
    w_c = lookup(params["w_lin"], cand_g)[:, 0]
    return const + w_c + v_c @ u_sum
