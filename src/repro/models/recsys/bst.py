"""BST (Behavior Sequence Transformer, arXiv:1905.06874): one
transformer block (8 heads) over [history ; target item], concatenated
output into a 1024-512-256 MLP CTR head."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.models.common import bce_with_logits, layer_norm, mlp_apply, mlp_init
from repro.models.recsys.embedding import init_table, lookup, padded_rows


def init_params(key, cfg: RecsysConfig) -> dict:
    d = cfg.embed_dim
    dtype = jnp.dtype(cfg.dtype)
    s_total = cfg.seq_len + 1                     # history + target
    ks = jax.random.split(key, 3 + cfg.n_blocks)
    blocks = []
    for i in range(cfg.n_blocks):
        bk = jax.random.split(ks[3 + i], 6)
        sc = d ** -0.5
        blocks.append(dict(
            wq=(jax.random.normal(bk[0], (d, d)) * sc).astype(dtype),
            wk=(jax.random.normal(bk[1], (d, d)) * sc).astype(dtype),
            wv=(jax.random.normal(bk[2], (d, d)) * sc).astype(dtype),
            wo=(jax.random.normal(bk[3], (d, d)) * sc).astype(dtype),
            w1=(jax.random.normal(bk[4], (d, 4 * d)) * sc).astype(dtype),
            w2=(jax.random.normal(bk[5], (4 * d, d)) * (4 * d) ** -0.5).astype(dtype),
            ln1_s=jnp.ones((d,), jnp.float32), ln1_b=jnp.zeros((d,), jnp.float32),
            ln2_s=jnp.ones((d,), jnp.float32), ln2_b=jnp.zeros((d,), jnp.float32),
        ))
    return dict(
        item_emb=init_table(ks[0], padded_rows(cfg.n_items + 1), d, dtype),
        pos_emb=(jax.random.normal(ks[1], (s_total, d)) * 0.01).astype(dtype),
        blocks=blocks,
        head=mlp_init(ks[2], (s_total * d,) + cfg.mlp_dims + (1,), dtype),
    )


def _attn(b, h, n_heads):
    bs, s, d = h.shape
    dh = d // n_heads
    q = (h @ b["wq"]).reshape(bs, s, n_heads, dh)
    k = (h @ b["wk"]).reshape(bs, s, n_heads, dh)
    v = (h @ b["wv"]).reshape(bs, s, n_heads, dh)
    sc = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * dh ** -0.5
    p = jax.nn.softmax(sc, axis=-1)   # bidirectional (CTR scoring)
    o = jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32))
    return o.reshape(bs, s, d).astype(h.dtype) @ b["wo"]


def forward(params: dict, seq: jax.Array, target: jax.Array,
            cfg: RecsysConfig) -> jax.Array:
    """seq [B, S], target [B] -> CTR logits [B]."""
    full = jnp.concatenate([seq, target[:, None]], axis=1)  # [B, S+1]
    h = lookup(params["item_emb"], full) + params["pos_emb"][None]
    for b in params["blocks"]:
        a = _attn(b, layer_norm(h, b["ln1_s"], b["ln1_b"]), cfg.n_heads)
        h = h + a
        f = layer_norm(h, b["ln2_s"], b["ln2_b"])
        h = h + jax.nn.relu(f @ b["w1"]) @ b["w2"]
    x = h.reshape(h.shape[0], -1)
    return mlp_apply(params["head"], x,
                     len(cfg.mlp_dims) + 1)[..., 0].astype(jnp.float32)


def loss_fn(params: dict, batch: dict, cfg: RecsysConfig) -> jax.Array:
    logits = forward(params, batch["seq"], batch["target"], cfg)
    return bce_with_logits(logits, batch["labels"])


def serve_step(params: dict, batch: dict, cfg: RecsysConfig) -> jax.Array:
    return forward(params, batch["seq"], batch["target"], cfg)


def retrieval_step(params: dict, batch: dict, cfg: RecsysConfig) -> jax.Array:
    """One user, C candidate targets: the transformer + MLP run batched
    over candidates (BST has no factorization shortcut — this is the
    honest cost of its interaction structure)."""
    seq, cand = batch["seq"], batch["cand"]
    c = cand.shape[0]
    seq_b = jnp.broadcast_to(seq, (c, seq.shape[1]))
    return forward(params, seq_b, cand, cfg)
