"""Shared model building blocks (pure-jax, framework-free)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = x32.mean(axis=-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = (d_in ** -0.5)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def mlp_init(key, dims: tuple[int, ...], dtype) -> dict:
    """Plain MLP: dims = (in, h1, ..., out); relu between layers."""
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"w{i}": dense_init(keys[i], dims[i], dims[i + 1], dtype)
        for i in range(len(dims) - 1)
    } | {
        f"b{i}": jnp.zeros((dims[i + 1],), dtype)
        for i in range(len(dims) - 1)
    }


def mlp_apply(params: dict, x: jax.Array, n_layers: int) -> jax.Array:
    for i in range(n_layers):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n_layers - 1:
            x = jax.nn.relu(x)
    return x


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over positions with label >= 0 (padding = -1).
    logits [..., V] (possibly vocab-sharded), labels [...]."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = labels >= 0
    nll = jnp.where(mask, lse - ll, 0.0)
    return nll.sum() / jnp.maximum(mask.sum(), 1)


def bce_with_logits(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
