"""jax version-compat shims (container pins jax 0.4.37).

The codebase targets the modern mesh API (``jax.set_mesh``,
``jax.sharding.AxisType``, ``jax.sharding.get_abstract_mesh``,
``jax.make_mesh(..., axis_types=...)``, ``jax.shard_map``). On older
jax these names are missing although the underlying machinery exists
(``Mesh`` is a context manager, ``jax.experimental.shard_map`` takes
``check_rep``). ``install()`` fills ONLY the missing attributes —
every shim is gated on ``hasattr``, so on a jax that already provides
the API this module is a no-op and the real implementations win.

Imported for its side effect from ``repro/__init__.py`` so every
entry point (tests, benchmarks, subprocess snippets) that touches any
``repro`` module gets the shims before it calls the modern API.
"""
from __future__ import annotations

import contextlib
import functools

import jax


def _ambient_mesh():
    """The mesh made ambient by ``set_mesh`` (physical Mesh or None)."""
    from jax._src import mesh as mesh_lib
    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def install() -> None:
    # --- jax.sharding.AxisType (sharding-in-types enum, jax >= 0.5) ---
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType:  # minimal stand-in: only identity is consumed
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"
        jax.sharding.AxisType = AxisType

    # --- jax.sharding.get_abstract_mesh ------------------------------
    if not hasattr(jax.sharding, "get_abstract_mesh"):
        def get_abstract_mesh():
            m = _ambient_mesh()
            return m.abstract_mesh if m is not None else None
        jax.sharding.get_abstract_mesh = get_abstract_mesh

    # --- jax.set_mesh (context manager form) --------------------------
    if not hasattr(jax, "set_mesh"):
        @contextlib.contextmanager
        def set_mesh(mesh):
            with mesh:  # legacy Mesh context: sets thread_resources
                yield mesh
        jax.set_mesh = set_mesh

    # --- jax.make_mesh(..., axis_types=...) ---------------------------
    # signature inspection, NOT a probe call: constructing a Mesh would
    # initialize the backend as a side effect of `import repro`
    import inspect
    try:
        params = inspect.signature(jax.make_mesh).parameters
        _needs_axis_types_shim = "axis_types" not in params
    except (TypeError, ValueError):  # unintrospectable: assume modern
        _needs_axis_types_shim = False
    if _needs_axis_types_shim:
        _orig_make_mesh = jax.make_mesh

        @functools.wraps(_orig_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, devices=None,
                      axis_types=None):
            del axis_types  # legacy meshes have no per-axis types
            return _orig_make_mesh(axis_shapes, axis_names, devices=devices)
        jax.make_mesh = make_mesh

    # --- jax.lax.axis_size -------------------------------------------
    if not hasattr(jax.lax, "axis_size"):
        def axis_size(name):
            from jax._src import core
            frame = core.axis_frame(name)  # returns the size on 0.4.x
            return getattr(frame, "size", frame)
        jax.lax.axis_size = axis_size

    # --- jax.shard_map (top-level, check_vma kwarg) -------------------
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh=None, in_specs, out_specs, check_vma=True,
                      **kw):
            if mesh is None:  # modern jax resolves the ambient mesh
                mesh = _ambient_mesh()
                if mesh is None:
                    raise ValueError(
                        "shard_map: no mesh passed and no ambient mesh "
                        "(enter one with jax.set_mesh)")
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma,
                              **kw)
        jax.shard_map = shard_map


install()
