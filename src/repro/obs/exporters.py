"""Metric/trace exporters: Prometheus text exposition (with a round-
trip parser), a stdlib HTTP endpoint, and a JSONL snapshot writer.

The HTTP endpoint is what a load test, dashboard, or the ROADMAP's
replica load balancer scrapes::

    /metrics        Prometheus text exposition of the registry
    /snapshot.json  the registry's plain-dict snapshot
    /traces         Chrome trace-event JSON of the tracer's ring buffer
    /healthz        liveness probe: always 200 {"status": "ok"} (what a
                    replica load balancer polls)
    /quality.json   the quality plane's snapshot (live recall + Wilson
                    interval, SLO state, loss funnel, drift) when a
                    ``quality`` provider is attached

``parse_prometheus_text`` exists so tests (and the report CLI) can
assert on the *exported* surface, not on registry internals — the
contract is the text format.
"""
from __future__ import annotations

import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.obs.registry import MetricsRegistry
from repro.obs.trace import Tracer

_ESC = {"\\": "\\\\", "\n": "\\n", '"': '\\"'}


def _escape(v: str) -> str:
    return "".join(_ESC.get(ch, ch) for ch in str(v))


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in labels.items())
    return "{" + inner + "}"


def prometheus_text(registry: MetricsRegistry) -> str:
    """Render the registry in Prometheus text exposition format v0.0.4.

    Histograms expose cumulative ``_bucket{le=...}`` series plus
    ``_sum`` / ``_count``; gauge callbacks are evaluated here (a
    failing callback drops its sample, never the scrape).
    """
    lines = []
    for fam in registry.collect():
        lines.append(f"# HELP {fam.name} {_escape(fam.help)}")
        lines.append(f"# TYPE {fam.name} {fam.kind}")
        for label_values, child in fam.samples():
            labels = dict(zip(fam.label_names, label_values))
            if fam.kind == "histogram":
                cum = 0
                for edge, c in zip(child.edges, child.counts):
                    cum += c
                    le = dict(labels, le=f"{edge:.6g}")
                    lines.append(f"{fam.name}_bucket{_fmt_labels(le)} "
                                 f"{cum}")
                cum += child.counts[-1]
                le = dict(labels, le="+Inf")
                lines.append(f"{fam.name}_bucket{_fmt_labels(le)} {cum}")
                lines.append(f"{fam.name}_sum{_fmt_labels(labels)} "
                             f"{child.total:.9g}")
                lines.append(f"{fam.name}_count{_fmt_labels(labels)} "
                             f"{child.n}")
            else:
                try:
                    v = child.value
                except Exception:   # noqa: BLE001 — see docstring
                    continue
                lines.append(f"{fam.name}{_fmt_labels(labels)} {v:.9g}")
    return "\n".join(lines) + "\n"


def parse_prometheus_text(text: str) -> dict:
    """Parse text exposition back into
    ``{name: {"type": ..., "samples": {frozen_labels: value}}}`` where
    ``frozen_labels`` is a sorted tuple of ``(label, value)`` pairs.
    Supports exactly what :func:`prometheus_text` emits (quoted label
    values with ``\\"``/``\\n``/``\\\\`` escapes)."""
    out: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(None, 3)
            out.setdefault(name, {"type": kind, "samples": {}})
            continue
        if line.startswith("#"):
            continue
        # <name>{labels} <value>   |   <name> <value>
        if "{" in line:
            name, rest = line.split("{", 1)
            label_str, value_str = rest.rsplit("}", 1)
            labels = []
            i = 0
            while i < len(label_str):
                eq = label_str.index("=", i)
                key = label_str[i:eq]
                assert label_str[eq + 1] == '"'
                j = eq + 2
                buf = []
                while label_str[j] != '"':
                    if label_str[j] == "\\":
                        nxt = label_str[j + 1]
                        buf.append({"n": "\n"}.get(nxt, nxt))
                        j += 2
                    else:
                        buf.append(label_str[j])
                        j += 1
                labels.append((key, "".join(buf)))
                i = j + 2 if j + 1 < len(label_str) \
                    and label_str[j + 1] == "," else j + 1
        else:
            name, value_str = line.rsplit(None, 1)
            labels = []
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[:-len(suffix)] in out:
                base = name[:-len(suffix)]
                break
        fam = out.setdefault(base if base in out else name,
                             {"type": "untyped", "samples": {}})
        key = (name, tuple(sorted(labels)))
        fam["samples"][key] = float(value_str)
    return out


def write_jsonl_snapshot(registry: MetricsRegistry, path: str, *,
                         extra: dict | None = None) -> dict:
    """Append one JSON line holding the registry snapshot (plus
    caller-supplied ``extra`` fields, e.g. a benchmark tag). Returns
    the record written."""
    rec = {"unix_time": time.time(), "metrics": registry.snapshot()}
    if extra:
        rec.update(extra)
    with open(path, "a", encoding="utf-8") as f:
        f.write(json.dumps(rec) + "\n")
    return rec


class ObsHTTPServer:
    """Background stdlib HTTP endpoint exposing one registry (and
    optionally one tracer). ``port=0`` binds an ephemeral port —
    read it back from ``.port``. Close with ``.close()`` (or use as a
    context manager)."""

    def __init__(self, registry: MetricsRegistry,
                 tracer: Tracer | None = None, *, host: str = "127.0.0.1",
                 port: int = 0, quality=None):
        self.registry = registry
        self.tracer = tracer
        self.quality = quality   # zero-arg callable -> JSON-able dict
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):              # noqa: N802 — stdlib API
                if self.path in ("/metrics", "/"):
                    body = prometheus_text(outer.registry)
                    ctype = "text/plain; version=0.0.4; charset=utf-8"
                elif self.path == "/snapshot.json":
                    body = json.dumps(outer.registry.snapshot())
                    ctype = "application/json"
                elif self.path == "/traces" and outer.tracer is not None:
                    body = json.dumps(outer.tracer.export_chrome())
                    ctype = "application/json"
                elif self.path == "/healthz":
                    body = json.dumps({"status": "ok"})
                    ctype = "application/json"
                elif self.path == "/quality.json" \
                        and outer.quality is not None:
                    body = json.dumps(outer.quality())
                    ctype = "application/json"
                else:
                    self.send_error(404)
                    return
                data = body.encode("utf-8")
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(data)))
                self.end_headers()
                self.wfile.write(data)

            def log_message(self, *a):      # silence per-request stderr
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="obs-exporter", daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join()

    def __enter__(self) -> "ObsHTTPServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def start_exporter(registry: MetricsRegistry,
                   tracer: Tracer | None = None, *,
                   host: str = "127.0.0.1", port: int = 0,
                   quality=None) -> ObsHTTPServer:
    """Start the background metrics/trace HTTP endpoint. ``quality``
    is a zero-arg callable returning a JSON-serializable dict (e.g.
    ``ShadowAuditor.snapshot``), served at ``/quality.json``."""
    return ObsHTTPServer(registry, tracer, host=host, port=port,
                         quality=quality)


__all__ = ["prometheus_text", "parse_prometheus_text",
           "write_jsonl_snapshot", "ObsHTTPServer", "start_exporter"]
