"""Human-readable rendering of the obs surfaces — `python -m
repro.obs.report` prints a metrics-snapshot table, the top-N slowest
traces with their per-stage breakdown, and the quality plane's
recall/funnel report.

    PYTHONPATH=src python -m repro.obs.report \
        [--snapshot obs_snapshots.jsonl] [--traces traces.json] \
        [--quality quality.json] [--top 5]

``--snapshot`` takes a JSONL file written by
:func:`repro.obs.exporters.write_jsonl_snapshot` (the LAST line is
rendered); ``--traces`` a Chrome trace-event JSON file (as exported by
``Tracer.export_chrome`` / the ``/traces`` endpoint); ``--quality`` a
``ShadowAuditor.snapshot()`` JSON file (as served at
``/quality.json``). All renderers are importable so the serving
example and tests reuse them.
"""
from __future__ import annotations

import argparse
import json
import sys


def snapshot_table(snapshot: dict, *, max_rows: int = 200) -> str:
    """Registry snapshot dict -> aligned text table (one row per
    sample; histograms show count/mean/p50/p95/p99)."""
    rows = [("metric", "labels", "value")]
    for name in sorted(snapshot):
        fam = snapshot[name]
        for s in fam.get("samples", []):
            labels = ",".join(f"{k}={v}"
                              for k, v in sorted(s["labels"].items()))
            if fam["type"] == "histogram":
                val = (f"n={s['count']} mean={s['mean']:.3g} "
                       f"p50={s['p50']:.3g} p95={s['p95']:.3g} "
                       f"p99={s['p99']:.3g}")
            else:
                v = s["value"]
                val = f"{v:.6g}" if isinstance(v, float) else str(v)
            rows.append((name, labels, val))
    rows = rows[:max_rows + 1]
    widths = [max(len(r[i]) for r in rows) for i in range(2)]
    return "\n".join(f"{r[0]:<{widths[0]}}  {r[1]:<{widths[1]}}  {r[2]}"
                     for r in rows)


def traces_from_chrome(chrome: dict) -> list[dict]:
    """Group Chrome trace events back into per-trace summaries:
    ``{"trace_id", "name", "duration_s", "spans": [(name, dur_s,
    parent_id, span_id), ...]}``, root first."""
    by_trace: dict = {}
    for ev in chrome.get("traceEvents", []):
        args = ev.get("args", {})
        tid = args.get("trace_id")
        if tid is None:
            continue
        by_trace.setdefault(tid, []).append(ev)
    out = []
    for tid, events in by_trace.items():
        roots = [e for e in events if e["args"].get("parent_id") is None]
        if not roots:
            continue
        root = roots[0]
        spans = sorted(events, key=lambda e: e["ts"])
        out.append({
            "trace_id": tid,
            "name": root["name"],
            "duration_s": root["dur"] / 1e6,
            "spans": [(e["name"], e["dur"] / 1e6,
                       e["args"].get("parent_id"),
                       e["args"].get("span_id")) for e in spans],
        })
    return out


def slowest_traces(chrome: dict, n: int = 5) -> list[dict]:
    """The ``n`` slowest traces in a Chrome trace-event export,
    slowest first."""
    traces = traces_from_chrome(chrome)
    traces.sort(key=lambda t: -t["duration_s"])
    return traces[:n]


def slowest_traces_table(chrome: dict, n: int = 5) -> str:
    lines = []
    for t in slowest_traces(chrome, n):
        lines.append(f"trace {t['trace_id']}  {t['name']}  "
                     f"{t['duration_s'] * 1e3:.3f} ms")
        root_id = next((sid for name, _, pid, sid in t["spans"]
                        if pid is None), None)
        for name, dur, pid, _ in t["spans"]:
            if pid is None:
                continue
            depth = 1 if pid == root_id else 2
            lines.append(f"{'  ' * depth}- {name:<16} "
                         f"{dur * 1e3:.3f} ms")
    return "\n".join(lines) if lines else "(no traces)"


def funnel_table(quality: dict) -> str:
    """Render a ``ShadowAuditor.snapshot()`` dict as a text report:
    live recall with its Wilson interval, the SLO verdict, and the
    per-stage loss funnel (share of attributed misses per stage)."""
    win = quality.get("window", {})
    target = quality.get("target")
    lines = [
        f"live recall@{quality.get('k')}: "
        f"{win.get('live_recall', 0.0):.4f}  "
        f"wilson=[{win.get('wilson_lo', 0.0):.4f}, "
        f"{win.get('wilson_hi', 1.0):.4f}]  "
        f"({win.get('trials', 0)} trials / "
        f"{win.get('audited', 0)} audited)",
        f"SLO: {quality.get('slo_state', 'ok')}"
        + (f"  (target {target:.3f})" if target is not None
           else "  (no target attached)"),
        f"audits={quality.get('audits', 0)}  "
        f"dropped={quality.get('dropped', 0)}  "
        f"errors={quality.get('errors', 0)}",
    ]
    loss = quality.get("loss", {})
    misses = quality.get("misses", 0)
    total = sum(loss.values())
    lines.append(f"loss funnel ({misses} attributed misses):")
    for stage in ("router", "selector", "scorer", "refine"):
        cnt = loss.get(stage, 0)
        share = cnt / total if total else 0.0
        bar = "#" * round(share * 40)
        lines.append(f"  {stage:<9} {cnt:>6}  {share:>6.1%}  {bar}")
    drift = quality.get("drift")
    if drift is not None:
        lines.append(
            f"drift: nnz x{drift['nnz_ratio']:.3f}  "
            f"l1 x{drift['l1_ratio']:.3f}  "
            f"topcoord_tv={drift['topcoord_tv']:.3f}  "
            f"in_sample={drift['in_sample']:.2f}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render obs snapshots and trace exports as text.")
    ap.add_argument("--snapshot", default=None,
                    help="JSONL snapshot file (last line is rendered)")
    ap.add_argument("--traces", default=None,
                    help="Chrome trace-event JSON file")
    ap.add_argument("--quality", default=None,
                    help="ShadowAuditor snapshot JSON file "
                         "(the /quality.json payload)")
    ap.add_argument("--top", type=int, default=5,
                    help="slowest traces to show (default 5)")
    args = ap.parse_args(argv)
    if not args.snapshot and not args.traces and not args.quality:
        ap.error("nothing to do: pass --snapshot, --traces and/or "
                 "--quality")
    if args.snapshot:
        with open(args.snapshot, encoding="utf-8") as f:
            lines = [ln for ln in f.read().splitlines() if ln.strip()]
        if not lines:
            print(f"{args.snapshot}: empty", file=sys.stderr)
            return 1
        rec = json.loads(lines[-1])
        print(f"== metrics snapshot ({args.snapshot}, "
              f"{len(lines)} records, showing last) ==")
        print(snapshot_table(rec["metrics"]))
    if args.traces:
        with open(args.traces, encoding="utf-8") as f:
            chrome = json.load(f)
        print(f"== top {args.top} slowest traces ({args.traces}) ==")
        print(slowest_traces_table(chrome, args.top))
    if args.quality:
        with open(args.quality, encoding="utf-8") as f:
            quality = json.load(f)
        # either a bare ShadowAuditor.snapshot() or an artifact that
        # wraps several (e.g. serving_load's {"tuned": ..., "mistuned":
        # ...} obs_quality.json) — render every snapshot found
        sections = [("", quality)] if "window" in quality else \
            [(f" [{k}]", v) for k, v in quality.items()
             if isinstance(v, dict) and "window" in v]
        for tag, snap in sections:
            print(f"== quality plane ({args.quality}{tag}) ==")
            print(funnel_table(snap))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
