"""Per-request tracing: span trees, a bounded trace ring buffer, and
Chrome trace-event export.

A *trace* is one request's (or one sync launch's) tree of spans. The
id is minted at ``AsyncSeismicServer.submit`` and rides the request
through the queue, the micro-batcher, and — on sampled launches — down
into per-stage and per-refine-round child spans of
``run_pipeline_staged``. Completed traces land in a bounded ring
buffer (oldest evicted) and export as Chrome trace-event JSON that
loads directly in Perfetto / ``chrome://tracing``.

Span model (see ``src/repro/obs/README.md`` for the full table)::

    request                      one per submit; root span
    ├─ queue_wait                submit -> dispatch
    └─ launch                    dispatch -> results ready
       ├─ stage_prep ...         6 children, batch leader only,
       ├─ stage_refine           on SAMPLED launches
       │  ├─ refine_round_0      per-round children of stage_refine
       │  └─ refine_round_1
       └─ ...

A batch launch runs ONCE for up to ``max_batch`` requests: every
member request gets its own ``launch`` span (same wall interval,
``batch_seq`` attr links them), and the per-stage children attach to
the *batch leader*'s launch span — stages ran once, so they are
recorded once. Coalesced followers carry ``coalesced_into=<primary
trace id>`` on their root span.

All span timestamps are ``time.monotonic()`` seconds (the serving
layer's clock); Chrome export converts to microseconds.
"""
from __future__ import annotations

import itertools
import json
import threading
from collections import deque
from dataclasses import dataclass, field


@dataclass
class Span:
    """One timed operation inside a trace."""

    trace_id: int
    span_id: int
    parent_id: int | None
    name: str
    t0: float                       # monotonic seconds
    t1: float | None = None         # None while open
    attrs: dict = field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return (self.t1 if self.t1 is not None else self.t0) - self.t0


@dataclass
class Trace:
    """One request's span tree. ``spans[0]`` is the root."""

    trace_id: int
    spans: list[Span] = field(default_factory=list)

    @property
    def root(self) -> Span:
        return self.spans[0]

    @property
    def duration_s(self) -> float:
        return self.root.duration_s

    def span_map(self) -> dict[int, Span]:
        return {s.span_id: s for s in self.spans}


class Tracer:
    """Thread-safe span factory + bounded finished-trace ring buffer.

    ``capacity`` bounds RETAINED finished traces, not tracing rate —
    every request is traced; old traces are evicted FIFO. The ring
    holds small plain-python objects (~a few hundred bytes per trace),
    so the default keeps memory in the low MBs.
    """

    def __init__(self, capacity: int = 256):
        self.capacity = capacity
        self._lock = threading.Lock()
        self._ring: deque[Trace] = deque(maxlen=capacity)
        self._ids = itertools.count(1)
        self.dropped = 0            # finished traces evicted from the ring

    # ------------------------------------------------------ span API

    def start_trace(self, name: str, t0: float, **attrs) -> Trace:
        """Mint a trace whose root span is ``name``, open at ``t0``."""
        with self._lock:
            tid = next(self._ids)
            sid = next(self._ids)
        tr = Trace(trace_id=tid)
        tr.spans.append(Span(trace_id=tid, span_id=sid, parent_id=None,
                             name=name, t0=t0, attrs=dict(attrs)))
        return tr

    def add_span(self, trace: Trace, name: str, t0: float,
                 t1: float | None = None, parent: Span | None = None,
                 **attrs) -> Span:
        """Append a span (retroactively closed when ``t1`` is given).
        ``parent`` defaults to the trace root."""
        with self._lock:
            sid = next(self._ids)
        p = parent if parent is not None else trace.root
        s = Span(trace_id=trace.trace_id, span_id=sid,
                 parent_id=p.span_id, name=name, t0=t0, t1=t1,
                 attrs=dict(attrs))
        trace.spans.append(s)
        return s

    def end_span(self, span: Span, t1: float, **attrs) -> None:
        span.t1 = t1
        if attrs:
            span.attrs.update(attrs)

    def end_trace(self, trace: Trace, t1: float, **attrs) -> None:
        """Close the root span and retire the trace into the ring."""
        self.end_span(trace.root, t1, **attrs)
        with self._lock:
            if len(self._ring) == self._ring.maxlen:
                self.dropped += 1
            self._ring.append(trace)

    # ----------------------------------------------------- inspection

    def finished(self) -> list[Trace]:
        """Snapshot of retained finished traces, oldest first."""
        with self._lock:
            return list(self._ring)

    def drain(self) -> list[Trace]:
        with self._lock:
            out = list(self._ring)
            self._ring.clear()
            return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    # -------------------------------------------------------- export

    def export_chrome(self) -> dict:
        """Chrome trace-event JSON of every retained trace (viewable in
        Perfetto / about:tracing)."""
        return chrome_trace(self.finished())


def chrome_trace(traces: list[Trace]) -> dict:
    """Traces -> Chrome trace-event JSON (``ph: "X"`` complete events).

    Each trace gets its own ``tid`` so its spans nest visually;
    ``args`` carries the span/parent ids so the tree survives the
    (flat) event format round-trip.
    """
    events = []
    for tr in traces:
        for s in tr.spans:
            t1 = s.t1 if s.t1 is not None else s.t0
            events.append({
                "name": s.name,
                "cat": "seismic",
                "ph": "X",
                "ts": s.t0 * 1e6,              # Chrome wants microseconds
                "dur": max(0.0, (t1 - s.t0) * 1e6),
                "pid": 1,
                "tid": tr.trace_id,
                "args": {"trace_id": tr.trace_id, "span_id": s.span_id,
                         "parent_id": s.parent_id, **s.attrs},
            })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def validate_trace(trace: Trace, *, eps: float = 5e-4) -> None:
    """Assert one trace's span tree is well-formed: exactly one root,
    every parent id resolves in-trace, spans are closed, and children
    lie inside their parent's interval (within ``eps`` seconds of
    timer slop). Raises ``ValueError`` on the first violation."""
    by_id = trace.span_map()
    roots = [s for s in trace.spans if s.parent_id is None]
    if len(roots) != 1 or roots[0] is not trace.root:
        raise ValueError(f"trace {trace.trace_id}: {len(roots)} roots")
    for s in trace.spans:
        if s.t1 is None:
            raise ValueError(
                f"trace {trace.trace_id}: span {s.name} never closed")
        if s.t1 < s.t0:
            raise ValueError(
                f"trace {trace.trace_id}: span {s.name} ends before "
                f"it starts")
        if s.parent_id is None:
            continue
        p = by_id.get(s.parent_id)
        if p is None:
            raise ValueError(
                f"trace {trace.trace_id}: span {s.name} parent "
                f"{s.parent_id} not in trace")
        if s.t0 < p.t0 - eps or (p.t1 is not None and s.t1 > p.t1 + eps):
            raise ValueError(
                f"trace {trace.trace_id}: span {s.name} "
                f"[{s.t0:.6f}, {s.t1:.6f}] outside parent {p.name} "
                f"[{p.t0:.6f}, {p.t1:.6f}]")


def chrome_trace_json(traces: list[Trace]) -> str:
    return json.dumps(chrome_trace(traces))


__all__ = ["Span", "Trace", "Tracer", "chrome_trace",
           "chrome_trace_json", "validate_trace"]
