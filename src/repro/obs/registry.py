"""Unified, labeled, thread-safe metrics registry.

One ``MetricsRegistry`` holds every metric the serving and retrieval
layers emit — counters, gauges, and log-bucket histograms, each a
*family* keyed by a Prometheus-style name with a fixed label schema.
The registry is the single source the exporters scrape
(:mod:`repro.obs.exporters`), the report CLI renders
(:mod:`repro.obs.report`), and the legacy ``ServerTelemetry`` facade
(:mod:`repro.serve.telemetry`) now writes through — there is exactly
one metric sink per server, however many surfaces read it.

Design points:

* families are created idempotently (``registry.counter(name, ...)``
  returns the existing family when called twice) but re-registering a
  name with a different type or label schema is an error — silent
  metric aliasing is how dashboards lie;
* all mutation paths take the registry lock; records are cheap (a
  bisect into fixed bucket edges, an add, a dict move) so the lock is
  uncontended at serving rates;
* gauges can carry a *callback* (``set_fn``) evaluated at collect
  time, for values that are derived state (cache hit-rate, shed rate,
  tuned-policy drift) rather than events;
* the histogram quantile estimator is shared with
  ``serve.telemetry.Histogram`` (which subclasses it): a single
  cumulative-count walk, geometric interpolation *within* the landing
  bucket, estimates monotone in ``p`` and always inside
  ``[vmin, vmax]``.
"""
from __future__ import annotations

import bisect
import itertools
import math
import re
import threading

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


class Histogram:
    """Fixed log-spaced-bucket histogram (default 1us .. 1000s).

    Quantiles are bucket-resolution estimates, refined by geometric
    interpolation inside the landing bucket: for target rank ``t`` in a
    bucket holding ``c`` observations between edges ``[l, r)``, the
    estimate is ``l * (r/l) ** frac`` with ``frac`` the rank's position
    within the bucket. The estimator is monotone non-decreasing in
    ``p`` and always clamped to the observed ``[vmin, vmax]`` —
    ``percentile(0.0) == vmin`` and ``percentile(1.0) == vmax`` exactly.
    """

    def __init__(self, lo: float = 1e-6, hi: float = 1e3,
                 n_buckets: int = 64):
        self.lo, self.hi = lo, hi
        ratio = (hi / lo) ** (1.0 / n_buckets)
        self.edges = [lo * ratio ** i for i in range(1, n_buckets + 1)]
        self.counts = [0] * (n_buckets + 1)   # last bucket = overflow
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def record(self, x: float) -> None:
        self.counts[bisect.bisect_left(self.edges, x)] += 1
        self.n += 1
        self.total += x
        self.vmin = min(self.vmin, x)
        self.vmax = max(self.vmax, x)

    def percentiles(self, ps) -> list[float]:
        """Quantile estimates for every ``p`` in ``ps`` from ONE
        cumulative-count walk (the cumsum is built once, each query is
        a bisect into it)."""
        if self.n == 0:
            return [0.0 for _ in ps]
        cums = list(itertools.accumulate(self.counts))
        return [self._quantile(p, cums) for p in ps]

    def percentile(self, p: float) -> float:
        """p in [0, 1] -> monotone, [vmin, vmax]-bounded estimate."""
        return self.percentiles((p,))[0]

    def _quantile(self, p: float, cums: list[int]) -> float:
        target = min(max(p, 0.0), 1.0) * self.n
        if target <= 0:
            return self.vmin
        i = bisect.bisect_left(cums, target)
        i = min(i, len(self.counts) - 1)
        prev = cums[i - 1] if i else 0
        in_bucket = self.counts[i]
        frac = (target - prev) / in_bucket if in_bucket else 1.0
        left = self.lo if i == 0 else self.edges[i - 1]
        if i < len(self.edges):
            right = self.edges[i]
        else:                                  # overflow bucket
            right = max(self.vmax, left)
        est = left * (right / left) ** frac if left > 0 else right * frac
        return min(max(est, self.vmin), self.vmax)

    def summary(self) -> dict:
        if self.n == 0:
            return {"count": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0,
                    "p99": 0.0, "min": 0.0, "max": 0.0}
        p50, p95, p99 = self.percentiles((0.50, 0.95, 0.99))
        return {"count": self.n, "mean": self.total / self.n,
                "p50": p50, "p95": p95, "p99": p99,
                "min": self.vmin, "max": self.vmax}


class Counter:
    """Monotone float/int accumulator (one labeled child)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n=1) -> None:
        if n < 0:
            raise ValueError(f"counters only go up (inc {n})")
        self.value += n


class Gauge:
    """Point-in-time value; either set directly or computed at collect
    time by a callback (``set_fn``)."""

    __slots__ = ("_value", "_fn")

    def __init__(self):
        self._value = 0.0
        self._fn = None

    def set(self, v: float) -> None:
        self._fn = None
        self._value = float(v)

    def set_fn(self, fn) -> None:
        """Derive the value lazily at every collect — for rates and
        drift computed from other state."""
        self._fn = fn

    @property
    def value(self) -> float:
        if self._fn is not None:
            return float(self._fn())
        return self._value


class Family:
    """One named metric with a fixed label schema and per-labelset
    children. Children are created on first use and never expire."""

    def __init__(self, name: str, kind: str, help: str,
                 label_names: tuple[str, ...], child_factory, lock):
        self.name = name
        self.kind = kind
        self.help = help
        self.label_names = label_names
        self._children: dict[tuple[str, ...], object] = {}
        self._factory = child_factory
        self._lock = lock

    def labels(self, *values):
        """The child for one labelset (values positional, matching
        ``label_names``; coerced to str)."""
        if len(values) != len(self.label_names):
            raise ValueError(
                f"{self.name}: expected labels {self.label_names}, "
                f"got {values!r}")
        key = tuple(str(v) for v in values)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = self._factory()
            return child

    def samples(self):
        """Snapshot of (label_values, child) pairs, sorted by labels."""
        with self._lock:
            items = sorted(self._children.items())
        return items


class MetricsRegistry:
    """Thread-safe collection of metric families (the one per-server
    sink). ``collect()`` is the exporter surface; ``snapshot()`` the
    plain-dict (JSONL) one."""

    def __init__(self):
        self._lock = threading.RLock()
        self._families: dict[str, Family] = {}

    # -------------------------------------------------- registration

    def _family(self, name: str, kind: str, help: str,
                labels: tuple[str, ...], factory) -> Family:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        labels = tuple(labels)
        for ln in labels:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r} on {name}")
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.label_names != labels:
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{fam.kind}{fam.label_names}, not "
                        f"{kind}{labels}")
                return fam
            fam = Family(name, kind, help, labels, factory, self._lock)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "",
                labels: tuple[str, ...] = ()) -> Family:
        return self._family(name, "counter", help, labels, Counter)

    def gauge(self, name: str, help: str = "",
              labels: tuple[str, ...] = ()) -> Family:
        return self._family(name, "gauge", help, labels, Gauge)

    def histogram(self, name: str, help: str = "",
                  labels: tuple[str, ...] = (), *, lo: float = 1e-6,
                  hi: float = 1e3, n_buckets: int = 64) -> Family:
        return self._family(
            name, "histogram", help, labels,
            lambda: Histogram(lo=lo, hi=hi, n_buckets=n_buckets))

    # ------------------------------------------------------- reading

    def collect(self) -> list[Family]:
        with self._lock:
            return [self._families[k] for k in sorted(self._families)]

    def get(self, name: str) -> Family | None:
        with self._lock:
            return self._families.get(name)

    def snapshot(self) -> dict:
        """Plain JSON-serializable dict: family -> sample list. Gauge
        callbacks are evaluated here; a failing callback drops only its
        own sample."""
        out = {}
        for fam in self.collect():
            samples = []
            for label_values, child in fam.samples():
                labels = dict(zip(fam.label_names, label_values))
                if fam.kind == "histogram":
                    samples.append({"labels": labels,
                                    **child.summary()})
                else:
                    try:
                        samples.append({"labels": labels,
                                        "value": child.value})
                    except Exception:   # noqa: BLE001 — a broken gauge
                        continue        # callback must not kill scrapes
            out[fam.name] = {"type": fam.kind, "help": fam.help,
                             "samples": samples}
        return out


__all__ = ["Histogram", "Counter", "Gauge", "Family", "MetricsRegistry"]
