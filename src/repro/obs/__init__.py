"""End-to-end observability for the serving and retrieval layers.

    trace       per-request span trees, bounded ring buffer, Chrome
                trace-event export (Perfetto-viewable)
    registry    labeled thread-safe metrics (counters / gauges /
                histograms) — the one sink every exporter scrapes
    exporters   Prometheus text exposition + stdlib HTTP endpoint +
                JSONL snapshot writer
    device      achieved-vs-modeled HBM bandwidth per stage per fuse
                level (workmodel bytes / measured stage seconds)
    report      `python -m repro.obs.report` snapshot + slowest-trace
                tables

``Observability`` is the bundle a server takes: one registry, one
tracer, and the stage-sampling knob. Request/queue/launch spans are
recorded for EVERY request (cheap plain-python bookkeeping); the
stage-level children require the stage-by-stage pipeline, which
materializes inter-stage arrays and costs roughly one extra fused
launch of wall time, so they are recorded on every
``stage_sample_every``-th launch — sampled tracing keeps full
instrumentation inside the <5% p50 / <3% QPS overhead gate
(``benchmarks/obs_overhead.py``; the default cadence amortizes the
staged launch to well under 1% of throughput) while still producing a
complete request -> queue_wait -> launch -> stages -> refine-round
tree on a steady cadence. Set ``stage_sample_every=1`` to trace stages on every
launch (demos, debugging), ``0`` to disable stage detail entirely.
"""
from __future__ import annotations

import dataclasses

from repro.obs.device import DeviceAccounting
from repro.obs.exporters import (ObsHTTPServer, parse_prometheus_text,
                                 prometheus_text, start_exporter,
                                 write_jsonl_snapshot)
from repro.obs.quality import (FUNNEL_STAGES, ShadowAuditor, per_query_recall,
                               recall_at_k, sample_stats, wilson_interval)
from repro.obs.registry import (Counter, Family, Gauge, Histogram,
                                MetricsRegistry)
from repro.obs.trace import (Span, Trace, Tracer, chrome_trace,
                             chrome_trace_json, validate_trace)


@dataclasses.dataclass
class Observability:
    """One server's observability bundle: metric sink + tracer +
    sampling policy (+ optionally the quality plane's auditor, which
    servers pick up and feed sampled requests). Build with
    :meth:`create`."""

    registry: MetricsRegistry
    tracer: Tracer | None = None
    stage_sample_every: int = 128
    auditor: ShadowAuditor | None = None

    @classmethod
    def create(cls, *, trace_capacity: int = 256,
               stage_sample_every: int = 128,
               tracing: bool = True) -> "Observability":
        return cls(registry=MetricsRegistry(),
                   tracer=Tracer(capacity=trace_capacity)
                   if tracing else None,
                   stage_sample_every=stage_sample_every)

    def sample_stages(self, launch_seq: int) -> bool:
        """Deterministic stage-detail sampling: every Nth launch."""
        return (self.stage_sample_every > 0
                and launch_seq % self.stage_sample_every == 0)


__all__ = [
    "Observability",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "Family",
    "Tracer", "Trace", "Span", "chrome_trace", "chrome_trace_json",
    "validate_trace",
    "prometheus_text", "parse_prometheus_text", "write_jsonl_snapshot",
    "ObsHTTPServer", "start_exporter",
    "DeviceAccounting",
    "ShadowAuditor", "recall_at_k", "per_query_recall", "wilson_interval",
    "sample_stats", "FUNNEL_STAGES",
]
