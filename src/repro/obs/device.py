"""Device-truthful stage accounting: pair each stage's measured
blocking wall time with its modeled HBM traffic.

On the CPU interpret path, wall time says little about what a fusion
level buys on device — but the *modeled* HBM bytes per stage
(:mod:`repro.retrieval.workmodel`, the same arithmetic the kernel
wrappers use for tile selection) are hardware-truthful by
construction. This module turns the one-off benchmark rows into
continuously exported metrics: on every staged (sampled) launch it
updates, per stage and per fuse level,

    seismic_stage_modeled_bytes_per_query{stage,fuse_level}
        modeled HBM bytes one query moves through the stage. The
        scorer's value is DYNAMIC: at ``fuse_level >= 1`` it charges
        only the candidate tiles the kernel actually processes, via
        the ``cand_tiles_processed`` host mirror of the tile-skip
        predicate — so cache-friendly traffic (high dedupe rates)
        shows up as shrinking modeled bytes, live.

    seismic_stage_achieved_bytes_per_second{stage,fuse_level}
        modeled bytes moved by the launch divided by the stage's
        measured blocking wall time — achieved-vs-modeled bandwidth.
        On a real TPU this approaches HBM bandwidth for the streaming
        stages; on the interpret path it is a consistency signal
        (fused levels should move fewer modeled bytes per second of
        *unchanged* wall time).

Only the three stages with a traffic model (router / scorer / refine)
are accounted; prep, selector, and merge move output-sized arrays the
model treats as free.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import numpy as np

from repro.retrieval.workmodel import (refine_bytes, router_bytes,
                                       scorer_bytes)

if TYPE_CHECKING:
    from repro.core.types import SeismicIndex
    from repro.obs.registry import MetricsRegistry
    from repro.retrieval.params import SearchParams

MODELED_STAGES = ("router", "scorer", "refine")


def scored_slots_mirror(cand, n_docs: int, nnz: int, dim: int, *,
                        quant: bool) -> int:
    """Per-query candidate slots the fused scorer kernel actually
    processes, from the ``cand_tiles_processed`` host mirror of its
    tile-skip predicate (bit-for-bit the kernel's own decision — same
    tile choice, same padded layout)."""
    from repro.kernels.gather_dot.ops import (cand_tile_choice,
                                              cand_tiles_processed)
    a = np.asarray(cand)
    qn, c = a.shape
    ch = cand_tile_choice(qn, c, nnz, quant=quant, dim=dim)
    proc = cand_tiles_processed(a, n_docs, ch.tile_q, ch.tile_n)
    return int(proc.sum()) * ch.tile_q * ch.tile_n // max(qn, 1)


class DeviceAccounting:
    """Registry-backed achieved-vs-modeled bandwidth accounting for one
    (index, params) serving configuration."""

    def __init__(self, index: "SeismicIndex", p: "SearchParams",
                 registry: "MetricsRegistry"):
        self.index = index
        self.p = p
        self.fuse = str(p.fuse_level)
        cfg = index.config
        self.nnz = int(index.fwd.coords.shape[1])
        self.quant = index.fwd_scale is not None
        self._modeled = registry.gauge(
            "seismic_stage_modeled_bytes_per_query",
            "Modeled HBM bytes per query per stage "
            "(repro.retrieval.workmodel)",
            ("stage", "fuse_level"))
        self._bw = registry.gauge(
            "seismic_stage_achieved_bytes_per_second",
            "Modeled stage bytes moved / measured blocking stage wall "
            "time", ("stage", "fuse_level"))
        # router and refine traffic is static in the launch shape
        self._static = {
            "router": router_bytes(
                cut=p.cut, n_blocks=cfg.n_blocks,
                summary_nnz=cfg.summary_nnz, dim=index.dim,
                fuse_level=p.fuse_level, n_superblocks=cfg.n_superblocks,
                fanout=p.superblock_fanout,
                superblock_budget=p.superblock_budget,
                superblock_nnz=cfg.superblock_nnz),
            "refine": refine_bytes(
                k=p.k, degree=p.graph_degree, rounds=p.refine_rounds,
                nnz=self.nnz, quant=self.quant, dim=index.dim,
                fuse_level=p.fuse_level),
        }
        for stage, b in self._static.items():
            self._modeled.labels(stage, self.fuse).set(b)

    def scorer_bytes_per_query(self, cand=None) -> int:
        """Scorer traffic for one launch's candidate tensor (``cand``
        as produced by the scorer stage; ``None`` models the worst case
        with every slot scored)."""
        if cand is None:
            n_slots = self.p.block_budget * self.index.config.block_cap
            scored = n_slots
        else:
            a = np.asarray(cand)
            n_slots = a.shape[1]
            if self.p.fuse_level >= 1:
                scored = scored_slots_mirror(
                    a, self.index.n_docs, self.nnz, self.index.dim,
                    quant=self.quant)
            else:
                scored = n_slots
        return scorer_bytes(n_slots=n_slots, scored_slots=scored,
                            nnz=self.nnz, quant=self.quant,
                            dim=self.index.dim,
                            fuse_level=self.p.fuse_level)

    def observe(self, stage_seconds: dict[str, float], width: int,
                cand=None) -> None:
        """Record one staged launch: ``stage_seconds`` maps stage name
        to blocking wall seconds, ``width`` is the launch width (rows),
        ``cand`` the scorer stage's candidate output if captured."""
        per_query = dict(self._static)
        per_query["scorer"] = self.scorer_bytes_per_query(cand)
        for stage in MODELED_STAGES:
            b = per_query[stage]
            self._modeled.labels(stage, self.fuse).set(b)
            dt = stage_seconds.get(stage)
            if dt is not None and dt > 0 and b > 0:
                self._bw.labels(stage, self.fuse).set(b * width / dt)


__all__ = ["DeviceAccounting", "scored_slots_mirror", "MODELED_STAGES"]
