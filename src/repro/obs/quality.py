"""Quality observability plane: shadow-oracle recall auditing,
per-stage loss attribution, and query-drift alerting.

PR 7's observability sees latency and HBM bytes; nothing verifies the
*recall* a ``TunedPolicy`` promises once traffic is live. This module
is the recall half:

``recall_at_k``     the ONE shared recall implementation (benchmarks,
                    tuner, auditor — previously three copies).
``ShadowAuditor``   samples every ``audit_sample_every``-th served
                    request into a bounded off-hot-path queue, recomputes
                    exact top-k on a background thread
                    (``core.oracle.exact_topk`` over the index's forward
                    plane), and emits windowed live-recall gauges with
                    Wilson confidence intervals plus an ok/warn/breach
                    SLO state machine against the tuned recall target.
``attribute_misses`` the loss-attribution funnel: every missed oracle
                    doc maps to EXACTLY ONE dropping stage —

    router      no probed list routed any block holding the doc (the
                doc is reachable only through unprobed coordinates,
                dead blocks, or superblock-pruned blocks)
    selector    at least one routed block holds the doc, but the
                selector cut every such block (budget/threshold), so
                the doc was never exactly scored
    scorer      the doc WAS exactly scored (it is in the scorer's
                candidate row) yet lost the merge — u8 quantization
                error or a score tie displaced it
    refine      the doc sat in the refine stage's expansion frontier
                (a graph neighbor of the merged top-k) and refinement
                still did not keep it

The attribution is a total function over misses, so per-query funnel
counts sum to exactly the miss count — the benchmark gate.

Drift sketches: the auditor compares live query shape (nnz, L1 mass,
top-coordinate histogram, canonical row digests) against
:func:`sample_stats` of the tuning sample, so an SLO breach can be
triaged as "queries moved" vs "index degraded".

Ground truth caveat: the oracle scores through the index's forward
plane (dequantized when ``fwd_quant`` is on) — it measures what the
index *could* return, which is the right referent for attributing
pipeline losses.

Module-level imports stay numpy + stdlib + ``repro.obs.registry`` so
``repro.core`` / ``repro.tune`` can lazily call back into this module
without an import cycle.
"""
from __future__ import annotations

import collections
import math
import queue
import threading

import numpy as np

FUNNEL_STAGES = ("router", "selector", "scorer", "refine")
SLO_STATES = ("ok", "warn", "breach")


# --------------------------------------------------------------- recall

def recall_at_k(approx_ids, exact_ids) -> float:
    """|approx ∩ exact| / |exact| — the paper's "accuracy".

    Sentinels: ids ``< 0`` (the pipeline's -1 padding) are dropped from
    BOTH sides before the intersection; the index sentinel ``n_docs``
    never appears in merged output, so no upper filter is applied.
    Ties: not forgiven — a doc with a score equal to the k-th exact
    score but outside the oracle's (deterministic, stable-argsort)
    top-k counts as a miss. The denominator is ``max(|exact|, 1)`` so
    an empty oracle row yields 0.0 instead of dividing by zero.
    """
    a = {int(x) for x in np.asarray(approx_ids).reshape(-1) if x >= 0}
    e = {int(x) for x in np.asarray(exact_ids).reshape(-1) if x >= 0}
    return len(a & e) / max(len(e), 1)


def per_query_recall(ids, exact_ids) -> np.ndarray:
    """Row-wise :func:`recall_at_k` over [Q, k] batches -> f64 [Q]."""
    ids = np.asarray(ids)
    exact_ids = np.asarray(exact_ids)
    return np.array([recall_at_k(ids[q], exact_ids[q])
                     for q in range(ids.shape[0])], np.float64)


def wilson_interval(successes: int, trials: int,
                    z: float = 1.96) -> tuple[float, float]:
    """Wilson score interval for a binomial proportion.

    Returns the maximally uninformative ``(0.0, 1.0)`` at zero trials.
    Unlike the normal approximation it never leaves [0, 1] and stays
    honest at the p≈1 recalls this plane watches.
    """
    if trials <= 0:
        return 0.0, 1.0
    n = float(trials)
    p = successes / n
    z2 = z * z
    denom = 1.0 + z2 / n
    center = (p + z2 / (2.0 * n)) / denom
    half = z * math.sqrt(p * (1.0 - p) / n + z2 / (4.0 * n * n)) / denom
    return max(0.0, center - half), min(1.0, center + half)


# -------------------------------------------------------- drift sketch

def sample_stats(coords, vals, dim: int, *,
                 n_hist_buckets: int = 32) -> dict:
    """Shape statistics of a query sample — the drift reference.

    Returns mean nnz / mean L1 mass, a normalized histogram of each
    query's heaviest coordinate over ``n_hist_buckets`` equal
    coordinate ranges, and the set of canonical per-row digests
    (:func:`repro.tune.policy.row_digests`) so served queries can be
    tested for literal membership in the tuning sample.
    """
    c = np.asarray(coords)
    v = np.asarray(vals, np.float32)
    live = v > 0
    nnz = live.sum(axis=1)
    l1 = np.where(live, v, 0.0).sum(axis=1)
    top = np.take_along_axis(c, np.argmax(v, axis=1)[:, None],
                             axis=1)[:, 0]
    buckets = np.clip(top.astype(np.int64) * n_hist_buckets // max(dim, 1),
                      0, n_hist_buckets - 1)
    hist = np.bincount(buckets, minlength=n_hist_buckets).astype(np.float64)
    hist /= max(hist.sum(), 1.0)
    from repro.tune.policy import row_digests
    return {"n": int(c.shape[0]), "dim": int(dim),
            "n_hist_buckets": int(n_hist_buckets),
            "mean_nnz": float(nnz.mean()) if nnz.size else 0.0,
            "mean_l1": float(l1.mean()) if l1.size else 0.0,
            "topcoord_hist": hist,
            "digests": frozenset(row_digests(c, v))}


# ------------------------------------------------------------- funnel

def attribute_misses(missing_ids, *, cand_row, lists_row, router_r_row,
                     q_coords, q_vals, doc_map, n_blocks: int,
                     n_docs: int, knn_ids=None,
                     merge_row=None) -> dict[int, str]:
    """Attribute each missed oracle doc to exactly one dropping stage.

    Inputs are ONE query's audit captures: the scorer candidate row
    (``cand``, sentinel-padded — exactly the set of exactly-scored
    docs, because the scorer masks docs of unselected blocks to the
    sentinel before dedupe), the probed coordinate row (``lists``),
    the flat router score row (``router_r``, ``-inf`` = dead or
    pruned, laid out ``slot * n_blocks + block``), and — when the
    params refine — the pre-refine merged ids plus the index's kNN
    rows (trimmed to the served ``graph_degree``). ``doc_map`` is
    :func:`repro.core.build.doc_block_map`'s CSR doc -> (list, block)
    membership.

    Precedence (first match wins): scorer > refine > selector >
    router. Multi-round refinement uses the round-0 frontier — later
    rounds expand from docs already attributed by earlier checks.
    Total function: ``len(result) == len(missing_ids)`` always.
    """
    cand = np.asarray(cand_row).reshape(-1)
    cand_set = {int(x) for x in cand if 0 <= x < n_docs}
    frontier: set[int] = set()
    if knn_ids is not None and merge_row is not None:
        m = np.asarray(merge_row).reshape(-1)
        m = m[(m >= 0) & (m < n_docs)]
        if m.size:
            nbrs = np.asarray(knn_ids)[m].reshape(-1)
            frontier = {int(x) for x in nbrs if 0 <= x < n_docs}
    qpos = {int(c) for c, v in zip(np.asarray(q_coords).reshape(-1),
                                   np.asarray(q_vals).reshape(-1))
            if v > 0}
    slots_of: dict[int, list[int]] = {}
    for s, coord in enumerate(np.asarray(lists_row).reshape(-1)):
        coord = int(coord)
        if coord in qpos:           # skip padded probe slots (coord 0)
            slots_of.setdefault(coord, []).append(s)
    r_row = np.asarray(router_r_row, np.float64).reshape(-1)
    indptr, mem_lists, mem_blocks = doc_map
    out: dict[int, str] = {}
    for d in missing_ids:
        d = int(d)
        if d in cand_set:
            out[d] = "scorer"
            continue
        if d in frontier:
            out[d] = "refine"
            continue
        routed = False
        for j in range(int(indptr[d]), int(indptr[d + 1])):
            slots = slots_of.get(int(mem_lists[j]))
            if not slots:
                continue
            b = int(mem_blocks[j])
            if any(np.isfinite(r_row[s * n_blocks + b]) for s in slots):
                routed = True
                break
        out[d] = "selector" if routed else "router"
    return out


# ------------------------------------------------------------ auditor

class _OracleView:
    """Host-side numpy view of the index's forward plane + structural
    maps, built once (lazily) on the audit worker thread."""

    def __init__(self, index):
        q = np.asarray(index.fwd.vals)
        if index.fwd_scale is not None:
            scale = np.asarray(index.fwd_scale, np.float64)
            zero = np.asarray(index.fwd_zero, np.float64)
            vals = np.where(q > 0,
                            (q.astype(np.float64) - 1.0) * scale[:, None]
                            + zero[:, None], 0.0)
        else:
            vals = q.astype(np.float64)
        self.fwd_coords = np.asarray(index.fwd.coords).astype(np.int64)
        self.fwd_vals = vals
        self.dim = index.dim
        self.n_docs = index.n_docs
        self.n_blocks = index.config.n_blocks
        from repro.core.build import doc_block_map
        self.doc_map = doc_block_map(index)
        self.knn = None if index.knn_ids is None \
            else np.asarray(index.knn_ids)


class _AuditItem:
    __slots__ = ("coords", "vals", "ids", "captures")

    def __init__(self, coords, vals, ids, captures):
        self.coords = coords
        self.vals = vals
        self.ids = ids
        self.captures = captures


_CAPTURE_KEYS = ("cand", "lists", "router_r", "merge_ids")


class ShadowAuditor:
    """Shadow-oracle live-recall auditor for one serving operating
    point.

    The serving hot path calls :meth:`plan` once per launch (a counter
    bump) and, for each selected row, :meth:`feed` (row copies +
    ``put_nowait``; a full queue sheds the sample and increments
    ``seismic_audit_dropped_total`` — auditing never backpressures
    traffic). A daemon worker thread recomputes exact top-k per audited
    request, updates the sliding recall window, attributes misses
    through the funnel when stage captures rode along, and folds the
    query's drift features in.

    ``target`` defaults to the attached ``TunedPolicy`` whose knobs
    match ``params`` (same resolution as the serving drift gauges);
    with no match the SLO machine reports ``ok`` forever. Pass
    ``target=`` explicitly to audit a deliberately mistuned point.

    Metrics (on ``registry``): ``seismic_audits_total``,
    ``seismic_audit_dropped_total``, ``seismic_audit_errors_total``,
    ``seismic_recall_loss_total{stage}``, ``seismic_live_recall{k}``
    (+ ``_wilson_lo`` / ``_wilson_hi``), ``seismic_recall_slo_state``
    (0=ok 1=warn 2=breach), ``seismic_recall_slo_target``, and — when
    a ``reference`` from :func:`sample_stats` is given —
    ``seismic_query_drift_nnz`` / ``_l1`` (live/reference mean ratio),
    ``seismic_query_drift_topcoord_tv`` (total variation distance),
    ``seismic_query_drift_in_sample`` (fraction of windowed queries
    literally in the tuning sample). One auditor per registry: the
    gauge callbacks are last-writer-wins.
    """

    def __init__(self, index, params, registry, *,
                 audit_sample_every: int = 64, queue_bound: int = 128,
                 window: int = 512, target: float | None = None,
                 reference: dict | None = None, z: float = 1.96):
        self.index = index
        self.params = params
        self.registry = registry
        self.audit_sample_every = int(audit_sample_every)
        self.z = float(z)
        self.reference = reference
        if target is None:
            from repro.tune.policy import KNOB_FIELDS
            match = next(
                (t for t in (getattr(index, "tuned", ()) or ())
                 if all(getattr(t, f) == getattr(params, f)
                        for f in KNOB_FIELDS)), None)
            target = match.target if match is not None else None
        self.target = target
        self._q: queue.Queue = queue.Queue(maxsize=queue_bound)
        self._lock = threading.Lock()
        self._served = 0
        self._win: collections.deque = collections.deque(maxlen=window)
        self._loss = {s: 0 for s in FUNNEL_STAGES}
        self._funnel_misses = 0
        self._view: _OracleView | None = None
        self._thread: threading.Thread | None = None
        self._register_metrics()

    # -------------------------------------------------------- metrics

    def _register_metrics(self) -> None:
        reg = self.registry
        self._c_audits = reg.counter(
            "seismic_audits_total",
            "Shadow-oracle audits completed").labels()
        self._c_dropped = reg.counter(
            "seismic_audit_dropped_total",
            "Audit samples shed because the audit queue was full"
            ).labels()
        self._c_errors = reg.counter(
            "seismic_audit_errors_total",
            "Audits aborted by an exception on the worker").labels()
        self._c_loss = reg.counter(
            "seismic_recall_loss_total",
            "Missed oracle docs attributed to the stage that dropped "
            "them", ("stage",))
        for s in FUNNEL_STAGES:        # pre-create: funnel rows scrape as 0
            self._c_loss.labels(s)
        k = str(self.params.k)
        reg.gauge("seismic_live_recall",
                  "Windowed live recall@k from shadow audits",
                  ("k",)).labels(k) \
            .set_fn(lambda: self.window_stats()["live_recall"])
        reg.gauge("seismic_live_recall_wilson_lo",
                  "Wilson lower bound of the windowed live recall",
                  ("k",)).labels(k) \
            .set_fn(lambda: self.window_stats()["wilson_lo"])
        reg.gauge("seismic_live_recall_wilson_hi",
                  "Wilson upper bound of the windowed live recall",
                  ("k",)).labels(k) \
            .set_fn(lambda: self.window_stats()["wilson_hi"])
        reg.gauge("seismic_recall_slo_state",
                  "Recall SLO state: 0=ok 1=warn 2=breach").labels() \
            .set_fn(lambda: float(SLO_STATES.index(self.slo_state)))
        reg.gauge("seismic_recall_slo_target",
                  "Recall target the SLO machine compares against "
                  "(0 = no target attached)").labels() \
            .set(self.target if self.target is not None else 0.0)
        if self.reference is not None:
            reg.gauge("seismic_query_drift_nnz",
                      "Windowed mean query nnz over the tuning sample's"
                      ).labels().set_fn(lambda: self.drift()["nnz_ratio"])
            reg.gauge("seismic_query_drift_l1",
                      "Windowed mean query L1 mass over the tuning "
                      "sample's").labels() \
                .set_fn(lambda: self.drift()["l1_ratio"])
            reg.gauge("seismic_query_drift_topcoord_tv",
                      "Total variation distance between live and "
                      "tuning top-coordinate histograms").labels() \
                .set_fn(lambda: self.drift()["topcoord_tv"])
            reg.gauge("seismic_query_drift_in_sample",
                      "Fraction of windowed queries literally in the "
                      "tuning sample").labels() \
                .set_fn(lambda: self.drift()["in_sample"])

    # ------------------------------------------------------ lifecycle

    def start(self) -> "ShadowAuditor":
        if self._thread is not None:
            raise RuntimeError("auditor already started")
        self._thread = threading.Thread(target=self._worker,
                                        name="seismic-auditor",
                                        daemon=True)
        self._thread.start()
        return self

    def close(self) -> None:
        if self._thread is None:
            return
        self._q.put(None)               # blocking: the sentinel must land
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "ShadowAuditor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def drain(self) -> None:
        """Block until every queued audit has been processed (the
        worker must be running)."""
        self._q.join()

    # ------------------------------------------------------- hot path

    def plan(self, n: int) -> tuple[int, ...]:
        """Which of the next ``n`` served requests to audit — row
        offsets into the launch. One counter bump under the lock;
        cadence is global across every thread that dispatches."""
        e = self.audit_sample_every
        if e <= 0 or n <= 0:
            return ()
        with self._lock:
            start = self._served
            self._served += n
        return tuple(range((-start) % e, n, e))

    def feed(self, coords, vals, ids, *, captures=None,
             row: int = 0) -> None:
        """Enqueue one served request for audit (row copies only; the
        oracle runs on the worker). ``captures`` is the staged
        pipeline's probe dict for the whole launch; ``row`` selects
        this request's rows. Sheds (and counts) when the queue is
        full."""
        item = self._make_item(coords, vals, ids, captures, row)
        try:
            self._q.put_nowait(item)
        except queue.Full:
            self._c_dropped.inc()

    def audit_once(self, coords, vals, ids, *, captures=None,
                   row: int = 0) -> None:
        """Synchronous single-request audit (tests, overhead
        measurement) — same computation as the worker path."""
        self._audit(self._make_item(coords, vals, ids, captures, row))

    def _make_item(self, coords, vals, ids, captures, row) -> _AuditItem:
        caps = None
        if captures is not None:
            caps = {}
            for key in _CAPTURE_KEYS:
                a = captures.get(key)
                if a is None:
                    caps = None
                    break
                caps[key] = np.asarray(a)[row].copy()
        return _AuditItem(np.asarray(coords, np.int32).copy(),
                          np.asarray(vals, np.float32).copy(),
                          np.asarray(ids, np.int64).copy(), caps)

    # --------------------------------------------------------- worker

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                self._audit(item)
            except Exception:   # noqa: BLE001 — auditing must not kill serving
                self._c_errors.inc()
            finally:
                self._q.task_done()

    def _audit(self, item: _AuditItem) -> None:
        from repro.core.oracle import exact_topk
        if self._view is None:
            self._view = _OracleView(self.index)
        view = self._view
        p = self.params
        _, eids = exact_topk(view.fwd_coords, view.fwd_vals, view.dim,
                             item.coords, item.vals, p.k)
        exact = {int(x) for x in eids}
        approx = {int(x) for x in item.ids if x >= 0}
        hits = len(approx & exact)
        trials = len(exact)
        missing = sorted(exact - approx)
        attributed: dict[int, str] = {}
        if item.captures is not None and missing:
            refine_on = (p.refine_rounds > 0 and p.graph_degree > 0
                         and view.knn is not None)
            attributed = attribute_misses(
                missing, cand_row=item.captures["cand"],
                lists_row=item.captures["lists"],
                router_r_row=item.captures["router_r"],
                q_coords=item.coords, q_vals=item.vals,
                doc_map=view.doc_map, n_blocks=view.n_blocks,
                n_docs=view.n_docs,
                knn_ids=view.knn[:, :p.graph_degree]
                if refine_on else None,
                merge_row=item.captures["merge_ids"]
                if refine_on else None)
        nnz, l1, bucket, in_ref = self._features(item)
        with self._lock:
            self._win.append((hits, trials, nnz, l1, bucket, in_ref))
            for stage in attributed.values():
                self._loss[stage] += 1
            if item.captures is not None:
                self._funnel_misses += len(missing)
        for stage in attributed.values():
            self._c_loss.labels(stage).inc()
        self._c_audits.inc()

    def _features(self, item: _AuditItem):
        live = item.vals > 0
        nnz = int(live.sum())
        l1 = float(item.vals[live].sum())
        ref = self.reference
        nb = ref["n_hist_buckets"] if ref is not None else 32
        dim = ref["dim"] if ref is not None else self.index.dim
        top = int(item.coords[int(np.argmax(item.vals))])
        bucket = min(max(top * nb // max(dim, 1), 0), nb - 1)
        in_ref = False
        if ref is not None and ref.get("digests"):
            from repro.tune.policy import row_digest
            in_ref = row_digest(item.coords, item.vals) in ref["digests"]
        return nnz, l1, bucket, in_ref

    # -------------------------------------------------------- reading

    def window_stats(self) -> dict:
        with self._lock:
            rows = list(self._win)
        hits = sum(r[0] for r in rows)
        trials = sum(r[1] for r in rows)
        lo, hi = wilson_interval(hits, trials, self.z)
        return {"audited": len(rows), "hits": hits, "trials": trials,
                "live_recall": hits / trials if trials else 0.0,
                "wilson_lo": lo, "wilson_hi": hi}

    @property
    def slo_state(self) -> str:
        st = self.window_stats()
        if self.target is None or st["trials"] == 0:
            return "ok"
        if st["wilson_hi"] < self.target:
            return "breach"
        if st["live_recall"] < self.target:
            return "warn"
        return "ok"

    def drift(self) -> dict:
        """Live-vs-reference drift sketch over the current window."""
        ref = self.reference
        with self._lock:
            rows = list(self._win)
        if ref is None or not rows:
            return {"nnz_ratio": 1.0, "l1_ratio": 1.0,
                    "topcoord_tv": 0.0, "in_sample": 0.0}
        n = len(rows)
        nnz = sum(r[2] for r in rows) / n
        l1 = sum(r[3] for r in rows) / n
        nb = ref["n_hist_buckets"]
        hist = np.bincount([r[4] for r in rows],
                           minlength=nb).astype(np.float64) / n
        tv = 0.5 * float(np.abs(hist - ref["topcoord_hist"]).sum())
        return {"nnz_ratio": nnz / max(ref["mean_nnz"], 1e-12),
                "l1_ratio": l1 / max(ref["mean_l1"], 1e-12),
                "topcoord_tv": tv,
                "in_sample": sum(r[5] for r in rows) / n}

    def snapshot(self) -> dict:
        """JSON-serializable quality snapshot — the ``/quality.json``
        payload and the benchmark artifact record."""
        with self._lock:
            loss = dict(self._loss)
            funnel_misses = self._funnel_misses
            served = self._served
        return {"k": self.params.k,
                "target": self.target,
                "slo_state": self.slo_state,
                "served": served,
                "audit_sample_every": self.audit_sample_every,
                "audits": int(self._c_audits.value),
                "dropped": int(self._c_dropped.value),
                "errors": int(self._c_errors.value),
                "window": self.window_stats(),
                "loss": loss,
                "misses": funnel_misses,
                "drift": self.drift() if self.reference is not None
                else None}


__all__ = ["recall_at_k", "per_query_recall", "wilson_interval",
           "sample_stats", "attribute_misses", "ShadowAuditor",
           "FUNNEL_STAGES", "SLO_STATES"]
