"""int8 error-feedback gradient compression for the DP all-reduce.

Classic EF-SGD/1-bit-Adam-style scheme adapted to int8:

  g_hat   = g + e                      (apply carried error)
  q       = int8_quantize(g_hat)       (per-tensor symmetric scale)
  g_sync  = psum(dequant(q)) / world   (8x fewer bytes on the wire*)
  e'      = g_hat - dequant(q)         (error feedback)

(*) On real hardware the psum must run on the int8 payload + one f32
scale per tensor (psum of int8 with per-shard scales -> all_gather of
scales). We implement exactly that: all_gather the per-shard scales,
all_gather the int8 payloads... no — that loses the 8x. The production
formulation used here: quantize with a GLOBALLY agreed scale (psum-max
of local absmax, 4 bytes), then psum the int8 tensors widened to int32
(the wire format a TPU reduction uses for sub-word types). The HLO
then carries 1/4 the f32 bytes; the error-feedback state keeps the
update unbiased over time.

Used by ``make_dp_train_step`` — an explicit shard_map DP training
step: per-device grads -> compressed psum -> identical AdamW update on
every shard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, ef_state, axes):
    """Error-feedback int8 psum over mesh ``axes`` (inside shard_map).
    Returns (synced_grads, new_ef_state)."""
    world = 1
    for ax in axes:
        world *= jax.lax.axis_size(ax)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        absmax = jnp.max(jnp.abs(g))
        absmax = jax.lax.pmax(absmax, axes)          # shared scale (4B)
        scale = jnp.maximum(absmax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        err = g - deq
        synced = jax.lax.psum(q.astype(jnp.int32), axes).astype(jnp.float32)
        return synced * scale / world, err

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    synced = treedef.unflatten([o[0] for o in out])
    new_e = treedef.unflatten([o[1] for o in out])
    return synced, new_e
