from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state
from repro.train.train_step import make_train_step

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "make_train_step"]
