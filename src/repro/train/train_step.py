"""Generic train step: value_and_grad -> AdamW, with optional
microbatched gradient accumulation (the accumulation scan is also the
compute/collective overlap lever: per-microbatch DP reductions overlap
the next microbatch's compute under XLA async collectives)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.train.optimizer import AdamWConfig, adamw_update


def make_train_step(loss_fn, opt_cfg: AdamWConfig, *, microbatches: int = 1,
                    donate: bool = True):
    """loss_fn(params, batch) -> scalar. Returns jit-able
    train_step(params, opt_state, batch) -> (params, opt_state, metrics).
    """

    def step(params, opt_state, batch):
        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_fn(carry, micro):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, micro)
                grad_acc = jax.tree.map(jnp.add, grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_fn, (jnp.zeros((), jnp.float32), zeros), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, metrics = adamw_update(grads, opt_state, params,
                                                  opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step
