"""In-house AdamW (no optax in this environment) + LR schedules.

Optimizer state is a pytree mirroring the params (m, v in fp32), so it
shards with the same rules as the params; ZeRO-style sharding over the
data axes is applied by the launcher via ``zero_shard_spec``
(distributed/param_sharding.py).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac * lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> dict:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return dict(m=jax.tree.map(f32, params), v=jax.tree.map(f32, params),
                step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    """One AdamW step with global-norm clipping. Returns
    (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = dict(grad_norm=gnorm, lr=lr)
    return new_p, dict(m=new_m, v=new_v, step=step), metrics
