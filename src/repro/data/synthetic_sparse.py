"""Synthetic learned-sparse-embedding collections.

MS MARCO / NQ embeddings are not available offline, so benchmarks run
on collections synthesized to match the SPLADE statistics the paper
reports (§7.1) and the concentration-of-importance property (§4):

  * vocabulary ~30k with Zipf-like coordinate popularity,
  * docs ~119 nnz, queries ~43 nnz (scaled down proportionally for CPU
    test sizes),
  * log-normal weights -> a heavy-tailed per-vector value profile, so
    the top ~10 query entries / ~50 doc entries carry ~0.75 of the L1
    mass (validated by benchmarks/fig1_concentration.py),
  * a shared topic structure so queries have true near neighbors and
    recall curves are non-trivial.

Generation is vectorized numpy (Gumbel top-k for sampling coords
without replacement per row).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.ops import PaddedSparse


@dataclasses.dataclass(frozen=True)
class SyntheticSparseConfig:
    dim: int = 4096
    n_docs: int = 8192
    n_queries: int = 256
    doc_nnz: int = 96
    query_nnz: int = 32
    n_topics: int = 64
    topic_coords: int = 384       # candidate coords per topic
    zipf_a: float = 1.05
    value_sigma: float = 1.0      # log-normal sigma -> concentration
    doc_topic_mix: int = 2        # topics mixed per doc
    seed: int = 0


def _sample_rows(rng, logits: np.ndarray, nnz: int):
    """Gumbel top-k: one draw of ``nnz`` distinct indices per row,
    with probability proportional to exp(logits)."""
    g = rng.gumbel(size=logits.shape)
    return np.argsort(-(logits + g), axis=-1)[:, :nnz]


def make_collection(cfg: SyntheticSparseConfig = SyntheticSparseConfig()):
    """Returns (docs: PaddedSparse-like numpy arrays, queries, meta)."""
    rng = np.random.default_rng(cfg.seed)
    d = cfg.dim

    # Zipf-ish popularity over a shuffled vocabulary
    ranks = rng.permutation(d) + 1
    pop = 1.0 / ranks ** cfg.zipf_a
    log_pop = np.log(pop)

    # topics: coordinate subsets with log-normal affinities
    topic_coords = _sample_rows(
        rng, np.broadcast_to(log_pop, (cfg.n_topics, d)).copy(),
        cfg.topic_coords)                                   # [T, m]
    topic_w = rng.lognormal(0.0, cfg.value_sigma,
                            size=topic_coords.shape)        # [T, m]

    def _draw(n_rows: int, nnz: int, primary_scale: float):
        t1 = rng.integers(0, cfg.n_topics, n_rows)
        t2 = rng.integers(0, cfg.n_topics, n_rows)
        # mix the affinity profiles of 1-2 topics in coord space
        logits = np.full((n_rows, d), -np.inf)
        rows = np.arange(n_rows)[:, None]
        np.maximum.at(logits, (rows, topic_coords[t1]),
                      np.log(topic_w[t1]) * primary_scale)
        if cfg.doc_topic_mix > 1:
            np.maximum.at(logits, (rows, topic_coords[t2]),
                          np.log(topic_w[t2]) * primary_scale * 0.5)
        logits = np.where(np.isfinite(logits), logits, -30.0)
        coords = _sample_rows(rng, logits, nnz)             # [n, nnz]
        base = np.exp(logits[rows, coords])
        vals = base * rng.lognormal(0.0, cfg.value_sigma * 0.5,
                                    size=coords.shape)
        vals = vals / np.maximum(vals.max(axis=-1, keepdims=True), 1e-9) * 3.0
        return coords.astype(np.int32), vals.astype(np.float32), t1

    doc_c, doc_v, doc_t = _draw(cfg.n_docs, cfg.doc_nnz, 1.0)
    q_c, q_v, q_t = _draw(cfg.n_queries, cfg.query_nnz, 1.3)

    docs = PaddedSparse(doc_c, doc_v, d)
    queries = PaddedSparse(q_c, q_v, d)
    meta = dict(doc_topics=doc_t, query_topics=q_t, config=cfg)
    return docs, queries, meta
