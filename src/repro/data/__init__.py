from repro.data.synthetic_sparse import SyntheticSparseConfig, make_collection

__all__ = ["SyntheticSparseConfig", "make_collection"]
