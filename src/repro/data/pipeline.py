"""Host-side data pipeline: synthetic generators + prefetching loader.

Production posture: generators run on the host (one process per pod in
a real deployment, sharded by ``(shard_id, n_shards)``), a background
thread keeps a bounded prefetch queue full, and the training loop only
ever blocks when it outruns the producers. The bounded queue is also
the straggler-mitigation mechanism on the input side — a slow shard
never back-pressures the collective path, it only drains its queue.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import numpy as np


class PrefetchLoader:
    """Wraps an iterator factory with a daemon producer thread and a
    bounded queue (depth = ``prefetch``)."""

    def __init__(self, make_iter: Callable[[], Iterator], prefetch: int = 4):
        self._queue: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()

        def produce():
            try:
                for item in make_iter():
                    if self._stop.is_set():
                        return
                    self._queue.put(item)
            finally:
                self._queue.put(None)

        self._thread = threading.Thread(target=produce, daemon=True)
        self._thread.start()

    def __iter__(self):
        while True:
            item = self._queue.get()
            if item is None:
                return
            yield item

    def close(self):
        self._stop.set()


def lm_token_stream(vocab: int, batch: int, seq_len: int, *, seed: int = 0,
                    shard_id: int = 0, n_shards: int = 1):
    """Synthetic LM batches with a learnable structure (orderly n-gram
    process, not uniform noise) so loss curves actually descend."""
    rng = np.random.default_rng(seed + 7919 * shard_id)
    trans = rng.integers(0, vocab, size=(256,))

    def gen():
        step = 0
        while True:
            start = rng.integers(0, vocab, (batch, 1))
            toks = [start]
            for _ in range(seq_len):
                prev = toks[-1]
                nxt = np.where(rng.random((batch, 1)) < 0.7,
                               trans[prev % 256], rng.integers(0, vocab, (batch, 1)))
                toks.append(nxt)
            seqs = np.concatenate(toks, axis=1)
            yield dict(tokens=seqs[:, :seq_len].astype(np.int32),
                       labels=seqs[:, 1:seq_len + 1].astype(np.int32))
            step += 1

    return gen


def recsys_log_stream(cfg, batch: int, *, seed: int = 0, shard_id: int = 0):
    """Synthetic click logs. Label correlates with a hidden linear
    structure over the ids so models have signal to fit."""
    rng = np.random.default_rng(seed + 104729 * shard_id)

    def gen():
        w_hidden = rng.standard_normal(64)
        while True:
            if cfg.interaction in ("fm-2way", "concat"):
                ids = np.stack([rng.integers(0, r, batch)
                                for r in cfg.table_rows], axis=1)
                dense = rng.standard_normal((batch, cfg.n_dense_feat))
                z = (ids.sum(axis=1) % 64)
                logit = w_hidden[z] + 0.5 * dense[:, 0]
                labels = (rng.random(batch) < 1 / (1 + np.exp(-logit)))
                yield dict(ids=ids.astype(np.int32),
                           dense=dense.astype(np.float32),
                           labels=labels.astype(np.float32))
            elif cfg.interaction == "self-attn-seq":
                seq = rng.integers(1, cfg.n_items, (batch, cfg.seq_len))
                pos = np.roll(seq, -1, axis=1)
                pos[:, -1] = rng.integers(1, cfg.n_items, batch)
                neg = rng.integers(1, cfg.n_items, (batch, cfg.seq_len))
                yield dict(seq=seq.astype(np.int32), pos=pos.astype(np.int32),
                           neg=neg.astype(np.int32))
            else:  # bst
                seq = rng.integers(1, cfg.n_items, (batch, cfg.seq_len))
                target = rng.integers(1, cfg.n_items, batch)
                labels = (target % 2 == seq[:, -1] % 2)
                yield dict(seq=seq.astype(np.int32),
                           target=target.astype(np.int32),
                           labels=labels.astype(np.float32))

    return gen


def random_graph(n_nodes: int, n_edges: int, d_feat: int, n_classes: int,
                 *, seed: int = 0):
    """Full-graph batch with community structure (labels recoverable
    from neighborhoods)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n_nodes)
    # homophilous edges: 70% same-community
    src = rng.integers(0, n_nodes, n_edges)
    same = rng.random(n_edges) < 0.7
    dst = np.where(same, _same_label_partner(rng, labels, src, n_classes),
                   rng.integers(0, n_nodes, n_edges))
    onehot = np.eye(n_classes)[labels]
    if d_feat >= n_classes:
        base = np.concatenate(
            [onehot, np.zeros((n_nodes, d_feat - n_classes))], axis=1)
    else:
        base = onehot[:, :d_feat]
    feats = base + 0.5 * rng.standard_normal((n_nodes, d_feat))
    # append sink node
    feats = np.concatenate([feats, np.zeros((1, d_feat))], axis=0)
    labels = np.concatenate([labels, [-1]])
    edges = np.stack([src, dst], axis=1)
    return dict(feats=feats.astype(np.float32),
                edges=edges.astype(np.int32),
                labels=labels.astype(np.int32))


def _same_label_partner(rng, labels, src, n_classes):
    order = np.argsort(labels[:-1] if labels[-1] == -1 else labels,
                       kind="stable")
    lbl_sorted = labels[order]
    out = np.empty_like(src)
    for c in range(n_classes):
        lo, hi = np.searchsorted(lbl_sorted, [c, c + 1])
        mask = labels[src] == c
        if hi > lo:
            out[mask] = order[rng.integers(lo, hi, mask.sum())]
        else:
            out[mask] = src[mask]
    return out
