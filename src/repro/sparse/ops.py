"""Padded-sparse vector substrate.

Learned sparse embeddings (SPLADE-family) are nonnegative vectors in
R^d with ~40-200 non-zeros out of d~30k. TPUs want fixed shapes, so the
canonical representation here is *padded CSR rows*:

    coords: int32 [N, nnz_max]   (padding entries point at coord 0)
    vals:   float [N, nnz_max]   (padding entries are exactly 0.0)

A padded entry contributes 0 to every inner product, so no masks are
needed on the scoring path; masks are recovered as ``vals > 0`` when
structure matters (counts, summaries).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PaddedSparse:
    """A batch of sparse vectors in padded CSR-row layout."""

    coords: jax.Array  # int32 [N, nnz_max]
    vals: jax.Array    # float [N, nnz_max], padding == 0.0
    dim: int = dataclasses.field(metadata=dict(static=True), default=0)

    @property
    def n(self) -> int:
        return self.coords.shape[0]

    @property
    def nnz_max(self) -> int:
        return self.coords.shape[1]

    def nnz(self) -> jax.Array:
        return (self.vals != 0).sum(axis=-1)

    def astype(self, dtype) -> "PaddedSparse":
        return PaddedSparse(self.coords, self.vals.astype(dtype), self.dim)

    def __getitem__(self, idx) -> "PaddedSparse":
        return PaddedSparse(self.coords[idx], self.vals[idx], self.dim)


def densify(ps: PaddedSparse, dtype=jnp.float32) -> jax.Array:
    """[N, nnz] padded-sparse -> [N, d] dense. Padding adds 0 at coord 0."""
    n = ps.coords.shape[0]
    out = jnp.zeros((n, ps.dim), dtype=dtype)
    rows = jnp.arange(n)[:, None]
    return out.at[rows, ps.coords].add(ps.vals.astype(dtype))


def densify_one(coords: jax.Array, vals: jax.Array, dim: int,
                dtype=jnp.float32) -> jax.Array:
    """[nnz] sparse -> [d] dense."""
    return jnp.zeros((dim,), dtype=dtype).at[coords].add(vals.astype(dtype))


def sparsify(dense: jax.Array, nnz_max: int) -> PaddedSparse:
    """[N, d] dense -> padded-sparse keeping the nnz_max largest entries.

    Exact when each row has <= nnz_max non-zeros (padding keeps val 0).
    """
    vals, coords = jax.lax.top_k(dense, nnz_max)
    vals = jnp.where(vals > 0, vals, 0.0)
    coords = jnp.where(vals > 0, coords, 0)
    return PaddedSparse(coords.astype(jnp.int32), vals, dense.shape[-1])


def inner_product_padded(q_dense: jax.Array, coords: jax.Array,
                         vals: jax.Array) -> jax.Array:
    """<q, x> for dense q [d] against padded-sparse rows [N, nnz] -> [N].

    The jnp reference for the ``gather_dot`` Pallas kernel.
    """
    return (q_dense[coords] * vals).sum(axis=-1)


@partial(jax.jit, static_argnames=("out_nnz",))
def alpha_mass_subvector(coords: jax.Array, vals: jax.Array, alpha: float,
                         out_nnz: int) -> tuple[jax.Array, jax.Array]:
    """Definition 3.1: keep the largest-|value| entries while their
    cumulative L1 mass stays within ``alpha * ||x||_1``; at least one
    entry is always kept. Output is padded to ``out_nnz`` entries.
    """
    order = jnp.argsort(-jnp.abs(vals))
    sv = vals[order]
    sc = coords[order]
    cum = jnp.cumsum(jnp.abs(sv))
    total = cum[-1]
    keep = cum <= alpha * total
    keep = keep.at[0].set(True)  # never emit an empty subvector
    sv = jnp.where(keep, sv, 0.0)[:out_nnz]
    sc = jnp.where(keep, sc, 0)[:out_nnz]
    pad = out_nnz - sv.shape[0]
    if pad > 0:
        sv = jnp.pad(sv, (0, pad))
        sc = jnp.pad(sc, (0, pad))
    return sc.astype(jnp.int32), sv


def top_cut(coords: jax.Array, vals: jax.Array, cut: int) -> tuple[jax.Array, jax.Array]:
    """The ``cut`` largest-value entries of one sparse vector (Alg. 2, L1)."""
    v, idx = jax.lax.top_k(vals, cut)
    c = jnp.take(coords, idx)
    c = jnp.where(v > 0, c, 0)
    v = jnp.where(v > 0, v, 0.0)
    return c.astype(jnp.int32), v


def l1_mass_fraction(vals: np.ndarray, top: int) -> np.ndarray:
    """Fraction of L1 mass captured by the ``top`` largest entries
    (numpy; used by the Fig. 1 concentration benchmark)."""
    v = np.sort(np.abs(vals), axis=-1)[..., ::-1]
    total = v.sum(axis=-1)
    total = np.where(total == 0, 1.0, total)
    return v[..., :top].sum(axis=-1) / total
