"""8-bit affine scalar quantization for summary vectors (paper §5.3).

The paper subtracts the minimum value m, splits the range into equal
sub-intervals, and stores the interval id; reconstruction is
``id * scale + m``. We quantize per summary (per block) so the
dequantization constants ride along with each block and fuse into the
routing inner product.

Deviation for padded layouts: level 0 is reserved for padding (exact
zero on reconstruction); real values occupy levels 1..255 over the
[vmin, vmax] range of the positive entries. This keeps the scoring
path mask-free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_LEVELS = 254.0  # real values map to 1..255 -> 254 intervals


def _affine_u8(vals: jax.Array, rounder) -> tuple[jax.Array, jax.Array,
                                                  jax.Array]:
    """Shared affine-u8 body; ``rounder`` maps exact levels to ints."""
    valid = vals > 0
    big = jnp.finfo(jnp.float32).max
    v32 = vals.astype(jnp.float32)
    vmin = jnp.min(jnp.where(valid, v32, big), axis=-1)
    vmin = jnp.where(vmin < big, vmin, 0.0)
    vmax = jnp.max(jnp.where(valid, v32, 0.0), axis=-1)
    scale = jnp.maximum(vmax - vmin, 1e-12) / _LEVELS
    q = rounder((v32 - vmin[..., None]) / scale[..., None]) + 1.0
    q = jnp.clip(q, 1, 255)
    q = jnp.where(valid, q, 0).astype(jnp.uint8)
    return q, scale, vmin


def quantize_u8(vals: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """vals [..., S] (padding == 0) -> (q uint8 [..., S], scale [...], zero [...]).

    Quantizes over the last axis; only positive entries define the
    range. q == 0 always means padding.
    """
    return _affine_u8(vals, jnp.round)


def quantize_u8_ceil(vals: jax.Array) -> tuple[jax.Array, jax.Array,
                                               jax.Array]:
    """Like :func:`quantize_u8` but rounds levels UP, so every
    reconstructed value >= its input (never below).

    Used for the superblock summary tier: the coarse summary must
    upper-bound every child block summary coordinate-wise, and
    round-to-nearest would break the bound by up to scale/2. Level
    arithmetic: q = ceil((v - vmin)/scale) + 1 <= 255 because
    (vmax - vmin)/scale = 254, so no lossy clipping from above.
    """
    return _affine_u8(vals, jnp.ceil)


def dequantize_u8(q: jax.Array, scale: jax.Array, zero: jax.Array,
                  dtype=jnp.float32) -> jax.Array:
    """Reconstruct values; level 0 (padding) maps to exactly 0."""
    v = (q.astype(dtype) - 1.0) * scale[..., None].astype(dtype) \
        + zero[..., None].astype(dtype)
    return jnp.where(q > 0, v, 0.0).astype(dtype)
