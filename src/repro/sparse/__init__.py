from repro.sparse.ops import (
    PaddedSparse,
    densify,
    sparsify,
    alpha_mass_subvector,
    top_cut,
    inner_product_padded,
    l1_mass_fraction,
)
from repro.sparse.quant import quantize_u8, dequantize_u8

__all__ = [
    "PaddedSparse",
    "densify",
    "sparsify",
    "alpha_mass_subvector",
    "top_cut",
    "inner_product_padded",
    "l1_mass_fraction",
    "quantize_u8",
    "dequantize_u8",
]
