from repro.kernels.summary_dot.ops import summary_dot, summary_dot_batch

__all__ = ["summary_dot", "summary_dot_batch"]
