from repro.kernels.summary_dot.ops import summary_dot

__all__ = ["summary_dot"]
