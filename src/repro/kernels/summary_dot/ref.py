"""Pure-jnp oracles for the summary_dot kernels."""
import jax
import jax.numpy as jnp

from repro.sparse.quant import dequantize_u8


def summary_dot_ref(q_dense: jax.Array, sum_coords: jax.Array,
                    sum_q: jax.Array, sum_scale: jax.Array,
                    sum_zero: jax.Array) -> jax.Array:
    """Single query: r[l, b] = <q, dequant(summary[l, b])>."""
    sv = dequantize_u8(sum_q, sum_scale, sum_zero, dtype=q_dense.dtype)
    return (jnp.take(q_dense, sum_coords, axis=0) * sv).sum(axis=-1)


def summary_dot_batch_ref(q_dense: jax.Array, sum_coords: jax.Array,
                          sum_q: jax.Array, sum_scale: jax.Array,
                          sum_zero: jax.Array) -> jax.Array:
    """Query batch: r[q, l] = <q_dense[q], dequant(summary[q, l])>."""
    qn, l, s = sum_coords.shape
    sv = dequantize_u8(sum_q, sum_scale, sum_zero, dtype=q_dense.dtype)
    gathered = jnp.take_along_axis(
        q_dense, sum_coords.reshape(qn, l * s), axis=1).reshape(qn, l, s)
    return (gathered * sv).sum(axis=-1)
