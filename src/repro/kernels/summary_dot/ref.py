"""Pure-jnp oracle for the summary_dot kernel."""
import jax
import jax.numpy as jnp

from repro.sparse.quant import dequantize_u8


def summary_dot_ref(q_dense: jax.Array, sum_coords: jax.Array,
                    sum_q: jax.Array, sum_scale: jax.Array,
                    sum_zero: jax.Array) -> jax.Array:
    """r[l, b] = <q, dequant(summary[l, b])>."""
    sv = dequantize_u8(sum_q, sum_scale, sum_zero, dtype=q_dense.dtype)
    return (jnp.take(q_dense, sum_coords, axis=0) * sv).sum(axis=-1)
