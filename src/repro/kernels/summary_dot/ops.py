"""jit'd public wrapper for summary_dot."""
from __future__ import annotations

import jax

from repro.kernels.summary_dot.ref import summary_dot_ref
from repro.kernels.summary_dot.summary_dot import summary_dot_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def summary_dot(q_dense: jax.Array, sum_coords: jax.Array, sum_q: jax.Array,
                sum_scale: jax.Array, sum_zero: jax.Array) -> jax.Array:
    """Quantized routing scores [cut, nb]; dequant fused in-kernel."""
    return summary_dot_pallas(q_dense, sum_coords, sum_q, sum_scale,
                              sum_zero, interpret=not _on_tpu())


__all__ = ["summary_dot", "summary_dot_ref"]
