"""Public wrappers for summary_dot: pad to tile multiples, pick
interpret mode off-TPU.

``summary_dot_batch``  [Q, L, S] summaries -> [Q, L] routing scores
                       (one kernel launch for the whole query batch)
``summary_dot``        single-query [cut, nb, S] compatibility API
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.summary_dot.ref import (summary_dot_batch_ref,
                                           summary_dot_ref)
from repro.kernels.summary_dot.summary_dot import (summary_dot_batch_pallas,
                                                   summary_dot_pallas)

_TILE_Q = 8     # f32 sublane width
_TILE_L = 128   # lane width


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_batch_call(q_dense, sum_coords, sum_q, sum_scale, sum_zero, *,
                    interpret):
    """Pad Q to _TILE_Q and L to _TILE_L, launch, slice back."""
    qn, l, s = sum_coords.shape
    pq = (-qn) % _TILE_Q
    pls = (-l) % _TILE_L
    if pq or pls:
        q_dense = jnp.pad(q_dense, ((0, pq), (0, 0)))
        sum_coords = jnp.pad(sum_coords, ((0, pq), (0, pls), (0, 0)))
        sum_q = jnp.pad(sum_q, ((0, pq), (0, pls), (0, 0)))
        sum_scale = jnp.pad(sum_scale, ((0, pq), (0, pls)))
        sum_zero = jnp.pad(sum_zero, ((0, pq), (0, pls)))
    out = summary_dot_batch_pallas(q_dense, sum_coords, sum_q, sum_scale,
                                   sum_zero, tile_q=_TILE_Q, tile_l=_TILE_L,
                                   interpret=interpret)
    return out[:qn, :l]


def summary_dot_batch(q_dense: jax.Array, sum_coords: jax.Array,
                      sum_q: jax.Array, sum_scale: jax.Array,
                      sum_zero: jax.Array) -> jax.Array:
    """Batched quantized routing scores [Q, L]; dequant fused in-kernel."""
    return _pad_batch_call(q_dense, sum_coords, sum_q, sum_scale, sum_zero,
                           interpret=not _on_tpu())


def summary_dot(q_dense: jax.Array, sum_coords: jax.Array, sum_q: jax.Array,
                sum_scale: jax.Array, sum_zero: jax.Array) -> jax.Array:
    """Single-query routing scores [cut, nb] (pre-batch compatibility)."""
    return summary_dot_pallas(q_dense, sum_coords, sum_q, sum_scale,
                              sum_zero, interpret=not _on_tpu())


__all__ = ["summary_dot", "summary_dot_batch", "summary_dot_ref",
           "summary_dot_batch_ref"]
