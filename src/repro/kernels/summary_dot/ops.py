"""Public wrappers for summary_dot: pad to tile multiples, pick tiles
from the shared VMEM model, resolve interpret mode centrally.

``summary_dot_batch``  [Q, L, S] summaries -> [Q, L] routing scores
                       (one kernel launch for the whole query batch)
``summary_dot``        single-query [cut, nb, S] compatibility API

Tiling is chosen per launch shape by :mod:`repro.kernels.tiling`
(lane/sublane-aligned, VMEM-budgeted, never wider than the padded
problem); pass explicit ``tile_q`` / ``tile_l`` to pin a tiling (the
microbench sweep does). Results are tile-invariant — every output
element is an independent sum — which the parity tests pin.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.runtime import default_interpret
from repro.kernels.summary_dot.ref import (summary_dot_batch_ref,
                                           summary_dot_ref)
from repro.kernels.summary_dot.summary_dot import (summary_dot_batch_pallas,
                                                   summary_dot_pallas)
from repro.kernels.tiling import choose_tiles, summary_row_bytes

_TILE_Q = 8     # minimum aligned tile (f32 sublane) — chooser floor
_TILE_L = 128   # minimum aligned tile (lane width) — chooser floor


def _pad_batch_call(q_dense, sum_coords, sum_q, sum_scale, sum_zero, *,
                    tile_q=None, tile_l=None, interpret=None):
    """Choose tiles, pad Q/L up to them, launch, slice back."""
    interpret = default_interpret(interpret)
    qn, l, s = sum_coords.shape
    if tile_q is None or tile_l is None:
        ch = choose_tiles(qn, l, row_bytes=summary_row_bytes(s),
                          q_row_bytes=4 * q_dense.shape[1])
        tile_q = tile_q if tile_q is not None else ch.tile_q
        tile_l = tile_l if tile_l is not None else ch.tile_n
    pq = (-qn) % tile_q
    pls = (-l) % tile_l
    if pq or pls:
        q_dense = jnp.pad(q_dense, ((0, pq), (0, 0)))
        sum_coords = jnp.pad(sum_coords, ((0, pq), (0, pls), (0, 0)))
        sum_q = jnp.pad(sum_q, ((0, pq), (0, pls), (0, 0)))
        sum_scale = jnp.pad(sum_scale, ((0, pq), (0, pls)))
        sum_zero = jnp.pad(sum_zero, ((0, pq), (0, pls)))
    out = summary_dot_batch_pallas(q_dense, sum_coords, sum_q, sum_scale,
                                   sum_zero, tile_q=tile_q, tile_l=tile_l,
                                   interpret=interpret)
    return out[:qn, :l]


def summary_dot_batch(q_dense: jax.Array, sum_coords: jax.Array,
                      sum_q: jax.Array, sum_scale: jax.Array,
                      sum_zero: jax.Array, *, tile_q: int | None = None,
                      tile_l: int | None = None,
                      interpret: bool | None = None) -> jax.Array:
    """Batched quantized routing scores [Q, L]; dequant fused in-kernel."""
    return _pad_batch_call(q_dense, sum_coords, sum_q, sum_scale, sum_zero,
                           tile_q=tile_q, tile_l=tile_l, interpret=interpret)


def summary_dot(q_dense: jax.Array, sum_coords: jax.Array, sum_q: jax.Array,
                sum_scale: jax.Array, sum_zero: jax.Array, *,
                interpret: bool | None = None) -> jax.Array:
    """Single-query routing scores [cut, nb] (pre-batch compatibility)."""
    return summary_dot_pallas(q_dense, sum_coords, sum_q, sum_scale,
                              sum_zero, interpret=interpret)


__all__ = ["summary_dot", "summary_dot_batch", "summary_dot_ref",
           "summary_dot_batch_ref"]
