"""Pallas TPU kernel: quantized summary routing (Seismic phase R).

Computes, for every (probed list l, block b):

    r[l, b] = sum_s q_dense[sum_coords[l,b,s]] * dequant(sum_q[l,b,s])

with the u8 affine dequantization ((q-1)*scale + zero, level 0 = pad)
FUSED into the multiply — the paper's "matrix multiplication against
all quantized summaries of an inverted list" (§7.1), done without ever
materializing the dequantized summaries in HBM.

Tiling:
  grid = (cut,)  — one grid step per probed list
  blocks: coords/q [1, nb, S] tiles, scale/zero [1, nb], q resident [d]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _summary_dot_kernel(q_ref, coords_ref, sq_ref, scale_ref, zero_ref,
                        out_ref):
    q = q_ref[...]                                  # [d]
    coords = coords_ref[0]                          # [nb, S]
    sq = sq_ref[0].astype(q.dtype)                  # [nb, S] u8 -> f
    scale = scale_ref[0].astype(q.dtype)            # [nb]
    zero = zero_ref[0].astype(q.dtype)              # [nb]
    gathered = jnp.take(q, coords, axis=0)          # [nb, S]
    deq = (sq - 1.0) * scale[:, None] + zero[:, None]
    deq = jnp.where(sq > 0, deq, 0.0)               # level 0 == padding
    out_ref[0] = (gathered * deq).sum(axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def summary_dot_pallas(q_dense: jax.Array, sum_coords: jax.Array,
                       sum_q: jax.Array, sum_scale: jax.Array,
                       sum_zero: jax.Array, *,
                       interpret: bool = True) -> jax.Array:
    """r [cut, nb] from quantized summaries [cut, nb, S]."""
    cut, nb, s = sum_coords.shape
    d = q_dense.shape[0]
    return pl.pallas_call(
        _summary_dot_kernel,
        grid=(cut,),
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1, nb, s), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, nb, s), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, nb), lambda i: (i, 0)),
            pl.BlockSpec((1, nb), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, nb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cut, nb), q_dense.dtype),
        interpret=interpret,
    )(q_dense, sum_coords, sum_q, sum_scale, sum_zero)
