"""Pallas TPU kernel: natively query-batched quantized summary routing
(Seismic phase R).

For a whole query batch at once, computes

    r[q, l] = sum_s q_dense[q, sum_coords[q, l, s]] * dequant(sum_q[q, l, s])

where ``l`` runs over the flattened (probed list, block) axis and the
u8 affine dequantization ((level-1)*scale + zero, level 0 = padding)
is FUSED into the multiply — the paper's "matrix multiplication
against all quantized summaries of an inverted list" (§7.1), done for
the entire batch in ONE kernel launch and without ever materializing
the dequantized summaries in HBM.

Tiling (every block is >= 2-D; ops.py pads Q to tile_q and L to
tile_l — the summary width S and vocab d pass through as-is, so
non-interpret Mosaic lowering expects lane-aligned S/d; off-TPU
coverage is interpret-mode only, see ROADMAP "TPU validation"):

  grid = (Q / tile_q, L / tile_l)   — queries x summary tiles
  q block      [tile_q, d]          dense query tile, VMEM-resident
                                    across the inner (summary) grid axis
  coords/sq    [tile_q, tile_l, S]  one summary tile per grid step
  scale/zero   [tile_q, tile_l]
  out          [tile_q, tile_l]

The per-row dynamic gather ``take_along_axis(q, coords)`` lowers
through the TPU gather/scatter unit on current Mosaic; interpret mode
(selected automatically off-TPU by ops.py) executes the same program
on CPU and is what the parity tests pin against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _summary_dot_kernel(q_ref, coords_ref, sq_ref, scale_ref, zero_ref,
                        out_ref):
    q = q_ref[...]                                  # [tq, d]
    coords = coords_ref[...]                        # [tq, tl, S]
    sq = sq_ref[...].astype(q.dtype)                # [tq, tl, S] u8 -> f
    scale = scale_ref[...].astype(q.dtype)          # [tq, tl]
    zero = zero_ref[...].astype(q.dtype)            # [tq, tl]
    tq, tl, s = coords.shape
    gathered = jnp.take_along_axis(
        q, coords.reshape(tq, tl * s), axis=1).reshape(tq, tl, s)
    deq = (sq - 1.0) * scale[..., None] + zero[..., None]
    deq = jnp.where(sq > 0, deq, 0.0)               # level 0 == padding
    out_ref[...] = (gathered * deq).sum(axis=-1)


@functools.partial(jax.jit,
                   static_argnames=("tile_q", "tile_l", "interpret"))
def summary_dot_batch_pallas(q_dense: jax.Array, sum_coords: jax.Array,
                             sum_q: jax.Array, sum_scale: jax.Array,
                             sum_zero: jax.Array, *, tile_q: int = 8,
                             tile_l: int = 128,
                             interpret: bool = True) -> jax.Array:
    """r [Q, L] from quantized summaries [Q, L, S]; one launch per batch.

    Q must be a multiple of tile_q and L of tile_l (ops.py pads).
    """
    qn, l, s = sum_coords.shape
    d = q_dense.shape[1]
    assert q_dense.shape[0] == qn and qn % tile_q == 0 and l % tile_l == 0, (
        q_dense.shape, sum_coords.shape, tile_q, tile_l)
    grid = (qn // tile_q, l // tile_l)
    return pl.pallas_call(
        _summary_dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_q, tile_l, s), lambda i, j: (i, j, 0)),
            pl.BlockSpec((tile_q, tile_l, s), lambda i, j: (i, j, 0)),
            pl.BlockSpec((tile_q, tile_l), lambda i, j: (i, j)),
            pl.BlockSpec((tile_q, tile_l), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_l), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, l), q_dense.dtype),
        interpret=interpret,
    )(q_dense, sum_coords, sum_q, sum_scale, sum_zero)


def summary_dot_pallas(q_dense: jax.Array, sum_coords: jax.Array,
                       sum_q: jax.Array, sum_scale: jax.Array,
                       sum_zero: jax.Array, *,
                       interpret: bool | None = None) -> jax.Array:
    """Single-query compatibility shim: r [cut, nb] via the batched
    kernel with Q=1 (kept for callers/tests of the pre-batch API)."""
    from repro.kernels.summary_dot.ops import _pad_batch_call
    cut, nb, s = sum_coords.shape
    r = _pad_batch_call(q_dense[None], sum_coords.reshape(1, cut * nb, s),
                        sum_q.reshape(1, cut * nb, s),
                        sum_scale.reshape(1, cut * nb),
                        sum_zero.reshape(1, cut * nb), interpret=interpret)
    return r[0].reshape(cut, nb)
