"""Shape- and VMEM-budget-driven tile selection for the retrieval
kernels (summary_dot, gather_dot, and the fused router/refine family).

The kernels used to hardcode ``tile_q=8, tile_n=128`` — the minimum
hardware-aligned tile. That is correct for any shape but leaves
bandwidth on the table for large launches (more grid steps, more query
re-fetches per candidate tile) and over-pads tiny ones. The chooser
replaces the constants with a deterministic function of the problem
shape and a VMEM budget:

  * tiles stay aligned to the f32 register layout — ``tile_q`` a
    multiple of the 8-row sublane, ``tile_n`` a multiple of the
    128-lane vector width;
  * tiles never exceed the padded problem size (no pure-padding grid
    steps) nor a per-axis cap (huge tiles serialize the grid and kill
    the pipelining the BlockSpec machinery buys);
  * the per-grid-step footprint — the VMEM-resident query tile plus
    double-buffered streamed rows plus the output tile — must fit
    ``vmem_budget`` bytes. Preference order: widest ``tile_n`` first
    (longer contiguous HBM bursts on the streamed candidate axis),
    tallest ``tile_q`` second (amortizes query-tile residency across
    more rows).

Everything is computed from static shapes at trace time, so a choice
never varies between runs of the same launch shape — parity tests pin
that results are tile-invariant anyway.

``bytes_moved`` is the companion traffic model the kernel microbench
reports (and the fusion smoke gates compare): HBM bytes a tiled launch
moves, counting streamed rows once and the query tile once per
candidate-axis grid step.
"""
from __future__ import annotations

import dataclasses

SUBLANE = 8        # f32 sublane height — tile_q alignment
LANE = 128         # lane width — tile_n alignment
# Per-core VMEM is ~16 MiB on current TPUs; budget half of it for one
# grid step so double-buffering the next step's operands always fits.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024
MAX_TILE_Q = 64    # caps keep the grid parallel even under huge budgets
MAX_TILE_N = 2048


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@dataclasses.dataclass(frozen=True)
class TileChoice:
    """One resolved tiling with its modeled footprint."""

    tile_q: int
    tile_n: int
    vmem_bytes: int      # modeled per-grid-step VMEM footprint
    fits: bool           # False only for the minimum-tile fallback


def tile_vmem_bytes(tile_q: int, tile_n: int, *, row_bytes: int,
                    q_row_bytes: int, out_bytes: int = 4) -> int:
    """Modeled VMEM footprint of one grid step.

    ``row_bytes`` — bytes per streamed candidate/summary row (coords +
    values + per-row dequant constants); ``q_row_bytes`` — bytes per
    VMEM-resident query row (4 * d for f32). Streamed rows are
    double-buffered (the DMA for grid step j+1 overlaps compute on j).
    """
    return (tile_q * q_row_bytes
            + 2 * tile_q * tile_n * row_bytes
            + tile_q * tile_n * out_bytes)


def choose_tiles(qn: int, n: int, *, row_bytes: int, q_row_bytes: int,
                 out_bytes: int = 4,
                 vmem_budget: int = VMEM_BUDGET_BYTES,
                 max_tile_q: int = MAX_TILE_Q,
                 max_tile_n: int = MAX_TILE_N) -> TileChoice:
    """Pick (tile_q, tile_n) for a [qn, n]-shaped launch.

    Deterministic in the arguments. Falls back to the minimum aligned
    tile (SUBLANE x LANE) when even that exceeds the budget (pathologic
    row widths) — ``fits=False`` flags it for the microbench report.
    """
    if qn <= 0 or n <= 0:
        raise ValueError(f"degenerate launch shape ({qn}, {n})")
    tq_cap = min(max_tile_q, _round_up(qn, SUBLANE))
    tn_cap = min(max_tile_n, _round_up(n, LANE))
    for tn in range(tn_cap, 0, -LANE):
        # widest n first; for each width take the tallest fitting tq
        for tq in range(tq_cap, 0, -SUBLANE):
            used = tile_vmem_bytes(tq, tn, row_bytes=row_bytes,
                                   q_row_bytes=q_row_bytes,
                                   out_bytes=out_bytes)
            if used <= vmem_budget:
                return TileChoice(tile_q=tq, tile_n=tn, vmem_bytes=used,
                                  fits=True)
    used = tile_vmem_bytes(SUBLANE, LANE, row_bytes=row_bytes,
                           q_row_bytes=q_row_bytes, out_bytes=out_bytes)
    return TileChoice(tile_q=SUBLANE, tile_n=LANE, vmem_bytes=used,
                      fits=False)


def choose_tile_q(qn: int, *, fixed_bytes: int, per_query_bytes: int,
                  vmem_budget: int = VMEM_BUDGET_BYTES,
                  max_tile_q: int = MAX_TILE_Q) -> int:
    """Tile height for query-grid-only kernels (the fused router/refine
    launches, whose candidate axis lives inside the kernel).

    ``fixed_bytes`` is the footprint shared by every grid step (the
    kernel-resident index planes); ``per_query_bytes`` the per-row
    state (dense query row + per-row intermediates/outputs).
    """
    tq_cap = min(max_tile_q, _round_up(max(qn, 1), SUBLANE))
    for tq in range(tq_cap, 0, -SUBLANE):
        if fixed_bytes + tq * per_query_bytes <= vmem_budget:
            return tq
    return SUBLANE


def bytes_moved(qn: int, n: int, tile_q: int, tile_n: int, *,
                row_bytes: int, q_row_bytes: int,
                out_bytes: int = 4) -> int:
    """Modeled HBM traffic of one tiled [qn, n] launch.

    Streamed rows cross HBM once; the query tile is re-fetched once per
    candidate-axis grid step; the output is written once. Padded edges
    count (the hardware moves them), which is exactly why the chooser
    refuses tiles wider than the padded problem.
    """
    pq = _round_up(qn, tile_q)
    pn = _round_up(n, tile_n)
    grid_n = pn // tile_n
    return (pq * pn * row_bytes          # streamed candidate/summary rows
            + grid_n * pq * q_row_bytes  # query tile per candidate tile
            + pq * pn * out_bytes)       # output scores


def summary_row_bytes(s: int) -> int:
    """Streamed bytes per summary row: i32 coords + u8 levels + f32
    (scale, zero)."""
    return s * (4 + 1) + 8


def gather_row_bytes(nnz: int, *, quant: bool) -> int:
    """Streamed bytes per candidate row: i32 coords + values (u8 when
    the forward index is compact, f32 otherwise) + per-doc (scale,
    zero) on the quantized plane."""
    return nnz * (4 + (1 if quant else 4)) + (8 if quant else 0)


__all__ = ["SUBLANE", "LANE", "VMEM_BUDGET_BYTES", "TileChoice",
           "tile_vmem_bytes", "choose_tiles", "choose_tile_q",
           "bytes_moved", "summary_row_bytes", "gather_row_bytes"]
