"""Pallas kernels: the FUSED router (Seismic phase R in one launch).

The staged router pays two HBM round-trips that this kernel family
removes:

* flat routing materializes the probed summaries host-side
  (``index.sum_coords[lists]`` -> [Q, cut*nb, S] + the u8/scale/zero
  planes) before the summary_dot launch;
* hierarchical routing additionally gathers the child summaries of the
  surviving superblocks ([Q, M, f, S] int32 + u8 + 2 f32 planes)
  between its stage-A and stage-B summary_dot launches, plus a
  separate top-M launch in between.

Here the kernel receives the probed coordinate ids ``lists [Q, cut]``
and the per-list summary planes, and performs stage A, the per-query
top-M superblock selection, the child-summary gather, and stage B in
ONE launch — per-query intermediates never leave VMEM. Outputs are the
tiny per-query results only (flat: the routed scores; hierarchical:
child scores + their flat positions for the host-side scatter, which
is [Q, M*f] — the one intermediate that is output-sized, not
summary-sized).

Math is op-for-op identical to the unfused path (same dequant formula,
same -inf masking, same top_k), so ``fuse_level=2`` is bit-exact with
``fuse_level=0`` — the parity tests pin it.

Coverage boundary (see src/repro/kernels/README.md): the summary
planes ride in whole-array blocks, exact under interpret mode (CPU
CI). The Mosaic lowering additionally needs the planes VMEM-resident
(fine for per-list tiers at paper scale) or an ANY-space DMA variant,
and in-kernel ``top_k`` support; real-TPU validation is the
ROADMAP-tracked follow-on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -jnp.inf


def _summary_scores(q, coords, u8, scale, zero):
    """<q_row, dequant(summary)> for [tq, L, S] summaries — the same
    fused-dequant inner product as the summary_dot kernel."""
    tq, l, s = coords.shape
    gathered = jnp.take_along_axis(
        q, coords.reshape(tq, l * s), axis=1).reshape(tq, l, s)
    u8f = u8.astype(q.dtype)
    deq = (u8f - 1.0) * scale[..., None].astype(q.dtype) \
        + zero[..., None].astype(q.dtype)
    deq = jnp.where(u8 > 0, deq, 0.0)           # level 0 == padding
    return (gathered * deq).sum(axis=-1)


def _take_rows(plane, lists):
    """plane [L, ...] indexed by lists [tq, cut] -> [tq, cut, ...]."""
    return jnp.take(plane, lists, axis=0, mode="clip")


def _router_flat_kernel(lists_ref, q_ref, sumc_ref, sumq_ref, sums_ref,
                        sumz_ref, blen_ref, r_ref):
    lists = lists_ref[...]                      # [tq, cut]
    q = q_ref[...]                              # [tq, d]
    tq, cut = lists.shape
    nb = blen_ref.shape[1]
    s = sumc_ref.shape[2]
    sc = _take_rows(sumc_ref[...], lists).reshape(tq, cut * nb, s)
    sq = _take_rows(sumq_ref[...], lists).reshape(tq, cut * nb, s)
    scale = _take_rows(sums_ref[...], lists).reshape(tq, cut * nb)
    zero = _take_rows(sumz_ref[...], lists).reshape(tq, cut * nb)
    r = _summary_scores(q, sc, sq, scale, zero)
    alive = (_take_rows(blen_ref[...], lists) > 0).reshape(tq, cut * nb)
    r_ref[...] = jnp.where(alive, r, NEG)


@functools.partial(jax.jit, static_argnames=("tile_q", "interpret"))
def router_flat_pallas(lists: jax.Array, q_dense: jax.Array,
                       sum_coords: jax.Array, sum_q: jax.Array,
                       sum_scale: jax.Array, sum_zero: jax.Array,
                       block_len: jax.Array, *, tile_q: int = 8,
                       interpret: bool = True) -> jax.Array:
    """Fused flat route: probed lists [Q, cut] + summary planes
    [L, nb, S] -> routed scores r [Q, cut*nb] (-inf dead), one launch.
    Q must be a multiple of tile_q (ops.py pads)."""
    qn, cut = lists.shape
    l, nb, s = sum_coords.shape
    d = q_dense.shape[1]
    assert q_dense.shape[0] == qn and qn % tile_q == 0, (
        q_dense.shape, lists.shape, tile_q)
    grid = (qn // tile_q,)
    full3 = pl.BlockSpec((l, nb, s), lambda i: (0, 0, 0))
    full2 = pl.BlockSpec((l, nb), lambda i: (0, 0))
    return pl.pallas_call(
        _router_flat_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, cut), lambda i: (i, 0)),
            pl.BlockSpec((tile_q, d), lambda i: (i, 0)),
            full3, full3, full2, full2, full2,
        ],
        out_specs=pl.BlockSpec((tile_q, cut * nb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((qn, cut * nb), q_dense.dtype),
        interpret=interpret,
    )(lists, q_dense, sum_coords, sum_q, sum_scale, sum_zero, block_len)


def _router_hier_kernel(lists_ref, q_ref, supc_ref, supq_ref, sups_ref,
                        supz_ref, sumc_ref, sumq_ref, sums_ref, sumz_ref,
                        blen_ref, rb_ref, flat_ref, *, m, fanout):
    lists = lists_ref[...]                      # [tq, cut]
    q = q_ref[...]                              # [tq, d]
    tq, cut = lists.shape
    l, ns, s2 = supc_ref.shape
    nb = blen_ref.shape[1]
    s = sumc_ref.shape[2]
    blen = blen_ref[...]                        # [L, nb]
    # ---- stage A: coarse superblock tier for the probed lists
    sc = _take_rows(supc_ref[...], lists).reshape(tq, cut * ns, s2)
    sq = _take_rows(supq_ref[...], lists).reshape(tq, cut * ns, s2)
    sscale = _take_rows(sups_ref[...], lists).reshape(tq, cut * ns)
    szero = _take_rows(supz_ref[...], lists).reshape(tq, cut * ns)
    u = _summary_scores(q, sc, sq, sscale, szero)
    # a superblock is alive iff any child block is (all-padding -> -inf)
    blk_alive = jnp.pad(blen > 0, ((0, 0), (0, (-nb) % fanout)))
    sup_alive = blk_alive.reshape(l, ns, fanout).any(-1)
    u = jnp.where(_take_rows(sup_alive, lists).reshape(tq, cut * ns),
                  u, NEG)
    # ---- per-query top-M superblocks, child gather, stage B — all VMEM
    us, sup_ids = jax.lax.top_k(u, m)           # [tq, M]
    li = sup_ids // ns                          # probed slot
    gi = sup_ids % ns                           # group in list
    child = gi[..., None] * fanout + jnp.arange(fanout)     # [tq, M, f]
    in_range = child < nb
    child = jnp.minimum(child, nb - 1)
    coord = jnp.take_along_axis(lists, li, axis=1)          # [tq, M]
    bsc = sumc_ref[...][coord[..., None], child]            # [tq, M, f, S]
    bsq = sumq_ref[...][coord[..., None], child]
    bscale = sums_ref[...][coord[..., None], child]
    bzero = sumz_ref[...][coord[..., None], child]
    rb = _summary_scores(q, bsc.reshape(tq, m * fanout, s),
                         bsq.reshape(tq, m * fanout, s),
                         bscale.reshape(tq, m * fanout),
                         bzero.reshape(tq, m * fanout))
    alive = (in_range
             & (blen[coord[..., None], child] > 0)
             & jnp.isfinite(us)[..., None])                 # [tq, M, f]
    rb_ref[...] = jnp.where(alive.reshape(tq, m * fanout), rb, NEG)
    flat_ref[...] = (li[..., None] * nb
                     + child).reshape(tq, m * fanout).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("m", "fanout", "tile_q",
                                             "interpret"))
def router_hier_pallas(lists: jax.Array, q_dense: jax.Array,
                       sup_coords: jax.Array, sup_q: jax.Array,
                       sup_scale: jax.Array, sup_zero: jax.Array,
                       sum_coords: jax.Array, sum_q: jax.Array,
                       sum_scale: jax.Array, sum_zero: jax.Array,
                       block_len: jax.Array, *, m: int, fanout: int,
                       tile_q: int = 8, interpret: bool = True
                       ) -> tuple[jax.Array, jax.Array]:
    """Fused two-stage route: stage A over the superblock tier, top-``m``
    per query, in-VMEM child-summary gather, stage B — one launch.

    Returns (rb [Q, m*fanout] child scores with pruned/dead at -inf,
    flat [Q, m*fanout] positions into the [cut*nb] routed layout); the
    host scatters them (output-sized work, no summary-sized
    intermediate). Q must be a multiple of tile_q (ops.py pads).
    """
    qn, cut = lists.shape
    l, ns, s2 = sup_coords.shape
    _, nb, s = sum_coords.shape
    d = q_dense.shape[1]
    assert q_dense.shape[0] == qn and qn % tile_q == 0, (
        q_dense.shape, lists.shape, tile_q)
    assert 0 < m <= cut * ns, (m, cut, ns)
    grid = (qn // tile_q,)
    sup3 = pl.BlockSpec((l, ns, s2), lambda i: (0, 0, 0))
    sup2 = pl.BlockSpec((l, ns), lambda i: (0, 0))
    sum3 = pl.BlockSpec((l, nb, s), lambda i: (0, 0, 0))
    sum2 = pl.BlockSpec((l, nb), lambda i: (0, 0))
    out_spec = pl.BlockSpec((tile_q, m * fanout), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_router_hier_kernel, m=m, fanout=fanout),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, cut), lambda i: (i, 0)),
            pl.BlockSpec((tile_q, d), lambda i: (i, 0)),
            sup3, sup3, sup2, sup2,
            sum3, sum3, sum2, sum2, sum2,
        ],
        out_specs=(out_spec, out_spec),
        out_shape=(
            jax.ShapeDtypeStruct((qn, m * fanout), q_dense.dtype),
            jax.ShapeDtypeStruct((qn, m * fanout), jnp.int32),
        ),
        interpret=interpret,
    )(lists, q_dense, sup_coords, sup_q, sup_scale, sup_zero,
      sum_coords, sum_q, sum_scale, sum_zero, block_len)
