from repro.kernels.router_fused.ops import (router_flat_batch,
                                            router_hier_batch)

__all__ = ["router_flat_batch", "router_hier_batch"]
