"""Public wrappers for the fused router kernels: pick tile_q from the
VMEM model (the candidate axis lives inside the kernel), pad Q, launch,
slice back. Interpret mode resolves through the shared runtime helper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.router_fused.router_fused import (router_flat_pallas,
                                                     router_hier_pallas)
from repro.kernels.runtime import default_interpret
from repro.kernels.tiling import choose_tile_q


def _plane_bytes(*arrays) -> int:
    return sum(int(a.size) * a.dtype.itemsize for a in arrays)


def _pad_q(tile_q, lists, q_dense):
    pq = (-lists.shape[0]) % tile_q
    if pq:
        lists = jnp.pad(lists, ((0, pq), (0, 0)))
        q_dense = jnp.pad(q_dense, ((0, pq), (0, 0)))
    return lists, q_dense


def router_flat_batch(lists: jax.Array, q_dense: jax.Array,
                      sum_coords: jax.Array, sum_q: jax.Array,
                      sum_scale: jax.Array, sum_zero: jax.Array,
                      block_len: jax.Array, *, tile_q: int | None = None,
                      interpret: bool | None = None) -> jax.Array:
    """Fused flat route -> r [Q, cut*nb] (-inf dead blocks)."""
    interpret = default_interpret(interpret)
    qn, cut = lists.shape
    nb, s = sum_coords.shape[1], sum_coords.shape[2]
    if tile_q is None:
        # per query row: dense query + the in-VMEM gathered summaries
        per_q = 4 * q_dense.shape[1] + cut * nb * (5 * s + 12)
        tile_q = choose_tile_q(qn, fixed_bytes=_plane_bytes(
            sum_coords, sum_q, sum_scale, sum_zero, block_len),
            per_query_bytes=per_q)
    lists_p, q_p = _pad_q(tile_q, lists, q_dense)
    out = router_flat_pallas(lists_p, q_p, sum_coords, sum_q, sum_scale,
                             sum_zero, block_len, tile_q=tile_q,
                             interpret=interpret)
    return out[:qn]


def router_hier_batch(lists: jax.Array, q_dense: jax.Array,
                      sup_coords: jax.Array, sup_q: jax.Array,
                      sup_scale: jax.Array, sup_zero: jax.Array,
                      sum_coords: jax.Array, sum_q: jax.Array,
                      sum_scale: jax.Array, sum_zero: jax.Array,
                      block_len: jax.Array, *, m: int, fanout: int,
                      tile_q: int | None = None,
                      interpret: bool | None = None
                      ) -> tuple[jax.Array, jax.Array]:
    """Fused two-stage route -> (rb [Q, m*fanout], flat [Q, m*fanout])."""
    interpret = default_interpret(interpret)
    qn, cut = lists.shape
    ns, s2 = sup_coords.shape[1], sup_coords.shape[2]
    s = sum_coords.shape[2]
    if tile_q is None:
        per_q = (4 * q_dense.shape[1]
                 + cut * ns * (5 * s2 + 12)      # stage-A gather
                 + m * fanout * (5 * s + 20))    # child gather + outputs
        tile_q = choose_tile_q(qn, fixed_bytes=_plane_bytes(
            sup_coords, sup_q, sup_scale, sup_zero,
            sum_coords, sum_q, sum_scale, sum_zero, block_len),
            per_query_bytes=per_q)
    lists_p, q_p = _pad_q(tile_q, lists, q_dense)
    rb, flat = router_hier_pallas(
        lists_p, q_p, sup_coords, sup_q, sup_scale, sup_zero,
        sum_coords, sum_q, sum_scale, sum_zero, block_len,
        m=m, fanout=fanout, tile_q=tile_q, interpret=interpret)
    return rb[:qn], flat[:qn]


__all__ = ["router_flat_batch", "router_hier_batch"]
