"""Public wrappers for gather_dot: pad to tile multiples, pick
interpret mode off-TPU.

``gather_dot_batch``  [Q, N, nnz] candidates -> [Q, N] exact scores,
                      one kernel launch per batch; optional fused u8
                      dequant via (scale, zero)
``gather_dot``        single-query [N, nnz] compatibility API
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gather_dot.gather_dot import (gather_dot_batch_pallas,
                                                 gather_dot_pallas)
from repro.kernels.gather_dot.ref import gather_dot_batch_ref, gather_dot_ref

_TILE_Q = 8     # f32 sublane width
_TILE_N = 128   # lane width


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_batch_call(q_dense, coords, vals, scale, zero, *,
                    tile_n=_TILE_N, interpret=True):
    """Pad Q to _TILE_Q and N to tile_n, launch, slice back."""
    qn, n, _ = coords.shape
    pq = (-qn) % _TILE_Q
    pn = (-n) % tile_n
    if pq or pn:
        q_dense = jnp.pad(q_dense, ((0, pq), (0, 0)))
        coords = jnp.pad(coords, ((0, pq), (0, pn), (0, 0)))
        vals = jnp.pad(vals, ((0, pq), (0, pn), (0, 0)))
        if scale is not None:
            scale = jnp.pad(scale, ((0, pq), (0, pn)))
            zero = jnp.pad(zero, ((0, pq), (0, pn)))
    out = gather_dot_batch_pallas(q_dense, coords, vals, scale, zero,
                                  tile_q=_TILE_Q, tile_n=tile_n,
                                  interpret=interpret)
    return out[:qn, :n]


def gather_dot_batch(q_dense: jax.Array, coords: jax.Array,
                     vals: jax.Array, scale: jax.Array | None = None,
                     zero: jax.Array | None = None) -> jax.Array:
    """Batched sparse·dense scoring [Q, N, nnz] -> [Q, N].

    With (scale, zero) given, ``vals`` is uint8 and the per-doc affine
    dequantization fuses into the kernel (compact forward index)."""
    return _pad_batch_call(q_dense, coords, vals, scale, zero,
                           interpret=not _on_tpu())


def gather_dot(q_dense: jax.Array, coords: jax.Array,
               vals: jax.Array) -> jax.Array:
    """Single-query sparse·dense scoring [N, nnz] -> [N] (pre-batch
    compatibility API)."""
    return gather_dot_pallas(q_dense, coords, vals,
                             interpret=not _on_tpu())


__all__ = ["gather_dot", "gather_dot_batch", "gather_dot_ref",
           "gather_dot_batch_ref"]
