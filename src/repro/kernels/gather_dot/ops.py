"""jit'd public wrapper for the gather_dot kernel: pads N to the tile
size, picks interpret mode off-TPU, falls back to ref on any platform
where neither applies."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gather_dot.gather_dot import gather_dot_pallas
from repro.kernels.gather_dot.ref import gather_dot_ref

_TILE = 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gather_dot(q_dense: jax.Array, coords: jax.Array,
               vals: jax.Array) -> jax.Array:
    """Batched sparse·dense scoring with tile padding. [N,nnz] -> [N]."""
    n = coords.shape[0]
    pad = (-n) % _TILE
    if pad:
        coords = jnp.pad(coords, ((0, pad), (0, 0)))
        vals = jnp.pad(vals, ((0, pad), (0, 0)))
    out = gather_dot_pallas(q_dense, coords, vals, tile_n=_TILE,
                            interpret=not _on_tpu())
    return out[:n]


__all__ = ["gather_dot", "gather_dot_ref"]
