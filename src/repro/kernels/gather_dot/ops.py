"""Public wrappers for gather_dot: pad to tile multiples, pick tiles
from the shared VMEM model, resolve interpret mode centrally.

``gather_dot_batch``       [Q, N, nnz] pre-gathered candidate rows ->
                           [Q, N] exact scores; optional fused u8
                           dequant via (scale, zero)
``gather_dot_cand_batch``  [Q, C] candidate DOC IDS + the forward plane
                           -> [Q, C] scores; the gather happens inside
                           the kernel and all-sentinel tiles are
                           skipped (the compaction fast path,
                           ``SearchParams.fuse_level >= 1``)
``gather_dot``             single-query [N, nnz] compatibility API

All wrappers resolve interpret mode through the single
:func:`repro.kernels.runtime.default_interpret` helper (auto-select
off-TPU; explicit bool overrides) — no wrapper hardcodes its own
default anymore. Tiling comes from :mod:`repro.kernels.tiling` unless
pinned explicitly (the microbench sweep pins it).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gather_dot.gather_dot import (gather_dot_batch_pallas,
                                                 gather_dot_cand_pallas,
                                                 gather_dot_pallas)
from repro.kernels.gather_dot.ref import gather_dot_batch_ref, gather_dot_ref
from repro.kernels.runtime import default_interpret
from repro.kernels.tiling import TileChoice, choose_tiles, gather_row_bytes

_TILE_Q = 8     # minimum aligned tile (f32 sublane) — chooser floor
_TILE_N = 128   # minimum aligned tile (lane width) — chooser floor


def cand_tile_choice(qn: int, c: int, nnz: int, *, quant: bool,
                     dim: int) -> TileChoice:
    """THE tile choice of the candidate-driven kernel for a [qn, c]
    launch — one definition shared by ``gather_dot_cand_batch``, the
    microbench/throughput reports, and the obs device accounting, so
    every ``cand_tiles_processed`` mirror evaluates the kernel's
    actual tiling (the +4 charges the in-kernel candidate-id column)."""
    return choose_tiles(qn, c,
                        row_bytes=gather_row_bytes(nnz, quant=quant) + 4,
                        q_row_bytes=4 * dim)


def _pad_batch_call(q_dense, coords, vals, scale, zero, *,
                    tile_q=None, tile_n=None, interpret=None):
    """Choose tiles, pad Q/N up to them, launch, slice back."""
    interpret = default_interpret(interpret)
    qn, n, nnz = coords.shape
    if tile_q is None or tile_n is None:
        ch = choose_tiles(qn, n,
                          row_bytes=gather_row_bytes(
                              nnz, quant=scale is not None),
                          q_row_bytes=4 * q_dense.shape[1])
        tile_q = tile_q if tile_q is not None else ch.tile_q
        tile_n = tile_n if tile_n is not None else ch.tile_n
    pq = (-qn) % tile_q
    pn = (-n) % tile_n
    if pq or pn:
        q_dense = jnp.pad(q_dense, ((0, pq), (0, 0)))
        coords = jnp.pad(coords, ((0, pq), (0, pn), (0, 0)))
        vals = jnp.pad(vals, ((0, pq), (0, pn), (0, 0)))
        if scale is not None:
            scale = jnp.pad(scale, ((0, pq), (0, pn)))
            zero = jnp.pad(zero, ((0, pq), (0, pn)))
    out = gather_dot_batch_pallas(q_dense, coords, vals, scale, zero,
                                  tile_q=tile_q, tile_n=tile_n,
                                  interpret=interpret)
    return out[:qn, :n]


def gather_dot_batch(q_dense: jax.Array, coords: jax.Array,
                     vals: jax.Array, scale: jax.Array | None = None,
                     zero: jax.Array | None = None, *,
                     tile_q: int | None = None, tile_n: int | None = None,
                     interpret: bool | None = None) -> jax.Array:
    """Batched sparse·dense scoring [Q, N, nnz] -> [Q, N].

    With (scale, zero) given, ``vals`` is uint8 and the per-doc affine
    dequantization fuses into the kernel (compact forward index)."""
    return _pad_batch_call(q_dense, coords, vals, scale, zero,
                           tile_q=tile_q, tile_n=tile_n, interpret=interpret)


def gather_dot_cand_batch(q_dense: jax.Array, cand: jax.Array,
                          fwd_coords: jax.Array, fwd_vals: jax.Array,
                          fwd_scale: jax.Array | None = None,
                          fwd_zero: jax.Array | None = None, *,
                          n_docs: int, tile_q: int | None = None,
                          tile_n: int | None = None,
                          interpret: bool | None = None) -> jax.Array:
    """Candidate-driven scoring: ids [Q, C] + forward plane [N, nnz] ->
    scores [Q, C] (sentinel ids >= n_docs -> -inf).

    The forward gather runs inside the kernel (no [Q, C, nnz] HBM
    intermediate) and tiles whose candidates are all sentinel are
    skipped — pack live candidates to a prefix first
    (``scorer.compact_candidates``) to maximize skipped tiles.
    Q/C padding uses the sentinel, so padding lands in skipped tiles.
    """
    interpret = default_interpret(interpret)
    qn, c = cand.shape
    nnz = fwd_coords.shape[1]
    if tile_q is None or tile_n is None:
        ch = cand_tile_choice(qn, c, nnz,
                              quant=fwd_scale is not None,
                              dim=q_dense.shape[1])
        tile_q = tile_q if tile_q is not None else ch.tile_q
        tile_n = tile_n if tile_n is not None else ch.tile_n
    pq = (-qn) % tile_q
    pn = (-c) % tile_n
    if pq or pn:
        q_dense = jnp.pad(q_dense, ((0, pq), (0, 0)))
        cand = jnp.pad(cand, ((0, pq), (0, pn)),
                       constant_values=n_docs)    # padding == sentinel
    out = gather_dot_cand_pallas(q_dense, cand, fwd_coords, fwd_vals,
                                 fwd_scale, fwd_zero, n_docs=n_docs,
                                 tile_q=tile_q, tile_n=tile_n,
                                 interpret=interpret)
    return out[:qn, :c]


def cand_tiles_processed(cand, n_docs: int, tile_q: int,
                         tile_n: int) -> np.ndarray:
    """Host-side mirror of the candidate kernel's skip predicate:
    bool [gridQ, gridN] — True where a tile holds at least one live
    candidate and the kernel runs its gather + dot.

    This IS the work model the microbench and the compaction smoke
    gate report (``scored slots = processed.sum() * tile_q * tile_n``);
    it matches the kernel's ``pl.when`` decision bit-for-bit because it
    evaluates the same predicate on the same padded layout.
    """
    a = np.asarray(cand)
    qn, c = a.shape
    pq = (-qn) % tile_q
    pn = (-c) % tile_n
    if pq or pn:
        a = np.pad(a, ((0, pq), (0, pn)), constant_values=n_docs)
    gq, gn = a.shape[0] // tile_q, a.shape[1] // tile_n
    live = (a < n_docs).reshape(gq, tile_q, gn, tile_n)
    return live.any(axis=(1, 3))


def gather_dot(q_dense: jax.Array, coords: jax.Array,
               vals: jax.Array, *,
               interpret: bool | None = None) -> jax.Array:
    """Single-query sparse·dense scoring [N, nnz] -> [N] (pre-batch
    compatibility API)."""
    return gather_dot_pallas(q_dense, coords, vals, interpret=interpret)


__all__ = ["gather_dot", "gather_dot_batch", "gather_dot_cand_batch",
           "cand_tile_choice", "cand_tiles_processed", "gather_dot_ref",
           "gather_dot_batch_ref"]
