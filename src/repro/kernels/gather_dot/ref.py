"""Pure-jnp oracle for the gather_dot kernel."""
import jax
import jax.numpy as jnp


def gather_dot_ref(q_dense: jax.Array, coords: jax.Array,
                   vals: jax.Array) -> jax.Array:
    """scores[n] = sum_j q_dense[coords[n, j]] * vals[n, j]."""
    return (jnp.take(q_dense, coords, axis=0)
            * vals.astype(q_dense.dtype)).sum(axis=-1)
