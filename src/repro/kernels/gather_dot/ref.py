"""Pure-jnp oracles for the gather_dot kernels."""
import jax
import jax.numpy as jnp


def gather_dot_ref(q_dense: jax.Array, coords: jax.Array,
                   vals: jax.Array) -> jax.Array:
    """Single query: scores[n] = sum_j q_dense[coords[n, j]] * vals[n, j]."""
    return (jnp.take(q_dense, coords, axis=0)
            * vals.astype(q_dense.dtype)).sum(axis=-1)


def gather_dot_batch_ref(q_dense: jax.Array, coords: jax.Array,
                         vals: jax.Array, scale: jax.Array | None = None,
                         zero: jax.Array | None = None) -> jax.Array:
    """Query batch: scores[q, n] = <q_dense[q], candidate[q, n]>.

    With (scale, zero), vals is u8 and dequantized first (level 0 -> 0),
    mirroring the fused-quant kernel variant."""
    qn, n, nnz = coords.shape
    gathered = jnp.take_along_axis(
        q_dense, coords.reshape(qn, n * nnz), axis=1).reshape(qn, n, nnz)
    if scale is not None:
        v = vals.astype(q_dense.dtype)
        deq = (v - 1.0) * scale[..., None].astype(q_dense.dtype) \
            + zero[..., None].astype(q_dense.dtype)
        v = jnp.where(vals > 0, deq, 0.0)
    else:
        v = vals.astype(q_dense.dtype)
    return (gathered * v).sum(axis=-1)
