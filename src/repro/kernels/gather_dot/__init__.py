from repro.kernels.gather_dot.ops import gather_dot, gather_dot_batch

__all__ = ["gather_dot", "gather_dot_batch"]
