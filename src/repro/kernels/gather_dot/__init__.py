from repro.kernels.gather_dot.ops import gather_dot

__all__ = ["gather_dot"]
