"""Pallas TPU kernel: natively query-batched sparse·dense inner
products (Seismic phase S, Alg. 2 line 9).

For a whole query batch and its per-query candidate tiles in padded-CSR
layout, computes

    scores[q, n] = sum_j q_dense[q, coords[q, n, j]] * vals[q, n, j]

in ONE kernel launch. This is the op the paper engineers around x86
cache misses with prefetch intrinsics (§5.4); the TPU analog streams
candidate tiles HBM->VMEM while the dense query tile stays
VMEM-resident across the inner grid axis.

When the forward index is compact (u8 values, ``fwd_quant=True``) the
per-doc affine dequantization ((level-1)*scale + zero, level 0 = pad)
fuses into the multiply — candidate values cross HBM as one byte each
and are never materialized as floats.

Tiling (ops.py pads Q to tile_q and N to tile_n — the row width nnz
and vocab d pass through as-is, so non-interpret Mosaic lowering
expects lane-aligned nnz/d; off-TPU coverage is interpret-mode only):
  grid = (Q / tile_q, N / tile_n)   — queries x candidate tiles
  q block       [tile_q, d]         VMEM-resident dense query tile
  coords/vals   [tile_q, tile_n, nnz]
  scale/zero    [tile_q, tile_n]    (quantized variant only)
  out           [tile_q, tile_n]

The per-row dynamic gather lowers through the TPU gather/scatter unit
on current Mosaic; interpret mode (auto-selected off-TPU by ops.py)
runs the same program on CPU for the ref.py parity tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather(q, coords):
    tq, tn, nnz = coords.shape
    return jnp.take_along_axis(
        q, coords.reshape(tq, tn * nnz), axis=1).reshape(tq, tn, nnz)


def _gather_dot_kernel(q_ref, coords_ref, vals_ref, out_ref):
    q = q_ref[...]                              # [tq, d]
    coords = coords_ref[...]                    # [tq, tn, nnz]
    vals = vals_ref[...].astype(q.dtype)
    out_ref[...] = (_gather(q, coords) * vals).sum(axis=-1)


def _gather_dot_quant_kernel(q_ref, coords_ref, vals_ref, scale_ref,
                             zero_ref, out_ref):
    q = q_ref[...]                              # [tq, d]
    coords = coords_ref[...]                    # [tq, tn, nnz]
    u8 = vals_ref[...].astype(q.dtype)          # [tq, tn, nnz]
    scale = scale_ref[...].astype(q.dtype)      # [tq, tn]
    zero = zero_ref[...].astype(q.dtype)
    deq = (u8 - 1.0) * scale[..., None] + zero[..., None]
    deq = jnp.where(u8 > 0, deq, 0.0)           # level 0 == padding
    out_ref[...] = (_gather(q, coords) * deq).sum(axis=-1)


@functools.partial(jax.jit,
                   static_argnames=("tile_q", "tile_n", "interpret"))
def gather_dot_batch_pallas(q_dense: jax.Array, coords: jax.Array,
                            vals: jax.Array, scale: jax.Array | None = None,
                            zero: jax.Array | None = None, *,
                            tile_q: int = 8, tile_n: int = 128,
                            interpret: bool = True) -> jax.Array:
    """scores [Q, N] = sum_j q_dense[q, coords[q, :, j]] * vals[q, :, j].

    Q must be a multiple of tile_q and N of tile_n (ops.py pads). With
    (scale, zero) given, vals is u8 and dequant fuses into the dot.
    """
    qn, n, nnz = coords.shape
    d = q_dense.shape[1]
    assert q_dense.shape[0] == qn and qn % tile_q == 0 and n % tile_n == 0, (
        q_dense.shape, coords.shape, tile_q, tile_n)
    grid = (qn // tile_q, n // tile_n)
    q_spec = pl.BlockSpec((tile_q, d), lambda i, j: (i, 0))
    row_spec = pl.BlockSpec((tile_q, tile_n, nnz), lambda i, j: (i, j, 0))
    sz_spec = pl.BlockSpec((tile_q, tile_n), lambda i, j: (i, j))
    quant = scale is not None
    kernel = _gather_dot_quant_kernel if quant else _gather_dot_kernel
    in_specs = [q_spec, row_spec, row_spec] + ([sz_spec, sz_spec] if quant
                                               else [])
    args = (q_dense, coords, vals) + ((scale, zero) if quant else ())
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=sz_spec,
        out_shape=jax.ShapeDtypeStruct((qn, n), q_dense.dtype),
        interpret=interpret,
    )(*args)


def gather_dot_pallas(q_dense: jax.Array, coords: jax.Array,
                      vals: jax.Array, *, tile_n: int = 128,
                      interpret: bool | None = None) -> jax.Array:
    """Single-query compatibility shim: scores [N] via the batched
    kernel with Q=1 (kept for callers/tests of the pre-batch API).
    N must be a multiple of tile_n (ops.py pads)."""
    from repro.kernels.gather_dot.ops import _pad_batch_call
    return _pad_batch_call(q_dense[None], coords[None], vals[None],
                           None, None, tile_n=tile_n, interpret=interpret)[0]


# --------------------------------------------------------------------------
# Candidate-driven variant: the kernel receives candidate DOC IDS and the
# whole forward plane, gathers each candidate's (coords, vals) row itself,
# and skips tiles that are 100% sentinel. This is the compaction partner
# (SearchParams.fuse_level >= 1): the scorer packs live candidates to a
# prefix, so at high dedupe rates most candidate tiles are pure sentinel
# and the kernel's pl.when predicate skips their gather + dot entirely —
# tile_n work shrinks with the dedupe rate instead of being paid on every
# padded slot. Host-side nothing [Q, C, nnz]-shaped is ever materialized.
#
# Coverage boundary: the forward-plane operands ride in whole-array
# blocks, which interpret mode (CPU CI) executes exactly; the Mosaic
# lowering needs them VMEM-resident or an ANY-space DMA variant — see
# src/repro/kernels/README.md ("interpret vs Mosaic").
# --------------------------------------------------------------------------


def _cand_scores(q, cand, fwd_coords, fwd_vals, scale, zero, n_docs):
    """Shared scoring body: gather candidate rows, (dequant,) dot, mask
    sentinels to -inf. Bit-identical math to the host-gather path."""
    c = jnp.take(fwd_coords, cand, axis=0, mode="clip").astype(jnp.int32)
    v = jnp.take(fwd_vals, cand, axis=0, mode="clip")
    tq, tn, nnz = c.shape
    gathered = jnp.take_along_axis(
        q, c.reshape(tq, tn * nnz), axis=1).reshape(tq, tn, nnz)
    if scale is not None:
        u8 = v.astype(q.dtype)
        s = jnp.take(scale, cand, mode="clip").astype(q.dtype)
        z = jnp.take(zero, cand, mode="clip").astype(q.dtype)
        deq = (u8 - 1.0) * s[..., None] + z[..., None]
        v = jnp.where(u8 > 0, deq, 0.0)     # level 0 == padding
    else:
        v = v.astype(q.dtype)
    out = (gathered * v).sum(axis=-1)
    return jnp.where(cand < n_docs, out, -jnp.inf)


def _gather_dot_cand_kernel(cand_ref, q_ref, fwdc_ref, fwdv_ref, out_ref,
                            *, n_docs):
    cand = cand_ref[...]                        # [tq, tn]
    out_ref[...] = jnp.full(cand.shape, -jnp.inf, out_ref.dtype)

    @pl.when(jnp.any(cand < n_docs))            # all-sentinel tile: skip
    def _process():
        out_ref[...] = _cand_scores(q_ref[...], cand, fwdc_ref[...],
                                    fwdv_ref[...], None, None, n_docs)


def _gather_dot_cand_quant_kernel(cand_ref, q_ref, fwdc_ref, fwdv_ref,
                                  fs_ref, fz_ref, out_ref, *, n_docs):
    cand = cand_ref[...]                        # [tq, tn]
    out_ref[...] = jnp.full(cand.shape, -jnp.inf, out_ref.dtype)

    @pl.when(jnp.any(cand < n_docs))            # all-sentinel tile: skip
    def _process():
        out_ref[...] = _cand_scores(q_ref[...], cand, fwdc_ref[...],
                                    fwdv_ref[...], fs_ref[...], fz_ref[...],
                                    n_docs)


@functools.partial(jax.jit, static_argnames=("n_docs", "tile_q", "tile_n",
                                             "interpret"))
def gather_dot_cand_pallas(q_dense: jax.Array, cand: jax.Array,
                           fwd_coords: jax.Array, fwd_vals: jax.Array,
                           fwd_scale: jax.Array | None = None,
                           fwd_zero: jax.Array | None = None, *,
                           n_docs: int, tile_q: int = 8, tile_n: int = 128,
                           interpret: bool = True) -> jax.Array:
    """scores [Q, C] for candidate doc ids [Q, C] against the forward
    plane [N, nnz]; sentinel ids (>= n_docs) score -inf, all-sentinel
    tiles are skipped. Q % tile_q == 0 and C % tile_n == 0 (ops.py pads
    with the sentinel, so padding lands in skipped tiles).
    """
    qn, c = cand.shape
    assert q_dense.shape[0] == qn and qn % tile_q == 0 and c % tile_n == 0, (
        q_dense.shape, cand.shape, tile_q, tile_n)
    grid = (qn // tile_q, c // tile_n)
    d = q_dense.shape[1]
    n, nnz = fwd_coords.shape
    tile_spec = pl.BlockSpec((tile_q, tile_n), lambda i, j: (i, j))
    q_spec = pl.BlockSpec((tile_q, d), lambda i, j: (i, 0))
    plane_spec = pl.BlockSpec((n, nnz), lambda i, j: (0, 0))
    doc_spec = pl.BlockSpec((n,), lambda i, j: (0,))
    quant = fwd_scale is not None
    kernel = (_gather_dot_cand_quant_kernel if quant
              else _gather_dot_cand_kernel)
    in_specs = [tile_spec, q_spec, plane_spec, plane_spec] \
        + ([doc_spec, doc_spec] if quant else [])
    args = (cand, q_dense, fwd_coords, fwd_vals) \
        + ((fwd_scale, fwd_zero) if quant else ())
    return pl.pallas_call(
        functools.partial(kernel, n_docs=n_docs),
        grid=grid,
        in_specs=in_specs,
        out_specs=tile_spec,
        out_shape=jax.ShapeDtypeStruct((qn, c), q_dense.dtype),
        interpret=interpret,
    )(*args)
