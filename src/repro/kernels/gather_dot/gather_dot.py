"""Pallas TPU kernel: natively query-batched sparse·dense inner
products (Seismic phase S, Alg. 2 line 9).

For a whole query batch and its per-query candidate tiles in padded-CSR
layout, computes

    scores[q, n] = sum_j q_dense[q, coords[q, n, j]] * vals[q, n, j]

in ONE kernel launch. This is the op the paper engineers around x86
cache misses with prefetch intrinsics (§5.4); the TPU analog streams
candidate tiles HBM->VMEM while the dense query tile stays
VMEM-resident across the inner grid axis.

When the forward index is compact (u8 values, ``fwd_quant=True``) the
per-doc affine dequantization ((level-1)*scale + zero, level 0 = pad)
fuses into the multiply — candidate values cross HBM as one byte each
and are never materialized as floats.

Tiling (ops.py pads Q to tile_q and N to tile_n — the row width nnz
and vocab d pass through as-is, so non-interpret Mosaic lowering
expects lane-aligned nnz/d; off-TPU coverage is interpret-mode only):
  grid = (Q / tile_q, N / tile_n)   — queries x candidate tiles
  q block       [tile_q, d]         VMEM-resident dense query tile
  coords/vals   [tile_q, tile_n, nnz]
  scale/zero    [tile_q, tile_n]    (quantized variant only)
  out           [tile_q, tile_n]

The per-row dynamic gather lowers through the TPU gather/scatter unit
on current Mosaic; interpret mode (auto-selected off-TPU by ops.py)
runs the same program on CPU for the ref.py parity tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather(q, coords):
    tq, tn, nnz = coords.shape
    return jnp.take_along_axis(
        q, coords.reshape(tq, tn * nnz), axis=1).reshape(tq, tn, nnz)


def _gather_dot_kernel(q_ref, coords_ref, vals_ref, out_ref):
    q = q_ref[...]                              # [tq, d]
    coords = coords_ref[...]                    # [tq, tn, nnz]
    vals = vals_ref[...].astype(q.dtype)
    out_ref[...] = (_gather(q, coords) * vals).sum(axis=-1)


def _gather_dot_quant_kernel(q_ref, coords_ref, vals_ref, scale_ref,
                             zero_ref, out_ref):
    q = q_ref[...]                              # [tq, d]
    coords = coords_ref[...]                    # [tq, tn, nnz]
    u8 = vals_ref[...].astype(q.dtype)          # [tq, tn, nnz]
    scale = scale_ref[...].astype(q.dtype)      # [tq, tn]
    zero = zero_ref[...].astype(q.dtype)
    deq = (u8 - 1.0) * scale[..., None] + zero[..., None]
    deq = jnp.where(u8 > 0, deq, 0.0)           # level 0 == padding
    out_ref[...] = (_gather(q, coords) * deq).sum(axis=-1)


@functools.partial(jax.jit,
                   static_argnames=("tile_q", "tile_n", "interpret"))
def gather_dot_batch_pallas(q_dense: jax.Array, coords: jax.Array,
                            vals: jax.Array, scale: jax.Array | None = None,
                            zero: jax.Array | None = None, *,
                            tile_q: int = 8, tile_n: int = 128,
                            interpret: bool = True) -> jax.Array:
    """scores [Q, N] = sum_j q_dense[q, coords[q, :, j]] * vals[q, :, j].

    Q must be a multiple of tile_q and N of tile_n (ops.py pads). With
    (scale, zero) given, vals is u8 and dequant fuses into the dot.
    """
    qn, n, nnz = coords.shape
    d = q_dense.shape[1]
    assert q_dense.shape[0] == qn and qn % tile_q == 0 and n % tile_n == 0, (
        q_dense.shape, coords.shape, tile_q, tile_n)
    grid = (qn // tile_q, n // tile_n)
    q_spec = pl.BlockSpec((tile_q, d), lambda i, j: (i, 0))
    row_spec = pl.BlockSpec((tile_q, tile_n, nnz), lambda i, j: (i, j, 0))
    sz_spec = pl.BlockSpec((tile_q, tile_n), lambda i, j: (i, j))
    quant = scale is not None
    kernel = _gather_dot_quant_kernel if quant else _gather_dot_kernel
    in_specs = [q_spec, row_spec, row_spec] + ([sz_spec, sz_spec] if quant
                                               else [])
    args = (q_dense, coords, vals) + ((scale, zero) if quant else ())
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=sz_spec,
        out_shape=jax.ShapeDtypeStruct((qn, n), q_dense.dtype),
        interpret=interpret,
    )(*args)


def gather_dot_pallas(q_dense: jax.Array, coords: jax.Array,
                      vals: jax.Array, *, tile_n: int = 128,
                      interpret: bool = True) -> jax.Array:
    """Single-query compatibility shim: scores [N] via the batched
    kernel with Q=1 (kept for callers/tests of the pre-batch API).
    N must be a multiple of tile_n (ops.py pads)."""
    from repro.kernels.gather_dot.ops import _pad_batch_call
    return _pad_batch_call(q_dense[None], coords[None], vals[None],
                           None, None, tile_n=tile_n, interpret=interpret)[0]
