"""Pallas TPU kernel: batched sparse·dense inner products.

The forward-index scoring hot-spot of Seismic (Alg. 2 line 9): for a
tile of candidate documents in padded-CSR layout, compute

    scores[n] = sum_j q_dense[coords[n, j]] * vals[n, j]

This is the op the paper engineers around x86 cache misses with
prefetch intrinsics (§5.4); the TPU analog is streaming candidate
tiles HBM->VMEM while the dense query stays VMEM-resident.

Tiling:
  grid  = (ceil(N / tile_n),)
  coords/vals blocks: [tile_n, nnz]   (one VMEM tile per grid step)
  q: full [d] in VMEM (d*4B <= ~1 MiB for a 30522-term SPLADE
     vocabulary after fp32; vocab chunking in ops.py keeps larger
     vocabularies under the cap)
  out block: [tile_n]

The per-lane dynamic gather ``q[coords_tile]`` lowers through the TPU
gather/scatter unit on current Mosaic; the documented fallback for
lowerings that reject it is a one-hot contraction per 128-wide
coordinate chunk (same math, MXU-friendly). Kernel semantics are
validated in interpret mode against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_dot_kernel(q_ref, coords_ref, vals_ref, out_ref):
    q = q_ref[...]                      # [d] resident
    coords = coords_ref[...]            # [tile_n, nnz] int32
    vals = vals_ref[...]                # [tile_n, nnz]
    gathered = jnp.take(q, coords, axis=0)      # per-lane gather
    out_ref[...] = (gathered * vals.astype(q.dtype)).sum(axis=-1)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def gather_dot_pallas(q_dense: jax.Array, coords: jax.Array,
                      vals: jax.Array, *, tile_n: int = 128,
                      interpret: bool = True) -> jax.Array:
    """scores [N] = sum_j q_dense[coords[:, j]] * vals[:, j].

    N must be a multiple of tile_n (ops.py pads).
    """
    n, nnz = coords.shape
    d = q_dense.shape[0]
    assert n % tile_n == 0, (n, tile_n)
    grid = (n // tile_n,)
    return pl.pallas_call(
        _gather_dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),            # q: whole vector
            pl.BlockSpec((tile_n, nnz), lambda i: (i, 0)),  # coords tile
            pl.BlockSpec((tile_n, nnz), lambda i: (i, 0)),  # vals tile
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), q_dense.dtype),
        interpret=interpret,
    )(q_dense, coords, vals)
