"""Shared kernel runtime policy: one place that decides interpret mode.

Every public kernel wrapper historically made its own call — some
hardcoded ``interpret=True``, others probed the backend — so moving a
caller between wrappers could silently change whether the Mosaic
lowering ran. All wrappers now resolve through
:func:`default_interpret`: ``None`` means auto-select (interpret
everywhere except a real TPU backend), an explicit bool overrides (the
microbench uses this to force-interpret on device for parity checks).
"""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    """True when the default jax backend is a real TPU."""
    return jax.default_backend() == "tpu"


def default_interpret(interpret: bool | None = None) -> bool:
    """Resolve an ``interpret`` knob: ``None`` -> auto (not on TPU)."""
    return not on_tpu() if interpret is None else bool(interpret)


__all__ = ["on_tpu", "default_interpret"]
