"""Public wrapper: GQA-aware flash attention with padding + head fold."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    sm_scale: float | None = None, causal: bool = True,
                    window: int | None = None,
                    tile_q: int = 128, tile_k: int = 128) -> jax.Array:
    """q [B, Hq, Sq, D], k/v [B, Hkv, Sk, D] (Hq % Hkv == 0) -> q-shaped.

    Pads Sq/Sk to tile multiples, folds (B, H) into the kernel batch,
    expands kv heads for GQA (a production kernel indexes instead).
    """
    b, hq, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    if sm_scale is None:
        sm_scale = dh ** -0.5
    group = hq // hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    pad_q = (-sq) % tile_q
    pad_k = (-sk) % tile_k
    qf = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0))).reshape(
        b * hq, sq + pad_q, dh)
    kf = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0))).reshape(
        b * hq, sk + pad_k, dh)
    vf = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0))).reshape(
        b * hq, sk + pad_k, dh)
    out = flash_attention_pallas(
        qf, kf, vf, sm_scale=sm_scale, causal=causal, window=window,
        kv_len=sk, tile_q=tile_q, tile_k=tile_k, interpret=not _on_tpu())
    return out[:, :sq].reshape(b, hq, sq, dh)


__all__ = ["flash_attention", "attention_ref"]
