"""Pure-jnp oracle for the flash_attention kernel."""
import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  sm_scale: float, causal: bool = True,
                  window: int | None = None,
                  kv_len: int | None = None) -> jax.Array:
    """q [BH, Sq, D], k/v [BH, Sk, D] -> [BH, Sq, D]; full softmax."""
    sq, sk = q.shape[1], k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if kv_len is not None:
        mask &= k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows produce uniform p; zero them for parity
    any_valid = mask.any(-1)
    p = jnp.where(any_valid[None, :, None], p, 0.0)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
