"""Pallas TPU kernel: block-tiled softmax attention (FlashAttention-style).

Beyond-paper performance layer for the assigned LM architectures: the
prefill/train attention hot-spot, tiled for VMEM with the online-softmax
recurrence so the [S, S] score matrix never materializes in HBM.

Grid = (batch*heads, n_q_tiles, n_k_tiles), k innermost; running
(m, l, acc) state lives in VMEM scratch across the k sweep. MXU-aligned
tiles (128 defaults). Supports causal masking and sliding-window
(Gemma-style local) attention; kv-length masking covers padded keys.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               sm_scale, causal, window, kv_len, tile_q, tile_k, n_k):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # [tq, d]
    k = k_ref[0].astype(jnp.float32)            # [tk, d]
    v = v_ref[0].astype(jnp.float32)            # [tk, d]
    s = (q @ k.T) * sm_scale                    # [tq, tk]

    q_pos = qi * tile_q + jax.lax.broadcasted_iota(jnp.int32, (tile_q, tile_k), 0)
    k_pos = kj * tile_k + jax.lax.broadcasted_iota(jnp.int32, (tile_q, tile_k), 1)
    mask = k_pos < kv_len                       # padded keys
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)         # fully-masked rows -> 0
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "sm_scale", "causal", "window", "kv_len", "tile_q", "tile_k",
    "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           sm_scale: float, causal: bool = True,
                           window: int | None = None, kv_len: int,
                           tile_q: int = 128, tile_k: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q [BH, Sq, D], k/v [BH, Sk, D] -> out [BH, Sq, D].

    Sq % tile_q == 0 and Sk % tile_k == 0 (ops.py pads); ``kv_len``
    masks padded key positions.
    """
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % tile_q == 0 and sk % tile_k == 0
    n_q, n_k = sq // tile_q, sk // tile_k
    kernel = functools.partial(
        _fa_kernel, sm_scale=sm_scale, causal=causal, window=window,
        kv_len=kv_len, tile_q=tile_q, tile_k=tile_k, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, tile_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tile_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, tile_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_q,), jnp.float32),       # running max
            pltpu.VMEM((tile_q,), jnp.float32),       # running denom
            pltpu.VMEM((tile_q, d), jnp.float32),     # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
