"""Pallas kernel: one fused refine round (Seismic + kNN-graph stage 6).

The staged refine round materializes three HBM intermediates per
round: the [Q, k*degree] neighbor expansion, its sorted/deduped copy,
and the [Q, C, nnz] gathered forward rows for rescoring. This kernel
runs neighbor expand -> sort-based dedupe -> seen-mask -> candidate
compaction -> forward gather -> exact dot in ONE launch; only the
round's results (cand [Q, C], scores [Q, C]) leave VMEM.

Math is op-for-op identical to the unfused round (graph.refine +
scorer.dedupe_batch + scorer.score_candidates), with compaction
(fuse_level >= 1 packs live candidates to a prefix) applied in-kernel,
so the merged top-k is bit-exact across fuse levels — parity tests pin
it.

Coverage boundary (see src/repro/kernels/README.md): graph and forward
planes ride in whole-array blocks — exact under interpret mode (CPU
CI); Mosaic needs them VMEM-resident or an ANY-space DMA variant, plus
in-kernel sort support. Real-TPU validation is the ROADMAP follow-on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -jnp.inf


def _refine_round_kernel(ids_ref, scored_ref, q_ref, knn_ref, fwdc_ref,
                         fwdv_ref, *rest, n_docs, degree, quant):
    if quant:
        fs_ref, fz_ref, cand_ref, out_ref = rest
    else:
        cand_ref, out_ref = rest
    ids = ids_ref[...]                          # [tq, k]
    scored = scored_ref[...]                    # [tq, W]
    q = q_ref[...]                              # [tq, d]
    tq, k = ids.shape
    # ---- expand: graph neighbors of the current top-k
    safe = jnp.clip(ids, 0, n_docs - 1)
    nbrs = jnp.take(knn_ref[...], safe, axis=0,
                    mode="clip")[..., :degree]  # [tq, k, deg]
    nbrs = jnp.where(ids[..., None] >= 0, nbrs, n_docs)
    cand = nbrs.reshape(tq, k * degree).astype(jnp.int32)
    # ---- dedupe within the expansion (sort + neighbor mask)
    s = jnp.sort(cand, axis=-1)
    dup = jnp.concatenate(
        [jnp.zeros((tq, 1), bool), s[:, 1:] == s[:, :-1]], axis=1)
    cand = jnp.where(dup, n_docs, s)
    # ---- mask ids scored in any earlier round / the original merge
    seen = (cand[:, :, None] == scored[:, None, :]).any(-1)
    cand = jnp.where(seen, n_docs, cand)
    # ---- compaction: pack the live frontier to a sorted prefix
    cand = jnp.sort(cand, axis=-1)
    # ---- exact rescore through the scorer's forward plane
    c = jnp.take(fwdc_ref[...], cand, axis=0,
                 mode="clip").astype(jnp.int32)             # [tq, C, nnz]
    v = jnp.take(fwdv_ref[...], cand, axis=0, mode="clip")
    nnz = c.shape[-1]
    gathered = jnp.take_along_axis(
        q, c.reshape(tq, -1), axis=1).reshape(tq, k * degree, nnz)
    if quant:
        u8 = v.astype(q.dtype)
        sc = jnp.take(fs_ref[...], cand, mode="clip").astype(q.dtype)
        zc = jnp.take(fz_ref[...], cand, mode="clip").astype(q.dtype)
        deq = (u8 - 1.0) * sc[..., None] + zc[..., None]
        v = jnp.where(u8 > 0, deq, 0.0)         # level 0 == padding
    else:
        v = v.astype(q.dtype)
    scores = (gathered * v).sum(axis=-1)
    cand_ref[...] = cand
    out_ref[...] = jnp.where(cand < n_docs, scores, NEG)


@functools.partial(jax.jit, static_argnames=("n_docs", "degree", "tile_q",
                                             "interpret"))
def refine_round_pallas(ids: jax.Array, scored: jax.Array,
                        q_dense: jax.Array, knn_ids: jax.Array,
                        fwd_coords: jax.Array, fwd_vals: jax.Array,
                        fwd_scale: jax.Array | None = None,
                        fwd_zero: jax.Array | None = None, *,
                        n_docs: int, degree: int, tile_q: int = 8,
                        interpret: bool = True
                        ) -> tuple[jax.Array, jax.Array]:
    """One fused refine round.

    ids [Q, k] (-1 padding), scored [Q, W] (sentinel-padded already-
    scored ids) -> (cand [Q, k*degree] packed live-prefix frontier,
    scores [Q, k*degree] with sentinels at -inf). Q % tile_q == 0
    (ops.py pads).
    """
    qn, k = ids.shape
    w = scored.shape[1]
    d = q_dense.shape[1]
    n, nnz = fwd_coords.shape
    assert q_dense.shape[0] == qn and qn % tile_q == 0, (
        q_dense.shape, ids.shape, tile_q)
    assert 0 < degree <= knn_ids.shape[1], (degree, knn_ids.shape)
    grid = (qn // tile_q,)
    c_out = k * degree
    quant = fwd_scale is not None
    plane2 = lambda a, b: pl.BlockSpec((a, b), lambda i: (0, 0))  # noqa: E731
    in_specs = [
        pl.BlockSpec((tile_q, k), lambda i: (i, 0)),
        pl.BlockSpec((tile_q, w), lambda i: (i, 0)),
        pl.BlockSpec((tile_q, d), lambda i: (i, 0)),
        plane2(n, knn_ids.shape[1]),
        plane2(n, nnz), plane2(n, nnz),
    ]
    args = [ids, scored, q_dense, knn_ids, fwd_coords, fwd_vals]
    if quant:
        in_specs += [pl.BlockSpec((n,), lambda i: (0,))] * 2
        args += [fwd_scale, fwd_zero]
    out_spec = pl.BlockSpec((tile_q, c_out), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_refine_round_kernel, n_docs=n_docs,
                          degree=degree, quant=quant),
        grid=grid,
        in_specs=in_specs,
        out_specs=(out_spec, out_spec),
        out_shape=(
            jax.ShapeDtypeStruct((qn, c_out), jnp.int32),
            jax.ShapeDtypeStruct((qn, c_out), q_dense.dtype),
        ),
        interpret=interpret,
    )(*args)
