from repro.kernels.refine_fused.ops import refine_round_batch

__all__ = ["refine_round_batch"]
