"""Public wrapper for the fused refine round: pick tile_q from the
VMEM model (the frontier axis lives inside the kernel), pad Q, launch,
slice back. Interpret mode resolves through the shared runtime helper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.refine_fused.refine_fused import refine_round_pallas
from repro.kernels.runtime import default_interpret
from repro.kernels.tiling import choose_tile_q, gather_row_bytes


def _plane_bytes(*arrays) -> int:
    return sum(int(a.size) * a.dtype.itemsize for a in arrays)


def refine_round_batch(ids: jax.Array, scored: jax.Array,
                       q_dense: jax.Array, knn_ids: jax.Array,
                       fwd_coords: jax.Array, fwd_vals: jax.Array,
                       fwd_scale: jax.Array | None = None,
                       fwd_zero: jax.Array | None = None, *,
                       n_docs: int, degree: int,
                       tile_q: int | None = None,
                       interpret: bool | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """One fused refine round (expand + dedupe + seen-mask + compact +
    rescore): ids [Q, k] (-1 pad) x scored [Q, W] -> (cand [Q, k*degree]
    live-prefix frontier, scores [Q, k*degree], sentinels at -inf)."""
    interpret = default_interpret(interpret)
    qn, k = ids.shape
    nnz = fwd_coords.shape[1]
    quant = fwd_scale is not None
    planes = [knn_ids, fwd_coords, fwd_vals]
    if quant:
        planes += [fwd_scale, fwd_zero]
    if tile_q is None:
        c = k * degree
        # per query row: dense query + ids/scored tiles + the expanded
        # frontier's gathered rows + both outputs
        per_q = (4 * q_dense.shape[1] + 4 * (k + scored.shape[1])
                 + c * (gather_row_bytes(nnz, quant=quant) + 4 * nnz + 16))
        tile_q = choose_tile_q(qn, fixed_bytes=_plane_bytes(*planes),
                               per_query_bytes=per_q)
    pq = (-qn) % tile_q
    if pq:
        ids = jnp.pad(ids, ((0, pq), (0, 0)), constant_values=-1)
        scored = jnp.pad(scored, ((0, pq), (0, 0)), constant_values=n_docs)
        q_dense = jnp.pad(q_dense, ((0, pq), (0, 0)))
    cand, scores = refine_round_pallas(
        ids, scored, q_dense, knn_ids, fwd_coords, fwd_vals,
        fwd_scale, fwd_zero, n_docs=n_docs, degree=degree,
        tile_q=tile_q, interpret=interpret)
    return cand[:qn], scores[:qn]


__all__ = ["refine_round_batch"]
