"""Synchronous serving facades (thin; the serving *system* lives in the
sibling modules of ``repro.serve``).

``LMDecoder``       — KV-cache decode loop around decode_step (greedy or
                      temperature sampling) with batched requests.
``SeismicServer``   — offline-batch retrieval: pads a whole request
                      batch to a fixed size and chunks it through the
                      jitted pipeline. Kept for back-compat and bulk
                      jobs; online traffic should use
                      ``repro.serve.batcher.AsyncSeismicServer``, which
                      micro-batches in-flight queries instead of
                      padding each call.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import TransformerConfig
from repro.core.types import SeismicIndex
from repro.retrieval import SearchParams, search_pipeline
from repro.models.transformer import lm
from repro.serve.telemetry import ServerTelemetry
from repro.sparse.ops import PaddedSparse


class LMDecoder:
    def __init__(self, params, cfg: TransformerConfig, batch: int,
                 max_seq: int):
        self.params = params
        self.cfg = cfg
        self.max_seq = max_seq
        self.cache = lm.init_cache(cfg, batch, max_seq)
        self._step = jax.jit(
            lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg))

    def generate(self, prompts: np.ndarray, n_steps: int, *,
                 greedy: bool = True, seed: int = 0) -> np.ndarray:
        """prompts [B, P] int32 -> tokens [B, P + n_steps]."""
        b, plen = prompts.shape
        key = jax.random.PRNGKey(seed)
        toks = [prompts[:, i] for i in range(plen)]
        # prefill by stepping (keeps one compiled program)
        logits = None
        for i in range(plen):
            logits, self.cache = self._step(
                self.params, self.cache,
                jnp.asarray(toks[i][:, None], jnp.int32),
                jnp.asarray(i, jnp.int32))
        for j in range(n_steps):
            if greedy:
                nxt = jnp.argmax(logits, axis=-1)
            else:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits)
            toks.append(np.asarray(nxt, np.int32))
            logits, self.cache = self._step(
                self.params, self.cache,
                jnp.asarray(nxt[:, None], jnp.int32),
                jnp.asarray(plen + j, jnp.int32))
        return np.stack(toks, axis=1)


@dataclasses.dataclass
class RetrievalResult:
    ids: np.ndarray
    scores: np.ndarray
    docs_evaluated: np.ndarray


class SeismicServer:
    """Fixed-batch jitted retrieval front-end over the shared staged
    pipeline (repro.retrieval): pads request batches to ``max_batch``
    so the jitted pipeline never recompiles."""

    def __init__(self, index: SeismicIndex, params: SearchParams,
                 max_batch: int = 256, *,
                 telemetry: ServerTelemetry | None = None, obs=None,
                 auditor=None):
        from repro.graph.refine import validate_refine_params
        from repro.tune.policy import validate_tuned_index
        validate_refine_params(index, params)   # fail before first launch
        validate_tuned_index(index)             # stale TunedPolicy -> now
        self.index = index
        self.params = params
        self.max_batch = max_batch
        if telemetry is None and obs is not None:
            telemetry = ServerTelemetry(registry=obs.registry)
        self.telemetry = telemetry
        self.obs = obs
        self.auditor = auditor if auditor is not None \
            else getattr(obs, "auditor", None)
        self._fns = None
        self._device = None
        need_staged = self.auditor is not None or (
            obs is not None and obs.stage_sample_every > 0)
        if need_staged:
            from repro.retrieval.pipeline import stage_fns
            self._fns = stage_fns(index, params)
        if obs is not None and obs.stage_sample_every > 0:
            from repro.obs.device import DeviceAccounting
            self._device = DeviceAccounting(index, params,
                                            self.telemetry.registry)
        self._launch_seq = 0
        # serving generation; bumped on every swap_index (no result
        # cache here, but callers key their own memoization on it)
        self.epoch = 0
        if self.telemetry is not None:
            self.telemetry.registry.gauge(
                "seismic_index_epoch",
                "Generation of the index being served (bumped on "
                "every swap_index / mutation publish)").labels() \
                .set_fn(lambda: self.epoch)

    def swap_index(self, index: SeismicIndex,
                   params: SearchParams | None = None) -> int:
        """Publish a new index (and optionally new params); returns the
        new serving epoch. The facade is synchronous — callers serialize
        ``search``/``swap_index`` themselves — so the swap is a plain
        field update plus revalidation and staged-fns rebuild."""
        from repro.graph.refine import validate_refine_params
        from repro.tune.policy import validate_tuned_index
        params = self.params if params is None else params
        validate_refine_params(index, params)
        validate_tuned_index(index)
        if self._fns is not None:
            from repro.retrieval.pipeline import stage_fns
            self._fns = stage_fns(index, params)
        if self._device is not None:
            from repro.obs.device import DeviceAccounting
            self._device = DeviceAccounting(index, params,
                                            self.telemetry.registry)
        self.index = index
        self.params = params
        self.epoch += 1
        return self.epoch

    def apply_mutation(self, mutable, mutate_fn=None) -> int:
        """Optionally run ``mutate_fn(mutable)`` (inserts / deletes /
        compaction on a ``repro.core.mutate.MutableSeismicIndex``),
        then publish its current snapshot via :meth:`swap_index`."""
        if mutate_fn is not None:
            mutate_fn(mutable)
        return self.swap_index(mutable.index)

    def _search_staged(self, chunk: PaddedSparse, n_real: int,
                       audit_rows: tuple[int, ...] = ()):
        """One sampled (or audited) chunk through the staged pipeline:
        emits a ``launch`` trace with per-stage (and per-refine-round)
        child spans, feeds device accounting, and feeds the shadow
        auditor the planned rows. Bit-exact with the fused path."""
        from repro.retrieval.pipeline import run_pipeline_staged
        from repro.serve.batcher import attach_stage_spans
        tracer = self.obs.tracer if self.obs is not None else None
        triples: list[tuple[str, float, float]] = []
        probed: dict[str, object] = {}
        tel = self.telemetry
        t0 = time.monotonic()
        out = run_pipeline_staged(
            self.index, chunk.coords, chunk.vals, self.params,
            fns=self._fns,
            record=(lambda s, dt: tel.record_latency(f"stage_{s}", dt))
            if tel is not None else None,
            span_cb=lambda name, a, b: triples.append((name, a, b)),
            split_refine=True, probe=probed.__setitem__,
            audit=bool(audit_rows))
        t1 = time.monotonic()
        a_span = None
        if audit_rows:
            coords = np.asarray(chunk.coords)
            vals = np.asarray(chunk.vals)
            ids = np.asarray(out[1])
            a0 = time.monotonic()
            for i in audit_rows:
                self.auditor.feed(coords[i], vals[i], ids[i],
                                  captures=probed, row=i)
            a_span = (a0, time.monotonic())
        if tracer is not None:
            tr = tracer.start_trace("launch", t0,
                                    width=chunk.coords.shape[0],
                                    occupancy=n_real, sync=True)
            attach_stage_spans(tracer, tr, tr.root, triples)
            if a_span is not None:
                tracer.add_span(tr, "audit", a_span[0], a_span[1])
            tracer.end_trace(tr, a_span[1] if a_span is not None else t1,
                             status="done")
        if self._device is not None:
            stage_seconds = {name: b - a for name, a, b in triples}
            self._device.observe(stage_seconds, chunk.coords.shape[0],
                                 cand=probed.get("cand"))
        return out, t1 - t0

    def search(self, queries: PaddedSparse) -> RetrievalResult:
        q = queries
        n = q.coords.shape[0]
        if n == 0:
            return RetrievalResult(
                ids=np.zeros((0, self.params.k), np.int32),
                scores=np.zeros((0, self.params.k), np.float32),
                docs_evaluated=np.zeros((0,), np.int32))
        pad = (-n) % self.max_batch
        if pad:
            coords = jnp.pad(q.coords, ((0, pad), (0, 0)))
            vals = jnp.pad(q.vals, ((0, pad), (0, 0)))
            q = PaddedSparse(coords, vals, q.dim)
        outs = []
        for s in range(0, q.coords.shape[0], self.max_batch):
            chunk = PaddedSparse(q.coords[s:s + self.max_batch],
                                 q.vals[s:s + self.max_batch], q.dim)
            seq = self._launch_seq
            self._launch_seq += 1
            n_chunk = min(self.max_batch, n - s)
            audit_rows = self.auditor.plan(n_chunk) \
                if self.auditor is not None else ()
            sampled = (self.obs is not None
                       and self.obs.sample_stages(seq))
            if self._fns is not None and (sampled or audit_rows):
                out, dt = self._search_staged(chunk, n_chunk,
                                              audit_rows)
                if self.telemetry is not None:
                    self.telemetry.record_latency("launch", dt)
                    self.telemetry.inc("batches")
                    self.telemetry.observe_occupancy(
                        min(self.max_batch, n - s))
                outs.append(out)
                continue
            if self.telemetry is None:      # async dispatch, convert at end
                outs.append(search_pipeline(self.index, chunk, self.params))
                continue
            t0 = time.perf_counter()
            out = jax.block_until_ready(
                search_pipeline(self.index, chunk, self.params))
            self.telemetry.record_latency(
                "launch", time.perf_counter() - t0)
            self.telemetry.inc("batches")
            self.telemetry.observe_occupancy(min(self.max_batch, n - s))
            outs.append(out)
        scores = np.concatenate([np.asarray(o[0]) for o in outs])[:n]
        ids = np.concatenate([np.asarray(o[1]) for o in outs])[:n]
        ev = np.concatenate([np.asarray(o[2]) for o in outs])[:n]
        if self.telemetry is not None:
            self.telemetry.inc("requests", n)
            self.telemetry.inc("served", n)
        return RetrievalResult(ids=ids, scores=scores, docs_evaluated=ev)
