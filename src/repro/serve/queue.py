"""Thread-safe bounded request queue with per-request dispatch
deadlines — the admission-control front door of the async server.

A request's ``deadline`` is the absolute monotonic time by which it
must be *dispatched* (included in a pipeline launch); the micro-batcher
blocks in ``next_batch`` until either ``max_batch`` requests are
waiting or the earliest deadline in the queue expires, whichever comes
first. Backpressure when the queue is at ``bound``:

  ``reject``       refuse the new request (caller fails its future)
  ``shed_oldest``  drop the oldest queued request to admit the new one
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

import numpy as np

ADMISSION_POLICIES = ("reject", "shed_oldest")


class ServeFuture:
    """Completion handle for one submitted query.

    ``status`` is one of ``pending`` / ``done`` / ``shed`` /
    ``rejected`` / ``error: ...``; ``result`` blocks and raises unless
    the request finished ``done``.
    """

    def __init__(self):
        self._event = threading.Event()
        self._lock = threading.Lock()
        self._result = None
        self.status = "pending"

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: float | None = None) -> bool:
        return self._event.wait(timeout)

    def result(self, timeout: float | None = None):
        if not self._event.wait(timeout):
            raise TimeoutError("request still pending")
        if self.status != "done":
            raise RuntimeError(f"request not served: {self.status}")
        return self._result

    # Completion is first-writer-wins: once the event is set, the
    # (status, result) pair is immutable. A launch that raises AFTER
    # fulfilling part of its batch must not flip already-``done``
    # futures to ``error`` (their result may already be consumed), and
    # a racing shed/fail must not clobber a concurrent fulfil. Both
    # return whether THIS call won the transition, so callers only
    # emit completion side effects (trace end, counters) once.

    def _set(self, result) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            self._result = result
            self.status = "done"
            self._event.set()
            return True

    def _fail(self, status: str) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            self.status = status
            self._event.set()
            return True


@dataclasses.dataclass
class Request:
    """One queued query (already normalized to the server's nnz width)."""

    coords: np.ndarray          # int32 [nnz]
    vals: np.ndarray            # float32 [nnz]
    submit_t: float             # monotonic enqueue time
    deadline: float             # absolute monotonic dispatch deadline
    future: ServeFuture
    cache_key: bytes | None = None
    # per-request trace (repro.obs.trace.Trace) minted at submit when
    # the server carries an Observability bundle; rides the queue so
    # the batcher can close the span tree at fulfil time
    trace: object | None = None
    # in-flight coalescing: (future, submit_t, trace) of identical-
    # fingerprint requests submitted while this one was
    # queued/executing — fulfilled from this request's launch slot
    # with their OWN submit times, so per-request latency stays honest
    # (appended only under the batcher's coalesce lock)
    followers: list[tuple[ServeFuture, float, object | None]] = \
        dataclasses.field(default_factory=list)


class RequestQueue:
    """FIFO queue with deadline-aware blocking batch extraction."""

    def __init__(self, bound: int = 1024, policy: str = "reject"):
        if policy not in ADMISSION_POLICIES:
            raise ValueError(f"unknown admission policy {policy!r}; "
                             f"choose from {ADMISSION_POLICIES}")
        self.bound = bound
        self.policy = policy
        self._q: deque[Request] = deque()
        self._cond = threading.Condition()
        self._closed = False
        self._min_deadline = float("inf")   # running min over self._q

    @property
    def depth(self) -> int:
        with self._cond:
            return len(self._q)

    @property
    def closed(self) -> bool:
        with self._cond:
            return self._closed

    def put(self, req: Request) -> tuple[str, Request | None]:
        """Admit a request. Returns (status, shed_request) with status
        ``ok`` | ``rejected`` (backpressure) | ``closed`` (shutdown)."""
        with self._cond:
            if self._closed:
                return "closed", None
            shed = None
            if len(self._q) >= self.bound:
                if self.policy == "reject":
                    return "rejected", None
                shed = self._q.popleft()
                if shed.deadline <= self._min_deadline:
                    self._recompute_min()
            self._q.append(req)
            self._min_deadline = min(self._min_deadline, req.deadline)
            self._cond.notify_all()
        return "ok", shed

    def next_batch(self, max_n: int,
                   now_fn=time.monotonic) -> list[Request] | None:
        """Block until a batch is due; None once closed and drained.

        A batch is due when ``max_n`` requests are queued, the earliest
        queued deadline has expired, or the queue was closed (drain
        immediately, don't make shutdown wait out deadlines).
        """
        with self._cond:
            while True:
                if self._q:
                    if self._closed or len(self._q) >= max_n:
                        return self._pop(max_n)
                    now = now_fn()
                    if now >= self._min_deadline:
                        return self._pop(max_n)
                    self._cond.wait(self._min_deadline - now)
                elif self._closed:
                    return None
                else:
                    self._cond.wait()

    def _pop(self, max_n: int) -> list[Request]:
        out = [self._q.popleft()
               for _ in range(min(len(self._q), max_n))]
        self._recompute_min()
        return out

    def _recompute_min(self) -> None:
        # O(len) but only on pop/shed, not on every wakeup
        self._min_deadline = min((r.deadline for r in self._q),
                                 default=float("inf"))

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()
