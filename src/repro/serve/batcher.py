"""Deadline-based micro-batching front-end over the staged pipeline.

``AsyncSeismicServer`` accepts single queries (``submit``) from any
thread and coalesces whatever is in flight into fixed-shape
``[width, query_nnz]`` launches of the jitted ``search_pipeline``
(dispatch on batch-full OR oldest-deadline-expiry, never recompiling).
Launch widths come from a pre-compiled LADDER (default ``8/32/128``
clipped to ``max_batch``): each dispatch picks the smallest compiled
width covering the coalesced batch, so a lone tail request stops
paying the full ``max_batch`` of padded pipeline work. Every width is
compiled at warmup; per-width dispatch counts land in telemetry
(``launch_width_<w>``). The server then fulfills per-request futures. Around that core sit admission
control (bounded queue, ``reject`` / ``shed_oldest``), a quantized-
fingerprint LRU result cache, request coalescing (concurrently
in-flight requests with identical quantized fingerprints share one
launch slot — the LRU only catches repeats *after* the first
completes), and telemetry (per-stage latency when ``stage_timing`` is
on, queue depth, batch occupancy, cache hit-rate).

Observability (``obs=Observability.create()``): a trace id is minted
at ``submit`` and every request produces a span tree — ``request``
root, ``queue_wait`` and ``launch`` children, and (on every
``stage_sample_every``-th launch) the six ``stage_*`` children plus
per-``refine_round_<j>`` grandchildren, recorded into the tracer's
ring buffer and exportable as Chrome trace-event JSON. The registry
gains serving gauges (cache hit-rate, shed/reject rate, deadline-miss
rate, per-width occupancy, tuned-policy drift) and, via
:class:`repro.obs.device.DeviceAccounting`, achieved-vs-modeled HBM
bytes per stage per fuse level on every sampled (staged) launch. See
``src/repro/obs/README.md`` for the span model and metric names.

The synchronous ``SeismicServer`` facade in ``engine`` remains the
simple offline-batch path; this class is the serving path every
future scaling layer (sharded serving, replication) plugs into.
"""
from __future__ import annotations

import dataclasses
import struct
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.types import SeismicIndex
from repro.graph.refine import validate_refine_params
from repro.retrieval import SearchParams, search_pipeline
from repro.retrieval.pipeline import run_pipeline_staged, stage_fns
from repro.serve.cache import LRUCache, fingerprint_candidates
from repro.serve.queue import Request, RequestQueue, ServeFuture
from repro.serve.telemetry import ServerTelemetry
from repro.sparse.ops import PaddedSparse


@dataclasses.dataclass
class ServeResult:
    """Per-request retrieval result with serving metadata."""

    ids: np.ndarray            # int32 [k], -1 padding
    scores: np.ndarray         # f32 [k]
    docs_evaluated: int
    cached: bool = False
    coalesced: bool = False    # fulfilled from another request's slot
    latency_s: float = 0.0     # submit -> fulfil wall time
    occupancy: int = 0         # real queries in the serving launch


def attach_stage_spans(tracer, trace, parent, triples) -> None:
    """Turn ``run_pipeline_staged`` span triples ``(name, t0, t1)``
    into child spans of ``parent``: ``stage_<name>`` for the six
    stages, with ``refine_round_<j>`` entries nested under the
    ``stage_refine`` span."""
    rounds = [t for t in triples if t[0].startswith("refine_round_")]
    refine_span = None
    for name, a, b in triples:
        if name.startswith("refine_round_"):
            continue
        sp = tracer.add_span(trace, f"stage_{name}", a, b, parent=parent)
        if name == "refine":
            refine_span = sp
    for name, a, b in rounds:
        tracer.add_span(trace, name, a, b,
                        parent=refine_span if refine_span is not None
                        else parent)


class AsyncSeismicServer:
    """Micro-batching async retrieval server over one Seismic index.

    Parameters
    ----------
    max_batch     maximum launch width; a dispatch never carries more
                  than this many distinct requests.
    launch_widths ascending pre-compiled launch widths (the ladder).
                  ``None`` selects the default rungs ``(8, 32, 128)``
                  clipped to ``max_batch`` (which is always the top
                  rung). Each dispatch pads to the smallest rung
                  covering the batch instead of always ``max_batch``.
    query_nnz     fixed per-query nnz width; longer queries keep their
                  ``query_nnz`` heaviest coordinates.
    deadline_s    default max time a request may wait for co-batching
                  before a (possibly partial) launch is forced.
    queue_bound   admission limit; beyond it ``admission`` applies
                  ("reject" new requests or "shed_oldest" queued ones).
    cache_size    LRU entries keyed on quantized query fingerprints;
                  0 disables caching.
    coalesce      share one launch slot among concurrently in-flight
                  requests with identical quantized fingerprints (the
                  LRU cache only catches repeats after the first
                  completes; this catches the simultaneous burst).
    stage_timing  serve EVERY launch through the stage-by-stage
                  pipeline and record ``stage_*`` latency histograms
                  (slightly slower than the fused launch; with ``obs``
                  attached prefer its sampled stage tracing instead).
    obs           an ``repro.obs.Observability`` bundle: enables
                  request tracing, the serving gauges, and sampled
                  staged launches with device accounting. When given
                  and ``telemetry`` is not, the telemetry facade
                  writes into the bundle's registry so one scrape
                  sees everything.
    auditor       a ``repro.obs.ShadowAuditor`` (defaults to
                  ``obs.auditor``): every ``audit_sample_every``-th
                  served request is copied off the hot path for
                  shadow-oracle recall auditing; audited launches run
                  the staged pipeline with funnel captures and carry
                  an ``audit`` span. The auditor's worker lifecycle is
                  the owner's (start it or its queue sheds).
    deadline_grace_s  slack before a dispatch past its deadline counts
                  as a deadline MISS (deadline-triggered dispatches
                  legitimately run a hair past it; a miss means the
                  batcher fell behind by more than this).
    """

    DEFAULT_WIDTHS = (8, 32, 128)

    def __init__(self, index: SeismicIndex, params: SearchParams, *,
                 max_batch: int = 32, query_nnz: int = 32,
                 launch_widths: tuple[int, ...] | None = None,
                 deadline_s: float = 2e-3, queue_bound: int = 1024,
                 admission: str = "reject", cache_size: int = 0,
                 coalesce: bool = True, stage_timing: bool = False,
                 telemetry: ServerTelemetry | None = None,
                 obs=None, auditor=None,
                 deadline_grace_s: float = 1e-3):
        validate_refine_params(index, params)   # fail before threads spin
        from repro.tune.policy import validate_tuned_index
        validate_tuned_index(index)             # stale TunedPolicy -> now
        self.index = index
        self.params = params
        self.max_batch = max_batch
        if launch_widths is None:
            launch_widths = tuple(w for w in self.DEFAULT_WIDTHS
                                  if w < max_batch)
        else:
            if any(w <= 0 or w > max_batch for w in launch_widths):
                raise ValueError(
                    f"launch_widths {launch_widths} must lie in "
                    f"[1, max_batch={max_batch}]")
            launch_widths = tuple(w for w in launch_widths
                                  if w < max_batch)
        # max_batch is always the top rung, so every batch has a cover
        self.launch_widths = tuple(sorted(set(launch_widths))) \
            + (max_batch,)
        self.query_nnz = query_nnz
        self.deadline_s = deadline_s
        self.deadline_grace_s = deadline_grace_s
        self.stage_timing = stage_timing
        self.obs = obs
        self.queue = RequestQueue(bound=queue_bound, policy=admission)
        self.cache = LRUCache(cache_size) if cache_size > 0 else None
        self.coalesce = coalesce
        self._inflight: dict[bytes, Request] = {}
        self._coalesce_lock = threading.Lock()
        # serving epoch: bumped on every swap_index. Baked into every
        # cache/coalesce key, so results computed against an earlier
        # index can never be served after a swap (their keys become
        # unreachable — no stale top-k survives a mutation).
        self.epoch = 0
        self._swap_lock = threading.RLock()
        if telemetry is not None:
            self.telemetry = telemetry
        else:
            self.telemetry = ServerTelemetry(
                registry=obs.registry if obs is not None else None)
        self._tracer = obs.tracer if obs is not None else None
        self.auditor = auditor if auditor is not None \
            else getattr(obs, "auditor", None)
        # an auditor needs the staged programs compiled: audited
        # launches run staged to capture the funnel's memberships
        staged_wanted = stage_timing or self.auditor is not None or (
            obs is not None and obs.stage_sample_every > 0)
        self._fns = stage_fns(index, params) if staged_wanted else None
        self._device = None
        if self._fns is not None:
            from repro.obs.device import DeviceAccounting
            self._device = DeviceAccounting(index, params,
                                            self.telemetry.registry)
        # launch counters are shared by every thread that may dispatch
        # (one worker here; N replica workers in ReplicaSeismicServer)
        self._stats_lock = threading.Lock()
        self._launch_seq = 0
        self._width_stats: dict[int, list[int]] = {}   # w -> [launches,
        self._ev_sum = 0.0                             #       slots]
        self._ev_n = 0
        self._register_gauges()
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------ observability

    def _event(self, name: str):
        """Current value of one ``seismic_events_total`` counter."""
        return self.telemetry.registry.counter(
            "seismic_events_total", labels=("event",)).labels(name).value

    def _register_gauges(self) -> None:
        """Derived serving gauges, evaluated lazily at scrape time.
        One bundle per server: sharing an Observability registry across
        servers would make the last one win these callbacks."""
        reg = self.telemetry.registry
        reg.gauge("seismic_index_epoch",
                  "Generation of the index being served (bumped on "
                  "every swap_index / mutation publish)").labels() \
            .set_fn(lambda: self.epoch)
        reg.gauge("seismic_cache_hit_rate",
                  "LRU result-cache hit rate since start").labels() \
            .set_fn(lambda: self.cache.stats()["hit_rate"]
                    if self.cache is not None else 0.0)
        reg.gauge("seismic_shed_rate",
                  "(shed + rejected) / submitted requests").labels() \
            .set_fn(lambda: (self._event("shed")
                             + self._event("rejected"))
                    / max(1, self._event("requests")))
        reg.gauge("seismic_deadline_miss_rate",
                  "dispatches later than deadline + grace / dispatched"
                  ).labels() \
            .set_fn(lambda: self._event("deadline_missed")
                    / max(1, self._event("dispatched")))
        self._width_occ = reg.gauge(
            "seismic_launch_width_occupancy",
            "Mean real-request fill fraction per compiled launch width",
            ("width",))
        self._ev_mean = reg.gauge(
            "seismic_docs_evaluated_mean",
            "Running mean docs exactly scored per served query"
            ).labels()
        from repro.tune.policy import KNOB_FIELDS
        self._tuned_match = next(
            (t for t in (getattr(self.index, "tuned", ()) or ())
             if all(getattr(t, f) == getattr(self.params, f)
                    for f in KNOB_FIELDS)), None)
        if self._tuned_match is not None:
            cost = self._tuned_match.measured_cost
            reg.gauge("seismic_tuned_drift_docs",
                      "Served mean docs_evaluated minus the attached "
                      "TunedPolicy's measured cost", ("target",)) \
                .labels(f"{self._tuned_match.target:g}") \
                .set_fn(lambda: (self._ev_sum / self._ev_n - cost)
                        if self._ev_n else 0.0)
            reg.gauge("seismic_tuned_drift_ratio",
                      "Served mean docs_evaluated over the attached "
                      "TunedPolicy's measured cost", ("target",)) \
                .labels(f"{self._tuned_match.target:g}") \
                .set_fn(lambda: (self._ev_sum / self._ev_n / cost)
                        if self._ev_n and cost else 1.0)

    # ------------------------------------------------------- lifecycle

    def start(self, warmup: bool = True) -> "AsyncSeismicServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        if self.queue.closed:
            raise RuntimeError("server was stopped; its queue is closed "
                               "— build a new AsyncSeismicServer")
        if warmup:
            self.warmup()
        self._thread = threading.Thread(target=self._worker,
                                        name="seismic-batcher",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Close admission, drain queued requests, join the worker."""
        self.queue.close()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "AsyncSeismicServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def warmup(self) -> None:
        """Compile every ladder width before serving traffic — the
        fused program always, plus the staged (and per-refine-round)
        programs when stage timing or sampled stage tracing is on."""
        self._warmup_for(self.index, self.params, self._fns)

    def _warmup_for(self, index, params, fns) -> None:
        """Warmup body against an explicit (index, params, fns) triple
        so ``swap_index`` can compile the incoming index BEFORE it is
        published (first post-swap dispatch must not stall every
        in-flight deadline behind compilation)."""
        for width in self.launch_widths:
            coords = jnp.zeros((width, self.query_nnz), jnp.int32)
            vals = jnp.zeros((width, self.query_nnz), jnp.float32)
            if not self.stage_timing:
                jax.block_until_ready(search_pipeline(
                    index, PaddedSparse(coords, vals, index.dim),
                    params))
            if fns is not None:
                jax.block_until_ready(run_pipeline_staged(
                    index, coords, vals, params,
                    fns=fns, split_refine=True))

    # ----------------------------------------------------- index swap

    def swap_index(self, index: SeismicIndex,
                   params: SearchParams | None = None, *,
                   warmup: bool = True) -> int:
        """Atomically publish a new index (and optionally new params);
        returns the new serving epoch.

        Safe against in-flight launches: the (index, fns, params)
        triple is snapshotted under ``_swap_lock`` by every dispatch,
        so a launch runs entirely against one generation — never a torn
        mix. The epoch bump makes every pre-swap cache/coalesce key
        unreachable, so results computed against the old index are
        never served again (see the stale-cache regression test).
        Requests already dispatched against the old index still
        complete and are fulfilled — their results are cached under
        old-epoch keys, i.e. dropped.

        With ``warmup`` (default) the new index is compiled at every
        ladder width before publication, off the serving path.
        """
        params = self.params if params is None else params
        validate_refine_params(index, params)
        from repro.tune.policy import validate_tuned_index
        validate_tuned_index(index)
        fns = stage_fns(index, params) if self._fns is not None else None
        device = self._device
        if fns is not None:
            from repro.obs.device import DeviceAccounting
            device = DeviceAccounting(index, params,
                                      self.telemetry.registry)
        if warmup:
            self._warmup_for(index, params, fns)
        with self._swap_lock:
            self._publish_swap(index, params, fns, device)
            epoch = self.epoch
        # re-derive gauges bound to the served pair (tuned-policy drift
        # targets, cache hit-rate closures): families are idempotent and
        # set_fn callbacks overwrite, so re-registration rebinds them
        self._register_gauges()
        self.telemetry.inc("swaps")
        return epoch

    def _publish_swap(self, index, params, fns, device) -> None:
        """Swap commit point; runs under ``_swap_lock``. Subclasses
        extend it to keep their mirrors in step (replica server)."""
        self.index = index
        self.params = params
        self._fns = fns
        self._device = device
        self.epoch += 1

    def apply_mutation(self, mutable, mutate_fn=None, *,
                       warmup: bool = True) -> int:
        """Serve a :class:`repro.core.mutate.MutableSeismicIndex`'s
        current snapshot: optionally run ``mutate_fn(mutable)`` first
        (inserts / deletes / compaction), then publish the mutated
        snapshot via :meth:`swap_index`. Returns the new serving epoch.
        """
        if mutate_fn is not None:
            mutate_fn(mutable)
        return self.swap_index(mutable.index, warmup=warmup)

    # ------------------------------------------------------ submission

    def submit(self, coords, vals,
               deadline_s: float | None = None) -> ServeFuture:
        """Enqueue one sparse query; returns its completion future.

        Cache hits fulfil immediately without touching the queue; a
        request whose fingerprint matches one already in flight
        attaches to that request's launch slot instead of occupying
        its own (``coalesce``). Rejected / shed requests get a failed
        future (``status`` set), never an exception on the submitting
        thread. With tracing on, every path ends the request's trace
        with a ``status`` attr.
        """
        tel = self.telemetry
        tel.inc("requests")
        c, v = self._normalize(coords, vals)
        now = time.monotonic()
        tr = self._tracer.start_trace("request", now) \
            if self._tracer is not None else None
        key = None
        cand_keys: list[bytes] = []
        if self.cache is not None or self.coalesce:
            # the serving epoch prefixes every cache/coalesce key: a
            # swap_index bumps it, instantly orphaning all results
            # computed against the previous index (stale-cache fix).
            # Multiple fingerprint candidates cover scale-bucket
            # boundary jitter (see serve.cache): probe all, file under
            # the primary.
            ep = struct.pack("<Q", self.epoch)
            cand_keys = [ep + fp for fp in fingerprint_candidates(c, v)]
            key = cand_keys[0]
        if self.cache is not None:
            hit = self.cache.get_any(cand_keys)   # counted by the LRU
            if hit is not None:
                fut = ServeFuture()
                ids, scores, ev = hit
                fut._set(ServeResult(ids=ids.copy(), scores=scores.copy(),
                                     docs_evaluated=ev, cached=True))
                if tr is not None:
                    self._tracer.end_trace(tr, time.monotonic(),
                                           status="done", cached=True)
                return fut
        req = Request(coords=c, vals=v, submit_t=now,
                      deadline=now + (self.deadline_s if deadline_s is None
                                      else deadline_s),
                      future=ServeFuture(), cache_key=key, trace=tr)
        # the check-attach-or-enqueue-and-register must be atomic, or
        # two racing duplicates both become primaries / a follower
        # attaches to a request whose slot already fulfilled
        with self._coalesce_lock:
            if self.coalesce:
                primary = next(
                    (p for ck in cand_keys
                     if (p := self._inflight.get(ck)) is not None), None)
                if primary is not None:
                    primary.followers.append((req.future, now, tr))
                    if tr is not None:
                        tr.root.attrs["coalesced_into"] = \
                            primary.trace.trace_id \
                            if primary.trace is not None else "untraced"
                    tel.inc("coalesced")
                    return req.future
            status, shed = self.queue.put(req)
            if status == "ok" and self.coalesce:
                self._inflight[key] = req
            if shed is not None:
                self._unregister(shed)
        if status != "ok":
            tel.inc(status)                 # "rejected" or "closed"
            req.future._fail(status)
            if tr is not None:
                self._tracer.end_trace(tr, time.monotonic(),
                                       status=status)
        elif shed is not None:
            tel.inc("shed")
            self._fail_all(shed, "shed")
        tel.observe_queue_depth(self.queue.depth)
        return req.future

    def search(self, queries: PaddedSparse,
               deadline_s: float | None = None):
        """Synchronous batch convenience: submit every row, wait all.

        Returns an ``engine.RetrievalResult`` so callers can swap the
        sync facade for the async server without changing result
        handling. Rejected/shed rows come back as -1 ids.
        """
        from repro.serve.engine import RetrievalResult
        coords = np.asarray(queries.coords)
        vals = np.asarray(queries.vals)
        futs = [self.submit(coords[i], vals[i], deadline_s)
                for i in range(coords.shape[0])]
        ids = np.full((len(futs), self.params.k), -1, np.int32)
        scores = np.full((len(futs), self.params.k), -np.inf, np.float32)
        ev = np.zeros((len(futs),), np.int32)
        for i, f in enumerate(futs):
            f.wait()
            if f.status == "done":
                r = f._result
                ids[i], scores[i], ev[i] = r.ids, r.scores, \
                    r.docs_evaluated
        return RetrievalResult(ids=ids, scores=scores, docs_evaluated=ev)

    # ---------------------------------------------------------- worker

    def _worker(self) -> None:
        while True:
            batch = self.queue.next_batch(self.max_batch)
            if batch is None:
                return
            try:
                self._launch(batch)
            except Exception as e:   # noqa: BLE001 — fail the batch, keep serving
                for r in batch:
                    self._fail_all(r, f"error: {type(e).__name__}: {e}")

    # --------------------------------------------- in-flight coalescing

    def _unregister(self, req: Request) -> None:
        """Drop ``req`` from the in-flight map (caller holds the lock
        or owns the request). No more followers can attach after this."""
        if req.cache_key is not None \
                and self._inflight.get(req.cache_key) is req:
            del self._inflight[req.cache_key]

    def _finish_inflight(self, req: Request) -> list:
        """Atomically retire ``req`` from the in-flight map and snapshot
        its followers; later duplicates become fresh primaries."""
        with self._coalesce_lock:
            self._unregister(req)
            return req.followers

    def _fail_all(self, req: Request, status: str) -> None:
        """Fail a request's future and every coalesced follower.

        Completion is first-writer-wins (``ServeFuture._fail`` returns
        whether this call transitioned), so a batch-wide failure after
        a partial fulfil leaves already-``done`` futures — and their
        already-ended traces — untouched."""
        now = time.monotonic()
        for f, _, ftr in self._finish_inflight(req):
            if f._fail(status) and ftr is not None:
                self._tracer.end_trace(ftr, now, status=status)
        if req.future._fail(status) and req.trace is not None:
            self._tracer.end_trace(req.trace, now, status=status)

    def _pick_width(self, n: int) -> int:
        """Smallest pre-compiled ladder rung covering ``n`` requests."""
        for w in self.launch_widths:
            if w >= n:
                return w
        return self.max_batch

    def _next_seq(self) -> int:
        with self._stats_lock:
            seq = self._launch_seq
            self._launch_seq += 1
            return seq

    def _pack(self, batch: list[Request],
              width: int) -> tuple[np.ndarray, np.ndarray]:
        """Batch rows -> fixed-shape [width, query_nnz] launch arrays."""
        coords = np.zeros((width, self.query_nnz), np.int32)
        vals = np.zeros((width, self.query_nnz), np.float32)
        for i, r in enumerate(batch):
            coords[i], vals[i] = r.coords, r.vals
        return coords, vals

    def _execute(self, index, fns, coords: np.ndarray, vals: np.ndarray,
                 staged: bool, delay_s: float = 0.0, *,
                 audit: bool = False, params: SearchParams | None = None):
        """One pipeline execution against ``index``; returns host arrays
        plus wall-time bounds and (staged only) per-stage span triples.

        ``delay_s`` injects artificial per-launch latency INSIDE the
        timed window (replica benchmarks / balancer tests: the EWMA
        must see it). ``audit`` (staged only) additionally probes the
        funnel's membership captures for the shadow auditor."""
        tel = self.telemetry
        p = self.params if params is None else params
        triples: list[tuple[str, float, float]] = []
        probed: dict[str, object] = {}
        t0 = time.monotonic()
        if delay_s > 0.0:
            time.sleep(delay_s)
        if staged:
            scores, ids, ev = run_pipeline_staged(
                index, jnp.asarray(coords), jnp.asarray(vals),
                p, fns=fns,
                record=lambda s, dt: tel.record_latency(f"stage_{s}", dt),
                span_cb=lambda name, a, b: triples.append((name, a, b)),
                split_refine=True, probe=probed.__setitem__,
                audit=audit)
        else:
            scores, ids, ev = jax.block_until_ready(search_pipeline(
                index,
                PaddedSparse(jnp.asarray(coords), jnp.asarray(vals),
                             index.dim),
                p))
        t1 = time.monotonic()
        return (np.asarray(ids), np.asarray(scores), np.asarray(ev),
                t0, t1, triples, probed)

    def _account(self, n: int, width: int, ev: np.ndarray, staged: bool,
                 triples, probed) -> None:
        """Post-execution telemetry shared by every dispatch path."""
        tel = self.telemetry
        tel.inc("batches")
        tel.observe_occupancy(n)
        with self._stats_lock:
            ws = self._width_stats.setdefault(width, [0, 0])
            ws[0] += 1
            ws[1] += n
            occ = ws[1] / (ws[0] * width)
            self._ev_sum += float(ev[:n].sum())
            self._ev_n += n
            ev_mean = self._ev_sum / self._ev_n
        self._width_occ.labels(str(width)).set(occ)
        self._ev_mean.set(ev_mean)
        if staged and self._device is not None:
            stage_seconds = {name: b - a for name, a, b in triples}
            self._device.observe(stage_seconds, width,
                                 cand=probed.get("cand"))

    def _launch(self, batch: list[Request], *, index=None, fns=None,
                delay_s: float = 0.0, span_attrs: dict | None = None,
                on_timing=None) -> None:
        """One fixed-shape pipeline launch serving ``len(batch)`` rows.

        The keyword hooks are the replica-server seam: ``index``/``fns``
        select a replica's copy (default: the server's own), ``delay_s``
        injects artificial latency, ``span_attrs`` lands extra attrs on
        every launch span (e.g. ``replica=rid``), and ``on_timing(
        launch_seconds, stage_seconds)`` feeds the balancer's EWMA."""
        tel = self.telemetry
        n = len(batch)
        width = self._pick_width(n)
        tel.inc(f"launch_width_{width}")
        tel.inc("dispatched", n)
        seq = self._next_seq()
        audit_rows = self.auditor.plan(n) if self.auditor is not None \
            else ()
        # one atomic snapshot of the serving generation: a concurrent
        # swap_index can never tear an old index against new stage fns
        # or params inside a single launch
        with self._swap_lock:
            if index is None:
                index = self.index
            if fns is None:
                fns = self._fns
            params = self.params
        have_fns = fns is not None
        capture = bool(audit_rows) and have_fns
        staged = self.stage_timing or capture or (
            have_fns
            and self.obs is not None and self.obs.sample_stages(seq))
        coords, vals = self._pack(batch, width)
        dispatch_t = time.monotonic()
        ids, scores, ev, t0, t1, triples, probed = self._execute(
            index, fns, coords, vals, staged, delay_s, audit=capture,
            params=params)
        tel.record_latency("launch", t1 - t0)
        if on_timing is not None:
            on_timing(t1 - t0,
                      {name: b - a for name, a, b in triples})
        self._account(n, width, ev, staged, triples, probed)
        audit_span = None
        if audit_rows:
            a0 = time.monotonic()
            for i in audit_rows:
                self.auditor.feed(coords[i], vals[i], ids[i],
                                  captures=probed if capture else None,
                                  row=i)
            audit_span = (a0, time.monotonic())
        self._fulfil(batch, ids, scores, ev, dispatch_t=dispatch_t,
                     t1=t1, width=width, seq=seq, staged=staged,
                     triples=triples, span_attrs=span_attrs,
                     audit_span=audit_span)

    def _fulfil(self, batch: list[Request], ids: np.ndarray,
                scores: np.ndarray, ev: np.ndarray, *, dispatch_t: float,
                t1: float, width: int, seq: int, staged: bool,
                triples=(), span_attrs: dict | None = None,
                audit_span: tuple[float, float] | None = None) -> None:
        """Fulfil every request (and coalesced follower) of a batch from
        the launch's result rows; closes caches, histograms, spans."""
        tel = self.telemetry
        n = len(batch)
        attrs = span_attrs or {}
        done_t = time.monotonic()
        leader = batch[0]
        served = 0
        for i, r in enumerate(batch):
            if self.cache is not None and r.cache_key is not None:
                # copies: don't let caller mutation poison hits, don't
                # pin the whole launch arrays via views
                self.cache.put(r.cache_key,
                               (ids[i].copy(), scores[i].copy(),
                                int(ev[i])))
            if dispatch_t > r.deadline + self.deadline_grace_s:
                tel.inc("deadline_missed")
            tel.record_latency("queue_wait", dispatch_t - r.submit_t)
            tel.record_latency("request_e2e", done_t - r.submit_t)
            if r.trace is not None:
                self._tracer.add_span(r.trace, "queue_wait",
                                      r.submit_t, dispatch_t)
                launch_span = self._tracer.add_span(
                    r.trace, "launch", dispatch_t, t1, width=width,
                    occupancy=n, batch_seq=seq, staged=staged, **attrs)
                # stages ran once for the batch: their spans attach to
                # the batch leader's launch span only
                if r is leader and staged:
                    attach_stage_spans(self._tracer, r.trace,
                                       launch_span, triples)
                # likewise the audit feed (one per launch): root-level
                # on the leader, it runs after the launch window
                if r is leader and audit_span is not None:
                    self._tracer.add_span(r.trace, "audit",
                                          audit_span[0], audit_span[1])
            # retire from the in-flight map BEFORE fulfilling: once the
            # followers snapshot is taken no new duplicate can attach
            # to this slot (they re-enter as cache hits / new primaries)
            followers = self._finish_inflight(r)
            for f, t_sub, ftr in followers:
                # a follower attached mid-execution waited 0 in queue
                tel.record_latency("queue_wait",
                                   max(0.0, dispatch_t - t_sub))
                tel.record_latency("request_e2e",
                                   max(0.0, done_t - t_sub))
                if f._set(ServeResult(
                        ids=ids[i].copy(), scores=scores[i].copy(),
                        docs_evaluated=int(ev[i]), coalesced=True,
                        latency_s=max(0.0, done_t - t_sub),
                        occupancy=n)) and ftr is not None:
                    # a follower that attached mid-execution has
                    # t_sub > dispatch_t: clamp its spans into
                    # [t_sub, ...] so the tree stays valid (the
                    # histogram above clamps; spans must too)
                    f_disp = max(t_sub, dispatch_t)
                    f_end = max(f_disp, t1)
                    self._tracer.add_span(ftr, "queue_wait",
                                          t_sub, f_disp)
                    self._tracer.add_span(ftr, "launch", f_disp, f_end,
                                          width=width, occupancy=n,
                                          batch_seq=seq, staged=staged,
                                          **attrs)
                    self._tracer.end_trace(ftr, max(done_t, f_end),
                                           status="done")
            if r.future._set(ServeResult(
                    ids=ids[i], scores=scores[i],
                    docs_evaluated=int(ev[i]), cached=False,
                    latency_s=done_t - r.submit_t, occupancy=n)) \
                    and r.trace is not None:
                self._tracer.end_trace(r.trace, done_t, status="done",
                                       docs_evaluated=int(ev[i]))
            served += 1 + len(followers)
        tel.inc("served", served)

    # --------------------------------------------------------- helpers

    def _normalize(self, coords, vals) -> tuple[np.ndarray, np.ndarray]:
        """Pad/truncate one sparse query to the fixed ``query_nnz``."""
        c = np.asarray(coords, np.int32).ravel()
        v = np.asarray(vals, np.float32).ravel()
        if c.shape != v.shape:
            raise ValueError(f"coords {c.shape} vs vals {v.shape}")
        if c.size > self.query_nnz:          # keep heaviest coordinates
            keep = np.argpartition(v, -self.query_nnz)[-self.query_nnz:]
            c, v = c[keep], v[keep]
        out_c = np.zeros((self.query_nnz,), np.int32)
        out_v = np.zeros((self.query_nnz,), np.float32)
        out_c[:c.size], out_v[:v.size] = c, v
        out_c[out_v <= 0] = 0                # canonical padding slots
        out_v[out_v <= 0] = 0.0
        return out_c, out_v

    def telemetry_export(self) -> dict:
        """Telemetry snapshot plus cache stats, as one plain dict."""
        out = self.telemetry.export()
        out["cache"] = self.cache.stats() if self.cache is not None \
            else None
        return out
