"""Deadline-based micro-batching front-end over the staged pipeline.

``AsyncSeismicServer`` accepts single queries (``submit``) from any
thread and coalesces whatever is in flight into fixed-shape
``[width, query_nnz]`` launches of the jitted ``search_pipeline``
(dispatch on batch-full OR oldest-deadline-expiry, never recompiling).
Launch widths come from a pre-compiled LADDER (default ``8/32/128``
clipped to ``max_batch``): each dispatch picks the smallest compiled
width covering the coalesced batch, so a lone tail request stops
paying the full ``max_batch`` of padded pipeline work. Every width is
compiled at warmup; per-width dispatch counts land in telemetry
(``launch_width_<w>``). The server then fulfills per-request futures. Around that core sit admission
control (bounded queue, ``reject`` / ``shed_oldest``), a quantized-
fingerprint LRU result cache, request coalescing (concurrently
in-flight requests with identical quantized fingerprints share one
launch slot — the LRU only catches repeats *after* the first
completes), and telemetry (per-stage latency when ``stage_timing`` is
on, queue depth, batch occupancy, cache hit-rate).

The synchronous ``SeismicServer`` facade in ``engine`` remains the
simple offline-batch path; this class is the serving path every
future scaling layer (sharded serving, replication) plugs into.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.types import SeismicIndex
from repro.graph.refine import validate_refine_params
from repro.retrieval import SearchParams, search_pipeline
from repro.retrieval.pipeline import run_pipeline_staged, stage_fns
from repro.serve.cache import LRUCache, query_fingerprint
from repro.serve.queue import Request, RequestQueue, ServeFuture
from repro.serve.telemetry import ServerTelemetry
from repro.sparse.ops import PaddedSparse


@dataclasses.dataclass
class ServeResult:
    """Per-request retrieval result with serving metadata."""

    ids: np.ndarray            # int32 [k], -1 padding
    scores: np.ndarray         # f32 [k]
    docs_evaluated: int
    cached: bool = False
    coalesced: bool = False    # fulfilled from another request's slot
    latency_s: float = 0.0     # submit -> fulfil wall time
    occupancy: int = 0         # real queries in the serving launch


class AsyncSeismicServer:
    """Micro-batching async retrieval server over one Seismic index.

    Parameters
    ----------
    max_batch     maximum launch width; a dispatch never carries more
                  than this many distinct requests.
    launch_widths ascending pre-compiled launch widths (the ladder).
                  ``None`` selects the default rungs ``(8, 32, 128)``
                  clipped to ``max_batch`` (which is always the top
                  rung). Each dispatch pads to the smallest rung
                  covering the batch instead of always ``max_batch``.
    query_nnz     fixed per-query nnz width; longer queries keep their
                  ``query_nnz`` heaviest coordinates.
    deadline_s    default max time a request may wait for co-batching
                  before a (possibly partial) launch is forced.
    queue_bound   admission limit; beyond it ``admission`` applies
                  ("reject" new requests or "shed_oldest" queued ones).
    cache_size    LRU entries keyed on quantized query fingerprints;
                  0 disables caching.
    coalesce      share one launch slot among concurrently in-flight
                  requests with identical quantized fingerprints (the
                  LRU cache only catches repeats after the first
                  completes; this catches the simultaneous burst).
    stage_timing  serve through the stage-by-stage pipeline and record
                  ``stage_*`` latency histograms (slightly slower than
                  the fused launch; keep off unless profiling).
    """

    DEFAULT_WIDTHS = (8, 32, 128)

    def __init__(self, index: SeismicIndex, params: SearchParams, *,
                 max_batch: int = 32, query_nnz: int = 32,
                 launch_widths: tuple[int, ...] | None = None,
                 deadline_s: float = 2e-3, queue_bound: int = 1024,
                 admission: str = "reject", cache_size: int = 0,
                 coalesce: bool = True, stage_timing: bool = False,
                 telemetry: ServerTelemetry | None = None):
        validate_refine_params(index, params)   # fail before threads spin
        from repro.tune.policy import validate_tuned_index
        validate_tuned_index(index)             # stale TunedPolicy -> now
        self.index = index
        self.params = params
        self.max_batch = max_batch
        if launch_widths is None:
            launch_widths = tuple(w for w in self.DEFAULT_WIDTHS
                                  if w < max_batch)
        else:
            if any(w <= 0 or w > max_batch for w in launch_widths):
                raise ValueError(
                    f"launch_widths {launch_widths} must lie in "
                    f"[1, max_batch={max_batch}]")
            launch_widths = tuple(w for w in launch_widths
                                  if w < max_batch)
        # max_batch is always the top rung, so every batch has a cover
        self.launch_widths = tuple(sorted(set(launch_widths))) \
            + (max_batch,)
        self.query_nnz = query_nnz
        self.deadline_s = deadline_s
        self.stage_timing = stage_timing
        self.queue = RequestQueue(bound=queue_bound, policy=admission)
        self.cache = LRUCache(cache_size) if cache_size > 0 else None
        self.coalesce = coalesce
        self._inflight: dict[bytes, Request] = {}
        self._coalesce_lock = threading.Lock()
        self.telemetry = telemetry if telemetry is not None \
            else ServerTelemetry()
        self._fns = stage_fns(index, params) if stage_timing else None
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------- lifecycle

    def start(self, warmup: bool = True) -> "AsyncSeismicServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        if self.queue.closed:
            raise RuntimeError("server was stopped; its queue is closed "
                               "— build a new AsyncSeismicServer")
        if warmup:
            self.warmup()
        self._thread = threading.Thread(target=self._worker,
                                        name="seismic-batcher",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Close admission, drain queued requests, join the worker."""
        self.queue.close()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "AsyncSeismicServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def warmup(self) -> None:
        """Compile every ladder width before serving traffic."""
        for width in self.launch_widths:
            coords = jnp.zeros((width, self.query_nnz), jnp.int32)
            vals = jnp.zeros((width, self.query_nnz), jnp.float32)
            if self.stage_timing:
                jax.block_until_ready(run_pipeline_staged(
                    self.index, coords, vals, self.params, fns=self._fns))
            else:
                jax.block_until_ready(search_pipeline(
                    self.index, PaddedSparse(coords, vals, self.index.dim),
                    self.params))

    # ------------------------------------------------------ submission

    def submit(self, coords, vals,
               deadline_s: float | None = None) -> ServeFuture:
        """Enqueue one sparse query; returns its completion future.

        Cache hits fulfil immediately without touching the queue; a
        request whose fingerprint matches one already in flight
        attaches to that request's launch slot instead of occupying
        its own (``coalesce``). Rejected / shed requests get a failed
        future (``status`` set), never an exception on the submitting
        thread.
        """
        tel = self.telemetry
        tel.inc("requests")
        c, v = self._normalize(coords, vals)
        key = None
        if self.cache is not None or self.coalesce:
            key = query_fingerprint(c, v)
        if self.cache is not None:
            hit = self.cache.get(key)       # hit/miss counted by the LRU
            if hit is not None:
                fut = ServeFuture()
                ids, scores, ev = hit
                fut._set(ServeResult(ids=ids.copy(), scores=scores.copy(),
                                     docs_evaluated=ev, cached=True))
                return fut
        now = time.monotonic()
        req = Request(coords=c, vals=v, submit_t=now,
                      deadline=now + (self.deadline_s if deadline_s is None
                                      else deadline_s),
                      future=ServeFuture(), cache_key=key)
        # the check-attach-or-enqueue-and-register must be atomic, or
        # two racing duplicates both become primaries / a follower
        # attaches to a request whose slot already fulfilled
        with self._coalesce_lock:
            if self.coalesce:
                primary = self._inflight.get(key)
                if primary is not None:
                    primary.followers.append((req.future, now))
                    tel.inc("coalesced")
                    return req.future
            status, shed = self.queue.put(req)
            if status == "ok" and self.coalesce:
                self._inflight[key] = req
            if shed is not None:
                self._unregister(shed)
        if status != "ok":
            tel.inc(status)                 # "rejected" or "closed"
            req.future._fail(status)
        elif shed is not None:
            tel.inc("shed")
            self._fail_all(shed, "shed")
        tel.observe_queue_depth(self.queue.depth)
        return req.future

    def search(self, queries: PaddedSparse,
               deadline_s: float | None = None):
        """Synchronous batch convenience: submit every row, wait all.

        Returns an ``engine.RetrievalResult`` so callers can swap the
        sync facade for the async server without changing result
        handling. Rejected/shed rows come back as -1 ids.
        """
        from repro.serve.engine import RetrievalResult
        coords = np.asarray(queries.coords)
        vals = np.asarray(queries.vals)
        futs = [self.submit(coords[i], vals[i], deadline_s)
                for i in range(coords.shape[0])]
        ids = np.full((len(futs), self.params.k), -1, np.int32)
        scores = np.full((len(futs), self.params.k), -np.inf, np.float32)
        ev = np.zeros((len(futs),), np.int32)
        for i, f in enumerate(futs):
            f.wait()
            if f.status == "done":
                r = f._result
                ids[i], scores[i], ev[i] = r.ids, r.scores, \
                    r.docs_evaluated
        return RetrievalResult(ids=ids, scores=scores, docs_evaluated=ev)

    # ---------------------------------------------------------- worker

    def _worker(self) -> None:
        while True:
            batch = self.queue.next_batch(self.max_batch)
            if batch is None:
                return
            try:
                self._launch(batch)
            except Exception as e:   # noqa: BLE001 — fail the batch, keep serving
                for r in batch:
                    self._fail_all(r, f"error: {type(e).__name__}: {e}")

    # --------------------------------------------- in-flight coalescing

    def _unregister(self, req: Request) -> None:
        """Drop ``req`` from the in-flight map (caller holds the lock
        or owns the request). No more followers can attach after this."""
        if req.cache_key is not None \
                and self._inflight.get(req.cache_key) is req:
            del self._inflight[req.cache_key]

    def _finish_inflight(self, req: Request) -> list:
        """Atomically retire ``req`` from the in-flight map and snapshot
        its followers; later duplicates become fresh primaries."""
        with self._coalesce_lock:
            self._unregister(req)
            return req.followers

    def _fail_all(self, req: Request, status: str) -> None:
        """Fail a request's future and every coalesced follower."""
        for f, _ in self._finish_inflight(req):
            f._fail(status)
        req.future._fail(status)

    def _pick_width(self, n: int) -> int:
        """Smallest pre-compiled ladder rung covering ``n`` requests."""
        for w in self.launch_widths:
            if w >= n:
                return w
        return self.max_batch

    def _launch(self, batch: list[Request]) -> None:
        """One fixed-shape pipeline launch serving ``len(batch)`` rows."""
        tel = self.telemetry
        n = len(batch)
        width = self._pick_width(n)
        tel.inc(f"launch_width_{width}")
        coords = np.zeros((width, self.query_nnz), np.int32)
        vals = np.zeros((width, self.query_nnz), np.float32)
        for i, r in enumerate(batch):
            coords[i], vals[i] = r.coords, r.vals
        dispatch_t = time.monotonic()
        t0 = time.perf_counter()
        if self.stage_timing:
            scores, ids, ev = run_pipeline_staged(
                self.index, jnp.asarray(coords), jnp.asarray(vals),
                self.params, fns=self._fns,
                record=lambda s, dt: tel.record_latency(f"stage_{s}", dt))
        else:
            scores, ids, ev = jax.block_until_ready(search_pipeline(
                self.index,
                PaddedSparse(jnp.asarray(coords), jnp.asarray(vals),
                             self.index.dim),
                self.params))
        tel.record_latency("launch", time.perf_counter() - t0)
        tel.inc("batches")
        tel.observe_occupancy(n)
        ids = np.asarray(ids)
        scores = np.asarray(scores)
        ev = np.asarray(ev)
        done_t = time.monotonic()
        served = 0
        for i, r in enumerate(batch):
            if self.cache is not None and r.cache_key is not None:
                # copies: don't let caller mutation poison hits, don't
                # pin the whole launch arrays via views
                self.cache.put(r.cache_key,
                               (ids[i].copy(), scores[i].copy(),
                                int(ev[i])))
            tel.record_latency("queue_wait", dispatch_t - r.submit_t)
            tel.record_latency("request_e2e", done_t - r.submit_t)
            # retire from the in-flight map BEFORE fulfilling: once the
            # followers snapshot is taken no new duplicate can attach
            # to this slot (they re-enter as cache hits / new primaries)
            followers = self._finish_inflight(r)
            for f, t_sub in followers:
                # a follower attached mid-execution waited 0 in queue
                tel.record_latency("queue_wait",
                                   max(0.0, dispatch_t - t_sub))
                tel.record_latency("request_e2e", done_t - t_sub)
                f._set(ServeResult(
                    ids=ids[i].copy(), scores=scores[i].copy(),
                    docs_evaluated=int(ev[i]), coalesced=True,
                    latency_s=done_t - t_sub, occupancy=n))
            r.future._set(ServeResult(
                ids=ids[i], scores=scores[i], docs_evaluated=int(ev[i]),
                cached=False, latency_s=done_t - r.submit_t, occupancy=n))
            served += 1 + len(followers)
        tel.inc("served", served)

    # --------------------------------------------------------- helpers

    def _normalize(self, coords, vals) -> tuple[np.ndarray, np.ndarray]:
        """Pad/truncate one sparse query to the fixed ``query_nnz``."""
        c = np.asarray(coords, np.int32).ravel()
        v = np.asarray(vals, np.float32).ravel()
        if c.shape != v.shape:
            raise ValueError(f"coords {c.shape} vs vals {v.shape}")
        if c.size > self.query_nnz:          # keep heaviest coordinates
            keep = np.argpartition(v, -self.query_nnz)[-self.query_nnz:]
            c, v = c[keep], v[keep]
        out_c = np.zeros((self.query_nnz,), np.int32)
        out_v = np.zeros((self.query_nnz,), np.float32)
        out_c[:c.size], out_v[:v.size] = c, v
        out_c[out_v <= 0] = 0                # canonical padding slots
        out_v[out_v <= 0] = 0.0
        return out_c, out_v

    def telemetry_export(self) -> dict:
        """Telemetry snapshot plus cache stats, as one plain dict."""
        out = self.telemetry.export()
        out["cache"] = self.cache.stats() if self.cache is not None \
            else None
        return out
