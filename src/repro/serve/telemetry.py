"""Serving telemetry — now a thin compatibility facade over the
unified metrics registry (:mod:`repro.obs.registry`).

``ServerTelemetry`` keeps its PR-2 API (``record_latency`` / ``inc`` /
``observe_occupancy`` / ``observe_queue_depth`` / ``export``) and its
plain-dict export shape, but every record lands in a
``MetricsRegistry`` as a labeled metric family:

    record_latency(name, s)   -> seismic_latency_seconds{span=name}
    inc(name, n)              -> seismic_events_total{event=name}
    observe_occupancy(n)      -> seismic_launch_occupancy_total{n_real=n}
    observe_queue_depth(d)    -> seismic_queue_depth / _queue_depth_max

so the same numbers the load benchmarks always consumed as dicts are
now ALSO scrapeable through the Prometheus / JSONL exporters, with no
double bookkeeping. Pass a shared registry (e.g. from an
``Observability`` bundle) to merge server telemetry with the tracing
and device-accounting metrics; by default each facade owns a fresh
one.

``Histogram`` re-exports the registry histogram: log-spaced buckets
with quantile estimates that are monotone in ``p`` and always inside
``[vmin, vmax]`` (a single cumulative-count walk shared with every
registry histogram — the PR-2 first-bucket geometric-mean estimate and
its odd ``vmin``/``vmax`` clamping are gone).
"""
from __future__ import annotations

from repro.obs.registry import Histogram, MetricsRegistry

__all__ = ["Histogram", "ServerTelemetry"]


class ServerTelemetry:
    """Thread-safe metric sink shared by the queue, batcher, and cache
    (compatibility facade over :class:`repro.obs.MetricsRegistry`).

    Latency histograms are keyed by name (``request_e2e``,
    ``queue_wait``, ``launch``, and ``stage_<name>`` when the server
    runs the staged timing path); counters count requests / batches /
    admission events; occupancy is a per-launch integer histogram.
    """

    def __init__(self, registry: MetricsRegistry | None = None):
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self._lat = self.registry.histogram(
            "seismic_latency_seconds",
            "Serving latency by span (request_e2e / queue_wait / "
            "launch / stage_*)", ("span",))
        self._events = self.registry.counter(
            "seismic_events_total",
            "Serving events (requests / batches / served / rejected / "
            "shed / coalesced / launch_width_* / ...)", ("event",))
        self._occ = self.registry.counter(
            "seismic_launch_occupancy_total",
            "Launches by real (un-padded) request count", ("n_real",))
        self._depth = self.registry.gauge(
            "seismic_queue_depth", "Admission queue depth at last "
            "observation").labels()
        self._depth_max = self.registry.gauge(
            "seismic_queue_depth_max", "Max observed admission queue "
            "depth").labels()

    def record_latency(self, name: str, seconds: float) -> None:
        self._lat.labels(name).record(seconds)

    def inc(self, name: str, n: int = 1) -> None:
        self._events.labels(name).inc(n)

    def observe_occupancy(self, n_real: int) -> None:
        self._occ.labels(str(n_real)).inc()

    def observe_queue_depth(self, depth: int) -> None:
        self._depth.set(depth)
        self._depth_max.set(max(self._depth_max.value, depth))

    def export(self) -> dict:
        """Plain-dict snapshot (JSON-serializable, no live references).

        Shape unchanged since PR 2 — benchmarks, tests, and the
        examples keep consuming it; the registry is the superset
        surface for exporters.
        """
        counters = {}
        for (event,), child in self._events.samples():
            counters[event] = child.value
        hists = {}
        for (span,), child in self._lat.samples():
            hists[span] = child.summary()
        occupancy = {}
        for (n_real,), child in self._occ.samples():
            occupancy[int(n_real)] = child.value
        launches = sum(occupancy.values())
        served = sum(k * v for k, v in occupancy.items())
        return {
            "counters": counters,
            "latency_s": {k: hists[k] for k in sorted(hists)},
            "batch": {
                "launches": launches,
                "mean_occupancy":
                    served / launches if launches else 0.0,
                "occupancy_counts": {str(k): v for k, v in
                                     sorted(occupancy.items())},
            },
            "queue": {"depth_max": self._depth_max.value,
                      "depth_last": self._depth.value},
        }
