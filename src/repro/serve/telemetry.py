"""Serving telemetry: latency histograms, counters, batch occupancy,
queue depth — exported as plain dicts so benchmarks and load tests can
consume them without any observability dependency.

All record paths are lock-protected (the batcher worker thread and the
submitting threads write concurrently) and cheap: a histogram record is
one bisect into fixed log-spaced bucket edges.
"""
from __future__ import annotations

import bisect
import math
import threading


class Histogram:
    """Fixed log-spaced-bucket histogram (default 1us .. 1000s).

    Percentiles are bucket-resolution estimates: the geometric mean of
    the bucket the p-quantile falls into. Good to ~15% with the default
    64 buckets over 9 decades — plenty for latency reporting.
    """

    def __init__(self, lo: float = 1e-6, hi: float = 1e3,
                 n_buckets: int = 64):
        self.lo, self.hi = lo, hi
        ratio = (hi / lo) ** (1.0 / n_buckets)
        self.edges = [lo * ratio ** i for i in range(1, n_buckets + 1)]
        self.counts = [0] * (n_buckets + 1)   # last bucket = overflow
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def record(self, x: float) -> None:
        self.counts[bisect.bisect_left(self.edges, x)] += 1
        self.n += 1
        self.total += x
        self.vmin = min(self.vmin, x)
        self.vmax = max(self.vmax, x)

    def percentile(self, p: float) -> float:
        """p in [0, 1] -> bucket-resolution quantile estimate."""
        if self.n == 0:
            return 0.0
        target = p * self.n
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= target:
                left = self.lo if i == 0 else self.edges[i - 1]
                right = self.edges[min(i, len(self.edges) - 1)]
                return min(max(math.sqrt(left * right), self.vmin),
                           self.vmax)
        return self.vmax

    def summary(self) -> dict:
        if self.n == 0:
            return {"count": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0,
                    "p99": 0.0, "min": 0.0, "max": 0.0}
        return {"count": self.n, "mean": self.total / self.n,
                "p50": self.percentile(0.50), "p95": self.percentile(0.95),
                "p99": self.percentile(0.99), "min": self.vmin,
                "max": self.vmax}


class ServerTelemetry:
    """Thread-safe metric sink shared by the queue, batcher, and cache.

    Latency histograms are keyed by name (``request_e2e``,
    ``queue_wait``, ``launch``, and ``stage_<name>`` when the server
    runs the staged timing path); counters count requests / batches /
    admission events; occupancy is a per-launch integer histogram.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._hists: dict[str, Histogram] = {}
        self._counters: dict[str, int] = {}
        self._occupancy: dict[int, int] = {}
        self._depth_max = 0
        self._depth_last = 0

    def record_latency(self, name: str, seconds: float) -> None:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram()
            h.record(seconds)

    def inc(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def observe_occupancy(self, n_real: int) -> None:
        with self._lock:
            self._occupancy[n_real] = self._occupancy.get(n_real, 0) + 1

    def observe_queue_depth(self, depth: int) -> None:
        with self._lock:
            self._depth_last = depth
            self._depth_max = max(self._depth_max, depth)

    def export(self) -> dict:
        """Plain-dict snapshot (JSON-serializable, no live references)."""
        with self._lock:
            launches = sum(self._occupancy.values())
            served = sum(k * v for k, v in self._occupancy.items())
            return {
                "counters": dict(self._counters),
                "latency_s": {k: h.summary()
                              for k, h in sorted(self._hists.items())},
                "batch": {
                    "launches": launches,
                    "mean_occupancy":
                        served / launches if launches else 0.0,
                    "occupancy_counts": {str(k): v for k, v in
                                         sorted(self._occupancy.items())},
                },
                "queue": {"depth_max": self._depth_max,
                          "depth_last": self._depth_last},
            }
