"""Query-result cache: LRU over quantized sparse-query fingerprints.

Learned sparse queries repeat (head queries, paraphrase dedup upstream)
and SPLADE weights carry more precision than retrieval needs, so the
cache key quantizes each query to an 8-bit impact grid: two queries
whose coordinates match and whose relative weights agree to ~0.4%
share a fingerprint and one pipeline launch serves both.
"""
from __future__ import annotations

import struct
import threading
from collections import OrderedDict

import numpy as np


def query_fingerprint(coords: np.ndarray, vals: np.ndarray,
                      bits: int = 8) -> bytes:
    """Order-invariant quantized fingerprint of one padded-sparse query.

    Padding entries (val <= 0) are dropped; surviving (coord, val)
    pairs are coord-sorted; values are scaled to the row max and
    rounded to a ``bits``-bit grid. The row max itself enters coarsely
    (eighth-of-an-octave buckets) so score *scale* changes only bust
    the cache when they could change the top-k ordering downstream.
    """
    v = np.asarray(vals, np.float32).ravel()
    c = np.asarray(coords, np.int64).ravel()
    live = v > 0
    c, v = c[live], v[live]
    if c.size == 0:
        return b"empty"
    order = np.argsort(c, kind="stable")
    c, v = c[order], v[order]
    vmax = float(v.max())
    q = np.round(v / vmax * ((1 << bits) - 1)).astype(np.uint16)
    scale_bucket = int(np.round(np.log2(vmax) * 8))
    return (c.astype(np.int32).tobytes() + q.tobytes()
            + struct.pack("<i", scale_bucket))


class LRUCache:
    """Thread-safe LRU mapping fingerprint -> served result payload."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._d: OrderedDict[bytes, object] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: bytes):
        with self._lock:
            if key in self._d:
                self._d.move_to_end(key)
                self.hits += 1
                return self._d[key]
            self.misses += 1
            return None

    def put(self, key: bytes, value) -> None:
        if self.capacity <= 0:
            return
        with self._lock:
            self._d[key] = value
            self._d.move_to_end(key)
            while len(self._d) > self.capacity:
                self._d.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {"size": len(self._d), "capacity": self.capacity,
                    "hits": self.hits, "misses": self.misses,
                    "hit_rate": self.hits / total if total else 0.0}
