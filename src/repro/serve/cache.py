"""Query-result cache: LRU over quantized sparse-query fingerprints.

Learned sparse queries repeat (head queries, paraphrase dedup upstream)
and SPLADE weights carry more precision than retrieval needs, so the
cache key quantizes each query to an 8-bit impact grid: two queries
whose coordinates match and whose relative weights agree to ~0.4%
share a fingerprint and one pipeline launch serves both.

Scale-bucket stability: the row max enters the key coarsely (eighth-
of-an-octave buckets on ``log2(vmax)``), and a pure rounding bucket
puts near-identical queries on opposite sides of a bucket edge — a
head query whose max weight jitters by fractions of a percent would
silently flap between two keys and halve its hit rate. No deterministic
single-key quantizer can fix that (any bucketing function has SOME
boundary), so the cache probes a small *candidate set* instead:
:func:`fingerprint_candidates` returns the primary key plus, within a
guard band of ``SCALE_GUARD`` around a bucket edge, the neighboring
bucket's key. Lookups probe every candidate (``LRUCache.get_any``);
inserts go under the primary. Two queries whose ``log2(vmax) * 8``
differ by less than ``2 * SCALE_GUARD - |edge distance|`` — in
particular any vmax jitter within ±0.4% — always share at least one
candidate key, so the flap becomes a hit.
"""
from __future__ import annotations

import math
import struct
import threading
from collections import OrderedDict

import numpy as np

# guard band around a scale-bucket edge, in bucket units (1 bucket =
# an eighth of an octave of vmax). 0.05 buckets ~ 0.43% of vmax —
# comfortably wider than the ±0.2% jitter the regression test pins,
# and far narrower than the ~9% value change a full bucket represents.
SCALE_GUARD = 0.05


def _fingerprint_parts(coords: np.ndarray, vals: np.ndarray,
                       bits: int) -> tuple[bytes, float] | None:
    """Shared body: (coord+impact-grid payload, fractional scale
    coordinate ``log2(vmax) * 8``); None for an empty query."""
    v = np.asarray(vals, np.float32).ravel()
    c = np.asarray(coords, np.int64).ravel()
    live = v > 0
    c, v = c[live], v[live]
    if c.size == 0:
        return None
    order = np.argsort(c, kind="stable")
    c, v = c[order], v[order]
    vmax = float(v.max())
    q = np.round(v / vmax * ((1 << bits) - 1)).astype(np.uint16)
    return (c.astype(np.int32).tobytes() + q.tobytes(),
            math.log2(vmax) * 8.0)


def query_fingerprint(coords: np.ndarray, vals: np.ndarray,
                      bits: int = 8) -> bytes:
    """Order-invariant quantized fingerprint of one padded-sparse query.

    Padding entries (val <= 0) are dropped; surviving (coord, val)
    pairs are coord-sorted; values are scaled to the row max and
    rounded to a ``bits``-bit grid. The row max itself enters coarsely
    (eighth-of-an-octave buckets) so score *scale* changes only bust
    the cache when they could change the top-k ordering downstream.
    This is the PRIMARY key — cache lookups should probe the full
    :func:`fingerprint_candidates` set so boundary jitter still hits.
    """
    parts = _fingerprint_parts(coords, vals, bits)
    if parts is None:
        return b"empty"
    payload, x = parts
    return payload + struct.pack("<i", int(np.round(x)))


def fingerprint_candidates(coords: np.ndarray, vals: np.ndarray,
                           bits: int = 8) -> tuple[bytes, ...]:
    """Candidate cache keys for one query: ``(primary,)`` normally,
    ``(primary, neighbor-bucket)`` when the scale coordinate falls
    within ``SCALE_GUARD`` of a bucket edge.

    ``candidates[0] == query_fingerprint(...)`` always, so inserting
    under the primary and probing every candidate makes two queries
    whose vmax differs by sub-guard jitter share a cache line no matter
    which side of the edge each rounds to.
    """
    parts = _fingerprint_parts(coords, vals, bits)
    if parts is None:
        return (b"empty",)
    payload, x = parts
    b = int(np.round(x))
    keys = [payload + struct.pack("<i", b)]
    frac = x - b
    if frac > 0.5 - SCALE_GUARD:
        keys.append(payload + struct.pack("<i", b + 1))
    elif frac < -(0.5 - SCALE_GUARD):
        keys.append(payload + struct.pack("<i", b - 1))
    return tuple(keys)


class LRUCache:
    """Thread-safe LRU mapping fingerprint -> served result payload."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._d: OrderedDict[bytes, object] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: bytes):
        with self._lock:
            if key in self._d:
                self._d.move_to_end(key)
                self.hits += 1
                return self._d[key]
            self.misses += 1
            return None

    def get_any(self, keys):
        """First hit among candidate ``keys`` (one hit/miss counted for
        the whole probe, so multi-candidate lookups don't dilute the
        hit rate)."""
        with self._lock:
            for key in keys:
                if key in self._d:
                    self._d.move_to_end(key)
                    self.hits += 1
                    return self._d[key]
            self.misses += 1
            return None

    def put(self, key: bytes, value) -> None:
        if self.capacity <= 0:
            return
        with self._lock:
            self._d[key] = value
            self._d.move_to_end(key)
            while len(self._d) > self.capacity:
                self._d.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {"size": len(self._d), "capacity": self.capacity,
                    "hits": self.hits, "misses": self.misses,
                    "hit_rate": self.hits / total if total else 0.0}
