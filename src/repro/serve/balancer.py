"""Stage-timing replica balancer: EWMA cost -> virtual-time dispatch.

``StageTimingBalancer`` routes micro-batches across N replica workers
using the wall-time the replicas actually report back — the per-launch
seconds (and, on staged launches, the per-stage breakdown that
``run_pipeline_staged`` exposes). Policy: deficit round-robin over
*virtual time*.

Every replica carries a virtual clock ``vtime``; ``pick()`` dispatches
to the replica with the smallest effective clock and advances that
clock by the replica's EWMA cost estimate (plus an in-flight penalty so
a replica whose slowness has not been *measured* yet cannot absorb the
whole backlog while its first report is pending). The result:

  * dispatch share is proportional to 1/cost — a replica 10x slower
    gets ~10x fewer batches;
  * never starvation — a slow replica's clock advances only when it is
    picked, so it is always picked again once the fast clocks catch up;
  * deterministic — no randomness; ties break on fewest dispatches,
    then lowest replica id.

The balancer is plain bookkeeping under one lock: no sleeping, no
threads of its own. ``snapshot()`` feeds the ``seismic_replica_*``
gauges.
"""
from __future__ import annotations

import threading


class StageTimingBalancer:
    """Virtual-time dispatch over ``n_replicas`` workers.

    Parameters
    ----------
    n_replicas  number of replica workers to balance over.
    alpha       EWMA smoothing for per-replica cost (0 < alpha <= 1);
                higher tracks drift faster, lower is steadier.
    prior_s     initial per-launch cost estimate. Equal priors mean the
                first dispatches round-robin until real timings arrive.
    """

    def __init__(self, n_replicas: int, *, alpha: float = 0.3,
                 prior_s: float = 1e-3):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.n_replicas = n_replicas
        self.alpha = alpha
        self._lock = threading.Lock()
        self._cost = [float(prior_s)] * n_replicas      # EWMA s/launch
        self._stage_cost: list[dict[str, float]] = \
            [{} for _ in range(n_replicas)]             # EWMA s/stage
        self._vtime = [0.0] * n_replicas
        self._dispatches = [0] * n_replicas
        self._inflight = [0] * n_replicas
        self._recorded = [0] * n_replicas

    # ------------------------------------------------------------ policy

    def pick(self) -> int:
        """Choose the replica for the next dispatch and advance its
        virtual clock. Returns the replica id."""
        with self._lock:
            def effective(r: int) -> float:
                # un-acknowledged dispatches count at the current cost
                # estimate: backpressure on replicas that are behind
                return self._vtime[r] + self._inflight[r] * self._cost[r]
            rid = min(range(self.n_replicas),
                      key=lambda r: (effective(r), self._dispatches[r], r))
            self._vtime[rid] += self._cost[rid]
            self._dispatches[rid] += 1
            self._inflight[rid] += 1
            return rid

    def record(self, rid: int, seconds: float,
               stage_seconds: dict[str, float] | None = None) -> None:
        """Report one finished launch on ``rid``: ``seconds`` of wall
        time (on staged launches equal to the sum of the per-stage
        timings), plus the optional per-stage breakdown."""
        a = self.alpha
        with self._lock:
            self._inflight[rid] = max(0, self._inflight[rid] - 1)
            self._recorded[rid] += 1
            if self._recorded[rid] == 1:
                self._cost[rid] = float(seconds)   # drop the prior
            else:
                self._cost[rid] = (1 - a) * self._cost[rid] + a * seconds
            if stage_seconds:
                sc = self._stage_cost[rid]
                for name, dt in stage_seconds.items():
                    prev = sc.get(name)
                    sc[name] = float(dt) if prev is None \
                        else (1 - a) * prev + a * dt

    # ----------------------------------------------------- introspection

    def cost(self, rid: int) -> float:
        with self._lock:
            return self._cost[rid]

    def dispatches(self, rid: int) -> int:
        with self._lock:
            return self._dispatches[rid]

    def snapshot(self) -> dict:
        """Per-replica rollup for telemetry: cost EWMAs, dispatch
        counts/shares, in-flight depth, per-stage cost EWMAs."""
        with self._lock:
            total = max(1, sum(self._dispatches))
            return {
                "cost_ewma_s": list(self._cost),
                "dispatches": list(self._dispatches),
                "dispatch_share": [d / total for d in self._dispatches],
                "inflight": list(self._inflight),
                "stage_cost_ewma_s": [dict(sc) for sc in self._stage_cost],
            }
