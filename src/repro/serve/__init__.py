"""Serving subsystem: async deadline-based micro-batching over the
staged retrieval pipeline (see README.md in this package).

    engine      thin synchronous facades (SeismicServer, LMDecoder)
    queue       bounded deadline request queue + admission control
    batcher     AsyncSeismicServer (the micro-batching server)
    replica     ReplicaSeismicServer (N replica workers — mirrored or
                doc-sharded — behind the one queue)
    balancer    StageTimingBalancer (EWMA-cost virtual-time dispatch)
    cache       quantized-fingerprint LRU result cache
    telemetry   compatibility facade over repro.obs.MetricsRegistry
                (plain-dict export shape unchanged)

Pass ``obs=repro.obs.Observability.create()`` to either server for
request tracing, the serving gauges, sampled per-stage spans, and
device accounting — one registry scraped by the ``repro.obs``
exporters. See ``src/repro/obs/README.md``.
"""
from repro.serve.balancer import StageTimingBalancer
from repro.serve.batcher import AsyncSeismicServer, ServeResult
from repro.serve.cache import LRUCache, query_fingerprint
from repro.serve.engine import LMDecoder, RetrievalResult, SeismicServer
from repro.serve.queue import (ADMISSION_POLICIES, Request, RequestQueue,
                               ServeFuture)
from repro.serve.replica import ReplicaSeismicServer
from repro.serve.telemetry import Histogram, ServerTelemetry

__all__ = [
    "AsyncSeismicServer", "ServeResult",
    "ReplicaSeismicServer", "StageTimingBalancer",
    "SeismicServer", "RetrievalResult", "LMDecoder",
    "RequestQueue", "Request", "ServeFuture", "ADMISSION_POLICIES",
    "LRUCache", "query_fingerprint",
    "Histogram", "ServerTelemetry",
]
