"""Replica-parallel serving: N workers behind ONE admission queue.

``ReplicaSeismicServer`` composes the two halves that existed but had
never met: the async micro-batcher (``serve.batcher``) and the
doc-sharded index (``core.distributed``). One ``RequestQueue`` keeps
admission control, deadline batching, coalescing, and the LRU cache
exactly as in ``AsyncSeismicServer`` (this class subclasses it and
reuses its ``_launch`` internals); behind the queue a dispatcher thread
routes each micro-batch to one of N replica worker threads.

Two topologies:

  ``mirror``   every replica owns the SAME full index (one jit cache,
               zero extra memory for host threads; with per-device
               placement each replica would own a device copy). The
               dispatcher routes each batch to exactly one replica
               chosen by a :class:`repro.serve.balancer
               .StageTimingBalancer`: per-replica EWMA cost from the
               launch wall time (and the per-stage timings
               ``run_pipeline_staged`` exposes on staged launches)
               drives virtual-time dispatch — a slow replica gets
               proportionally fewer batches but is never starved.
               Results are bit-identical to ``AsyncSeismicServer`` at
               every replica count: same pipeline, same index, same
               launch-width ladder.

  ``shard``    replica r owns doc shard r of a ``build_sharded_index``
               stacked pytree. Every batch fans out to ALL replicas;
               each scores its shard locally, globalizes + masks pad
               hits via ``core.distributed.mask_shard_topk`` (the same
               invariant the ``shard_map`` path applies before its
               all-gather), and the last-finishing replica merges the
               per-shard top-k with the existing ``merge_topk`` and
               fulfils the batch. ``docs_evaluated`` is the sum over
               shards. This is the thread-parallel twin of
               ``make_distributed_search`` — the topology every later
               multi-host (``jax.process_index()``-style) deployment
               plugs into.

Telemetry: all ``AsyncSeismicServer`` metrics, plus per-replica
rollups in the same registry —

  ``seismic_replica_dispatches_total{replica}``  batches dispatched
  ``seismic_replica_cost_ewma_seconds{replica}`` balancer cost estimate
  ``seismic_replica_dispatch_share{replica}``    fraction of dispatches
  ``seismic_replica_inflight{replica}``          un-acked dispatches
  ``seismic_replica_stage_seconds{replica,stage}`` per-stage cost EWMA
                                                 (staged launches only)

and a ``replica`` attr on every launch span (``shard-merge`` on merged
shard launches).

``replica_delay_s`` injects artificial per-launch latency per replica
(inside the timed window, so the balancer's EWMA sees it) — the
deterministic knob the scaling/degradation benchmarks and the balancer
tests are built on; ``time.sleep`` releases the GIL, so delayed
replicas genuinely overlap.
"""
from __future__ import annotations

import queue as _queue
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.distributed import mask_shard_topk
from repro.retrieval import SearchParams
from repro.retrieval.merge import merge_topk
from repro.serve.balancer import StageTimingBalancer
from repro.serve.batcher import AsyncSeismicServer
from repro.serve.queue import Request

MODES = ("mirror", "shard")


class _ShardJob:
    """One micro-batch fanned out to every shard; the last replica to
    deposit its part runs the merge + fulfil."""

    __slots__ = ("batch", "coords", "vals", "width", "seq", "dispatch_t",
                 "parts", "t0_min", "t1_max", "failed", "_lock",
                 "_remaining", "view")

    def __init__(self, batch: list[Request], coords: np.ndarray,
                 vals: np.ndarray, width: int, seq: int,
                 dispatch_t: float, n_replicas: int, view: tuple):
        self.batch = batch
        self.coords = coords
        self.vals = vals
        self.width = width
        self.seq = seq
        self.dispatch_t = dispatch_t
        # (replicas, per_shard, n_docs, merge) snapshotted at dispatch:
        # every shard part of ONE job scores the SAME index generation
        # even if swap_index lands mid-fan-out (a torn job would merge
        # top-k lists from two different corpora)
        self.view = view
        self.parts: dict[int, tuple] = {}
        self.t0_min = float("inf")
        self.t1_max = 0.0
        self.failed = False
        self._lock = threading.Lock()
        self._remaining = n_replicas

    def add(self, rid: int, part, t0: float, t1: float) -> bool:
        """Deposit shard ``rid``'s result; True when this was the last
        outstanding part AND no part failed (caller merges)."""
        with self._lock:
            self.parts[rid] = part
            self.t0_min = min(self.t0_min, t0)
            self.t1_max = max(self.t1_max, t1)
            self._remaining -= 1
            return self._remaining == 0 and not self.failed

    def fail(self) -> bool:
        """Mark the job failed; True for the first failing shard only
        (that one fails the batch futures)."""
        with self._lock:
            self._remaining -= 1
            first = not self.failed
            self.failed = True
            return first


class ReplicaSeismicServer(AsyncSeismicServer):
    """Micro-batching server with N replica workers behind one queue.

    Parameters (on top of ``AsyncSeismicServer``'s)
    ----------
    index           ``mode="mirror"``: one ``SeismicIndex`` shared by
                    every replica. ``mode="shard"``: the stacked pytree
                    from ``build_sharded_index`` (leading axis = shard).
    n_replicas      worker count. Required for mirror; defaults to the
                    stacked leading axis for shard (must match if
                    given).
    mode            ``mirror`` | ``shard`` (see module docstring).
    balancer        routing policy; default
                    ``StageTimingBalancer(n_replicas)``. Mirror mode
                    routes each batch through ``balancer.pick()``;
                    shard mode fans out but still feeds per-replica
                    timings for the rollup gauges.
    replica_delay_s artificial per-launch latency: scalar (uniform) or
                    one value per replica.
    n_docs          live corpus size for shard mode (pre-padding
                    ``docs.n``); bounds globalized ids at the merge.
                    Defaults to ``n_replicas * per_shard`` — the
                    content-based pad mask still applies either way.
    mailbox_depth   per-replica dispatch buffer; a full mailbox
                    backpressures the dispatcher (and, via vtime, the
                    balancer already steers away from slow replicas).

    ``stage_timing`` (and sampled staged launches) are mirror-mode
    features: shard-mode launches run the fused pipeline per shard and
    attach no stage spans to the merged trace.
    """

    def __init__(self, index, params: SearchParams, *,
                 n_replicas: int | None = None, mode: str = "mirror",
                 balancer: StageTimingBalancer | None = None,
                 replica_delay_s=None, n_docs: int | None = None,
                 mailbox_depth: int = 8, **kw):
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; choose from {MODES}")
        if mode == "mirror":
            if n_replicas is None or n_replicas < 1:
                raise ValueError("mirror mode needs n_replicas >= 1")
            shards = None
            representative = index
        else:
            n_shards = jax.tree.leaves(index)[0].shape[0]
            if n_replicas is None:
                n_replicas = n_shards
            elif n_replicas != n_shards:
                raise ValueError(
                    f"n_replicas={n_replicas} != stacked index shards "
                    f"{n_shards}")
            if kw.get("stage_timing"):
                raise ValueError("stage_timing is mirror-mode only; "
                                 "shard launches run fused per shard")
            shards = [jax.tree.map(lambda x, s=s: x[s], index)
                      for s in range(n_shards)]
            representative = shards[0]
        self.mode = mode
        self.n_replicas = n_replicas
        self.mailbox_depth = mailbox_depth
        super().__init__(representative, params, **kw)
        if mode == "shard":
            # shard launches are always fused; drop the staged program
            # (and its device accounting, which binds one index)
            self._fns = None
            self._device = None
            self.per_shard = representative.fwd.coords.shape[0]
            self.n_docs = n_docs if n_docs is not None \
                else n_replicas * self.per_shard
            self._replicas = [(s, None) for s in shards]
            k, nd = self.params.k, self.n_docs
            self._merge = jax.jit(
                lambda cand, scores: merge_topk(cand, scores, k, nd))
        else:
            self.n_docs = n_docs
            self._replicas = [(self.index, self._fns)] * n_replicas
        self.balancer = balancer if balancer is not None \
            else StageTimingBalancer(n_replicas)
        if self.balancer.n_replicas != n_replicas:
            raise ValueError(
                f"balancer covers {self.balancer.n_replicas} replicas, "
                f"server has {n_replicas}")
        if replica_delay_s is None:
            self._delay = [0.0] * n_replicas
        elif np.isscalar(replica_delay_s):
            self._delay = [float(replica_delay_s)] * n_replicas
        else:
            self._delay = [float(d) for d in replica_delay_s]
            if len(self._delay) != n_replicas:
                raise ValueError(
                    f"replica_delay_s has {len(self._delay)} entries "
                    f"for {n_replicas} replicas")
        self._mailboxes: list[_queue.Queue] = []
        self._replica_threads: list[threading.Thread] = []
        self._register_replica_gauges()

    # ------------------------------------------------------ observability

    def _register_replica_gauges(self) -> None:
        reg = self.telemetry.registry
        self._replica_dispatches = reg.counter(
            "seismic_replica_dispatches_total",
            "Micro-batches dispatched to each replica", ("replica",))
        cost_g = reg.gauge(
            "seismic_replica_cost_ewma_seconds",
            "Balancer EWMA launch cost per replica", ("replica",))
        share_g = reg.gauge(
            "seismic_replica_dispatch_share",
            "Fraction of dispatches routed to each replica", ("replica",))
        inflight_g = reg.gauge(
            "seismic_replica_inflight",
            "Dispatches not yet acknowledged per replica", ("replica",))
        self._replica_stage_g = reg.gauge(
            "seismic_replica_stage_seconds",
            "EWMA per-stage seconds per replica (staged launches)",
            ("replica", "stage"))
        for rid in range(self.n_replicas):
            cost_g.labels(str(rid)).set_fn(
                lambda rid=rid: self.balancer.cost(rid))
            share_g.labels(str(rid)).set_fn(
                lambda rid=rid: self.balancer.snapshot()
                ["dispatch_share"][rid])
            inflight_g.labels(str(rid)).set_fn(
                lambda rid=rid: self.balancer.snapshot()["inflight"][rid])

    def _on_timing(self, rid: int, seconds: float,
                   stage_seconds: dict[str, float]) -> None:
        """Per-launch feedback from a replica worker into the balancer
        and the per-replica gauges."""
        self.balancer.record(rid, seconds, stage_seconds or None)
        if stage_seconds:
            rollup = self.balancer.snapshot()["stage_cost_ewma_s"][rid]
            for name, ewma in rollup.items():
                if not name.startswith("refine_round_"):
                    self._replica_stage_g.labels(str(rid), name).set(ewma)

    # ------------------------------------------------------- lifecycle

    def start(self, warmup: bool = True) -> "ReplicaSeismicServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        if self.queue.closed:
            raise RuntimeError("server was stopped; its queue is closed "
                               "— build a new ReplicaSeismicServer")
        self._mailboxes = [_queue.Queue(maxsize=self.mailbox_depth)
                           for _ in range(self.n_replicas)]
        self._replica_threads = [
            threading.Thread(target=self._replica_loop, args=(rid,),
                             name=f"seismic-replica-{rid}", daemon=True)
            for rid in range(self.n_replicas)]
        for t in self._replica_threads:
            t.start()
        return super().start(warmup=warmup)

    def warmup(self) -> None:
        super().warmup()
        if self.mode == "shard":
            self._warmup_merge(self._merge, self.params.k)

    def _warmup_merge(self, merge, k: int) -> None:
        for width in self.launch_widths:
            cand = jnp.full((width, self.n_replicas * k), -1,
                            jnp.int32)
            scores = jnp.full((width, self.n_replicas * k),
                              -jnp.inf, jnp.float32)
            jax.block_until_ready(merge(cand, scores))

    # ----------------------------------------------------- index swap

    def _publish_swap(self, index, params, fns, device) -> None:
        super()._publish_swap(index, params, fns, device)
        if self.mode == "mirror":
            # republish the mirror list wholesale; replica loops
            # re-read it per item, so the next batch on every replica
            # serves the new generation
            self._replicas = [(self.index, self._fns)] * self.n_replicas

    def swap_index(self, index, params: SearchParams | None = None, *,
                   warmup: bool = True, n_docs: int | None = None) -> int:
        """Mirror mode: identical to ``AsyncSeismicServer.swap_index``
        (every replica flips to the new index on its next batch). Shard
        mode: ``index`` is a new ``build_sharded_index`` stacked pytree
        with the SAME shard count; per-shard state (slices, globalize
        offsets, merge program) is republished atomically, and in-flight
        shard jobs finish on their dispatch-time view."""
        if self.mode == "mirror":
            return super().swap_index(index, params, warmup=warmup)
        params = self.params if params is None else params
        n_shards = jax.tree.leaves(index)[0].shape[0]
        if n_shards != self.n_replicas:
            raise ValueError(
                f"stacked index has {n_shards} shards; server has "
                f"{self.n_replicas} replicas (shard swap cannot resize)")
        shards = [jax.tree.map(lambda x, s=s: x[s], index)
                  for s in range(n_shards)]
        rep = shards[0]
        from repro.graph.refine import validate_refine_params
        from repro.tune.policy import validate_tuned_index
        validate_refine_params(rep, params)
        validate_tuned_index(rep)
        per_shard = rep.fwd.coords.shape[0]
        nd = n_docs if n_docs is not None else n_shards * per_shard
        k = params.k
        merge = jax.jit(
            lambda cand, scores: merge_topk(cand, scores, k, nd))
        if warmup:
            self._warmup_for(rep, params, None)
            self._warmup_merge(merge, k)
        with self._swap_lock:
            self._publish_swap(rep, params, None, None)
            self.per_shard = per_shard
            self.n_docs = nd
            self._merge = merge
            self._replicas = [(s, None) for s in shards]
            epoch = self.epoch
        self._register_gauges()
        self.telemetry.inc("swaps")
        return epoch

    # ---------------------------------------------------------- worker

    def _worker(self) -> None:
        """Dispatcher: pull micro-batches off the ONE queue, route to
        replica mailboxes; on shutdown drain, send sentinels, join."""
        try:
            while True:
                batch = self.queue.next_batch(self.max_batch)
                if batch is None:
                    return
                try:
                    if self.mode == "mirror":
                        rid = self.balancer.pick()
                        self._replica_dispatches.labels(str(rid)).inc()
                        self._mailboxes[rid].put(batch)
                    else:
                        self._dispatch_shard_job(batch)
                except Exception as e:   # noqa: BLE001 — fail batch, keep routing
                    for r in batch:
                        self._fail_all(r, f"error: {type(e).__name__}: {e}")
        finally:
            for box in self._mailboxes:
                box.put(None)
            for t in self._replica_threads:
                t.join()
            self._replica_threads = []

    def _dispatch_shard_job(self, batch: list[Request]) -> None:
        tel = self.telemetry
        n = len(batch)
        width = self._pick_width(n)
        tel.inc(f"launch_width_{width}")
        tel.inc("dispatched", n)
        coords, vals = self._pack(batch, width)
        with self._swap_lock:
            view = (self._replicas, self.per_shard, self.n_docs,
                    self._merge)
        job = _ShardJob(batch, coords, vals, width, self._next_seq(),
                        time.monotonic(), self.n_replicas, view)
        for rid, box in enumerate(self._mailboxes):
            self._replica_dispatches.labels(str(rid)).inc()
            box.put(job)

    def _replica_loop(self, rid: int) -> None:
        delay = self._delay[rid]
        while True:
            item = self._mailboxes[rid].get()
            if item is None:
                return
            # re-read the replica's (index, fns) for EVERY item: the
            # list object is republished wholesale by swap_index, so a
            # mirror replica picks up a swapped index on its next batch
            # instead of serving the retired generation forever
            index, fns = self._replicas[rid]
            try:
                if isinstance(item, _ShardJob):
                    self._run_shard_part(rid, item)
                else:
                    self._launch(
                        item, index=index, fns=fns, delay_s=delay,
                        span_attrs={"replica": rid},
                        on_timing=lambda s, st, rid=rid:
                            self._on_timing(rid, s, st))
            except Exception as e:   # noqa: BLE001 — fail batch, keep serving
                status = f"error: {type(e).__name__}: {e}"
                if isinstance(item, _ShardJob):
                    if item.fail():
                        for r in item.batch:
                            self._fail_all(r, status)
                else:
                    for r in item:
                        self._fail_all(r, status)

    # ------------------------------------------------------ shard mode

    def _run_shard_part(self, rid: int, job: _ShardJob) -> None:
        """Score one shard, globalize + pad-mask its top-k, deposit;
        the last shard in merges and fulfils the whole batch. All shard
        state comes from the job's dispatch-time view, never ``self``
        (see ``_ShardJob.view``)."""
        replicas, per_shard, n_docs, _ = job.view
        index, _ = replicas[rid]
        ids, scores, ev, t0, t1, _, _ = self._execute(
            index, None, job.coords, job.vals, False, self._delay[rid])
        self._on_timing(rid, t1 - t0, {})
        # same invariant as the shard_map path: mask pad hits to
        # (-inf, -1) BEFORE anything crosses the shard boundary
        m_scores, m_gids = mask_shard_topk(
            jnp.asarray(scores), jnp.asarray(ids), index.fwd,
            rid * per_shard, n_docs=n_docs)
        part = (np.asarray(m_gids), np.asarray(m_scores), ev)
        if job.add(rid, part, t0, t1):
            self._finish_shard_job(job)

    def _finish_shard_job(self, job: _ShardJob) -> None:
        tel = self.telemetry
        n = len(job.batch)
        parts = [job.parts[r] for r in range(self.n_replicas)]
        all_g = np.concatenate([p[0] for p in parts], axis=1)
        all_s = np.concatenate([p[1] for p in parts], axis=1)
        merge = job.view[3]
        top_s, top_ids, _ = merge(jnp.asarray(all_g),
                                  jnp.asarray(all_s))
        # docs_evaluated is the total exactly-scored docs ACROSS shards
        ev = np.sum([p[2] for p in parts], axis=0)
        top_ids = np.asarray(top_ids)
        top_s = np.asarray(top_s)
        t1 = time.monotonic()
        tel.record_latency("launch", t1 - job.t0_min)
        self._account(n, job.width, ev, False, (), {})
        # shard-mode audits are recall-only (no funnel captures: shard
        # launches run fused, and memberships are per-shard anyway);
        # the auditor must be built over the FULL corpus index so its
        # oracle sees the same doc-id space as the merged top-k
        audit_span = None
        if self.auditor is not None:
            rows = self.auditor.plan(n)
            if rows:
                a0 = time.monotonic()
                for i in rows:
                    self.auditor.feed(job.coords[i], job.vals[i],
                                      top_ids[i], captures=None, row=i)
                audit_span = (a0, time.monotonic())
        self._fulfil(job.batch, top_ids, top_s, ev,
                     dispatch_t=job.dispatch_t, t1=t1, width=job.width,
                     seq=job.seq, staged=False,
                     span_attrs={"replica": "shard-merge",
                                 "n_shards": self.n_replicas},
                     audit_span=audit_span)
