"""kNN-graph refinement subsystem (recall recovery for tight budgets).

At small ``block_budget`` the inverted index misses near-neighbors of
the documents it does retrieve. This package pairs the index with a
document kNN graph (Bruch et al. 2025, arXiv 2501.11628; the guided-
traversal idea of Mallia et al. 2022) so the pipeline can expand and
exactly rescore those near-misses in one extra batched stage:

    build     ``build_doc_graph`` runs the batched ``search_pipeline``
              over the corpus itself -> ``knn_ids [N, degree]``
              attached to the ``SeismicIndex`` (persisted by
              ``ckpt.save_index`` with pre-graph back-compat);
              ``compact_forward=True`` also rebuilds the padded
              forward index as u8-quantized values + per-doc affine
              (the BigANN-scale memory configuration)
    refine    ``refine_batch`` — pipeline stage 6: gather neighbors of
              the merged top-k, dedupe against already-scored ids,
              rescore through the scorer's own forward plane via the
              batched ``gather_dot`` kernel, re-merge

Query-time knobs live on ``SearchParams``: ``graph_degree`` (<= built
degree; 0 disables, bit-exact with the five-stage pipeline) and
``refine_rounds`` (frontier expansions per query).
"""
from repro.graph.build import (build_doc_graph, compact_forward_index,
                               doc_queries)
from repro.graph.refine import (expand_neighbors, refine_batch,
                                validate_refine_params)

__all__ = [
    "build_doc_graph", "compact_forward_index", "doc_queries",
    "expand_neighbors", "refine_batch", "validate_refine_params",
]
