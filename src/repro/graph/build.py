"""Offline kNN-graph construction over a built Seismic index.

The graph is built by running the EXISTING batched ``search_pipeline``
over the corpus itself: every document's padded-sparse row becomes a
query, the pipeline's merged top-(degree+1) answers it, and the
document's own id is dropped from its result row. Two things fall out
of that choice:

  * the build is a corpus-sized stress test of the batched retrieval
    kernels (fixed-shape chunked launches, one compile), and
  * graph quality inherits the index's accuracy knobs — a generous
    ``build_params`` (large ``block_budget``) gives near-exact edges.

``compact_forward=True`` additionally rebuilds the padded forward
index as u8-quantized values with per-doc affine (scale, zero) and
u16 coords (dim < 65536) BEFORE the graph build, so both the scorer
stage and the refine stage's rescore run the fused-dequant
``gather_dot`` path over one compact ``[n_docs, doc_nnz]`` plane —
the BigANN-scale memory configuration. Refinement always rescores
through the index's own forward plane (see ``refine.py`` on why score
consistency with the scorer is load-bearing), so compaction is a
whole-pipeline decision, not a refine-only one.

Neighbors are stored score-descending with the sentinel ``n_docs``
padding missing edges, so any prefix of a higher-degree build is a
valid lower-degree graph (``SearchParams.graph_degree`` may be any
value up to the built degree).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

import numpy as np
import jax.numpy as jnp

from repro.retrieval.params import SearchParams
from repro.sparse.ops import PaddedSparse
from repro.sparse.quant import dequantize_u8, quantize_u8

if TYPE_CHECKING:  # annotation-only: repro.core imports the retrieval
    from repro.core.types import SeismicIndex  # pipeline, which imports
    #                                            repro.graph — a module-
    #                                            level import here would
    #                                            close that cycle


def doc_queries(index: SeismicIndex) -> PaddedSparse:
    """The corpus as a query batch: dequantized f32 forward rows."""
    fwd = index.fwd
    if index.fwd_scale is not None:
        vals = dequantize_u8(fwd.vals, index.fwd_scale, index.fwd_zero)
    else:
        vals = fwd.vals.astype(jnp.float32)
    return PaddedSparse(fwd.coords.astype(jnp.int32), vals, fwd.dim)


def compact_forward_index(index: SeismicIndex) -> SeismicIndex:
    """Swap the forward plane for its u8-quantized padded layout
    (per-doc affine scale/zero, u16 coords when dim < 65536) — the
    same compaction ``SeismicConfig.fwd_quant`` applies at build time.
    No-op if the index is already compact."""
    if index.fwd_scale is not None:
        return index
    q, scale, zero = quantize_u8(index.fwd.vals.astype(jnp.float32))
    cdt = jnp.uint16 if index.dim < 65536 else jnp.int32
    fwd = PaddedSparse(index.fwd.coords.astype(cdt), q, index.dim)
    cfg = dataclasses.replace(index.config, fwd_quant=True)
    return dataclasses.replace(index, fwd=fwd, fwd_scale=scale,
                               fwd_zero=zero, config=cfg)


def _drop_self(ids: np.ndarray, start: int, degree: int,
               n_docs: int) -> np.ndarray:
    """Per row: remove the row's own doc id and -1 padding, keep the
    first ``degree`` survivors (score order preserved), sentinel-pad."""
    rows = ids.shape[0]
    own = (start + np.arange(rows))[:, None]
    keep = (ids != own) & (ids >= 0)
    # stable argsort on ~keep floats kept entries to the front in order
    order = np.argsort(~keep, axis=1, kind="stable")
    picked = np.take_along_axis(ids, order, axis=1)[:, :degree]
    kept = np.take_along_axis(keep, order, axis=1)[:, :degree]
    return np.where(kept, picked, n_docs).astype(np.int32)


def build_doc_graph(index: SeismicIndex, *, degree: int = 8,
                    build_params: SearchParams | None = None,
                    batch: int = 256,
                    compact_forward: bool = False) -> SeismicIndex:
    """Attach a document kNN graph to a built index; returns the
    extended index (the ``knn_ids`` artifact rides the ``SeismicIndex``
    pytree, so ``ckpt.save_index`` persists it with back-compat).

    ``build_params`` defaults to a generous budget-policy search with
    ``k = degree + 1`` (the +1 absorbs the self match). The corpus is
    chunked into fixed ``[batch, nnz_d]`` launches so the jitted
    pipeline compiles once.
    """
    # deferred: retrieval.pipeline imports repro.graph.refine, so a
    # module-level import here would close an import cycle through the
    # package __init__
    from repro.retrieval.pipeline import search_pipeline
    if degree <= 0:
        raise ValueError(f"degree must be positive, got {degree}")
    if build_params is None:
        build_params = SearchParams(
            k=degree + 1, cut=8, block_budget=64, policy="budget")
    elif build_params.k < degree + 1:
        raise ValueError(
            f"build_params.k={build_params.k} cannot yield degree="
            f"{degree} neighbors after dropping the self match")
    if compact_forward:
        index = compact_forward_index(index)
    n = index.n_docs
    queries = doc_queries(index)
    nbrs = np.empty((n, degree), np.int32)
    for s in range(0, n, batch):
        chunk = queries[s:s + batch]
        real = chunk.n
        pad = batch - real
        if pad:      # last chunk: pad to the compiled launch shape
            chunk = PaddedSparse(
                jnp.pad(chunk.coords, ((0, pad), (0, 0))),
                jnp.pad(chunk.vals, ((0, pad), (0, 0))), chunk.dim)
        _, ids, _ = search_pipeline(index, chunk, build_params)
        nbrs[s:s + real] = _drop_self(np.asarray(ids)[:real], s, degree, n)
    return dataclasses.replace(index, knn_ids=jnp.asarray(nbrs))
