"""Stage 6 — refine: kNN-graph neighbor expansion + exact rescore.

The inverted index trades recall for speed at small ``block_budget``:
near-miss documents fall outside the selected blocks even though they
sit right next to retrieved documents in embedding space. The
refinement stage (Bruch et al. 2025, arXiv 2501.11628; guided
traversal of Mallia et al. 2022) recovers them without touching the
inverted index again:

    1. gather the graph neighbors of the current merged top-k
       (``knn_ids``), giving ``[Q, k * graph_degree]`` candidates;
    2. dedupe — among the expansion (``scorer.dedupe_batch``) and
       against every id scored in any earlier round or the original
       merge (sentinel masking), so no document is rescored twice and
       only the genuinely new frontier pays scoring work;
    3. exactly rescore the survivors through the scorer stage's
       ``score_candidates`` — the SAME forward plane and batched
       ``gather_dot`` kernel as phase S (u8 dequant fused on a compact
       forward index), so merged scores are consistent across stages;
    4. re-merge to top-k; repeat ``refine_rounds`` times.

Score consistency in step 3 is load-bearing: rescoring through any
*other* value plane (e.g. an independently quantized copy) mixes two
score scales in one merge, and quantization-inflated imposters can
displace exactly-scored true positives — refinement would then LOSE
recall at high-recall operating points. Scoring through the scorer's
plane makes the merged objective uniform, so the candidate pool only
ever grows under it and recall@k is monotone non-decreasing in
``refine_rounds`` (up to exact score ties).

``refine_rounds == 0`` or ``graph_degree == 0`` is a bit-exact no-op:
the stage returns its inputs untouched at trace time, so pipelines
without the knob compile to the PR 3 program unchanged.

``SearchParams.fuse_level`` changes execution, not results: level 1
compacts each round's frontier before the candidate-driven scoring
kernel (sentinel tiles skipped); level 2 fuses the whole round —
expand, dedupe, seen-mask, compact, rescore — into one Pallas launch
(:mod:`repro.kernels.refine_fused`), so the ``[Q, k * graph_degree]``
expansion is never materialized in HBM. All levels are bit-exact.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp

from repro.retrieval.params import SearchParams

if TYPE_CHECKING:  # annotation-only: keeps repro.graph import-cycle-free
    from repro.core.types import SeismicIndex


def validate_refine_params(index: SeismicIndex, p: SearchParams) -> None:
    """Fail fast when the refinement knobs don't match the index."""
    if p.graph_degree <= 0:
        return
    if index.knn_ids is None:
        raise ValueError(
            f"graph refinement requested (graph_degree={p.graph_degree}) "
            "but the index has no kNN graph; attach one with "
            "repro.graph.build_doc_graph")
    built = index.knn_ids.shape[1]
    if p.graph_degree > built:
        raise ValueError(
            f"graph_degree={p.graph_degree} exceeds the built graph "
            f"degree {built}; rebuild with a larger degree or lower the "
            "knob (neighbors are score-ordered, so any prefix is valid)")


def expand_neighbors(index: SeismicIndex, ids: jax.Array,
                     degree: int) -> jax.Array:
    """Graph neighbors of the current top-k -> [Q, k * degree] doc ids.

    ``ids`` carries -1 padding; padded rows expand to the sentinel
    ``n_docs``. Neighbors are stored score-descending, so taking the
    first ``degree`` columns is the best-edge prefix of a
    larger-degree build.
    """
    safe = jnp.clip(ids, 0, index.n_docs - 1)
    nbrs = jnp.take(index.knn_ids, safe, axis=0,
                    mode="clip")[..., :degree]          # [Q, k, deg]
    nbrs = jnp.where(ids[..., None] >= 0, nbrs, index.n_docs)
    qn = ids.shape[0]
    return nbrs.reshape(qn, -1).astype(jnp.int32)


def scored_init(ids: jax.Array, n_docs: int) -> jax.Array:
    """The seen-set seed for round 0: the original merge's ids with
    padding mapped to the sentinel."""
    return jnp.where(ids >= 0, ids, n_docs)


def refine_one_round(index: SeismicIndex, q_dense: jax.Array,
                     scores: jax.Array, ids: jax.Array, ev: jax.Array,
                     scored: jax.Array, p: SearchParams
                     ) -> tuple[jax.Array, jax.Array, jax.Array,
                                jax.Array]:
    """ONE expand + rescore + re-merge round.

    ``scored`` is every id scored in any earlier round (or the
    original merge), sentinel-padded; the round masks it out of the
    expansion so only the genuinely new frontier pays scoring work,
    and returns it widened by this round's candidates. Factored out of
    :func:`refine_batch` so the staged/traced pipeline can run (and
    time) rounds individually — same ops, bit-exact either way.
    """
    from repro.retrieval.merge import merge_topk
    from repro.retrieval.scorer import dedupe_batch, score_candidates
    if p.fuse_level >= 2:
        # one launch: expand + dedupe + seen-mask + compact +
        # rescore — the [Q, k*degree] expansion never leaves VMEM
        from repro.kernels.refine_fused import refine_round_batch
        cand, new_s = refine_round_batch(
            ids, scored, q_dense, index.knn_ids, index.fwd.coords,
            index.fwd.vals, index.fwd_scale, index.fwd_zero,
            n_docs=index.n_docs, degree=p.graph_degree)
    else:
        from repro.retrieval.scorer import compact_candidates
        cand = dedupe_batch(
            expand_neighbors(index, ids, p.graph_degree), index.n_docs)
        seen = (cand[:, :, None] == scored[:, None, :]).any(-1)
        cand = jnp.where(seen, index.n_docs, cand)
        if p.fuse_level >= 1:
            cand = compact_candidates(cand)
        new_s = score_candidates(index, q_dense, cand, p.use_kernel,
                                 fuse_level=p.fuse_level)
    if index.tombstone is not None:
        # stale graph edges may still point at deleted docs between
        # compactions (and, post-compaction, reverse edges toward a
        # purged id are rewritten lazily) — mask AFTER scoring so both
        # the fused-kernel and unfused paths are covered
        from repro.retrieval.router import NEG
        from repro.retrieval.scorer import mask_tombstoned
        cand = mask_tombstoned(index, cand)
        new_s = jnp.where(cand < index.n_docs, new_s, NEG)
    all_ids = jnp.concatenate(
        [jnp.where(ids >= 0, ids, index.n_docs), cand], axis=1)
    all_s = jnp.concatenate([scores, new_s], axis=1)
    ev = ev + (cand < index.n_docs).sum(axis=-1)
    scores, ids, _ = merge_topk(all_ids, all_s, p.k, index.n_docs)
    return scores, ids, ev, jnp.concatenate([scored, cand], axis=1)


def refine_batch(index: SeismicIndex, q_dense: jax.Array,
                 scores: jax.Array, ids: jax.Array, ev: jax.Array,
                 p: SearchParams
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Neighbor-expand + rescore + re-merge the merged top-k.

    Input/output contract matches ``merge_topk``: (scores [Q, k],
    ids [Q, k] with -1 padding, docs_evaluated [Q]). Traceable; with
    ``refine_rounds == 0`` or ``graph_degree == 0`` it is the
    identity (no ops traced).
    """
    if p.refine_rounds <= 0 or p.graph_degree <= 0:
        return scores, ids, ev
    validate_refine_params(index, p)
    # every id scored in any earlier round (or the original merge):
    # masked out of each round's expansion, so only the genuinely new
    # frontier is rescored and ev counts distinct documents. Grows by
    # k * graph_degree per round — the rounds loop is unrolled, so the
    # widening shape stays static under jit.
    scored = scored_init(ids, index.n_docs)
    for _ in range(p.refine_rounds):
        scores, ids, ev, scored = refine_one_round(
            index, q_dense, scores, ids, ev, scored, p)
    return scores, ids, ev
