"""repro: Seismic (SIGIR'24) as a multi-pod JAX framework.

Layers:
  core/        the paper's contribution (index build + approximate query)
  sparse/      padded-sparse vector substrate
  kernels/     Pallas TPU kernels for the scoring hot-spots
  models/      assigned architecture pool (LM transformers, GNN, recsys)
  data/        synthetic data generators + host pipeline
  train/       optimizer, train loop, grad compression
  serve/       decode + retrieval serving engines
  tune/        recall-target operating-point autotuner (TunedPolicy)
  ckpt/        sharded checkpointing with elastic re-mesh
  distributed/ mesh helpers, sharding rules, roofline math
  configs/     selectable architecture configs (--arch <id>)
  launch/      mesh.py, dryrun.py, train.py, serve.py
"""

from repro import compat as _compat  # noqa: F401  (jax API shims, see module)

__version__ = "0.1.0"
