"""Stage 4 — scorer: forward-index exact scoring (paper phase S).

Gathers the member docs of every selected block for the whole batch,
dedupes candidates per query (sort + neighbor mask), and computes the
exact inner products against the forward index. With ``use_kernel``
the batched gather_dot Pallas kernel scores all [Q, C] candidates in
one launch; a compact (u8) forward index dequantizes inside the
kernel.

With ``fuse_level >= 1`` two things change (bit-exact results,
different execution):

* candidates are COMPACTED after dedupe — a second sort packs the live
  ids into a sorted prefix and the duplicate/dead sentinels into the
  tail (:func:`compact_candidates`);
* scoring switches to the candidate-driven kernel
  (:func:`repro.kernels.gather_dot.ops.gather_dot_cand_batch`): the
  forward gather happens inside the kernel (no host-side [Q, C, nnz]
  intermediate) and all-sentinel candidate tiles are skipped entirely,
  so scored work shrinks with the dedupe rate instead of being paid on
  every padded slot.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp

from repro.retrieval.router import NEG, RoutedBatch
from repro.retrieval.selector import Selection
from repro.sparse.quant import dequantize_u8

if TYPE_CHECKING:  # annotation-only: keeps repro.retrieval import-cycle-free
    from repro.core.types import SeismicIndex


def gather_block_docs(index: SeismicIndex, lists: jax.Array,
                      blocks: jax.Array) -> jax.Array:
    """Member doc ids of selected flat blocks -> [Q, B, block_cap].

    ``blocks`` indexes the flattened (cut, n_blocks) axis of the router
    output; out-of-length slots pad with the sentinel ``n_docs``.
    """
    nb = index.config.n_blocks
    li = blocks // nb                               # [Q, B] probed-slot id
    bi = blocks % nb
    coord = jnp.take_along_axis(lists, li, axis=1)  # [Q, B] coordinate
    off = index.block_off[coord, bi]                # [Q, B]
    ln = index.block_len[coord, bi]
    ar = jnp.arange(index.config.block_cap)
    pos = jnp.clip(off[..., None] + ar, 0, index.config.lam - 1)
    docs = jnp.take_along_axis(index.list_docs[coord], pos, axis=2)
    return jnp.where(ar < ln[..., None], docs, index.n_docs)


def mask_tombstoned(index: SeismicIndex, cand: jax.Array) -> jax.Array:
    """Deleted candidates -> sentinel (identity when the index carries
    no tombstones — the trace-time gate keeps immutable-index programs
    byte-identical).

    Masking at the ID level (not the score level) keeps
    ``docs_evaluated`` consistent with a fresh build of the equivalent
    corpus: a deleted doc is not a candidate at all, rather than a
    candidate with a -inf score.
    """
    if index.tombstone is None:
        return cand
    dead = jnp.take(index.tombstone, cand, mode="clip")
    return jnp.where(dead, index.n_docs, cand)


def score_tail(index: SeismicIndex, q_dense: jax.Array
               ) -> tuple[jax.Array, jax.Array]:
    """Exact scores for the unblocked tail segment -> ([Q, T], [Q, T]).

    Tail docs (``index.tail_ids``) bypass routing/selection entirely:
    they are appended to every query's candidate set and scored through
    the same forward plane as blocked candidates. Zero-score tail docs
    (no coordinate overlap with the query) are masked back to the
    sentinel — a fresh build would never have surfaced them as
    candidates, so both the merge and ``docs_evaluated`` stay
    bit-consistent with the equivalent immutable index.

    Tail ids are always larger than every blocked doc id (ids are
    assigned monotonically and the tail drains at compaction), so
    appending the tail after the deduped block candidates preserves
    the ascending live-candidate order ``merge_topk`` tie-breaking
    relies on. Tail/block candidate sets are disjoint by construction
    (a doc is either compacted into blocks or still in the tail), so
    no cross-segment dedupe is needed.
    """
    tail = mask_tombstoned(index, index.tail_ids)            # [T]
    cand = jnp.broadcast_to(tail[None, :],
                            (q_dense.shape[0], tail.shape[0]))
    scores = score_candidates(index, q_dense, cand, use_kernel=False)
    live = (cand < index.n_docs) & (scores > 0)
    return jnp.where(live, cand, index.n_docs), \
        jnp.where(live, scores, NEG)


def dedupe_batch(cand: jax.Array, n_docs: int) -> jax.Array:
    """Sort each query's candidate ids and mask duplicates to the
    sentinel. [Q, C] -> [Q, C]."""
    s = jnp.sort(cand, axis=-1)
    dup = jnp.concatenate(
        [jnp.zeros((cand.shape[0], 1), bool), s[:, 1:] == s[:, :-1]], axis=1)
    return jnp.where(dup, n_docs, s)


def compact_candidates(cand: jax.Array) -> jax.Array:
    """Pack live candidate ids into a sorted prefix, sentinels into the
    tail. [Q, C] -> [Q, C].

    After :func:`dedupe_batch` the live ids are ascending but the
    duplicate sentinels sit interspersed among them; one more sort
    moves every sentinel (== n_docs, larger than any live id) to the
    tail while PRESERVING the relative order of the live ids — both
    orders are ascending, so downstream ``merge_topk`` tie-breaking
    (first occurrence wins) is unchanged and results stay bit-exact.
    The payoff is the candidate-driven kernel's tile skip: live work
    concentrates in the leading tiles and the sentinel tail is never
    gathered or scored.
    """
    return jnp.sort(cand, axis=-1)


def score_candidates(index: SeismicIndex, q_dense: jax.Array,
                     cand: jax.Array, use_kernel: bool, *,
                     fuse_level: int = 0) -> jax.Array:
    """Exact <q, doc> for candidate ids [Q, C] (sentinel -> -inf).

    With a compact (fwd_quant) index the per-doc u8 dequant fuses into
    the gather-dot; scores stay 'exact' up to ~0.4% value quantization.
    At ``fuse_level >= 1`` the candidate-driven kernel gathers forward
    rows in-kernel and skips all-sentinel tiles (see module docstring);
    ``use_kernel`` governs only the unfused path.
    """
    if fuse_level >= 1:
        from repro.kernels.gather_dot.ops import gather_dot_cand_batch
        return gather_dot_cand_batch(
            q_dense, cand, index.fwd.coords, index.fwd.vals,
            index.fwd_scale, index.fwd_zero, n_docs=index.n_docs)
    c = jnp.take(index.fwd.coords, cand, axis=0,
                 mode="clip").astype(jnp.int32)              # [Q, C, nnz]
    v = jnp.take(index.fwd.vals, cand, axis=0, mode="clip")
    quant = index.fwd_scale is not None
    scale = zero = None
    if quant:
        scale = jnp.take(index.fwd_scale, cand, mode="clip")
        zero = jnp.take(index.fwd_zero, cand, mode="clip")
    if use_kernel:
        from repro.kernels.gather_dot.ops import gather_dot_batch
        scores = gather_dot_batch(q_dense, c, v, scale, zero)
    else:
        if quant:
            v = dequantize_u8(v, scale, zero)
        else:
            v = v.astype(jnp.float32)
        qn = cand.shape[0]
        gathered = jnp.take_along_axis(
            q_dense, c.reshape(qn, -1), axis=1).reshape(c.shape)
        scores = (gathered * v).sum(axis=-1)
    return jnp.where(cand < index.n_docs, scores, NEG)


def score_selection(index: SeismicIndex, batch: RoutedBatch,
                    sel: Selection, use_kernel: bool, *,
                    fuse_level: int = 0) -> tuple[jax.Array, jax.Array]:
    """Selected blocks -> (cand [Q, B*cap], exact scores [Q, B*cap]).

    Blocks carrying a -inf selection score (dead / pruned / already
    evaluated) contribute only sentinel candidates. ``fuse_level >= 1``
    compacts the deduped candidates before the (candidate-driven)
    kernel scores them — bit-exact, see module docstring.

    On a mutable index (``repro.core.mutate``) two extra columns of
    work appear: tombstoned candidates are masked to the sentinel
    before dedupe, and the exactly-scored tail segment is appended
    after the blocked candidates (:func:`score_tail`).
    """
    docs = gather_block_docs(index, batch.lists, sel.blocks)
    docs = jnp.where(jnp.isfinite(sel.block_scores)[..., None], docs,
                     index.n_docs)
    qn = docs.shape[0]
    cand = dedupe_batch(mask_tombstoned(index, docs.reshape(qn, -1)),
                        index.n_docs)
    if fuse_level >= 1:
        cand = compact_candidates(cand)
    scores = score_candidates(index, batch.q_dense, cand, use_kernel,
                              fuse_level=fuse_level)
    if index.tail_ids is not None:
        tail_cand, tail_scores = score_tail(index, batch.q_dense)
        cand = jnp.concatenate([cand, tail_cand], axis=1)
        scores = jnp.concatenate([scores, tail_scores], axis=1)
    return cand, scores
