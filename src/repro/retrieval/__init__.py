"""Batch-first staged retrieval pipeline (the Seismic execution path).

Architecture
============

Every search — local ``search_batch``, served ``SeismicServer.search``,
and each doc shard of the distributed ``shard_map`` search — executes
the SAME staged pipeline. Each stage is a pure function over whole
``[Q, ...]`` query batches (no vmap over a scalar-query function), so
the hot phases lower to one natively-batched Pallas kernel launch per
batch and every stage can be timed, swapped, or sharded independently:

    prep      queries [Q, nnz]  ->  q_dense [Q, d], probed lists [Q, cut]
              (batch densify + top-``cut`` coordinate selection,
              Alg. 2 line 1)
    router    probed lists      ->  r [Q, cut * n_blocks]
              (quantized summary inner products, paper phase R;
              ``kernels/summary_dot`` batched kernel, u8 dequant fused)
    selector  r                 ->  Selection(blocks [Q, B], scores)
              (pluggable block-selection policy — the decisive
              accuracy/cost lever; see the registry below)
    scorer    blocks            ->  cand [Q, C], exact scores [Q, C]
              (forward-index gather + dedupe + exact inner products,
              paper phase S; ``kernels/gather_dot`` batched kernel,
              compact-index u8 dequant fused)
    merge     cand, scores      ->  top-k ids/scores + docs_evaluated
    refine    top-k             ->  top-k (recall-recovered)
              (kNN-graph neighbor expansion + exact rescore + re-merge,
              ``repro.graph``; gated on ``SearchParams.graph_degree`` /
              ``refine_rounds`` — 0 traces as the identity)

Stage contract
--------------

* Stages are jit-traceable pure functions of fixed-shape arrays; all
  shapes are static given ``SearchParams`` (a hashable static arg).
* Candidate padding uses the sentinel doc id ``index.n_docs``; dead or
  masked blocks carry a ``-inf`` score and contribute only sentinels.
* A selector is ``fn(index, batch: RoutedBatch, p) -> Selection`` and
  is looked up from ``SearchParams.policy`` via the registry:

      ``budget``            top block_budget blocks by summary score
      ``adaptive``          two-stage heap_factor pruning (Alg. 2)
      ``global_threshold``  BMP-style: keep blocks whose summary score
                            clears a fraction of the per-query max
                            (Block-Max Pruning, Mallia et al. 2024)

  Register new policies with ``register_selector``; they become valid
  ``SearchParams.policy`` values everywhere (local/served/distributed)
  with no further wiring.

Entry points
------------

``search_pipeline(index, queries, p)``  jitted batched search
``run_pipeline(index, q_coords, q_vals, p)``  traceable core (use
inside shard_map / larger jitted programs).
``stage_fns`` / ``run_pipeline_staged``  the same pipeline as six
standalone-jitted stages with per-stage wall-time reporting — the
timing hooks behind serving telemetry and the stage benchmark.
"""
from repro.retrieval.merge import merge_topk
from repro.retrieval.params import SearchParams
from repro.retrieval.pipeline import (STAGES, run_pipeline,
                                      run_pipeline_staged, search_pipeline,
                                      stage_fns)
from repro.retrieval.prep import prep_queries
from repro.retrieval.router import route_batch, router_work, RoutedBatch
from repro.retrieval.scorer import score_selection
from repro.retrieval.selector import (Selection, get_selector,
                                      register_selector, selector_names)

__all__ = [
    "SearchParams", "RoutedBatch", "Selection",
    "prep_queries", "route_batch", "router_work", "score_selection",
    "merge_topk",
    "run_pipeline", "search_pipeline",
    "STAGES", "stage_fns", "run_pipeline_staged",
    "get_selector", "register_selector", "selector_names",
]
