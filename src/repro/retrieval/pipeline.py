"""Pipeline orchestration: prep -> router -> selector -> scorer -> merge.

``run_pipeline`` is the traceable batch-first core shared by every
execution surface (local search_batch, SeismicServer, the distributed
shard_map search); ``search_pipeline`` is its jitted front door.
"""
from __future__ import annotations

from functools import partial
from typing import TYPE_CHECKING

import jax

from repro.retrieval.merge import merge_topk
from repro.retrieval.params import SearchParams
from repro.retrieval.prep import prep_queries
from repro.retrieval.router import route_batch
from repro.retrieval.scorer import score_selection
from repro.retrieval.selector import get_selector
from repro.sparse.ops import PaddedSparse

if TYPE_CHECKING:  # annotation-only: keeps repro.retrieval import-cycle-free
    from repro.core.types import SeismicIndex


def run_pipeline(index: SeismicIndex, q_coords: jax.Array,
                 q_vals: jax.Array, p: SearchParams
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Batched staged search over padded-sparse queries [Q, nnz].

    Returns (scores [Q, k], ids [Q, k] with -1 padding,
    docs_evaluated [Q]). Traceable: safe inside jit / shard_map.
    """
    select = get_selector(p.policy)                 # static under jit
    q_dense, lists, _ = prep_queries(q_coords, q_vals, index.dim, p.cut)
    batch = route_batch(index, q_dense, lists, p.use_kernel)
    sel = select(index, batch, p)
    cand, scores = score_selection(index, batch, sel, p.use_kernel)
    return merge_topk(cand, scores, p.k, index.n_docs)


@partial(jax.jit, static_argnames=("p",))
def search_pipeline(index: SeismicIndex, queries: PaddedSparse,
                    p: SearchParams):
    """Jitted batched Seismic search (the shared execution path).

    Returns (scores [Q,k], ids [Q,k] with -1 padding, docs_evaluated [Q]).
    """
    return run_pipeline(index, queries.coords, queries.vals, p)
