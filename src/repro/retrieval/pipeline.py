"""Pipeline orchestration: prep -> router -> selector -> scorer ->
merge -> refine.

``run_pipeline`` is the traceable batch-first core shared by every
execution surface (local search_batch, SeismicServer, the distributed
shard_map search); ``search_pipeline`` is its jitted front door.
``stage_fns`` / ``run_pipeline_staged`` expose the same pipeline as
six standalone-jitted stages for per-stage latency attribution (the
serving telemetry and the stage-throughput benchmark both hook here).

The sixth stage (refine — kNN-graph neighbor expansion, see
``repro.graph``) is gated on ``SearchParams.graph_degree`` /
``refine_rounds``; with either at 0 it traces as the identity, so the
five-stage program of earlier revisions is reproduced bit-exactly.
"""
from __future__ import annotations

import time
from functools import partial
from typing import TYPE_CHECKING, Callable

import jax

from repro.graph.refine import refine_batch
from repro.retrieval.merge import merge_topk
from repro.retrieval.params import SearchParams
from repro.retrieval.prep import prep_queries
from repro.retrieval.router import route_batch
from repro.retrieval.scorer import score_selection
from repro.retrieval.selector import get_selector
from repro.sparse.ops import PaddedSparse

if TYPE_CHECKING:  # annotation-only: keeps repro.retrieval import-cycle-free
    from repro.core.types import SeismicIndex


def run_pipeline(index: SeismicIndex, q_coords: jax.Array,
                 q_vals: jax.Array, p: SearchParams
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Batched staged search over padded-sparse queries [Q, nnz].

    Returns (scores [Q, k], ids [Q, k] with -1 padding,
    docs_evaluated [Q]). Traceable: safe inside jit / shard_map.
    """
    select = get_selector(p.policy)                 # static under jit
    q_dense, lists, _ = prep_queries(q_coords, q_vals, index.dim, p.cut)
    batch = route_batch(index, q_dense, lists, p)
    sel = select(index, batch, p)
    cand, scores = score_selection(index, batch, sel, p.use_kernel,
                                   fuse_level=p.fuse_level)
    top_s, top_ids, ev = merge_topk(cand, scores, p.k, index.n_docs)
    return refine_batch(index, q_dense, top_s, top_ids, ev, p)


@partial(jax.jit, static_argnames=("p",))
def search_pipeline(index: SeismicIndex, queries: PaddedSparse,
                    p: SearchParams):
    """Jitted batched Seismic search (the shared execution path).

    Returns (scores [Q,k], ids [Q,k] with -1 padding, docs_evaluated [Q]).
    """
    return run_pipeline(index, queries.coords, queries.vals, p)


STAGES = ("prep", "router", "selector", "scorer", "merge", "refine")


def stage_fns(index: SeismicIndex, p: SearchParams
              ) -> dict[str, Callable]:
    """Standalone-jitted stage functions (index and params closed over).

    These are the per-stage timing hooks: each stage compiles on its
    own so a caller can ``block_until_ready`` between stages and
    attribute wall time, at the cost of materializing inter-stage
    arrays (slightly slower end-to-end than the fused
    ``search_pipeline``). Keyed by ``STAGES`` name, plus
    ``refine_round`` — a single refine round for the traced path's
    per-round child spans (compiled lazily, one program per widening
    ``scored`` shape).
    """
    from repro.graph.refine import refine_one_round
    select = get_selector(p.policy)
    return {
        "prep": jax.jit(
            lambda c, v: prep_queries(c, v, index.dim, p.cut)),
        "router": jax.jit(
            lambda qd, ls: route_batch(index, qd, ls, p)),
        "selector": jax.jit(lambda b: select(index, b, p)),
        "scorer": jax.jit(
            lambda b, s: score_selection(index, b, s, p.use_kernel,
                                         fuse_level=p.fuse_level)),
        "merge": jax.jit(lambda c, s: merge_topk(c, s, p.k, index.n_docs)),
        "refine": jax.jit(
            lambda qd, s, i, e: refine_batch(index, qd, s, i, e, p)),
        "refine_round": jax.jit(
            lambda qd, s, i, e, sc: refine_one_round(index, qd, s, i, e,
                                                     sc, p)),
    }


def run_pipeline_staged(index: SeismicIndex, q_coords: jax.Array,
                        q_vals: jax.Array, p: SearchParams,
                        fns: dict[str, Callable] | None = None,
                        record: Callable[[str, float], None] | None = None,
                        span_cb: Callable[[str, float, float], None]
                        | None = None,
                        split_refine: bool = False,
                        probe: Callable[[str, object], None] | None = None,
                        audit: bool = False
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Stage-by-stage pipeline with per-stage wall-time reporting.

    ``record(stage_name, seconds)`` is called once per stage with the
    blocking wall time; ``span_cb(stage_name, t0, t1)`` additionally
    receives the ``time.monotonic`` start/end stamps (the tracer hook).
    With ``split_refine`` the refine stage runs round-by-round and
    ``refine_round_<j>`` intervals are reported to ``span_cb`` (nested
    inside the ``refine`` interval) — identical results, one extra jit
    boundary per round. ``probe(name, value)`` exposes chosen
    intermediates (``("cand", scorer candidate ids)``) to device
    accounting without changing any dataflow; with ``audit`` the probe
    additionally receives the per-stage membership captures the
    quality-plane loss funnel attributes misses from — ``lists``
    (probed coordinates), ``router_r`` (flat block summary scores,
    -inf = unrouted), and ``merge_ids`` (pre-refine merged top-k).
    Pass a prebuilt ``fns`` (from ``stage_fns``) to reuse compiled
    stages across calls; fixed input shapes never recompile. Output
    matches ``search_pipeline``.
    """
    if fns is None:
        fns = stage_fns(index, p)

    def timed(name, fn, *args):
        t0 = time.monotonic()
        out = jax.block_until_ready(fn(*args))
        t1 = time.monotonic()
        if record is not None:
            record(name, t1 - t0)
        if span_cb is not None:
            span_cb(name, t0, t1)
        return out

    q_dense, lists, _ = timed("prep", fns["prep"], q_coords, q_vals)
    batch = timed("router", fns["router"], q_dense, lists)
    sel = timed("selector", fns["selector"], batch)
    cand, scores = timed("scorer", fns["scorer"], batch, sel)
    if probe is not None:
        probe("cand", cand)
        if audit:
            probe("lists", lists)
            probe("router_r", batch.r)
    top_s, top_ids, ev = timed("merge", fns["merge"], cand, scores)
    if audit and probe is not None:
        probe("merge_ids", top_ids)
    if not (split_refine and p.refine_rounds > 0 and p.graph_degree > 0):
        return timed("refine", fns["refine"], q_dense, top_s, top_ids, ev)
    # round-by-round refine: same ops as refine_batch, one jit boundary
    # per round so each round's wall time is attributable
    from repro.graph.refine import scored_init, validate_refine_params
    validate_refine_params(index, p)
    t0 = time.monotonic()
    scored = scored_init(top_ids, index.n_docs)
    s, i, e = top_s, top_ids, ev
    for j in range(p.refine_rounds):
        s, i, e, scored = timed(f"refine_round_{j}", fns["refine_round"],
                                q_dense, s, i, e, scored)
    t1 = time.monotonic()
    if record is not None:
        record("refine", t1 - t0)
    if span_cb is not None:
        span_cb("refine", t0, t1)
    return s, i, e
