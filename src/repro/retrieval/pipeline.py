"""Pipeline orchestration: prep -> router -> selector -> scorer ->
merge -> refine.

``run_pipeline`` is the traceable batch-first core shared by every
execution surface (local search_batch, SeismicServer, the distributed
shard_map search); ``search_pipeline`` is its jitted front door.
``stage_fns`` / ``run_pipeline_staged`` expose the same pipeline as
six standalone-jitted stages for per-stage latency attribution (the
serving telemetry and the stage-throughput benchmark both hook here).

The sixth stage (refine — kNN-graph neighbor expansion, see
``repro.graph``) is gated on ``SearchParams.graph_degree`` /
``refine_rounds``; with either at 0 it traces as the identity, so the
five-stage program of earlier revisions is reproduced bit-exactly.
"""
from __future__ import annotations

import time
from functools import partial
from typing import TYPE_CHECKING, Callable

import jax

from repro.graph.refine import refine_batch
from repro.retrieval.merge import merge_topk
from repro.retrieval.params import SearchParams
from repro.retrieval.prep import prep_queries
from repro.retrieval.router import route_batch
from repro.retrieval.scorer import score_selection
from repro.retrieval.selector import get_selector
from repro.sparse.ops import PaddedSparse

if TYPE_CHECKING:  # annotation-only: keeps repro.retrieval import-cycle-free
    from repro.core.types import SeismicIndex


def run_pipeline(index: SeismicIndex, q_coords: jax.Array,
                 q_vals: jax.Array, p: SearchParams
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Batched staged search over padded-sparse queries [Q, nnz].

    Returns (scores [Q, k], ids [Q, k] with -1 padding,
    docs_evaluated [Q]). Traceable: safe inside jit / shard_map.
    """
    select = get_selector(p.policy)                 # static under jit
    q_dense, lists, _ = prep_queries(q_coords, q_vals, index.dim, p.cut)
    batch = route_batch(index, q_dense, lists, p)
    sel = select(index, batch, p)
    cand, scores = score_selection(index, batch, sel, p.use_kernel,
                                   fuse_level=p.fuse_level)
    top_s, top_ids, ev = merge_topk(cand, scores, p.k, index.n_docs)
    return refine_batch(index, q_dense, top_s, top_ids, ev, p)


@partial(jax.jit, static_argnames=("p",))
def search_pipeline(index: SeismicIndex, queries: PaddedSparse,
                    p: SearchParams):
    """Jitted batched Seismic search (the shared execution path).

    Returns (scores [Q,k], ids [Q,k] with -1 padding, docs_evaluated [Q]).
    """
    return run_pipeline(index, queries.coords, queries.vals, p)


STAGES = ("prep", "router", "selector", "scorer", "merge", "refine")


def stage_fns(index: SeismicIndex, p: SearchParams
              ) -> dict[str, Callable]:
    """Standalone-jitted stage functions (index and params closed over).

    These are the per-stage timing hooks: each stage compiles on its
    own so a caller can ``block_until_ready`` between stages and
    attribute wall time, at the cost of materializing inter-stage
    arrays (slightly slower end-to-end than the fused
    ``search_pipeline``). Keyed by ``STAGES`` name.
    """
    select = get_selector(p.policy)
    return {
        "prep": jax.jit(
            lambda c, v: prep_queries(c, v, index.dim, p.cut)),
        "router": jax.jit(
            lambda qd, ls: route_batch(index, qd, ls, p)),
        "selector": jax.jit(lambda b: select(index, b, p)),
        "scorer": jax.jit(
            lambda b, s: score_selection(index, b, s, p.use_kernel,
                                         fuse_level=p.fuse_level)),
        "merge": jax.jit(lambda c, s: merge_topk(c, s, p.k, index.n_docs)),
        "refine": jax.jit(
            lambda qd, s, i, e: refine_batch(index, qd, s, i, e, p)),
    }


def run_pipeline_staged(index: SeismicIndex, q_coords: jax.Array,
                        q_vals: jax.Array, p: SearchParams,
                        fns: dict[str, Callable] | None = None,
                        record: Callable[[str, float], None] | None = None
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Stage-by-stage pipeline with per-stage wall-time reporting.

    ``record(stage_name, seconds)`` is called once per stage with the
    blocking wall time. Pass a prebuilt ``fns`` (from ``stage_fns``) to
    reuse compiled stages across calls; fixed input shapes never
    recompile. Output matches ``search_pipeline``.
    """
    if fns is None:
        fns = stage_fns(index, p)

    def timed(name, fn, *args):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args))
        if record is not None:
            record(name, time.perf_counter() - t0)
        return out

    q_dense, lists, _ = timed("prep", fns["prep"], q_coords, q_vals)
    batch = timed("router", fns["router"], q_dense, lists)
    sel = timed("selector", fns["selector"], batch)
    cand, scores = timed("scorer", fns["scorer"], batch, sel)
    top_s, top_ids, ev = timed("merge", fns["merge"], cand, scores)
    return timed("refine", fns["refine"], q_dense, top_s, top_ids, ev)
