"""Stage 5 — merge: final batched top-k over exact candidate scores."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def merge_topk(cand: jax.Array, scores: jax.Array, k: int, n_docs: int
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(cand [Q, C], scores [Q, C]) -> (top_s [Q, k], ids [Q, k] with -1
    padding, docs_evaluated [Q])."""
    top_s, pos = jax.lax.top_k(scores, k)
    top_ids = jnp.take_along_axis(cand, pos, axis=1)
    top_ids = jnp.where(jnp.isfinite(top_s), top_ids, -1)
    docs_evaluated = (cand < n_docs).sum(axis=-1)
    return top_s, top_ids.astype(jnp.int32), docs_evaluated
