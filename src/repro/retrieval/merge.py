"""Stage 5 — merge: final batched top-k over exact candidate scores."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def merge_topk(cand: jax.Array, scores: jax.Array, k: int, n_docs: int
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(cand [Q, C], scores [Q, C]) -> (top_s [Q, k], ids [Q, k] with -1
    padding, docs_evaluated [Q]).

    ``k`` may exceed the candidate-axis width C (tiny
    ``block_budget * block_cap`` configs): the top-k clamps to C and
    the tail pads with -1 ids / -inf scores, keeping the [Q, k] output
    contract.
    """
    kk = min(k, scores.shape[-1])
    top_s, pos = jax.lax.top_k(scores, kk)
    top_ids = jnp.take_along_axis(cand, pos, axis=1)
    top_ids = jnp.where(jnp.isfinite(top_s), top_ids, -1)
    if kk < k:
        qn = scores.shape[0]
        top_s = jnp.concatenate(
            [top_s, jnp.full((qn, k - kk), -jnp.inf, top_s.dtype)], axis=1)
        top_ids = jnp.concatenate(
            [top_ids, jnp.full((qn, k - kk), -1, top_ids.dtype)], axis=1)
    docs_evaluated = (cand < n_docs).sum(axis=-1)
    return top_s, top_ids.astype(jnp.int32), docs_evaluated
