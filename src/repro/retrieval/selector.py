"""Stage 3 — selector: pluggable block-selection policies.

The policy that decides WHICH routed blocks get exact scoring is the
decisive accuracy/cost lever of block-based sparse retrieval (Seismic
Alg. 2; Block-Max Pruning, Mallia et al. 2024; Bruch et al. 2023), so
it is a registry of batch-first functions rather than branches inside
the pipeline. A selector maps the routed batch to a fixed-shape block
selection:

    fn(index, batch: RoutedBatch, p: SearchParams) -> Selection

Blocks it wants ignored keep a -inf score; the scorer masks their docs
to the sentinel. ``SearchParams.policy`` picks the registry entry, so
new policies apply to local, served, and distributed search alike.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, TYPE_CHECKING

import jax
import jax.numpy as jnp

from repro.retrieval.params import SearchParams
from repro.retrieval.router import NEG, RoutedBatch

if TYPE_CHECKING:  # annotation-only: keeps repro.retrieval import-cycle-free
    from repro.core.types import SeismicIndex


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Selection:
    """Fixed-shape batched block selection."""

    blocks: jax.Array        # i32 [Q, B] flat ids into RoutedBatch.r
    block_scores: jax.Array  # f32 [Q, B] summary scores (-inf = masked)


SelectorFn = Callable[["SeismicIndex", RoutedBatch, SearchParams], Selection]

_SELECTORS: dict[str, SelectorFn] = {}


def register_selector(name: str, fn: SelectorFn | None = None):
    """Register a block-selection policy (usable as a decorator)."""
    def wrap(f: SelectorFn) -> SelectorFn:
        _SELECTORS[name] = f
        return f
    return wrap if fn is None else wrap(fn)


def get_selector(name: str) -> SelectorFn:
    try:
        return _SELECTORS[name]
    except KeyError:
        raise KeyError(f"unknown selector policy {name!r}; "
                       f"registered: {sorted(_SELECTORS)}") from None


def selector_names() -> tuple[str, ...]:
    return tuple(sorted(_SELECTORS))


@register_selector("budget")
def select_budget(index: SeismicIndex, batch: RoutedBatch,
                  p: SearchParams) -> Selection:
    """Top ``block_budget`` blocks by summary score (IVF-style routing,
    one pass)."""
    scores, blocks = jax.lax.top_k(batch.r, p.block_budget)
    return Selection(blocks=blocks, block_scores=scores)


@register_selector("global_threshold")
def select_global_threshold(index: SeismicIndex, batch: RoutedBatch,
                            p: SearchParams) -> Selection:
    """BMP-style global threshold: keep blocks whose summary score
    clears ``threshold_factor`` of the per-query best block (the
    block-max upper bound), capped at ``block_budget``. One routing
    pass, no forward-index bootstrap."""
    rmax = jnp.max(batch.r, axis=-1, keepdims=True)         # [Q, 1]
    passing = batch.r >= rmax * p.threshold_factor
    kept = jnp.where(passing, batch.r, NEG)
    scores, blocks = jax.lax.top_k(kept, p.block_budget)
    return Selection(blocks=blocks, block_scores=scores)


@register_selector("adaptive")
def select_adaptive(index: SeismicIndex, batch: RoutedBatch,
                    p: SearchParams) -> Selection:
    """Two-stage emulation of Alg. 2's heap_factor pruning: stage 1
    fully scores the top ``probe_budget`` blocks to bootstrap a
    k-th-best estimate theta; stage 2 keeps only blocks with
    summary >= theta / heap_factor (capped at block_budget). Recovers
    the paper's dynamic pruning without a serial heap."""
    from repro.retrieval.scorer import (compact_candidates, dedupe_batch,
                                        gather_block_docs, mask_tombstoned,
                                        score_candidates)
    # ---- stage 1: bootstrap theta from the top probe_budget blocks
    # (clamped: a block_budget below probe_budget degrades to pure
    # budget routing instead of a negative stage-2 top_k)
    probe = min(p.probe_budget, p.block_budget)
    r1, b1 = jax.lax.top_k(batch.r, probe)
    qn = batch.r.shape[0]
    cand1 = gather_block_docs(index, batch.lists, b1).reshape(qn, -1)
    # deleted docs must not inflate theta: a tombstoned high scorer
    # would tighten the stage-2 threshold against docs that can never
    # be returned (tail docs are not folded in — theta only ever ends
    # up lower, which keeps MORE blocks, never fewer)
    cand1 = dedupe_batch(mask_tombstoned(index, cand1), index.n_docs)
    if p.fuse_level >= 1:
        cand1 = compact_candidates(cand1)
    s1 = score_candidates(index, batch.q_dense, cand1, p.use_kernel,
                          fuse_level=p.fuse_level)
    theta = jax.lax.top_k(s1, p.k)[0][:, -1]                # [Q]
    theta = jnp.where(jnp.isfinite(theta), theta, NEG)
    # ---- stage 2: Alg. 2 line 6 -> keep blocks w/ r >= theta/heap_factor
    rows = jnp.arange(qn)[:, None]
    r2 = batch.r.at[rows, b1].set(NEG)                      # already done
    passing = r2 >= theta[:, None] / p.heap_factor
    r2 = jnp.where(passing, r2, NEG)
    v2, b2 = jax.lax.top_k(r2, p.block_budget - probe)
    return Selection(blocks=jnp.concatenate([b1, b2], axis=1),
                     block_scores=jnp.concatenate([r1, v2], axis=1))
