"""Stage 2 — router: quantized summary scoring (paper phase R).

Two routed paths behind ``SearchParams.superblock_fanout``:

* **flat** (``superblock_fanout == 0``, the default): scores EVERY
  summary of every probed list for the whole query batch in one shot —
  the flattened (probed list, block) axis has length ``cut * n_blocks``
  and the result is ``r [Q, cut * n_blocks]`` with dead blocks at
  -inf.
* **hierarchical** (``superblock_fanout > 0``, requires an index built
  with the matching ``SeismicConfig.superblock_fanout``): a BMP-style
  two-stage route. Stage A scores the coarse superblock tier
  (``cut * n_superblocks`` summaries, each upper-bounding its
  children); stage B keeps the top ``superblock_budget`` superblocks
  per query and scores ONLY their children's block summaries
  (``superblock_budget * fanout`` dots), scattering the scores back
  into the flat ``[Q, cut * n_blocks]`` layout with pruned blocks at
  -inf. Selector policies consume the result unchanged. Router work
  drops from ``cut * n_blocks`` to
  ``cut * n_superblocks + superblock_budget * fanout`` summary dots
  per query (:func:`router_work`).

With ``use_kernel`` both tiers use the batched summary_dot Pallas
kernel (u8 dequant fused) — the identical kernel, just different
summary arrays.

With ``fuse_level >= 2`` the whole route collapses into ONE fused
Pallas launch per tier (:mod:`repro.kernels.router_fused`): the
host-side summary gathers (``index.sum_coords[lists]`` and, for the
hierarchical path, the ``[Q, M, f, S]`` child-summary gather between
stage A and stage B) move inside the kernel and never touch HBM. Only
the hierarchical scatter back into the flat layout stays on the host —
it is output-sized, not summary-sized. Results are bit-exact with the
unfused path (parity tests pin it).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp

from repro.retrieval.params import SearchParams
from repro.sparse.quant import dequantize_u8

if TYPE_CHECKING:  # annotation-only: keeps repro.retrieval import-cycle-free
    from repro.core.types import SeismicIndex

NEG = -jnp.inf


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RoutedBatch:
    """Everything the selector and scorer stages need, batched."""

    q_dense: jax.Array   # f32 [Q, d]
    lists: jax.Array     # i32 [Q, cut]     probed coordinate per slot
    r: jax.Array         # f32 [Q, cut*nb]  block summary scores (-inf dead)


def _summary_scores(q_dense, sc, sq, scale, zero, use_kernel):
    """<q, dequant(summary)> over a flat [Q, L, S] summary axis."""
    if use_kernel:
        from repro.kernels.summary_dot.ops import summary_dot_batch
        return summary_dot_batch(q_dense, sc, sq, scale, zero)
    qn = sc.shape[0]
    sv = dequantize_u8(sq, scale, zero)
    gathered = jnp.take_along_axis(
        q_dense, sc.reshape(qn, -1), axis=1).reshape(sc.shape)
    return (gathered * sv).sum(axis=-1)


def _route_flat(index: SeismicIndex, q_dense: jax.Array, lists: jax.Array,
                p: SearchParams) -> RoutedBatch:
    """Summary inner products for all blocks of the probed lists."""
    if p.fuse_level >= 2:
        from repro.kernels.router_fused import router_flat_batch
        r = router_flat_batch(lists, q_dense, index.sum_coords,
                              index.sum_q, index.sum_scale,
                              index.sum_zero, index.block_len)
        return RoutedBatch(q_dense=q_dense, lists=lists, r=r)
    qn, cut = lists.shape
    nb = index.config.n_blocks
    s = index.sum_coords.shape[-1]
    sc = index.sum_coords[lists].reshape(qn, cut * nb, s)   # [Q, L, S]
    sq = index.sum_q[lists].reshape(qn, cut * nb, s)
    scale = index.sum_scale[lists].reshape(qn, cut * nb)
    zero = index.sum_zero[lists].reshape(qn, cut * nb)
    r = _summary_scores(q_dense, sc, sq, scale, zero, p.use_kernel)
    alive = (index.block_len[lists] > 0).reshape(qn, cut * nb)
    r = jnp.where(alive, r, NEG)
    return RoutedBatch(q_dense=q_dense, lists=lists, r=r)


def _route_hierarchical(index: SeismicIndex, q_dense: jax.Array,
                        lists: jax.Array, p: SearchParams) -> RoutedBatch:
    """Superblock tier -> survivors -> child block summaries.

    Pruning is justified by upper bounds: a block is pruned only when
    its superblock's score (>= the block's own summary score) misses
    the per-query top ``superblock_budget``, so every pruned block
    scores at most the weakest kept superblock.
    """
    qn, cut = lists.shape
    cfg = index.config
    nb, f, ns = cfg.n_blocks, cfg.superblock_fanout, cfg.n_superblocks
    if p.fuse_level >= 2:
        # one launch for stage A + top-M + child gather + stage B; the
        # host keeps only the output-sized scatter below
        from repro.kernels.router_fused import router_hier_batch
        m = min(p.superblock_budget, cut * ns)
        rb, flat = router_hier_batch(
            lists, q_dense, index.sup_coords, index.sup_q,
            index.sup_scale, index.sup_zero, index.sum_coords,
            index.sum_q, index.sum_scale, index.sum_zero,
            index.block_len, m=m, fanout=f)
        r = jnp.full((qn, cut * nb), NEG, q_dense.dtype)
        r = r.at[jnp.arange(qn)[:, None], flat].max(rb)
        return RoutedBatch(q_dense=q_dense, lists=lists, r=r)
    s2 = index.sup_coords.shape[-1]
    # ---- stage A: coarse tier, one batched summary_dot over cut * ns
    sc = index.sup_coords[lists].reshape(qn, cut * ns, s2)
    sq = index.sup_q[lists].reshape(qn, cut * ns, s2)
    scale = index.sup_scale[lists].reshape(qn, cut * ns)
    zero = index.sup_zero[lists].reshape(qn, cut * ns)
    u = _summary_scores(q_dense, sc, sq, scale, zero, p.use_kernel)
    # a superblock is alive iff any child block is (all-padding -> -inf)
    blk_alive = jnp.pad(index.block_len > 0, ((0, 0), (0, (-nb) % f)))
    sup_alive = blk_alive.reshape(-1, ns, f).any(-1)        # [L, ns]
    u = jnp.where(sup_alive[lists].reshape(qn, cut * ns), u, NEG)
    # ---- stage B: children of the top-M superblocks only
    m = min(p.superblock_budget, cut * ns)
    us, sup_ids = jax.lax.top_k(u, m)                       # [Q, M]
    li = sup_ids // ns                                      # probed slot
    gi = sup_ids % ns                                       # group in list
    child = gi[..., None] * f + jnp.arange(f)               # [Q, M, f]
    in_range = child < nb
    child = jnp.minimum(child, nb - 1)
    coord = jnp.take_along_axis(lists, li, axis=1)          # [Q, M]
    bsc = index.sum_coords[coord[..., None], child]         # [Q, M, f, S]
    bsq = index.sum_q[coord[..., None], child]
    bscale = index.sum_scale[coord[..., None], child]
    bzero = index.sum_zero[coord[..., None], child]
    s = bsc.shape[-1]
    rb = _summary_scores(q_dense, bsc.reshape(qn, m * f, s),
                         bsq.reshape(qn, m * f, s),
                         bscale.reshape(qn, m * f),
                         bzero.reshape(qn, m * f), p.use_kernel)
    alive = (in_range
             & (index.block_len[coord[..., None], child] > 0)
             & jnp.isfinite(us)[..., None])                 # [Q, M, f]
    rb = jnp.where(alive.reshape(qn, m * f), rb, NEG)
    # ---- scatter back into the flat (probed slot, block) layout
    flat = (li[..., None] * nb + child).reshape(qn, m * f)
    r = jnp.full((qn, cut * nb), NEG, q_dense.dtype)
    r = r.at[jnp.arange(qn)[:, None], flat].max(rb)
    return RoutedBatch(q_dense=q_dense, lists=lists, r=r)


def route_batch(index: SeismicIndex, q_dense: jax.Array, lists: jax.Array,
                p: SearchParams) -> RoutedBatch:
    """Phase R for the whole batch; flat or hierarchical per
    ``p.superblock_fanout`` (0 = flat, bit-exact with the single-tier
    router)."""
    if p.superblock_fanout <= 0:
        return _route_flat(index, q_dense, lists, p)
    if index.sup_coords is None:
        raise ValueError(
            "hierarchical routing requested (superblock_fanout="
            f"{p.superblock_fanout}) but the index has no superblock "
            "tier; build with SeismicConfig(superblock_fanout > 0)")
    if index.config.superblock_fanout != p.superblock_fanout:
        raise ValueError(
            f"superblock_fanout mismatch: SearchParams has "
            f"{p.superblock_fanout}, index was built with "
            f"{index.config.superblock_fanout}")
    return _route_hierarchical(index, q_dense, lists, p)


def router_work(cfg, p: SearchParams) -> int:
    """Summary inner products the router evaluates per query — the
    phase-R work metric (flat: ``cut * n_blocks``; hierarchical:
    ``cut * n_superblocks + superblock_budget * fanout``)."""
    if p.superblock_fanout <= 0:
        return p.cut * cfg.n_blocks
    coarse = p.cut * cfg.n_superblocks
    return coarse + min(p.superblock_budget, coarse) * p.superblock_fanout
