"""Stage 2 — router: quantized summary scoring (paper phase R).

Scores EVERY summary of every probed list for the whole query batch in
one shot: the flattened (probed list, block) axis has length
``cut * n_blocks`` and the result is ``r [Q, cut * n_blocks]`` with
dead blocks at -inf. With ``use_kernel`` the batched summary_dot
Pallas kernel (u8 dequant fused) does this in a single launch.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp

from repro.sparse.quant import dequantize_u8

if TYPE_CHECKING:  # annotation-only: keeps repro.retrieval import-cycle-free
    from repro.core.types import SeismicIndex

NEG = -jnp.inf


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RoutedBatch:
    """Everything the selector and scorer stages need, batched."""

    q_dense: jax.Array   # f32 [Q, d]
    lists: jax.Array     # i32 [Q, cut]     probed coordinate per slot
    r: jax.Array         # f32 [Q, cut*nb]  block summary scores (-inf dead)


def route_batch(index: SeismicIndex, q_dense: jax.Array, lists: jax.Array,
                use_kernel: bool) -> RoutedBatch:
    """Summary inner products for all blocks of the probed lists."""
    qn, cut = lists.shape
    nb = index.config.n_blocks
    s = index.sum_coords.shape[-1]
    sc = index.sum_coords[lists].reshape(qn, cut * nb, s)   # [Q, L, S]
    sq = index.sum_q[lists].reshape(qn, cut * nb, s)
    scale = index.sum_scale[lists].reshape(qn, cut * nb)
    zero = index.sum_zero[lists].reshape(qn, cut * nb)
    if use_kernel:
        from repro.kernels.summary_dot.ops import summary_dot_batch
        r = summary_dot_batch(q_dense, sc, sq, scale, zero)
    else:
        sv = dequantize_u8(sq, scale, zero)
        gathered = jnp.take_along_axis(
            q_dense, sc.reshape(qn, -1), axis=1).reshape(sc.shape)
        r = (gathered * sv).sum(axis=-1)
    alive = (index.block_len[lists] > 0).reshape(qn, cut * nb)
    r = jnp.where(alive, r, NEG)
    return RoutedBatch(q_dense=q_dense, lists=lists, r=r)
