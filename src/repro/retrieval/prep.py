"""Stage 1 — prep: batch query densification + probed-coordinate cut.

Input is the padded-CSR query batch; output is the dense query matrix
(kept VMEM-resident by the downstream kernels) and the top-``cut``
coordinates each query probes (Alg. 2 line 1), computed for the whole
batch with one top_k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sparse.ops import PaddedSparse, densify


def prep_queries(q_coords: jax.Array, q_vals: jax.Array, dim: int,
                 cut: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """[Q, nnz] padded-sparse queries -> (q_dense [Q, d],
    lists [Q, cut] int32, list_vals [Q, cut]).

    Padded entries (val == 0) map to coord 0 with val 0; probing coord 0
    repeatedly is harmless — its routed blocks dedupe downstream.
    """
    vals = q_vals.astype(jnp.float32)
    q_dense = densify(PaddedSparse(q_coords, vals, dim))
    cv, idx = jax.lax.top_k(vals, cut)                      # [Q, cut]
    cc = jnp.take_along_axis(q_coords, idx, axis=1)
    cc = jnp.where(cv > 0, cc, 0)
    cv = jnp.where(cv > 0, cv, 0.0)
    return q_dense, cc.astype(jnp.int32), cv
