"""Query-time hyper-parameters (paper's cut, heap_factor).

``SearchParams`` is a frozen (hashable) dataclass so it can ride as a
static jit argument; every pipeline stage shape is determined by it.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Query-time hyper-parameters shared by every pipeline stage."""

    k: int = 10
    cut: int = 8                  # probed query coordinates
    block_budget: int = 32        # max fully-evaluated blocks
    heap_factor: float = 0.9      # summary over-estimate correction
    policy: str = "adaptive"      # selector registry key ("budget" |
    #                               "adaptive" | "global_threshold" | ...)
    probe_budget: int = 8         # stage-1 blocks for the adaptive policy
    threshold_factor: float = 0.75  # global_threshold: keep blocks with
    #                                 summary >= factor * per-query max
    use_kernel: bool = False      # batched Pallas gather/summary kernels
    superblock_fanout: int = 0    # hierarchical routing: 0 = flat (score
    #                               every block summary); > 0 = two-stage
    #                               BMP-style route over the coarse
    #                               superblock tier (must match the
    #                               index's SeismicConfig.superblock_fanout)
    superblock_budget: int = 16   # hierarchical routing: superblocks kept
    #                               per query after the coarse stage; only
    #                               their children's block summaries are
    #                               scored (work = cut * n_superblocks +
    #                               superblock_budget * fanout)
    graph_degree: int = 0         # kNN-graph refinement: neighbors expanded
    #                               per merged top-k doc (<= the built
    #                               graph degree; 0 = refine stage is a
    #                               bit-exact no-op)
    refine_rounds: int = 0        # kNN-graph refinement: frontier
    #                               expansions per query (each round
    #                               expands + rescores + re-merges;
    #                               0 = refine stage is a bit-exact no-op)
