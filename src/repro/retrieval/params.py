"""Query-time hyper-parameters (paper's cut, heap_factor).

``SearchParams`` is a frozen (hashable) dataclass so it can ride as a
static jit argument; every pipeline stage shape is determined by it.

Rather than hand-picking the coupled quality knobs per collection,
indexes tuned with ``repro.tune`` carry persisted ``TunedPolicy``
operating points; ``SearchParams.from_tuned(index, target)`` resolves
the cheapest one meeting a recall target back into pipeline params.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Query-time hyper-parameters shared by every pipeline stage."""

    k: int = 10
    cut: int = 8                  # probed query coordinates
    block_budget: int = 32        # max fully-evaluated blocks
    heap_factor: float = 0.9      # summary over-estimate correction
    policy: str = "adaptive"      # selector registry key ("budget" |
    #                               "adaptive" | "global_threshold" | ...)
    probe_budget: int = 8         # stage-1 blocks for the adaptive policy
    threshold_factor: float = 0.75  # global_threshold: keep blocks with
    #                                 summary >= factor * per-query max
    use_kernel: bool = False      # batched Pallas gather/summary kernels
    fuse_level: int = 0           # kernel-fusion ladder (execution detail,
    #                               results identical at every level):
    #                               0 = unfused reference path (bit-exact
    #                                   with the pre-fusion pipeline);
    #                               1 = candidate compaction — scorer and
    #                                   refine pack live candidates to a
    #                                   prefix and score through the
    #                                   candidate-driven gather_dot kernel
    #                                   (in-kernel forward gather, all-
    #                                   sentinel tiles skipped);
    #                               2 = level 1 + fused router (stage A +
    #                                   top-M + child gather + stage B in
    #                                   one launch) and fused refine
    #                                   (expand + dedupe + rescore in one
    #                                   launch). Fused stages are Pallas-
    #                                   only (interpret off-TPU);
    #                                   `use_kernel` still governs the
    #                                   unfused stages.
    superblock_fanout: int = 0    # hierarchical routing: 0 = flat (score
    #                               every block summary); > 0 = two-stage
    #                               BMP-style route over the coarse
    #                               superblock tier (must match the
    #                               index's SeismicConfig.superblock_fanout)
    superblock_budget: int = 16   # hierarchical routing: superblocks kept
    #                               per query after the coarse stage; only
    #                               their children's block summaries are
    #                               scored (work = cut * n_superblocks +
    #                               superblock_budget * fanout)
    graph_degree: int = 0         # kNN-graph refinement: neighbors expanded
    #                               per merged top-k doc (<= the built
    #                               graph degree; 0 = refine stage is a
    #                               bit-exact no-op)
    refine_rounds: int = 0        # kNN-graph refinement: frontier
    #                               expansions per query (each round
    #                               expands + rescores + re-merges;
    #                               0 = refine stage is a bit-exact no-op)

    def __post_init__(self):
        if self.fuse_level not in (0, 1, 2):
            raise ValueError(
                f"fuse_level must be 0, 1, or 2, got {self.fuse_level}")

    @classmethod
    def from_tuned(cls, index, target: float, *,
                   use_kernel: bool = False,
                   fuse_level: int = 0) -> "SearchParams":
        """Resolve the cheapest ``TunedPolicy`` persisted on ``index``
        whose MEASURED recall meets ``target`` (a policy tuned for 0.90
        that measured 0.95 satisfies a 0.92 request).

        Raises ``ValueError`` when the index carries no policy meeting
        the target — under-delivering recall silently is not an option
        for params derived from a persisted artifact. Duck-typed on the
        policy tuple (no ``repro.tune`` import: this module is a leaf).
        """
        policies = getattr(index, "tuned", ()) or ()
        if not policies:
            raise ValueError(
                "index carries no TunedPolicy; run repro.tune."
                "tune_and_attach (or pass explicit SearchParams)")
        feasible = [t for t in policies if t.satisfies(target)]
        if not feasible:
            best = max(t.measured_recall for t in policies)
            raise ValueError(
                f"no persisted TunedPolicy meets recall target "
                f"{target:.4f} (best measured {best:.4f} over "
                f"{len(policies)} policies); re-tune with a higher "
                "target or widen the tuning grid")
        chosen = min(feasible, key=lambda t: (t.measured_cost,
                                              t.router_cost, t.target))
        return chosen.to_params(use_kernel=use_kernel,
                                fuse_level=fuse_level)
