"""Deterministic per-query HBM-traffic model of the retrieval stages.

The fusion ladder (``SearchParams.fuse_level``) changes how many times
intermediate arrays cross HBM without changing any result, so wall
time on the CPU interpret path says nothing about what the fusions
buy. This module is the accounting that does: closed-form byte counts
per query for the router, scorer, and refine stages, derived from the
static launch shapes — the same arithmetic the kernel wrappers use for
tile selection (:mod:`repro.kernels.tiling`).

Conventions, applied uniformly so levels are comparable:

* bytes every level must move are counted once — streamed index rows
  (summaries / forward rows / graph rows), the dense query row, stage
  outputs;
* a HOST-MATERIALIZED intermediate (the unfused paths' gathered
  summary planes, the ``[C, nnz]`` gathered forward rows, the refine
  expansion) costs ``2 x`` its size — written once by the gather,
  read once by the consumer. Fused levels delete exactly these terms;
* candidate-axis work that the compaction kernel SKIPS (all-sentinel
  tiles, see ``gather_dot.ops.cand_tiles_processed``) is charged only
  for the processed slots the caller passes in.

The model is advisory (benchmarks report it; the microbench smoke gate
asserts fused levels strictly reduce it) — selection logic never
depends on it.
"""
from __future__ import annotations

from repro.kernels.tiling import gather_row_bytes, summary_row_bytes


def router_bytes(*, cut: int, n_blocks: int, summary_nnz: int, dim: int,
                 fuse_level: int, n_superblocks: int = 0, fanout: int = 0,
                 superblock_budget: int = 0,
                 superblock_nnz: int = 0) -> int:
    """Modeled HBM bytes per query for phase R (flat or hierarchical).

    ``fanout == 0`` models the flat route; otherwise the two-stage
    route with ``min(superblock_budget, cut * n_superblocks)`` kept
    superblocks. ``fuse_level >= 2`` deletes the host-gathered summary
    intermediates (the ``[cut*nb, S]`` probe gather; hierarchically
    also the ``[M, f, S]`` child gather between the stages).
    """
    q = 4 * dim
    if fanout <= 0:
        rows = cut * n_blocks
        row_b = summary_row_bytes(summary_nnz)
        base = q + rows * row_b + 4 * rows          # stream + r output
        if fuse_level >= 2:
            return base
        return base + 2 * rows * row_b              # gathered intermediate
    m = min(superblock_budget, cut * n_superblocks)
    rows_a = cut * n_superblocks
    row_a = summary_row_bytes(superblock_nnz)
    rows_b = m * fanout
    row_b = summary_row_bytes(summary_nnz)
    base = (q + rows_a * row_a + rows_b * row_b
            + 8 * rows_b                            # (rb, flat) outputs
            + 4 * cut * n_blocks)                   # flat-layout scatter
    if fuse_level >= 2:
        return base
    return base + 2 * (rows_a * row_a + rows_b * row_b)


def scorer_bytes(*, n_slots: int, scored_slots: int, nnz: int, quant: bool,
                 dim: int, fuse_level: int) -> int:
    """Modeled HBM bytes per query for phase S.

    ``n_slots`` — candidate slots entering the stage (block_budget *
    block_cap after dedupe padding); ``scored_slots`` — slots the
    candidate-driven kernel actually processes (``n_slots`` again at
    level 0, the ``cand_tiles_processed`` count at level >= 1).
    Level 0 additionally pays the host-gathered ``[n_slots, nnz]``
    forward-row intermediate both ways.
    """
    row_b = gather_row_bytes(nnz, quant=quant)
    q = 4 * dim
    ids_io = 8 * n_slots                            # cand ids in, scores out
    if fuse_level >= 1:
        return q + ids_io + scored_slots * row_b
    return q + ids_io + n_slots * row_b + 2 * n_slots * row_b


def refine_bytes(*, k: int, degree: int, rounds: int, nnz: int,
                 quant: bool, dim: int, fuse_level: int,
                 scored_slots_per_round: int | None = None) -> int:
    """Modeled HBM bytes per query for the refine stage.

    Per round the frontier is ``k * degree`` slots. Level < 2 pays the
    ``[k*degree]`` expansion + dedupe intermediates and (at level 0)
    the gathered forward rows both ways; level 2 runs the whole round
    in one launch and streams only the graph row + forward rows.
    """
    if rounds <= 0 or degree <= 0:
        return 0
    c = k * degree
    scored = c if scored_slots_per_round is None else scored_slots_per_round
    row_b = gather_row_bytes(nnz, quant=quant)
    q = 4 * dim
    graph = 4 * k * degree                          # streamed knn rows
    out = 8 * c                                     # (cand, scores) per round
    if fuse_level >= 2:
        per_round = q + graph + scored * row_b + out
    elif fuse_level >= 1:
        # expansion + dedupe ids written and re-read host-side
        per_round = q + graph + 2 * (2 * 4 * c) + scored * row_b + out
    else:
        per_round = (q + graph + 2 * (2 * 4 * c)
                     + c * row_b + 2 * c * row_b + out)
    return rounds * per_round


__all__ = ["router_bytes", "scorer_bytes", "refine_bytes"]
