"""Production training launcher.

Builds the mesh, shards params/optimizer with the rule-based specs
(ZeRO over DP for the optimizer state), wires the prefetching data
pipeline, checkpointing (async, keep-last-k, resume), and runs the
train loop. On this CPU container it is exercised with reduced configs
and a small forced device count; on a real slice the same entry point
runs the full configs:

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
      --batch 8 --seq 64 --steps 50 --reduced --devices 8
"""
import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force host device count (testing only)")
    ap.add_argument("--model-parallel", type=int, default=0,
                    help="TP width; default = 1 (reduced) / 16 (full)")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + f" --xla_force_host_platform_device_count={args.devices}")
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.ckpt import CheckpointManager
    from repro.data.pipeline import PrefetchLoader, lm_token_stream
    from repro.distributed.param_sharding import opt_state_specs
    from repro.launch.mesh import make_mesh_for
    from repro.models.api import get_bundle
    from repro.train import AdamWConfig, init_opt_state, make_train_step

    bundle = get_bundle(args.arch)
    cfg = bundle.reduced if args.reduced else bundle.config
    dims = dict(global_batch=args.batch, seq_len=args.seq)
    n_dev = len(jax.devices())
    tp = args.model_parallel or (1 if args.reduced else min(16, n_dev))
    mesh = make_mesh_for(n_dev, model_parallel=tp)
    dp = ("data",)
    print(f"mesh={dict(mesh.shape)} arch={cfg.name}")

    with jax.set_mesh(mesh):
        params = bundle.init(jax.random.PRNGKey(0), cfg, dims)
        pspecs = bundle.param_specs(params)
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                           is_leaf=lambda x: isinstance(x, P))
        params = jax.tree.map(jax.device_put, params, psh)
        opt = init_opt_state(params)
        ospecs = opt_state_specs(pspecs, params, zero=True, dp=dp,
                                 dp_size=mesh.shape["data"])
        osh = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs,
                           is_leaf=lambda x: isinstance(x, P))
        opt = jax.tree.map(jax.device_put, opt, osh)
        bsh = dict(tokens=NamedSharding(mesh, P(dp, None)),
                   labels=NamedSharding(mesh, P(dp, None)))

        opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=10,
                              total_steps=args.steps)
        step_fn = jax.jit(
            make_train_step(bundle.step(cfg, dims, "train"), opt_cfg,
                            microbatches=args.microbatches),
            in_shardings=(psh, osh, bsh), donate_argnums=(0, 1))

        mgr = CheckpointManager(args.ckpt_dir, keep=2)
        start = 0
        if args.resume:
            try:
                restored, start = mgr.restore_latest(
                    dict(params=params, opt=opt),
                    shardings=dict(params=psh, opt=osh))
                params, opt = restored["params"], restored["opt"]
                print(f"resumed from step {start}")
            except FileNotFoundError:
                print("no checkpoint; fresh start")

        loader = PrefetchLoader(
            lm_token_stream(cfg.vocab, args.batch, args.seq, seed=start),
            prefetch=4)
        t0 = time.time()
        for i, batch in enumerate(loader):
            if i >= args.steps:
                break
            step = start + i
            batch = {k: jax.device_put(jnp.asarray(v), bsh[k])
                     for k, v in batch.items()}
            params, opt, metrics = step_fn(params, opt, batch)
            if step % 10 == 0:
                print(f"step {step:5d}  loss={float(metrics['loss']):.4f}  "
                      f"{(time.time()-t0)/(i+1)*1000:.0f} ms/step")
            if step > 0 and step % args.ckpt_every == 0:
                mgr.save_async(step, dict(params=params, opt=opt))
        loader.close()
        mgr.save_async(start + args.steps, dict(params=params, opt=opt))
        mgr.wait()
        print("done")


if __name__ == "__main__":
    main()
