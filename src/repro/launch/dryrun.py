import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture x input-shape
x mesh) cell on the production meshes and record roofline inputs.

This proves the distribution config is coherent without hardware:
sharding mismatches, compile-time OOM math, and unsupported collectives
all fail HERE. Per cell it records:

  * compiled.memory_analysis()  (fits-in-HBM evidence)
  * compiled.cost_analysis()    (per-device FLOPs / bytes)
  * collective bytes parsed from the compiled HLO
  * the derived roofline terms (distributed/roofline.py)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--fast]

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import dataclasses
import json
import time
import traceback

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_arch, list_archs
from repro.distributed.hlo_analysis import collective_bytes, hlo_dot_flops
from repro.distributed.param_sharding import (cache_specs, opt_state_specs,
                                              lm_param_specs)
from repro.distributed.roofline import (Roofline, model_flops_infer,
                                        model_flops_train)
from repro.launch.mesh import make_production_mesh
from repro.models.api import get_bundle
from repro.train import AdamWConfig, init_opt_state, make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _dp(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _sharding_tree(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def input_specs(arch_id: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of a cell —
    weak-type-correct, shardable, no device allocation."""
    bundle = get_bundle(arch_id)
    cell = next(c for c in bundle.shapes if c.name == shape_name)
    return bundle.batch_specs(bundle.config, cell.dims, cell.kind), cell


# ------------------------------------------------------------ LM cells

def _seismic_override(mod, overrides: dict):
    import types as _t
    cfg = dataclasses.replace(
        mod.CONFIG, index=dataclasses.replace(mod.CONFIG.index, **overrides))
    proxy = _t.SimpleNamespace(CONFIG=cfg, SHAPES=mod.SHAPES,
                               REDUCED=mod.REDUCED)
    return proxy


def _lower_lm(bundle, cell, mesh, *, microbatches: int = 1):
    cfg = bundle.config
    dp = _dp(mesh)
    batch_sds = bundle.batch_specs(cfg, cell.dims, cell.kind)
    params_sds = jax.eval_shape(
        lambda k: bundle.init(k, cfg, cell.dims), jax.random.PRNGKey(0))
    pspecs = lm_param_specs(params_sds, mode=cfg.sharding_mode)
    psh = _sharding_tree(mesh, pspecs)

    if cell.kind == "train":
        opt_sds = jax.eval_shape(init_opt_state, params_sds)
        ospecs = opt_state_specs(pspecs, params_sds, zero=True, dp=dp,
                                 dp_size=int(np.prod([mesh.shape[a] for a in dp])))
        osh = _sharding_tree(mesh, ospecs)
        bsh = dict(tokens=NamedSharding(mesh, P(dp, None)),
                   labels=NamedSharding(mesh, P(dp, None)))
        loss = bundle.step(cfg, cell.dims, "train")
        step = make_train_step(loss, AdamWConfig(),
                               microbatches=microbatches)
        jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                         donate_argnums=(0, 1))
        args = (params_sds, opt_sds, batch_sds)
    elif cell.kind == "prefill":
        bsh = dict(tokens=NamedSharding(mesh, P(dp, None)))
        fwd = bundle.step(cfg, cell.dims, "prefill")
        jitted = jax.jit(fwd, in_shardings=(psh, bsh))
        args = (params_sds, batch_sds)
    else:  # decode
        cache_sds = jax.eval_shape(
            lambda: bundle.init_cache(cfg, cell.dims))
        dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
        cspecs = cache_specs(cache_sds, dp, dp_size=dp_size,
                             tp_size=mesh.shape.get("model", 1))
        csh = _sharding_tree(mesh, cspecs)
        b = cell.dims["global_batch"]
        tok_spec = P(dp, None) if b % dp_size == 0 else P()
        bsh = dict(tokens=NamedSharding(mesh, tok_spec),
                   pos=NamedSharding(mesh, P()))
        dec = bundle.step(cfg, cell.dims, "decode")
        jitted = jax.jit(dec, in_shardings=(psh, csh, bsh),
                         donate_argnums=(1,))
        args = (params_sds, cache_sds, batch_sds)

    lowered = jitted.lower(*args)
    # MODEL_FLOPS for the ratio row
    n_tok = cell.dims["global_batch"] * (cell.dims["seq_len"]
                                         if cell.kind != "decode" else 1)
    if cell.kind == "train":
        mf = model_flops_train(cfg.active_param_count(), n_tok)
    else:
        mf = model_flops_infer(cfg.active_param_count(), n_tok)
    return lowered, mf


# ----------------------------------------------------- GNN/recsys cells

def _lower_generic(bundle, cell, mesh):
    cfg = bundle.config
    dp = _dp(mesh)
    all_axes = tuple(mesh.axis_names)
    batch_sds = bundle.batch_specs(cfg, cell.dims, cell.kind)
    params_sds = jax.eval_shape(
        lambda k: bundle.init(k, cfg, cell.dims), jax.random.PRNGKey(0))
    pspecs = bundle.param_specs(params_sds)
    psh = _sharding_tree(mesh, pspecs)

    if bundle.family == "gnn":
        bspec = dict(feats=P(), edges=P(all_axes),
                     labels=P(), graph_ids=P(), graph_labels=P())
    else:
        def bs(name, sds):
            if name in ("cand",):
                return P(all_axes)
            if sds.shape and sds.shape[0] > 1:
                return P(dp)
            return P()
        bspec = {k: bs(k, v) for k, v in batch_sds.items()}
    bsh = {k: NamedSharding(mesh, bspec[k]) for k in batch_sds}

    if cell.kind == "train":
        opt_sds = jax.eval_shape(init_opt_state, params_sds)
        ospecs = opt_state_specs(pspecs, params_sds, zero=False)
        osh = _sharding_tree(mesh, ospecs)
        loss = bundle.step(cfg, cell.dims, "train")
        step = make_train_step(loss, AdamWConfig())
        jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                         donate_argnums=(0, 1))
        args = (params_sds, opt_sds, batch_sds)
    else:
        fn = bundle.step(cfg, cell.dims, cell.kind)
        jitted = jax.jit(fn, in_shardings=(psh, bsh))
        args = (params_sds, batch_sds)
    return jitted.lower(*args), 0.0


# --------------------------------------------------------- seismic cell

def _lower_seismic(mod, cell, mesh):
    from repro.core.distributed import make_distributed_search
    from repro.core.query import SearchParams
    from repro.core.types import SeismicConfig, SeismicIndex
    from repro.sparse.ops import PaddedSparse
    cfg = mod.CONFIG
    dp = _dp(mesh)
    doc_axes = ("model",) if "pod" not in mesh.axis_names else ("pod", "model")
    n_shards = int(np.prod([mesh.shape[a] for a in doc_axes]))
    per = -(-cfg.n_docs // n_shards)
    # per-shard index hyper-params scale with the local corpus: a shard
    # holding 1/P of the docs keeps lambda/P postings and beta/P blocks
    # per list (same recall structure, 1/P memory) — what a real
    # deployment provisions.
    icfg: SeismicConfig = dataclasses.replace(
        cfg.index,
        lam=max(64, cfg.index.lam // n_shards),
        beta=max(8, cfg.index.beta // n_shards),
        block_cap=cfg.index.block_cap)
    d, lam, nb, s = cfg.dim, icfg.lam, icfg.n_blocks, icfg.summary_nnz
    f16 = jnp.dtype(icfg.fwd_dtype)

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct((n_shards,) + shape, dtype)

    if icfg.fwd_quant:
        coord_dt, val_dt = jnp.uint16 if d < 65536 else jnp.int32, jnp.uint8
        fwd_scale = sds((per,), jnp.float32)
        fwd_zero = sds((per,), jnp.float32)
    else:
        coord_dt, val_dt = jnp.int32, f16
        fwd_scale = fwd_zero = None
    index_sds = SeismicIndex(
        fwd=PaddedSparse(sds((per, cfg.doc_nnz), coord_dt),
                         sds((per, cfg.doc_nnz), val_dt), d),
        list_docs=sds((d, lam), jnp.int32),
        list_vals=sds((d, lam), jnp.float32),
        list_len=sds((d,), jnp.int32),
        block_off=sds((d, nb), jnp.int32),
        block_len=sds((d, nb), jnp.int32),
        sum_coords=sds((d, nb, s), jnp.int32),
        sum_q=sds((d, nb, s), jnp.uint8),
        sum_scale=sds((d, nb), jnp.float32),
        sum_zero=sds((d, nb), jnp.float32),
        fwd_scale=fwd_scale, fwd_zero=fwd_zero,
        config=icfg)
    q = cell.dims["batch"]
    q_sds = jax.ShapeDtypeStruct((q, cfg.query_nnz), jnp.int32)
    v_sds = jax.ShapeDtypeStruct((q, cfg.query_nnz), jnp.float32)
    p = SearchParams(k=cell.dims["k"], cut=cell.dims["cut"],
                     block_budget=cell.dims["block_budget"],
                     policy="budget")
    search = make_distributed_search(mesh, p, doc_axes=doc_axes,
                                     data_axis="data")
    ish = jax.tree.map(lambda _: NamedSharding(mesh, P(doc_axes)), index_sds)
    qsh = NamedSharding(mesh, P("data"))
    jitted = jax.jit(search, in_shardings=(ish, qsh, qsh))
    # analytic per-device flops+bytes (gather-dot heavy; no HLO dots to
    # count, and memory_analysis charges the resident index rather than
    # the per-batch touched bytes):
    #   routing: cut lists x nb blocks x S entries (coords i32 + u8 val)
    #   scoring: budget x cap candidate docs x nnz (coords i32 + val)
    q_loc = q // mesh.shape["data"]
    per_query = (p.cut * nb * s * 2
                 + p.block_budget * icfg.block_cap * cfg.doc_nnz * 2)
    analytic = float(q_loc * per_query)
    if icfg.fwd_quant:
        entry_b = (2 if d < 65536 else 4) + 1   # u16 coord + u8 value
        doc_extra = 8                            # per-doc scale+zero
    else:
        entry_b = 4 + jnp.dtype(icfg.fwd_dtype).itemsize
        doc_extra = 0
    per_query_bytes = (p.cut * nb * s * 5                      # summaries
                       + p.block_budget * icfg.block_cap
                       * (cfg.doc_nnz * entry_b + doc_extra)   # fwd rows
                       + cfg.dim * 4 * 3)                      # q densify
    return jitted.lower(index_sds, q_sds, v_sds), 0.0, \
        dict(flops=analytic, bytes=float(q_loc * per_query_bytes))


# -------------------------------------------------------------- probes
#
# XLA:CPU's cost_analysis() only accounts for the ENTRY computation —
# scan/while bodies (our layer stacks) report ~zero flops. The probe
# methodology recovers honest per-device numbers: lower the SAME cell
# with a few layers UNROLLED (remat off, attention un-chunked so no
# while loops remain), take per-layer deltas, extrapolate linearly:
#
#   total = head_cost + n_layers_of_kind * per_layer_cost(kind)
#
# Memory analysis still comes from the production (scanned) compile.

def _probe_cost(bundle, cell, mesh, overrides: dict, *,
                microbatches: int = 1) -> dict:
    cfg = bundle.config
    probe_cfg = dataclasses.replace(
        cfg, unroll_layers=True, remat="none",
        attn_q_chunk=max(cell.dims.get("seq_len", 512), 512), **overrides)
    pb = dataclasses.replace(bundle, config=probe_cfg)
    lowered, _ = _lower_lm(pb, cell, mesh, microbatches=1)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    dots = hlo_dot_flops(hlo)          # fusion-body-aware matmul flops
    coll = collective_bytes(hlo)
    mem = compiled.memory_analysis()
    traffic = (mem.argument_size_in_bytes + mem.output_size_in_bytes
               + 2 * mem.temp_size_in_bytes)
    return dict(flops=dots["dot_flops"], hbm=float(traffic),
                coll=float(coll.get("total", 0)),
                coll_wire=float(coll.get("total_wire", 0)),
                n_while=dots["n_while"])


def probe_lm_totals(bundle, cell, mesh, *, microbatches: int = 1) -> dict:
    """Extrapolated per-device (flops, hbm, coll) for the full depth."""
    cfg = bundle.config
    if cfg.local_per_global > 0:          # gemma: local + global deltas
        c1 = _probe_cost(bundle, cell, mesh, dict(n_layers=1))
        c2 = _probe_cost(bundle, cell, mesh, dict(n_layers=2))
        cg = _probe_cost(bundle, cell, mesh,
                         dict(n_layers=2, local_per_global=1))
        import numpy as _np
        from repro.models.transformer.lm import layer_windows
        wins = layer_windows(cfg)
        n_local = int((wins > 0).sum())
        n_global = int((wins == 0).sum())
        out = {}
        for k in ("flops", "hbm", "coll", "coll_wire"):
            d_local = c2[k] - c1[k]
            d_global = cg[k] - c1[k]
            head = c1[k] - d_local
            out[k] = head + n_local * d_local + n_global * d_global
        out["n_probe_compiles"] = 3
        return out
    if cfg.moe:                            # dense0 + (L-1) moe layers
        c2 = _probe_cost(bundle, cell, mesh, dict(n_layers=2))
        c3 = _probe_cost(bundle, cell, mesh, dict(n_layers=3))
        n_moe = cfg.n_layers - cfg.n_dense_layers
        out = {k: c2[k] + (n_moe - 1) * (c3[k] - c2[k])
               for k in ("flops", "hbm", "coll", "coll_wire")}
        out["n_probe_compiles"] = 2
        return out
    c1 = _probe_cost(bundle, cell, mesh, dict(n_layers=1))
    c2 = _probe_cost(bundle, cell, mesh, dict(n_layers=2))
    out = {k: c1[k] + (cfg.n_layers - 1) * (c2[k] - c1[k])
           for k in ("flops", "hbm", "coll", "coll_wire")}
    out["n_probe_compiles"] = 2
    return out


# --------------------------------------------------------------- driver

def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             opt_overrides=None, tag: str = "", probe: bool = True,
             microbatches: int = 1) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mod = get_arch(arch_id)
    cell = next(c for c in mod.SHAPES if c.name == shape_name)
    if cell.skip:
        return dict(arch=arch_id, shape=shape_name, skipped=cell.skip)
    t0 = time.time()
    probe_totals = None
    analytic_flops = None
    with jax.set_mesh(mesh):
        if arch_id == "seismic-msmarco":
            if opt_overrides:   # overrides apply to the SeismicConfig
                mod = _seismic_override(mod, opt_overrides)
            lowered, mf, analytic_flops = _lower_seismic(mod, cell, mesh)
        else:
            bundle = get_bundle(arch_id)
            if opt_overrides:
                bundle = dataclasses.replace(
                    bundle, config=dataclasses.replace(
                        bundle.config, **opt_overrides))
            if bundle.family == "lm":
                lowered, mf = _lower_lm(bundle, cell, mesh,
                                        microbatches=microbatches)
                if probe and not multi_pod:
                    probe_totals = probe_lm_totals(
                        bundle, cell, mesh, microbatches=microbatches)
            else:
                lowered, mf = _lower_generic(bundle, cell, mesh)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    traffic = float(mem.argument_size_in_bytes + mem.output_size_in_bytes
                    + 2 * mem.temp_size_in_bytes)
    if probe_totals is not None:
        # scan-aware extrapolated totals (see probe docstring)
        flops = probe_totals["flops"]
        hbm = probe_totals["hbm"]
        coll = dict(coll, total=probe_totals["coll"],
                    total_wire=probe_totals["coll_wire"],
                    entry_total=coll.get("total", 0))
        flops_source = "probe-dot-count"
    elif arch_id == "seismic-msmarco":
        flops = analytic_flops["flops"]
        hbm = analytic_flops["bytes"]
        flops_source = "analytic"
    else:
        dots = hlo_dot_flops(hlo)
        flops = dots["dot_flops"]
        hbm = traffic
        flops_source = (f"hlo-dot-count(n_while={dots['n_while']})"
                        if dots["n_while"] else "hlo-dot-count")
    n_chips = int(np.prod(list(mesh.shape.values())))
    roof = Roofline(flops=flops, hbm_bytes=hbm,
                    coll_bytes=float(coll.get("total", 0)))
    rec = dict(
        arch=arch_id, shape=shape_name,
        mesh="x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        multi_pod=multi_pod, n_chips=n_chips, kind=cell.kind,
        compile_s=round(t_compile, 1),
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
            peak_est=mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes,
        ),
        cost=dict(flops=flops, hbm_bytes=hbm),
        collectives=coll,
        roofline=roof.as_dict(),
        probe=probe_totals,
        flops_source=flops_source,
        model_flops=mf,
        model_flops_ratio=(mf / (flops * n_chips)
                           if flops > 0 and mf > 0 else None),
        tag=tag,
    )
    return rec


def save_record(rec: dict, out_dir: str = OUT_DIR):
    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "multipod" if rec.get("multi_pod") else "singlepod"
    tag = f"__{rec['tag']}" if rec.get("tag") else ""
    name = f"{rec['arch']}__{rec['shape']}__{mesh_tag}{tag}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)
    return name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    args = ap.parse_args()

    jobs = []
    archs = list_archs() if args.all else [args.arch]
    for a in archs:
        mod = get_arch(a)
        shapes = [c.name for c in mod.SHAPES] if (args.all or not args.shape) \
            else [args.shape]
        for s in shapes:
            meshes = [False, True] if args.both_meshes else [args.multi_pod]
            for mp in meshes:
                jobs.append((a, s, mp))

    failures = []
    for a, s, mp in jobs:
        label = f"{a:24s} {s:14s} {'2x16x16' if mp else '16x16'}"
        try:
            rec = run_cell(a, s, multi_pod=mp)
            if "skipped" in rec:
                print(f"SKIP {label}: {rec['skipped']}")
                save_record(dict(rec, multi_pod=mp, tag=""), OUT_DIR)
                continue
            r = rec["roofline"]
            print(f"OK   {label}  compile={rec['compile_s']}s  "
                  f"flops/dev={rec['cost']['flops']:.3e}  "
                  f"coll/dev={rec['collectives'].get('total', 0):.3e}B  "
                  f"bound={r['bottleneck']}")
            print("     memory_analysis:", rec["memory"])
            save_record(rec)
        except Exception as e:
            failures.append((label, e))
            print(f"FAIL {label}: {type(e).__name__}: {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed")
    print("all dry-run cells passed")


if __name__ == "__main__":
    main()
