"""Aggregate experiments/dryrun/*.json into the §Roofline markdown
table (single-pod baselines) and the §Dry-run pass matrix.

  PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-6:
        return f"{x*1e9:.1f}ns"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def _fmt_b(x: float) -> str:
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


LEVERS = {
    "collective": "cut collective volume (reshard/overlap/compress)",
    "memory": "cut HBM traffic (remat policy, fusion, dtype)",
    "compute": "at roofline for MXUs; raise MFU via tiling/overlap",
}


def load(dir_: str):
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def roofline_table(recs, *, tag: str = "") -> str:
    rows = ["| arch | shape | kind | flops/dev | T_comp | T_mem | T_coll "
            "| bound | comp.frac | 6ND/HLO | lever |",
            "|---|---|---|---|---|---|---|---|---|---|---|"[:-4]]
    for r in recs:
        if r.get("multi_pod") or "skipped" in r:
            continue
        if (r.get("tag") or "") != tag:
            continue
        ro = r["roofline"]
        mfr = r.get("model_flops_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {ro['flops']:.2e} | {_fmt_s(ro['t_compute'])} "
            f"| {_fmt_s(ro['t_memory'])} | {_fmt_s(ro['t_collective'])} "
            f"| **{ro['bottleneck']}** | {ro['compute_fraction']:.2f} "
            f"| {mfr:.2f} |" if mfr else
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {ro['flops']:.2e} | {_fmt_s(ro['t_compute'])} "
            f"| {_fmt_s(ro['t_memory'])} | {_fmt_s(ro['t_collective'])} "
            f"| **{ro['bottleneck']}** | {ro['compute_fraction']:.2f} "
            f"| n/a |")
        rows[-1] += f" {LEVERS[ro['bottleneck']]} |"
    return "\n".join(rows)


def dryrun_matrix(recs) -> str:
    cells: dict = {}
    for r in recs:
        if (r.get("tag") or ""):
            continue
        key = (r["arch"], r["shape"])
        mesh = "multi" if r.get("multi_pod") else "single"
        if "skipped" in r:
            cells.setdefault(key, {})[mesh] = "SKIP"
            cells.setdefault(key, {})["why"] = r["skipped"]
        else:
            peak = r["memory"]["peak_est"]
            cells.setdefault(key, {})[mesh] = f"OK({_fmt_b(peak)})"
    rows = ["| arch | shape | 16x16 (peak/dev) | 2x16x16 (peak/dev) |",
            "|---|---|---|---|"]
    for (a, s), v in sorted(cells.items()):
        rows.append(f"| {a} | {s} | {v.get('single','?')} "
                    f"| {v.get('multi','?')} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"))
    args = ap.parse_args()
    recs = load(args.dir)
    print("## Dry-run matrix\n")
    print(dryrun_matrix(recs))
    print("\n## Roofline (single-pod 16x16 baselines)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
