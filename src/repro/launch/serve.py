"""Production serving launcher: builds a (doc-sharded) Seismic index
over a synthetic collection and serves batched queries; reports
throughput, recall, and docs-evaluated telemetry.

  PYTHONPATH=src python -m repro.launch.serve --n-docs 8192 --queries 256
  PYTHONPATH=src python -m repro.launch.serve --devices 8 --doc-shards 4
"""
import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=8192)
    ap.add_argument("--dim", type=int, default=2048)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--budget", type=int, default=16)
    ap.add_argument("--cut", type=int, default=10)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--doc-shards", type=int, default=1)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + f" --xla_force_host_platform_device_count={args.devices}")
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core import SeismicConfig, SearchParams, build_index
    from repro.core.baselines import exact_search
    from repro.core.oracle import recall_at_k
    from repro.data import SyntheticSparseConfig, make_collection
    from repro.serve.engine import SeismicServer
    from repro.sparse.ops import PaddedSparse

    cfg = SyntheticSparseConfig(dim=args.dim, n_docs=args.n_docs,
                                n_queries=args.queries, doc_nnz=96,
                                query_nnz=32)
    docs_np, queries_np, _ = make_collection(cfg)
    docs = PaddedSparse(jnp.asarray(docs_np.coords),
                        jnp.asarray(docs_np.vals), docs_np.dim)
    queries = PaddedSparse(jnp.asarray(queries_np.coords),
                           jnp.asarray(queries_np.vals), queries_np.dim)
    icfg = SeismicConfig(lam=192, beta=12, alpha=0.4, block_cap=32,
                         summary_nnz=48)
    p = SearchParams(k=args.k, cut=args.cut, block_budget=args.budget,
                     policy="adaptive")

    if args.doc_shards > 1:
        from repro.core.distributed import (build_sharded_index,
                                            make_distributed_search)
        n_dev = len(jax.devices())
        mesh = jax.make_mesh((n_dev // args.doc_shards, args.doc_shards),
                             ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        stacked = build_sharded_index(docs, icfg, args.doc_shards)
        search = make_distributed_search(mesh, p)
        with jax.set_mesh(mesh):
            t0 = time.time()
            s, ids = jax.jit(search)(stacked, queries.coords, queries.vals)
            jax.block_until_ready(s)
            dt = time.time() - t0
        ids = np.asarray(ids)
    else:
        index = build_index(docs, icfg, list_chunk=32)
        server = SeismicServer(index, p, max_batch=min(args.queries, 256))
        t0 = time.time()
        result = server.search(queries)
        dt = time.time() - t0
        ids = result.ids
        print(f"docs evaluated (mean): {result.docs_evaluated.mean():.0f}")

    _, exact_ids = exact_search(docs, queries, args.k)
    rec = np.mean([recall_at_k(ids[q], np.asarray(exact_ids[q]))
                   for q in range(args.queries)])
    print(f"{args.queries} queries in {dt*1000:.0f} ms "
          f"({dt/args.queries*1e6:.0f} us/query, includes first-batch "
          f"compile)  recall@{args.k}={rec:.3f}")


if __name__ == "__main__":
    main()
