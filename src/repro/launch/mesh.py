"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (16, 16) = 256 chips,
("data", "model"). Multi-pod: (2, 16, 16) = 512 chips,
("pod", "data", "model") — the leading pod axis is the inter-pod DCN
dimension; nothing below hardcodes 2 pods, so 4/8-pod meshes are a
shape change here.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh_for(n_devices: int, model_parallel: int = 1):
    """Elastic helper: whatever devices exist -> (data, model) mesh."""
    assert n_devices % model_parallel == 0
    shape = (n_devices // model_parallel, model_parallel)
    return jax.make_mesh(
        shape, ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
