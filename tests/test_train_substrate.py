"""Optimizer, schedules, data pipeline, and training-loop behaviour."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.train import AdamWConfig, adamw_update, init_opt_state, make_train_step
from repro.train.optimizer import global_norm, schedule


def _quad_loss(params, batch):
    return jnp.sum((params["w"] - batch["target"]) ** 2)


def test_adamw_converges_quadratic():
    params = dict(w=jnp.ones((8,)) * 5.0)
    opt = init_opt_state(params)
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, clip_norm=100.0)
    batch = dict(target=jnp.zeros((8,)))
    step = jax.jit(make_train_step(_quad_loss, cfg))
    for _ in range(150):
        params, opt, m = step(params, opt, batch)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_clipping_bounds_update():
    params = dict(w=jnp.zeros((4,)))
    opt = init_opt_state(params)
    cfg = AdamWConfig(lr=1.0, clip_norm=1e-3, warmup_steps=0, total_steps=10)
    grads = dict(w=jnp.ones((4,)) * 1e6)
    p2, opt2, m = adamw_update(grads, opt, params, cfg)
    assert float(m["grad_norm"]) > 1e5
    assert float(jnp.abs(p2["w"]).max()) < 2.0  # clip tamed the step


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1.0) < 0.01          # end of warmup
    assert lrs[-1] == pytest.approx(0.1, rel=0.05)
    assert all(b <= a + 1e-6 for a, b in zip(lrs[2:], lrs[3:]))  # decay


def test_microbatched_grad_accum_matches_full():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((6, 3)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((8, 6)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((8, 3)), jnp.float32)

    def loss(params, batch):
        return jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)

    cfg = AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=10)
    s1 = make_train_step(loss, cfg, microbatches=1)
    s4 = make_train_step(loss, cfg, microbatches=4)
    p1, _, m1 = jax.jit(s1)(dict(w=w), init_opt_state(dict(w=w)),
                            dict(x=x, y=y))
    p4, _, m4 = jax.jit(s4)(dict(w=w), init_opt_state(dict(w=w)),
                            dict(x=x, y=y))
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p4["w"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)


def test_lm_loss_descends_on_structured_stream():
    """End-to-end: tiny llama on the synthetic n-gram stream must beat
    its initial loss within a few dozen steps."""
    from repro.data.pipeline import PrefetchLoader, lm_token_stream
    from repro.models.api import get_bundle
    bundle = get_bundle("llama3-8b")
    cfg = bundle.reduced
    dims = dict(global_batch=8, seq_len=32)
    params = bundle.init(jax.random.PRNGKey(0), cfg, dims)
    loss_fn = bundle.step(cfg, dims, "train")
    step = jax.jit(make_train_step(loss_fn, AdamWConfig(
        lr=3e-3, warmup_steps=5, total_steps=100)))
    opt = init_opt_state(params)
    loader = PrefetchLoader(lm_token_stream(cfg.vocab, 8, 32), prefetch=2)
    losses = []
    for i, batch in enumerate(loader):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
        if i >= 40:
            break
    loader.close()
    assert np.mean(losses[-5:]) < np.mean(losses[:3]) - 0.3, losses[:3] + losses[-5:]


def test_prefetch_loader_order_and_close():
    from repro.data.pipeline import PrefetchLoader

    def make():
        return iter(range(10))

    out = list(PrefetchLoader(make, prefetch=3))
    assert out == list(range(10))


def test_global_norm():
    t = dict(a=jnp.asarray([3.0]), b=jnp.asarray([4.0]))
    assert float(global_norm(t)) == pytest.approx(5.0)
