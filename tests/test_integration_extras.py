"""Deeper integration coverage: Pallas path inside the model, MoE
dispatch invariants, and the production train launcher end-to-end
(multi-device subprocess with checkpoint resume)."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from helpers import run_with_devices


def test_lm_forward_pallas_matches_xla():
    """use_pallas=True (interpret-mode flash kernel) == XLA sdpa path."""
    from repro.models.api import get_bundle
    from repro.models.transformer import lm
    bundle = get_bundle("llama3-8b")
    cfg = bundle.reduced
    params = bundle.init(jax.random.PRNGKey(0), cfg, {})
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 128)), jnp.int32)
    lx, _ = lm.forward(params, toks, cfg, use_pallas=False)
    lp, _ = lm.forward(params, toks, cfg, use_pallas=True)
    np.testing.assert_allclose(np.asarray(lx, np.float32),
                               np.asarray(lp, np.float32),
                               rtol=5e-3, atol=5e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(4, 64), st.sampled_from([4, 8]), st.sampled_from([1, 2]),
       st.integers(0, 2 ** 31 - 1))
def test_moe_dispatch_invariants(t, e, k, seed):
    """Sort-based dispatch: outputs are convex combinations of expert
    outputs over the top-k experts; dropping only ever zeroes tokens."""
    from repro.configs.base import TransformerConfig
    from repro.models.transformer.ffn import init_moe, moe_local, _route
    cfg = TransformerConfig(
        name="t", n_layers=1, d_model=16, n_heads=2, n_kv_heads=1,
        d_head=8, d_ff=32, vocab=64, moe=True, n_experts=e, moe_top_k=k,
        moe_d_ff=8, capacity_factor=1.0, dtype="float32")
    key = jax.random.PRNGKey(seed % 2 ** 31)
    p = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (t, cfg.d_model))
    idx, w, aux = _route(p["router"], x, k)
    assert (idx >= 0).all() and (idx < e).all()
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    out, _ = moe_local(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()


def test_moe_capacity_drops_bounded():
    """With capacity_factor=1, at most (1 - 1/cf) of assignments drop;
    with a huge factor nothing drops and outputs differ."""
    from repro.configs.base import TransformerConfig
    from repro.models.transformer.ffn import init_moe, moe_local
    base = TransformerConfig(
        name="t", n_layers=1, d_model=16, n_heads=2, n_kv_heads=1,
        d_head=8, d_ff=32, vocab=64, moe=True, n_experts=4, moe_top_k=2,
        moe_d_ff=8, capacity_factor=0.25, dtype="float32")
    p = init_moe(jax.random.PRNGKey(0), base, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    out_small, _ = moe_local(p, x, base)
    big = dataclasses.replace(base, capacity_factor=64.0)
    out_big, _ = moe_local(p, x, big)
    s, b = np.asarray(out_small), np.asarray(out_big)
    # tight capacity drops assignments -> outputs differ and carry less
    # expert mass on average; generous capacity drops nothing
    changed = np.any(s != b, axis=-1).mean()
    assert changed > 0.2, changed
    assert np.linalg.norm(s, axis=-1).mean() \
        < np.linalg.norm(b, axis=-1).mean() + 1e-6


TRAIN_LAUNCH_CODE = r"""
import subprocess, sys, os
repo = %REPO%
env = dict(os.environ)
env["PYTHONPATH"] = os.path.join(repo, "src")
args = [sys.executable, "-m", "repro.launch.train", "--arch", "llama3-8b",
        "--reduced", "--steps", "12", "--batch", "4", "--seq", "16",
        "--ckpt-dir", "/tmp/launch_train_test", "--ckpt-every", "5"]
import shutil
shutil.rmtree("/tmp/launch_train_test", ignore_errors=True)
p1 = subprocess.run(args, env=env, capture_output=True, text=True, timeout=600)
assert p1.returncode == 0, p1.stderr[-2000:]
assert "loss=" in p1.stdout
# resume run picks up the committed checkpoint
p2 = subprocess.run(args + ["--resume"], env=env, capture_output=True,
                    text=True, timeout=600)
assert p2.returncode == 0, p2.stderr[-2000:]
assert "resumed from step" in p2.stdout, p2.stdout
print("OK launcher")
"""


def test_train_launcher_end_to_end(tmp_path):
    import os
    code = TRAIN_LAUNCH_CODE.replace(
        "%REPO%", repr(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__)))))
    out = run_with_devices(code, n_devices=1, timeout=1300)
    assert "OK launcher" in out
