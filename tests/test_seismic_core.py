"""System tests: index-build invariants, query accuracy, oracle agreement."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import SearchParams, search_batch
from repro.core.baselines import (build_ivf, exact_search, impact_search,
                                  ivf_search)
from repro.core.oracle import (NumpyIndexView, algorithm2, exact_topk,
                               recall_at_k)
from repro.sparse.quant import dequantize_u8


# ------------------------------------------------------------------ build

def test_build_shapes(small_index, small_collection):
    idx, cfg = small_index
    docs, *_ = small_collection
    assert idx.list_docs.shape == (docs.dim, cfg.lam)
    assert idx.sum_q.shape == (docs.dim, cfg.n_blocks, cfg.summary_nnz)
    assert int(idx.list_len.max()) <= cfg.lam


def test_static_pruning_is_topk_by_value(small_index, small_collection):
    """§5.1: each list holds the lam docs with the largest x_i."""
    idx, cfg = small_index
    docs, _, docs_np, *_ = small_collection
    d = docs.dim
    # reconstruct coordinate values from the collection
    dense = np.zeros((docs_np.coords.shape[0], d), np.float32)
    rows = np.arange(docs_np.coords.shape[0])[:, None]
    np.add.at(dense, (rows, docs_np.coords), docs_np.vals)
    list_docs = np.asarray(idx.list_docs)
    list_len = np.asarray(idx.list_len)
    for i in range(0, d, 97):
        ln = int(list_len[i])
        if ln == 0:
            continue
        col = dense[:, i]
        got = set(list_docs[i, :ln][list_docs[i, :ln] < dense.shape[0]].tolist())
        want_order = np.argsort(-col, kind="stable")[:ln]
        # value-level comparison (ties may be broken arbitrarily, §5.1)
        thresh = col[want_order[-1]]
        assert all(col[g] >= thresh - 1e-6 for g in got)
        assert len(got) == ln


def test_blocks_partition_list(small_index):
    """Physical blocks tile each list exactly: offsets/lengths cover
    [0, list_len) without overlap, each block <= block_cap."""
    idx, cfg = small_index
    off = np.asarray(idx.block_off)
    ln = np.asarray(idx.block_len)
    ll = np.asarray(idx.list_len)
    assert (ln <= cfg.block_cap).all()
    for i in range(0, off.shape[0], 53):
        used = ln[i] > 0
        if not used.any():
            assert ll[i] == 0
            continue
        segs = sorted(zip(off[i][used].tolist(), ln[i][used].tolist()))
        cursor = 0
        for o, l in segs:
            assert o == cursor
            cursor += l
        assert cursor == ll[i]


def test_summary_upper_bounds_partial_ip(small_index, small_collection):
    """Eq. 2 conservatism: before alpha-pruning, <q, phi(B)> >= <q, x>
    restricted to summary coords. After alpha-mass pruning + quant the
    bound may be violated only by the pruned mass + quant step."""
    idx, cfg = small_index
    docs, *_ = small_collection
    fwd_c = np.asarray(idx.fwd.coords)
    fwd_v = np.asarray(idx.fwd.vals)
    d = docs.dim
    sum_c = np.asarray(idx.sum_coords)
    sum_v = np.asarray(dequantize_u8(idx.sum_q, idx.sum_scale, idx.sum_zero))
    list_docs = np.asarray(idx.list_docs)
    off = np.asarray(idx.block_off)
    ln = np.asarray(idx.block_len)
    checked = 0
    for i in range(0, d, 211):
        for j in range(cfg.n_blocks):
            if ln[i, j] == 0:
                continue
            summ = np.zeros(d)
            np.maximum.at(summ, sum_c[i, j], sum_v[i, j])
            members = list_docs[i, off[i, j]: off[i, j] + ln[i, j]]
            for m in members[:4]:
                if m >= fwd_c.shape[0]:
                    continue
                doc = np.zeros(d)
                np.add.at(doc, fwd_c[m], fwd_v[m])
                mask = summ > 0
                # on the kept coords the (dequantized) max dominates
                assert (summ[mask] >= doc[mask] - float(idx.sum_scale[i, j])
                        - 1e-5).all()
                checked += 1
    assert checked > 20


# ------------------------------------------------------------------ query

@pytest.mark.parametrize("policy", ["budget", "adaptive"])
def test_search_recall(small_index, small_collection, policy):
    idx, _ = small_index
    docs, queries, *_ = small_collection
    p = SearchParams(k=10, cut=8, block_budget=48, heap_factor=0.9,
                     policy=policy)
    s, ids, ev = search_batch(idx, queries, p)
    es, eids = exact_search(docs, queries, 10)
    recalls = [recall_at_k(np.asarray(ids[q]), np.asarray(eids[q]))
               for q in range(queries.n)]
    assert np.mean(recalls) >= 0.9
    # approximate: must not evaluate the whole collection
    assert np.asarray(ev).mean() < 0.5 * docs.n


def test_adaptive_beats_budget_on_docs_evaluated(small_index, small_collection):
    """heap_factor-adaptive routing evaluates far fewer docs at similar
    recall (the paper's dynamic-pruning claim)."""
    idx, _ = small_index
    docs, queries, *_ = small_collection
    pb = SearchParams(k=10, cut=8, block_budget=48, policy="budget")
    pa = SearchParams(k=10, cut=8, block_budget=48, policy="adaptive")
    _, _, evb = search_batch(idx, queries, pb)
    _, _, eva = search_batch(idx, queries, pa)
    assert np.asarray(eva).mean() < 0.7 * np.asarray(evb).mean()


def test_search_scores_are_exact_ips(small_index, small_collection):
    """Returned scores must equal exact inner products (forward index
    correction, §5.4)."""
    idx, _ = small_index
    docs, queries, docs_np, queries_np, _ = small_collection
    p = SearchParams(k=10, cut=8, block_budget=48, policy="budget")
    s, ids, _ = search_batch(idx, queries, p)
    q_dense = np.zeros((queries.n, docs.dim))
    rows = np.arange(queries.n)[:, None]
    np.add.at(q_dense, (rows, queries_np.coords), queries_np.vals)
    fwd_c, fwd_v = np.asarray(idx.fwd.coords), np.asarray(idx.fwd.vals)
    for q in range(queries.n):
        for j in range(10):
            doc = int(ids[q, j])
            if doc < 0:
                continue
            ip = (q_dense[q][fwd_c[doc]] * fwd_v[doc]).sum()
            np.testing.assert_allclose(float(s[q, j]), ip, rtol=2e-4)


def test_oracle_algorithm2_agreement(small_index, small_collection):
    """The faithful heap traversal and the batched TPU path must land in
    the same accuracy regime on the same index."""
    idx, _ = small_index
    docs, queries, docs_np, queries_np, _ = small_collection
    view = NumpyIndexView(idx)
    p = SearchParams(k=10, cut=8, block_budget=48, policy="adaptive")
    _, ids, _ = search_batch(idx, queries, p)
    r_jax, r_orc = [], []
    for q in range(queries.n):
        es, eids = exact_topk(docs_np.coords, docs_np.vals, docs.dim,
                              queries_np.coords[q], queries_np.vals[q], 10)
        _, oids, _ = algorithm2(view, queries_np.coords[q],
                                queries_np.vals[q], 10, cut=8,
                                heap_factor=0.9)
        r_jax.append(recall_at_k(np.asarray(ids[q]), eids))
        r_orc.append(recall_at_k(oids, eids))
    assert abs(np.mean(r_jax) - np.mean(r_orc)) < 0.1
    assert np.mean(r_orc) > 0.85


def test_more_budget_more_recall(small_index, small_collection):
    idx, _ = small_index
    docs, queries, *_ = small_collection
    es, eids = exact_search(docs, queries, 10)
    rec = []
    for budget in (4, 16, 64):
        p = SearchParams(k=10, cut=8, block_budget=budget, policy="budget")
        _, ids, _ = search_batch(idx, queries, p)
        rec.append(np.mean([recall_at_k(np.asarray(ids[q]),
                                        np.asarray(eids[q]))
                            for q in range(queries.n)]))
    assert rec[0] <= rec[1] + 0.05 <= rec[2] + 0.1
    assert rec[-1] >= 0.95


# -------------------------------------------------------------- baselines

def test_ivf_baseline(small_index, small_collection):
    docs, queries, *_ = small_collection
    ivf = build_ivf(docs, n_clusters=64, cap=128)
    es, eids = exact_search(docs, queries, 10)
    _, ids, ev = ivf_search(ivf, queries, 10, nprobe=8)
    recalls = [recall_at_k(np.asarray(ids[q]), np.asarray(eids[q]))
               for q in range(queries.n)]
    assert np.mean(recalls) > 0.8


def test_impact_baseline_needs_more_postings(small_index, small_collection):
    """LSR breaks impact-sorted early termination: recall climbs slowly
    with the posting budget (paper §1/§7.2: IOQP is the slowest)."""
    idx, _ = small_index
    docs, queries, *_ = small_collection
    es, eids = exact_search(docs, queries, 10)

    def rec(b):
        _, ids = impact_search(idx.list_docs, idx.list_vals, idx.list_len,
                               docs.n, queries, 10, postings_per_list=b)
        return np.mean([recall_at_k(np.asarray(ids[q]), np.asarray(eids[q]))
                        for q in range(queries.n)])
    assert rec(16) < 0.6          # small budget is badly wrong
    assert rec(128) > rec(16)     # monotone improvement
