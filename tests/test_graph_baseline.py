"""IP-NSW graph baseline: correctness + the paper's docs-evaluated gap."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.graph_baseline import IPNSWIndex
from repro.core.oracle import exact_topk, recall_at_k


@pytest.fixture(scope="module")
def setup():
    from repro.data import SyntheticSparseConfig, make_collection
    cfg = SyntheticSparseConfig(dim=1024, n_docs=4096, n_queries=16,
                                doc_nnz=48, query_nnz=16, n_topics=32,
                                topic_coords=128, seed=9)
    docs, queries, _ = make_collection(cfg)
    idx = IPNSWIndex(docs.coords, docs.vals, cfg.dim, m=16)
    return cfg, docs, queries, idx


def _curve(cfg, docs, queries, idx, ef):
    recs, evs = [], []
    for qi in range(queries.coords.shape[0]):
        _, ids, ev = idx.search(queries.coords[qi], queries.vals[qi], 10, ef)
        _, eids = exact_topk(docs.coords, docs.vals, cfg.dim,
                             queries.coords[qi], queries.vals[qi], 10)
        recs.append(recall_at_k(ids, eids))
        evs.append(ev)
    return float(np.mean(recs)), float(np.mean(evs))


def test_ipnsw_monotone_in_ef(setup):
    cfg, docs, queries, idx = setup
    r1, e1 = _curve(cfg, docs, queries, idx, 8)
    r2, e2 = _curve(cfg, docs, queries, idx, 256)
    assert r2 >= r1
    assert e2 > e1          # wider beams always visit more docs
    assert r2 > 0.85


def test_seismic_beats_graph_on_docs_evaluated(setup):
    """The paper's headline (§7.2.1): at matched recall the graph walk
    evaluates far more documents than Seismic."""
    from repro.core import SeismicConfig, SearchParams, build_index, search_batch
    from repro.sparse.ops import PaddedSparse
    cfg, docs_np, queries_np, gidx = setup
    docs = PaddedSparse(jnp.asarray(docs_np.coords),
                        jnp.asarray(docs_np.vals), cfg.dim)
    queries = PaddedSparse(jnp.asarray(queries_np.coords),
                           jnp.asarray(queries_np.vals), cfg.dim)
    sidx = build_index(docs, SeismicConfig(lam=128, beta=8, alpha=0.4,
                                           block_cap=32, summary_nnz=32),
                       list_chunk=16)
    p = SearchParams(k=10, cut=8, block_budget=32, policy="adaptive")
    _, ids, ev = search_batch(sidx, queries, p)
    seismic_docs = float(np.asarray(ev).mean())
    r_seis = np.mean([
        recall_at_k(np.asarray(ids[q]),
                    exact_topk(docs_np.coords, docs_np.vals, cfg.dim,
                               queries_np.coords[q], queries_np.vals[q],
                               10)[1])
        for q in range(queries.n)])
    r_graph, graph_docs = _curve(cfg, docs_np, queries_np, gidx, 64)
    assert r_seis >= r_graph - 0.02          # at least matched accuracy
    assert graph_docs > 2.0 * seismic_docs   # paper: 2.6-18x by model
