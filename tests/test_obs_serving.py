"""Observability threaded through serving: request span trees from
real served traffic, coalesced-trace linkage, sampled stage detail,
device accounting, and the Prometheus endpoint contract — asserted on
the *exported* surface (parsed endpoint text), not registry internals.
"""
import json
import threading
import urllib.request

import numpy as np
import pytest

from repro.graph import build_doc_graph
from repro.obs import Observability, start_exporter, validate_trace
from repro.retrieval import STAGES, SearchParams
from repro.serve import AsyncSeismicServer, SeismicServer


def _params(**kw):
    kw.setdefault("k", 5)
    kw.setdefault("cut", 8)
    kw.setdefault("block_budget", 8)
    return SearchParams(**kw)


def _server(idx, obs, **kw):
    kw.setdefault("max_batch", 8)
    kw.setdefault("query_nnz", 16)
    kw.setdefault("deadline_s", 0.05)
    kw.setdefault("params", _params())
    params = kw.pop("params")
    return AsyncSeismicServer(idx, params, obs=obs, **kw)


@pytest.fixture(scope="module")
def graph_index(small_index):
    """The small index carrying a kNN doc graph, so sampled traces get
    refine-round child spans."""
    idx, _ = small_index
    return build_doc_graph(idx, degree=4, batch=256)


def _one_query(small_collection, i=0):
    _, queries, *_ = small_collection
    return (np.asarray(queries.coords[i]), np.asarray(queries.vals[i]))


def _spans_by_name(trace):
    out = {}
    for s in trace.spans:
        out.setdefault(s.name, []).append(s)
    return out


# ------------------------------------------------- span-tree structure

def test_single_request_full_span_tree(graph_index, small_collection):
    """The acceptance criterion: one served request on a sampled launch
    yields a connected request -> queue_wait + launch -> 6 stage spans
    -> refine-round children tree, and the Chrome export validates."""
    obs = Observability.create(stage_sample_every=1)
    srv = _server(graph_index, obs,
                  params=_params(graph_degree=4, refine_rounds=2),
                  deadline_s=0.01)
    c, v = _one_query(small_collection)
    with srv:
        assert srv.submit(c, v).result(10.0).ids.shape == (5,)
    traces = obs.tracer.finished()
    assert len(traces) == 1
    tr = traces[0]
    validate_trace(tr)
    by = _spans_by_name(tr)
    assert tr.root.name == "request"
    assert tr.root.attrs["status"] == "done"
    assert "docs_evaluated" in tr.root.attrs
    # queue_wait and launch hang off the request root
    (qw,), (launch,) = by["queue_wait"], by["launch"]
    assert qw.parent_id == tr.root.span_id
    assert launch.parent_id == tr.root.span_id
    assert launch.attrs["staged"] is True
    assert launch.attrs["occupancy"] == 1
    # all six stages hang off the launch span
    for stage in STAGES:
        (sp,) = by[f"stage_{stage}"]
        assert sp.parent_id == launch.span_id
    # per-round children nest under stage_refine
    (refine,) = by["stage_refine"]
    for j in range(2):
        (rnd,) = by[f"refine_round_{j}"]
        assert rnd.parent_id == refine.span_id
    assert set(by) == ({"request", "queue_wait", "launch",
                        "refine_round_0", "refine_round_1"}
                       | {f"stage_{s}" for s in STAGES})
    # the Chrome export is valid JSON with every span as an event
    chrome = json.loads(json.dumps(obs.tracer.export_chrome()))
    assert len(chrome["traceEvents"]) == len(tr.spans)
    assert {e["ph"] for e in chrome["traceEvents"]} == {"X"}


def test_unsampled_launches_skip_stage_detail(small_index,
                                              small_collection):
    """Off-cadence launches still trace request/queue/launch — stage
    children only appear every ``stage_sample_every``-th launch."""
    idx, _ = small_index
    obs = Observability.create(stage_sample_every=2)
    srv = _server(idx, obs, deadline_s=0.005, coalesce=False)
    c, v = _one_query(small_collection)
    with srv:
        for _ in range(4):                  # 4 sequential solo launches
            srv.submit(c, v).result(10.0)
    traces = obs.tracer.finished()
    assert len(traces) == 4
    staged_flags = []
    for tr in traces:
        validate_trace(tr)
        by = _spans_by_name(tr)
        assert set(by) >= {"request", "queue_wait", "launch"}
        (launch,) = by["launch"]
        staged_flags.append(launch.attrs["staged"])
        assert ("stage_router" in by) == launch.attrs["staged"]
    assert staged_flags == [True, False, True, False]   # seq 0,2 sampled


def test_concurrent_submits_wellformed_trees(small_index,
                                             small_collection):
    """Many threads submitting at once: every finished trace stays
    well-formed, and batch members share a launch interval linked by
    ``batch_seq``."""
    idx, _ = small_index
    _, queries, *_ = small_collection
    obs = Observability.create(stage_sample_every=1)
    srv = _server(idx, obs, coalesce=False, deadline_s=0.01)
    coords = np.asarray(queries.coords)
    vals = np.asarray(queries.vals)
    n_req, results = 16, []
    lock = threading.Lock()

    def client(i):
        r = srv.submit(coords[i % coords.shape[0]],
                       vals[i % vals.shape[0]]).result(20.0)
        with lock:
            results.append(r)

    with srv:
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(n_req)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert len(results) == n_req
    traces = obs.tracer.finished()
    assert len(traces) == n_req
    seqs = {}
    for tr in traces:
        validate_trace(tr)
        by = _spans_by_name(tr)
        (launch,) = by["launch"]
        assert by["queue_wait"][0].parent_id == tr.root.span_id
        seqs.setdefault(launch.attrs["batch_seq"], []).append(launch)
    # batch members agree on the launch interval and occupancy
    for members in seqs.values():
        assert len({(m.t0, m.t1) for m in members}) == 1
        assert all(m.attrs["occupancy"] == len(members)
                   for m in members)
    assert sum(len(m) for m in seqs.values()) == n_req


def test_coalesced_follower_trace_linkage(small_index, small_collection):
    """A coalesced duplicate gets its own complete trace whose root
    carries ``coalesced_into=<primary trace id>``."""
    idx, _ = small_index
    obs = Observability.create()
    srv = _server(idx, obs, deadline_s=0.01)
    c, v = _one_query(small_collection)
    f0 = srv.submit(c, v)                   # queued before worker start
    f1 = srv.submit(c, v)                   # coalesces onto f0's slot
    with srv:
        r0, r1 = f0.result(10.0), f1.result(10.0)
    assert not r0.coalesced and r1.coalesced
    traces = obs.tracer.finished()
    assert len(traces) == 2
    by_link = {tr.root.attrs.get("coalesced_into"): tr for tr in traces}
    primary = by_link.pop(None)
    ((linked_id, follower),) = by_link.items()
    assert linked_id == primary.trace_id
    for tr in (primary, follower):
        validate_trace(tr)
        assert tr.root.attrs["status"] == "done"
        assert set(_spans_by_name(tr)) >= {"request", "queue_wait",
                                           "launch"}


def test_midexecution_coalesce_spans_clamped(small_index,
                                             small_collection,
                                             monkeypatch):
    """Regression: a duplicate attaching while its primary is already
    executing has submit_t *after* the batch's dispatch_t; the follower
    queue_wait/launch spans must be clamped to non-negative intervals
    (pre-fix ``validate_trace`` rejected the inverted queue_wait)."""
    import repro.serve.batcher as batcher_mod
    idx, _ = small_index
    obs = Observability.create(stage_sample_every=0)   # fused path
    srv = _server(idx, obs, deadline_s=0.005)
    c, v = _one_query(small_collection)
    entered, release = threading.Event(), threading.Event()
    real = batcher_mod.search_pipeline
    first = []

    def slow_pipeline(index, q, params):
        out = real(index, q, params)
        if not first:                 # hold the first launch open so a
            first.append(1)           # duplicate can attach mid-flight
            entered.set()
            release.wait(10.0)
        return out

    with srv:                         # start (and warmup) unpatched
        monkeypatch.setattr(batcher_mod, "search_pipeline", slow_pipeline)
        f0 = srv.submit(c, v)
        assert entered.wait(10.0)
        f1 = srv.submit(c, v)         # coalesces onto the running batch
        release.set()
        r0, r1 = f0.result(10.0), f1.result(10.0)
    assert not r0.coalesced and r1.coalesced
    assert r1.latency_s >= 0.0
    traces = obs.tracer.finished()
    assert len(traces) == 2
    follower = next(tr for tr in traces
                    if tr.root.attrs.get("coalesced_into"))
    for tr in traces:
        validate_trace(tr)            # strict: every span has t1 >= t0
    by = _spans_by_name(follower)
    (qw,), (launch,) = by["queue_wait"], by["launch"]
    assert qw.t1 >= qw.t0
    assert launch.t1 >= launch.t0 and follower.root.t1 >= launch.t1


def test_cache_hit_and_rejected_traces_closed(small_index,
                                              small_collection):
    """Non-launch request outcomes still close their traces with a
    status: cache hits at submit, rejects at admission."""
    idx, _ = small_index
    obs = Observability.create()
    srv = _server(idx, obs, cache_size=8, deadline_s=0.005)
    c, v = _one_query(small_collection)
    with srv:
        srv.submit(c, v).result(10.0)
        assert srv.submit(c, v).result(10.0).cached
    statuses = sorted(tr.root.attrs["status"]
                      for tr in obs.tracer.finished())
    assert statuses == ["done", "done"]
    cached = [tr for tr in obs.tracer.finished()
              if tr.root.attrs.get("cached")]
    assert len(cached) == 1
    assert set(_spans_by_name(cached[0])) == {"request"}

    obs2 = Observability.create()
    srv2 = _server(idx, obs2, queue_bound=1, deadline_s=30.0,
                   coalesce=False)
    srv2.submit(c, v)
    f = srv2.submit(*_one_query(small_collection, 1))    # over bound
    assert f.status == "rejected"
    assert [tr.root.attrs["status"] for tr in obs2.tracer.finished()] \
        == ["rejected"]


# --------------------------------------------------- exported metrics

def test_prometheus_endpoint_serving_contract(graph_index,
                                              small_collection):
    """Parse the live /metrics endpoint after traced traffic: per-stage
    latency histograms, achieved-vs-modeled bytes gauges per fuse
    level, serving-health gauges."""
    from repro.obs import parse_prometheus_text
    from repro.obs.device import MODELED_STAGES

    obs = Observability.create(stage_sample_every=1)
    srv = _server(graph_index, obs,
                  params=_params(graph_degree=4, refine_rounds=1),
                  cache_size=8, deadline_s=0.005)
    _, queries, *_ = small_collection
    coords = np.asarray(queries.coords)
    vals = np.asarray(queries.vals)
    with srv, start_exporter(obs.registry, obs.tracer) as exp:
        for i in range(6):
            srv.submit(coords[i % 3], vals[i % 3]).result(10.0)
        with urllib.request.urlopen(exp.url + "/metrics") as r:
            text = r.read().decode()
    parsed = parse_prometheus_text(text)

    lat = parsed["seismic_latency_seconds"]
    assert lat["type"] == "histogram"

    def count_of(span):
        return lat["samples"].get(
            ("seismic_latency_seconds_count", (("span", span),)), 0.0)

    for span in ("request_e2e", "queue_wait", "launch"):
        assert count_of(span) >= 1
    for stage in STAGES:                    # sampled every launch here
        assert count_of(f"stage_{stage}") >= 1
    assert ("seismic_latency_seconds_bucket" in
            {name for name, _ in lat["samples"]})

    modeled = parsed["seismic_stage_modeled_bytes_per_query"]["samples"]
    achieved = parsed["seismic_stage_achieved_bytes_per_second"]["samples"]
    fuse = str(_params().fuse_level)
    for stage in MODELED_STAGES:
        key = (("fuse_level", fuse), ("stage", stage))
        assert modeled[("seismic_stage_modeled_bytes_per_query", key)] > 0
        assert achieved[
            ("seismic_stage_achieved_bytes_per_second", key)] > 0

    def scalar(name):
        return parsed[name]["samples"][(name, ())]

    assert scalar("seismic_cache_hit_rate") > 0        # repeat queries
    assert scalar("seismic_shed_rate") == 0.0
    assert scalar("seismic_deadline_miss_rate") <= 1.0
    assert scalar("seismic_docs_evaluated_mean") > 0
    occ = list(parsed["seismic_launch_width_occupancy"]
               ["samples"].values())
    assert occ and all(0 < o <= 1 for o in occ)


def test_tuned_drift_gauges(small_index, small_collection):
    """Serving params that match an attached TunedPolicy expose drift
    gauges against the policy's measured cost."""
    from repro.tune.policy import TunedPolicy, attach_tuned

    idx, _ = small_index
    pol = TunedPolicy(target=0.9, k=5, cut=8, block_budget=8,
                      policy="adaptive", measured_recall=0.95,
                      measured_cost=50.0)
    tuned_idx = attach_tuned(idx, [pol])
    obs = Observability.create()
    srv = _server(tuned_idx, obs, deadline_s=0.005)
    assert srv._tuned_match is pol
    with srv:
        srv.submit(*_one_query(small_collection)).result(10.0)
    snap = obs.registry.snapshot()
    (docs,) = snap["seismic_tuned_drift_docs"]["samples"]
    (ratio,) = snap["seismic_tuned_drift_ratio"]["samples"]
    assert docs["labels"] == {"target": "0.9"}
    served_mean = srv._ev_sum / srv._ev_n
    assert docs["value"] == pytest.approx(served_mean - 50.0)
    assert ratio["value"] == pytest.approx(served_mean / 50.0)


def test_telemetry_facade_shares_obs_registry(small_index,
                                              small_collection):
    """With ``obs`` attached the legacy export and the registry are two
    views of the SAME sink — no double bookkeeping."""
    idx, _ = small_index
    obs = Observability.create(stage_sample_every=0, tracing=False)
    srv = _server(idx, obs, deadline_s=0.005)
    assert srv.telemetry.registry is obs.registry
    with srv:
        srv.submit(*_one_query(small_collection)).result(10.0)
    tel = srv.telemetry_export()
    fam = obs.registry.get("seismic_events_total")
    reg_counts = {labels[0]: c.value for labels, c in fam.samples()}
    assert tel["counters"] == reg_counts
    assert reg_counts["served"] == 1


def test_sync_server_sampled_launch_traces(small_index,
                                           small_collection):
    """The offline SeismicServer facade records launch-rooted traces on
    the same sampling cadence."""
    idx, _ = small_index
    _, queries, *_ = small_collection
    obs = Observability.create(stage_sample_every=1)
    srv = SeismicServer(idx, _params(), max_batch=8, obs=obs)
    result = srv.search(queries)            # 16 queries -> 2 launches
    assert result.ids.shape == (queries.n, 5)
    traces = obs.tracer.finished()
    assert len(traces) == 2
    for tr in traces:
        validate_trace(tr)
        assert tr.root.name == "launch"
        assert tr.root.attrs["sync"] is True
        by = _spans_by_name(tr)
        for stage in STAGES:
            (sp,) = by[f"stage_{stage}"]
            assert sp.parent_id == tr.root.span_id
    lat = srv.telemetry.export()["latency_s"]
    for stage in STAGES:
        assert lat[f"stage_{stage}"]["count"] == 2
