"""Shared test utilities: subprocess device forcing + the hypothesis
fallback shim.

Multi-device tests must not set XLA_FLAGS in this process (jax locks
the device count on first init), so they shell out.

Property-test modules that ALSO carry deterministic sweeps import the
hypothesis surface from here (``from helpers import given, settings,
st, needs_hypothesis``): with hypothesis absent the decorators are
no-ops and every ``@needs_hypothesis`` test skips, while the
deterministic tests in the same module still collect and run. One
shim, not a copy per module.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # container without dev deps: deterministic
    HAVE_HYPOTHESIS = False  # sweeps still verify the invariants

    def given(*a, **k):      # no-op decorators so modules still collect
        return lambda f: f   # (tests are skipif-ed anyway)

    def settings(*a, **k):
        return lambda f: f

    class _St:
        def __getattr__(self, name):
            return lambda *a, **k: None
    st = _St()

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="property tests need hypothesis")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n--- stdout ---\n"
            f"{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    return proc.stdout
