"""Run a python snippet in a subprocess with a forced device count.

Multi-device tests must not set XLA_FLAGS in this process (jax locks
the device count on first init), so they shell out.
"""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n--- stdout ---\n"
            f"{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    return proc.stdout
