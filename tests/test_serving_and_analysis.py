"""Serving engines, data generators, and the HLO/roofline analysis
utilities."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.distributed.hlo_analysis import (collective_bytes, hlo_dot_flops,
                                            _shape_bytes)
from repro.distributed.roofline import Roofline, model_flops_train


# ------------------------------------------------------------- serving

def test_seismic_server_batching():
    from repro.core import SeismicConfig, SearchParams, build_index
    from repro.data import SyntheticSparseConfig, make_collection
    from repro.serve.engine import SeismicServer
    from repro.sparse.ops import PaddedSparse
    cfg = SyntheticSparseConfig(dim=512, n_docs=1024, n_queries=70,
                                doc_nnz=32, query_nnz=12, n_topics=16,
                                topic_coords=96)
    docs_np, queries_np, _ = make_collection(cfg)
    docs = PaddedSparse(jnp.asarray(docs_np.coords),
                        jnp.asarray(docs_np.vals), docs_np.dim)
    queries = PaddedSparse(jnp.asarray(queries_np.coords),
                           jnp.asarray(queries_np.vals), queries_np.dim)
    idx = build_index(docs, SeismicConfig(lam=96, beta=8, alpha=0.4,
                                          block_cap=24, summary_nnz=24),
                      list_chunk=16)
    server = SeismicServer(idx, SearchParams(k=5, cut=8, block_budget=16),
                           max_batch=32)   # 70 queries -> 3 padded batches
    res = server.search(queries)
    assert res.ids.shape == (70, 5)
    assert res.scores.shape == (70, 5)
    assert (res.docs_evaluated > 0).all()
    # padding queries must not leak into results
    assert res.ids.max() < docs.n


@pytest.mark.parametrize("n", [1, 5, 13])
def test_seismic_server_matches_pipeline(small_index, small_collection, n):
    """Padding edges: a single query, a partial batch, and a count that
    is not a multiple of max_batch — the pad-and-chunk facade must
    reproduce the un-padded ``search_pipeline`` output exactly."""
    from repro.retrieval import SearchParams, search_pipeline
    from repro.serve.engine import SeismicServer
    idx, _ = small_index
    _, queries, *_ = small_collection
    p = SearchParams(k=5, cut=8, block_budget=8)
    sub = queries[:n]
    want_s, want_ids, want_ev = search_pipeline(idx, sub, p)
    res = SeismicServer(idx, p, max_batch=8).search(sub)
    np.testing.assert_array_equal(res.ids, np.asarray(want_ids))
    np.testing.assert_allclose(res.scores, np.asarray(want_s), rtol=1e-6)
    np.testing.assert_array_equal(res.docs_evaluated, np.asarray(want_ev))


def test_seismic_server_empty_batch(small_index, small_collection):
    from repro.retrieval import SearchParams
    from repro.serve.engine import SeismicServer
    idx, _ = small_index
    _, queries, *_ = small_collection
    p = SearchParams(k=5, cut=8, block_budget=8)
    res = SeismicServer(idx, p, max_batch=8).search(queries[:0])
    assert res.ids.shape == (0, 5)
    assert res.scores.shape == (0, 5)
    assert res.docs_evaluated.shape == (0,)


def test_lm_decoder_generates():
    from repro.models.api import get_bundle
    from repro.serve.engine import LMDecoder
    bundle = get_bundle("phi3-medium-14b")
    cfg = bundle.reduced
    params = bundle.init(jax.random.PRNGKey(0), cfg, {})
    dec = LMDecoder(params, cfg, batch=2, max_seq=32)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 4)).astype(np.int32)
    out = dec.generate(prompts, n_steps=6, greedy=True)
    assert out.shape == (2, 10)
    np.testing.assert_array_equal(out[:, :4], prompts)


# ---------------------------------------------------------- generators

def test_recsys_log_stream_shapes():
    from repro.data.pipeline import recsys_log_stream
    from repro.models.api import get_bundle
    for arch in ("fm", "sasrec", "bst"):
        cfg = get_bundle(arch).reduced
        gen = recsys_log_stream(cfg, batch=16)()
        batch = next(gen)
        for k, v in batch.items():
            assert v.shape[0] == 16, (arch, k)


def test_random_graph_homophily():
    from repro.data.pipeline import random_graph
    g = random_graph(400, 4000, d_feat=12, n_classes=4, seed=0)
    labels, edges = g["labels"], g["edges"]
    src_l, dst_l = labels[edges[:, 0]], labels[edges[:, 1]]
    valid = (src_l >= 0) & (dst_l >= 0)
    same = (src_l == dst_l)[valid].mean()
    assert same > 0.5   # homophilous by construction (~0.7)


# ------------------------------------------------------------ analysis

def test_shape_bytes():
    assert _shape_bytes("f32[16,8]") == 512
    assert _shape_bytes("bf16[4]{0}") == 8
    assert _shape_bytes("(f32[2,2], u8[3])") == 19
    assert _shape_bytes("pred[]") == 1


def test_collective_bytes_parser():
    hlo = """
ENTRY %main {
  %ar = f32[128]{0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[64,2]{1,0} all-gather(%y), dimensions={0}
  %rs = (f32[8]{0}, f32[8]{0}) reduce-scatter(%a, %b), dimensions={0}
  %done = f32[128]{0} all-reduce-done(%start)
}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 512
    assert out["all-gather"] == 256
    assert out["reduce-scatter"] == 64
    assert out["total"] == 832
    assert out["total_wire"] == 832 + 512   # AR weighted 2x on the wire


def test_hlo_dot_flops_counter():
    hlo = """
%fused_computation {
  %p0 = f32[16,32]{1,0} parameter(0)
  %p1 = f32[32,8]{1,0} parameter(1)
  %dot.1 = f32[16,8]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
ENTRY %main {
  %a = bf16[4,8,16]{2,1,0} parameter(0)
  %b = bf16[4,16,2]{2,1,0} parameter(1)
  %dot.2 = bf16[4,8,2]{2,1,0} dot(%a, %b), lhs_batch_dims={0}, lhs_contracting_dims={2}, rhs_batch_dims={0}, rhs_contracting_dims={1}
}
"""
    out = hlo_dot_flops(hlo)
    assert out["n_dots"] == 2
    # dot.1: 2*16*8*32 = 8192 ; dot.2: 2*(4*8*2)*16 = 2048
    assert out["dot_flops"] == 8192 + 2048
    assert out["n_while"] == 0


def test_roofline_terms():
    r = Roofline(flops=197e12, hbm_bytes=819e9, coll_bytes=25e9)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.bottleneck in ("compute", "memory")
    assert r.compute_fraction() == pytest.approx(1.0)
    assert model_flops_train(8e9, 1e6) == pytest.approx(4.8e16)


def test_roofline_bottleneck_pick():
    r = Roofline(flops=1e12, hbm_bytes=1e9, coll_bytes=500e9)
    assert r.bottleneck == "collective"
    assert r.compute_fraction() < 0.01
