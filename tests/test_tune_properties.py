"""Property suite for the recall-target autotuner (repro.tune).

The tuner's contract is determinism + monotonicity, so everything here
is a property, not an example:

  * the recall/cost frontier is monotone — a higher recall target can
    never select a cheaper operating point;
  * the persisted ``TunedPolicy`` reproduces its knobs bit-exactly
    (attach -> save_index -> load_index -> from_tuned round-trip, and
    a JSON round-trip of the raw dataclass);
  * tuning is invariant to the ORDER of the held-out query sample —
    same point, same measured numbers, same fingerprint;
  * repeated tuning on identical inputs is bit-identical (the
    deterministic-seed check: no wall-clock, no RNG in the decision
    path);
  * stale persisted policies fail serve construction, not trace time.

Runs under the ``deterministic`` hypothesis profile (tests/conftest.py
registers it: derandomized, no example database) so CI cannot flake.
The deterministic tests (ckpt round-trips, bit-exact re-tune, serve
validation, frontier monotonicity) run even WITHOUT hypothesis via the
shared ``tests/helpers.py`` shim; only the ``@given`` tests skip.
"""
import dataclasses
import json

import numpy as np
import pytest
import jax.numpy as jnp

from helpers import given, needs_hypothesis, settings, st

from repro.core import SeismicConfig, build_index
from repro.core.baselines import exact_search
from repro.data import SyntheticSparseConfig, make_collection
from repro.graph import build_doc_graph
from repro.retrieval import SearchParams, search_pipeline
from repro.sparse.ops import PaddedSparse
from repro.tune import (MeasuredPoint, TunedPolicy, attach_tuned,
                        pareto_frontier, sample_fingerprint,
                        select_operating_point, sweep, tune,
                        tune_and_attach, validate_policy)

DEGREE = 6
_CFG = SyntheticSparseConfig(dim=512, n_docs=1024, n_queries=16,
                             doc_nnz=32, query_nnz=12, n_topics=16,
                             topic_coords=96, seed=13)
_ICFG = SeismicConfig(lam=96, beta=8, alpha=0.4, block_cap=24,
                      summary_nnz=24)

# small coupled grid: budgets x refine rounds (enough structure for a
# real frontier, small enough that the sweep compiles in seconds)
_GRID = [SearchParams(k=10, cut=8, block_budget=b, policy="budget",
                      graph_degree=d, refine_rounds=r)
         for b in (2, 4, 8, 16)
         for d, r in ((0, 0), (DEGREE, 1), (DEGREE, 2))]


_cache: dict = {}


def _fixture():
    """Built graph-carrying index + held-out sample + one shared sweep
    (module-cached: hypothesis examples must not rebuild indexes)."""
    if "fix" not in _cache:
        docs_np, queries_np, _ = make_collection(_CFG)
        docs = PaddedSparse(jnp.asarray(docs_np.coords),
                            jnp.asarray(docs_np.vals), docs_np.dim)
        queries = PaddedSparse(jnp.asarray(queries_np.coords),
                               jnp.asarray(queries_np.vals),
                               queries_np.dim)
        idx = build_index(docs, _ICFG, list_chunk=16)
        idx = build_doc_graph(idx, degree=DEGREE, batch=64,
                              build_params=SearchParams(
                                  k=DEGREE + 1, cut=8, block_budget=16,
                                  policy="budget"))
        _, eids = exact_search(docs, queries, 10)
        eids = np.asarray(eids)
        points = sweep(idx, queries, eids, k=10, grid=_GRID)
        _cache["fix"] = (idx, queries, eids, points)
    return _cache["fix"]


# --------------------------------------------------- frontier properties

def test_pareto_frontier_is_strictly_monotone():
    _, _, _, points = _fixture()
    front = pareto_frontier(points)
    assert len(front) >= 2, "degenerate sweep: no trade-off measured"
    for a, b in zip(front, front[1:]):
        assert b.recall > a.recall
        assert b.cost_key >= a.cost_key


def test_frontier_dominates_all_points():
    """Every swept point is dominated by (or on) the frontier, on the
    TRUE cost pair (docs, router dots) — not the tie-break knob tuple,
    which would hide an equal-cost higher-recall sibling."""
    _, _, _, points = _fixture()
    front = pareto_frontier(points)
    for pt in points:
        assert any(
            f.recall >= pt.recall - 1e-9
            and (f.docs_evaluated, f.router_cost)
            <= (pt.docs_evaluated, pt.router_cost)
            for f in front), pt


@needs_hypothesis
@settings(max_examples=30)
@given(st.floats(0.30, 0.999), st.floats(0.30, 0.999))
def test_higher_target_never_cheaper(t1, t2):
    """Selection monotonicity: raising the recall target can only keep
    or raise the selected cost, never lower it."""
    _, _, _, points = _fixture()
    lo, hi = sorted((t1, t2))
    best = max(pt.recall for pt in points)
    if hi > best:                       # clamp into the feasible range
        lo, hi = lo * best, hi * best
    a = select_operating_point(points, lo)
    b = select_operating_point(points, hi)
    assert b.cost_key >= a.cost_key


def test_infeasible_target_raises_with_best_achievable():
    _, _, _, points = _fixture()
    with pytest.raises(ValueError, match="infeasible"):
        select_operating_point(points, 1.5)


# ------------------------------------------- bit-exact reproducibility

def test_tune_is_deterministic_bit_for_bit():
    """Two tunes on identical inputs produce the identical policy —
    the decision path contains no wall time and no RNG."""
    idx, queries, eids, _ = _fixture()
    a = tune(idx, queries, eids, 0.85, grid=_GRID)
    b = tune(idx, queries, eids, 0.85, grid=_GRID)
    assert a == b


def test_tuned_policy_roundtrips_through_ckpt(tmp_path):
    """attach -> save_index -> load_index -> from_tuned reproduces the
    knobs AND the search results bit-exactly."""
    from repro.ckpt import load_index, save_index
    idx, queries, eids, points = _fixture()
    tidx = tune_and_attach(idx, queries, eids, targets=[0.8, 0.9],
                           grid=_GRID)
    save_index(str(tmp_path), tidx)
    loaded = load_index(str(tmp_path))
    assert loaded.tuned == tidx.tuned
    p0 = SearchParams.from_tuned(tidx, 0.85)
    p1 = SearchParams.from_tuned(loaded, 0.85)
    assert p0 == p1
    s0, i0, e0 = search_pipeline(tidx, queries, p0)
    s1, i1, e1 = search_pipeline(loaded, queries, p1)
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))


def test_tuned_policy_json_roundtrip_exact():
    """The manifest serialization (plain json) is lossless for every
    field, floats included."""
    idx, queries, eids, points = _fixture()
    pol = tune(idx, queries, eids, 0.85, points=points)
    d = json.loads(json.dumps(dataclasses.asdict(pol)))
    assert TunedPolicy(**d) == pol


def test_pre_tune_checkpoint_loads_untuned_and_bitexact(tmp_path):
    """An index saved WITHOUT policies (the pre-tune manifest layout)
    loads with tuned == () and searches bit-exact."""
    from repro.ckpt import load_index, save_index
    idx, queries, _, _ = _fixture()
    save_index(str(tmp_path), idx)
    loaded = load_index(str(tmp_path))
    assert loaded.tuned == ()
    p = SearchParams(k=10, cut=8, block_budget=8, policy="budget")
    s0, i0, _ = search_pipeline(idx, queries, p)
    s1, i1, _ = search_pipeline(loaded, queries, p)
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


# ------------------------------------------------- order invariance

@needs_hypothesis
@settings(max_examples=5)
@given(st.permutations(list(range(_CFG.n_queries))))
def test_tune_order_invariant(perm):
    """Tuning on a permuted held-out sample yields the IDENTICAL
    policy: same knobs, same measured recall/cost, same fingerprint."""
    idx, queries, eids, _ = _fixture()
    base = tune(idx, queries, eids, 0.85, grid=_GRID)
    perm = np.asarray(perm)
    shuffled = PaddedSparse(queries.coords[perm], queries.vals[perm],
                            queries.dim)
    permuted = tune(idx, shuffled, eids[perm], 0.85, grid=_GRID)
    assert permuted == base


def test_tune_order_invariant_fixed_permutation():
    """Deterministic single-permutation variant of the property above,
    so order invariance stays covered where hypothesis is absent."""
    idx, queries, eids, _ = _fixture()
    base = tune(idx, queries, eids, 0.85, grid=_GRID)
    perm = np.arange(_CFG.n_queries)[::-1]
    shuffled = PaddedSparse(queries.coords[perm], queries.vals[perm],
                            queries.dim)
    assert tune(idx, shuffled, eids[perm], 0.85, grid=_GRID) == base


@needs_hypothesis
@settings(max_examples=10)
@given(st.permutations(list(range(_CFG.n_queries))))
def test_sample_fingerprint_order_invariant(perm):
    _, queries, _, _ = _fixture()
    perm = np.asarray(perm)
    a = sample_fingerprint(queries.coords, queries.vals)
    b = sample_fingerprint(np.asarray(queries.coords)[perm],
                           np.asarray(queries.vals)[perm])
    assert a == b


def test_fingerprint_sensitive_to_sample_content():
    _, queries, _, _ = _fixture()
    vals = np.asarray(queries.vals).copy()
    vals[0, 0] += 1.0
    assert sample_fingerprint(queries.coords, vals) \
        != sample_fingerprint(queries.coords, queries.vals)


# --------------------------------------------- resolution + validation

def test_from_tuned_picks_cheapest_satisfying_policy():
    idx, queries, eids, points = _fixture()
    tidx = tune_and_attach(idx, queries, eids, targets=[0.7, 0.95],
                           grid=_GRID)
    lo = min(tidx.tuned, key=lambda t: t.measured_cost)
    hi = max(tidx.tuned, key=lambda t: t.measured_cost)
    # a request the cheap policy already satisfies resolves to it
    if lo.satisfies(0.7):
        assert SearchParams.from_tuned(tidx, 0.7) == lo.to_params()
    assert SearchParams.from_tuned(tidx, 0.95) == hi.to_params()
    with pytest.raises(ValueError, match="no persisted TunedPolicy"):
        SearchParams.from_tuned(tidx, 0.9999)
    with pytest.raises(ValueError, match="no TunedPolicy"):
        SearchParams.from_tuned(idx, 0.7)           # untuned index


def test_stale_policy_fails_serve_construction():
    """A persisted policy that outlived its index artifacts (graph
    dropped, superblock tier mismatch) must fail at server build."""
    from repro.serve import SeismicServer
    idx, queries, eids, points = _fixture()
    tidx = tune_and_attach(idx, queries, eids, targets=[0.85],
                           grid=_GRID)
    pol = tidx.tuned[0]
    assert pol.graph_degree > 0, "grid should have tuned into refine"
    stale = dataclasses.replace(tidx, knn_ids=None)
    with pytest.raises(ValueError, match="kNN graph"):
        SeismicServer(stale, SearchParams(k=10))
    # consistent index + policies constructs fine
    SeismicServer(tidx, SearchParams.from_tuned(tidx, 0.85))


def test_validate_policy_rejects_degenerate_and_mismatched():
    idx, *_ = _fixture()
    with pytest.raises(ValueError, match="target"):
        validate_policy(idx, TunedPolicy(target=0.0))
    with pytest.raises(ValueError, match="degenerate"):
        validate_policy(idx, TunedPolicy(target=0.9, block_budget=0))
    with pytest.raises(ValueError, match="not a registered"):
        validate_policy(idx, TunedPolicy(target=0.9, policy="nope"))
    with pytest.raises(ValueError, match="superblock"):
        validate_policy(idx, TunedPolicy(target=0.9,
                                         superblock_fanout=4))
    with pytest.raises(ValueError, match="exceeds the built"):
        validate_policy(idx, TunedPolicy(target=0.9,
                                         graph_degree=DEGREE + 1,
                                         refine_rounds=1))


def test_attach_tuned_orders_deterministically():
    idx, queries, eids, points = _fixture()
    a = tune(idx, queries, eids, 0.9, points=points)
    b = tune(idx, queries, eids, 0.7, points=points)
    assert attach_tuned(idx, [a, b]).tuned \
        == attach_tuned(idx, [b, a]).tuned


# --------------------------------------------- cost-model invariants

def test_measured_point_cost_key_total_order():
    """cost_key must order ANY two points deterministically (ties on
    docs and router work break on the knob tuple, never ambiguously)."""
    _, _, _, points = _fixture()
    keys = [pt.cost_key for pt in points]
    assert len(set(keys)) == len(keys)
    sorted(keys)          # every pair comparable (mixed types would raise)


def test_refine_cotuning_beats_budget_at_equal_recall():
    """The tentpole claim, mechanically: some refined point reaches the
    recall of a pure-budget point at strictly lower docs_evaluated —
    i.e. the graph stage pays for a reduced block budget."""
    _, _, _, points = _fixture()
    pure = [pt for pt in points if pt.params.refine_rounds == 0]
    refined = [pt for pt in points if pt.params.refine_rounds > 0]
    assert any(r.recall >= p.recall and
               r.docs_evaluated < p.docs_evaluated
               for p in pure for r in refined)
