"""Unit + property tests for the padded-sparse substrate."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.sparse.ops import (PaddedSparse, alpha_mass_subvector, densify,
                              densify_one, inner_product_padded,
                              l1_mass_fraction, sparsify, top_cut)
from repro.sparse.quant import dequantize_u8, quantize_u8


def _rand_sparse(rng, n, nnz, dim):
    coords = np.stack([rng.choice(dim, nnz, replace=False) for _ in range(n)])
    vals = rng.lognormal(0, 1, (n, nnz)).astype(np.float32)
    return PaddedSparse(jnp.asarray(coords.astype(np.int32)),
                        jnp.asarray(vals), dim)


def test_densify_sparsify_roundtrip():
    rng = np.random.default_rng(0)
    ps = _rand_sparse(rng, 8, 16, 128)
    dense = densify(ps)
    ps2 = sparsify(dense, 16)
    np.testing.assert_allclose(np.asarray(densify(ps2)), np.asarray(dense),
                               rtol=1e-6)


def test_padding_contributes_zero():
    coords = jnp.array([[3, 0, 0], [5, 7, 0]], jnp.int32)
    vals = jnp.array([[2.0, 0.0, 0.0], [1.0, 3.0, 0.0]])
    ps = PaddedSparse(coords, vals, 10)
    q = jnp.arange(10, dtype=jnp.float32)
    out = inner_product_padded(q, ps.coords, ps.vals)
    np.testing.assert_allclose(np.asarray(out), [6.0, 26.0])


def test_alpha_mass_definition():
    # Definition 3.1 on a known vector
    coords = jnp.arange(5, dtype=jnp.int32)
    vals = jnp.array([5.0, 3.0, 1.0, 0.5, 0.5])  # L1 = 10
    sc, sv = alpha_mass_subvector(coords, vals, alpha=0.8, out_nnz=5)
    # cumsums: 5, 8, 9 -> keep 5,3 (<=8) ; 9 > 8 stops
    kept = sorted(float(v) for v in np.asarray(sv) if v > 0)
    assert kept == [3.0, 5.0]


def test_alpha_mass_never_empty():
    coords = jnp.arange(3, dtype=jnp.int32)
    vals = jnp.array([4.0, 3.0, 2.0])
    sc, sv = alpha_mass_subvector(coords, vals, alpha=0.01, out_nnz=3)
    assert (np.asarray(sv) > 0).sum() == 1
    assert float(sv[0]) == 4.0


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 30), st.floats(0.1, 1.0), st.integers(0, 2 ** 31 - 1))
def test_alpha_mass_property(nnz, alpha, seed):
    """alpha-mass subvector keeps <= alpha * L1 (or exactly one entry)
    and always keeps the largest entries first."""
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.lognormal(0, 1, nnz).astype(np.float32))
    coords = jnp.arange(nnz, dtype=jnp.int32)
    sc, sv = alpha_mass_subvector(coords, vals, alpha, max(nnz, 1))
    kept = np.asarray(sv)
    mass = kept.sum()
    total = float(np.abs(np.asarray(vals)).sum())
    n_kept = (kept > 0).sum()
    assert n_kept >= 1
    if n_kept > 1:
        assert mass <= alpha * total + 1e-4
    # kept set == the n_kept largest values
    top = np.sort(np.asarray(vals))[::-1][:n_kept]
    np.testing.assert_allclose(np.sort(kept[kept > 0])[::-1], top, rtol=1e-6)


def test_top_cut():
    coords = jnp.array([7, 3, 9, 1], jnp.int32)
    vals = jnp.array([0.5, 2.0, 1.0, 0.1])
    c, v = top_cut(coords, vals, 2)
    assert set(np.asarray(c).tolist()) == {3, 9}


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 64), st.integers(0, 2 ** 31 - 1))
def test_quant_roundtrip_property(nnz, seed):
    """u8 quantization reconstructs within scale/2; padding -> exact 0."""
    rng = np.random.default_rng(seed)
    vals = rng.lognormal(0, 1, nnz).astype(np.float32)
    vals[rng.random(nnz) < 0.3] = 0.0  # padding
    v = jnp.asarray(vals)[None, :]
    q, scale, zero = quantize_u8(v)
    rec = np.asarray(dequantize_u8(q, scale, zero))[0]
    err_tol = float(scale[0]) * 0.51 + 1e-6
    valid = vals > 0
    if valid.any():
        assert np.abs(rec[valid] - vals[valid]).max() <= err_tol
    assert (rec[~valid] == 0).all()


def test_quant_summary_ip_error_small():
    """Quantized summary IP stays within ~1% of the float IP (the §7.3
    'quantization does not hinder effectiveness' claim)."""
    rng = np.random.default_rng(3)
    vals = jnp.asarray(rng.lognormal(0, 1, (16, 64)).astype(np.float32))
    q8, scale, zero = quantize_u8(vals)
    rec = dequantize_u8(q8, scale, zero)
    qv = jnp.asarray(rng.lognormal(0, 1, (64,)).astype(np.float32))
    ip_f = np.asarray(vals @ qv)
    ip_q = np.asarray(rec @ qv)
    rel = np.abs(ip_q - ip_f) / np.abs(ip_f)
    assert rel.max() < 0.01


def test_l1_mass_fraction_monotone():
    rng = np.random.default_rng(0)
    vals = rng.lognormal(0, 1.2, (32, 100))
    f10 = l1_mass_fraction(vals, 10)
    f50 = l1_mass_fraction(vals, 50)
    assert (f50 >= f10 - 1e-9).all()
    assert (l1_mass_fraction(vals, 100) > 0.999).all()
