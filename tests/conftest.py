"""Shared fixtures. NOTE: no XLA_FLAGS device-count forcing here —
smoke tests must see the single real CPU device; multi-device tests
spawn subprocesses (tests/helpers.py)."""
import numpy as np
import pytest

import jax.numpy as jnp

try:
    # deterministic-seed profile: hypothesis example generation derives
    # from the test body, never from entropy or a shared DB, so the
    # tuner property suites (and every other property test) can't flake
    # across CI runs or machines
    from hypothesis import settings as _hyp_settings
    _hyp_settings.register_profile("deterministic", derandomize=True,
                                   deadline=None, database=None)
    _hyp_settings.load_profile("deterministic")
except ImportError:          # property suites skip cleanly when absent
    pass

from repro.data import SyntheticSparseConfig, make_collection
from repro.sparse.ops import PaddedSparse


@pytest.fixture(scope="session")
def small_collection():
    cfg = SyntheticSparseConfig(dim=1024, n_docs=2048, n_queries=16,
                                doc_nnz=48, query_nnz=16, n_topics=32,
                                topic_coords=128, seed=7)
    docs_np, queries_np, meta = make_collection(cfg)
    docs = PaddedSparse(jnp.asarray(docs_np.coords),
                        jnp.asarray(docs_np.vals), docs_np.dim)
    queries = PaddedSparse(jnp.asarray(queries_np.coords),
                           jnp.asarray(queries_np.vals), queries_np.dim)
    return docs, queries, docs_np, queries_np, cfg


@pytest.fixture(scope="session")
def small_index(small_collection):
    from repro.core import SeismicConfig, build_index
    docs, *_ = small_collection
    cfg = SeismicConfig(lam=128, beta=8, alpha=0.4, block_cap=32,
                        summary_nnz=32)
    return build_index(docs, cfg, list_chunk=16), cfg
