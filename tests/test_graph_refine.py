"""kNN-graph refinement subsystem (repro.graph): verification suite.

The refine stage's contracts, each checked mechanically:

  * gating — ``graph_degree=0`` or ``refine_rounds=0`` traces as the
    identity, so a graph-carrying index is BIT-EXACT with the plain
    five-stage pipeline when the knobs are off;
  * monotonicity — refine rescoring goes through the scorer's own
    ``score_candidates`` (same forward plane), so the merged objective
    is uniform and recall@10 is monotone non-decreasing in
    ``refine_rounds``;
  * recovery — at a halved block budget, degree-8/1-round refinement
    lifts recall@10 by >= 5 points (the benchmark gate, enforced here
    at test scale);
  * artifacts — graph edges exclude self, respect the degree prefix
    property, and round-trip through ``ckpt.save_index`` (graph
    present AND pre-graph back-compat);
  * kernel parity — ``use_kernel=True`` refinement (interpret-mode
    Pallas gather_dot) matches the jnp path;
  * adaptive fanout — ``core.build.suggest_fanout`` and its
    ``configs/seismic_msmarco`` wiring.
"""
import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (SeismicConfig, build_index, live_blocks,
                        suggest_fanout)
from repro.core.baselines import exact_search
from repro.core.oracle import recall_at_k
from repro.data import SyntheticSparseConfig, make_collection
from repro.graph import (build_doc_graph, compact_forward_index,
                         expand_neighbors, validate_refine_params)
from repro.retrieval import SearchParams, search_pipeline
from repro.sparse.ops import PaddedSparse

DEGREE = 8


def _collection(seed=3, dim=512, n_docs=2048, n_queries=24):
    cfg = SyntheticSparseConfig(dim=dim, n_docs=n_docs,
                                n_queries=n_queries, doc_nnz=32,
                                query_nnz=12, n_topics=16,
                                topic_coords=96, seed=seed)
    docs_np, queries_np, _ = make_collection(cfg)
    docs = PaddedSparse(jnp.asarray(docs_np.coords),
                        jnp.asarray(docs_np.vals), docs_np.dim)
    queries = PaddedSparse(jnp.asarray(queries_np.coords),
                           jnp.asarray(queries_np.vals), queries_np.dim)
    return docs, queries


_cache: dict = {}


def _built():
    """(plain index, graph index, queries, exact ids) — built once."""
    if "fix" not in _cache:
        docs, queries = _collection()
        icfg = SeismicConfig(lam=96, beta=8, alpha=0.4, block_cap=24,
                             summary_nnz=24)
        idx = build_index(docs, icfg, list_chunk=16)
        gidx = build_doc_graph(
            idx, degree=DEGREE, batch=256,
            build_params=SearchParams(k=DEGREE + 1, cut=8,
                                      block_budget=16, policy="budget"))
        _, eids = exact_search(docs, queries, 10)
        _cache["fix"] = (idx, gidx, queries, np.asarray(eids))
    return _cache["fix"]


def _recall(idx, queries, eids, p):
    _, ids, _ = search_pipeline(idx, queries, p)
    ids = np.asarray(ids)
    return float(np.mean([recall_at_k(ids[q], eids[q])
                          for q in range(ids.shape[0])]))


# ------------------------------------------------------------- gating

def _assert_same_results(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_degree0_bitexact_with_plain_pipeline():
    """The graph-carrying index with refinement off must reproduce the
    five-stage (pre-graph) pipeline bit-exactly — scores, ids, AND
    docs_evaluated."""
    idx, gidx, queries, _ = _built()
    for p in (SearchParams(k=10, cut=8, block_budget=8),
              SearchParams(k=10, cut=8, block_budget=8, graph_degree=0,
                           refine_rounds=3),
              SearchParams(k=10, cut=8, block_budget=8,
                           graph_degree=DEGREE, refine_rounds=0)):
        _assert_same_results(search_pipeline(idx, queries, p),
                             search_pipeline(gidx, queries, p))


@pytest.mark.parametrize("policy", ["budget", "adaptive",
                                    "global_threshold"])
def test_degree0_bitexact_all_policies(policy):
    idx, gidx, queries, _ = _built()
    p = SearchParams(k=10, cut=8, block_budget=8, policy=policy)
    _assert_same_results(search_pipeline(idx, queries, p),
                         search_pipeline(gidx, queries, p))


# -------------------------------------------------------- monotonicity

def test_recall_monotone_in_refine_rounds():
    """Refine rescoring shares the scorer's forward plane, so the
    merged objective is uniform: the top-k only ever improves under it
    and recall@10 never decreases as rounds grow."""
    idx, gidx, queries, eids = _built()
    p0 = SearchParams(k=10, cut=8, block_budget=4, policy="budget")
    prev = _recall(idx, queries, eids, p0)
    for rounds in (1, 2, 3):
        p = dataclasses.replace(p0, graph_degree=DEGREE,
                                refine_rounds=rounds)
        r = _recall(gidx, queries, eids, p)
        assert r >= prev, (rounds, prev, r)
        prev = r


def test_docs_evaluated_grows_with_rounds():
    """Each round rescores only NEW candidates (dedupe against the
    already-scored top-k), so docs_evaluated grows by at most
    k * graph_degree per round and strictly grows while the frontier
    is fresh."""
    _, gidx, queries, _ = _built()
    p0 = SearchParams(k=10, cut=8, block_budget=4, policy="budget")
    _, _, ev_prev = search_pipeline(gidx, queries, p0)
    ev_prev = np.asarray(ev_prev)
    for rounds in (1, 2):
        p = dataclasses.replace(p0, graph_degree=DEGREE,
                                refine_rounds=rounds)
        _, _, ev = search_pipeline(gidx, queries, p)
        ev = np.asarray(ev)
        assert (ev >= ev_prev).all()
        assert (ev <= ev_prev + 10 * DEGREE).all()
        ev_prev = ev


def test_refined_topk_has_no_duplicates():
    _, gidx, queries, _ = _built()
    p = SearchParams(k=10, cut=8, block_budget=4, policy="budget",
                     graph_degree=DEGREE, refine_rounds=2)
    _, ids, _ = search_pipeline(gidx, queries, p)
    ids = np.asarray(ids)
    for q in range(ids.shape[0]):
        real = ids[q][ids[q] >= 0]
        assert len(set(real.tolist())) == real.size


# ---------------------------------------------------- recall recovery

def test_refine_lift_at_halved_budget():
    """The benchmark acceptance gate at test scale: degree-8 one-round
    refinement recovers >= 5 recall points at half the block budget."""
    idx, gidx, queries, eids = _built()
    p0 = SearchParams(k=10, cut=8, block_budget=4, policy="budget")
    p1 = dataclasses.replace(p0, graph_degree=DEGREE, refine_rounds=1)
    r0 = _recall(idx, queries, eids, p0)
    r1 = _recall(gidx, queries, eids, p1)
    assert r1 - r0 >= 0.05, (r0, r1)


def test_refine_kernel_parity():
    """use_kernel=True (interpret-mode Pallas gather_dot) must match
    the jnp rescoring path."""
    _, gidx, queries, _ = _built()
    p = SearchParams(k=10, cut=8, block_budget=4, policy="budget",
                     graph_degree=DEGREE, refine_rounds=2)
    pk = dataclasses.replace(p, use_kernel=True)
    s0, i0, e0 = search_pipeline(gidx, queries, p)
    s1, i1, e1 = search_pipeline(gidx, queries, pk)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))


@pytest.mark.parametrize("rounds", [1, 3])
def test_refine_fuse_levels_bitexact(rounds):
    """fuse_level 0/1/2 refinement (unfused / compacted frontier /
    single-launch fused round) must be BITWISE identical on scores,
    ids, and docs_evaluated."""
    _, gidx, queries, _ = _built()
    p0 = SearchParams(k=10, cut=8, block_budget=4, policy="budget",
                      graph_degree=DEGREE, refine_rounds=rounds)
    outs = [search_pipeline(gidx, queries,
                            dataclasses.replace(p0, fuse_level=lvl))
            for lvl in (0, 1, 2)]
    _assert_same_results(outs[0], outs[1])
    _assert_same_results(outs[0], outs[2])


def test_compact_forward_graph_pipeline():
    """compact_forward=True: u8 forward plane shared by scorer and
    refine; the refined search still beats the unrefined one on the
    SAME compact index (consistent objective)."""
    idx, _, queries, eids = _built()
    cgidx = build_doc_graph(
        idx, degree=DEGREE, batch=256, compact_forward=True,
        build_params=SearchParams(k=DEGREE + 1, cut=8, block_budget=16,
                                  policy="budget"))
    assert cgidx.fwd.vals.dtype == jnp.uint8
    assert cgidx.fwd_scale is not None and cgidx.config.fwd_quant
    p0 = SearchParams(k=10, cut=8, block_budget=4, policy="budget")
    p1 = dataclasses.replace(p0, graph_degree=DEGREE, refine_rounds=1)
    r0 = _recall(cgidx, queries, eids, p0)
    r1 = _recall(cgidx, queries, eids, p1)
    assert r1 - r0 >= 0.05, (r0, r1)


# ----------------------------------------------------- graph artifact

def test_graph_edges_exclude_self_and_padding():
    _, gidx, *_ = _built()
    nbrs = np.asarray(gidx.knn_ids)
    n = gidx.n_docs
    own = np.arange(n)[:, None]
    assert (nbrs != own).all(), "self edges must be dropped"
    assert ((nbrs >= 0) & (nbrs <= n)).all()   # real ids or sentinel n


def test_graph_degree_prefix_property():
    """graph_degree below the built degree uses the best-edge prefix:
    expand_neighbors(d) rows are the first d columns of the full
    expansion."""
    _, gidx, queries, _ = _built()
    p = SearchParams(k=10, cut=8, block_budget=4)
    _, ids, _ = search_pipeline(gidx, queries, p)
    full = np.asarray(expand_neighbors(gidx, ids, DEGREE)).reshape(
        ids.shape[0], -1, DEGREE)
    half = np.asarray(expand_neighbors(gidx, ids, DEGREE // 2)).reshape(
        ids.shape[0], -1, DEGREE // 2)
    np.testing.assert_array_equal(full[..., :DEGREE // 2], half)


def test_expand_neighbors_padding_rows():
    """-1 (padding) ids expand to the sentinel only."""
    _, gidx, *_ = _built()
    ids = jnp.asarray([[0, -1], [-1, -1]], jnp.int32)
    out = np.asarray(expand_neighbors(gidx, ids, 4)).reshape(2, 2, 4)
    assert (out[0, 1] == gidx.n_docs).all()
    assert (out[1] == gidx.n_docs).all()
    assert (out[0, 0] == np.asarray(gidx.knn_ids)[0, :4]).all()


def test_validation_errors():
    idx, gidx, queries, _ = _built()
    with pytest.raises(ValueError, match="no kNN graph"):
        validate_refine_params(
            idx, SearchParams(graph_degree=4, refine_rounds=1))
    with pytest.raises(ValueError, match="exceeds the built"):
        validate_refine_params(
            gidx, SearchParams(graph_degree=DEGREE + 1, refine_rounds=1))
    # the same errors surface through the pipeline at trace time
    with pytest.raises(ValueError, match="no kNN graph"):
        search_pipeline(idx, queries,
                        SearchParams(k=10, cut=8, graph_degree=4,
                                     refine_rounds=1))
    with pytest.raises(ValueError, match="cannot yield"):
        build_doc_graph(idx, degree=DEGREE,
                        build_params=SearchParams(k=DEGREE))
    with pytest.raises(ValueError, match="positive"):
        build_doc_graph(idx, degree=0)


# --------------------------------------------------------------- ckpt

def test_index_ckpt_roundtrip_with_graph(tmp_path):
    from repro.ckpt import load_index, save_index
    _, gidx, queries, _ = _built()
    save_index(str(tmp_path), gidx)
    gidx2 = load_index(str(tmp_path))
    np.testing.assert_array_equal(np.asarray(gidx.knn_ids),
                                  np.asarray(gidx2.knn_ids))
    p = SearchParams(k=10, cut=8, block_budget=4, graph_degree=DEGREE,
                     refine_rounds=2)
    _assert_same_results(search_pipeline(gidx, queries, p),
                         search_pipeline(gidx2, queries, p))


def test_index_ckpt_pre_graph_backcompat(tmp_path):
    """A checkpoint written WITHOUT the graph (the old layout) must
    load with knn_ids=None and refuse refinement knobs cleanly."""
    from repro.ckpt import load_index, save_index
    idx, _, queries, _ = _built()
    save_index(str(tmp_path), idx)
    idx2 = load_index(str(tmp_path))
    assert idx2.knn_ids is None and idx2.graph_degree == 0
    p = SearchParams(k=10, cut=8, block_budget=8)
    _assert_same_results(search_pipeline(idx, queries, p),
                         search_pipeline(idx2, queries, p))
    with pytest.raises(ValueError, match="no kNN graph"):
        search_pipeline(idx2, queries,
                        dataclasses.replace(p, graph_degree=4,
                                            refine_rounds=1))


def test_nbytes_accounts_graph():
    idx, gidx, *_ = _built()
    nb, gnb = idx.nbytes(), gidx.nbytes()
    assert nb["graph"] == 0
    assert gnb["graph"] == gidx.knn_ids.nbytes > 0
    assert gnb["total"] == nb["total"] + gnb["graph"]


# ----------------------------------------------------- adaptive fanout

def test_suggest_fanout_single_block_lists():
    """Collections dominated by single-block lists must get fanout 0 —
    the coarse tier would be pure overhead."""
    assert suggest_fanout(np.ones(256)) == 0
    assert suggest_fanout(np.zeros(256)) == 0
    assert suggest_fanout([]) == 0
    assert suggest_fanout([2, 1, 2, 1]) == 0


def test_suggest_fanout_scales_like_sqrt():
    assert suggest_fanout(np.full(64, 9)) == 3
    assert suggest_fanout(np.full(64, 25)) == 5
    assert suggest_fanout(np.full(64, 100)) == 8     # capped
    assert suggest_fanout(np.full(64, 100), max_fanout=16) == 10


def test_suggest_fanout_on_built_index_routes():
    """The suggested fanout from real live-block stats must build a
    working hierarchical index (routing parity at generous budget)."""
    docs, queries = _collection()
    icfg = SeismicConfig(lam=96, beta=8, alpha=0.4, block_cap=24,
                         summary_nnz=24)
    idx = build_index(docs, icfg, list_chunk=16)
    f = suggest_fanout(live_blocks(idx))
    assert f >= 2       # multi-block lists at this config
    hidx = build_index(docs, dataclasses.replace(icfg,
                                                 superblock_fanout=f),
                       list_chunk=16)
    pf = SearchParams(k=10, cut=8, block_budget=8)
    ph = dataclasses.replace(pf, superblock_fanout=f,
                             superblock_budget=8 * hidx.config.n_superblocks)
    _assert_same_results(search_pipeline(idx, queries, pf),
                         search_pipeline(hidx, queries, ph))


def test_config_hier_variants():
    from repro.configs.seismic_msmarco import (CONFIG, CONFIG_HIER,
                                               REDUCED, REDUCED_HIER,
                                               with_suggested_fanout)
    assert CONFIG_HIER.index.superblock_fanout > 0
    assert REDUCED_HIER.index.superblock_fanout > 0
    assert CONFIG.index.superblock_fanout == 0      # base stays flat
    # single-block stats: unchanged config comes back
    same = with_suggested_fanout(REDUCED, np.ones(REDUCED.dim))
    assert same is REDUCED
