"""Async serving subsystem: deadline micro-batching, admission control,
result cache, telemetry, and parity with the raw jitted pipeline."""
import struct
import threading
import time

import numpy as np
import pytest
import jax.numpy as jnp

from repro.retrieval import SearchParams, search_pipeline
from repro.serve import (AsyncSeismicServer, LRUCache, RequestQueue,
                         ServerTelemetry, query_fingerprint)
from repro.serve.queue import Request, ServeFuture
from repro.serve.telemetry import Histogram


def _params(**kw):
    kw.setdefault("k", 5)
    kw.setdefault("cut", 8)
    kw.setdefault("block_budget", 8)
    return SearchParams(**kw)


def _server(small_index, **kw):
    idx, _ = small_index
    kw.setdefault("max_batch", 8)
    kw.setdefault("query_nnz", 16)
    kw.setdefault("deadline_s", 0.05)
    return AsyncSeismicServer(idx, _params(), **kw)


# ---------------------------------------------------------- dispatch

def test_deadline_expiry_partial_launch(small_index, small_collection):
    """Fewer than max_batch queries must still launch (padded) once the
    dispatch deadline expires — the acceptance-criterion behavior."""
    _, queries, *_ = small_collection
    srv = _server(small_index, deadline_s=0.08)
    with srv:
        t0 = time.monotonic()
        futs = [srv.submit(np.asarray(queries.coords[i]),
                           np.asarray(queries.vals[i]))
                for i in range(3)]                    # 3 < max_batch=8
        for f in futs:
            assert f.wait(5.0)
        waited = time.monotonic() - t0
    res = [f.result() for f in futs]
    # one partial (padded) launch served all three requests
    assert all(r.occupancy == 3 for r in res)
    assert waited >= 0.08          # not dispatched before the deadline
    assert waited < 4.0
    tel = srv.telemetry_export()
    assert tel["batch"]["occupancy_counts"] == {"3": 1}


def test_batch_full_dispatch_beats_deadline(small_index, small_collection):
    """A full batch launches immediately, long before a lazy deadline."""
    _, queries, *_ = small_collection
    srv = _server(small_index, deadline_s=30.0)       # effectively never
    with srv:
        t0 = time.monotonic()
        futs = [srv.submit(np.asarray(queries.coords[i % queries.n]),
                           np.asarray(queries.vals[i % queries.n]))
                for i in range(8)]                    # == max_batch
        for f in futs:
            assert f.wait(10.0)
        waited = time.monotonic() - t0
    assert waited < 10.0                              # not the deadline
    assert all(f.result().occupancy == 8 for f in futs)


def test_async_matches_unbatched_pipeline(small_index, small_collection):
    """Micro-batched results == one direct pipeline call per shape."""
    idx, _ = small_index
    _, queries, *_ = small_collection
    p = _params()
    want_s, want_ids, want_ev = search_pipeline(idx, queries, p)
    srv = _server(small_index)
    with srv:
        res = srv.search(queries)
    np.testing.assert_array_equal(res.ids, np.asarray(want_ids))
    np.testing.assert_allclose(res.scores, np.asarray(want_s),
                               rtol=1e-6)
    np.testing.assert_array_equal(res.docs_evaluated,
                                  np.asarray(want_ev))


def test_queries_wider_than_nnz_budget(small_index, small_collection):
    """Overlong queries keep their heaviest coordinates and still serve."""
    _, queries, *_ = small_collection
    c = np.concatenate([np.asarray(queries.coords[0])] * 3)
    v = np.concatenate([np.asarray(queries.vals[0]),
                        np.zeros((2 * queries.nnz_max,), np.float32)])
    srv = _server(small_index)
    with srv:
        fut = srv.submit(c, v, deadline_s=0.01)
        res = fut.result(5.0)
    assert res.ids.shape == (5,)
    assert (res.ids >= -1).all()


# ---------------------------------------------- launch-width ladder

def test_launch_width_ladder_defaults(small_index):
    """Default rungs are (8, 32, 128) clipped to max_batch, which is
    always the top rung."""
    idx, _ = small_index
    mk = lambda mb: AsyncSeismicServer(idx, _params(), max_batch=mb)
    assert mk(32).launch_widths == (8, 32)
    assert mk(8).launch_widths == (8,)
    assert mk(200).launch_widths == (8, 32, 128, 200)
    assert mk(3).launch_widths == (3,)


def test_launch_width_explicit_and_validation(small_index):
    idx, _ = small_index
    srv = AsyncSeismicServer(idx, _params(), max_batch=16,
                             launch_widths=(4, 2, 4))
    assert srv.launch_widths == (2, 4, 16)     # sorted, deduped, top rung
    with pytest.raises(ValueError, match="launch_widths"):
        AsyncSeismicServer(idx, _params(), max_batch=16,
                           launch_widths=(0, 4))
    with pytest.raises(ValueError, match="launch_widths"):
        AsyncSeismicServer(idx, _params(), max_batch=16,
                           launch_widths=(4, 32))


def test_pick_width_smallest_cover(small_index):
    idx, _ = small_index
    srv = AsyncSeismicServer(idx, _params(), max_batch=16,
                             launch_widths=(2, 4))
    assert [srv._pick_width(n) for n in (1, 2, 3, 4, 5, 16)] \
        == [2, 2, 4, 4, 16, 16]


def test_launch_width_dispatch_and_telemetry(small_index,
                                             small_collection):
    """A 3-request batch dispatches at the smallest covering rung (4),
    not max_batch, and the per-width telemetry counter records it —
    results still match the raw pipeline."""
    idx, _ = small_index
    _, queries, *_ = small_collection
    srv = _server(small_index, max_batch=8, launch_widths=(2, 4),
                  deadline_s=0.05)
    with srv:
        futs = [srv.submit(np.asarray(queries.coords[i]),
                           np.asarray(queries.vals[i]))
                for i in range(3)]
        res = [f.result(10.0) for f in futs]
    assert all(r.occupancy == 3 for r in res)
    counters = srv.telemetry_export()["counters"]
    assert counters["launch_width_4"] == 1
    assert "launch_width_8" not in counters
    p = _params()
    want_s, want_ids, _ = search_pipeline(idx, queries, p)
    for i, r in enumerate(res):
        np.testing.assert_array_equal(r.ids, np.asarray(want_ids)[i])
        np.testing.assert_allclose(r.scores, np.asarray(want_s)[i],
                                   rtol=1e-6)


# -------------------------------------------------- admission control

def test_admission_reject_new(small_index, small_collection):
    _, queries, *_ = small_collection
    srv = _server(small_index, queue_bound=2, admission="reject",
                  max_batch=4, deadline_s=0.2)
    # don't start the worker: the queue must actually fill (distinct
    # queries — identical ones would coalesce instead of queueing)
    futs = [srv.submit(np.asarray(queries.coords[i]),
                       np.asarray(queries.vals[i])) for i in range(4)]
    statuses = [f.status for f in futs]
    assert statuses.count("rejected") == 2
    assert srv.telemetry_export()["counters"]["rejected"] == 2
    srv.queue.close()


def test_admission_shed_oldest(small_index, small_collection):
    _, queries, *_ = small_collection
    srv = _server(small_index, queue_bound=2, admission="shed_oldest",
                  max_batch=4, deadline_s=0.2)
    futs = [srv.submit(np.asarray(queries.coords[i]),
                       np.asarray(queries.vals[i])) for i in range(4)]
    assert futs[0].status == "shed"
    assert futs[1].status == "shed"
    assert futs[2].status == "pending"
    assert futs[3].status == "pending"
    with pytest.raises(RuntimeError, match="shed"):
        futs[0].result(0.0)
    assert srv.telemetry_export()["counters"]["shed"] == 2
    srv.queue.close()


def test_restart_after_stop_raises(small_index):
    """stop() closes the queue for good; a silent dead restart (every
    submit failing 'closed') must be a loud error instead."""
    srv = _server(small_index)
    with srv:
        pass
    with pytest.raises(RuntimeError, match="stopped"):
        srv.start()


def test_stop_drains_pending_requests(small_index, small_collection):
    """close() must serve what was admitted, not strand futures."""
    _, queries, *_ = small_collection
    srv = _server(small_index, deadline_s=60.0)       # deadline never fires
    with srv:
        futs = [srv.submit(np.asarray(queries.coords[i]),
                           np.asarray(queries.vals[i]))
                for i in range(3)]
    # exiting the context closes + drains the queue
    assert all(f.status == "done" for f in futs)


# --------------------------------------------------------------- cache

def test_result_cache_hit(small_index, small_collection):
    _, queries, *_ = small_collection
    srv = _server(small_index, cache_size=32, deadline_s=0.01)
    c = np.asarray(queries.coords[0])
    v = np.asarray(queries.vals[0])
    with srv:
        first = srv.submit(c, v).result(5.0)
        second = srv.submit(c, v).result(5.0)
    assert not first.cached
    assert second.cached
    np.testing.assert_array_equal(first.ids, second.ids)
    tel = srv.telemetry_export()
    assert tel["cache"]["hits"] == 1
    assert tel["cache"]["hit_rate"] == pytest.approx(0.5)
    # the cached row owns its storage: it must not alias the served
    # result (mutation poisoning) nor pin the [max_batch, k] launch
    # arrays via a view
    # cache keys carry the serving-epoch prefix (stale-result fix)
    key = struct.pack("<Q", srv.epoch) \
        + query_fingerprint(*srv._normalize(c, v))
    cached_ids, cached_scores, _ = srv.cache.get(key)
    np.testing.assert_array_equal(cached_ids, first.ids)
    assert not np.shares_memory(cached_ids, first.ids)
    assert cached_ids.base is None and cached_scores.base is None


def test_fingerprint_quantized_and_order_invariant():
    c = np.array([5, 9, 2], np.int64)
    v = np.array([1.0, 0.5, 0.25], np.float32)
    base = query_fingerprint(c, v)
    perm = np.array([2, 0, 1])
    assert query_fingerprint(c[perm], v[perm]) == base
    assert query_fingerprint(c, v * (1 + 1e-4)) == base   # sub-grid jitter
    assert query_fingerprint(c, v[::-1].copy()) != base   # different weights
    assert query_fingerprint(c, v * 4.0) != base          # scale bucket moved
    # padding (val 0) entries don't contribute
    assert query_fingerprint(np.append(c, 0), np.append(v, 0.0)) == base
    assert query_fingerprint(np.array([]), np.array([])) == b"empty"


def test_inflight_coalescing_shares_launch_slot(small_index,
                                                small_collection):
    """Identical-fingerprint requests queued CONCURRENTLY must occupy
    one launch slot: submit duplicates before the worker starts, then
    let one batch serve them all (the LRU cache can't catch these —
    no result exists yet when the duplicates arrive)."""
    _, queries, *_ = small_collection
    srv = _server(small_index, deadline_s=0.01)
    c0 = np.asarray(queries.coords[0])
    v0 = np.asarray(queries.vals[0])
    c1 = np.asarray(queries.coords[1])
    v1 = np.asarray(queries.vals[1])
    futs = [srv.submit(c0, v0), srv.submit(c0, v0), srv.submit(c1, v1),
            srv.submit(c0, v0)]
    # three duplicates of q0 share the first request's slot
    assert srv.queue.depth == 2
    with srv:                              # worker drains the backlog
        res = [f.result(10.0) for f in futs]
    assert not res[0].coalesced and not res[2].coalesced
    assert res[1].coalesced and res[3].coalesced
    np.testing.assert_array_equal(res[0].ids, res[1].ids)
    np.testing.assert_array_equal(res[0].ids, res[3].ids)
    np.testing.assert_array_equal(res[0].scores, res[1].scores)
    # followers own their storage (no aliasing with the primary's view)
    assert not np.shares_memory(res[0].ids, res[1].ids)
    tel = srv.telemetry_export()
    assert tel["counters"]["coalesced"] == 2
    assert tel["counters"]["served"] == 4  # all four requests fulfilled
    assert tel["batch"]["occupancy_counts"] == {"2": 1}


def test_inflight_coalescing_retires_after_fulfilment(small_index,
                                                      small_collection):
    """Once a request's slot fulfils, its fingerprint leaves the
    in-flight map: a later duplicate becomes a fresh primary (or a
    cache hit when the LRU is on), never a follower of a dead slot."""
    _, queries, *_ = small_collection
    srv = _server(small_index, deadline_s=0.005)
    c = np.asarray(queries.coords[0])
    v = np.asarray(queries.vals[0])
    with srv:
        first = srv.submit(c, v).result(10.0)
        assert srv._inflight == {}         # retired with the launch
        second = srv.submit(c, v).result(10.0)
    assert not first.coalesced and not second.coalesced
    np.testing.assert_array_equal(first.ids, second.ids)
    assert srv.telemetry_export()["counters"].get("coalesced", 0) == 0


def test_inflight_coalescing_disabled(small_index, small_collection):
    _, queries, *_ = small_collection
    srv = _server(small_index, coalesce=False, deadline_s=0.01)
    c = np.asarray(queries.coords[0])
    v = np.asarray(queries.vals[0])
    f0, f1 = srv.submit(c, v), srv.submit(c, v)
    assert srv.queue.depth == 2            # both occupy real slots
    with srv:
        r0, r1 = f0.result(10.0), f1.result(10.0)
    assert not r0.coalesced and not r1.coalesced
    np.testing.assert_array_equal(r0.ids, r1.ids)


def test_shed_fails_followers(small_index, small_collection):
    """Shedding a primary fails its coalesced followers too — no
    orphaned futures hanging forever."""
    _, queries, *_ = small_collection
    srv = _server(small_index, queue_bound=1, admission="shed_oldest",
                  deadline_s=30.0)
    c0 = np.asarray(queries.coords[0])
    v0 = np.asarray(queries.vals[0])
    c1 = np.asarray(queries.coords[1])
    v1 = np.asarray(queries.vals[1])
    f_primary = srv.submit(c0, v0)
    f_follower = srv.submit(c0, v0)        # coalesces onto f_primary
    # bound=1: sheds f_primary (short deadline so the drain below
    # doesn't wait out the server-default 30s)
    f_new = srv.submit(c1, v1, deadline_s=0.01)
    assert f_primary.status == "shed"
    assert f_follower.status == "shed"
    assert f_new.status == "pending"
    with srv:                              # drain the survivor
        assert f_new.result(10.0).ids.shape == (5,)


def test_lru_cache_eviction():
    cache = LRUCache(2)
    cache.put(b"a", 1)
    cache.put(b"b", 2)
    assert cache.get(b"a") == 1          # refresh a
    cache.put(b"c", 3)                   # evicts b
    assert cache.get(b"b") is None
    assert cache.get(b"a") == 1 and cache.get(b"c") == 3
    stats = cache.stats()
    assert stats["size"] == 2
    assert stats["hits"] == 3 and stats["misses"] == 1


# ----------------------------------------------------- queue mechanics

def _req(deadline, now):
    return Request(coords=np.zeros(4, np.int32),
                   vals=np.zeros(4, np.float32), submit_t=now,
                   deadline=deadline, future=ServeFuture())


def test_queue_next_batch_on_deadline():
    q = RequestQueue(bound=8)
    now = time.monotonic()
    q.put(_req(now + 0.05, now))
    t0 = time.perf_counter()
    batch = q.next_batch(4)
    assert len(batch) == 1
    assert time.perf_counter() - t0 >= 0.045


def test_queue_next_batch_on_full():
    q = RequestQueue(bound=8)
    now = time.monotonic()
    for _ in range(4):
        q.put(_req(now + 60.0, now))
    t0 = time.perf_counter()
    batch = q.next_batch(4)                # full -> no deadline wait
    assert len(batch) == 4
    assert time.perf_counter() - t0 < 1.0


def test_queue_close_unblocks_and_drains():
    q = RequestQueue(bound=8)
    now = time.monotonic()
    q.put(_req(now + 60.0, now))
    got = []
    th = threading.Thread(
        target=lambda: got.extend([q.next_batch(4), q.next_batch(4)]))
    th.start()
    time.sleep(0.02)
    q.close()
    th.join(2.0)
    assert not th.is_alive()
    assert len(got[0]) == 1 and got[1] is None
    status, _ = q.put(_req(now, now))      # closed queue admits nothing
    assert status == "closed"


# ------------------------------------------------- telemetry / staging

def test_histogram_percentiles():
    h = Histogram()
    for ms in range(1, 101):               # 1ms .. 100ms uniform
        h.record(ms * 1e-3)
    s = h.summary()
    assert s["count"] == 100
    assert s["p50"] == pytest.approx(0.050, rel=0.25)
    assert s["p99"] == pytest.approx(0.100, rel=0.25)
    assert s["min"] == pytest.approx(1e-3)
    assert s["max"] == pytest.approx(0.1)


def test_telemetry_export_plain_dict():
    import json
    tel = ServerTelemetry()
    tel.record_latency("launch", 0.01)
    tel.inc("batches")
    tel.observe_occupancy(3)
    tel.observe_queue_depth(5)
    out = tel.export()
    json.dumps(out)                        # plain/serializable
    assert out["counters"]["batches"] == 1
    assert out["batch"]["mean_occupancy"] == 3.0
    assert out["queue"]["depth_max"] == 5
    assert out["latency_s"]["launch"]["count"] == 1


def test_stage_timing_records_all_stages(small_index, small_collection):
    from repro.retrieval import STAGES
    _, queries, *_ = small_collection
    srv = _server(small_index, stage_timing=True, deadline_s=0.01)
    with srv:
        srv.submit(np.asarray(queries.coords[0]),
                   np.asarray(queries.vals[0])).result(10.0)
    lat = srv.telemetry_export()["latency_s"]
    for stage in STAGES:
        assert lat[f"stage_{stage}"]["count"] >= 1
