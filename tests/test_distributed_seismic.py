"""Distributed (doc-sharded) Seismic vs single-shard reference.

Runs in a subprocess with 8 forced host devices (the main test process
must keep the real single-device view).
"""
from helpers import run_with_devices

CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.data import SyntheticSparseConfig, make_collection
from repro.core import SeismicConfig, SearchParams
from repro.core.distributed import build_sharded_index, make_distributed_search
from repro.core.baselines import exact_search
from repro.core.oracle import recall_at_k
from repro.sparse.ops import PaddedSparse

assert len(jax.devices()) == 8
cfg = SyntheticSparseConfig(dim=512, n_docs=1024, n_queries=16, doc_nnz=32,
                            query_nnz=12, n_topics=16, topic_coords=96, seed=3)
docs_np, queries_np, _ = make_collection(cfg)
docs = PaddedSparse(jnp.asarray(docs_np.coords), jnp.asarray(docs_np.vals), docs_np.dim)
queries = PaddedSparse(jnp.asarray(queries_np.coords), jnp.asarray(queries_np.vals), queries_np.dim)

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
scfg = SeismicConfig(lam=96, beta=8, alpha=0.4, block_cap=24, summary_nnz=24)
stacked = build_sharded_index(docs, scfg, n_shards=4)
p = SearchParams(k=10, cut=8, block_budget=32, policy="adaptive")
search = make_distributed_search(mesh, p, doc_axes=("model",), data_axis="data")
with jax.set_mesh(mesh):
    s, ids = jax.jit(search)(stacked, queries.coords, queries.vals)
es, eids = exact_search(docs, queries, 10)
recalls = [recall_at_k(np.asarray(ids[q]), np.asarray(eids[q])) for q in range(16)]
assert np.mean(recalls) >= 0.9, np.mean(recalls)

# global ids must be valid and scores exact IPs
q_dense = np.zeros((16, docs.dim))
rows = np.arange(16)[:, None]
np.add.at(q_dense, (rows, queries_np.coords), queries_np.vals)
for q in range(16):
    for j in range(10):
        doc = int(ids[q, j])
        if doc < 0:
            continue
        assert 0 <= doc < docs.n
        ip = (q_dense[q][docs_np.coords[doc]] * docs_np.vals[doc]).sum()
        assert abs(float(s[q, j]) - ip) < 1e-3 * max(1.0, abs(ip)), (q, j)
print("OK distributed")
"""


def test_distributed_search_8dev():
    out = run_with_devices(CODE, n_devices=8)
    assert "OK distributed" in out
