"""Correctness of the §Perf optimization variants: compact forward
index, fixed blocking, centroid summaries, FSDP sharding, node-sharded
GIN aggregation."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import SeismicConfig, SearchParams, build_index, search_batch
from repro.core.baselines import exact_search
from repro.core.oracle import recall_at_k
from repro.sparse.ops import PaddedSparse
from helpers import run_with_devices


@pytest.fixture(scope="module")
def coll():
    from repro.data import SyntheticSparseConfig, make_collection
    cfg = SyntheticSparseConfig(dim=1024, n_docs=2048, n_queries=24,
                                doc_nnz=48, query_nnz=16, n_topics=32,
                                topic_coords=128, seed=5)
    docs_np, queries_np, _ = make_collection(cfg)
    docs = PaddedSparse(jnp.asarray(docs_np.coords),
                        jnp.asarray(docs_np.vals), docs_np.dim)
    queries = PaddedSparse(jnp.asarray(queries_np.coords),
                           jnp.asarray(queries_np.vals), queries_np.dim)
    _, eids = exact_search(docs, queries, 10)
    return docs, queries, np.asarray(eids)


BASE = SeismicConfig(lam=128, beta=8, alpha=0.4, block_cap=32,
                     summary_nnz=32)


def _recall(idx, queries, eids, policy="adaptive", budget=32):
    p = SearchParams(k=10, cut=8, block_budget=budget, policy=policy)
    _, ids, ev = search_batch(idx, queries, p)
    return np.mean([recall_at_k(np.asarray(ids[q]), eids[q])
                    for q in range(queries.n)]), ev


def test_fwd_quant_recall_and_size(coll):
    """Compact (u16/u8) forward index: same recall, smaller, u16 coords."""
    docs, queries, eids = coll
    idx_f = build_index(docs, BASE, list_chunk=16)
    idx_q = build_index(docs, dataclasses.replace(BASE, fwd_quant=True),
                        list_chunk=16)
    rf, _ = _recall(idx_f, queries, eids)
    rq, _ = _recall(idx_q, queries, eids)
    assert abs(rf - rq) < 0.02
    assert idx_q.fwd.coords.dtype == jnp.uint16
    assert idx_q.fwd.vals.dtype == jnp.uint8
    assert idx_q.fwd_scale is not None
    bytes_f = idx_f.fwd.coords.nbytes + idx_f.fwd.vals.nbytes
    bytes_q = (idx_q.fwd.coords.nbytes + idx_q.fwd.vals.nbytes
               + idx_q.fwd_scale.nbytes + idx_q.fwd_zero.nbytes)
    assert bytes_q < 0.5 * bytes_f


def test_fwd_quant_scores_close(coll):
    """Quantized forward scores within ~1% of float scores."""
    docs, queries, eids = coll
    idx_f = build_index(docs, BASE, list_chunk=16)
    idx_q = build_index(docs, dataclasses.replace(BASE, fwd_quant=True),
                        list_chunk=16)
    p = SearchParams(k=10, cut=8, block_budget=32, policy="budget")
    sf, idf, _ = search_batch(idx_f, queries, p)
    sq, idq, _ = search_batch(idx_q, queries, p)
    # compare scores of shared results
    for q in range(queries.n):
        f = {int(i): float(s) for i, s in zip(idf[q], sf[q]) if i >= 0}
        qd = {int(i): float(s) for i, s in zip(idq[q], sq[q]) if i >= 0}
        common = set(f) & set(qd)
        assert len(common) >= 5
        for doc in common:
            assert abs(f[doc] - qd[doc]) / max(abs(f[doc]), 1e-6) < 0.02


def test_fixed_blocking_builds_and_searches(coll):
    docs, queries, eids = coll
    idx = build_index(docs, dataclasses.replace(BASE, blocking="fixed"),
                      list_chunk=16)
    r, _ = _recall(idx, queries, eids, policy="budget", budget=48)
    assert r > 0.8  # works, geometrically weaker (see fig5 bench)
    # fixed blocks are impact-ordered contiguous chunks of size <= cap
    ln = np.asarray(idx.block_len)
    assert (ln <= BASE.block_cap).all()


def test_centroid_summaries_build_and_search(coll):
    docs, queries, eids = coll
    idx = build_index(docs, dataclasses.replace(BASE,
                                                summary_kind="centroid"),
                      list_chunk=16)
    r, _ = _recall(idx, queries, eids, budget=48)
    assert r > 0.8


FSDP_CODE = r"""
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.api import get_bundle
from repro.distributed.param_sharding import lm_param_specs
from repro.models.transformer import lm

bundle = get_bundle("llama3-8b")
# reduced cfg with dims divisible by the 2x4 mesh world (8)
cfg = dataclasses.replace(bundle.reduced, sharding_mode="fsdp",
                          d_model=64, d_ff=128, vocab=256)
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
with jax.set_mesh(mesh):
    params = bundle.init(jax.random.PRNGKey(0), cfg, {})
    specs = lm_param_specs(params, mode="fsdp")
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                       is_leaf=lambda x: isinstance(x, P))
    params_sh = jax.tree.map(jax.device_put, params, psh)
    toks = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab,
                                                         (8, 16)), jnp.int32)
    logits_sh, _ = jax.jit(lambda p, t: lm.forward(p, t, cfg))(params_sh, toks)

# reference: unsharded tp-mode forward with identical params
cfg_ref = dataclasses.replace(cfg, sharding_mode="tp")
logits_ref, _ = lm.forward(params, toks, cfg_ref)
np.testing.assert_allclose(np.asarray(logits_sh, np.float32),
                           np.asarray(logits_ref, np.float32),
                           rtol=2e-2, atol=2e-2)
print("OK fsdp")
"""


def test_fsdp_forward_matches_unsharded():
    out = run_with_devices(FSDP_CODE, n_devices=8)
    assert "OK fsdp" in out


GIN_SHARD_CODE = r"""
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.configs.base import GNNConfig
from repro.models.gnn import gin

rng = np.random.default_rng(0)
n, e, f = 512, 2048, 8
feats = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
edges = jnp.asarray(rng.integers(0, n, (e, 2)), jnp.int32)
cfg_ps = GNNConfig(name="t", n_layers=3, d_hidden=16, n_classes=4)
cfg_sh = dataclasses.replace(cfg_ps, aggregate_mode="shard")
params = gin.init_params(jax.random.PRNGKey(0), cfg_ps, f, 4)

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
with jax.set_mesh(mesh):
    h_ps = jax.jit(lambda p: gin.forward(p, feats, edges, cfg_ps))(params)
    h_sh = jax.jit(lambda p: gin.forward(p, feats, edges, cfg_sh))(params)
np.testing.assert_allclose(np.asarray(h_ps), np.asarray(h_sh),
                           rtol=1e-4, atol=1e-4)
print("OK gin shard")
"""


def test_gin_sharded_aggregation_matches_psum():
    out = run_with_devices(GIN_SHARD_CODE, n_devices=8)
    assert "OK gin shard" in out
