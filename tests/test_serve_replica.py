"""Replica-parallel serving: StageTimingBalancer policy, mirror-mode
bit-exactness vs the single AsyncSeismicServer, shard-mode merge parity
with the reference per-shard merge, pad-doc invariants at k > live
hits, balancer monotonicity under injected slowness, clean shutdown
with in-flight work, and the per-replica telemetry/span surface."""
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import SeismicConfig
from repro.core.distributed import build_sharded_index, mask_shard_topk
from repro.obs import Observability, validate_trace
from repro.retrieval import SearchParams, search_pipeline
from repro.retrieval.merge import merge_topk
from repro.serve import (AsyncSeismicServer, ReplicaSeismicServer,
                         StageTimingBalancer)
from repro.sparse.ops import PaddedSparse


def _params(**kw):
    kw.setdefault("k", 5)
    kw.setdefault("cut", 8)
    kw.setdefault("block_budget", 8)
    return SearchParams(**kw)


def _queries(small_collection, n):
    _, queries, *_ = small_collection
    qc, qv = np.asarray(queries.coords), np.asarray(queries.vals)
    return [(qc[i % queries.n], qv[i % queries.n]) for i in range(n)]


def _serve_all(server, qs):
    with server:
        futs = [server.submit(c, v) for c, v in qs]
        return [f.result(30.0) for f in futs]


# ----------------------------------------------------------- balancer

def test_balancer_proportional_dispatch_never_starves():
    """Dispatch share tracks 1/cost — the 3x-slower replica gets ~3x
    fewer batches — and the slow replica keeps being picked."""
    bal = StageTimingBalancer(3)
    cost = [0.009, 0.003, 0.003]
    counts = [0, 0, 0]
    for _ in range(300):
        rid = bal.pick()
        counts[rid] += 1
        bal.record(rid, cost[rid])
    assert counts[0] >= 10                      # never starved
    assert counts[0] < counts[1] and counts[0] < counts[2]
    # share ratio ~ cost ratio (3x), loosely bounded
    assert 2.0 <= counts[1] / counts[0] <= 4.5
    snap = bal.snapshot()
    assert sum(snap["dispatches"]) == 300
    assert abs(sum(snap["dispatch_share"]) - 1.0) < 1e-9
    assert snap["cost_ewma_s"][0] == pytest.approx(0.009, rel=0.2)


def test_balancer_single_replica_and_stage_rollup():
    bal = StageTimingBalancer(1)
    assert [bal.pick() for _ in range(5)] == [0] * 5
    assert bal.snapshot()["inflight"] == [5]
    bal.record(0, 0.01, {"router": 0.004, "scorer": 0.006})
    bal.record(0, 0.02, {"router": 0.008, "scorer": 0.012})
    sc = bal.snapshot()["stage_cost_ewma_s"][0]
    assert 0.004 < sc["router"] < 0.008
    assert bal.snapshot()["inflight"] == [3]   # 5 picked, 2 acked


def test_balancer_validation():
    with pytest.raises(ValueError):
        StageTimingBalancer(0)
    with pytest.raises(ValueError):
        StageTimingBalancer(2, alpha=0.0)
    with pytest.raises(ValueError):
        StageTimingBalancer(2, alpha=1.5)


# -------------------------------------------------------- mirror mode

@pytest.mark.parametrize("n_replicas", [1, 2, 3])
def test_mirror_bit_exact_vs_async_server(small_index, small_collection,
                                          n_replicas):
    """The acceptance criterion: same index, same params => the replica
    server returns bit-identical (scores, ids, docs_evaluated) to the
    single AsyncSeismicServer at every replica count."""
    idx, _ = small_index
    qs = _queries(small_collection, 12)
    kw = dict(max_batch=4, query_nnz=16, deadline_s=0.01,
              cache_size=0, coalesce=False)
    ref = _serve_all(AsyncSeismicServer(idx, _params(), **kw), qs)
    got = _serve_all(
        ReplicaSeismicServer(idx, _params(), n_replicas=n_replicas, **kw),
        qs)
    for a, b in zip(ref, got):
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.scores, b.scores)
        assert a.docs_evaluated == b.docs_evaluated


def test_mirror_slow_replica_gets_fewer_dispatches(small_index,
                                                   small_collection):
    """Balancer monotonicity end-to-end: with replica 0 artificially
    30ms slow, the healthy replica absorbs most micro-batches."""
    idx, _ = small_index
    srv = ReplicaSeismicServer(idx, _params(), n_replicas=2,
                               replica_delay_s=(0.03, 0.0),
                               max_batch=2, query_nnz=16,
                               deadline_s=0.002, cache_size=0,
                               coalesce=False)
    # paced arrivals: the balancer only learns from completed launches,
    # so give the 30ms replica time to report before the last dispatch
    with srv:
        futs = []
        for c, v in _queries(small_collection, 24):
            futs.append(srv.submit(c, v))
            time.sleep(0.008)
        res = [f.result(30.0) for f in futs]
    assert all(r.ids.shape == (5,) for r in res)
    snap = srv.balancer.snapshot()
    assert sum(snap["dispatches"]) >= 2
    assert snap["dispatches"][1] > snap["dispatches"][0]
    # the slow replica's measured cost dominates the healthy one's
    assert snap["cost_ewma_s"][0] > snap["cost_ewma_s"][1]


def test_mirror_clean_stop_with_inflight_work(small_index,
                                              small_collection):
    """stop() with queued + in-flight work on every replica drains
    everything: no future is left pending, all threads join."""
    idx, _ = small_index
    srv = ReplicaSeismicServer(idx, _params(), n_replicas=3,
                               replica_delay_s=0.01, max_batch=2,
                               query_nnz=16, deadline_s=30.0,
                               cache_size=0, coalesce=False)
    srv.start()
    futs = [srv.submit(c, v) for c, v in _queries(small_collection, 24)]
    srv.stop()                       # close + drain, no waiting first
    for f in futs:
        assert f.done()
        assert f.status == "done"
        assert f.result().ids.shape == (5,)
    assert srv._thread is None
    assert srv._replica_threads == []
    # every replica did real work while shutting down
    snap = srv.balancer.snapshot()
    assert sum(snap["dispatches"]) == 12    # 24 requests / max_batch 2
    assert all(d >= 1 for d in snap["dispatches"])


def test_mirror_replica_gauges_and_span_labels(small_index,
                                               small_collection):
    """Per-replica rollups land in the shared registry and every launch
    span carries the replica label; traces stay valid."""
    idx, _ = small_index
    obs = Observability.create(stage_sample_every=1)
    srv = ReplicaSeismicServer(idx, _params(), n_replicas=2,
                               obs=obs, max_batch=4, query_nnz=16,
                               deadline_s=0.01, cache_size=0,
                               coalesce=False)
    _serve_all(srv, _queries(small_collection, 8))
    reg = obs.registry
    disp = reg.get("seismic_replica_dispatches_total")
    total = sum(c.value for _, c in disp.samples())
    assert total >= 1
    snap = srv.balancer.snapshot()
    assert total == sum(snap["dispatches"])
    cost = reg.get("seismic_replica_cost_ewma_seconds")
    assert {lv[0] for lv, _ in cost.samples()} == {"0", "1"}
    picked = [rid for rid in (0, 1) if snap["dispatches"][rid]]
    assert all(cost.labels(str(rid)).value > 0 for rid in picked)
    # staged launches fed the per-stage rollup gauge
    stage = reg.get("seismic_replica_stage_seconds")
    assert any(lv[1] == "scorer" for lv, _ in stage.samples())
    seen = set()
    for tr in obs.tracer.finished():
        validate_trace(tr)
        for s in tr.spans:
            if s.name == "launch":
                assert "replica" in s.attrs
                seen.add(s.attrs["replica"])
    assert seen <= {0, 1} and seen
    share = reg.get("seismic_replica_dispatch_share")
    assert sum(g.value for _, g in share.samples()) == pytest.approx(1.0)


# --------------------------------------------------------- shard mode

N_SHARDS = 4


@pytest.fixture(scope="module")
def shard_setup():
    """37 docs over 4 shards: per_shard=10, the last shard carries 7
    live docs + 3 all-zero pad rows — k=10 exceeds its live hits."""
    rng = np.random.default_rng(7)
    n_docs, dim, nnz = 37, 128, 8
    coords = np.argsort(rng.random((n_docs, dim)), axis=1)[:, :nnz] \
        .astype(np.int32)
    vals = rng.uniform(0.2, 1.0, (n_docs, nnz)).astype(np.float32)
    docs = PaddedSparse(jnp.asarray(coords), jnp.asarray(vals), dim)
    cfg = SeismicConfig(lam=16, beta=4, alpha=0.5, block_cap=8,
                        summary_nnz=8)
    stacked = build_sharded_index(docs, cfg, n_shards=N_SHARDS,
                                  list_chunk=8)
    queries = [(coords[i], vals[i]) for i in range(0, 36, 6)]  # 6 rows
    return stacked, docs, queries


def _shard_params():
    return SearchParams(k=10, cut=4, block_budget=4, policy="budget")


def test_shard_mode_matches_reference_merge(shard_setup):
    """Server output == per-shard pipeline + mask_shard_topk +
    merge_topk run by hand, bit for bit; docs_evaluated sums over
    shards."""
    stacked, docs, queries = shard_setup
    p = _shard_params()
    n_q = len(queries)
    srv = ReplicaSeismicServer(stacked, p, mode="shard", n_docs=docs.n,
                               max_batch=n_q, query_nnz=8,
                               deadline_s=30.0, cache_size=0,
                               coalesce=False)
    srv.start()
    futs = [srv.submit(c, v) for c, v in queries]   # one full batch
    srv.stop()
    res = [f.result() for f in futs]

    qc = jnp.asarray(np.stack([c for c, _ in queries]))
    qv = jnp.asarray(np.stack([v for _, v in queries]))
    per = stacked.fwd.coords.shape[1]
    parts_s, parts_g, parts_ev = [], [], []
    for s in range(N_SHARDS):
        shard = jax.tree.map(lambda x, s=s: x[s], stacked)
        sc, ids, ev = search_pipeline(
            shard, PaddedSparse(qc, qv, docs.dim), p)
        sc, gids = mask_shard_topk(sc, ids, shard.fwd, s * per,
                                   n_docs=docs.n)
        parts_s.append(np.asarray(sc))
        parts_g.append(np.asarray(gids))
        parts_ev.append(np.asarray(ev))
    top_s, top_ids, _ = merge_topk(
        jnp.asarray(np.concatenate(parts_g, axis=1)),
        jnp.asarray(np.concatenate(parts_s, axis=1)), p.k, docs.n)
    ev_ref = np.sum(parts_ev, axis=0)
    for i, r in enumerate(res):
        assert np.array_equal(r.ids, np.asarray(top_ids)[i])
        assert np.array_equal(r.scores, np.asarray(top_s)[i])
        assert r.docs_evaluated == int(ev_ref[i])


def test_shard_mode_k_exceeds_live_hits_no_pad_leak(shard_setup):
    """The satellite-bug invariant at the serving seam: with k above
    every shard's live-hit count, no out-of-range global id and no
    0.0-scored pad row reaches a caller; dead slots are (-1, -inf)."""
    stacked, docs, queries = shard_setup
    srv = ReplicaSeismicServer(stacked, _shard_params(), mode="shard",
                               n_docs=docs.n, max_batch=4, query_nnz=8,
                               deadline_s=0.01, cache_size=0,
                               coalesce=False)
    res = _serve_all(srv, queries)
    for r in res:
        assert r.ids.shape == (10,)
        live = r.ids >= 0
        assert (r.ids[live] < docs.n).all()
        assert np.isfinite(r.scores[live]).all()
        assert (r.ids[~live] == -1).all()
        assert np.isneginf(r.scores[~live]).all()
        assert r.docs_evaluated > 0


def test_shard_mode_coalesce_and_trace_label(shard_setup):
    """Coalescing works across the fan-out/merge path and merged launch
    spans carry the shard-merge replica label."""
    stacked, docs, _ = shard_setup
    c = np.asarray(stacked.fwd.coords[0, 0])
    v = np.asarray(stacked.fwd.vals[0, 0], np.float32)
    obs = Observability.create()
    srv = ReplicaSeismicServer(stacked, _shard_params(), mode="shard",
                               n_docs=docs.n, obs=obs, max_batch=8,
                               query_nnz=8, deadline_s=0.02,
                               cache_size=0, coalesce=True)
    f0 = srv.submit(c, v)                # queued before worker start
    f1 = srv.submit(c, v)                # coalesces onto f0's slot
    with srv:
        r0, r1 = f0.result(30.0), f1.result(30.0)
    assert r1.coalesced
    assert np.array_equal(r0.ids, r1.ids)
    for tr in obs.tracer.finished():
        validate_trace(tr)
        for s in tr.spans:
            if s.name == "launch":
                assert s.attrs["replica"] == "shard-merge"
                assert s.attrs["n_shards"] == N_SHARDS


def test_shard_mode_clean_stop_with_inflight_work(shard_setup):
    stacked, docs, queries = shard_setup
    srv = ReplicaSeismicServer(stacked, _shard_params(), mode="shard",
                               n_docs=docs.n, replica_delay_s=0.005,
                               max_batch=2, query_nnz=8,
                               deadline_s=30.0, cache_size=0,
                               coalesce=False)
    srv.start()
    futs = [srv.submit(c, v) for c, v in queries * 2]   # 12 requests
    srv.stop()
    for f in futs:
        assert f.status == "done"
    assert srv._replica_threads == []


# -------------------------------------------------------- validation

def test_constructor_validation(small_index, shard_setup):
    idx, _ = small_index
    stacked, _, _ = shard_setup
    p = _params()
    with pytest.raises(ValueError, match="unknown mode"):
        ReplicaSeismicServer(idx, p, n_replicas=2, mode="quorum")
    with pytest.raises(ValueError, match="n_replicas"):
        ReplicaSeismicServer(idx, p, mode="mirror")
    with pytest.raises(ValueError, match="stacked index shards"):
        ReplicaSeismicServer(stacked, _shard_params(), mode="shard",
                             n_replicas=3)
    with pytest.raises(ValueError, match="mirror-mode only"):
        ReplicaSeismicServer(stacked, _shard_params(), mode="shard",
                             stage_timing=True)
    with pytest.raises(ValueError, match="balancer covers"):
        ReplicaSeismicServer(idx, p, n_replicas=2,
                             balancer=StageTimingBalancer(3))
    with pytest.raises(ValueError, match="replica_delay_s"):
        ReplicaSeismicServer(idx, p, n_replicas=2,
                             replica_delay_s=(0.1, 0.1, 0.1))
