"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle,
shape/dtype sweeps + hypothesis property tests."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gather_dot.gather_dot import gather_dot_pallas
from repro.kernels.gather_dot.ops import gather_dot
from repro.kernels.gather_dot.ref import gather_dot_ref
from repro.kernels.summary_dot.ops import summary_dot
from repro.kernels.summary_dot.ref import summary_dot_ref
from repro.sparse.quant import quantize_u8


# ------------------------------------------------------------- gather_dot

@pytest.mark.parametrize("n,nnz,d", [(128, 16, 512), (256, 96, 4096),
                                     (384, 33, 1000), (5, 8, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_dot_sweep(n, nnz, d, dtype):
    rng = np.random.default_rng(n + nnz)
    q = jnp.asarray(rng.lognormal(0, 1, d), dtype)
    coords = jnp.asarray(rng.integers(0, d, (n, nnz)), jnp.int32)
    vals = jnp.asarray(rng.lognormal(0, 1, (n, nnz)), dtype)
    got = gather_dot(q, coords, vals)
    want = gather_dot_ref(q, coords, vals)
    rtol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=rtol)


def test_gather_dot_tile_exact():
    """Direct pallas call on an exact tile multiple (no ops padding)."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.random(256), jnp.float32)
    coords = jnp.asarray(rng.integers(0, 256, (256, 24)), jnp.int32)
    vals = jnp.asarray(rng.random((256, 24)), jnp.float32)
    got = gather_dot_pallas(q, coords, vals, tile_n=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(gather_dot_ref(q, coords, vals)),
                               rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 300), st.integers(1, 40), st.integers(2, 600),
       st.integers(0, 2 ** 31 - 1))
def test_gather_dot_property(n, nnz, d, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal(d), jnp.float32)
    coords = jnp.asarray(rng.integers(0, d, (n, nnz)), jnp.int32)
    vals = jnp.asarray(rng.standard_normal((n, nnz)), jnp.float32)
    got = gather_dot(q, coords, vals)
    want = gather_dot_ref(q, coords, vals)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------ summary_dot

@pytest.mark.parametrize("cut,nb,s,d", [(8, 12, 32, 1024), (1, 4, 8, 128),
                                        (16, 20, 64, 4096)])
def test_summary_dot_sweep(cut, nb, s, d):
    rng = np.random.default_rng(cut * nb)
    q = jnp.asarray(rng.lognormal(0, 1, d), jnp.float32)
    coords = jnp.asarray(rng.integers(0, d, (cut, nb, s)), jnp.int32)
    vals = rng.lognormal(0, 1, (cut, nb, s)).astype(np.float32)
    vals[rng.random((cut, nb, s)) < 0.3] = 0.0  # padding
    q8, scale, zero = quantize_u8(jnp.asarray(vals))
    got = summary_dot(q, coords, q8, scale, zero)
    want = summary_dot_ref(q, coords, q8, scale, zero)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_summary_dot_matches_unquantized_closely():
    """Fused dequant routing ~ float routing within quantization error."""
    rng = np.random.default_rng(1)
    d = 2048
    q = jnp.asarray(rng.lognormal(0, 1, d), jnp.float32)
    coords = jnp.asarray(rng.integers(0, d, (4, 8, 32)), jnp.int32)
    vals = jnp.asarray(rng.lognormal(0, 1, (4, 8, 32)), jnp.float32)
    q8, scale, zero = quantize_u8(vals)
    got = np.asarray(summary_dot(q, coords, q8, scale, zero))
    exact = np.asarray((jnp.take(q, coords, axis=0) * vals).sum(-1))
    rel = np.abs(got - exact) / np.maximum(np.abs(exact), 1e-9)
    assert rel.max() < 0.02


# -------------------------------------------------------- flash_attention

@pytest.mark.parametrize("b,h,hkv,s,dh", [(1, 4, 4, 128, 64),
                                          (2, 8, 2, 256, 64),
                                          (1, 2, 1, 200, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, h, hkv, s, dh, causal):
    rng = np.random.default_rng(s + h)
    q = jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, dh)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, tile_q=128, tile_k=128)
    kk = jnp.repeat(k, h // hkv, axis=1).reshape(b * h, s, dh)
    vv = jnp.repeat(v, h // hkv, axis=1).reshape(b * h, s, dh)
    want = attention_ref(q.reshape(b * h, s, dh), kk, vv,
                         sm_scale=dh ** -0.5, causal=causal,
                         kv_len=s).reshape(b, h, s, dh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_sliding_window():
    """Gemma-style local attention: window masking agrees with ref."""
    rng = np.random.default_rng(5)
    b, h, s, dh = 1, 2, 256, 64
    q = jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=64)
    want = attention_ref(q.reshape(b * h, s, dh), k.reshape(b * h, s, dh),
                         v.reshape(b * h, s, dh), sm_scale=dh ** -0.5,
                         causal=True, window=64, kv_len=s)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want).reshape(b, h, s, dh),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(9)
    b, h, s, dh = 1, 2, 128, 64
    mk = lambda: jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.bfloat16)
    q, k, v = mk(), mk(), mk()
    got = flash_attention(q, k, v, causal=True)
    want = attention_ref(q.reshape(b * h, s, dh), k.reshape(b * h, s, dh),
                         v.reshape(b * h, s, dh), sm_scale=dh ** -0.5,
                         causal=True, kv_len=s)
    np.testing.assert_allclose(np.asarray(got, np.float32).reshape(-1),
                               np.asarray(want, np.float32).reshape(-1),
                               rtol=0.05, atol=0.05)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 2), st.sampled_from([1, 2, 4]),
       st.integers(10, 300), st.sampled_from([32, 64]),
       st.integers(0, 2 ** 31 - 1))
def test_flash_attention_property(b, h, s, dh, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.float32)
    got = flash_attention(q, k, v, causal=True)
    want = attention_ref(q.reshape(b * h, s, dh), k.reshape(b * h, s, dh),
                         v.reshape(b * h, s, dh), sm_scale=dh ** -0.5,
                         causal=True, kv_len=s).reshape(b, h, s, dh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
