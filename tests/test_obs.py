"""Observability primitives (repro.obs): the histogram quantile
estimator, the labeled metrics registry, Prometheus text round-trip,
the HTTP/JSONL exporters, and the trace ring buffer.

Runs under the ``deterministic`` hypothesis profile; the monotone-
percentile property test skips cleanly when hypothesis is absent
(deterministic sweeps in this module cover the same invariants).
"""
import json
import threading
import urllib.error
import urllib.request

import pytest

from helpers import given, needs_hypothesis, settings, st
from repro.obs import (MetricsRegistry, Tracer, chrome_trace,
                       chrome_trace_json, parse_prometheus_text,
                       prometheus_text, start_exporter, validate_trace,
                       write_jsonl_snapshot)
from repro.obs.registry import Histogram


# ------------------------------------------------- histogram estimator

def test_histogram_percentile_monotone_and_bounded():
    """The satellite fix: estimates monotone non-decreasing in p and
    always inside [vmin, vmax], with exact endpoints."""
    h = Histogram()
    for x in [3e-6, 5e-5, 1e-4, 1e-4, 2e-3, 0.7, 0.7, 0.7, 12.0, 900.0]:
        h.record(x)
    ps = [i / 200 for i in range(201)]
    qs = h.percentiles(ps)
    assert qs == sorted(qs)                      # monotone in p
    assert all(h.vmin <= q <= h.vmax for q in qs)
    assert h.percentile(0.0) == h.vmin
    assert h.percentile(1.0) == h.vmax
    # out-of-range p clamps instead of extrapolating
    assert h.percentile(-0.5) == h.vmin
    assert h.percentile(1.5) == h.vmax


def test_histogram_single_observation_and_empty():
    h = Histogram()
    assert h.percentile(0.5) == 0.0              # empty -> 0, no crash
    h.record(0.042)
    for p in (0.0, 0.3, 0.5, 0.99, 1.0):
        assert h.percentile(p) == pytest.approx(0.042)


def test_histogram_overflow_bucket_bounded():
    """Observations past the top edge land in the overflow bucket; the
    estimate must still be clamped to the real max, not the edge."""
    h = Histogram(lo=1e-6, hi=1.0, n_buckets=8)
    h.record(50.0)
    h.record(70.0)
    assert h.percentile(0.5) <= 70.0
    assert h.percentile(1.0) == 70.0


def test_histogram_percentiles_shared_walk_matches_single():
    h = Histogram()
    for i in range(1, 400):
        h.record(i * 1.7e-4)
    ps = (0.1, 0.5, 0.9, 0.99)
    assert h.percentiles(ps) == [h.percentile(p) for p in ps]


@needs_hypothesis
@settings(max_examples=60, deadline=None)
@given(xs=st.lists(st.floats(min_value=1e-7, max_value=5e3,
                             allow_nan=False, allow_infinity=False),
                   min_size=1, max_size=200),
       ps=st.lists(st.floats(min_value=0.0, max_value=1.0),
                   min_size=2, max_size=32))
def test_histogram_percentile_property(xs, ps):
    h = Histogram()
    for x in xs:
        h.record(x)
    ps = sorted(ps)
    qs = h.percentiles(ps)
    assert qs == sorted(qs)
    assert all(h.vmin <= q <= h.vmax for q in qs)


# ------------------------------------------------------------ registry

def test_registry_idempotent_and_conflicts():
    reg = MetricsRegistry()
    c = reg.counter("x_total", "help", labels=("event",))
    assert reg.counter("x_total", labels=("event",)) is c
    with pytest.raises(ValueError):              # kind conflict
        reg.gauge("x_total", labels=("event",))
    with pytest.raises(ValueError):              # label-schema conflict
        reg.counter("x_total", labels=("other",))
    with pytest.raises(ValueError):
        reg.counter("bad name")
    with pytest.raises(ValueError):
        reg.counter("ok_total", labels=("bad-label",))
    with pytest.raises(ValueError):
        c.labels("a", "b")                       # wrong label arity
    with pytest.raises(ValueError):
        c.labels("a").inc(-1)                    # counters only go up


def test_registry_thread_safety():
    reg = MetricsRegistry()
    n_threads, n_iter = 8, 500

    def work(tid):
        for i in range(n_iter):
            reg.counter("hits_total", labels=("t",)).labels(tid).inc()
            reg.histogram("lat_seconds").labels().record(1e-3 * (i + 1))

    threads = [threading.Thread(target=work, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    fam = reg.get("hits_total")
    assert sum(c.value for _, c in fam.samples()) == n_threads * n_iter
    assert reg.histogram("lat_seconds").labels().n == n_threads * n_iter


def test_gauge_callback_failure_drops_sample_not_scrape():
    reg = MetricsRegistry()
    reg.gauge("ok").labels().set(2.5)
    reg.gauge("broken").labels().set_fn(lambda: 1 / 0)
    snap = reg.snapshot()
    assert snap["ok"]["samples"][0]["value"] == 2.5
    assert snap["broken"]["samples"] == []       # dropped, no raise
    text = prometheus_text(reg)
    assert "ok 2.5" in text
    assert "\nbroken " not in text


# ----------------------------------------------- exporters round-trip

def _demo_registry():
    reg = MetricsRegistry()
    ev = reg.counter("seismic_events_total", "lifecycle", ("event",))
    ev.labels("served").inc(7)
    ev.labels('quo"te\nnl').inc(1)               # escaping round-trips
    reg.gauge("seismic_cache_hit_rate", "hits/(hits+misses)") \
        .labels().set(0.25)
    lat = reg.histogram("seismic_latency_seconds", "spans", ("span",))
    for ms in (1, 2, 5, 10):
        lat.labels("request_e2e").record(ms * 1e-3)
    return reg


def test_prometheus_text_round_trip():
    reg = _demo_registry()
    parsed = parse_prometheus_text(prometheus_text(reg))
    assert parsed["seismic_events_total"]["type"] == "counter"
    samples = parsed["seismic_events_total"]["samples"]
    assert samples[("seismic_events_total",
                    (("event", "served"),))] == 7.0
    assert samples[("seismic_events_total",
                    (("event", 'quo"te\nnl'),))] == 1.0
    assert parsed["seismic_cache_hit_rate"]["samples"][
        ("seismic_cache_hit_rate", ())] == 0.25
    hist = parsed["seismic_latency_seconds"]
    assert hist["type"] == "histogram"
    assert hist["samples"][("seismic_latency_seconds_count",
                            (("span", "request_e2e"),))] == 4.0
    # cumulative buckets: the +Inf bucket equals the count
    assert hist["samples"][("seismic_latency_seconds_bucket",
                            (("le", "+Inf"),
                             ("span", "request_e2e"),))] == 4.0


def test_jsonl_snapshot(tmp_path):
    reg = _demo_registry()
    path = str(tmp_path / "obs.jsonl")
    rec = write_jsonl_snapshot(reg, path, extra={"tag": "t1"})
    write_jsonl_snapshot(reg, path)
    lines = [json.loads(l) for l in open(path, encoding="utf-8")]
    assert len(lines) == 2                       # appends, not truncates
    assert lines[0]["tag"] == "t1"
    assert lines[0]["metrics"] == rec["metrics"]
    served = [s for s in lines[1]["metrics"]["seismic_events_total"]
              ["samples"] if s["labels"] == {"event": "served"}]
    assert served[0]["value"] == 7


def test_http_endpoint_routes():
    reg = _demo_registry()
    tracer = Tracer()
    tr = tracer.start_trace("request", 0.0)
    tracer.add_span(tr, "launch", 0.0, 1.0)
    tracer.end_trace(tr, 1.0, status="done")
    with start_exporter(reg, tracer) as exp:
        with urllib.request.urlopen(exp.url + "/metrics") as r:
            assert "version=0.0.4" in r.headers["Content-Type"]
            text = r.read().decode()
        assert parse_prometheus_text(text)["seismic_events_total"]
        with urllib.request.urlopen(exp.url + "/snapshot.json") as r:
            snap = json.load(r)
        assert snap["seismic_cache_hit_rate"]["samples"][0]["value"] \
            == 0.25
        with urllib.request.urlopen(exp.url + "/traces") as r:
            chrome = json.load(r)
        assert {e["name"] for e in chrome["traceEvents"]} \
            == {"request", "launch"}
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(exp.url + "/nope")


# ------------------------------------------------------------- tracing

def test_trace_ring_bounded_and_dropped_counted():
    tracer = Tracer(capacity=4)
    for i in range(10):
        tr = tracer.start_trace("request", float(i))
        tracer.end_trace(tr, float(i) + 0.5)
    assert len(tracer) == 4
    assert tracer.dropped == 6
    kept = tracer.finished()
    assert [t.root.t0 for t in kept] == [6.0, 7.0, 8.0, 9.0]  # oldest out
    assert tracer.drain() == kept
    assert len(tracer) == 0


def test_chrome_trace_export_and_args():
    tracer = Tracer()
    tr = tracer.start_trace("request", 1.0)
    sp = tracer.add_span(tr, "launch", 1.1, 1.4, width=8)
    tracer.add_span(tr, "stage_router", 1.15, 1.2, parent=sp)
    tracer.end_trace(tr, 1.5, status="done")
    chrome = chrome_trace([tr])
    json.loads(chrome_trace_json([tr]))          # valid JSON
    ev = {e["name"]: e for e in chrome["traceEvents"]}
    assert ev["launch"]["ph"] == "X"
    assert ev["launch"]["ts"] == pytest.approx(1.1e6)   # microseconds
    assert ev["launch"]["dur"] == pytest.approx(0.3e6)
    assert ev["launch"]["args"]["width"] == 8
    # the tree survives the flat event format via args ids
    assert ev["stage_router"]["args"]["parent_id"] \
        == ev["launch"]["args"]["span_id"]
    assert ev["launch"]["args"]["parent_id"] \
        == ev["request"]["args"]["span_id"]


def test_validate_trace_violations():
    tracer = Tracer()
    ok = tracer.start_trace("request", 0.0)
    sp = tracer.add_span(ok, "launch", 0.1, 0.4)
    tracer.add_span(ok, "stage_prep", 0.15, 0.2, parent=sp)
    tracer.end_trace(ok, 0.5)
    validate_trace(ok)

    open_child = tracer.start_trace("request", 0.0)
    tracer.add_span(open_child, "launch", 0.1)   # never closed
    tracer.end_trace(open_child, 0.5)
    with pytest.raises(ValueError, match="never closed"):
        validate_trace(open_child)

    orphan = tracer.start_trace("request", 0.0)
    bad = tracer.add_span(orphan, "launch", 0.1, 0.2)
    bad.parent_id = 10 ** 9                       # dangling parent id
    tracer.end_trace(orphan, 0.5)
    with pytest.raises(ValueError, match="not in trace"):
        validate_trace(orphan)

    outside = tracer.start_trace("request", 0.0)
    tracer.add_span(outside, "launch", 0.1, 9.0)  # past root close
    tracer.end_trace(outside, 0.5)
    with pytest.raises(ValueError, match="outside parent"):
        validate_trace(outside)

    backwards = tracer.start_trace("request", 0.0)
    tracer.add_span(backwards, "launch", 0.3, 0.1)
    tracer.end_trace(backwards, 0.5)
    with pytest.raises(ValueError, match="ends before"):
        validate_trace(backwards)
