"""The staged batch-first retrieval pipeline and its natively-batched
Pallas kernels (interpret-mode parity vs refs), plus the cross-stage
invariants the autotuner leans on: ``merge_topk`` permutation /
sentinel-duplicate invariance and k>C clamp edges, and selector
policies returning fixed shapes under jit. Hypothesis hardens the
merge properties when installed; the deterministic sweeps run always.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from helpers import given, needs_hypothesis, settings, st

from repro.kernels.gather_dot.ops import gather_dot, gather_dot_batch
from repro.kernels.gather_dot.ref import gather_dot_batch_ref, gather_dot_ref
from repro.kernels.summary_dot.ops import summary_dot, summary_dot_batch
from repro.kernels.summary_dot.ref import (summary_dot_batch_ref,
                                           summary_dot_ref)
from repro.retrieval import (SearchParams, get_selector, register_selector,
                             search_pipeline, selector_names)
from repro.sparse.quant import quantize_u8


# ------------------------------------------------- batched gather_dot

@pytest.mark.parametrize("qn,n,nnz,d", [
    (8, 128, 16, 512),     # exact tile multiples
    (3, 37, 17, 300),      # neither Q nor N tile-aligned
    (1, 5, 8, 64),         # tiny single-query batch
    (13, 260, 33, 1000),   # N just past two tiles
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_dot_batch_parity(qn, n, nnz, d, dtype):
    rng = np.random.default_rng(qn * n + nnz)
    q = jnp.asarray(rng.lognormal(0, 1, (qn, d)), dtype)
    coords = jnp.asarray(rng.integers(0, d, (qn, n, nnz)), jnp.int32)
    vals = jnp.asarray(rng.lognormal(0, 1, (qn, n, nnz)), dtype)
    got = gather_dot_batch(q, coords, vals)
    want = gather_dot_batch_ref(q, coords, vals)
    rtol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=rtol)


def test_gather_dot_batch_fused_dequant_parity():
    """Compact-forward-index path: u8 values + per-candidate (scale,
    zero) dequantized inside the kernel."""
    rng = np.random.default_rng(0)
    qn, n, nnz, d = 5, 70, 24, 777
    q = jnp.asarray(rng.lognormal(0, 1, (qn, d)), jnp.float32)
    coords = jnp.asarray(rng.integers(0, d, (qn, n, nnz)), jnp.int32)
    vals = rng.lognormal(0, 1, (qn, n, nnz)).astype(np.float32)
    vals[rng.random((qn, n, nnz)) < 0.25] = 0.0    # padded entries
    u8, scale, zero = quantize_u8(jnp.asarray(vals))
    got = gather_dot_batch(q, coords, u8, scale, zero)
    want = gather_dot_batch_ref(q, coords, u8, scale, zero)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gather_dot_legacy_single_query_api():
    """The pre-batch [N, nnz] API still matches its ref (Q=1 reshape)."""
    rng = np.random.default_rng(1)
    n, nnz, d = 37, 12, 400
    q = jnp.asarray(rng.lognormal(0, 1, d), jnp.float32)
    coords = jnp.asarray(rng.integers(0, d, (n, nnz)), jnp.int32)
    vals = jnp.asarray(rng.lognormal(0, 1, (n, nnz)), jnp.float32)
    np.testing.assert_allclose(np.asarray(gather_dot(q, coords, vals)),
                               np.asarray(gather_dot_ref(q, coords, vals)),
                               rtol=1e-6)


# ------------------------------------------------ batched summary_dot

@pytest.mark.parametrize("qn,l,s,d", [
    (8, 128, 32, 1024),    # exact tile multiples
    (3, 45, 12, 300),      # odd everything
    (1, 1, 8, 64),         # single query, single block
    (9, 200, 24, 2048),    # L between tile multiples
])
def test_summary_dot_batch_parity(qn, l, s, d):
    rng = np.random.default_rng(qn + l)
    q = jnp.asarray(rng.lognormal(0, 1, (qn, d)), jnp.float32)
    coords = jnp.asarray(rng.integers(0, d, (qn, l, s)), jnp.int32)
    vals = rng.lognormal(0, 1, (qn, l, s)).astype(np.float32)
    vals[rng.random((qn, l, s)) < 0.3] = 0.0       # padding
    u8, scale, zero = quantize_u8(jnp.asarray(vals))
    got = summary_dot_batch(q, coords, u8, scale, zero)
    want = summary_dot_batch_ref(q, coords, u8, scale, zero)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_summary_dot_batch_all_padding_rows():
    """Summaries that are 100% padding (level 0) must score exactly 0."""
    rng = np.random.default_rng(2)
    qn, l, s, d = 4, 20, 16, 256
    q = jnp.asarray(rng.lognormal(0, 1, (qn, d)), jnp.float32)
    coords = jnp.asarray(rng.integers(0, d, (qn, l, s)), jnp.int32)
    u8 = jnp.zeros((qn, l, s), jnp.uint8)
    scale = jnp.asarray(rng.random((qn, l)), jnp.float32)
    zero = jnp.asarray(rng.random((qn, l)), jnp.float32)
    got = np.asarray(summary_dot_batch(q, coords, u8, scale, zero))
    np.testing.assert_array_equal(got, np.zeros((qn, l), np.float32))


def test_summary_dot_legacy_single_query_api():
    rng = np.random.default_rng(3)
    cut, nb, s, d = 5, 9, 16, 512
    q = jnp.asarray(rng.lognormal(0, 1, d), jnp.float32)
    coords = jnp.asarray(rng.integers(0, d, (cut, nb, s)), jnp.int32)
    vals = rng.lognormal(0, 1, (cut, nb, s)).astype(np.float32)
    u8, scale, zero = quantize_u8(jnp.asarray(vals))
    got = summary_dot(q, coords, u8, scale, zero)
    want = summary_dot_ref(q, coords, u8, scale, zero)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------- scorer stage masks

def test_score_candidates_sentinel_padding(small_index):
    """Sentinel candidate ids (== n_docs) must score -inf on both the
    jnp and the kernel path; real ids must agree across paths."""
    from repro.retrieval.scorer import score_candidates
    idx, _ = small_index
    rng = np.random.default_rng(4)
    qn, c = 3, 40
    q_dense = jnp.asarray(rng.lognormal(0, 1, (qn, idx.dim)), jnp.float32)
    cand = rng.integers(0, idx.n_docs, (qn, c))
    cand[:, ::3] = idx.n_docs                       # sentinel-padded slots
    cand = jnp.asarray(cand, jnp.int32)
    s_jnp = np.asarray(score_candidates(idx, q_dense, cand, False))
    s_krn = np.asarray(score_candidates(idx, q_dense, cand, True))
    assert (s_jnp[:, ::3] == -np.inf).all()
    np.testing.assert_allclose(s_jnp, s_krn, rtol=1e-5, atol=1e-5)


# ------------------------------------------ pipeline + selector registry

def test_selector_registry():
    assert set(selector_names()) >= {"budget", "adaptive",
                                     "global_threshold"}
    with pytest.raises(KeyError, match="unknown selector"):
        get_selector("nope")

    @register_selector("_test_probe")
    def probe(index, batch, p):     # pragma: no cover - registry only
        return None

    assert get_selector("_test_probe") is probe


@pytest.mark.parametrize("policy", ["budget", "adaptive",
                                    "global_threshold"])
def test_pipeline_policies_recall(small_index, small_collection, policy):
    from repro.core.baselines import exact_search
    from repro.core.oracle import recall_at_k
    idx, _ = small_index
    docs, queries, *_ = small_collection
    p = SearchParams(k=10, cut=8, block_budget=48, policy=policy)
    _, ids, ev = search_pipeline(idx, queries, p)
    _, eids = exact_search(docs, queries, 10)
    rec = np.mean([recall_at_k(np.asarray(ids[q]), np.asarray(eids[q]))
                   for q in range(queries.n)])
    assert rec >= 0.9, (policy, rec)
    assert np.asarray(ev).mean() < 0.5 * docs.n


def test_global_threshold_prunes_vs_budget(small_index, small_collection):
    """The BMP-style selector must evaluate fewer docs than exhaustive
    budget routing at the same block budget."""
    idx, _ = small_index
    _, queries, *_ = small_collection
    pb = SearchParams(k=10, cut=8, block_budget=48, policy="budget")
    pg = SearchParams(k=10, cut=8, block_budget=48,
                      policy="global_threshold")
    _, _, evb = search_pipeline(idx, queries, pb)
    _, _, evg = search_pipeline(idx, queries, pg)
    assert np.asarray(evg).mean() < np.asarray(evb).mean()


def test_pipeline_kernel_path_matches_jnp(small_index, small_collection):
    """use_kernel=True (batched Pallas, interpret mode on CPU) must
    reproduce the jnp path bit-for-bit on ids and near-exactly on
    scores."""
    idx, _ = small_index
    _, queries, *_ = small_collection
    p0 = SearchParams(k=10, cut=8, block_budget=32, policy="adaptive")
    p1 = SearchParams(k=10, cut=8, block_budget=32, policy="adaptive",
                      use_kernel=True)
    s0, i0, e0 = search_pipeline(idx, queries, p0)
    s1, i1, e1 = search_pipeline(idx, queries, p1)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))


@pytest.mark.parametrize("policy", ["budget", "adaptive",
                                    "global_threshold"])
def test_pipeline_fuse_levels_bitexact(small_index, small_collection,
                                       policy):
    """The fuse_level ladder (0 = unfused, 1 = candidate compaction +
    candidate-driven scorer kernel, 2 = + fused router) must be
    BITWISE identical on scores, ids, and docs_evaluated — fusion
    reshapes execution, never results (tests/test_fusion.py carries
    the stage-level and hierarchical/refined variants)."""
    import dataclasses
    idx, _ = small_index
    _, queries, *_ = small_collection
    p0 = SearchParams(k=10, cut=8, block_budget=32, policy=policy)
    outs = [search_pipeline(idx, queries,
                            dataclasses.replace(p0, fuse_level=lvl))
            for lvl in (0, 1, 2)]
    for lvl_out in outs[1:]:
        for x, y in zip(outs[0], lvl_out):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_adaptive_small_block_budget(small_index, small_collection):
    """block_budget < probe_budget must degrade to pure budget routing,
    not crash on a negative stage-2 top_k."""
    idx, _ = small_index
    _, queries, *_ = small_collection
    p = SearchParams(k=5, cut=8, block_budget=4, probe_budget=8,
                     policy="adaptive")
    s, ids, ev = search_pipeline(idx, queries, p)
    assert ids.shape == (queries.n, 5)
    assert (np.asarray(ev) > 0).all()


def test_pipeline_compact_fwd_index_kernel_parity():
    """fwd_quant=True: the scorer's in-kernel u8 dequant must agree
    with the jnp dequant path through the whole pipeline."""
    from repro.core import SeismicConfig, build_index
    from repro.data import SyntheticSparseConfig, make_collection
    from repro.sparse.ops import PaddedSparse
    cfg = SyntheticSparseConfig(dim=512, n_docs=1024, n_queries=8,
                                doc_nnz=32, query_nnz=12, n_topics=16,
                                topic_coords=96, seed=5)
    docs_np, queries_np, _ = make_collection(cfg)
    docs = PaddedSparse(jnp.asarray(docs_np.coords),
                        jnp.asarray(docs_np.vals), docs_np.dim)
    queries = PaddedSparse(jnp.asarray(queries_np.coords),
                           jnp.asarray(queries_np.vals), queries_np.dim)
    idx = build_index(docs, SeismicConfig(lam=96, beta=8, alpha=0.4,
                                          block_cap=24, summary_nnz=24,
                                          fwd_quant=True), list_chunk=16)
    assert idx.fwd_scale is not None
    p0 = SearchParams(k=10, cut=8, block_budget=32, policy="adaptive")
    p1 = SearchParams(k=10, cut=8, block_budget=32, policy="adaptive",
                      use_kernel=True)
    s0, i0, _ = search_pipeline(idx, queries, p0)
    s1, i1, _ = search_pipeline(idx, queries, p1)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               rtol=1e-4, atol=1e-4)


def test_search_batch_is_pipeline(small_index, small_collection):
    """The core.query compatibility shim must be the shared pipeline."""
    from repro.core import search_batch
    idx, _ = small_index
    _, queries, *_ = small_collection
    p = SearchParams(k=5, cut=8, block_budget=16, policy="budget")
    s0, i0, e0 = search_batch(idx, queries, p)
    s1, i1, e1 = search_pipeline(idx, queries, p)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))


# ------------------------------------------------------- merge guards

def test_merge_topk_k_wider_than_candidates():
    """k > C must clamp to the candidate axis and pad with -1 / -inf."""
    from repro.retrieval import merge_topk
    cand = jnp.array([[3, 7, 9], [2, 4, 11]], jnp.int32)   # 11 = sentinel
    scores = jnp.array([[1.0, 3.0, 2.0], [5.0, -jnp.inf, -jnp.inf]])
    top_s, ids, ev = merge_topk(cand, scores, k=6, n_docs=11)
    assert top_s.shape == (2, 6) and ids.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(ids[0]), [7, 9, 3, -1, -1, -1])
    np.testing.assert_array_equal(np.asarray(ids[1]), [2, -1, -1, -1, -1, -1])
    assert np.asarray(top_s)[0, :3].tolist() == [3.0, 2.0, 1.0]
    assert (np.asarray(top_s)[:, 3:] == -np.inf).all()
    np.testing.assert_array_equal(np.asarray(ev), [3, 2])


def test_pipeline_tiny_block_budget_large_k(small_index, small_collection):
    """block_budget * block_cap < k must not crash the pipeline."""
    idx, icfg = small_index
    _, queries, *_ = small_collection
    p = SearchParams(k=2 * icfg.block_cap, cut=8, block_budget=1,
                     policy="budget")
    s, ids, _ = search_pipeline(idx, queries, p)
    assert ids.shape == (queries.n, 2 * icfg.block_cap)
    assert (np.asarray(ids)[:, -1] == -1).all()   # padded tail


# ----------------------- merge invariants the autotuner leans on
#
# The tuner's cost/recall measurements are only order-invariant and
# reproducible if the merge stage itself is: a permutation of the
# candidate axis, or extra sentinel-masked duplicate slots (exactly
# what dedupe_batch and the refine stage emit), must not change the
# merged top-k nor docs_evaluated.

def _random_merge_inputs(seed, qn=3, c=24, n_docs=100):
    rng = np.random.default_rng(seed)
    cand = rng.integers(0, n_docs, (qn, c)).astype(np.int32)
    sent = rng.random((qn, c)) < 0.2
    cand[sent] = n_docs                              # sentinel slots
    # distinct scores (ties would make the top-k order depend on input
    # position — the pipeline never ties exactly except at -inf)
    scores = np.empty((qn, c), np.float32)
    for q in range(qn):
        scores[q] = rng.permutation(np.arange(c, dtype=np.float32))
    scores[sent] = -np.inf
    return cand, scores, n_docs


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_merge_topk_permutation_invariant(seed):
    from repro.retrieval import merge_topk
    cand, scores, n_docs = _random_merge_inputs(seed)
    perm = np.random.default_rng(seed + 100).permutation(cand.shape[1])
    s0, i0, e0 = merge_topk(jnp.asarray(cand), jnp.asarray(scores),
                            10, n_docs)
    s1, i1, e1 = merge_topk(jnp.asarray(cand[:, perm]),
                            jnp.asarray(scores[:, perm]), 10, n_docs)
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))


@pytest.mark.parametrize("seed", [3, 4])
def test_merge_topk_sentinel_duplicate_slots_invariant(seed):
    """Appending masked duplicate slots (sentinel id, -inf score — what
    dedupe_batch turns repeated candidates into) must change nothing:
    not the top-k, not docs_evaluated."""
    from repro.retrieval import merge_topk
    cand, scores, n_docs = _random_merge_inputs(seed)
    qn, c = cand.shape
    extra = 7
    cand2 = np.concatenate(
        [cand, np.full((qn, extra), n_docs, np.int32)], axis=1)
    scores2 = np.concatenate(
        [scores, np.full((qn, extra), -np.inf, np.float32)], axis=1)
    s0, i0, e0 = merge_topk(jnp.asarray(cand), jnp.asarray(scores),
                            10, n_docs)
    s1, i1, e1 = merge_topk(jnp.asarray(cand2), jnp.asarray(scores2),
                            10, n_docs)
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))


@needs_hypothesis
@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 40), st.integers(1, 32))
def test_hypothesis_merge_topk_invariants(seed, k, c):
    """Random k/C (including k > C clamp edges): permutation and
    sentinel-slot invariance plus the [Q, k] padding contract."""
    from repro.retrieval import merge_topk
    cand, scores, n_docs = _random_merge_inputs(seed, qn=2, c=c)
    perm = np.random.default_rng(seed ^ 0x5EED).permutation(c)
    s0, i0, e0 = merge_topk(jnp.asarray(cand), jnp.asarray(scores),
                            k, n_docs)
    s1, i1, e1 = merge_topk(jnp.asarray(cand[:, perm]),
                            jnp.asarray(scores[:, perm]), k, n_docs)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))
    assert i0.shape == (2, k) and s0.shape == (2, k)
    if k > c:                                   # clamped: padded tail
        assert (np.asarray(i0)[:, c:] == -1).all()
        assert (np.asarray(s0)[:, c:] == -np.inf).all()
    ids = np.asarray(i0)
    assert ((ids == -1) | (ids < n_docs)).all()  # sentinels never leak


def test_selectors_fixed_shapes_under_jit(small_index, small_collection):
    """Every registered selector policy must produce a fixed-shape
    Selection ([Q, block_budget]) under jit — the tuner swaps policies
    as static args and relies on no data-dependent shapes anywhere."""
    from repro.retrieval.prep import prep_queries
    from repro.retrieval.router import route_batch
    idx, _ = small_index
    _, queries, *_ = small_collection
    budget = 12
    for name in selector_names():
        if name.startswith("_"):                # test-registered probes
            continue
        p = SearchParams(k=10, cut=8, block_budget=budget, policy=name)
        select = get_selector(name)
        q_dense, lists, _ = prep_queries(queries.coords, queries.vals,
                                         idx.dim, p.cut)
        batch = route_batch(idx, q_dense, lists, p)
        sel = jax.eval_shape(
            lambda b, _f=select: _f(idx, b, p), batch)
        assert sel.blocks.shape == (queries.n, budget), name
        assert sel.block_scores.shape == (queries.n, budget), name
        # and the traced stage agrees with the abstract eval
        out = jax.jit(lambda b, _f=select: _f(idx, b, p))(batch)
        assert out.blocks.shape == (queries.n, budget), name
