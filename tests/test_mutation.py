"""Streaming index mutation (repro.core.mutate): LSM-style tail
segments, tombstone deletes, and compaction.

The load-bearing contract, checked here end to end:

    frozen blocks + exact tail + tombstones  ==  one logical corpus

At FULL budget (``cut`` covers every query coordinate and
``block_budget = cut * n_blocks``) approximate search degenerates to
exact search over the candidate union, so a grown-and-mutated index
must BIT-match ``build_index`` of the equivalent corpus — same ids,
same scores, same ``docs_evaluated`` — where "equivalent corpus" means
a capacity-sized collection whose deleted / never-assigned rows are
all-zero.

Deterministic sweeps always run; the ``@needs_hypothesis`` sequences
add randomized insert/delete/compact interleavings when hypothesis is
installed (the conftest pins its deterministic profile).
"""
import numpy as np
import pytest
import jax.numpy as jnp

from helpers import given, needs_hypothesis, settings, st
from repro.core import MutableSeismicIndex, SeismicConfig, build_index, \
    make_mutable
from repro.retrieval import SearchParams, search_pipeline
from repro.sparse.ops import PaddedSparse
from repro.sparse.quant import dequantize_u8

DIM = 64
NNZ = 8
CAP = 40

CFG = SeismicConfig(lam=16, beta=2, alpha=1.0, block_cap=4,
                    summary_nnz=64, superblock_fanout=2)


def _full_budget_params(k: int = 10) -> SearchParams:
    """Exhaustive operating point: every routed block selected."""
    return SearchParams(k=k, cut=NNZ, block_budget=NNZ * CFG.n_blocks,
                        policy="budget")


def _rand_docs(rng, n: int):
    coords = np.stack([rng.choice(np.arange(1, DIM), NNZ, replace=False)
                       for _ in range(n)]).astype(np.int64)
    vals = rng.uniform(0.1, 1.0, (n, NNZ)).astype(np.float32)
    return coords, vals


def _queries(rng, n: int = 8) -> PaddedSparse:
    coords, vals = _rand_docs(rng, n)
    return PaddedSparse(jnp.asarray(coords.astype(np.int32)),
                        jnp.asarray(vals), DIM)


def _equivalence_corpus(mut: MutableSeismicIndex) -> PaddedSparse:
    """Capacity-sized collection equal to the mutable's logical corpus:
    live rows carry their forward entries, deleted / unassigned rows
    are all-zero."""
    coords = np.asarray(mut.index.fwd.coords).copy()
    vals = np.asarray(mut.index.fwd.vals).copy()
    if mut.index.fwd_scale is not None:
        vals = np.asarray(dequantize_u8(
            jnp.asarray(vals), mut.index.fwd_scale, mut.index.fwd_zero))
    dead = np.asarray(mut.index.tombstone).copy()
    dead[mut.n_docs:] = True
    coords[dead] = 0
    vals[dead] = 0.0
    return PaddedSparse(jnp.asarray(coords), jnp.asarray(vals), DIM)


def _assert_bitmatch(mut: MutableSeismicIndex, queries: PaddedSparse,
                     p: SearchParams) -> None:
    fresh = build_index(_equivalence_corpus(mut), CFG)
    s_m, i_m, ev_m = search_pipeline(mut.index, queries, p)
    s_f, i_f, ev_f = search_pipeline(fresh, queries, p)
    np.testing.assert_array_equal(np.asarray(i_m), np.asarray(i_f))
    np.testing.assert_array_equal(np.asarray(s_m), np.asarray(s_f))
    np.testing.assert_array_equal(np.asarray(ev_m), np.asarray(ev_f))


# ------------------------------------------------------- growth + search

def test_grow_from_empty_bitmatches_fresh_build():
    """Corpus grown empty -> full through insert_docs with periodic
    auto-compaction serves the exact same results as a fresh build."""
    rng = np.random.default_rng(0)
    mut = MutableSeismicIndex.empty(DIM, NNZ, CFG, capacity=CAP,
                                    tail_cap=16, tail_max=8)
    queries = _queries(rng)
    p = _full_budget_params()
    inserted = 0
    epochs = [mut.epoch]
    while inserted < CAP:
        b = min(int(rng.integers(1, 6)), CAP - inserted)
        ids = mut.insert_docs(*_rand_docs(rng, b))
        np.testing.assert_array_equal(
            ids, np.arange(inserted, inserted + b))
        inserted += b
        epochs.append(mut.epoch)
        _assert_bitmatch(mut, queries, p)       # live tail mid-growth
    assert mut.n_docs == CAP
    assert all(b > a for a, b in zip(epochs, epochs[1:]))
    mut.compact()
    assert mut.tail_occupancy == 0
    _assert_bitmatch(mut, queries, p)


def test_capacity_exhaustion_raises():
    mut = MutableSeismicIndex.empty(DIM, NNZ, CFG, capacity=4, tail_cap=8)
    rng = np.random.default_rng(1)
    mut.insert_docs(*_rand_docs(rng, 4))
    with pytest.raises(ValueError, match="capacity exhausted"):
        mut.insert_docs(*_rand_docs(rng, 1))


def test_make_mutable_lifts_built_index():
    """Wrapping an existing build + inserting on top matches a fresh
    build over the concatenated corpus."""
    rng = np.random.default_rng(2)
    base_c, base_v = _rand_docs(rng, 20)
    docs = PaddedSparse(jnp.asarray(base_c), jnp.asarray(base_v), DIM)
    mut = make_mutable(build_index(docs, CFG), capacity=CAP, tail_cap=16,
                       tail_max=8)
    assert mut.n_docs == 20
    ids = mut.insert_docs(*_rand_docs(rng, 12))
    np.testing.assert_array_equal(ids, np.arange(20, 32))
    _assert_bitmatch(mut, _queries(rng), _full_budget_params())
    mut.compact()
    _assert_bitmatch(mut, _queries(rng), _full_budget_params())


# ---------------------------------------------------------- tombstones

def test_deleted_docs_never_returned():
    """Deletes on blocked AND tail docs: masked from results the moment
    delete_docs returns, purged physically at compact — and the search
    bit-matches a fresh build without those docs at every step."""
    rng = np.random.default_rng(3)
    mut = MutableSeismicIndex.empty(DIM, NNZ, CFG, capacity=CAP,
                                    tail_cap=16, tail_max=8)
    mut.insert_docs(*_rand_docs(rng, 30))
    mut.compact()                      # 30 blocked docs
    mut.insert_docs(*_rand_docs(rng, 6))   # 6 live in the tail
    queries = _queries(rng)
    p = _full_budget_params()
    doomed = np.array([1, 7, 19, 31, 33])  # blocked + tail victims
    mut.delete_docs(doomed)
    assert mut.n_live == 31
    for ids in (np.asarray(search_pipeline(mut.index, queries, p)[1]),):
        assert not np.isin(ids, doomed).any()
    _assert_bitmatch(mut, queries, p)          # pre-compaction
    mut.compact()
    ids = np.asarray(search_pipeline(mut.index, queries, p)[1])
    assert not np.isin(ids, doomed).any()
    _assert_bitmatch(mut, queries, p)          # post-purge
    # ids are never reused: the next insert continues after the dead
    new = mut.insert_docs(*_rand_docs(rng, 2))
    np.testing.assert_array_equal(new, [36, 37])
    _assert_bitmatch(mut, queries, p)


def test_delete_is_idempotent_and_checked():
    rng = np.random.default_rng(4)
    mut = MutableSeismicIndex.empty(DIM, NNZ, CFG, capacity=8, tail_cap=8)
    mut.insert_docs(*_rand_docs(rng, 4))
    mut.delete_docs([1, 2])
    mut.delete_docs([2])               # idempotent
    assert mut.n_live == 2
    with pytest.raises(ValueError, match="delete ids"):
        mut.delete_docs([17])


def test_adaptive_policy_excludes_deleted():
    """The adaptive selector bootstraps theta from exact stage-1 scores;
    tombstoned docs must neither surface in results nor inflate theta
    into over-pruning."""
    rng = np.random.default_rng(5)
    mut = MutableSeismicIndex.empty(DIM, NNZ, CFG, capacity=CAP,
                                    tail_cap=16, tail_max=8)
    mut.insert_docs(*_rand_docs(rng, 36))
    mut.compact()
    doomed = np.arange(0, 36, 5)
    mut.delete_docs(doomed)
    p = SearchParams(k=10, cut=NNZ, block_budget=NNZ * CFG.n_blocks,
                     policy="adaptive", probe_budget=4, heap_factor=0.9)
    ids = np.asarray(search_pipeline(mut.index, _queries(rng), p)[1])
    assert not np.isin(ids, doomed).any()


# ------------------------------------------------- summary monotonicity

def _block_members(index, ell: int, b: int) -> np.ndarray:
    off = int(index.block_off[ell, b])
    ln = int(index.block_len[ell, b])
    docs = np.asarray(index.list_docs[ell, off:off + ln])
    return docs[docs < index.n_docs]


def test_summaries_upper_bound_members_after_mutation():
    """After an insert/delete/compact sequence every u8 block summary
    still upper-bounds its live members' exact scores (up to the
    round-to-nearest quantization slack), and every superblock summary
    upper-bounds its children EXACTLY (ceil quantization — the
    monotone-merge invariant compaction must preserve)."""
    rng = np.random.default_rng(6)
    mut = MutableSeismicIndex.empty(DIM, NNZ, CFG, capacity=CAP,
                                    tail_cap=16, tail_max=6)
    mut.insert_docs(*_rand_docs(rng, 25))
    mut.delete_docs([2, 9, 14])
    mut.insert_docs(*_rand_docs(rng, 10))
    mut.compact()
    mut.insert_docs(*_rand_docs(rng, 5))   # leave a live tail too
    idx = mut.index
    fwd_c = np.asarray(idx.fwd.coords)
    fwd_v = np.asarray(idx.fwd.vals, np.float32)
    q_dense = np.zeros((4, DIM), np.float32)
    qs = _queries(rng, 4)
    for r, (qc, qv) in enumerate(zip(np.asarray(qs.coords),
                                     np.asarray(qs.vals))):
        np.add.at(q_dense[r], qc, qv)
        q_dense[r, 0] = 0.0
    fanout = CFG.superblock_fanout
    checked = 0
    for ell in range(idx.n_lists):
        blk_scores = np.full(CFG.n_blocks, -np.inf)
        for b in range(CFG.n_blocks):
            if int(idx.block_len[ell, b]) == 0:
                continue
            sc = np.asarray(idx.sum_coords[ell, b])
            sv = np.asarray(dequantize_u8(idx.sum_q[ell, b],
                                          idx.sum_scale[ell, b],
                                          idx.sum_zero[ell, b]))
            s_sum = q_dense[:, sc] @ sv                     # [4]
            slack = 0.5 * float(idx.sum_scale[ell, b]) \
                * q_dense.sum(axis=1)
            for d in _block_members(idx, ell, b):
                exact = q_dense[:, fwd_c[d]] @ fwd_v[d]
                assert np.all(s_sum + slack + 1e-4 >= exact), \
                    f"block summary violated at list {ell} block {b}"
                checked += 1
            blk_scores[b] = s_sum.max()
        if idx.sup_coords is None:
            continue
        for g in range(CFG.n_superblocks):
            kids = blk_scores[g * fanout:(g + 1) * fanout]
            if not np.isfinite(kids).any():
                continue
            pc = np.asarray(idx.sup_coords[ell, g])
            pv = np.asarray(dequantize_u8(idx.sup_q[ell, g],
                                          idx.sup_scale[ell, g],
                                          idx.sup_zero[ell, g]))
            sup = q_dense[:, pc] @ pv
            for b in range(g * fanout, (g + 1) * fanout):
                if int(idx.block_len[ell, b]) == 0:
                    continue
                sc = np.asarray(idx.sum_coords[ell, b])
                sv = np.asarray(dequantize_u8(idx.sum_q[ell, b],
                                              idx.sum_scale[ell, b],
                                              idx.sum_zero[ell, b]))
                child = q_dense[:, sc] @ sv
                assert np.all(sup + 1e-4 >= child), \
                    f"superblock bound violated at list {ell} group {g}"
    assert checked > 0


# --------------------------------------------------- checkpoint round-trip

def test_index_checkpoint_roundtrips_tail_and_tombstones(tmp_path):
    """save_index/load_index persist the mutation plane; resuming a
    MutableSeismicIndex from the restored snapshot serves identically
    and keeps the tombstones dead forever."""
    from repro.ckpt.checkpoint import load_index, save_index
    rng = np.random.default_rng(7)
    mut = MutableSeismicIndex.empty(DIM, NNZ, CFG, capacity=CAP,
                                    tail_cap=16, tail_max=8)
    mut.insert_docs(*_rand_docs(rng, 24))
    mut.compact()
    mut.insert_docs(*_rand_docs(rng, 5))       # live tail at save time
    mut.delete_docs([3, 11, 25])               # blocked + tail victims
    save_index(str(tmp_path), mut.index, step=1)
    restored = load_index(str(tmp_path), step=1)
    assert restored.tail_ids is not None
    assert restored.tombstone is not None
    queries = _queries(rng)
    p = _full_budget_params()
    s0, i0, ev0 = search_pipeline(mut.index, queries, p)
    s1, i1, ev1 = search_pipeline(restored, queries, p)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(ev0), np.asarray(ev1))
    # resume mutating on top of the restored snapshot
    mut2 = make_mutable(restored, capacity=CAP, tail_cap=16, tail_max=8,
                        n_docs=mut.n_docs)
    assert mut2.tail_occupancy == mut.tail_occupancy
    assert mut2.n_live == mut.n_live
    mut2.compact()
    ids = np.asarray(search_pipeline(mut2.index, queries, p)[1])
    assert not np.isin(ids, [3, 11, 25]).any()
    _assert_bitmatch(mut2, queries, p)


def test_backcompat_index_without_mutation_plane(tmp_path):
    """Pre-mutation checkpoints (no tail/tombstone keys) still load,
    with the mutation plane absent (None) — and the compiled program
    for such an index is the immutable one."""
    from repro.ckpt.checkpoint import load_index, save_index
    rng = np.random.default_rng(8)
    docs = PaddedSparse(*map(jnp.asarray, _rand_docs(rng, 16)), DIM)
    idx = build_index(docs, CFG)
    save_index(str(tmp_path), idx, step=0)
    restored = load_index(str(tmp_path), step=0)
    assert restored.tail_ids is None and restored.tombstone is None


# ------------------------------------------------ property-based sequences

OPS = st.lists(
    st.one_of(
        st.tuples(st.just("insert"), st.integers(1, 6)),
        st.tuples(st.just("delete"), st.integers(0, 1_000_000)),
        st.tuples(st.just("compact"), st.just(0)),
    ),
    min_size=1, max_size=12)


@needs_hypothesis
@settings(max_examples=25, deadline=None)
@given(ops=OPS, seed=st.integers(0, 2**16))
def test_property_any_sequence_bitmatches_equivalent_build(ops, seed):
    """(a) after ANY insert/delete/compact sequence, full-budget search
    bit-matches build_index of the equivalent final corpus."""
    rng = np.random.default_rng(seed)
    mut = MutableSeismicIndex.empty(DIM, NNZ, CFG, capacity=CAP,
                                    tail_cap=16, tail_max=8)
    for op, arg in ops:
        if op == "insert":
            b = min(arg, CAP - mut.n_docs)
            if b > 0:
                mut.insert_docs(*_rand_docs(rng, b))
        elif op == "delete" and mut.n_docs > 0:
            mut.delete_docs([arg % mut.n_docs])
        elif op == "compact":
            mut.compact()
    _assert_bitmatch(mut, _queries(rng), _full_budget_params())


@needs_hypothesis
@settings(max_examples=10, deadline=None)
@given(ops=OPS, seed=st.integers(0, 2**16))
def test_property_summaries_stay_upper_bounds(ops, seed):
    """(b) after ANY sequence, block summaries upper-bound live member
    scores (quantization slack) for random nonnegative queries."""
    rng = np.random.default_rng(seed)
    mut = MutableSeismicIndex.empty(DIM, NNZ, CFG, capacity=CAP,
                                    tail_cap=16, tail_max=8)
    for op, arg in ops:
        if op == "insert":
            b = min(arg, CAP - mut.n_docs)
            if b > 0:
                mut.insert_docs(*_rand_docs(rng, b))
        elif op == "delete" and mut.n_docs > 0:
            mut.delete_docs([arg % mut.n_docs])
        elif op == "compact":
            mut.compact()
    idx = mut.index
    fwd_c = np.asarray(idx.fwd.coords)
    fwd_v = np.asarray(idx.fwd.vals, np.float32)
    q = np.zeros(DIM, np.float32)
    qc, qv = _rand_docs(rng, 1)
    q[qc[0]] = qv[0]
    q[0] = 0.0
    for ell in range(idx.n_lists):
        for b in range(CFG.n_blocks):
            if int(idx.block_len[ell, b]) == 0:
                continue
            sc = np.asarray(idx.sum_coords[ell, b])
            sv = np.asarray(dequantize_u8(idx.sum_q[ell, b],
                                          idx.sum_scale[ell, b],
                                          idx.sum_zero[ell, b]))
            s_sum = float(q[sc] @ sv)
            slack = 0.5 * float(idx.sum_scale[ell, b]) * float(q.sum())
            for d in _block_members(idx, ell, b):
                exact = float(q[fwd_c[d]] @ fwd_v[d])
                assert s_sum + slack + 1e-4 >= exact
